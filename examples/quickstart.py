"""Quickstart: the paper's protocols in five minutes.

Two nodes hold adversarially-partitioned labeled 2-D data (the paper's
Data3 — the dataset where naive voting collapses to 50%); we run every
protocol from the paper and print accuracy vs. communication, reproducing
the Table 2 story end to end.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import datasets
from repro.core.protocols import baselines, one_way, two_way


def acc(clf, shards):
    X = np.concatenate([s[0] for s in shards])
    y = np.concatenate([s[1] for s in shards])
    return float(np.mean(clf.predict(X) == y))


def main():
    eps = 0.05
    shards = datasets.data3(n_per_node=500, k=2, seed=0)
    print(f"Data3: 2 nodes x 500 points, adversarial partition, eps={eps}\n")
    rows = [
        ("NAIVE (ship everything)", baselines.naive(shards)),
        ("VOTING (local classifiers)", baselines.voting(shards)),
        ("RANDOM (one-way eps-net, Thm 3.1)", baselines.random(shards, eps=eps)),
        ("MAXMARG (two-way heuristic, Sec 4.4)",
         two_way.iterative_support_maxmarg(shards, eps=eps)),
        ("MEDIAN (two-way, Thm 5.1: O(log 1/eps))",
         two_way.iterative_support_median(shards, eps=eps)),
    ]
    print(f"{'method':45s} {'accuracy':>9s} {'points':>7s} {'rounds':>7s}")
    for name, r in rows:
        print(f"{name:45s} {100 * acc(r.classifier, shards):8.1f}% "
              f"{r.comm['points']:7d} {r.rounds:7d}")

    print("\n0-error protocols for simple classes (Sec 3):")
    th = one_way.threshold_protocol(datasets.threshold_instance(n=400, k=2))
    iv = one_way.interval_protocol(datasets.interval_instance(n=400, k=2))
    rc = one_way.rectangle_protocol(datasets.rectangle_instance(n=400, k=2, d=3))
    for name, r, sh in (
        ("thresholds (Lem 3.1)", th, datasets.threshold_instance(n=400, k=2)),
        ("intervals  (Lem 3.2)", iv, datasets.interval_instance(n=400, k=2)),
        ("rectangles (Thm 3.2)", rc, datasets.rectangle_instance(n=400, k=2, d=3)),
    ):
        print(f"  {name}: acc={100 * acc(r.classifier, sh):.1f}% "
              f"cost={r.comm['points']} points")


if __name__ == "__main__":
    main()
