"""Batched serving demo: prefill a prompt batch, then greedy-decode with the
per-architecture cache (KV / MLA-latent / SSM state / hybrid).

Runs the three cache families side by side (reduced configs):
  smollm-135m   dense GQA      -> KV cache
  rwkv6-7b      attention-free -> O(1) recurrent state
  jamba-...     hybrid         -> mamba state + attention KV, MoE routing

Run:  PYTHONPATH=src python examples/serve_decode.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.models import model as M
from repro.serve.engine import ServeConfig, ServingEngine


def demo(arch: str, n_new: int = 16):
    cfg = C.get_config(arch).reduced()
    params = M.init_lm(jax.random.PRNGKey(0), cfg)
    B, S = 4, 24
    sc = ServeConfig(batch=B, cache_len=S + n_new + 1, dtype=jnp.float32,
                     enc_len=32 if cfg.enc_dec else 0)
    eng = ServingEngine(cfg, params, sc)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["vision_embed"] = jax.random.normal(jax.random.PRNGKey(2),
                                                  (B, 8, cfg.d_model)) * 0.02
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        batch["rope_pos"] = jnp.broadcast_to(pos[None], (3, B, S)).astype(jnp.int32)
    if cfg.enc_dec:
        batch["audio_embed"] = jax.random.normal(jax.random.PRNGKey(3),
                                                 (B, 32, cfg.d_model)) * 0.02
    t0 = time.time()
    logits = eng.prefill_prompt(batch)
    t1 = time.time()
    toks = eng.generate(logits[:, -1].argmax(-1), n_new)
    t2 = time.time()
    cache_leaves = len(jax.tree.leaves(eng.caches))
    cache_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(eng.caches))
    print(f"{arch:24s} prefill {1e3 * (t1 - t0):7.1f}ms  "
          f"{n_new} tokens {1e3 * (t2 - t1):7.1f}ms  "
          f"cache: {cache_leaves} leaves {cache_bytes / 1e6:.2f}MB")
    print(f"{'':24s} sample: {np.asarray(toks[0][:8]).tolist()}")


def main():
    print(f"{'arch':24s} {'prefill':>15s} {'decode':>18s}  cache")
    for arch in ("smollm-135m", "rwkv6-7b", "jamba-1.5-large-398b",
                 "deepseek-v2-236b", "whisper-medium"):
        demo(arch)


if __name__ == "__main__":
    main()
