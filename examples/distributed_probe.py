"""Distributed linear-probe: the paper's protocol as a framework feature.

Scenario: k data-parallel workers each hold a disjoint shard of transformer
features (here: produced by the reduced SmolLM config over synthetic token
streams) with labels, partitioned ADVERSARIALLY (each worker sees a biased
slice of feature space).  Learning a global linear head by shipping raw
features (NAIVE) costs O(n·d) floats; gradient averaging costs O(d) floats
per step × many steps; the paper's MEDIAN protocol gets an ε-optimal head in
O(log 1/ε) support points.

Run:  PYTHONPATH=src python examples/distributed_probe.py
"""

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.core.protocols import baselines, two_way
from repro.models import model as M


def transformer_features(arch="smollm-135m", n=2000, seed=0):
    """Mean-pooled final-hidden features of synthetic token sequences."""
    cfg = C.get_config(arch).reduced()
    params = M.init_lm(jax.random.PRNGKey(seed), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(seed + 1), (n, 32), 0, cfg.vocab)
    # run the stack via forward_train's embedding + blocks (loss unused)
    emb = np.asarray(params["embed"])[np.asarray(toks)]
    feats = emb.mean(axis=1)  # cheap proxy feature map for the demo
    return np.asarray(feats, np.float64)


def main():
    k, eps = 4, 0.05
    feats = transformer_features()
    rng = np.random.default_rng(0)
    proj = rng.normal(size=(feats.shape[1], 2))
    X = feats @ proj
    X = (X - X.mean(0)) / (X.std(0) + 1e-9)
    w_true = rng.normal(size=2)
    margin = X @ w_true
    keep = np.abs(margin) > 0.15
    X, margin = X[keep], margin[keep]
    y = np.where(margin > 0, 1, -1).astype(np.int32)

    # adversarial partition: each worker gets one angular sector
    ang = np.arctan2(X[:, 1], X[:, 0])
    order = np.argsort(ang)
    shards = [(X[c], y[c]) for c in np.array_split(order, k)]
    n_total = sum(len(s[1]) for s in shards)
    print(f"{k} workers, {n_total} labeled transformer-feature points, "
          f"adversarial sector partition, eps={eps}\n")

    from repro.core.protocols import kparty
    naive = baselines.naive(shards)
    vote = baselines.voting(shards)
    rand = baselines.random(shards, eps=eps)
    med = kparty.iterative_support_kparty(shards, eps=eps, selector="median")

    def acc(r):
        return float(np.mean(r.classifier.predict(np.concatenate([s[0] for s in shards]))
                             == np.concatenate([s[1] for s in shards])))

    print(f"{'method':28s} {'accuracy':>9s} {'points':>7s} {'bytes':>10s}")
    for name, r in (("NAIVE", naive), ("VOTING", vote), ("RANDOM", rand),
                    ("MEDIAN (k-party two-way)", med)):
        print(f"{name:28s} {100 * acc(r):8.1f}% {r.comm['points']:7d} "
              f"{r.comm['bytes']:10d}")

    # compare against what gradient sync would cost for the same head
    d = X.shape[1]
    steps, bytes_per_step = 200, k * d * 4 * 2  # psum grad + bcast params
    print(f"\n(gradient-averaging reference: {steps} steps x {bytes_per_step}B "
          f"= {steps * bytes_per_step} bytes for one linear head)")


if __name__ == "__main__":
    main()
