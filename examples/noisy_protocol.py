"""Noisy-setting demo (paper §8.2, implemented): labels flipped at 5 %/10 %,
the noise-tolerant protocol still recovers a near-clean separator with
two-orders-less communication than centralizing the noisy data.

Run:  PYTHONPATH=src python examples/noisy_protocol.py
"""

import numpy as np

from repro.core import datasets
from repro.core.protocols import baselines, two_way


def main():
    for rate in (0.05, 0.10):
        shards = datasets.data3(n_per_node=500, k=2, seed=0)
        noisy = datasets.add_label_noise(shards, rate=rate)
        r = two_way.iterative_support_noisy(noisy, eps=0.05)
        nv = baselines.naive(noisy)
        Xc = np.concatenate([s[0] for s in shards])
        yc = np.concatenate([s[1] for s in shards])
        yn = np.concatenate([s[1] for s in noisy])
        print(f"noise {100 * rate:.0f}%:")
        print(f"  noisy-MAXMARG: clean-label acc "
              f"{100 * np.mean(r.classifier.predict(Xc) == yc):5.1f}%  "
              f"noisy-label acc {100 * np.mean(r.classifier.predict(Xc) == yn):5.1f}%  "
              f"cost {r.comm['points']} points")
        print(f"  NAIVE:         clean-label acc "
              f"{100 * np.mean(nv.classifier.predict(Xc) == yc):5.1f}%  "
              f"cost {nv.comm['points']} points")


if __name__ == "__main__":
    main()
