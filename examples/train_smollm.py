"""End-to-end training driver: SmolLM-135M (full config) on the synthetic
pipeline for a few hundred steps, CPU-sized by default.

This is the same ``make_train_step`` the multi-pod dry-run lowers for the
(16,16) production mesh; here it runs eagerly on the host devices.

Run (reduced, ~2 min):
  PYTHONPATH=src python examples/train_smollm.py
Full 135M for 200 steps (slow on CPU):
  PYTHONPATH=src python examples/train_smollm.py --full --steps 200
"""

import argparse

import jax.numpy as jnp

import repro.configs as C
from repro.data.pipeline import DataConfig, synthetic_stream
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="full 135M config")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = C.get_config("smollm-135m")
    if not args.full:
        cfg = cfg.reduced()
    print(f"arch={cfg.name} params={cfg.param_count() / 1e6:.1f}M "
          f"steps={args.steps} batch={args.batch} seq={args.seq}")

    dc = DataConfig(seq_len=args.seq, global_batch=args.batch, seed=0)
    tc = TrainConfig(steps=args.steps, warmup=max(10, args.steps // 20),
                     log_every=max(1, args.steps // 20),
                     dtype=jnp.float32 if not args.full else jnp.bfloat16,
                     ckpt_dir=args.ckpt,
                     optim=AdamWConfig(lr=3e-3 if not args.full else 6e-4))
    tr = Trainer(cfg, tc, synthetic_stream(cfg, dc))
    last = tr.run()
    first = tr.history[0]["loss"]
    print(f"\nloss {first:.3f} -> {last['loss']:.3f} "
          f"({'learned' if last['loss'] < first - 0.3 else 'check config'})")


if __name__ == "__main__":
    main()
