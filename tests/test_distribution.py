"""Sharding-rule unit tests (single real CPU device: rules are validated
structurally — specs must be buildable, divisible, and cover every leaf)."""

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
import numpy as np
import pytest

import repro.configs as C
from repro.distribution.sharding import (batch_shardings, cache_shardings,
                                         opt_shardings, param_shardings)
from repro.data.pipeline import make_batch_specs
from repro.models import model as M
from repro.models.config import INPUT_SHAPES
from repro.optim.adamw import adamw_init


def tiny_mesh(shape=(1, 1), axes=("data", "model")):
    devs = np.asarray(jax.devices()[:1]).reshape(shape)
    return Mesh(devs, axes)


@pytest.mark.parametrize("arch", list(C.ARCHS))
def test_param_shardings_cover_all_leaves(arch):
    cfg = C.get_config(arch).reduced()
    mesh = tiny_mesh()
    pshapes = jax.eval_shape(lambda: M.init_lm(jax.random.PRNGKey(0), cfg))
    psh = param_shardings(mesh, pshapes, fsdp=False)
    n_params = len(jax.tree.leaves(pshapes))
    n_specs = len(jax.tree.leaves(psh, is_leaf=lambda x: isinstance(x, NamedSharding)))
    assert n_specs == n_params
    # every spec is structurally valid for its leaf on a 1x1 mesh
    for leaf, sh in zip(jax.tree.leaves(pshapes),
                        jax.tree.leaves(psh, is_leaf=lambda x: isinstance(x, NamedSharding))):
        assert isinstance(sh, NamedSharding)
        assert len([a for a in sh.spec if a is not None]) <= len(leaf.shape)


def test_divisibility_on_production_axis_sizes():
    """Specs must divide evenly for the production model-axis width (16):
    build against an AbstractMesh with the real (16, 16) shape and check
    every announced 'model'-sharded dim divides by 16, on FULL configs."""
    from jax.sharding import AbstractMesh
    try:
        AbstractMesh((16, 16), ("data", "model"))
    except TypeError:
        pytest.skip("AbstractMesh((shape), (axis_names)) signature requires "
                    "a newer jax — pre-existing version skew on this "
                    "container's jax (ROADMAP.md)")
    for arch in C.ARCHS:
        cfg = C.get_config(arch)
        pshapes = jax.eval_shape(lambda c=cfg: M.init_lm(jax.random.PRNGKey(0), c))
        mesh = AbstractMesh((16, 16), ("data", "model"))
        psh = param_shardings(mesh, pshapes, fsdp=False)
        flat_shapes = jax.tree_util.tree_flatten_with_path(pshapes)[0]
        flat_specs = jax.tree.leaves(psh, is_leaf=lambda x: isinstance(x, NamedSharding))
        for (path, leaf), sh in zip(flat_shapes, flat_specs):
            for dim, axis in enumerate(sh.spec):
                if axis == "model":
                    assert leaf.shape[dim] % 16 == 0, (arch, path, leaf.shape, dim)


def test_opt_shardings_follow_params():
    cfg = C.get_config("smollm-135m").reduced()
    mesh = tiny_mesh()
    pshapes = jax.eval_shape(lambda: M.init_lm(jax.random.PRNGKey(0), cfg))
    oshapes = jax.eval_shape(lambda: adamw_init(pshapes))
    osh = opt_shardings(mesh, oshapes, fsdp=False)
    assert len(jax.tree.leaves(osh, is_leaf=lambda x: isinstance(x, NamedSharding))) == \
        len(jax.tree.leaves(oshapes))


@pytest.mark.parametrize("shape_name", list(INPUT_SHAPES))
def test_batch_shardings_build(shape_name):
    cfg = C.get_config("smollm-135m")
    shape = INPUT_SHAPES[shape_name]
    mesh = tiny_mesh()
    specs = make_batch_specs(cfg, shape)
    bsh = batch_shardings(mesh, specs, shape)
    assert set(bsh) == set(specs)


def test_cache_shardings_long_context_seq_parallel():
    """long_500k (batch=1): KV cache must shard sequence, not batch."""
    cfg = C.get_config("smollm-135m")
    shape = INPUT_SHAPES["long_500k"]
    mesh = tiny_mesh()
    cshapes = jax.eval_shape(lambda: M.make_caches(cfg, 1, 16384, jnp.bfloat16))
    csh = cache_shardings(mesh, cshapes, shape, cfg)
    found_seq_shard = False
    flat = jax.tree_util.tree_flatten_with_path(cshapes)[0]
    specs = jax.tree.leaves(csh, is_leaf=lambda x: isinstance(x, NamedSharding))
    for (path, leaf), sh in zip(flat, specs):
        keys = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        if "k" in keys.split("/")[-1] or "v" in keys.split("/")[-1]:
            if any(a == "data" for a in sh.spec):
                found_seq_shard = True
    assert found_seq_shard
