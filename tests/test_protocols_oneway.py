"""One-way protocol tests: paper Lemma 3.1/3.2, Theorems 3.1/3.2/6.1/6.2."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import datasets
from repro.core.protocols import one_way

from conftest import global_err


# ---------------------------------------------------------------------------
# thresholds (Lemma 3.1 + Thm 6.2 k-party): 0 error, <= 2 points per hop
# ---------------------------------------------------------------------------

@given(st.integers(2, 6), st.integers(0, 10_000))
@settings(max_examples=40, deadline=None)
def test_threshold_zero_error_constant_comm(k, seed):
    shards = datasets.threshold_instance(n=50 * k, k=k, seed=seed)
    r = one_way.threshold_protocol(shards)
    assert global_err(r.classifier, shards) == 0.0
    assert r.comm["points"] <= 2 * (k - 1)  # paper: 2k one-way communication


# ---------------------------------------------------------------------------
# intervals (Lemma 3.2): 0 error, <= 4 points per hop
# ---------------------------------------------------------------------------

@given(st.integers(2, 5), st.integers(0, 10_000))
@settings(max_examples=40, deadline=None)
def test_interval_zero_error_constant_comm(k, seed):
    shards = datasets.interval_instance(n=50 * k, k=k, seed=seed)
    r = one_way.interval_protocol(shards)
    assert global_err(r.classifier, shards) == 0.0
    assert r.comm["points"] <= 4 * (k - 1)


def test_interval_empty_case():
    """A has only negatives (the paper's ∅ branch)."""
    rng = np.random.default_rng(0)
    XA = rng.uniform(2, 3, size=(20, 1))
    yA = -np.ones(20, dtype=np.int32)
    XB = rng.uniform(0, 1, size=(20, 1))
    yB = np.where((XB[:, 0] > 0.3) & (XB[:, 0] < 0.6), 1, -1)
    r = one_way.interval_protocol([(XA, yA), (XB, yB)])
    assert global_err(r.classifier, [(XA, yA), (XB, yB)]) == 0.0


# ---------------------------------------------------------------------------
# rectangles (Thm 3.2 / 6.2): 0 error, O(d) per hop
# ---------------------------------------------------------------------------

@given(st.integers(1, 6), st.integers(2, 4), st.integers(0, 10_000))
@settings(max_examples=40, deadline=None)
def test_rectangle_zero_error(d, k, seed):
    shards = datasets.rectangle_instance(n=60 * k, k=k, d=d, seed=seed)
    r = one_way.rectangle_protocol(shards)
    assert global_err(r.classifier, shards) == 0.0
    # paper: 4d values = 4 corner points per hop in our point-encoding
    assert r.comm["points"] <= 4 * (k - 1)


# ---------------------------------------------------------------------------
# ε-net sampling (Thm 3.1 / 6.1)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [2, 4])
def test_random_sampling_eps_error(k):
    eps = 0.1
    fails = 0
    for seed in range(5):
        shards = datasets.data1(n_per_node=300, k=k, seed=seed)
        r = one_way.random_sampling(shards, eps=eps, seed=seed)
        if global_err(r.classifier, shards) > eps:
            fails += 1
    assert fails <= 1  # 'with constant probability'
    assert r.extra["sample_size"] < 300  # actually cheaper than naive


def test_local_only_no_comm():
    shards = datasets.data1(n_per_node=200, k=2, seed=0)
    # random partition: re-shuffle the union so iid holds (paper Thm 2.1)
    X = np.concatenate([s[0] for s in shards])
    y = np.concatenate([s[1] for s in shards])
    rng = np.random.default_rng(0)
    perm = rng.permutation(len(y))
    half = len(y) // 2
    iid = [(X[perm[:half]], y[perm[:half]]), (X[perm[half:]], y[perm[half:]])]
    r = one_way.local_only(iid)
    assert r.comm["points"] == 0
    assert global_err(r.classifier, iid) <= 0.05


@given(st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_threshold_single_class_shards(seed):
    """Adversarial sorted split gives node 0 only positives — the ∅ case."""
    rng = np.random.default_rng(seed)
    x = np.sort(rng.uniform(-1, 1, size=60))
    t = rng.uniform(-0.5, 0.5)
    y = np.where(x < t, 1, -1).astype(np.int32)
    half = len(x) // 2
    shards = [(x[:half].reshape(-1, 1), y[:half]),
              (x[half:].reshape(-1, 1), y[half:])]
    r = one_way.threshold_protocol(shards)
    assert global_err(r.classifier, shards) == 0.0


@given(st.integers(1, 4), st.integers(2, 4), st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_rectangle_no_positives_anywhere(d, k, seed):
    """∅ sentinel on the positive class across EVERY shard (used to raise
    TypeError): the result must be the always-negative rectangle."""
    rng = np.random.default_rng(seed)
    shards = [(rng.uniform(-1, 1, size=(15, d)), -np.ones(15, np.int32))
              for _ in range(k)]
    r = one_way.rectangle_protocol(shards)
    assert global_err(r.classifier, shards) == 0.0
    assert np.all(r.classifier.predict(rng.uniform(-3, 3, size=(40, d))) == -1)


@given(st.integers(1, 4), st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_rectangle_one_class_missing(d, seed):
    """One node holds only negatives (outside points) — ∅ sentinel path."""
    rng = np.random.default_rng(seed)
    lo, hi = -0.4 * np.ones(d), 0.4 * np.ones(d)
    Xin = rng.uniform(-0.35, 0.35, size=(30, d))
    Xout = rng.uniform(0.6, 1.0, size=(30, d)) * rng.choice([-1, 1], size=(30, d))
    shards = [(Xout[:15], -np.ones(15, np.int32)),
              (np.concatenate([Xin, Xout[15:]]),
               np.concatenate([np.ones(30, np.int32), -np.ones(15, np.int32)]))]
    r = one_way.rectangle_protocol(shards)
    assert global_err(r.classifier, shards) == 0.0
