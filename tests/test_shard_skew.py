"""Shard-skew observability gates (ISSUE 7 satellite; ROADMAP's
skewed-shard item).

``hotloop.shard_skew`` turns :func:`hotloop.balanced_index`'s per-shard
live counts into the max/mean padding-waste ratio, and ``run_hot`` folds
it into a caller-supplied ``stats`` dict on every sharded dispatch.  This
module property-tests both halves over adversarially skewed live masks —
all-in-one-shard, alternating, single survivor, saturated, empty — plus
seeded random masks: the balanced index must partition the live set
exactly (each row once, in its own shard's slice, pad tail all
out-of-range), and the skew ratio must report 1.0 at balance, S at full
concentration and 0.0 when nothing is live.

Needs >1 device only for the end-to-end stats-threading case (same
XLA_FLAGS arrangement as tests/test_engine_sharded.py); the pure-host
properties run anywhere.
"""

import os
import sys

if "jax" not in sys.modules:                     # must precede jax init
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax

from repro import engine
from repro.core import datasets
from repro.engine import hotloop


# ---------------------------------------------------------------------------
# shard_skew
# ---------------------------------------------------------------------------


def test_skew_balanced_is_one():
    assert hotloop.shard_skew(np.array([5, 5, 5, 5])) == 1.0


def test_skew_all_dead_is_zero():
    assert hotloop.shard_skew(np.zeros(4, np.int32)) == 0.0
    assert hotloop.shard_skew(np.array([])) == 0.0


def test_skew_full_concentration_is_shard_count():
    for s in (2, 4, 8):
        counts = np.zeros(s, np.int32)
        counts[0] = 17
        assert hotloop.shard_skew(counts) == float(s)


def test_skew_monotone_under_concentration():
    """Moving live rows from a lighter shard to the heaviest one never
    decreases the ratio (same total, worse balance)."""
    counts = np.array([8, 8, 8, 8])
    prev = hotloop.shard_skew(counts)
    while counts[1] > 0:
        counts[0] += 1
        counts[1] -= 1
        cur = hotloop.shard_skew(counts)
        assert cur >= prev
        prev = cur
    assert prev == hotloop.shard_skew(np.array([16, 0, 8, 8]))


# ---------------------------------------------------------------------------
# balanced_index partition properties
# ---------------------------------------------------------------------------


def _check_partition(act, B, S):
    """The balanced index must be a padded exact partition of ``act``."""
    act = np.asarray(act, np.int64)
    idx, n_act = hotloop.balanced_index(act, B, S)
    B_loc = B // S
    L = idx.size // S
    assert idx.size == S * L
    assert L % hotloop.BATCH_MULT == 0 and L >= hotloop.BATCH_MULT
    assert n_act.shape == (S,)
    np.testing.assert_array_equal(
        n_act, np.bincount(act // B_loc, minlength=S))
    assert L >= int(n_act.max(initial=0))       # every live row covered
    recovered = []
    for s in range(S):
        sl = idx[s * L:(s + 1) * L]
        c = int(n_act[s])
        assert (sl[c:] == B).all()              # pad tail: scatter-drop OOB
        local = sl[:c]
        assert ((0 <= local) & (local < B_loc)).all()
        recovered.extend((local.astype(np.int64) + s * B_loc).tolist())
    # exactly the live set, each row once, ordered within its shard
    assert recovered == sorted(act.tolist())
    return n_act


ADVERSARIAL = [
    ("one_shard_full", lambda B, S: np.arange(B // S)),
    ("last_shard_only", lambda B, S: np.arange(B - B // S, B)),
    ("alternating", lambda B, S: np.arange(0, B, 2)),
    ("single_survivor", lambda B, S: np.array([B - 1])),
    ("one_per_shard", lambda B, S: np.arange(S) * (B // S)),
    ("saturated", lambda B, S: np.arange(B)),
    ("empty", lambda B, S: np.array([], np.int64)),
]


@pytest.mark.parametrize("name,gen", ADVERSARIAL, ids=[n for n, _ in ADVERSARIAL])
@pytest.mark.parametrize("B,S", [(16, 2), (32, 4), (64, 8)])
def test_balanced_index_adversarial(name, gen, B, S):
    act = np.sort(np.asarray(gen(B, S), np.int64))
    n_act = _check_partition(act, B, S)
    skew = hotloop.shard_skew(n_act)
    if name == "empty":
        assert skew == 0.0
    elif name in ("one_shard_full", "last_shard_only", "single_survivor"):
        assert skew == float(S)                 # worst case: one shard owns all
    elif name in ("saturated", "one_per_shard"):
        assert skew == 1.0
    else:
        assert 1.0 <= skew <= float(S)


@pytest.mark.parametrize("B,S", [(16, 2), (32, 4), (64, 8), (48, 4)])
def test_balanced_index_random_masks(B, S):
    rng = np.random.default_rng(B * 31 + S)
    for trial in range(50):
        # bias some trials hard toward one shard to walk the skew range
        p = rng.uniform(0.05, 0.95)
        mask = rng.random(B) < p
        if trial % 3 == 0:
            mask[B // S:] &= rng.random(B - B // S) < 0.1
        act = np.flatnonzero(mask)
        if act.size == 0:
            continue
        n_act = _check_partition(act, B, S)
        assert 1.0 <= hotloop.shard_skew(n_act) <= float(S)


# ---------------------------------------------------------------------------
# stats threading through the sharded hot loop
# ---------------------------------------------------------------------------


def test_single_device_sweep_accepts_stats_dict():
    """An unsharded sweep takes the stats dict without touching the shard
    keys (no balanced_index call) and without perturbing results."""
    insts = [engine.ProtocolInstance(
        datasets.data1(n_per_node=24, k=2, seed=i), 0.1) for i in range(4)]
    stats = {}
    res = engine.run_sweep(insts, n_angles=64, max_epochs=8, stats=stats)
    assert all(r.converged for r in res)
    assert "shard_skew_max" not in stats
    assert "shard_dispatches" not in stats


@pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="sharded stats threading needs >1 device "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
def test_sharded_sweep_records_skew():
    """A staggered-convergence sharded sweep must fold every
    balanced_index call's skew into the stats dict."""
    from repro.launch.mesh import make_data_mesh
    mesh = make_data_mesh()
    gens = (datasets.data1, datasets.data2, datasets.data3)
    insts = [engine.ProtocolInstance(
        gens[i % 3](n_per_node=40, k=2, seed=i), (0.1, 0.05)[i % 2])
        for i in range(16)]
    stats = {}
    res = engine.run_sweep(insts, n_angles=128, max_epochs=16,
                           mesh=mesh, stats=stats)
    assert all(r.converged for r in res)
    assert stats["shard_dispatches"] >= 1
    n_dev = len(mesh.devices.ravel())
    assert 1.0 <= stats["shard_skew_last"] <= float(n_dev)
    assert stats["shard_skew_last"] <= stats["shard_skew_max"] <= float(n_dev)
