"""Roofline machinery tests: the while-aware HLO cost model must multiply
loop bodies by trip count (XLA's cost_analysis does not — the reason this
model exists) and count dot flops / collective wire bytes correctly."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo_cost import analyze_hlo
from repro.analysis.roofline import parse_collectives, _ring_factor


def _compiled(fn, *shapes):
    args = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    return jax.jit(fn).lower(*args).compile()


def test_single_matmul_flops_exact():
    c = _compiled(lambda a, b: a @ b, (64, 128), (128, 32))
    r = analyze_hlo(c.as_text())
    assert r["flops"] == pytest.approx(2 * 64 * 128 * 32, rel=0.01)


def test_scan_multiplies_flops_by_trip_count():
    def f(x):
        def body(c, _):
            return c @ c, None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out
    c = _compiled(f, (128, 128))
    r = analyze_hlo(c.as_text())
    single = analyze_hlo(_compiled(lambda x: x @ x, (128, 128)).as_text())
    assert r["flops"] == pytest.approx(10 * single["flops"], rel=0.05)
    # XLA's own counter reports the body once — document the discrepancy.
    # (Older jax returns cost_analysis() as a [dict]; normalize, and skip
    # the XLA-counter comparison when flops are not exposed at all.)
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    if "flops" not in ca:
        pytest.skip("compiled.cost_analysis() exposes no flops on this jax")
    assert float(ca["flops"]) < r["flops"] / 5


def test_nested_scan_multiplies_product():
    def f(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ ci, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        out, _ = jax.lax.scan(outer, x, None, length=4)
        return out
    c = _compiled(f, (64, 64))
    r = analyze_hlo(c.as_text())
    single = analyze_hlo(_compiled(lambda x: x @ x, (64, 64)).as_text())
    assert r["flops"] == pytest.approx(12 * single["flops"], rel=0.05)


def test_batched_dot_flops():
    c = _compiled(lambda a, b: jnp.einsum("bij,bjk->bik", a, b),
                  (8, 32, 64), (8, 64, 16))
    r = analyze_hlo(c.as_text())
    assert r["flops"] == pytest.approx(2 * 8 * 32 * 64 * 16, rel=0.01)


def test_bytes_nonzero_and_sane():
    c = _compiled(lambda a: a * 2.0 + 1.0, (1024, 1024))
    r = analyze_hlo(c.as_text())
    nbytes = 1024 * 1024 * 4
    # at least read + write; fused elementwise should stay within a few x
    assert nbytes * 1.5 <= r["bytes"] <= nbytes * 6


def test_ring_factors():
    assert _ring_factor("all-gather", 8) == pytest.approx(7 / 8)
    assert _ring_factor("all-reduce", 8) == pytest.approx(2 * 7 / 8)
    assert _ring_factor("reduce-scatter", 8) == 7.0
    assert _ring_factor("all-gather", 1) == 0.0


def test_parse_collectives_from_text():
    hlo = """
ENTRY %main (p: f32[16,128]) -> f32[16,128] {
  %p = f32[16,128]{1,0} parameter(0)
  ROOT %ar = f32[16,128]{1,0} all-reduce(%p), replica_groups=[4,8]<=[32], to_apply=%add
}
"""
    r = parse_collectives(hlo)
    payload = 16 * 128 * 4
    assert r["counts"]["all-reduce"] == 1
    assert r["total_bytes"] == int(payload * 2 * 7 / 8)
