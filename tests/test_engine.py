"""Batched protocol engine: B=1 parity, sweep parity, padding invariance.

The acceptance bar: a batched sweep of ≥ 32 MEDIAN/kparty instances (varying
ε and seed) must produce, for every instance, the same converged flag, global
error ≤ ε, and identical comm totals as the per-instance path.
"""

import numpy as np
import pytest

from repro import engine
from repro.core import datasets
from repro.core.protocols import kparty, two_way

from conftest import global_err

N_ANGLES = 512
MAX_EPOCHS = 32


def _sweep_instances():
    """36 instances: dataset × ε × seed, k=2."""
    out = []
    for gen in (datasets.data1, datasets.data2, datasets.data3):
        for eps in (0.2, 0.1, 0.05, 0.025):
            for seed in (0, 1, 2):
                shards = gen(n_per_node=100, k=2, seed=seed)
                out.append(engine.ProtocolInstance(shards, eps))
    return out


def test_batched_sweep_matches_per_instance_path():
    insts = _sweep_instances()
    assert len(insts) >= 32
    batched = engine.run_instances(insts, n_angles=N_ANGLES,
                                   max_epochs=MAX_EPOCHS)
    for inst, rb in zip(insts, batched):
        rs = kparty.iterative_support_kparty(
            inst.shards, eps=inst.eps, max_epochs=MAX_EPOCHS,
            n_angles=N_ANGLES, selector="median")
        assert rb.converged == rs.converged
        assert rb.converged, f"instance eps={inst.eps} did not converge"
        assert rb.comm == rs.comm, (inst.eps, rb.comm, rs.comm)
        assert rb.rounds == rs.rounds
        assert global_err(rb.classifier, inst.shards) <= inst.eps
        np.testing.assert_allclose(rb.classifier.w, rs.classifier.w)
        assert rb.classifier.b == rs.classifier.b


def test_kparty_batch_matches_per_instance_path():
    insts = [engine.ProtocolInstance(
                 datasets.data3(n_per_node=75, k=4, seed=s), eps)
             for s in (0, 1) for eps in (0.1, 0.05)]
    batched = engine.run_instances(insts, n_angles=N_ANGLES,
                                   max_epochs=MAX_EPOCHS)
    for inst, rb in zip(insts, batched):
        rs = kparty.iterative_support_kparty(
            inst.shards, eps=inst.eps, max_epochs=MAX_EPOCHS,
            n_angles=N_ANGLES, selector="median")
        assert rb.converged == rs.converged and rb.converged
        assert rb.comm == rs.comm
        assert global_err(rb.classifier, inst.shards) <= inst.eps


def test_padding_invariance():
    """An instance's outcome must not depend on its batch neighbours: ragged
    shard sizes are padded with label-0 rows, which every masked reduction
    ignores."""
    small = engine.ProtocolInstance(
        datasets.data1(n_per_node=60, k=2, seed=3), 0.05)
    big = engine.ProtocolInstance(
        datasets.data3(n_per_node=200, k=2, seed=4), 0.05)
    alone = engine.run_instances([small], n_angles=N_ANGLES,
                                 max_epochs=MAX_EPOCHS)[0]
    padded = engine.run_instances([small, big], n_angles=N_ANGLES,
                                  max_epochs=MAX_EPOCHS)[0]
    assert alone.comm == padded.comm
    assert alone.converged == padded.converged
    assert alone.rounds == padded.rounds
    np.testing.assert_array_equal(alone.classifier.w, padded.classifier.w)


def test_public_api_runs_on_engine():
    shards = datasets.data2(n_per_node=100, k=2, seed=0)
    r = two_way.iterative_support_median(shards, eps=0.05)
    assert r.extra and r.extra.get("engine") and r.extra["batch"] == 1
    assert r.converged
    assert global_err(r.classifier, shards) <= 0.05


def test_eps_shrinks_uncertainty_not_comm_explosion():
    """Thm 5.1 shape through the engine: halving ε repeatedly adds only
    O(1) epochs per halving."""
    shards = datasets.data3(n_per_node=200, k=2, seed=1)
    insts = [engine.ProtocolInstance(shards, eps)
             for eps in (0.2, 0.1, 0.05, 0.025)]
    rs = engine.run_instances(insts, n_angles=N_ANGLES, max_epochs=MAX_EPOCHS)
    rounds = [r.rounds for r in rs]
    assert all(r.converged for r in rs)
    assert rounds[-1] <= rounds[0] + 8


def test_transcript_capacity_never_overflows():
    """The static capacity bound must hold for the worst observed fill."""
    insts = _sweep_instances()[:8]
    data, state0, k, cap = engine.pack_instances(
        insts, n_angles=N_ANGLES, max_epochs=MAX_EPOCHS)
    import jax.numpy as jnp
    from repro.core import geometry as geo
    V = jnp.asarray(geo.direction_grid(N_ANGLES), jnp.float32)
    final = engine.run_compiled(data, V, state0, k=k,
                                max_turns=k * MAX_EPOCHS)
    assert int(np.max(np.asarray(final.w_fill))) <= cap - 2


def test_first_turn_constant_fold_is_exact():
    """The hoisted first turn (median-cut scan folded to index 0) must
    produce a state identical to the general step on the fresh state."""
    import jax.numpy as jnp
    from repro.core import geometry as geo
    from repro.engine import median as M

    insts = _sweep_instances()[:5]
    data, state0, k, _ = engine.pack_instances(
        insts, n_angles=N_ANGLES, max_epochs=MAX_EPOCHS)
    V = jnp.asarray(geo.direction_grid(N_ANGLES), jnp.float32)
    s_fold = M.step(data, V, state0, k=k, first_turn=True)
    s_full = M.step(data, V, state0, k=k, first_turn=False)
    for name, a, b in zip(s_fold._fields, s_fold, s_full):
        a_leaves = a if not hasattr(a, "_fields") else list(a)
        b_leaves = b if not hasattr(b, "_fields") else list(b)
        if hasattr(a, "_fields"):
            for fa, fb in zip(a_leaves, b_leaves):
                np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))
        else:
            np.testing.assert_array_equal(np.asarray(a_leaves),
                                          np.asarray(b_leaves), err_msg=name)


def test_incremental_ranges_match_kernel_rescan():
    """The running per-node (lo, hi) maintained at append time must match a
    full threshold_ranges rescan of the final transcript buffers — through
    both the jitted-JAX reference and the batch-grid Pallas kernel.  The
    tolerance is 1 f32 ulp: the incremental path projects via a broadcast
    multiply-add while the kernels use a d-contraction dot, which XLA may
    fuse (FMA) differently."""
    insts = _sweep_instances()[:6]
    data, state0, k, _ = engine.pack_instances(
        insts, n_angles=64, max_epochs=MAX_EPOCHS)
    import jax.numpy as jnp
    from repro.core import geometry as geo
    V = jnp.asarray(geo.direction_grid(64), jnp.float32)
    final = engine.run_compiled(data, V, state0, k=k,
                                max_turns=k * MAX_EPOCHS)
    for j in range(k):
        for use_pallas in (False, True):
            lo, hi = engine.dataplane.ranges(
                V, final.wx[:, j], final.wy[:, j], use_pallas=use_pallas)
            for got, want in ((lo, final.lo_w[:, j]), (hi, final.hi_w[:, j])):
                got, want = np.asarray(got), np.asarray(want)
                fin = np.isfinite(want)
                np.testing.assert_array_equal(np.isfinite(got), fin)
                np.testing.assert_allclose(got[fin], want[fin], rtol=1e-6)


def test_sou_helper_padding_rows_inert():
    insts = _sweep_instances()[:4]
    data, state0, k, _ = engine.pack_instances(
        insts, n_angles=N_ANGLES, max_epochs=MAX_EPOCHS)
    import jax.numpy as jnp
    from repro.core import geometry as geo
    V = jnp.asarray(geo.direction_grid(N_ANGLES), jnp.float32)
    lo, hi = engine.dataplane.ranges(
        V, state0.wx[:, 0], state0.wy[:, 0], use_pallas=False)
    mask = engine.dataplane.uncertain(
        V, state0.dir_ok, lo, hi, data.X[:, 0], data.y[:, 0],
        use_pallas=False)
    # empty transcript: every real point uncertain, every padding row not
    np.testing.assert_array_equal(np.asarray(mask),
                                  np.asarray(data.y[:, 0] != 0))
