"""Training-loop + serving-engine + checkpoint integration tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

if not hasattr(jax.sharding, "get_abstract_mesh"):
    pytest.skip(
        "model stack requires jax.sharding.get_abstract_mesh (jax >= 0.5.x); "
        "pre-existing version skew on this container's jax, unrelated to the "
        "protocol/engine code (ROADMAP.md)", allow_module_level=True)

import repro.configs as C
from repro.data.pipeline import DataConfig, synthetic_stream
from repro.models import model as M
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.serve.engine import ServeConfig, ServingEngine
from repro.train.checkpoint import load_checkpoint, save_checkpoint
from repro.train.trainer import TrainConfig, Trainer, make_train_step


def test_loss_decreases_tiny_model():
    cfg = C.get_config("smollm-135m").reduced()
    dc = DataConfig(seq_len=64, global_batch=8, seed=0)
    tc = TrainConfig(steps=30, warmup=5, log_every=10, dtype=jnp.float32,
                     optim=AdamWConfig(lr=3e-3))
    tr = Trainer(cfg, tc, synthetic_stream(cfg, dc))
    tr.run()
    first = tr.history[0]["loss"]
    last = tr.history[-1]["loss"]
    assert last < first - 0.5, (first, last)


def test_microbatch_grad_equivalence():
    """microbatches=2 must produce the same update as microbatches=1."""
    cfg = C.get_config("smollm-135m").reduced()
    params = M.init_lm(jax.random.PRNGKey(0), cfg)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab),
        "targets": jax.random.randint(jax.random.PRNGKey(2), (4, 32), 0, cfg.vocab),
    }
    outs = []
    for mb in (1, 2):
        tc = TrainConfig(dtype=jnp.float32, microbatches=mb, optim=AdamWConfig())
        step = jax.jit(make_train_step(cfg, tc))
        p2, _, m = step(params, adamw_init(params), batch)
        outs.append((p2, float(m["loss"])))
    (pa, la), (pb, lb) = outs
    assert la == pytest.approx(lb, rel=1e-4)
    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_checkpoint_roundtrip(tmp_path):
    cfg = C.get_config("smollm-135m").reduced()
    params = M.init_lm(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    save_checkpoint(str(tmp_path), params, opt, step=7)
    p2, o2, step = load_checkpoint(str(tmp_path))
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(opt), jax.tree.leaves(o2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("arch", ["smollm-135m", "rwkv6-7b", "jamba-1.5-large-398b",
                                  "whisper-medium"])
def test_serving_engine_generates(arch):
    cfg = C.get_config(arch).reduced()
    params = M.init_lm(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    sc = ServeConfig(batch=B, cache_len=64, dtype=jnp.float32,
                     enc_len=32 if cfg.enc_dec else 0)
    eng = ServingEngine(cfg, params, sc)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["vision_embed"] = jax.random.normal(jax.random.PRNGKey(2), (B, 8, cfg.d_model)) * 0.02
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        batch["rope_pos"] = jnp.broadcast_to(pos[None], (3, B, S)).astype(jnp.int32)
    if cfg.enc_dec:
        batch["audio_embed"] = jax.random.normal(jax.random.PRNGKey(3), (B, 32, cfg.d_model)) * 0.02
    logits = eng.prefill_prompt(batch)
    first = logits[:, -1, :].argmax(-1)
    toks = eng.generate(first, n_tokens=5)
    assert toks.shape == (B, 5)
    assert np.all((toks >= 0) & (toks < cfg.vocab))


def test_greedy_decode_deterministic():
    cfg = C.get_config("smollm-135m").reduced()
    params = M.init_lm(jax.random.PRNGKey(0), cfg)
    outs = []
    for _ in range(2):
        sc = ServeConfig(batch=1, cache_len=32, dtype=jnp.float32)
        eng = ServingEngine(cfg, params, sc)
        batch = {"tokens": jnp.arange(8)[None] % cfg.vocab}
        logits = eng.prefill_prompt(batch)
        outs.append(eng.generate(logits[:, -1].argmax(-1), 6))
    np.testing.assert_array_equal(outs[0], outs[1])
