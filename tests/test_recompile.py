"""Recompile-count regression gate for the hot loop (ISSUE 6 satellite).

The compacted hot path promises its compile cache keys only on
``(n_pad, width, warm)`` (plus the constant-folded first turn): widths are
monotone per sweep and batch padding is quantized, so a multi-epoch sweep
compiles a handful of step variants and *re-running the same sweep compiles
nothing*.  Every compacted dispatch appends its key to
``hotloop.KEY_LOG``; this module pins

* lowering count ≤ distinct logged keys (no hidden cache dimension — e.g.
  shard-aware padding reintroducing a per-turn recompile), and
* a second identical sweep adds zero lowerings (perfect cross-sweep reuse).

Counts come from the jit caches themselves (``_cache_size()``), so the gate
holds for whatever the dispatches lower, not a wrapper's opinion of it.
"""

import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax

from repro import engine
from repro.core import datasets
from repro.engine import hotloop, maxmarg, median, unified

N_ANGLES = 256
MAX_EPOCHS = 24
_GENS = (datasets.data1, datasets.data2, datasets.data3)


def _grid(n, selector="median"):
    """Staggered convergence (mixed datasets/eps/seeds) so the sweep walks
    several width buckets and batch-compaction sizes."""
    return [engine.ProtocolInstance(
        _GENS[i % 3](n_per_node=40, k=2, seed=i),
        (0.1, 0.05, 0.02)[i % 3], selector) for i in range(n)]


def _median_lowerings():
    return median._step_jit._cache_size() + median._hot_turn._cache_size()


def _maxmarg_lowerings():
    return maxmarg._step_jit._cache_size() + maxmarg._hot_turn._cache_size()


def _unified_lowerings():
    return unified._step_jit._cache_size() + unified._hot_turn._cache_size()


def test_median_cache_keys_only_on_npad_width_warm():
    jax.clear_caches()
    hotloop.KEY_LOG.clear()
    # tight near-margin bands → multi-turn sweeps that walk several width
    # buckets and batch-compaction sizes
    insts = [engine.ProtocolInstance(
        datasets.data_mixed_hardness(n_per_node=60, k=4, seed=s), eps)
        for s in range(5) for eps in (0.05, 0.02)]
    first = engine.run_instances(insts, n_angles=N_ANGLES,
                                 max_epochs=MAX_EPOCHS)
    keys = set(hotloop.KEY_LOG)
    assert len(keys) >= 3, "grid too easy to exercise the cache"
    n_low = _median_lowerings()
    assert 0 < n_low <= len(keys), (n_low, sorted(keys))

    # identical sweep again: every dispatch hits the cache
    hotloop.KEY_LOG.clear()
    second = engine.run_instances(insts, n_angles=N_ANGLES,
                                  max_epochs=MAX_EPOCHS)
    assert set(hotloop.KEY_LOG) == keys
    assert _median_lowerings() == n_low, "re-running the same sweep recompiled"
    for a, b in zip(first, second):
        assert a.comm == b.comm and a.rounds == b.rounds


def test_maxmarg_cache_keys_only_on_npad_width_warm():
    jax.clear_caches()
    hotloop.KEY_LOG.clear()
    insts = _grid(10, selector="maxmarg")
    engine.maxmarg.run_instances(insts, max_epochs=MAX_EPOCHS)
    keys = set(hotloop.KEY_LOG)
    n_low = _maxmarg_lowerings()
    assert 0 < n_low <= len(keys), (n_low, sorted(keys))
    # the warm gate is part of the key: both branches may appear, nothing else
    assert all(isinstance(w, (bool, np.bool_)) for (_, _, w, _) in keys)

    hotloop.KEY_LOG.clear()
    engine.maxmarg.run_instances(insts, max_epochs=MAX_EPOCHS)
    assert _maxmarg_lowerings() == n_low, \
        "re-running the same sweep recompiled"


def test_unified_mixed_cache_ignores_selector_mix():
    """The unified dispatch's whole point: the compiled variants key on
    shapes and statics, NEVER on which rows run which protocol — so a
    permuted admission order of the same mixed grid (different selector
    interleaving, same per-row data) adds zero lowerings."""
    jax.clear_caches()
    hotloop.KEY_LOG.clear()
    insts = [engine.ProtocolInstance(
        _GENS[i % 3](n_per_node=40, k=2, seed=i),
        (0.1, 0.05, 0.05)[i % 3],
        ("median", "maxmarg", "sampling")[i % 3], seed=i)
        for i in range(9)]
    first = engine.run_sweep(insts, n_angles=64, max_epochs=8,
                             unified_dispatch=True)
    keys = set(hotloop.KEY_LOG)
    n_low = _unified_lowerings()
    assert 0 < n_low <= len(keys), (n_low, sorted(keys))

    # reversed mix: the hot loop's width/compaction choices are functions
    # of the *set* of live rows, so every dispatch hits the cache
    hotloop.KEY_LOG.clear()
    perm = list(reversed(insts))
    second = engine.run_sweep(perm, n_angles=64, max_epochs=8,
                              unified_dispatch=True)
    assert set(hotloop.KEY_LOG) == keys
    assert _unified_lowerings() == n_low, \
        "re-ordering the selector mix recompiled"
    for a, b in zip(first, reversed(second)):
        assert a.comm == b.comm and a.rounds == b.rounds


def test_unified_pool_single_pinned_key_zero_steady_recompiles():
    """ISSUE 10 acceptance: a mixed MEDIAN+MAXMARG+SAMPLING stream through
    ONE SessionPool uses one pinned dispatch key, and a second pool with a
    different admission order adds zero lowerings."""
    from repro.engine.session_pool import PoolConfig, SessionPool

    jax.clear_caches()
    hotloop.KEY_LOG.clear()
    insts = [engine.ProtocolInstance(
        _GENS[i % 3](n_per_node=16, k=2, seed=i),
        (0.1, 0.05, 0.05)[i % 3],
        ("median", "maxmarg", "sampling")[i % 3], seed=i)
        for i in range(6)]

    def run(order):
        pool = SessionPool(PoolConfig(slots=4, k=2, n_pad=16,
                                      selector="unified", n_angles=64,
                                      max_epochs=8))
        for inst in order:
            pool.submit(inst.shards, eps=inst.eps, selector=inst.selector,
                        seed=inst.seed)
        pool.run()
        return pool

    run(insts)
    keys = set(hotloop.KEY_LOG)
    assert len(keys) == 1, sorted(keys)     # the single pinned dispatch key
    n_low = unified._hot_turn._cache_size()
    assert n_low == 1

    hotloop.KEY_LOG.clear()
    run(list(reversed(insts)))
    assert set(hotloop.KEY_LOG) == keys
    assert unified._hot_turn._cache_size() == n_low, \
        "a second mixed pool recompiled"


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="sharded recompile gate needs >1 device")
def test_sharded_cache_keys_stable():
    """The shard-balanced index pads every slice to a common L, so the
    sharded sub-dispatch keys on (S·L, width, warm) exactly like the
    single-device path keys on n_pad — and a rerun compiles nothing."""
    from repro.launch.mesh import make_data_mesh

    from repro.engine.state import shard_specs

    mesh = make_data_mesh()
    jax.clear_caches()
    hotloop.KEY_LOG.clear()
    insts = _grid(len(mesh.devices) + 3)
    engine.run_instances(insts, n_angles=N_ANGLES, max_epochs=MAX_EPOCHS,
                         mesh=mesh)
    keys = set(hotloop.KEY_LOG)
    S = len(mesh.devices)
    # every key's n_pad is a whole number of equal per-shard slices
    assert all(n_pad % S == 0 for (n_pad, _w, _warm, _first) in keys), keys

    # the factory caches per (mesh, specs, opts, donate) — re-resolving with
    # the sweep's own arguments returns the very jits the run used
    data, state0, k, _cap = engine.pack_instances(
        insts, n_angles=N_ANGLES, max_epochs=MAX_EPOCHS, mesh=mesh)
    full_j, sub_j = median._sharded_dispatches(
        mesh, shard_specs(data), shard_specs(state0), (k, False, False), True)
    n_low = full_j._cache_size() + sub_j._cache_size()
    assert 0 < n_low <= len(keys), (n_low, sorted(keys))

    hotloop.KEY_LOG.clear()
    engine.run_instances(insts, n_angles=N_ANGLES, max_epochs=MAX_EPOCHS,
                         mesh=mesh)
    assert set(hotloop.KEY_LOG) == keys
    assert full_j._cache_size() + sub_j._cache_size() == n_low, \
        "re-running the same sharded sweep recompiled"
