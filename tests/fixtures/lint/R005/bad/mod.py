"""Known-bad R005: Python control flow on traced values — crashes at
trace time at best, silently bakes one branch into the dispatch at
worst."""

import functools

import jax
import jax.numpy as jnp
from jax import lax


@jax.jit
def branch_on_sum(x):
    s = jnp.sum(x)
    if s > 0:                    # BAD: tracer in `if`
        return s
    return -s


@functools.partial(jax.jit, static_argnames=("k",))
def loop_on_tracer(x, *, k):
    while x.sum() > k:           # BAD: tracer in `while`
        x = x - 1
    return x


def body(carry, inp):
    assert carry > 0             # BAD: assert-on-tracer inside scan body
    return carry + inp, inp


def run(xs):
    return lax.scan(body, 0.0, xs)


def step(data, state):
    if state.mean() > 0:         # BAD: traced via module-level jax.jit
        return state
    return -state


_step_jit = jax.jit(step)
