"""Known-clean R005: branching only on static params and structure —
traced values go through jnp.where/lax.cond."""

import functools

import jax
import jax.numpy as jnp
from jax import lax


@functools.partial(jax.jit, static_argnames=("first_turn", "trans_width"))
def step(data, state, *, first_turn, trans_width):
    if first_turn:                       # static: part of the compile key
        state = state + 1
    if trans_width is not None:          # static width selection
        data = data[:, :trans_width]
    if state is None:                    # structural: tracers are never None
        return data
    B = state.shape[0]
    if B > 4:                            # shapes are static under trace
        data = data[:4]
    branched = jnp.where(state > 0, state, -state)   # traced branch: where
    return lax.cond(jnp.all(branched > 0).astype(bool),
                    lambda s: s, lambda s: -s, branched)


def body(carry, inp):
    new = carry + inp
    return new, jnp.where(new > 0, new, 0.0)


def run(xs):
    return lax.scan(body, 0.0, xs)
