"""Known-clean R006: the committed kernel discipline — program ids feed
``pl.when`` predicates (comparisons, never raw indices), any address
derived from a pid is clamped, the scratch accumulator is as wide as the
output, and the entry point has a jnp twin in the sibling ref.py."""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(x_ref, o_ref, acc):
    ni = pl.program_id(0)
    num_n = pl.num_programs(0)

    @pl.when(ni == 0)                      # comparison: not an index
    def _init():
        acc[0, 0] = jnp.float32(0.0)

    lo = jnp.minimum(ni * 8, x_ref.shape[0] - 8)   # clamped address
    v = pl.load(x_ref, (pl.dslice(lo, 8),))
    acc[0, 0] = acc[0, 0] + jnp.sum(v)

    @pl.when(ni == num_n - 1)
    def _flush():
        o_ref[0] = acc[0, 0]


def scan_rows(x):
    return pl.pallas_call(
        _scan_kernel,
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, 1), jnp.float32)],
        grid=(8,),
    )(x)
