"""jnp reference twin for the clean kernel fixture — the differential
oracle the parity tests dispatch on."""

import jax.numpy as jnp


def scan_rows_ref(x):
    return jnp.sum(x, keepdims=True)[:1]
