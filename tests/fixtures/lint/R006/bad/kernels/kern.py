"""Known-bad R006: all three hygiene violations — unclamped program-id
addressing, a pallas entry with no jnp ref counterpart (no sibling
ref.py at all), and a bfloat16 scratch accumulating into an f32 out."""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(x_ref, o_ref, acc):
    ni = pl.program_id(0)
    base = ni * 8                                  # pid-derived, unclamped
    v = pl.load(x_ref, (base,))                    # BAD: past padded extent
    o_ref[base] = v                                # BAD: unclamped store
    acc[0, 0] = acc[0, 0] + v


def scan_rows(x):                                  # BAD: no ref.py twin
    return pl.pallas_call(
        _scan_kernel,
        out_shape=jax.ShapeDtypeStruct((x.shape[0],), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, 1), jnp.bfloat16)],   # BAD: narrow
        grid=(8,),
    )(x)
