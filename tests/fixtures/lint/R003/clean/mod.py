"""Known-clean R003: the committed hot-loop discipline — one blessed
view pull per turn; every host decision derives from it."""

import numpy as np


def run_hot(state, dispatch, host_view, k, cap):
    t = 0
    # pre-loop pull: scope is the turn loop only, setup may sync freely
    t = int(np.asarray(state.turn).max(initial=0))
    while t < cap:
        vh = host_view(state, t % k)           # blessed producer
        view = np.asarray(vh)                  # blessed chain
        done, warm_ok, fills = view
        if bool(done.all()):                   # host data: free to branch
            break
        width = int(np.max(fills))             # host data
        state = dispatch(state, width)
        t += 1
    return state
