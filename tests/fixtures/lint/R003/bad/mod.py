"""Known-bad R003: device→host syncs inside the turn loop, outside the
blessed packed-(3,B) host-view transfer — each one serializes the
double-buffered overlap."""

import numpy as np

import jax


def run_hot(state, dispatch, host_view, k):
    for t in range(8):
        view = np.asarray(host_view(state, t % k))     # blessed
        loss = state.loss.item()                       # BAD: scalar sync
        turns = state.turn.tolist()                    # BAD: full transfer
        raw = np.asarray(state.margin)                 # BAD: unblessed pull
        state.done.block_until_ready()                 # BAD: barrier
        got = jax.device_get(state.w)                  # BAD: device_get
        fill = int(state.fill[0])                      # BAD: cast on device
        state = dispatch(state)
    return state


def step_pool(pool, viewer, dispatch):
    while not pool.drained:
        flags = np.asarray(pool.state.flags)           # BAD: unblessed pull
        pool.state = dispatch(pool.state)
    return pool
