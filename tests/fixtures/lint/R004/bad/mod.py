"""Known-bad R004: PRNG keys consumed twice — the draws silently
correlate while every transcript still agrees, so only statistics gates
(reservoir chi-square) would ever notice at runtime."""

import jax


def double_consume(key):
    a = jax.random.normal(key, (3,))
    b = jax.random.uniform(key, (3,))        # BAD: key already consumed
    return a, b


def consume_then_split(key):
    x = jax.random.bernoulli(key, 0.5)
    k1, k2 = jax.random.split(key)           # BAD: splitting a spent key
    return x, k1, k2


def split_then_consume(key):
    ks = jax.random.split(key, 3)
    y = jax.random.normal(key, (2,))         # BAD: use the derived keys
    return ks, y


def cross_iteration(key, n):
    total = 0.0
    for i in range(n):
        total += jax.random.normal(key, ())  # BAD: same key every turn
    return total


def subscript_reuse(key):
    ks = jax.random.split(key, 2)
    a = jax.random.normal(ks[0], ())
    b = jax.random.normal(ks[0], ())         # BAD: ks[0] consumed twice
    return a, b
