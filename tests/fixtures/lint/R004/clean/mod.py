"""Known-clean R004: functional key discipline — split/fold_in before
every consumption; per-element and per-iteration derivations."""

import jax


def split_fanout(key):
    k1, k2 = jax.random.split(key)
    a = jax.random.normal(k1, (3,))
    b = jax.random.uniform(k2, (3,))
    return a, b


def fold_in_stream(key, n):
    total = 0.0
    for i in range(n):
        ki = jax.random.fold_in(key, i)      # fold_in per step: the idiom
        total += jax.random.normal(ki, ())
    return total


def indexed_keys(key, xs):
    ks = jax.random.split(key, len(xs))
    out = []
    for i, x in enumerate(xs):
        out.append(jax.random.normal(ks[i], ()))  # varying index: fine
    return out


def vmapped_hop_keys(keys, k):
    # the engine/oneway.py pattern: split each per-instance key once,
    # consume only the derivatives
    return jax.vmap(lambda kk: jax.random.split(kk, k - 1))(keys)
