"""Known-clean R002: everything reaching a static kwarg is pinned to the
compile-key lattice — quantized, constant, or a bounded comparison."""

import jax

GROWTH = 8


def _round_up(n, mult):
    return ((n + mult - 1) // mult) * mult


def step(data, state, *, trans_width, first_turn):
    return state


_step_jit = jax.jit(step, static_argnames=("trans_width", "first_turn"))


def run_turns(data, state, acts, cap):
    for t in range(10):
        # quantized onto the growth lattice: finitely many keys
        width = min(cap, _round_up(len(acts), GROWTH))
        state = _step_jit(data, state, trans_width=width,
                          first_turn=(t == 0))       # bounded bool: 2 keys
        state = _step_jit(data, state, trans_width=cap, first_turn=False)
    return state
