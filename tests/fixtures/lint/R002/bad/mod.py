"""Known-bad R002: Python-varying values minting a fresh compile key per
iteration — the recompile storm tests/test_recompile.py gates against."""

import jax


def step(data, state, *, trans_width, n_pad):
    return state


_step_jit = jax.jit(step, static_argnames=("trans_width", "n_pad"))


def run_turns(data, state, acts, labels):
    for t in range(10):
        # BAD: raw loop variable as a static kwarg
        state = _step_jit(data, state, trans_width=t, n_pad=8)
        # BAD: unquantized len() read
        state = _step_jit(data, state, trans_width=len(acts), n_pad=8)
        # BAD: raw .shape read
        state = _step_jit(data, state, trans_width=8, n_pad=data.shape[0])
    return state
