"""Known-bad R001: three use-after-donate shapes the runtime gate
(tests/test_hotloop_donate.py) only catches when the path is exercised."""

import jax


def step(data, state):
    return state


_step_don = jax.jit(step, donate_argnames=("state",))
_step_jit = jax.jit(step)


def straight_line(data, state, host_view):
    out = _step_don(data, state)
    view = host_view(state)          # BAD: state's buffer was donated
    return out, view


def conditional_alias(data, state, donate):
    step_d = _step_don if donate else _step_jit
    out = step_d(data, state)
    return out, state.turn           # BAD: donating alias reaches here


def loop_carried(data, state, k):
    for _ in range(k):
        _ = _step_don(data, state)   # BAD on 2nd iteration: state dead
    return data
