"""Known-clean R001: the single-consumer discipline the hot loops follow —
every donated name is rebound before any further read."""

import jax


def step(data, state):
    return state


_step_don = jax.jit(step, donate_argnames=("state",))


def rebound_chain(data, state, host_view, k):
    for _ in range(k):
        state = _step_don(data, state)   # consume + rebind, same statement
        view = host_view(state)          # reads the NEW handle
    return state, view


def exclusive_branches(data, state, flag):
    if flag:
        state = _step_don(data, state)
    else:
        pass                             # state never donated on this arm
    return state
