"""Engine one-way/baselines path: host-oracle comm parity, B=1 delegation,
mixed-sweep dispatch, and the rounds-metering contract across families.

The acceptance bar: across a grid per selector, the batched engine must
produce *identical* comm dicts (points/scalars/bits/messages/rounds/bytes)
and rounds to the retired host loops (``benchmarks/legacy_oneway.py``), the
public APIs must be the engine at B=1 exactly, and ``engine.run_sweep`` must
dispatch a mixed one-way + MEDIAN + MAXMARG grid in one call.
"""

import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro import engine
from repro.core import datasets
from repro.core.protocols import baselines, kparty, one_way, two_way

from benchmarks.legacy_oneway import HOSTLOOPS
from conftest import global_err

SELECTORS = tuple(engine.oneway.ONEWAY_SELECTORS)


def _grid(selector, k=2, n=80):
    """Instances per selector: dataset × ε × seed (12 per selector)."""
    out = []
    for gen in (datasets.data1, datasets.data2, datasets.data3):
        for eps in (0.1, 0.05):
            for seed in (0, 1):
                out.append(engine.ProtocolInstance(
                    gen(n_per_node=n, k=k, seed=seed), eps, selector, seed))
    return out


@pytest.mark.parametrize("selector", SELECTORS)
@pytest.mark.parametrize("k", [2, 4])
def test_engine_matches_legacy_oracle_comm(selector, k):
    insts = _grid(selector, k=k, n=60)
    batched = engine.oneway.run_instances(insts)
    for inst, rb in zip(insts, batched):
        rl = HOSTLOOPS[selector](inst.shards, inst.eps, inst.seed)
        assert rb.comm == rl.comm, (selector, inst.eps, rb.comm, rl.comm)
        assert rb.rounds == rl.rounds == rb.comm["rounds"]
        assert rb.converged == rl.converged


@pytest.mark.parametrize("selector", SELECTORS)
def test_batched_matches_b1_delegation(selector):
    insts = _grid(selector)
    batched = engine.oneway.run_instances(insts)
    api = {
        "sampling": lambda i: one_way.random_sampling(i.shards, eps=i.eps,
                                                      seed=i.seed),
        "naive": lambda i: baselines.naive(i.shards),
        "voting": lambda i: baselines.voting(i.shards),
        "mixing": lambda i: baselines.mixing(i.shards),
    }[selector]
    for inst, rb in zip(insts, batched):
        r1 = api(inst)
        assert r1.extra and r1.extra.get("engine") and r1.extra["batch"] == 1
        assert rb.comm == r1.comm
        assert rb.rounds == r1.rounds and rb.converged == r1.converged


def test_sampling_reaches_eps_and_beats_naive():
    """Thm 3.1/6.1 on the engine: ε-net error with sub-naive communication."""
    eps, fails = 0.1, 0
    for seed in range(5):
        shards = datasets.data1(n_per_node=300, k=4, seed=seed)
        r = one_way.random_sampling(shards, eps=eps, seed=seed)
        if global_err(r.classifier, shards) > eps:
            fails += 1
        assert r.extra["sample_size"] < 300
        assert r.comm["rounds"] == r.rounds == 3
    assert fails <= 1  # 'with constant probability'


def test_padding_invariance_oneway():
    """An instance's outcome must not depend on its batch neighbours."""
    small = engine.ProtocolInstance(
        datasets.data1(n_per_node=40, k=2, seed=3), 0.1, "sampling", 3)
    big = engine.ProtocolInstance(
        datasets.data3(n_per_node=160, k=2, seed=4), 0.02, "sampling", 4)
    alone = engine.oneway.run_instances([small])[0]
    padded = engine.oneway.run_instances([small, big])[0]
    assert alone.comm == padded.comm
    assert np.allclose(alone.classifier.w, padded.classifier.w)


def test_run_sweep_mixed_grid_all_three_paths():
    """One run_sweep call dispatches one-way + MEDIAN + MAXMARG instances
    and returns results in input order, each equal to its homogeneous run."""
    shards2 = datasets.data1(n_per_node=80, k=2, seed=0)
    shards3 = datasets.data3(n_per_node=80, k=2, seed=1)
    insts = [
        engine.ProtocolInstance(shards2, 0.05, "naive"),
        engine.ProtocolInstance(shards2, 0.05, "median"),
        engine.ProtocolInstance(shards3, 0.1, "sampling", 7),
        engine.ProtocolInstance(shards2, 0.05, "maxmarg"),
        engine.ProtocolInstance(shards3, 0.05, "voting"),
        engine.ProtocolInstance(shards3, 0.05, "mixing"),
    ]
    out = engine.run_sweep(insts, max_epochs=24, n_angles=256)
    assert [r.extra.get("selector", "median") if r.extra else "median"
            for r in out] == ["naive", "median", "sampling", "maxmarg",
                              "voting", "mixing"]
    for i in (0, 2, 4, 5):
        solo = engine.oneway.run_instances([insts[i]])[0]
        assert out[i].comm == solo.comm and out[i].rounds == solo.rounds
    with pytest.raises(TypeError):
        engine.run_sweep(insts[:1], cut_kernel=True)  # no MEDIAN in sweep


def test_rounds_metering_contract_all_families():
    """Regression for the metering drift: every protocol family's
    ``comm["rounds"]`` must agree with its ``ProtocolResult.rounds`` — the
    one-way protocols and baselines used to report k-1 (or 1) rounds while
    their logs said 0."""
    shards = datasets.data1(n_per_node=60, k=3, seed=0)
    one_way_family = [
        one_way.threshold_protocol(datasets.threshold_instance(n=90, k=3)),
        one_way.interval_protocol(datasets.interval_instance(n=90, k=3)),
        one_way.rectangle_protocol(datasets.rectangle_instance(n=90, k=3)),
        one_way.random_sampling(shards, eps=0.1),
        one_way.local_only(shards),
        baselines.naive(shards),
        baselines.voting(shards),
        baselines.random(shards, eps=0.1),
        baselines.mixing(shards),
    ]
    for r in one_way_family:
        assert r.comm["rounds"] == r.rounds, (r.rounds, r.comm)
    # two-way meters *turns*; the rounds field counts epochs of k turns
    for selector in ("median", "maxmarg"):
        r = kparty.iterative_support_kparty(shards, eps=0.05,
                                            selector=selector)
        assert r.converged
        k = len(shards)
        assert k * (r.rounds - 1) < r.comm["rounds"] <= k * r.rounds
    r = two_way.iterative_support_noisy(
        datasets.add_label_noise(shards[:2], rate=0.03), eps=0.05)
    assert r.comm["rounds"] == r.rounds


def test_rectangle_all_negative_shards_degenerate():
    """Regression: positives empty on *every* shard used to crash in
    ``AxisAlignedRectangle.from_bounds(None, ...)``; the paper's ∅ sentinel
    must yield the degenerate always-negative rectangle instead."""
    rng = np.random.default_rng(0)
    shards = [(rng.uniform(-1, 1, size=(20, 3)), -np.ones(20, np.int32))
              for _ in range(3)]
    r = one_way.rectangle_protocol(shards)
    assert global_err(r.classifier, shards) == 0.0
    probe = rng.uniform(-5, 5, size=(64, 3))
    assert np.all(r.classifier.predict(probe) == -1)
    assert r.comm["rounds"] == r.rounds == 2
    # no data at all still degrades gracefully (both sentinels ∅)
    empty = [(np.zeros((0, 3)), np.zeros((0,), np.int32)) for _ in range(2)]
    r0 = one_way.rectangle_protocol(empty)
    assert np.all(r0.classifier.predict(probe) == -1)


def test_custom_fit_runs_metered_host_path():
    """A custom fit callable keeps the host chain alive with identical
    metering to the engine delegation."""
    shards = datasets.data1(n_per_node=50, k=2, seed=0)
    from repro.core import classifiers as clf
    r_host = baselines.naive(shards, fit=clf.fit_max_margin)
    r_eng = baselines.naive(shards)
    assert not (r_host.extra or {}).get("engine")
    assert r_host.comm == r_eng.comm and r_host.rounds == r_eng.rounds
