"""Bench-tooling failure-mode gates (ISSUE 7 satellite).

The BENCH artifacts are machine-written; a killed benchmark leaves a
truncated file behind, and CI later reads it.  Both consumers —
``benchmarks/check_bench_schema.py`` (the schema gate) and
``benchmarks/bench_history.py`` (the cumulative fold) — must diagnose a
missing / truncated / wrong-shaped artifact in one clear line, never a
traceback.  Also pins the BENCH_service.json branch of the schema gate:
the robustness invariants (zero steady-state recompiles, empty oracle
mismatch list, terminal-status accounting, chaos runs that actually
injected faults) must each fail loudly when violated.
"""

import json
import os
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, ROOT)
sys.path.insert(0, os.path.join(ROOT, "benchmarks"))

import bench_history
import check_bench_schema as cbs


# ---------------------------------------------------------------------------
# check_bench_schema: degraded artifacts
# ---------------------------------------------------------------------------


def test_missing_artifact_is_one_clear_error(tmp_path):
    errs = cbs.check(str(tmp_path / "BENCH_engine.json"))
    assert len(errs) == 1
    assert "not found" in errs[0] and "producing benchmark" in errs[0]


def test_truncated_artifact_is_one_clear_error(tmp_path):
    p = tmp_path / "BENCH_engine.json"
    p.write_text('{"notes": "half-written, benchmark was kil')
    errs = cbs.check(str(p))
    assert len(errs) == 1
    assert "unreadable or truncated" in errs[0]


def test_binary_garbage_is_one_clear_error(tmp_path):
    p = tmp_path / "BENCH_maxmarg.json"
    p.write_bytes(b"\x80\x81\xfe\xff" * 16)
    errs = cbs.check(str(p))
    assert len(errs) == 1
    assert "unreadable or truncated" in errs[0]


def test_wrong_toplevel_is_one_clear_error(tmp_path):
    p = tmp_path / "BENCH_engine.json"
    p.write_text("[1, 2, 3]")
    errs = cbs.check(str(p))
    assert len(errs) == 1
    assert "top level is list" in errs[0]


def test_main_reports_and_returns_nonzero(tmp_path, capsys):
    rc = cbs.main([str(tmp_path / "BENCH_engine.json")])
    out = capsys.readouterr().out
    assert rc == 1
    assert "1 problem(s)" in out and "not found" in out


def test_committed_artifacts_still_pass():
    paths = [os.path.join(ROOT, f"BENCH_{n}.json")
             for n in ("engine", "maxmarg", "baselines", "history",
                       "service")]
    present = [p for p in paths if os.path.exists(p)]
    assert present, "no committed BENCH artifacts found"
    for p in present:
        assert cbs.check(p) == [], p


# ---------------------------------------------------------------------------
# check_bench_schema: the BENCH_service.json branch
# ---------------------------------------------------------------------------


def _service_report():
    return {
        "notes": "x",
        "sessions": 4, "slots": 2, "k": 2, "n_pad": 8,
        "selector": "median",
        "schedule": {"seed": 0, "p_dropout": 0.1, "p_drop_msg": 0.0,
                     "p_straggle": 0.0, "p_corrupt": 0.0, "straggle_max": 3},
        "statuses": {"converged": 2, "budget_exhausted": 1,
                     "quarantined": 1},
        "stats": {"dropouts": 3, "drop_msgs": 0, "straggles": 0,
                  "corruptions": 0},
        "fault_free_s": 0.1, "faulted_s": 0.2,
        "sessions_per_s_fault_free": 40.0, "sessions_per_s_faulted": 20.0,
        "steady_state_recompiles": 0,
        "oracle_checked": 4, "oracle_mismatches": [],
        "mixed_traffic": {
            "sessions": 6, "slots": 2,
            "per_family_sessions": {"median": 2, "maxmarg": 2,
                                    "sampling": 2},
            "unified_s": 0.3,
            "per_family_s": {"median": 0.1, "maxmarg": 0.1,
                             "sampling": 0.1},
            "per_family_total_s": 0.3,
            "steady_state_recompiles": 0,
            "steady_state_dispatch_keys": [[4, 100, False, False]],
            "checked": 6, "bitwise": 6, "mismatches": [],
        },
    }


def _check_service(tmp_path, report):
    p = tmp_path / "BENCH_service.json"
    p.write_text(json.dumps(report))
    return cbs.check(str(p))


def test_service_schema_accepts_valid_report(tmp_path):
    assert _check_service(tmp_path, _service_report()) == []


def test_service_schema_gates_recompiles(tmp_path):
    r = _service_report()
    r["steady_state_recompiles"] = 2
    errs = _check_service(tmp_path, r)
    assert any("steady_state_recompiles" in e and "wanted 0" in e
               for e in errs)


def test_service_schema_gates_oracle_mismatches(tmp_path):
    r = _service_report()
    r["oracle_mismatches"] = [{"sid": 3, "arm": "chaos_vs_fault_free"}]
    errs = _check_service(tmp_path, r)
    assert any("oracle_mismatches" in e and "bit-exact" in e for e in errs)


def test_service_schema_gates_unchecked_oracle(tmp_path):
    r = _service_report()
    r["oracle_checked"] = 0
    errs = _check_service(tmp_path, r)
    assert any("never ran" in e for e in errs)


def test_service_schema_gates_status_accounting(tmp_path):
    r = _service_report()
    r["statuses"]["converged"] = 1          # 3 != sessions=4
    errs = _check_service(tmp_path, r)
    assert any("never reached a terminal state" in e for e in errs)


def test_service_schema_gates_phantom_chaos(tmp_path):
    """A report claiming nonzero fault rates but zero injected faults
    means the chaos arm never actually ran chaotically."""
    r = _service_report()
    r["stats"] = {"dropouts": 0, "drop_msgs": 0, "straggles": 0,
                  "corruptions": 0}
    errs = _check_service(tmp_path, r)
    assert any("zero injected faults" in e for e in errs)


def test_service_schema_gates_mixed_recompiles(tmp_path):
    r = _service_report()
    r["mixed_traffic"]["steady_state_recompiles"] = 1
    errs = _check_service(tmp_path, r)
    assert any("mixed admission moved a compile-cache key" in e
               for e in errs)


def test_service_schema_gates_mixed_multi_key(tmp_path):
    r = _service_report()
    r["mixed_traffic"]["steady_state_dispatch_keys"].append(
        [8, 100, False, False])
    errs = _check_service(tmp_path, r)
    assert any("ONE pinned key" in e for e in errs)


def test_service_schema_gates_mixed_mismatches(tmp_path):
    r = _service_report()
    r["mixed_traffic"]["mismatches"] = [
        {"sid": 1, "selector": "maxmarg", "arm": "unified_vs_per_family"}]
    errs = _check_service(tmp_path, r)
    assert any("per-family pool twins" in e for e in errs)


def test_service_schema_gates_mixed_family_accounting(tmp_path):
    r = _service_report()
    r["mixed_traffic"]["per_family_sessions"]["median"] = 1  # 5 != 6
    errs = _check_service(tmp_path, r)
    assert any("do not sum to" in e for e in errs)


def test_service_schema_missing_key(tmp_path):
    r = _service_report()
    del r["steady_state_recompiles"]
    errs = _check_service(tmp_path, r)
    assert any("missing key 'steady_state_recompiles'" in e for e in errs)


# ---------------------------------------------------------------------------
# bench_history: degraded inputs
# ---------------------------------------------------------------------------


def test_history_extract_missing_returns_none(tmp_path):
    assert bench_history.extract(str(tmp_path / "BENCH_engine.json")) is None


def test_history_loader_truncated_exits_cleanly(tmp_path):
    p = tmp_path / "BENCH_engine.json"
    p.write_text('{"sequential_s": 1.0, "batched')
    with pytest.raises(SystemExit, match="unreadable or truncated"):
        bench_history.extract(str(p))


def test_history_loader_wrong_toplevel_exits_cleanly(tmp_path):
    p = tmp_path / "BENCH_engine.json"
    p.write_text('["not", "an", "object"]')
    with pytest.raises(SystemExit, match="top level is list"):
        bench_history.extract(str(p))


def test_history_fold_refuses_corrupt_history(tmp_path):
    bench = tmp_path / "BENCH_engine.json"
    bench.write_text(json.dumps({"sequential_s": 1.0, "batched_s": 0.5,
                                 "speedup": 2.0, "instances": 4,
                                 "parity_b1_ok": True}))
    out = tmp_path / "BENCH_history.json"
    out.write_text(json.dumps({"notes": "x", "entries": {"not": "a list"}}))
    with pytest.raises(SystemExit, match="refusing to overwrite"):
        bench_history.fold("pr7", str(tmp_path), str(out))


def test_history_fold_truncated_history_exits_cleanly(tmp_path):
    bench = tmp_path / "BENCH_engine.json"
    bench.write_text(json.dumps({"sequential_s": 1.0}))
    out = tmp_path / "BENCH_history.json"
    out.write_text('{"notes": "x", "entries": [{"label"')
    with pytest.raises(SystemExit, match="unreadable or truncated"):
        bench_history.fold("pr7", str(tmp_path), str(out))


def test_history_fold_no_artifacts_exits_cleanly(tmp_path):
    with pytest.raises(SystemExit, match="no BENCH_"):
        bench_history.fold("pr7", str(tmp_path),
                           str(tmp_path / "BENCH_history.json"))
