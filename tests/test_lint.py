"""repro.analysis.lint gates (ISSUE 8).

Four layers, mirroring the bench-tool tests' shape:

* per-rule fixture contracts — every registered rule detects its seeded
  known-bad fixture and stays silent on its known-clean twin;
* registry completeness — every rule has both fixtures and a DESIGN.md
  anchor, so a new rule cannot land undocumented or untested;
* the CLI driven end-to-end on temp trees — a synthetic new violation
  fails the build, the baseline ratchet only shrinks, inline disables
  demand a reason;
* one-line diagnostics for config/baseline failure modes (the
  ``check_bench_schema.py`` convention), plus the merge-state pin: the
  committed tree lints clean against the committed (empty) baseline.
"""

import json
import os
import shutil
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))

from repro.analysis.lint import (  # noqa: E402
    LintConfig,
    LintConfigError,
    load_config,
)
from repro.analysis.lint.baseline import (  # noqa: E402
    BaselineError,
    load_baseline,
    write_baseline,
)
from repro.analysis.lint.cli import main  # noqa: E402
from repro.analysis.lint.engine import lint_paths, lint_tree  # noqa: E402
from repro.analysis.lint.registry import all_rules  # noqa: E402

FIXTURES = os.path.join(ROOT, "tests", "fixtures", "lint")
RULE_IDS = ("R001", "R002", "R003", "R004", "R005", "R006")


def _default_config() -> LintConfig:
    return LintConfig(root=ROOT)


def _lint_fixture(rule_id: str, which: str):
    path = os.path.join(FIXTURES, rule_id, which)
    return lint_paths([path], _default_config())


# ---------------------------------------------------------------------------
# per-rule fixture contracts
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rule_id", RULE_IDS)
def test_rule_detects_known_bad(rule_id):
    findings = _lint_fixture(rule_id, "bad")
    hits = [f for f in findings if f.rule == rule_id]
    assert hits, f"{rule_id} missed its seeded known-bad fixture"


@pytest.mark.parametrize("rule_id", RULE_IDS)
def test_rule_passes_known_clean(rule_id):
    findings = _lint_fixture(rule_id, "clean")
    assert findings == [], [f.render() for f in findings]


def test_bad_fixtures_have_no_cross_rule_noise():
    """A bad fixture for rule X may only trip rule X — anything else means
    a rule is firing outside its contract."""
    for rule_id in RULE_IDS:
        findings = _lint_fixture(rule_id, "bad")
        other = [f.render() for f in findings if f.rule != rule_id]
        assert other == [], other


# ---------------------------------------------------------------------------
# registry completeness
# ---------------------------------------------------------------------------


def test_registry_has_at_least_six_rules():
    assert len(all_rules()) >= 6


def test_every_rule_has_both_fixtures():
    for rule in all_rules():
        for which in ("bad", "clean"):
            d = os.path.join(FIXTURES, rule.id, which)
            assert os.path.isdir(d), f"{rule.id} lacks a {which} fixture"
            assert any(f.endswith(".py") for _, _, fs in os.walk(d)
                       for f in fs), f"{rule.id}/{which} has no .py files"


def test_every_rule_has_a_design_anchor():
    design = open(os.path.join(ROOT, "DESIGN.md"), encoding="utf-8").read()
    assert "Static invariants" in design
    for rule in all_rules():
        assert rule.id in design, f"{rule.id} is undocumented in DESIGN.md"


def test_every_rule_names_its_runtime_gate():
    for rule in all_rules():
        assert rule.gate.strip(), f"{rule.id} has no runtime-gate mapping"
        assert rule.summary.strip()


# ---------------------------------------------------------------------------
# CLI on a temp tree: the CI story end-to-end
# ---------------------------------------------------------------------------

_VIOLATION = (
    "import jax\n\n\n"
    "def f(key):\n"
    "    a = jax.random.normal(key, (3,))\n"
    "    b = jax.random.uniform(key, (3,))\n"
    "    return a, b\n"
)

_SECOND_VIOLATION = (
    "import jax\n\n\n"
    "def g(key):\n"
    "    x = jax.random.bernoulli(key, 0.5)\n"
    "    ks = jax.random.split(key)\n"
    "    return x, ks\n"
)


def _tmp_tree(tmp_path):
    src = tmp_path / "src"
    src.mkdir()
    (src / "mod.py").write_text(_VIOLATION)
    cfg = tmp_path / "pyproject.toml"
    cfg.write_text("[tool.repro-lint]\n")
    return src, cfg


def test_cli_fails_on_synthetic_violation(tmp_path, capsys):
    src, cfg = _tmp_tree(tmp_path)
    rc = main([str(src), "--config", str(cfg)])
    out = capsys.readouterr().out
    assert rc == 1
    assert "R004" in out and "1 finding(s)" in out


def test_cli_baseline_accepts_then_fails_on_new_violation(tmp_path, capsys):
    src, cfg = _tmp_tree(tmp_path)
    base = tmp_path / ".lint-baseline.json"
    rc = main([str(src), "--config", str(cfg), "--baseline", str(base),
               "--write-baseline"])
    assert rc == 0
    # baselined: the committed debt passes
    rc = main([str(src), "--config", str(cfg), "--baseline", str(base)])
    assert rc == 0
    # a NEW violation fails the build even with the baseline in place
    (src / "mod2.py").write_text(_SECOND_VIOLATION)
    rc = main([str(src), "--config", str(cfg), "--baseline", str(base)])
    out = capsys.readouterr().out
    assert rc == 1
    assert "mod2.py" in out


def test_cli_stale_baseline_forces_shrink(tmp_path, capsys):
    src, cfg = _tmp_tree(tmp_path)
    base = tmp_path / ".lint-baseline.json"
    assert main([str(src), "--config", str(cfg), "--baseline", str(base),
                 "--write-baseline"]) == 0
    (src / "mod.py").write_text("x = 1\n")        # debt fixed
    rc = main([str(src), "--config", str(cfg), "--baseline", str(base)])
    out = capsys.readouterr().out
    assert rc == 1
    assert "stale baseline" in out and "shrink" in out


def test_write_baseline_refuses_growth(tmp_path, capsys):
    src, cfg = _tmp_tree(tmp_path)
    base = tmp_path / ".lint-baseline.json"
    assert main([str(src), "--config", str(cfg), "--baseline", str(base),
                 "--write-baseline"]) == 0
    (src / "mod2.py").write_text(_SECOND_VIOLATION)
    rc = main([str(src), "--config", str(cfg), "--baseline", str(base),
               "--write-baseline"])
    out = capsys.readouterr().out
    assert rc == 2
    assert "refusing to grow" in out
    # --allow-growth is the explicit escape hatch
    rc = main([str(src), "--config", str(cfg), "--baseline", str(base),
               "--write-baseline", "--allow-growth"])
    assert rc == 0


def test_baseline_shrinks_budget_monotonically(tmp_path):
    src, cfg = _tmp_tree(tmp_path)
    base = tmp_path / ".lint-baseline.json"
    assert main([str(src), "--config", str(cfg), "--baseline", str(base),
                 "--write-baseline"]) == 0
    assert load_baseline(str(base)).budget == 1
    (src / "mod.py").write_text("x = 1\n")
    assert main([str(src), "--config", str(cfg), "--baseline", str(base),
                 "--write-baseline"]) == 0
    assert load_baseline(str(base)).budget == 0
    # a hand-grown baseline (entries > budget) is rejected on load
    data = json.loads(base.read_text())
    data["findings"] = [{"rule": "R004", "path": "x.py", "hash": "ab"}] * 3
    base.write_text(json.dumps(data))
    with pytest.raises(BaselineError, match="may only shrink"):
        load_baseline(str(base))


def test_cli_json_format_and_annotations(tmp_path, capsys):
    src, cfg = _tmp_tree(tmp_path)
    rc = main([str(src), "--config", str(cfg), "--format", "json",
               "--annotate"])
    out = capsys.readouterr().out
    assert rc == 1
    json_part = out[: out.index("::error")]
    payload = json.loads(json_part)
    assert payload["ok"] is False
    assert payload["counts"]["findings"] == 1
    assert payload["findings"][0]["rule"] == "R004"
    assert "::error file=" in out and "title=R004" in out


def test_cli_module_entry_point(tmp_path):
    """`python -m repro.analysis.lint` — the exact CI invocation shape."""
    src, cfg = _tmp_tree(tmp_path)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", str(src),
         "--config", str(cfg)],
        capture_output=True, text=True, env=env)
    assert proc.returncode == 1
    assert "R004" in proc.stdout


# ---------------------------------------------------------------------------
# inline disables: mandatory reasons
# ---------------------------------------------------------------------------


def test_disable_with_reason_suppresses(tmp_path, capsys):
    src, cfg = _tmp_tree(tmp_path)
    (src / "mod.py").write_text(
        "import jax\n\n\n"
        "def f(key):\n"
        "    a = jax.random.normal(key, (3,))\n"
        "    b = jax.random.uniform(key, (3,))"
        "  # lint: disable=R004 (deliberate correlated draw for the test)\n"
        "    return a, b\n")
    rc = main([str(src), "--config", str(cfg)])
    out = capsys.readouterr().out
    assert rc == 0
    assert "1 suppressed" in out


def test_disable_on_preceding_comment_line(tmp_path):
    src, cfg = _tmp_tree(tmp_path)
    (src / "mod.py").write_text(
        "import jax\n\n\n"
        "def f(key):\n"
        "    a = jax.random.normal(key, (3,))\n"
        "    # lint: disable=R004 (correlated draw is the point here)\n"
        "    b = jax.random.uniform(key, (3,))\n"
        "    return a, b\n")
    assert main([str(src), "--config", str(cfg)]) == 0


def test_disable_without_reason_is_rejected(tmp_path, capsys):
    src, cfg = _tmp_tree(tmp_path)
    (src / "mod.py").write_text(
        "import jax\n\n\n"
        "def f(key):\n"
        "    a = jax.random.normal(key, (3,))\n"
        "    b = jax.random.uniform(key, (3,))  # lint: disable=R004\n"
        "    return a, b\n")
    rc = main([str(src), "--config", str(cfg)])
    out = capsys.readouterr().out
    assert rc == 1
    # the suppression is void AND the malformed comment is its own finding
    assert "R004" in out
    assert "R000" in out and "without a reason" in out


# ---------------------------------------------------------------------------
# one-line diagnostics (check_bench_schema.py convention)
# ---------------------------------------------------------------------------


def test_corrupt_baseline_is_one_clear_error(tmp_path, capsys):
    src, cfg = _tmp_tree(tmp_path)
    base = tmp_path / ".lint-baseline.json"
    base.write_text('{"version": 1, "budget": 0, "findings": [{"rule"')
    rc = main([str(src), "--config", str(cfg), "--baseline", str(base)])
    out = capsys.readouterr().out.strip()
    assert rc == 2
    assert len(out.splitlines()) == 1
    assert "unreadable or truncated" in out


def test_wrong_version_baseline_is_one_clear_error(tmp_path, capsys):
    src, cfg = _tmp_tree(tmp_path)
    base = tmp_path / ".lint-baseline.json"
    base.write_text('{"version": 99, "budget": 0, "findings": []}')
    rc = main([str(src), "--config", str(cfg), "--baseline", str(base)])
    out = capsys.readouterr().out.strip()
    assert rc == 2
    assert len(out.splitlines()) == 1 and "version" in out


def test_missing_baseline_is_one_clear_error(tmp_path, capsys):
    src, cfg = _tmp_tree(tmp_path)
    rc = main([str(src), "--config", str(cfg), "--baseline",
               str(tmp_path / "nope.json")])
    out = capsys.readouterr().out.strip()
    assert rc == 2
    assert len(out.splitlines()) == 1
    assert "not found" in out and "--write-baseline" in out


def test_invalid_toml_is_one_clear_error(tmp_path, capsys):
    src, _ = _tmp_tree(tmp_path)
    cfg = tmp_path / "pyproject.toml"
    cfg.write_text("[tool.repro-lint\nbroken")
    rc = main([str(src), "--config", str(cfg)])
    out = capsys.readouterr().out.strip()
    assert rc == 2
    assert len(out.splitlines()) == 1
    assert "invalid TOML" in out and "[tool.repro-lint]" in out


def test_exclude_without_reason_is_one_clear_error(tmp_path, capsys):
    src, _ = _tmp_tree(tmp_path)
    cfg = tmp_path / "pyproject.toml"
    cfg.write_text('[[tool.repro-lint.exclude]]\npath = "src/x.py"\n')
    rc = main([str(src), "--config", str(cfg)])
    out = capsys.readouterr().out.strip()
    assert rc == 2
    assert len(out.splitlines()) == 1
    assert "no 'reason'" in out


def test_exclude_manifest_skips_with_rationale(tmp_path, capsys):
    src, _ = _tmp_tree(tmp_path)
    cfg = tmp_path / "pyproject.toml"
    cfg.write_text(
        "[[tool.repro-lint.exclude]]\n"
        'path = "src/mod.py"\n'
        'reason = "fixture stack outside the contract"\n')
    rc = main([str(src), "--config", str(cfg), "-v"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "skipped (manifest)" in out
    assert "outside the contract" in out


# ---------------------------------------------------------------------------
# merge-state pins: the committed tree and its committed baseline
# ---------------------------------------------------------------------------


def test_committed_tree_is_clean():
    """engine/ + kernels/ + benchmarks lint clean under the committed
    config — the ISSUE 8 acceptance criterion, pinned as a test."""
    cfg = load_config(os.path.join(ROOT, "pyproject.toml"))
    findings = lint_paths(
        [os.path.join(ROOT, "src"), os.path.join(ROOT, "benchmarks")], cfg)
    assert findings == [], [f.render() for f in findings]


def test_committed_baseline_is_empty():
    b = load_baseline(os.path.join(ROOT, ".lint-baseline.json"))
    assert b.budget == 0
    assert b.entries == []


def test_committed_manifest_excludes_only_seed_stack():
    cfg = load_config(os.path.join(ROOT, "pyproject.toml"))
    assert cfg.excludes, "manifest should be explicit, not empty"
    for ex in cfg.excludes:
        assert ex.reason.strip(), f"{ex.path} has no rationale"
        # the protocol engine is never excluded
        assert not ex.path.startswith("src/repro/engine")
        assert ex.path not in ("src/repro/kernels", "src")
    excluded = {ex.path for ex in cfg.excludes}
    for required in ("src/repro/kernels/mamba.py",
                     "src/repro/kernels/rwkv6.py",
                     "src/repro/kernels/flash_attention.py",
                     "src/repro/models",
                     "src/repro/configs"):
        assert required in excluded, f"manifest lost {required}"


def test_engine_modules_are_genuinely_scanned():
    """Zero findings must mean 'clean', not 'blind': the analyzer resolves
    the real donating dispatches and traced steps in engine/median.py."""
    import ast as ast_mod

    from repro.analysis.lint.context import FileContext

    path = os.path.join(ROOT, "src", "repro", "engine", "median.py")
    src = open(path, encoding="utf-8").read()
    fc = FileContext(path, src, ast_mod.parse(src))
    donating = {n for n, b in fc.jit_bindings.items() if b.donated_nums}
    assert {"_step_jit_don", "_hot_turn_don", "step_d", "turn_d"} <= donating
    traced = fc.traced_functions()
    assert "step" in traced and traced["step"] is not None
    assert "trans_width" in traced["step"]
