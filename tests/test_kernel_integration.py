"""Model-level kernel integration: the Pallas attention backend must agree
with the XLA online pass through the full forward (smollm + qwen2.5 GQA)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

if not hasattr(jax.sharding, "get_abstract_mesh"):
    pytest.skip(
        "model stack requires jax.sharding.get_abstract_mesh (jax >= 0.5.x); "
        "pre-existing version skew on this container's jax, unrelated to the "
        "protocol/engine code (ROADMAP.md)", allow_module_level=True)

import repro.configs as C
from repro.models import model as M
from repro.models.layers import set_attention_impl


@pytest.mark.parametrize("arch", ["smollm-135m", "qwen2.5-14b"])
def test_pallas_attention_matches_xla_forward(arch):
    cfg = C.get_config(arch).reduced()
    params = M.init_lm(jax.random.PRNGKey(0), cfg)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab),
        "targets": jax.random.randint(jax.random.PRNGKey(2), (2, 64), 0, cfg.vocab),
    }
    try:
        set_attention_impl("xla")
        loss_x, _ = M.forward_train(params, cfg, batch, dtype=jnp.float32)
        set_attention_impl("pallas")
        loss_p, _ = M.forward_train(params, cfg, batch, dtype=jnp.float32)
    finally:
        set_attention_impl("xla")
    np.testing.assert_allclose(float(loss_x), float(loss_p), rtol=1e-4)


def test_set_attention_impl_validates():
    with pytest.raises(AssertionError):
        set_attention_impl("cuda")
