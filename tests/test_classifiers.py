"""Hypothesis-class unit + property tests (thresholds, intervals,
rectangles, max-margin linear separators)."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st
from hypothesis.extra.numpy import arrays

from repro.core import classifiers as clf


# ---------------------------------------------------------------------------
# thresholds
# ---------------------------------------------------------------------------

@given(st.lists(st.floats(-100, 100), min_size=2, max_size=50, unique=True),
       st.floats(-100, 100))
@settings(max_examples=50, deadline=None)
def test_threshold_fit_zero_error_when_separable(xs, t):
    x = np.asarray(xs)
    y = np.where(x < t, 1, -1)
    if len(np.unique(y)) == 0:
        return
    h = clf.Threshold.fit(x, y)
    assert h.error(x, y) == 0.0


def test_threshold_not_separable_raises():
    x = np.array([0.0, 1.0, 2.0])
    y = np.array([-1, 1, -1])
    with pytest.raises(ValueError):
        clf.Threshold.fit(x, y)


# ---------------------------------------------------------------------------
# intervals
# ---------------------------------------------------------------------------

@given(st.floats(-50, 50), st.floats(0.1, 20),
       st.lists(st.floats(-100, 100), min_size=1, max_size=60))
@settings(max_examples=50, deadline=None)
def test_interval_fit_zero_error(a, width, xs):
    b = a + width
    x = np.asarray(xs)
    y = np.where((x >= a) & (x <= b), 1, -1)
    h = clf.Interval.fit(x, y)
    assert h.error(x, y) == 0.0


def test_interval_all_negative_gives_empty():
    x = np.array([1.0, 2.0])
    y = np.array([-1, -1])
    h = clf.Interval.fit(x, y)
    assert np.all(h.predict(x) == -1)


# ---------------------------------------------------------------------------
# axis-aligned rectangles
# ---------------------------------------------------------------------------

@given(st.integers(1, 5), st.integers(5, 40), st.integers(0, 10_000))
@settings(max_examples=40, deadline=None)
def test_rectangle_merge_is_global_minimal(d, n, seed):
    rng = np.random.default_rng(seed)
    X1, X2 = rng.normal(size=(n, d)), rng.normal(size=(n, d))
    r = clf.AxisAlignedRectangle.merge(
        clf.AxisAlignedRectangle.minimal(X1), clf.AxisAlignedRectangle.minimal(X2))
    both = np.concatenate([X1, X2])
    assert np.allclose(r[0], both.min(0)) and np.allclose(r[1], both.max(0))


def test_rectangle_merge_empty_sentinel():
    r = clf.AxisAlignedRectangle.minimal(np.zeros((0, 3)))
    assert r is None
    r2 = clf.AxisAlignedRectangle.minimal(np.ones((2, 3)))
    assert clf.AxisAlignedRectangle.merge(r, r2) == r2


# ---------------------------------------------------------------------------
# max-margin linear separator
# ---------------------------------------------------------------------------

def _linearly_separable(n, d, seed, gap=0.3):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=d)
    w /= np.linalg.norm(w)
    X = rng.normal(size=(n, d))
    m = X @ w
    X = X[np.abs(m) > gap]
    y = np.where(X @ w > 0, 1, -1)
    return X, y


@pytest.mark.parametrize("d", [2, 5, 10])
def test_max_margin_zero_training_error(d):
    X, y = _linearly_separable(200, d, seed=d)
    h = clf.fit_max_margin(X, y)
    assert h.error(X, y) == 0.0
    assert h.margin > 0


def test_max_margin_canonical_form():
    X, y = _linearly_separable(100, 2, seed=1)
    h = clf.fit_max_margin(X, y)
    m = y * (X @ h.w + h.b)
    assert m.min() == pytest.approx(1.0, rel=1e-3)


def test_support_points_on_margin():
    X, y = _linearly_separable(300, 2, seed=2)
    h = clf.fit_max_margin(X, y)
    idx = clf.support_points(h, X, y)
    assert 1 <= len(idx) <= 8
    m = y * (X @ h.w + h.b)
    assert np.all(m[idx] <= m.min() * 1.15 + 1e-9)
