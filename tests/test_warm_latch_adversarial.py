"""Warm-latch gate under adversarial near-degenerate margins.

The hot path's clean-carry gate (``_svm_solve_batch`` warm entry) latches a
carried separator through a short polish only when the carry already
classifies the fit set cleanly.  On well-separated data that gate is
obviously safe; the dangerous regime is *near-degenerate* margins, where
support-band membership (functional margin ≤ (1+rtol)·min) is decided at
float precision — an ulp-scale wobble of the separator flips which points
count as support.  This module builds exactly those instances (the
latch-quality study the ROADMAP owed):

* a generator whose instances provably sit on the band edge: several rows'
  membership flips under an ulp-scale perturbation of the separator
  (asserted, not assumed);
* the gate contract, per instance and on BOTH solver paths (classic
  ``kernel=False`` and the tiled dispatch ``kernel=True``): a warm entry
  seeded with the ulp-perturbed carry either (a) latches through the gate
  and stays decision-exact vs the cold solve, or (b) falls back to the
  cold anneal bit-for-bit.  There is no third outcome — in particular no
  "latched but silently different decisions".
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import classifiers as clf
from repro.engine.maxmarg import RTOL


def make_band_flip_instance(d=8, n_easy=40, n_edge=6, seed=0,
                            rtol=RTOL, gmin=0.05):
    """A separable instance whose support band is ulp-degenerate.

    Rows sit at controlled functional margins around a unit separator w*:
    two anchor rows at ``gmin`` (the band's min), ``n_edge`` rows straddling
    the band edge ``(1+rtol)·gmin`` within a few float32 ulp, and easy rows
    far outside.  Membership of the edge rows under the exact separator is
    decided by the last bit of the margin computation.
    """
    rng = np.random.default_rng(seed)
    w = rng.standard_normal(d)
    w /= np.linalg.norm(w)
    edge = (1.0 + rtol) * gmin
    # straddle the edge at ±{1,2,3}·ulp steps, alternating sides
    eps = np.float32(edge) * np.spacing(np.float32(1.0))
    dists = [gmin, gmin]
    dists += [edge + ((-1) ** i) * (1 + i // 2) * eps for i in range(n_edge)]
    dists += list(rng.uniform(4 * gmin, 8 * gmin, n_easy))
    dists = np.asarray(dists)
    labels = np.where(rng.random(dists.size) < 0.5, 1.0, -1.0)
    # orthogonal jitter moves points along the hyperplane, not across it
    X = rng.standard_normal((dists.size, d)).astype(np.float64)
    X -= np.outer(X @ w, w)
    X += np.outer(labels * dists, w)
    return X.astype(np.float32), labels.astype(np.float32), w


def _band(X, y, w, b, rtol=RTOL):
    m = (y * (X @ w + b)).astype(np.float32)
    mmin = np.float32(max(m.min(), 1e-12))
    return m <= mmin * np.float32(1.0 + rtol)


def _ulp_perturb(w, b, direction=1):
    """One-ulp step on every separator component (the smallest
    representable wobble — e.g. a carry that crossed a float round-trip)."""
    to = np.float32(direction * np.inf)
    return (np.nextafter(w.astype(np.float32), to),
            np.nextafter(np.float32(b), to))


def test_generator_band_membership_flips_under_ulp_perturbation():
    flipped = 0
    for seed in range(4):
        X, y, w = make_band_flip_instance(seed=seed)
        base = _band(X, y, w.astype(np.float32), 0.0)
        for direction in (1, -1):
            wp, bp = _ulp_perturb(w, 0.0, direction)
            flipped += int(np.any(_band(X, y, wp, bp) != base))
    # the generator's defining property: ulp-scale separator perturbation
    # flips support-band membership on these instances
    assert flipped >= 4, flipped


def _pack(cases):
    d = cases[0][0].shape[1]
    N = max(X.shape[0] for X, _, _ in cases)
    B = len(cases)
    Xb = np.zeros((B, N, d), np.float32)
    yb = np.zeros((B, N), np.float32)
    for i, (X, y, _) in enumerate(cases):
        Xb[i, :X.shape[0]] = X
        yb[i, :X.shape[0]] = y
    return jnp.asarray(Xb), jnp.asarray(yb)


@pytest.mark.parametrize("kernel", [False, True])
def test_gate_holds_decision_exact_or_falls_back_cold(kernel):
    """Per instance: a warm entry whose carry is the cold solution wobbled
    by one ulp either latches decision-exact or replays the cold anneal
    bit-for-bit.  Ulp-scale perturbation cannot manufacture a third
    outcome on either solver path."""
    cases = [make_band_flip_instance(seed=s) for s in range(6)]
    Xb, yb = _pack(cases)
    lam = jnp.float32(1e-3)
    w_c, b_c, ok_c = clf._svm_solve_batch(Xb, yb, lam, 800, 3,
                                          kernel=kernel)
    assert bool(jnp.all(ok_c))
    wc, bc = np.asarray(w_c), np.asarray(b_c)
    w0 = np.stack([_ulp_perturb(wc[i], bc[i], 1 if i % 2 else -1)[0]
                   for i in range(len(cases))])
    b0 = np.asarray([_ulp_perturb(wc[i], bc[i], 1 if i % 2 else -1)[1]
                     for i in range(len(cases))])
    w_w, b_w, ok_w, gate = clf._svm_solve_batch(
        Xb, yb, lam, 800, 3, w0=jnp.asarray(w0), b0=jnp.asarray(b0),
        warm_ok=jnp.ones((len(cases),), bool), return_gate=True,
        kernel=kernel)
    ww, bw = np.asarray(w_w), np.asarray(b_w)
    gate, ok_w = np.asarray(gate), np.asarray(ok_w)
    Xn, yn = np.asarray(Xb), np.asarray(yb)
    for i in range(len(cases)):
        cold_exact = (np.array_equal(ww[i], wc[i])
                      and np.float32(bw[i]) == np.float32(bc[i]))
        if cold_exact:
            continue                       # (b) fell back cold, bit-for-bit
        # (a) must have latched through the gate, decision-exact vs cold
        assert gate[i] and ok_w[i], i
        valid = yn[i] != 0
        dec_w = Xn[i][valid] @ ww[i] + bw[i]
        dec_c = Xn[i][valid] @ wc[i] + bc[i]
        np.testing.assert_array_equal(np.sign(dec_w) * yn[i][valid] > 0,
                                      np.sign(dec_c) * yn[i][valid] > 0,
                                      err_msg=str(i))


@pytest.mark.parametrize("kernel", [False, True])
def test_untrusted_ulp_carry_is_cold_bit_for_bit(kernel):
    """warm_ok=False must neutralize even a maximally-plausible carry (the
    cold solution itself, ulp-wobbled): the whole batch replays the cold
    anneal bit-for-bit on both solver paths — the per-instance fallback
    basis the gate test above relies on."""
    cases = [make_band_flip_instance(seed=10 + s) for s in range(3)]
    Xb, yb = _pack(cases)
    lam = jnp.float32(1e-3)
    w_c, b_c, ok_c = clf._svm_solve_batch(Xb, yb, lam, 400, 2,
                                          kernel=kernel)
    w0 = np.nextafter(np.asarray(w_c), np.float32(np.inf))
    w_w, b_w, ok_w, gate = clf._svm_solve_batch(
        Xb, yb, lam, 400, 2, w0=jnp.asarray(w0), b0=b_c,
        warm_ok=jnp.zeros((len(cases),), bool), return_gate=True,
        kernel=kernel)
    assert not bool(np.any(np.asarray(gate)))
    np.testing.assert_array_equal(np.asarray(w_w), np.asarray(w_c))
    np.testing.assert_array_equal(np.asarray(b_w), np.asarray(b_c))
    np.testing.assert_array_equal(np.asarray(ok_w), np.asarray(ok_c))
