"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see the single real CPU device; only launch/dryrun.py forces 512 placeholders.
"""

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def global_err(clf, shards) -> float:
    X = np.concatenate([s[0] for s in shards])
    y = np.concatenate([s[1] for s in shards])
    return float(np.mean(clf.predict(X) != y))
