"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see the single real CPU device; only launch/dryrun.py forces 512 placeholders.

Property-based test modules need ``hypothesis`` (the ``dev`` extra in
pyproject.toml).  When it is absent the modules are skipped at collection
instead of erroring the whole run.
"""

import importlib.util

import numpy as np
import pytest

if importlib.util.find_spec("hypothesis") is None:
    collect_ignore = [
        "test_classifiers.py",
        "test_geometry.py",
        "test_protocol_properties.py",
        "test_protocols_oneway.py",
        "test_sampling.py",
    ]


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def global_err(clf, shards) -> float:
    X = np.concatenate([s[0] for s in shards])
    y = np.concatenate([s[1] for s in shards])
    return float(np.mean(clf.predict(X) != y))
