"""Differential gate for the MAXMARG hot path (warm-started, compacted
refits) against the cold padded execution model.

The hard-margin optimum each turn is determined by the transcript alone, so
warm-starting (polishing the previous turn's separator) and compaction
(solving at the live transcript width, dropping finished instances) may only
change *solve cost*, never a protocol decision.  This module pins that down:
across the engine test grid, warm+compacted and cold+padded runs must agree
exactly on comm totals, rounds, and convergence, and produce the same final
separator up to canonicalization.
"""

import os
import sys

import numpy as np
import jax.numpy as jnp
import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro import engine
from repro.core import classifiers as clf
from repro.core import datasets

MAX_EPOCHS = 24


def _grid():
    """The engine MAXMARG test grid (same as tests/test_engine_maxmarg.py)."""
    out = []
    for gen in (datasets.data1, datasets.data2, datasets.data3):
        for eps in (0.05, 0.02):
            for seed in (0, 1):
                out.append(engine.ProtocolInstance(
                    gen(n_per_node=100, k=2, seed=seed), eps, "maxmarg"))
    return out


def _canon(h):
    """Canonical direction of a separator: unit-norm augmented (w, b)."""
    v = np.concatenate([h.w, [h.b]])
    return v / (np.linalg.norm(v) + 1e-30)


@pytest.fixture(scope="module")
def warm_cold_runs():
    insts = _grid()
    hot = engine.maxmarg.run_instances(insts, max_epochs=MAX_EPOCHS,
                                       warm=True, compact=True)
    cold = engine.maxmarg.run_instances(insts, max_epochs=MAX_EPOCHS,
                                        warm=False, compact=False)
    return insts, hot, cold


def test_warm_cold_identical_comm_rounds_convergence(warm_cold_runs):
    insts, hot, cold = warm_cold_runs
    assert len(insts) >= 12
    for i, (rh, rc) in enumerate(zip(hot, cold)):
        assert rh.comm == rc.comm, (i, rh.comm, rc.comm)
        assert rh.rounds == rc.rounds, i
        assert rh.converged == rc.converged and rh.converged, i


def test_warm_cold_same_separator_up_to_canonicalization(warm_cold_runs):
    """Both paths approximate the same transcript-determined hard-margin
    optimum; after canonicalization the directions must agree closely and
    predict identically on every shard."""
    insts, hot, cold = warm_cold_runs
    for inst, rh, rc in zip(insts, hot, cold):
        vh, vc = _canon(rh.classifier), _canon(rc.classifier)
        assert abs(float(vh @ vc)) > 1.0 - 1e-4, (vh, vc)
        X = np.concatenate([s[0] for s in inst.shards])
        np.testing.assert_array_equal(rh.classifier.predict(X),
                                      rc.classifier.predict(X))


def test_warm_cold_parity_kparty():
    """k=4 multi-party case — the regime where the warm polish actually
    engages (a later coordinator's shard is often already cleanly
    classified)."""
    for seed, eps in ((0, 0.1), (1, 0.05)):
        shards = datasets.data3(n_per_node=75, k=4, seed=seed)
        inst = [engine.ProtocolInstance(shards, eps, "maxmarg")]
        rh = engine.maxmarg.run_instances(inst, max_epochs=MAX_EPOCHS,
                                          warm=True, compact=True)[0]
        rc = engine.maxmarg.run_instances(inst, max_epochs=MAX_EPOCHS,
                                          warm=False, compact=False)[0]
        assert rh.comm == rc.comm
        assert rh.rounds == rc.rounds and rh.converged == rc.converged


def test_compaction_alone_is_decision_exact():
    """Width+batch compaction without warm-starting: same decisions as the
    cold padded path (only float reassociation across padding changes)."""
    insts = _grid()[:6]
    comp = engine.maxmarg.run_instances(insts, max_epochs=MAX_EPOCHS,
                                        warm=False, compact=True)
    cold = engine.maxmarg.run_instances(insts, max_epochs=MAX_EPOCHS,
                                        warm=False, compact=False)
    for rh, rc in zip(comp, cold):
        assert rh.comm == rc.comm
        assert rh.rounds == rc.rounds and rh.converged == rc.converged


def test_solver_warm_entry_with_untrusted_init_is_cold_bit_for_bit():
    """The warm entry's fall-through: when no instance may latch
    (warm_ok=False), the anneal from zeros must be bit-identical to the
    cold entry — the polish only ever *adds* a latched prefix."""
    rng = np.random.default_rng(0)
    w_true = np.array([1.0, -0.5]) / np.linalg.norm([1.0, -0.5])
    X = rng.normal(size=(120, 2)).astype(np.float32)
    X = X[np.abs(X @ w_true) > 0.2]
    y = np.where(X @ w_true > 0, 1.0, -1.0).astype(np.float32)
    Xb, yb = jnp.asarray(X[None]), jnp.asarray(y[None])
    w_c, b_c, ok_c = clf._svm_solve_batch(Xb, yb, jnp.float32(1e-3), 500, 2)
    w_w, b_w, ok_w = clf._svm_solve_batch(
        Xb, yb, jnp.float32(1e-3), 500, 2,
        w0=jnp.asarray(rng.normal(size=(1, 2)), jnp.float32),
        b0=jnp.zeros((1,), jnp.float32),
        warm_ok=jnp.zeros((1,), bool))
    assert bool(ok_c[0]) and bool(ok_w[0])
    np.testing.assert_array_equal(np.asarray(w_c), np.asarray(w_w))
    np.testing.assert_array_equal(np.asarray(b_c), np.asarray(b_w))


def test_solver_polish_latches_clean_carried_separator():
    """A clean carried separator must latch through the polish (skipping
    every annealing stage) with preserved margin quality."""
    rng = np.random.default_rng(3)
    n = 150
    Xp = np.stack([-0.5 - rng.random(n), rng.normal(0, 2.0, n)], axis=1)
    Xn = np.stack([+0.5 + rng.random(n), rng.normal(0, 2.0, n)], axis=1)
    X = np.concatenate([Xp, Xn]).astype(np.float32)
    y = np.concatenate([np.ones(n), -np.ones(n)]).astype(np.float32)
    w0, b0, ok0 = clf.anneal_hard_margin(X, y)
    assert ok0
    Xb, yb = jnp.asarray(X[None]), jnp.asarray(y[None])
    w, b, ok = clf._svm_solve_batch(
        Xb, yb, jnp.float32(1e-3), 2000, 3,
        w0=jnp.asarray(w0[None], jnp.float32),
        b0=jnp.asarray([b0], jnp.float32),
        warm_ok=jnp.ones((1,), bool))
    assert bool(ok[0])
    m = y * (X @ np.asarray(w[0], np.float64) + float(b[0]))
    assert m.min() > 0                       # still separates
    geo = m.min() / np.linalg.norm(np.asarray(w[0]))
    assert geo >= 0.9 * 0.5                  # margin quality preserved


def test_hot_path_is_default_and_flagged():
    shards = datasets.data1(n_per_node=80, k=2, seed=0)
    r = engine.maxmarg.run_instances(
        [engine.ProtocolInstance(shards, 0.05, "maxmarg")])[0]
    assert r.extra["warm"] and r.extra["compact"]
    r_cold = engine.maxmarg.run_instances(
        [engine.ProtocolInstance(shards, 0.05, "maxmarg")],
        warm=False, compact=False)[0]
    assert not r_cold.extra["warm"] and not r_cold.extra["compact"]
    assert r.comm == r_cold.comm


def test_run_sweep_accepts_warm_compact_options():
    shards = datasets.data1(n_per_node=60, k=2, seed=1)
    insts = [engine.ProtocolInstance(shards, 0.05, "maxmarg")]
    r_hot = engine.run_sweep(insts, warm=True, compact=True)[0]
    r_cold = engine.run_sweep(insts, warm=False, compact=False)[0]
    assert r_hot.comm == r_cold.comm
