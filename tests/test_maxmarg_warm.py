"""Differential gate for the MAXMARG hot path (warm-started, compacted
refits) against the cold padded execution model.

The hard-margin optimum each turn is determined by the transcript alone, so
warm-starting (polishing the previous turn's separator) and compaction
(solving at the live transcript width, dropping finished instances) may only
change *solve cost*, never a protocol decision.  This module pins that down:
across the engine test grid, warm+compacted and cold+padded runs must agree
exactly on comm totals, rounds, and convergence, and produce the same final
separator up to canonicalization.
"""

import os
import sys

import numpy as np
import jax.numpy as jnp
import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro import engine
from repro.core import classifiers as clf
from repro.core import datasets

MAX_EPOCHS = 24


def _grid():
    """The engine MAXMARG test grid (same as tests/test_engine_maxmarg.py)."""
    out = []
    for gen in (datasets.data1, datasets.data2, datasets.data3):
        for eps in (0.05, 0.02):
            for seed in (0, 1):
                out.append(engine.ProtocolInstance(
                    gen(n_per_node=100, k=2, seed=seed), eps, "maxmarg"))
    return out


def _canon(h):
    """Canonical direction of a separator: unit-norm augmented (w, b)."""
    v = np.concatenate([h.w, [h.b]])
    return v / (np.linalg.norm(v) + 1e-30)


@pytest.fixture(scope="module")
def warm_cold_runs():
    insts = _grid()
    hot = engine.maxmarg.run_instances(insts, max_epochs=MAX_EPOCHS,
                                       warm=True, compact=True)
    cold = engine.maxmarg.run_instances(insts, max_epochs=MAX_EPOCHS,
                                        warm=False, compact=False)
    return insts, hot, cold


def test_warm_cold_identical_comm_rounds_convergence(warm_cold_runs):
    insts, hot, cold = warm_cold_runs
    assert len(insts) >= 12
    for i, (rh, rc) in enumerate(zip(hot, cold)):
        assert rh.comm == rc.comm, (i, rh.comm, rc.comm)
        assert rh.rounds == rc.rounds, i
        assert rh.converged == rc.converged and rh.converged, i


def test_warm_cold_same_separator_up_to_canonicalization(warm_cold_runs):
    """Both paths approximate the same transcript-determined hard-margin
    optimum; after canonicalization the directions must agree closely and
    predict identically on every shard."""
    insts, hot, cold = warm_cold_runs
    for inst, rh, rc in zip(insts, hot, cold):
        vh, vc = _canon(rh.classifier), _canon(rc.classifier)
        assert abs(float(vh @ vc)) > 1.0 - 1e-4, (vh, vc)
        X = np.concatenate([s[0] for s in inst.shards])
        np.testing.assert_array_equal(rh.classifier.predict(X),
                                      rc.classifier.predict(X))


def test_warm_cold_parity_kparty():
    """k=4 multi-party case — the regime where the warm polish actually
    engages (a later coordinator's shard is often already cleanly
    classified)."""
    for seed, eps in ((0, 0.1), (1, 0.05)):
        shards = datasets.data3(n_per_node=75, k=4, seed=seed)
        inst = [engine.ProtocolInstance(shards, eps, "maxmarg")]
        rh = engine.maxmarg.run_instances(inst, max_epochs=MAX_EPOCHS,
                                          warm=True, compact=True)[0]
        rc = engine.maxmarg.run_instances(inst, max_epochs=MAX_EPOCHS,
                                          warm=False, compact=False)[0]
        assert rh.comm == rc.comm
        assert rh.rounds == rc.rounds and rh.converged == rc.converged


def test_compaction_alone_is_decision_exact():
    """Width+batch compaction without warm-starting: same decisions as the
    cold padded path (only float reassociation across padding changes)."""
    insts = _grid()[:6]
    comp = engine.maxmarg.run_instances(insts, max_epochs=MAX_EPOCHS,
                                        warm=False, compact=True)
    cold = engine.maxmarg.run_instances(insts, max_epochs=MAX_EPOCHS,
                                        warm=False, compact=False)
    for rh, rc in zip(comp, cold):
        assert rh.comm == rc.comm
        assert rh.rounds == rc.rounds and rh.converged == rc.converged


def test_solver_warm_entry_with_untrusted_init_is_cold_bit_for_bit():
    """The warm entry's fall-through: when no instance may latch
    (warm_ok=False), the anneal from zeros must be bit-identical to the
    cold entry — the polish only ever *adds* a latched prefix."""
    rng = np.random.default_rng(0)
    w_true = np.array([1.0, -0.5]) / np.linalg.norm([1.0, -0.5])
    X = rng.normal(size=(120, 2)).astype(np.float32)
    X = X[np.abs(X @ w_true) > 0.2]
    y = np.where(X @ w_true > 0, 1.0, -1.0).astype(np.float32)
    Xb, yb = jnp.asarray(X[None]), jnp.asarray(y[None])
    w_c, b_c, ok_c = clf._svm_solve_batch(Xb, yb, jnp.float32(1e-3), 500, 2)
    w_w, b_w, ok_w = clf._svm_solve_batch(
        Xb, yb, jnp.float32(1e-3), 500, 2,
        w0=jnp.asarray(rng.normal(size=(1, 2)), jnp.float32),
        b0=jnp.zeros((1,), jnp.float32),
        warm_ok=jnp.zeros((1,), bool))
    assert bool(ok_c[0]) and bool(ok_w[0])
    np.testing.assert_array_equal(np.asarray(w_c), np.asarray(w_w))
    np.testing.assert_array_equal(np.asarray(b_c), np.asarray(b_w))


def test_solver_polish_latches_clean_carried_separator():
    """A clean carried separator must latch through the polish (skipping
    every annealing stage) with preserved margin quality."""
    rng = np.random.default_rng(3)
    n = 150
    Xp = np.stack([-0.5 - rng.random(n), rng.normal(0, 2.0, n)], axis=1)
    Xn = np.stack([+0.5 + rng.random(n), rng.normal(0, 2.0, n)], axis=1)
    X = np.concatenate([Xp, Xn]).astype(np.float32)
    y = np.concatenate([np.ones(n), -np.ones(n)]).astype(np.float32)
    w0, b0, ok0 = clf.anneal_hard_margin(X, y)
    assert ok0
    Xb, yb = jnp.asarray(X[None]), jnp.asarray(y[None])
    w, b, ok = clf._svm_solve_batch(
        Xb, yb, jnp.float32(1e-3), 2000, 3,
        w0=jnp.asarray(w0[None], jnp.float32),
        b0=jnp.asarray([b0], jnp.float32),
        warm_ok=jnp.ones((1,), bool))
    assert bool(ok[0])
    m = y * (X @ np.asarray(w[0], np.float64) + float(b[0]))
    assert m.min() > 0                       # still separates
    geo = m.min() / np.linalg.norm(np.asarray(w[0]))
    assert geo >= 0.9 * 0.5                  # margin quality preserved


def test_per_node_latches_end_to_end_with_identical_decisions():
    """A multi-epoch k-party sweep where the per-node warm carry actually
    latches (an easy node adopts a clean mid-epoch proposal and polishes
    from it at its next turn) — and every protocol decision still matches
    the cold padded model."""
    inst = [engine.ProtocolInstance(
        datasets.data_mixed_hardness(seed=0), 0.05, "maxmarg")]
    rp = engine.maxmarg.run_instances(inst, max_epochs=6)[0]
    rs = engine.maxmarg.run_instances(inst, max_epochs=6, per_node=False)[0]
    rc = engine.maxmarg.run_instances(inst, max_epochs=6,
                                      warm=False, compact=False)[0]
    assert rp.rounds >= 2, "grid must be multi-epoch for carries to exist"
    assert rp.extra["warm_latches"] >= 1, "per-node polish never latched"
    assert rp.extra["warm_latches"] >= rs.extra["warm_latches"]
    for r in (rp, rs):
        assert r.comm == rc.comm
        assert r.rounds == rc.rounds and r.converged == rc.converged
    assert rp.converged


def test_per_node_latch_where_single_carry_provably_falls_through():
    """The step-level differential the per-node upgrade exists for: a
    crafted mid-protocol state whose coordinator carries a *verified-clean*
    separator (per-node mode) while the previous turn's proposal (the
    single-carry init) misclassifies its fit set.  The per-node polish must
    latch, the single-carry path must fall through to the cold anneal, the
    latch counters must differ — and every protocol decision (comm deltas,
    transcript appends, termination) must be identical across per-node,
    single-carry, and fully cold execution."""
    from repro.engine import maxmarg as MM

    rng = np.random.default_rng(5)
    half = 30
    shards = []
    for cx in (-1.0, 0.0, 1.0):     # three easy blob pairs, separator x=0
        Xp = np.stack([rng.uniform(-2.0, -0.6, half),
                       rng.uniform(cx - 0.5, cx + 0.5, half)], 1)
        Xn = np.stack([rng.uniform(0.6, 2.0, half),
                       rng.uniform(cx - 0.5, cx + 0.5, half)], 1)
        X = np.concatenate([Xp, Xn]).astype(np.float32)
        y = np.concatenate([np.ones(half), -np.ones(half)]).astype(np.int32)
        shards.append((X, y))
    inst = [engine.ProtocolInstance(shards, 0.05, "maxmarg")]
    data, state0, k, _cap = engine.pack_instances_maxmarg(
        inst, max_epochs=8, max_support=4)

    # mid-protocol: node 0 holds two received support points, turn 3 (its
    # second coordination), carries the true separator as verified-clean;
    # the "previous turn's proposal" is orthogonal — dirty on everything
    wx = np.asarray(state0.wx).copy()
    wy = np.asarray(state0.wy).copy()
    w_fill = np.asarray(state0.w_fill).copy()
    wx[0, 0, 0], wy[0, 0, 0] = (-0.7, 0.3), 1
    wx[0, 0, 1], wy[0, 0, 1] = (0.7, -0.3), -1
    w_fill[0, 0] = 2
    base = state0._replace(
        wx=jnp.asarray(wx), wy=jnp.asarray(wy), w_fill=jnp.asarray(w_fill),
        turn=jnp.full((1,), 3, jnp.int32),   # per-instance (B,) turn
        h_w=jnp.asarray([[0.0, 1.0]], jnp.float32),      # dirty prev proposal
        h_b=jnp.zeros((1,), jnp.float32),
        h_valid=jnp.ones((1,), bool),
        warm_turn=jnp.ones((1,), bool),                  # host would attempt
        c_w=jnp.asarray(np.broadcast_to(
            np.asarray([[-1.0, 0.0]], np.float32)[:, None], (1, 3, 2)).copy()),
        c_b=jnp.zeros((1, 3), jnp.float32),
        c_valid=jnp.ones((1, 3), bool),
        warm_node=jnp.ones((1, 3), bool))

    opts = dict(k=k, max_support=4, steps=500, stages=2, lam0=1e-3,
                trans_width=None, fused_kernel=False)
    pn = MM._step_jit(data, base, warm=True, per_node=True, **opts)
    sg = MM._step_jit(data, base, warm=True, per_node=False, **opts)
    cold = MM._step_jit(data, base, warm=False, per_node=True, **opts)

    assert int(pn.latches[0]) == 1          # clean carry -> polish latch
    assert int(sg.latches[0]) == 0          # dirty init -> provable gate fail
    assert int(cold.latches[0]) == 0
    for other in (sg, cold):
        for a, b in zip(pn.comm, other.comm):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(pn.wy), np.asarray(other.wy))
        np.testing.assert_array_equal(np.asarray(pn.w_fill),
                                      np.asarray(other.w_fill))
        assert bool(pn.done[0]) == bool(other.done[0])
        assert bool(pn.converged[0]) == bool(other.converged[0])


def test_hot_path_is_default_and_flagged():
    shards = datasets.data1(n_per_node=80, k=2, seed=0)
    r = engine.maxmarg.run_instances(
        [engine.ProtocolInstance(shards, 0.05, "maxmarg")])[0]
    assert r.extra["warm"] and r.extra["compact"]
    assert r.extra["per_node"] and "warm_latches" in r.extra
    r_cold = engine.maxmarg.run_instances(
        [engine.ProtocolInstance(shards, 0.05, "maxmarg")],
        warm=False, compact=False)[0]
    assert not r_cold.extra["warm"] and not r_cold.extra["compact"]
    assert r.comm == r_cold.comm


def test_run_sweep_accepts_warm_compact_options():
    shards = datasets.data1(n_per_node=60, k=2, seed=1)
    insts = [engine.ProtocolInstance(shards, 0.05, "maxmarg")]
    r_hot = engine.run_sweep(insts, warm=True, compact=True)[0]
    r_cold = engine.run_sweep(insts, warm=False, compact=False)[0]
    assert r_hot.comm == r_cold.comm


def test_solver_kernel_warm_cold_decisions_bit_exact():
    """The tiled-solver dispatch (`solver_kernel=True`; jnp twin on CPU)
    must leave every MAXMARG protocol decision bit-exact — against its own
    warm/cold pair AND against the default classic-solver run.  This is the
    engine-level acceptance gate for `_svm_solve_batch(kernel=True)`: a
    solver path that changed comm, rounds or convergence anywhere on the
    paper grid would be a different protocol, not a faster solver."""
    insts = _grid()[:6]
    hot_k = engine.maxmarg.run_instances(insts, max_epochs=MAX_EPOCHS,
                                         warm=True, compact=True,
                                         solver_kernel=True)
    cold_k = engine.maxmarg.run_instances(insts, max_epochs=MAX_EPOCHS,
                                          warm=False, compact=False,
                                          solver_kernel=True)
    classic = engine.maxmarg.run_instances(insts, max_epochs=MAX_EPOCHS,
                                           warm=True, compact=True,
                                           solver_kernel=False)
    for i, (rh, rc, rd) in enumerate(zip(hot_k, cold_k, classic)):
        assert rh.comm == rc.comm == rd.comm, i
        assert rh.rounds == rc.rounds == rd.rounds, i
        assert rh.converged and rc.converged and rd.converged, i
        # same decision boundary direction across all three runs
        ch, cc, cd = _canon(rh.classifier), _canon(rc.classifier), \
            _canon(rd.classifier)
        assert min(abs(float(ch @ cc)), abs(float(ch @ cd))) > 1.0 - 1e-4, i


def test_solver_kernel_highd_sweep_converges():
    """The d ≫ 2 regime the kernel targets, end-to-end through the engine:
    a d=16 separable sweep with solver_kernel on/off converges identically
    (decision-exact), exercising the bucketed high-d dispatch path."""
    insts = [engine.ProtocolInstance(
        datasets.data_highd(n_per_node=80, k=2, d=16, seed=s, margin=0.2),
        0.05, "maxmarg") for s in (0, 1)]
    rk = engine.maxmarg.run_instances(insts, max_epochs=MAX_EPOCHS,
                                      solver_kernel=True)
    rc = engine.maxmarg.run_instances(insts, max_epochs=MAX_EPOCHS,
                                      solver_kernel=False)
    for i, (a, b) in enumerate(zip(rk, rc)):
        assert a.converged and b.converged, i
        assert a.comm == b.comm and a.rounds == b.rounds, i
