"""Sharded-vs-single-device differential gate for the multi-device hot loop
(DESIGN.md §sharded hot loop).

The sharded path splits the leading B axis over a 1-D ("data",) mesh with
donated state buffers and the double-buffered host loop both ON (the mesh
defaults) — everything inside a shard is the unmodified single-device
program on its local slice, so MEDIAN must stay *bit-exact* and MAXMARG
decision-exact (comm/rounds/convergence + prediction-level separator, the
same standard the warm gate holds) against the unchanged single-device hot
path.  Grids cover B divisible and non-divisible by the device count, the
k-party case, a staggered-convergence batch that exercises the
shard-balanced compacted dispatch (``hotloop.balanced_index``), the
overlap toggle, and the ``run_sweep`` mesh pass-through.

Needs >1 device: CI runs this module in the hot-path-parity step under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``; standalone runs get
the same flag set below (it must land before jax initializes — under a full
tier-1 run where another module already imported jax, the module skips on
the device count instead).
"""

import os
import sys

if "jax" not in sys.modules:                     # must precede jax init
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax

from repro import engine
from repro.core import datasets
from repro.launch.mesh import make_data_mesh

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="sharded hot loop needs >1 device "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")

N_ANGLES = 256
MAX_EPOCHS = 24
_GENS = (datasets.data1, datasets.data2, datasets.data3)


@pytest.fixture(scope="module")
def mesh():
    return make_data_mesh()


def _grid(n, k=2, selector="median", n_per_node=40):
    """n instances cycling datasets/eps/seeds — convergence times differ, so
    batches stagger and the compacted sub-dispatch path engages."""
    return [engine.ProtocolInstance(
        _GENS[i % 3](n_per_node=n_per_node, k=k, seed=i),
        (0.1, 0.05)[i % 2], selector) for i in range(n)]


def _assert_bitexact(insts, sharded, ref):
    for i, (a, b) in enumerate(zip(sharded, ref)):
        assert a.comm == b.comm, (i, a.comm, b.comm)
        assert a.rounds == b.rounds, i
        assert a.converged == b.converged and a.converged, i
        np.testing.assert_array_equal(a.classifier.w, b.classifier.w)
        assert a.classifier.b == b.classifier.b, i


def _assert_decision_exact(insts, sharded, ref):
    for i, (inst, a, b) in enumerate(zip(insts, sharded, ref)):
        assert a.comm == b.comm, (i, a.comm, b.comm)
        assert a.rounds == b.rounds, i
        assert a.converged == b.converged and a.converged, i
        X = np.concatenate([s[0] for s in inst.shards])
        np.testing.assert_array_equal(a.classifier.predict(X),
                                      b.classifier.predict(X))


# ---------------------------------------------------------------- MEDIAN --

def test_median_sharded_divisible(mesh):
    """B = 2 × devices: full-batch sharded dispatches engage; MEDIAN sharded
    results must be bit-exact vs the single-device hot path."""
    insts = _grid(2 * len(mesh.devices))
    sh = engine.run_instances(insts, n_angles=N_ANGLES,
                              max_epochs=MAX_EPOCHS, mesh=mesh)
    ref = engine.run_instances(insts, n_angles=N_ANGLES,
                               max_epochs=MAX_EPOCHS)
    _assert_bitexact(insts, sh, ref)
    assert all(r.extra["devices"] == len(mesh.devices) for r in sh)


def test_median_sharded_nondivisible(mesh):
    """B not a multiple of the device count: the pack pads with born-done
    dummies; results for the real instances are untouched."""
    insts = _grid(len(mesh.devices) + 5)
    sh = engine.run_instances(insts, n_angles=N_ANGLES,
                              max_epochs=MAX_EPOCHS, mesh=mesh)
    ref = engine.run_instances(insts, n_angles=N_ANGLES,
                               max_epochs=MAX_EPOCHS)
    _assert_bitexact(insts, sh, ref)


def test_median_sharded_kparty(mesh):
    insts = [engine.ProtocolInstance(
        datasets.data3(n_per_node=30, k=4, seed=s), eps)
        for s, eps in ((0, 0.1), (1, 0.05), (2, 0.1), (3, 0.05))]
    sh = engine.run_instances(insts, n_angles=N_ANGLES,
                              max_epochs=MAX_EPOCHS, mesh=mesh)
    ref = engine.run_instances(insts, n_angles=N_ANGLES,
                               max_epochs=MAX_EPOCHS)
    _assert_bitexact(insts, sh, ref)


def test_median_overlap_toggle(mesh):
    """Double buffering speculates turn t+1 from a stale view — MEDIAN must
    stay bit-exact with it on or off (any covering width is exact and stale
    active sets are masked no-op supersets)."""
    insts = _grid(len(mesh.devices) + 3)
    on = engine.run_instances(insts, n_angles=N_ANGLES,
                              max_epochs=MAX_EPOCHS, mesh=mesh, overlap=True)
    off = engine.run_instances(insts, n_angles=N_ANGLES,
                               max_epochs=MAX_EPOCHS, mesh=mesh,
                               overlap=False)
    _assert_bitexact(insts, on, off)


# --------------------------------------------------------------- MAXMARG --

def test_maxmarg_sharded_divisible(mesh):
    insts = _grid(2 * len(mesh.devices), selector="maxmarg")
    sh = engine.maxmarg.run_instances(insts, max_epochs=MAX_EPOCHS,
                                      mesh=mesh)
    ref = engine.maxmarg.run_instances(insts, max_epochs=MAX_EPOCHS)
    _assert_decision_exact(insts, sh, ref)
    assert all(r.extra["devices"] == len(mesh.devices) for r in sh)


def test_maxmarg_sharded_nondivisible(mesh):
    """Non-divisible B + k=3, the per-node warm-carry tracking path."""
    insts = _grid(len(mesh.devices) + 5, k=3, selector="maxmarg",
                  n_per_node=30)
    sh = engine.maxmarg.run_instances(insts, max_epochs=MAX_EPOCHS,
                                      mesh=mesh)
    ref = engine.maxmarg.run_instances(insts, max_epochs=MAX_EPOCHS)
    _assert_decision_exact(insts, sh, ref)


# --------------------------------------------------------------- plumbing --

def test_run_sweep_mesh_passthrough(mesh):
    """A mixed MEDIAN+MAXMARG sweep rides the sharded path per bucket."""
    insts = (_grid(3) + _grid(3, selector="maxmarg"))
    sh = engine.run_sweep(insts, n_angles=N_ANGLES, max_epochs=MAX_EPOCHS,
                          mesh=mesh)
    ref = engine.run_sweep(insts, n_angles=N_ANGLES, max_epochs=MAX_EPOCHS)
    _assert_decision_exact(insts, sh, ref)
    assert all(r.extra["devices"] == len(mesh.devices) for r in sh)


def test_mesh_requires_compact(mesh):
    insts = _grid(2)
    with pytest.raises(ValueError, match="compact"):
        engine.run_instances(insts, n_angles=N_ANGLES,
                             max_epochs=MAX_EPOCHS, mesh=mesh,
                             compact=False)
    with pytest.raises(ValueError, match="compact"):
        engine.maxmarg.run_instances(
            _grid(2, selector="maxmarg"), max_epochs=MAX_EPOCHS, mesh=mesh,
            compact=False)


def test_balanced_index_contract():
    """Per-shard slices are local, ordered, padded to a common multiple of
    BATCH_MULT with the out-of-range index, and counts match."""
    from repro.engine import hotloop

    B, S = 24, 4
    act = np.array([0, 1, 5, 6, 7, 8, 13, 18, 19, 20, 21, 22, 23])
    idx, counts = hotloop.balanced_index(act, B, S)
    B_loc = B // S
    L = len(idx) // S
    assert L % hotloop.BATCH_MULT == 0
    assert counts.tolist() == [3, 3, 1, 6]
    assert L == 8          # round_up(max count 6, 4)
    rebuilt = []
    for s in range(S):
        sl = idx[s * L:(s + 1) * L]
        c = counts[s]
        assert (sl[c:] == B).all()          # pad tail = out-of-range
        assert (np.diff(sl[:c]) > 0).all()  # ordered
        assert ((0 <= sl[:c]) & (sl[:c] < B_loc)).all()
        rebuilt.extend((sl[:c] + s * B_loc).tolist())
    assert rebuilt == act.tolist()
