"""Engine MAXMARG selector: legacy-oracle comm parity, B=1 delegation,
padding invariance, selector dispatch, and the d≠2 path.

The acceptance bar: across a ≥12-instance grid, the batched engine must
produce *identical* comm-byte totals (and rounds / converged flags) to the
retired host round loop it replaced (``benchmarks/legacy_maxmarg.py``), and
the public per-instance APIs must be the engine at B=1 exactly.
"""

import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro import engine
from repro.core import datasets
from repro.core.protocols import kparty, two_way

from benchmarks.legacy_maxmarg import kparty_maxmarg_hostloop
from conftest import global_err

MAX_EPOCHS = 24


def _grid():
    """12 two-party MAXMARG instances: dataset × ε × seed."""
    out = []
    for gen in (datasets.data1, datasets.data2, datasets.data3):
        for eps in (0.05, 0.02):
            for seed in (0, 1):
                out.append(engine.ProtocolInstance(
                    gen(n_per_node=100, k=2, seed=seed), eps, "maxmarg"))
    return out


def test_engine_matches_legacy_oracle_comm_bytes():
    insts = _grid()
    assert len(insts) >= 12
    batched = engine.maxmarg.run_instances(insts, max_epochs=MAX_EPOCHS)
    for inst, rb in zip(insts, batched):
        rl = kparty_maxmarg_hostloop(inst.shards, eps=inst.eps,
                                     max_epochs=MAX_EPOCHS)
        assert rb.comm == rl.comm, (inst.eps, rb.comm, rl.comm)
        assert rb.comm["bytes"] == rl.comm["bytes"]
        assert rb.converged == rl.converged and rb.converged
        assert rb.rounds == rl.rounds
        assert global_err(rb.classifier, inst.shards) <= inst.eps


def test_batched_matches_b1_delegation():
    insts = _grid()
    batched = engine.maxmarg.run_instances(insts, max_epochs=MAX_EPOCHS)
    for inst, rb in zip(insts, batched):
        r1 = kparty.iterative_support_kparty(
            inst.shards, eps=inst.eps, max_epochs=MAX_EPOCHS,
            selector="maxmarg")
        assert rb.comm == r1.comm
        assert rb.converged == r1.converged
        assert rb.rounds == r1.rounds


def test_kparty_matches_legacy_oracle():
    for seed, eps in ((0, 0.1), (1, 0.05)):
        shards = datasets.data3(n_per_node=75, k=4, seed=seed)
        re = kparty.iterative_support_kparty(
            shards, eps=eps, max_epochs=MAX_EPOCHS, selector="maxmarg")
        rl = kparty_maxmarg_hostloop(shards, eps=eps, max_epochs=MAX_EPOCHS)
        assert re.comm == rl.comm
        assert re.converged == rl.converged and re.rounds == rl.rounds


def test_padding_invariance():
    """An instance's outcome must not depend on its batch neighbours: ragged
    shard sizes are padded with label-0 rows, which the masked fit and every
    masked selection ignore."""
    small = engine.ProtocolInstance(
        datasets.data1(n_per_node=60, k=2, seed=3), 0.05, "maxmarg")
    big = engine.ProtocolInstance(
        datasets.data3(n_per_node=200, k=2, seed=4), 0.05, "maxmarg")
    alone = engine.maxmarg.run_instances([small], max_epochs=MAX_EPOCHS)[0]
    padded = engine.maxmarg.run_instances([small, big],
                                          max_epochs=MAX_EPOCHS)[0]
    assert alone.comm == padded.comm
    assert alone.converged == padded.converged
    assert alone.rounds == padded.rounds


def test_two_way_api_runs_on_engine():
    shards = datasets.data3(n_per_node=100, k=2, seed=0)
    r = two_way.iterative_support_maxmarg(shards, eps=0.05)
    assert r.extra and r.extra.get("engine")
    assert r.extra["selector"] == "maxmarg" and r.extra["batch"] == 1
    assert r.converged
    assert global_err(r.classifier, shards) <= 0.05


def test_higher_dim_on_engine():
    """MAXMARG has no direction grid, so the engine path covers any d;
    paper Table 3's d=10 lift must converge with small communication."""
    shards = datasets.lift_dim(datasets.data1(n_per_node=150, k=2, seed=0),
                               d=10, seed=7)
    r = two_way.iterative_support_maxmarg(shards, eps=0.05)
    assert r.converged
    assert global_err(r.classifier, shards) <= 0.05
    assert r.comm["points"] < 100


def test_selector_dispatch_buckets_mixed_sweeps():
    """engine.run_sweep buckets a mixed (selector, k) sweep and returns
    results in input order, each equal to its homogeneous run."""
    shards2 = datasets.data1(n_per_node=80, k=2, seed=0)
    shards4 = datasets.data3(n_per_node=60, k=4, seed=1)
    insts = [
        engine.ProtocolInstance(shards2, 0.05, "maxmarg"),
        engine.ProtocolInstance(shards2, 0.05, "median"),
        engine.ProtocolInstance(shards4, 0.1, "maxmarg"),
    ]
    out = engine.run_sweep(insts, max_epochs=MAX_EPOCHS, n_angles=256)
    assert [r.extra.get("selector", "median") if r.extra else "median"
            for r in out][0] == "maxmarg"
    r_mm = engine.maxmarg.run_instances([insts[0]], max_epochs=MAX_EPOCHS)[0]
    assert out[0].comm == r_mm.comm
    r_med = engine.run_instances([insts[1]], n_angles=256,
                                 max_epochs=MAX_EPOCHS)[0]
    assert out[1].comm == r_med.comm
    r_mm4 = engine.maxmarg.run_instances([insts[2]], max_epochs=MAX_EPOCHS)[0]
    assert out[2].comm == r_mm4.comm
    with pytest.raises(ValueError):
        engine.run_sweep([engine.ProtocolInstance(shards2, 0.05, "nope")])


def test_transcript_capacity_never_overflows():
    insts = _grid()
    data, state0, k, cap = engine.pack_instances_maxmarg(
        insts, max_epochs=MAX_EPOCHS, max_support=4)
    final = engine.maxmarg.run_compiled(data, state0, k=k,
                                        max_turns=k * MAX_EPOCHS)
    assert int(np.max(np.asarray(final.w_fill))) <= cap - 4
