"""Protocol-service front-end gates (ISSUE 7 tentpole, serve layer).

``repro.serve.ProtocolService`` is the streaming entry point over the
session pool: open a session, feed labeled batches per node (reservoir
ingest), close to enqueue, pump the pool.  The service adds no decision
logic, so these tests pin the wrapper semantics only: streamed ingest
that fits the reservoir reaches the pool byte-identical to a direct
``submit`` (results bitwise equal), oversized streams downsample at the
pinned shape, the supervision surface passes through, checkpointing
refuses open handles, and the satellite-6 API split holds — the
token-decode stub stays importable under its explicit name while the
protocol service is the package's primary export.
"""

import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.serve import (
    FAULT_FREE,
    FaultSchedule,
    PoolConfig,
    ProtocolService,
    ServingEngine,
    TokenServingEngine,
)

K = 2
N_PAD = 16


def _cfg(**kw):
    base = dict(slots=4, k=K, n_pad=N_PAD, n_angles=64, max_epochs=8)
    base.update(kw)
    return PoolConfig(**base)


def _shards(seed, n=N_PAD, k=K):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=2)
    w /= np.linalg.norm(w)
    out = []
    for _ in range(k):
        X = rng.normal(size=(n, 2)).astype(np.float32)
        out.append((X, np.where(X @ w > 0, 1, -1).astype(np.int32)))
    return out


def test_streamed_ingest_matches_direct_submit():
    """Feeding ≤ capacity points in chunks must reach the pool as exactly
    the direct-submit instance — bitwise-equal results."""
    svc = ProtocolService(_cfg())
    direct = ProtocolService(_cfg())
    sids = {}
    for seed in range(6):
        shards = _shards(seed)
        h = svc.open()
        for node, (X, y) in enumerate(shards):
            for lo in range(0, N_PAD, 5):          # ragged chunks
                svc.feed(h, node, X[lo:lo + 5], y[lo:lo + 5])
        sids[seed] = (svc.close(h), direct.submit(shards))
    svc.run()
    direct.run()
    for seed, (sa, sb) in sids.items():
        ra, rb = svc.result(sa), direct.result(sb)
        assert svc.status(sa) == "converged"
        assert np.array_equal(np.asarray(ra.classifier.w),
                              np.asarray(rb.classifier.w))
        assert float(ra.classifier.b) == float(rb.classifier.b)
        assert ra.comm == rb.comm and ra.rounds == rb.rounds


def test_partial_fill_streams_admit():
    """A reservoir closed below capacity submits its ragged snapshot; the
    pool pads with inert label-0 rows and the session still converges."""
    svc = ProtocolService(_cfg())
    shards = _shards(40, n=5)                     # 5 < n_pad real rows
    h = svc.open()
    for node, (X, y) in enumerate(shards):
        svc.feed(h, node, X, y)
    sid = svc.close(h)
    svc.run()
    assert svc.status(sid) == "converged"
    assert svc.result(sid).converged


def test_oversized_stream_downsamples_at_pinned_shape():
    """Feeding far more than the reservoir capacity still admits one
    pinned-shape instance (Vitter downsampling), and converges."""
    svc = ProtocolService(_cfg(), ingest_seed=1)
    rng = np.random.default_rng(0)
    w = rng.normal(size=2)
    w /= np.linalg.norm(w)
    h = svc.open()
    for node in range(K):
        for _ in range(10):                       # 10 * 64 points per node
            X = rng.normal(size=(64, 2)).astype(np.float32)
            svc.feed(h, node, X, np.where(X @ w > 0, 1, -1))
    sid = svc.close(h)
    svc.run()
    assert svc.status(sid) == "converged"
    assert svc.stats["admitted"] == 1


def test_ingest_validation():
    svc = ProtocolService(_cfg())
    with pytest.raises(ValueError, match="exceeds pinned n_pad"):
        svc.open(reservoir_capacity=N_PAD + 1)
    h = svc.open()
    with pytest.raises(ValueError, match="node 2 outside"):
        svc.feed(h, 2, np.zeros((1, 2), np.float32), np.ones(1))
    with pytest.raises(ValueError, match="empty node"):
        svc.close(h)                              # node 1 never fed
    h = svc.open()
    svc.feed(h, 0, np.zeros((1, 2), np.float32), np.ones(1))
    with pytest.raises(ValueError, match="empty node"):
        svc.close(h)


def test_checkpoint_refuses_open_handles(tmp_path):
    svc = ProtocolService(_cfg())
    h = svc.open()
    with pytest.raises(RuntimeError, match="still open"):
        svc.checkpoint(str(tmp_path))
    svc.feed(h, 0, np.zeros((1, 2), np.float32), np.ones(1))
    svc.feed(h, 1, np.zeros((1, 2), np.float32), np.ones(1))
    svc.close(h)
    svc.checkpoint(str(tmp_path))                 # closed handles are fine
    restored = ProtocolService.restore(str(tmp_path))
    restored.run()
    assert len(restored.pool.results) == 1


def test_faulted_service_surfaces_supervision():
    svc = ProtocolService(
        _cfg(), schedule=FaultSchedule(seed=3, p_dropout=0.15,
                                       p_straggle=0.1))
    for seed in range(8):
        svc.submit(_shards(seed))
    svc.run()
    assert svc.stats["dropouts"] + svc.stats["straggles"] > 0
    for sid in range(8):
        rec = svc.session(sid)
        assert rec["status"] in ("converged", "budget_exhausted",
                                 "quarantined")
        if rec["status"] == "quarantined":
            assert svc.result(sid) is None


def test_token_stub_kept_behind_explicit_name():
    """Satellite 6: the decode stub is NOT the protocol service — it lives
    on under TokenServingEngine (ServingEngine aliased for compat), and
    its docstring says so."""
    assert ServingEngine is TokenServingEngine
    assert "stub" in (TokenServingEngine.__doc__ or "").lower()
    import repro.serve as serve
    assert serve.ProtocolService is ProtocolService
    assert "ProtocolService" in (serve.engine.__doc__ or "")
    assert not FAULT_FREE.any_faults
