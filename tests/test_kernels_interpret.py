"""Interpret-mode parity coverage for the batch-grid protocol kernels.

The sweep engine's TPU data plane (``support_margin`` batched kernels, the
``median_cut`` scan, and the fused MAXMARG support/violation kernel) must be
testable in CPU CI, not just on TPU hardware.  This module forces Pallas
interpretation — via ``pltpu.force_tpu_interpret_mode`` where this jax
version has it, else per-call ``interpret=True`` — and checks every kernel
against its pure-jnp oracle on engine-shaped inputs (label-0 padding rows,
disallowed directions, ±inf range sentinels).

These tests run in the CI ``bench-smoke`` job alongside the BENCH schema
gate, so a kernel regression cannot hide behind a TPU-only test plan.
"""

import contextlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import ops, ref


def _interpret_ctx():
    """The strongest interpret forcing this jax exposes: the global
    force-TPU-interpret context when available (newer jax), else a null
    context — each call below also passes interpret=True explicitly, so the
    kernels interpret either way."""
    if hasattr(pltpu, "force_tpu_interpret_mode"):
        return pltpu.force_tpu_interpret_mode()
    return contextlib.nullcontext()


def _sweep_inputs(B=4, m=96, n=200, d=2, seed=7):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    V = jax.random.normal(ks[0], (m, d))
    V = V / jnp.linalg.norm(V, axis=1, keepdims=True)
    X = jax.random.normal(ks[1], (B, n, d))
    y = jnp.where(jax.random.bernoulli(ks[2], 0.5, (B, n)), 1, -1)
    y = y * jax.random.bernoulli(ks[3], 0.8, (B, n))     # label-0 pads
    ok = jax.random.bernoulli(ks[4], 0.7, (B, m))
    lo = jnp.where(jax.random.bernoulli(ks[5], 0.8, (B, m)),
                   jax.random.normal(ks[5], (B, m)), -jnp.inf)
    hi = jnp.where(jax.random.bernoulli(ks[4], 0.8, (B, m)),
                   lo + jax.random.uniform(ks[1], (B, m)), jnp.inf)
    return V, X, y, ok, lo, hi


def test_threshold_ranges_batched_interpret():
    V, X, y, *_ = _sweep_inputs()
    with _interpret_ctx():
        lo, hi = ops.support_ranges_batch(V, X, y, interpret=True)
    loe, hie = ref.threshold_ranges_batch_ref(V, X, y)
    for got, want in ((lo, loe), (hi, hie)):
        fin = np.isfinite(np.asarray(want))
        np.testing.assert_allclose(np.asarray(got)[fin],
                                   np.asarray(want)[fin], rtol=1e-5)


def test_uncertain_mask_batched_interpret():
    V, X, y, ok, lo, hi = _sweep_inputs()
    with _interpret_ctx():
        mask = ops.support_uncertain_batch(V, ok, lo, hi, X, y,
                                           interpret=True)
    want = ref.uncertain_mask_batch_ref(V, ok, lo, hi, X, y)
    assert bool(jnp.all(mask == want))


def test_median_cut_batched_interpret_bit_for_bit():
    V, X, y, ok, lo, hi = _sweep_inputs()
    with _interpret_ctx():
        got = ops.support_median_cut_batch(V, ok.astype(jnp.float32), lo, hi,
                                           X, y, interpret=True)
    want = ref.median_cut_scores_batch_ref(V, ok, lo, hi, X, y)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_median_extremes_batched_interpret_bit_for_bit():
    """The MEDIAN hot path's fill-capped per-turn extremes kernel: integer
    row choices must match the jnp reference exactly, including the
    absent-class and fully-padded-node fallbacks."""
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    B, k, nW, d = 4, 3, 60, 2
    XW = jax.random.normal(ks[0], (B, k, nW, d))
    yW = jnp.where(jax.random.bernoulli(ks[1], 0.5, (B, k, nW)), 1, -1)
    yW = yW * jax.random.bernoulli(ks[2], 0.8, (B, k, nW))  # label-0 pads
    yW = yW.at[0, 0].set(1)      # a node with no negative class
    yW = yW.at[1, 2].set(0)      # a fully padded node
    v = jax.random.normal(ks[3], (B, d))
    with _interpret_ctx():
        got = ops.support_extremes_batch(v, XW, yW, interpret=True)
    want = ref.median_extremes_batch_ref(v, XW, yW)
    for g, e in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(e))


@pytest.mark.parametrize("max_support,viol_ship", [(4, 2), (8, 2), (2, 1)])
def test_maxmarg_turn_scan_interpret_bit_for_bit(max_support, viol_ship):
    ks = jax.random.split(jax.random.PRNGKey(11), 8)
    B, N, k, n, d = 5, 72, 3, 40, 2
    K = jax.random.normal(ks[0], (B, N, d))
    yK = jnp.where(jax.random.bernoulli(ks[1], 0.5, (B, N)), 1, -1)
    yK = yK * jax.random.bernoulli(ks[2], 0.8, (B, N))
    X = jax.random.normal(ks[3], (B, k, n, d))
    y = jnp.where(jax.random.bernoulli(ks[4], 0.5, (B, k, n)), 1, -1)
    y = y * jax.random.bernoulli(ks[5], 0.8, (B, k, n))
    w = jax.random.normal(ks[6], (B, d))
    b = jax.random.normal(ks[7], (B,))
    with _interpret_ctx():
        got = ops.support_violation_batch(
            w, b, K, yK, X, y, max_support=max_support, viol_ship=viol_ship,
            interpret=True)
    want = ref.maxmarg_turn_batch_ref(
        w, b, K, yK, X, y, max_support=max_support, viol_ship=viol_ship)
    for g, e in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(e))


def _pegasos_inputs(B, N, d, seed=3, found_frac=0.3):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    X = jax.random.normal(ks[0], (B, N, d), jnp.float32)
    y = jnp.where(jax.random.bernoulli(ks[1], 0.5, (B, N)), 1.0, -1.0)
    y = y * jax.random.bernoulli(ks[2], 0.85, (B, N))    # label-0 pads
    nv = jnp.maximum(jnp.sum(y != 0, axis=1), 1).astype(jnp.float32)
    w = jnp.zeros((B, d), jnp.float32)
    b = jnp.zeros((B,), jnp.float32)
    lam = jnp.full((B,), 1e-2, jnp.float32)
    found = jax.random.bernoulli(ks[3], found_frac, (B,))
    w_best = jax.random.normal(ks[4], (B, d), jnp.float32)
    b_best = jax.random.normal(ks[5], (B,), jnp.float32)
    return X, y, nv, w, b, lam, found, w_best, b_best


def test_pegasos_stage_interpret_bit_for_bit():
    """Lane-aligned d + single N-tile: the kernel's op sequence is exactly
    the jnp twin's, so every output (including the fused latch) must match
    bit-for-bit through the interpreter."""
    args = _pegasos_inputs(B=6, N=48, d=8)
    want = ref.pegasos_stage_batch_ref(*args, nsteps=60)
    with _interpret_ctx():
        got = ops.pegasos_stage(*args, nsteps=60, use_pallas=True,
                                interpret=True, block_b=8, block_n=64,
                                unroll=1)
    for g, e in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(e))


def test_pegasos_stage_interpret_tiled_grid():
    """Multi-block grid with unaligned d and N: the VMEM gradient
    accumulation across N-tiles and the d-lane padding reassociate the
    contractions, so floats are allclose while the latch decisions
    (found / which w_best was taken) stay bit-equal."""
    args = _pegasos_inputs(B=5, N=70, d=12, seed=9)
    want = ref.pegasos_stage_batch_ref(*args, nsteps=60)
    with _interpret_ctx():
        got = ops.pegasos_stage(*args, nsteps=60, use_pallas=True,
                                interpret=True, block_b=2, block_n=16,
                                unroll=1)
    names = ("w", "b", "mmin", "found", "w_best", "b_best")
    for name, g, e in zip(names, got, want):
        if name == "found":
            np.testing.assert_array_equal(np.asarray(g), np.asarray(e))
        else:
            np.testing.assert_allclose(np.asarray(g), np.asarray(e),
                                       rtol=1e-5, atol=1e-6)


def test_pegasos_stage_interpret_warm_offset_and_latch():
    """t0 (the warm polish eta offset) threads through both paths
    identically, and an already-latched instance's w_best is never
    overwritten by a later separating stage."""
    args = _pegasos_inputs(B=4, N=32, d=8, seed=5, found_frac=1.0)
    want = ref.pegasos_stage_batch_ref(*args, nsteps=40, t0=1024.0)
    with _interpret_ctx():
        got = ops.pegasos_stage(*args, nsteps=40, t0=1024.0,
                                use_pallas=True, interpret=True,
                                block_b=8, block_n=32, unroll=1)
    for g, e in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(e))
    # all instances entered latched -> w_best must be the input w_best
    np.testing.assert_array_equal(np.asarray(got[4]), np.asarray(args[7]))
    np.testing.assert_array_equal(np.asarray(got[3]),
                                  np.ones(4, bool))
