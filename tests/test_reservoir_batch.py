"""Vectorized Reservoir.add_batch: inclusion probabilities and invariants.

Kept hypothesis-free so it runs even when the ``dev`` extra is absent (the
property-based reservoir tests live in test_sampling.py)."""

import numpy as np

from repro.core import sampling


def test_add_batch_size_and_membership():
    rng = np.random.default_rng(0)
    res = sampling.Reservoir(16, dim=2, rng=rng)
    X = rng.normal(size=(200, 2))
    y = np.where(rng.random(200) < 0.5, 1, -1)
    res.add_batch(X, y)
    RX, Ry = res.sample()
    assert RX.shape == (16, 2) and res.seen == 200
    for r in RX:
        assert np.any(np.all(np.isclose(X, r), axis=1))


def test_add_batch_fill_phase_exact():
    res = sampling.Reservoir(8, dim=1)
    X = np.arange(5, dtype=float).reshape(-1, 1)
    res.add_batch(X, np.ones(5, np.int32))
    RX, _ = res.sample()
    np.testing.assert_array_equal(RX, X)  # under capacity: verbatim, in order


def test_add_batch_across_multiple_shards():
    """Chained add_batch calls continue the same stream (the k-party chain
    protocol's use): global positions keep counting across calls."""
    rng = np.random.default_rng(3)
    res = sampling.Reservoir(10, dim=1, rng=rng)
    for c in range(4):
        X = np.full((50, 1), float(c))
        res.add_batch(X, np.ones(50, np.int32))
    assert res.seen == 200
    RX, _ = res.sample()
    assert RX.shape[0] == 10


def test_add_batch_uniform_inclusion():
    """Each of n items must land in a k-slot reservoir with probability
    ~ k/n (Vitter's invariant), same as the sequential sampler."""
    n, k, trials = 40, 8, 1500
    counts = np.zeros(n)
    for t in range(trials):
        rng = np.random.default_rng(t)
        res = sampling.Reservoir(k, dim=1, rng=rng)
        X = np.arange(n, dtype=float).reshape(-1, 1)
        res.add_batch(X, np.ones(n, np.int32))
        RX, _ = res.sample()
        counts[RX.reshape(-1).astype(int)] += 1
    freq = counts / trials
    assert np.all(np.abs(freq - k / n) < 0.05)


def test_add_batch_matches_sequential_distribution():
    """Batched and sequential ingestion draw from the same distribution:
    compare per-item inclusion frequencies."""
    n, k, trials = 30, 6, 1200
    freq = {}
    for mode in ("seq", "batch"):
        counts = np.zeros(n)
        for t in range(trials):
            rng = np.random.default_rng(10_000 + t)
            res = sampling.Reservoir(k, dim=1, rng=rng)
            X = np.arange(n, dtype=float).reshape(-1, 1)
            y = np.ones(n, np.int32)
            if mode == "seq":
                for i in range(n):
                    res.add(X[i], 1)
            else:
                res.add_batch(X, y)
            RX, _ = res.sample()
            counts[RX.reshape(-1).astype(int)] += 1
        freq[mode] = counts / trials
    assert np.all(np.abs(freq["seq"] - freq["batch"]) < 0.06)
