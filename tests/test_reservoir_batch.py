"""Vectorized Reservoir.add_batch: inclusion probabilities and invariants.

Kept hypothesis-free so it runs even when the ``dev`` extra is absent (the
property-based reservoir tests live in test_sampling.py)."""

import numpy as np

from repro.core import sampling


def test_add_batch_size_and_membership():
    rng = np.random.default_rng(0)
    res = sampling.Reservoir(16, dim=2, rng=rng)
    X = rng.normal(size=(200, 2))
    y = np.where(rng.random(200) < 0.5, 1, -1)
    res.add_batch(X, y)
    RX, Ry = res.sample()
    assert RX.shape == (16, 2) and res.seen == 200
    for r in RX:
        assert np.any(np.all(np.isclose(X, r), axis=1))


def test_add_batch_fill_phase_exact():
    res = sampling.Reservoir(8, dim=1)
    X = np.arange(5, dtype=float).reshape(-1, 1)
    res.add_batch(X, np.ones(5, np.int32))
    RX, _ = res.sample()
    np.testing.assert_array_equal(RX, X)  # under capacity: verbatim, in order


def test_add_batch_across_multiple_shards():
    """Chained add_batch calls continue the same stream (the k-party chain
    protocol's use): global positions keep counting across calls."""
    rng = np.random.default_rng(3)
    res = sampling.Reservoir(10, dim=1, rng=rng)
    for c in range(4):
        X = np.full((50, 1), float(c))
        res.add_batch(X, np.ones(50, np.int32))
    assert res.seen == 200
    RX, _ = res.sample()
    assert RX.shape[0] == 10


def test_add_batch_uniform_inclusion():
    """Each of n items must land in a k-slot reservoir with probability
    ~ k/n (Vitter's invariant), same as the sequential sampler."""
    n, k, trials = 40, 8, 1500
    counts = np.zeros(n)
    for t in range(trials):
        rng = np.random.default_rng(t)
        res = sampling.Reservoir(k, dim=1, rng=rng)
        X = np.arange(n, dtype=float).reshape(-1, 1)
        res.add_batch(X, np.ones(n, np.int32))
        RX, _ = res.sample()
        counts[RX.reshape(-1).astype(int)] += 1
    freq = counts / trials
    assert np.all(np.abs(freq - k / n) < 0.05)


def _chain_chi_square(include_counts, trials, cap, n):
    """Chi-square statistic of per-item inclusion counts against Vitter's
    uniform k/t = cap/n, with the per-item binomial variance."""
    p = cap / n
    exp = trials * p
    var = trials * p * (1.0 - p)
    return float(np.sum((include_counts - exp) ** 2) / var)


def test_add_batch_chain_inclusion_chi_square():
    """Statistical gate on the vectorized sampler: stream n items through a
    *multi-shard chain* of add_batch calls (the k-party protocol's use) and
    chi-square the per-item inclusion frequencies against Vitter's k/t.
    Catches any bias from the fill-phase/fancy-assignment vectorization that
    a membership test cannot see."""
    cap, trials = 8, 4000
    shard_sizes = (13, 9, 18, 8)          # ragged chain, n = 48
    n = sum(shard_sizes)
    counts = np.zeros(n)
    for t in range(trials):
        res = sampling.Reservoir(cap, dim=1, rng=np.random.default_rng(t))
        start = 0
        for sz in shard_sizes:
            X = np.arange(start, start + sz, dtype=float).reshape(-1, 1)
            res.add_batch(X, np.ones(sz, np.int32))
            start += sz
        RX, _ = res.sample()
        counts[RX.reshape(-1).astype(int)] += 1
    chi2 = _chain_chi_square(counts, trials, cap, n)
    # df = n-1 = 47: mean 47, sd ~9.7; 47 + 5 sd ≈ 96 — a generous gate that
    # still fails hard for e.g. a fill-phase item never being evicted
    assert chi2 < 100.0, (chi2, counts / trials)


def test_engine_reservoir_chain_inclusion_chi_square():
    """The engine's on-device sampler must draw from the same distribution:
    same multi-shard chain, jax.random keys, chi-square vs cap/n."""
    import jax
    import jax.numpy as jnp

    from repro.engine.oneway import _make_ingest

    cap, trials = 8, 2000
    shard_sizes = (13, 9, 18, 8)
    n = sum(shard_sizes)
    ingest = jax.jit(_make_ingest(cap))
    counts = np.zeros(n)
    shards = []
    start = 0
    for sz in shard_sizes:
        shards.append(jnp.arange(start, start + sz, dtype=jnp.float32
                                 ).reshape(-1, 1))
        start += sz
    labels = [jnp.ones(sz, jnp.int32) for sz in shard_sizes]
    for t in range(trials):
        key = jax.random.PRNGKey(t)
        resX = jnp.zeros((cap, 1), jnp.float32)
        resy = jnp.zeros((cap,), jnp.int32)
        seen = jnp.zeros((), jnp.int32)
        for hop, (Xi, yi) in enumerate(zip(shards, labels)):
            key, sub = jax.random.split(key)
            resX, resy, seen = ingest(resX, resy, seen, sub, Xi, yi,
                                      jnp.int32(cap))
        counts[np.asarray(resX).reshape(-1).astype(int)] += 1
    chi2 = _chain_chi_square(counts, trials, cap, n)
    assert chi2 < 100.0, (chi2, counts / trials)


def test_add_batch_matches_sequential_distribution():
    """Batched and sequential ingestion draw from the same distribution:
    compare per-item inclusion frequencies."""
    n, k, trials = 30, 6, 1200
    freq = {}
    for mode in ("seq", "batch"):
        counts = np.zeros(n)
        for t in range(trials):
            rng = np.random.default_rng(10_000 + t)
            res = sampling.Reservoir(k, dim=1, rng=rng)
            X = np.arange(n, dtype=float).reshape(-1, 1)
            y = np.ones(n, np.int32)
            if mode == "seq":
                for i in range(n):
                    res.add(X[i], 1)
            else:
                res.add_batch(X, y)
            RX, _ = res.sample()
            counts[RX.reshape(-1).astype(int)] += 1
        freq[mode] = counts / trials
    assert np.all(np.abs(freq["seq"] - freq["batch"]) < 0.06)
