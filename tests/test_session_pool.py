"""Session-pool robustness gates (ISSUE 7 tentpole).

The pool's contract (DESIGN.md §session pool & failure model) in test
form:

* **bit-exactness by construction** — every dispatch runs at ONE pinned
  compile key, so results are a pure function of a session's own data:
  streaming order, batch composition, fault delays and checkpoint/restore
  must all leave results bitwise identical, and chaos survivors must match
  the fault-free pool bit for bit;
* **engine parity** — the fault-free pool agrees with the sweep-path
  ``engine.run_instances`` oracle on every decision and metered bit
  (separators allclose; the two paths' compile keys may move floats by
  ulps — the engine's own hot-vs-cold caveat);
* **supervision** — each forced corruption kind trips exactly its paired
  invariant, dropouts escalate retry/backoff to a ``retry_budget``
  quarantine on schedule, stragglers delay without charging retries;
* **determinism** — same seed ⇒ identical eviction sets, retry counters
  and per-session ledgers across runs and across restore;
* **steady state** — a second identical run adds zero jit cache entries
  (admission refills slots at pinned keys).

Forced-fault cases use a duck-typed schedule (the pool only reads
``draws`` / ``straggle_max`` / ``any_faults``), pinning faults to exact
(sid, pool turn) coordinates instead of fishing for seeds.
"""

import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.engine import hotloop, median, run_instances, session_pool
from repro.engine.faults import (
    CORRUPT_COMM,
    CORRUPT_FILL,
    CORRUPT_NAN,
    FaultSchedule,
)
from repro.engine.session_pool import (
    ST_BUDGET,
    ST_CONVERGED,
    ST_QUARANTINED,
    PoolConfig,
    SessionPool,
)
from repro.engine.state import ProtocolInstance

K = 2
N_PAD = 16
N_ANGLES = 64
MAX_EPOCHS = 8

CHAOS = FaultSchedule(seed=3, p_dropout=0.08, p_drop_msg=0.04,
                      p_straggle=0.08, p_corrupt=0.03)


def _cfg(**kw):
    base = dict(slots=4, k=K, n_pad=N_PAD, n_angles=N_ANGLES,
                max_epochs=MAX_EPOCHS)
    base.update(kw)
    return PoolConfig(**base)


def _workload(n, seed=0, n_pad=N_PAD, k=K, separable=True):
    """Shared-separator instances, every shard exactly n_pad real rows so
    the pool and the run_instances oracle see identical data and budgets."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        w = rng.normal(size=2)
        w /= np.linalg.norm(w)
        shards = []
        for _ in range(k):
            X = rng.normal(size=(n_pad, 2)).astype(np.float32)
            if separable:
                yy = np.where(X @ w > 0, 1, -1).astype(np.int32)
            else:
                yy = rng.choice(np.array([-1, 1], np.int32), size=n_pad)
            shards.append((X, yy))
        out.append(shards)
    return out


def _run_pool(workload, cfg=None, schedule=None):
    pool = SessionPool(cfg or _cfg(), schedule)
    for shards in workload:
        pool.submit(shards)
    pool.run()
    return pool


def _res_bitwise(a, b):
    return (np.array_equal(np.asarray(a.classifier.w),
                           np.asarray(b.classifier.w))
            and float(a.classifier.b) == float(b.classifier.b)
            and a.comm == b.comm and a.rounds == b.rounds
            and a.converged == b.converged)


class ForcedSchedule:
    """Duck-typed fault schedule: fire exactly at the given (sid, turn)
    coordinates; a ``(sid, None)`` key fires on every turn."""

    straggle_max = 3
    any_faults = True

    def __init__(self, dropout=(), drop_msg=(), straggle=None, corrupt=None):
        self._drop = set(dropout)
        self._msg = set(drop_msg)
        self._str = dict(straggle or {})
        self._cor = dict(corrupt or {})

    @staticmethod
    def _hit(table, s, t):
        return (s, t) in table or (s, None) in table

    @staticmethod
    def _get(table, s, t, default):
        return table.get((s, t), table.get((s, None), default))

    def draws(self, sids, t):
        sids = [int(s) for s in np.asarray(sids)]
        return {
            "dropout": np.asarray(
                [self._hit(self._drop, s, t) for s in sids], bool),
            "drop_msg": np.asarray(
                [self._hit(self._msg, s, t) for s in sids], bool),
            "straggle": np.asarray(
                [self._get(self._str, s, t, 0) for s in sids], np.int32),
            "corrupt": np.asarray(
                [self._get(self._cor, s, t, -1) for s in sids], np.int32),
        }


# ---------------------------------------------------------------------------
# engine parity & composition invariance
# ---------------------------------------------------------------------------


def test_fault_free_pool_matches_engine_oracle():
    workload = _workload(10, seed=1)
    pool = _run_pool(workload)
    oracle = run_instances(
        [ProtocolInstance(shards=s, eps=pool.cfg.eps) for s in workload],
        n_angles=N_ANGLES, max_epochs=MAX_EPOCHS)
    for sid, o in enumerate(oracle):
        r = pool.results[sid]
        assert r.converged == o.converged and r.rounds == o.rounds
        assert r.comm == o.comm
        np.testing.assert_allclose(np.asarray(r.classifier.w),
                                   np.asarray(o.classifier.w),
                                   rtol=1e-5, atol=1e-6)
        assert np.isclose(float(r.classifier.b), float(o.classifier.b),
                          rtol=1e-5, atol=1e-6)
        assert r.extra["session_pool"] and r.extra["sid"] == sid


def test_streaming_order_is_bitwise_invariant():
    """All-at-once vs trickled submission changes admission timing and
    batch composition — with one pinned dispatch key neither may move a
    single bit of any result."""
    workload = _workload(9, seed=2)
    a = _run_pool(workload)

    b = SessionPool(_cfg())
    it = iter(workload)
    exhausted = False
    while True:
        while not exhausted and len(b.pending) < 1:
            try:
                b.submit(next(it))
            except StopIteration:
                exhausted = True
        if exhausted and b.drained():
            break
        b.step_pool()
    for sid in a.results:
        assert _res_bitwise(a.results[sid], b.results[sid]), sid


def test_budget_exhausted_sessions_still_report():
    workload = _workload(3, seed=4, separable=False)
    pool = _run_pool(workload)
    assert any(pool.sessions[s]["status"] == ST_BUDGET for s in range(3))
    for sid in range(3):
        r = pool.results[sid]
        if pool.sessions[sid]["status"] == ST_BUDGET:
            assert not r.converged and r.rounds == MAX_EPOCHS


def test_maxmarg_pool_smoke():
    cfg = _cfg(selector="maxmarg", slots=2, max_epochs=6)
    workload = _workload(4, seed=5)
    pool = _run_pool(workload, cfg=cfg)
    from repro.engine import maxmarg
    oracle = maxmarg.run_instances(
        [ProtocolInstance(shards=s, eps=cfg.eps, selector="maxmarg")
         for s in workload], max_epochs=6)
    for sid, o in enumerate(oracle):
        r = pool.results[sid]
        assert r.converged == o.converged and r.rounds == o.rounds
        assert r.comm == o.comm


def test_submit_validation():
    pool = SessionPool(_cfg())
    X = np.zeros((4, 2), np.float32)
    ok = np.ones((4,), np.int32)
    with pytest.raises(ValueError, match="expected 2 shards"):
        pool.submit([(X, ok)])
    with pytest.raises(ValueError, match="rows > pinned"):
        pool.submit([(np.zeros((N_PAD + 1, 2), np.float32),
                      np.ones((N_PAD + 1,), np.int32)), (X, ok)])
    with pytest.raises(ValueError, match="labels"):
        pool.submit([(X, np.array([1, 0, 1, 1])), (X, ok)])


# ---------------------------------------------------------------------------
# chaos determinism & graceful degradation
# ---------------------------------------------------------------------------


def test_chaos_two_runs_identical():
    """Same seed ⇒ identical eviction sets, retry counts, ledgers and
    bitwise-identical results — across two fresh pools."""
    workload = _workload(12, seed=3)
    a = _run_pool(workload, schedule=CHAOS)
    b = _run_pool(workload, schedule=CHAOS)
    assert a.stats == b.stats
    assert a.sessions == b.sessions
    assert set(a.results) == set(b.results)
    for sid in a.results:
        assert _res_bitwise(a.results[sid], b.results[sid]), sid
    # the run must actually have been chaotic
    assert a.stats["dropouts"] + a.stats["drop_msgs"] > 0
    assert a.stats["straggles"] > 0


def test_chaos_survivors_bitwise_vs_fault_free():
    workload = _workload(12, seed=3)
    chaos = _run_pool(workload, schedule=CHAOS)
    clean = _run_pool(workload)
    quarantined = 0
    for sid in range(len(workload)):
        rec = chaos.sessions[sid]
        if rec["status"] == ST_QUARANTINED:
            quarantined += 1
            assert sid not in chaos.results
            assert rec["quarantine_reason"] is not None
        else:
            assert _res_bitwise(chaos.results[sid], clean.results[sid]), sid
    assert quarantined == chaos.stats["quarantined"]


@pytest.mark.parametrize("kind,reason", [
    (CORRUPT_NAN, "nan_separator"),
    (CORRUPT_FILL, "fill_regression"),
    (CORRUPT_COMM, "comm_blowout"),
])
def test_corruption_kind_trips_its_invariant(kind, reason):
    # non-separable data keeps every session running its full turn budget,
    # so the mid-run corruption at pool turn 1 cannot race a same-turn
    # convergence (a finished session's transcript legitimately stops
    # growing, so the fill screen only covers still-running rows)
    workload = _workload(3, seed=6, separable=False)
    sched = ForcedSchedule(corrupt={(1, 1): kind})
    pool = _run_pool(workload, schedule=sched)
    rec = pool.sessions[1]
    assert rec["status"] == ST_QUARANTINED
    assert rec["quarantine_reason"] == reason
    assert rec["corrupt_kind"] == kind
    assert 1 not in pool.results
    assert pool.stats["quarantined"] == 1
    assert pool.stats["corruptions"] == 1
    # bystanders in the same batch are untouched
    clean = _run_pool(workload)
    for sid in (0, 2):
        assert pool.sessions[sid]["status"] == \
            clean.sessions[sid]["status"]
        assert _res_bitwise(pool.results[sid], clean.results[sid])


def test_dropout_escalates_to_retry_budget_quarantine():
    """A permanently-dropped session walks the exponential backoff ladder
    (1, 2, 4 pool turns for backoff_base=1) and quarantines when retries
    exceed the budget — on an exactly predictable pool turn."""
    workload = _workload(2, seed=7)
    pool = _run_pool(workload, schedule=ForcedSchedule(dropout={(0, None)}))
    rec = pool.sessions[0]
    budget = pool.cfg.retry_budget
    assert rec["status"] == ST_QUARANTINED
    assert rec["quarantine_reason"] == "retry_budget"
    assert rec["retries"] == budget + 1
    assert rec["backoffs"] == budget
    assert rec["dropouts"] == budget + 1
    assert rec["turns"] == 0 and 0 not in pool.results
    # retries land at t = 0, 2, 5, 10: gaps of 1 + 2^i, quarantined and
    # evicted on the turn the (budget+1)-th retry fires
    assert rec["evicted_turn"] == sum(1 + (1 << i) for i in range(budget))
    # the healthy neighbour is oblivious
    assert pool.sessions[1]["status"] == ST_CONVERGED


def test_drop_msg_retries_once_then_finishes_bitexact():
    workload = _workload(2, seed=8)
    pool = _run_pool(workload, schedule=ForcedSchedule(drop_msg={(0, 1)}))
    clean = _run_pool(workload)
    rec = pool.sessions[0]
    assert rec["status"] == ST_CONVERGED
    assert rec["drop_msgs"] == 1 and rec["dropouts"] == 0
    assert rec["retries"] == 1 and rec["backoffs"] == 1
    assert _res_bitwise(pool.results[0], clean.results[0])
    assert rec["evicted_turn"] > clean.sessions[0]["evicted_turn"]


def test_straggler_delays_without_charging_retries():
    workload = _workload(2, seed=9)
    pool = _run_pool(workload, schedule=ForcedSchedule(straggle={(0, 1): 2}))
    clean = _run_pool(workload)
    rec = pool.sessions[0]
    assert rec["status"] == ST_CONVERGED
    assert rec["straggles"] == 1
    assert rec["retries"] == 0 and rec["backoffs"] == 0
    assert _res_bitwise(pool.results[0], clean.results[0])
    assert rec["evicted_turn"] == clean.sessions[0]["evicted_turn"] + 3


# ---------------------------------------------------------------------------
# checkpoint / restore
# ---------------------------------------------------------------------------


def test_checkpoint_restore_resumes_bitexact(tmp_path):
    """Snapshot a chaotic pool mid-stream (live slots, pending queue,
    partial results); the restored pool and the original must finish with
    identical ledgers, stats and bitwise-identical results."""
    workload = _workload(12, seed=10)
    a = SessionPool(_cfg(), CHAOS)
    for shards in workload:
        a.submit(shards)
    for _ in range(6):
        a.step_pool()
    assert not a.drained()          # the snapshot must be mid-stream
    assert a.pending                # ... with sessions still queued
    a.checkpoint(str(tmp_path))

    b = SessionPool.restore(str(tmp_path))
    assert b.pool_turn == a.pool_turn
    a.run()
    b.run()
    assert a.stats == b.stats
    assert a.sessions == b.sessions
    assert set(a.results) == set(b.results)
    for sid in a.results:
        assert _res_bitwise(a.results[sid], b.results[sid]), sid
        assert a.results[sid].extra == b.results[sid].extra


def test_periodic_checkpoint_from_config(tmp_path):
    cfg = _cfg(checkpoint_every=4, checkpoint_dir=str(tmp_path))
    workload = _workload(5, seed=11)
    a = _run_pool(workload, cfg=cfg)
    assert os.path.exists(tmp_path / "latest.json")
    # the last periodic snapshot mid-run restores and finishes identically
    b = SessionPool.restore(str(tmp_path))
    b.run()
    for sid in a.results:
        assert _res_bitwise(a.results[sid], b.results[sid]), sid


# ---------------------------------------------------------------------------
# steady-state recompiles
# ---------------------------------------------------------------------------


def _pool_cache_entries():
    fns = (median._hot_turn, session_pool._admit_rows,
           session_pool._corrupt_median, session_pool._view_median,
           session_pool._mark_done)
    return sum(f._cache_size() for f in fns)


def test_second_identical_run_compiles_nothing():
    """The admission contract: slots refill at pinned cache keys, so a
    warmed pool re-running the same traffic adds zero jit cache entries
    and dispatches at exactly one compile key."""
    workload = _workload(10, seed=12)
    _run_pool(workload, schedule=CHAOS)       # warm every pinned key
    entries0 = _pool_cache_entries()
    keys0 = len(hotloop.KEY_LOG)
    _run_pool(workload, schedule=CHAOS)
    assert _pool_cache_entries() - entries0 == 0
    assert len(set(hotloop.KEY_LOG[keys0:])) == 1
