"""MLA absorbed-decode path (RunFlags.mla_absorb): the latent-space attention
rewrite must agree with the faithful reconstruct path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

if not hasattr(jax.sharding, "get_abstract_mesh"):
    pytest.skip(
        "model stack requires jax.sharding.get_abstract_mesh (jax >= 0.5.x); "
        "pre-existing version skew on this container's jax, unrelated to the "
        "protocol/engine code (ROADMAP.md)", allow_module_level=True)

import repro.configs as C
from repro.models import model as M
from repro.models.model import RunFlags


def test_mla_absorb_matches_faithful_decode():
    cfg = C.get_config("deepseek-v2-236b").reduced()
    B, S = 2, 12
    params = M.init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0, cfg.vocab)
    batch = {"tokens": toks[:, :S]}
    outs = {}
    for absorb in (False, True):
        flags = RunFlags(mla_absorb=absorb)
        caches = M.make_caches(cfg, B, S + 1, jnp.float32)
        _, caches = M.prefill(params, cfg, batch, caches, RunFlags(), dtype=jnp.float32)
        logits, _ = M.decode_step(params, cfg, caches, toks[:, S:S + 1],
                                  jnp.int32(S), flags, dtype=jnp.float32)
        outs[absorb] = np.asarray(logits[:, 0])
    np.testing.assert_allclose(outs[True], outs[False], rtol=2e-3, atol=2e-3)
