"""Per-kernel allclose sweeps: every Pallas kernel (interpret=True on CPU)
against its ref.py pure-jnp oracle over shapes × dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba import mamba_scan
from repro.kernels.rwkv6 import rwkv6_chunked
from repro.kernels.support_margin import threshold_ranges, uncertain_mask


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,KV,hd", [
    (1, 128, 4, 4, 64),     # MHA
    (2, 256, 8, 2, 64),     # GQA 4:1
    (1, 512, 4, 1, 32),     # MQA
    (2, 128, 6, 3, 128),    # odd head count
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes_dtypes(B, S, H, KV, hd, dtype):
    ks = jax.random.split(jax.random.PRNGKey(B * S + H), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    out = flash_attention(q, k, v, causal=True, block_q=128, block_k=128, interpret=True)
    exp = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **tol(dtype))


@pytest.mark.parametrize("window", [32, 128])
def test_flash_attention_sliding_window(window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 256, 2, 32))
    k = jax.random.normal(ks[1], (1, 256, 2, 32))
    v = jax.random.normal(ks[2], (1, 256, 2, 32))
    out = flash_attention(q, k, v, causal=True, window=window, interpret=True)
    exp = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=2e-4, atol=2e-4)


def test_flash_attention_kv_valid():
    """Decode-style: only the first kv_valid cache slots are real."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 128, 4, 32))
    k = jax.random.normal(ks[1], (2, 256, 4, 32))
    v = jax.random.normal(ks[2], (2, 256, 4, 32))
    out = flash_attention(q, k, v, causal=False, kv_valid=100, interpret=True)
    exp = ref.attention_ref(q, k, v, causal=False, kv_valid=100)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=2e-4, atol=2e-4)


def test_attention_ops_ragged_padding():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 100, 4, 32))
    k = jax.random.normal(ks[1], (1, 100, 2, 32))
    v = jax.random.normal(ks[2], (1, 100, 2, 32))
    out = ops.attention(q, k, v, causal=True, interpret=True)
    exp = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# rwkv6
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,hd,chunk", [
    (1, 64, 2, 32, 16),
    (2, 128, 3, 64, 32),
    (1, 96, 1, 16, 32),     # S not a multiple of chunk -> ops pads
])
def test_rwkv6_chunked_matches_scan(B, S, H, hd, chunk):
    ks = jax.random.split(jax.random.PRNGKey(S + hd), 5)
    r = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, hd))) * 0.98 + 0.01
    u = jax.random.normal(ks[4], (H, hd)) * 0.1
    y, sT = ops.rwkv6(r, k, v, w, u, chunk=chunk, interpret=True)
    ye, sTe = ref.rwkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sTe), rtol=1e-3, atol=1e-3)


def test_rwkv6_decay_extremes():
    """Near-0 and near-1 decays must both stay numerically sane."""
    B, S, H, hd = 1, 64, 1, 16
    ks = jax.random.split(jax.random.PRNGKey(9), 4)
    r = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    u = jnp.zeros((H, hd))
    for wval in (0.02, 0.999):
        w = jnp.full((B, S, H, hd), wval)
        y, _ = rwkv6_chunked(r, k, v, w, u, chunk=16, interpret=True)
        ye, _ = ref.rwkv6_ref(r, k, v, w, u)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ye), rtol=5e-3, atol=5e-3)


# ---------------------------------------------------------------------------
# mamba selective scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,di,ds,chunk,bdi", [
    (1, 64, 32, 16, 16, 32),
    (2, 128, 64, 16, 32, 32),
    (1, 100, 48, 8, 32, 16),   # ragged S and di -> ops pads
])
def test_mamba_scan_matches(B, S, di, ds, chunk, bdi):
    ks = jax.random.split(jax.random.PRNGKey(S * di), 5)
    xc = jax.random.normal(ks[0], (B, S, di))
    delta = jax.nn.softplus(jax.random.normal(ks[1], (B, S, di)) - 2)
    A = -jnp.exp(jax.random.normal(ks[2], (di, ds)) * 0.5)
    Bs = jax.random.normal(ks[3], (B, S, ds))
    Cs = jax.random.normal(ks[4], (B, S, ds))
    y, hT = ops.selective_scan(xc, delta, A, Bs, Cs, chunk=chunk, block_di=bdi,
                               interpret=True)
    ye, hTe = ref.mamba_ref(xc, delta, A, Bs, Cs)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hTe), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# support margin (paper data plane)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,d", [(64, 512, 2), (100, 333, 2), (256, 1024, 10),
                                   (7, 13, 3)])
def test_support_margin_vs_geometry_oracle(m, n, d):
    ks = jax.random.split(jax.random.PRNGKey(m + n), 4)
    V = jax.random.normal(ks[0], (m, d))
    V = V / jnp.linalg.norm(V, axis=1, keepdims=True)
    Xw = jax.random.normal(ks[1], (n, d))
    yw = jnp.where(jax.random.bernoulli(ks[2], 0.5, (n,)), 1, -1)
    X = jax.random.normal(ks[3], (n, d))
    ok = jax.random.bernoulli(ks[2], 0.8, (m,))

    lo, hi = ops.support_ranges(V, Xw, yw, interpret=True)
    loe, hie = ref.threshold_ranges_ref(V, Xw, yw)
    fin = np.isfinite(np.asarray(loe))
    np.testing.assert_allclose(np.asarray(lo)[fin], np.asarray(loe)[fin], rtol=1e-5)
    mask = ops.support_uncertain(V, ok, lo, hi, X, yw, interpret=True)
    maske = ref.uncertain_mask_ref(V, ok, loe, hie, X, yw)
    assert bool(jnp.all(mask == maske))


def test_support_margin_one_class_only():
    """All-positive transcript: hi stays +BIG (no negative constraint)."""
    V = jnp.eye(2)
    Xw = jnp.array([[1.0, 0.0], [2.0, 0.0]])
    yw = jnp.array([1, 1])
    lo, hi = ops.support_ranges(V, Xw, yw, interpret=True)
    assert float(lo[0]) == pytest.approx(2.0)
    assert float(hi[0]) >= 1e29


@pytest.mark.parametrize("B,m,n,d", [(1, 64, 256, 2), (4, 100, 333, 2),
                                     (8, 256, 512, 2), (3, 7, 13, 3)])
def test_support_margin_batched_vs_refs(B, m, n, d):
    """Batch-grid kernels against the jitted vmap oracles, including label-0
    padding rows (the ragged-shard convention)."""
    ks = jax.random.split(jax.random.PRNGKey(B * m + n), 4)
    V = jax.random.normal(ks[0], (m, d))
    V = V / jnp.linalg.norm(V, axis=1, keepdims=True)
    Xw = jax.random.normal(ks[1], (B, n, d))
    yw = jnp.where(jax.random.bernoulli(ks[2], 0.5, (B, n)), 1, -1)
    yw = yw * jax.random.bernoulli(ks[3], 0.8, (B, n))   # some label-0 pads
    X = jax.random.normal(ks[3], (B, n, d))
    ok = jax.random.bernoulli(ks[2], 0.8, (B, m))

    lo, hi = ops.support_ranges_batch(V, Xw, yw, interpret=True)
    loe, hie = ref.threshold_ranges_batch_ref(V, Xw, yw)
    fin = np.isfinite(np.asarray(loe))
    np.testing.assert_allclose(np.asarray(lo)[fin], np.asarray(loe)[fin],
                               rtol=1e-5)
    fin = np.isfinite(np.asarray(hie))
    np.testing.assert_allclose(np.asarray(hi)[fin], np.asarray(hie)[fin],
                               rtol=1e-5)
    mask = ops.support_uncertain_batch(V, ok, lo, hi, X, yw, interpret=True)
    maske = ref.uncertain_mask_batch_ref(V, ok, loe, hie, X, yw)
    assert bool(jnp.all(mask == maske))


def test_support_margin_batched_matches_per_instance():
    """Each batch slice must equal the single-instance kernel's output."""
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    B, m, n = 5, 64, 128
    V = jax.random.normal(ks[0], (m, 2))
    Xw = jax.random.normal(ks[1], (B, n, 2))
    yw = jnp.where(jax.random.bernoulli(ks[2], 0.5, (B, n)), 1, -1)
    lo_b, hi_b = ops.support_ranges_batch(V, Xw, yw, interpret=True)
    for b in range(B):
        lo1, hi1 = ops.support_ranges(V, Xw[b], yw[b], interpret=True)
        np.testing.assert_array_equal(np.asarray(lo_b[b]), np.asarray(lo1))
        np.testing.assert_array_equal(np.asarray(hi_b[b]), np.asarray(hi1))


@pytest.mark.parametrize("B,m,n,d", [(1, 64, 256, 2), (4, 100, 333, 2),
                                     (8, 512, 200, 2), (3, 7, 13, 3)])
def test_median_cut_batched_bit_for_bit(B, m, n, d):
    """The (B, m, n) median-cut scan kernel must match the vmap reference
    *bit-for-bit* — the scores are integer counts, so there is no tolerance
    to hide behind.  Includes label-0 padding rows, disallowed directions,
    and ±inf range sentinels (missing-class transcripts)."""
    ks = jax.random.split(jax.random.PRNGKey(B * m + n), 6)
    V = jax.random.normal(ks[0], (m, d))
    V = V / jnp.linalg.norm(V, axis=1, keepdims=True)
    X = jax.random.normal(ks[1], (B, n, d))
    y = jnp.where(jax.random.bernoulli(ks[2], 0.5, (B, n)), 1, -1)
    y = y * jax.random.bernoulli(ks[3], 0.8, (B, n))     # some label-0 pads
    ok = jax.random.bernoulli(ks[4], 0.7, (B, m))
    lo = jnp.where(jax.random.bernoulli(ks[5], 0.8, (B, m)),
                   jax.random.normal(ks[5], (B, m)), -jnp.inf)
    hi = jnp.where(jax.random.bernoulli(ks[4], 0.8, (B, m)),
                   lo + jax.random.uniform(ks[1], (B, m)), jnp.inf)

    got = ops.support_median_cut_batch(V, ok.astype(jnp.float32), lo, hi,
                                       X, y, interpret=True)
    want = ref.median_cut_scores_batch_ref(V, ok, lo, hi, X, y)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert got.dtype == jnp.int32


def test_median_cut_on_engine_grid_bit_for_bit():
    """Kernel vs reference on *real* engine sweep state (the test grid the
    acceptance bar names): mid-protocol dir_ok / incremental ranges /
    padded shards, every turn of a short sweep."""
    from repro import engine
    from repro.core import datasets, geometry as geo
    from repro.engine import median as M

    insts = [engine.ProtocolInstance(
                 datasets.data3(n_per_node=100, k=2, seed=s), 0.05)
             for s in range(4)]
    data, state, k, _ = engine.pack_instances(insts, n_angles=256,
                                              max_epochs=16)
    V = jnp.asarray(geo.direction_grid(256), jnp.float32)
    state = M.step(data, V, state, k=k, first_turn=True)
    for _ in range(5):
        # lock-step sweep: every per-instance turn is identical
        ci = int(np.asarray(state.turn)[0]) % k
        lo = jnp.take(state.lo_w, ci, axis=1)
        hi = jnp.take(state.hi_w, ci, axis=1)
        Xc = jnp.take(data.X, ci, axis=1)
        yc = jnp.take(data.y, ci, axis=1)
        got = ops.support_median_cut_batch(
            V, state.dir_ok.astype(jnp.float32), lo, hi, Xc, yc,
            interpret=True)
        want = ref.median_cut_scores_batch_ref(V, state.dir_ok, lo, hi,
                                               Xc, yc)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        state = M.step(data, V, state, k=k)


@pytest.mark.parametrize("B,N,k,n,d", [(1, 64, 2, 48, 2), (5, 33, 3, 21, 2),
                                       (4, 100, 2, 80, 5), (3, 24, 4, 16, 10)])
def test_maxmarg_turn_scan_bit_for_bit(B, N, k, n, d):
    """The fused support/violation kernel must match the vmap reference
    *bit-for-bit* (capped integer ranks + error counts), including label-0
    padding rows and one-class fit sets."""
    ks = jax.random.split(jax.random.PRNGKey(B * N + n), 8)
    K = jax.random.normal(ks[0], (B, N, d))
    yK = jnp.where(jax.random.bernoulli(ks[1], 0.5, (B, N)), 1, -1)
    yK = yK * jax.random.bernoulli(ks[2], 0.8, (B, N))   # label-0 pads
    X = jax.random.normal(ks[3], (B, k, n, d))
    y = jnp.where(jax.random.bernoulli(ks[4], 0.5, (B, k, n)), 1, -1)
    y = y * jax.random.bernoulli(ks[5], 0.8, (B, k, n))
    w = jax.random.normal(ks[6], (B, d))
    b = jax.random.normal(ks[7], (B,))

    got = ops.support_violation_batch(w, b, K, yK, X, y, interpret=True)
    want = ref.maxmarg_turn_batch_ref(w, b, K, yK, X, y)
    for g, e in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(e))
        assert g.dtype == jnp.int32


def test_maxmarg_turn_scan_on_engine_grid_bit_for_bit():
    """Kernel vs reference on *real* MAXMARG engine state: mid-protocol
    transcripts, live separators, padded shards — every turn of a short
    sweep (the kernel-vs-chain equivalence the engine's ``fused_kernel``
    switch relies on)."""
    from repro import engine
    from repro.core import datasets
    from repro.core.classifiers import _svm_solve_batch
    from repro.engine import maxmarg as MM

    insts = [engine.ProtocolInstance(
                 datasets.data3(n_per_node=60, k=2, seed=s), 0.02, "maxmarg")
             for s in range(4)]
    data, state, k, _ = engine.pack_instances_maxmarg(insts, max_epochs=8,
                                                      max_support=4)
    for _ in range(3):
        # lock-step sweep: every per-instance turn is identical
        ci = int(np.asarray(state.turn)[0]) % k
        Xc = jnp.take(data.X, ci, axis=1)
        yc = jnp.take(data.y, ci, axis=1)
        Wxc = jnp.take(state.wx, ci, axis=1)
        Wyc = jnp.take(state.wy, ci, axis=1)
        K = jnp.concatenate([Xc, Wxc], axis=1)
        yK = jnp.concatenate([yc, Wyc], axis=1)
        w, b, _ = _svm_solve_batch(K, yK.astype(K.dtype),
                                   jnp.float32(1e-3), 500, 2)
        got = ops.support_violation_batch(w, b, K, yK, data.X, data.y,
                                          interpret=True)
        want = ref.maxmarg_turn_batch_ref(w, b, K, yK, data.X, data.y)
        for g, e in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(e))
        state = MM._step_jit(data, state, k=k, max_support=4, steps=500,
                             stages=2, lam0=1e-3, trans_width=None,
                             warm=False, fused_kernel=False)
        if bool(jnp.all(state.done)):
            break


def test_geometry_consistency_with_kernel():
    """geometry.consistent_threshold_ranges (XLA path) == Pallas path."""
    from repro.core import geometry as geo
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    V = np.asarray(geo.direction_grid(128))
    Xw = jax.random.normal(ks[0], (50, 2))
    yw = jnp.where(jax.random.bernoulli(ks[1], 0.5, (50,)), 1, -1)
    lo_g, hi_g = geo.consistent_threshold_ranges(jnp.asarray(V), Xw, yw)
    lo_k, hi_k = ops.support_ranges(jnp.asarray(V), Xw, yw, interpret=True)
    fin = np.isfinite(np.asarray(lo_g))
    np.testing.assert_allclose(np.asarray(lo_k)[fin], np.asarray(lo_g)[fin], rtol=1e-5)
    fin = np.isfinite(np.asarray(hi_g))
    np.testing.assert_allclose(np.asarray(hi_k)[fin], np.asarray(hi_g)[fin], rtol=1e-5)
