"""Donation sanity gate (ISSUE 6 satellite).

Buffer donation lets the per-turn dispatch's scatter-back reuse the input
state's memory in place — but a donated handle is *invalidated*: touching it
afterwards raises.  The hot loop's contract (``hotloop.run_hot``) is a
strict single-consumer chain — each state handle feeds exactly one
dispatch, and the packed host view of a handle is enqueued before the
dispatch that donates it.  This module pins

* jax really does invalidate donated buffers on this backend (so the
  contract is load-bearing, not vacuous),
* a donated sweep runs end-to-end without a use-after-donate — with and
  without the double-buffered loop — and matches the non-donating default
  bit-for-bit (MEDIAN) / decision-for-decision (MAXMARG),
* the cold padded oracle is untouched by donated runs sharing the process.
"""

import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import jax.numpy as jnp

from repro import engine
from repro.core import datasets, geometry as geo
from repro.engine import median, maxmarg

N_ANGLES = 256
MAX_EPOCHS = 24
_GENS = (datasets.data1, datasets.data2, datasets.data3)


def _grid(n, selector="median"):
    return [engine.ProtocolInstance(
        _GENS[i % 3](n_per_node=40, k=2, seed=i),
        (0.1, 0.05)[i % 2], selector) for i in range(n)]


def test_use_after_donate_raises():
    """A donated dispatch must invalidate its input state: reading any leaf
    of the consumed handle afterwards raises instead of silently aliasing.
    (If donation were silently ignored — e.g. numpy inputs — the in-place
    scatter-back would be a no-op copy and the perf win fictitious.)"""
    insts = _grid(4)
    data, st, k, cap = engine.pack_instances(
        insts, n_angles=N_ANGLES, max_epochs=MAX_EPOCHS)
    V = jnp.asarray(geo.direction_grid(N_ANGLES), jnp.float32)
    out = median._step_jit_don(data, V, st, k=k, first_turn=True,
                               cut_kernel=False, extremes_kernel=False,
                               trans_width=8)
    jax.block_until_ready(out.wx)
    with pytest.raises((RuntimeError, ValueError)):
        np.asarray(st.wx)
    # the non-donating twin leaves its input untouched (fresh pack — the
    # first handle is dead)
    _, st2, _, _ = engine.pack_instances(
        insts, n_angles=N_ANGLES, max_epochs=MAX_EPOCHS)
    median._step_jit(data, V, st2, k=k, first_turn=True,
                     cut_kernel=False, extremes_kernel=False, trans_width=8)
    np.asarray(st2.wx)


def test_median_donated_sweep_bitexact():
    """donate=True (with and without the double-buffered loop) must complete
    without a use-after-donate and reproduce the default hot path exactly —
    the pin that the loop's single-consumer chain really holds."""
    insts = _grid(10)
    ref = engine.run_instances(insts, n_angles=N_ANGLES,
                               max_epochs=MAX_EPOCHS)
    for overlap in (False, True):
        don = engine.run_instances(insts, n_angles=N_ANGLES,
                                   max_epochs=MAX_EPOCHS,
                                   donate=True, overlap=overlap)
        for i, (a, b) in enumerate(zip(don, ref)):
            assert a.comm == b.comm, (overlap, i)
            assert a.rounds == b.rounds and a.converged == b.converged
            np.testing.assert_array_equal(a.classifier.w, b.classifier.w)
            assert a.classifier.b == b.classifier.b


def test_maxmarg_donated_sweep_decision_exact():
    insts = _grid(10, selector="maxmarg")
    ref = engine.maxmarg.run_instances(insts, max_epochs=MAX_EPOCHS)
    for overlap in (False, True):
        don = engine.maxmarg.run_instances(insts, max_epochs=MAX_EPOCHS,
                                           donate=True, overlap=overlap)
        for i, (inst, a, b) in enumerate(zip(insts, don, ref)):
            assert a.comm == b.comm, (overlap, i)
            assert a.rounds == b.rounds and a.converged == b.converged
            X = np.concatenate([s[0] for s in inst.shards])
            np.testing.assert_array_equal(a.classifier.predict(X),
                                          b.classifier.predict(X))


def test_cold_oracle_unaffected_by_donated_runs():
    """The cold padded while_loop path never donates; interleaving it with
    donated sweeps in one process must leave it bit-exact vs the hot path
    (the PR 4/5 differential standard)."""
    insts = _grid(6)
    engine.run_instances(insts, n_angles=N_ANGLES, max_epochs=MAX_EPOCHS,
                         donate=True, overlap=True)
    cold = engine.run_instances(insts, n_angles=N_ANGLES,
                                max_epochs=MAX_EPOCHS, compact=False)
    hot = engine.run_instances(insts, n_angles=N_ANGLES,
                               max_epochs=MAX_EPOCHS)
    for a, b in zip(hot, cold):
        assert a.comm == b.comm and a.rounds == b.rounds
        np.testing.assert_array_equal(a.classifier.w, b.classifier.w)
        assert a.classifier.b == b.classifier.b
