"""Batched annealed Pegasos solver: batched-vs-sequential parity, padding
invariance, and the warm-start margin regression bar."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import classifiers as clf


def _separable(n, d, seed, gap=0.3):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=d)
    w /= np.linalg.norm(w)
    X = rng.normal(size=(n, d))
    X = X[np.abs(X @ w) > gap]
    y = np.where(X @ w > 0, 1, -1)
    return X, y


def _solve_batch(Xs, ys, n_pad=0):
    """Stack instances (padding with label-0 rows to a common size, plus
    n_pad extra rows) and run the batched solver."""
    d = Xs[0].shape[1]
    N = max(x.shape[0] for x in Xs) + n_pad
    B = len(Xs)
    Xb = np.zeros((B, N, d), np.float32)
    yb = np.zeros((B, N), np.float32)
    for i, (X, y) in enumerate(zip(Xs, ys)):
        Xb[i, :X.shape[0]] = X
        yb[i, :X.shape[0]] = y
    return clf._svm_solve_batch(jnp.asarray(Xb), jnp.asarray(yb),
                                jnp.float32(1e-3), 2000, 3)


def test_batch_of_one_matches_single_instance_entry():
    X, y = _separable(150, 2, seed=0)
    w1, b1, ok1 = clf.anneal_hard_margin(X, y)
    wb, bb, okb = _solve_batch([X], [y])
    assert ok1 and bool(okb[0])
    np.testing.assert_allclose(w1, np.asarray(wb[0], np.float64), rtol=1e-6)
    assert b1 == pytest.approx(float(bb[0]), rel=1e-6)


@pytest.mark.parametrize("d", [2, 5])
def test_b8_matches_b1_per_instance(d):
    """Every instance of a B=8 batch must solve to (numerically) the same
    separator as its own B=1 run; all must reach 0 training error."""
    Xs, ys = zip(*[_separable(120 + 10 * i, d, seed=i) for i in range(8)])
    wb, bb, okb = _solve_batch(list(Xs), list(ys))
    assert bool(jnp.all(okb))
    for i, (X, y) in enumerate(zip(Xs, ys)):
        w1, b1, ok1 = _solve_batch([X], [y])
        assert bool(ok1[0])
        np.testing.assert_allclose(np.asarray(wb[i]), np.asarray(w1[0]),
                                   rtol=1e-4, atol=1e-5)
        # decisions, not just parameters: same margins ordering
        m_b = y * (X @ np.asarray(wb[i], np.float64) + float(bb[i]))
        m_1 = y * (X @ np.asarray(w1[0], np.float64) + float(b1[0]))
        assert m_b.min() > 0 and m_1.min() > 0
        np.testing.assert_allclose(m_b, m_1, rtol=1e-3, atol=1e-4)


def test_padding_rows_are_inert():
    """Label-0 rows must not change the fit beyond float reassociation:
    same data padded with 0 vs 64 extra zero rows."""
    X, y = _separable(130, 2, seed=3)
    w0, b0, _ = _solve_batch([X], [y], n_pad=0)
    w1, b1, _ = _solve_batch([X], [y], n_pad=64)
    np.testing.assert_allclose(np.asarray(w0), np.asarray(w1),
                               rtol=1e-4, atol=1e-6)
    assert float(b0[0]) == pytest.approx(float(b1[0]), rel=1e-4, abs=1e-6)


def test_first_success_stage_latched():
    """Instances that separate at stage 0 must not drift when later stages
    keep annealing for the hard instances sharing the batch: the B=2 batch
    (easy, hard) must give the easy instance the same result as alone."""
    Xe, ye = _separable(100, 2, seed=5, gap=0.8)   # wide gap: stage-0 win
    Xh, yh = _separable(400, 2, seed=6, gap=0.02)  # needs smaller lambda
    w_pair, b_pair, ok_pair = _solve_batch([Xe, Xh], [ye, yh])
    w_alone, b_alone, _ = _solve_batch([Xe], [ye])
    assert bool(jnp.all(ok_pair))
    np.testing.assert_allclose(np.asarray(w_pair[0]), np.asarray(w_alone[0]),
                               rtol=1e-4, atol=1e-6)


def test_warm_start_margin_regression():
    """The warm-started λ schedule must keep margin quality: on a
    known-geometry instance (two unit-separated slabs, optimal geometric
    margin 0.5) the fitted margin stays within 10% of optimal at the
    *default* (halved) step budget."""
    rng = np.random.default_rng(9)
    n = 200
    Xp = np.stack([-0.5 - rng.random(n), rng.normal(0, 2.0, n)], axis=1)
    Xn = np.stack([+0.5 + rng.random(n), rng.normal(0, 2.0, n)], axis=1)
    X = np.concatenate([Xp, Xn])
    y = np.concatenate([np.ones(n, np.int32), -np.ones(n, np.int32)])
    h = clf.fit_max_margin(X, y)
    assert h.error(X, y) == 0.0
    assert h.margin >= 0.9 * 0.5, h.margin
    # canonical form survives the device-side canonicalization
    m = y * (X @ h.w + h.b)
    assert m.min() == pytest.approx(1.0, rel=1e-3)


def _solve_batch_k(Xs, ys, kernel, n_pad=0, **kw):
    d = Xs[0].shape[1]
    N = max(x.shape[0] for x in Xs) + n_pad
    B = len(Xs)
    Xb = np.zeros((B, N, d), np.float32)
    yb = np.zeros((B, N), np.float32)
    for i, (X, y) in enumerate(zip(Xs, ys)):
        Xb[i, :X.shape[0]] = X
        yb[i, :X.shape[0]] = y
    return clf._svm_solve_batch(jnp.asarray(Xb), jnp.asarray(yb),
                                jnp.float32(1e-3), 2000, 3, **kw,
                                kernel=kernel)


@pytest.mark.parametrize("d", [2, 16])
def test_kernel_path_decision_parity(d):
    """kernel=True (the tiled-solver dispatch; jnp twin on CPU) and
    kernel=False (the classic loop) are two float approximations of the
    same transcript-determined optimum: identical convergence bits and
    identical sign decisions on every fit row."""
    Xs, ys = zip(*[_separable(120 + 10 * i, d, seed=i) for i in range(6)])
    wc, bc, okc = _solve_batch_k(list(Xs), list(ys), kernel=False)
    wk, bk, okk = _solve_batch_k(list(Xs), list(ys), kernel=True)
    np.testing.assert_array_equal(np.asarray(okc), np.asarray(okk))
    assert bool(jnp.all(okc))
    for i, (X, y) in enumerate(zip(Xs, ys)):
        mc = y * (X @ np.asarray(wc[i], np.float64) + float(bc[i]))
        mk = y * (X @ np.asarray(wk[i], np.float64) + float(bk[i]))
        assert mc.min() > 0 and mk.min() > 0  # both separate => same signs


def test_kernel_path_padding_rows_are_inert():
    """Extra label-0 rows must not change the kernel path's result at all
    beyond float reassociation: same convergence, near-identical
    separator (the masked-pad contract compacted fills rely on)."""
    X, y = _separable(140, 16, seed=3)
    w0, b0, ok0 = _solve_batch_k([X], [y], kernel=True)
    w1, b1, ok1 = _solve_batch_k([X], [y], kernel=True, n_pad=37)
    assert bool(ok0[0]) and bool(ok1[0])
    np.testing.assert_allclose(np.asarray(w0[0]), np.asarray(w1[0]),
                               rtol=1e-4, atol=1e-5)
    assert float(b0[0]) == pytest.approx(float(b1[0]), rel=1e-4, abs=1e-5)


def test_kernel_path_warm_gate_matches_classic():
    """The warm polish gate (return_gate bits) is computed from the same
    carried-separator margin scan on both paths, so the gate itself must
    be bit-identical; and a cold kernel=True entry must equal a warm
    kernel=True entry whose warm_ok is all-False, mirroring the classic
    path's warm/cold bit-exactness property."""
    Xs, ys = zip(*[_separable(120, 8, seed=i) for i in range(4)])
    B = len(Xs)
    wc, bc, okc, gc = _solve_batch_k(
        list(Xs), list(ys), kernel=False, return_gate=True,
        w0=jnp.zeros((B, 8), jnp.float32), b0=jnp.zeros((B,), jnp.float32),
        warm_ok=jnp.ones((B,), bool))
    wk, bk, okk, gk = _solve_batch_k(
        list(Xs), list(ys), kernel=True, return_gate=True,
        w0=jnp.zeros((B, 8), jnp.float32), b0=jnp.zeros((B,), jnp.float32),
        warm_ok=jnp.ones((B,), bool))
    np.testing.assert_array_equal(np.asarray(gc), np.asarray(gk))
    # cold == warm-with-all-False-gate, per path
    w_cold, b_cold, ok_cold = _solve_batch_k(list(Xs), list(ys), kernel=True)
    w_gate, b_gate, ok_gate, gate = _solve_batch_k(
        list(Xs), list(ys), kernel=True, return_gate=True,
        w0=jnp.ones((B, 8), jnp.float32), b0=jnp.zeros((B,), jnp.float32),
        warm_ok=jnp.zeros((B,), bool))
    assert not bool(jnp.any(gate))
    np.testing.assert_array_equal(np.asarray(w_cold), np.asarray(w_gate))
    np.testing.assert_array_equal(np.asarray(b_cold), np.asarray(b_gate))
    np.testing.assert_array_equal(np.asarray(ok_cold), np.asarray(ok_gate))
