"""Fault-schedule determinism gates (ISSUE 7 satellite).

The session pool's whole robustness story rests on ``engine/faults.py``
being a *pure hash*: same (seed, session id, pool turn) ⇒ same draw, no
RNG state to checkpoint, and a retried turn keyed on the *pool* turn faces
a fresh draw (no deterministic retry livelock).  This module pins those
properties directly on ``FaultSchedule.draws`` — the pool-level
consequences (identical eviction sets, bit-exact survivors, restore
replay) live in tests/test_session_pool.py.
"""

import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.engine import faults as F
from repro.engine.faults import FAULT_FREE, FaultSchedule

CHANNELS = ("dropout", "drop_msg", "straggle", "corrupt")
SIDS = np.arange(64, dtype=np.int64)


def _all_draws(sched, sids=SIDS, turns=32):
    return [sched.draws(sids, t) for t in range(turns)]


def test_draws_deterministic_across_instances():
    """Two separately-constructed equal schedules agree draw-for-draw —
    there is no hidden state, so nothing needs checkpointing."""
    a = FaultSchedule(seed=7, p_dropout=0.3, p_drop_msg=0.2,
                      p_straggle=0.3, p_corrupt=0.2)
    b = FaultSchedule(seed=7, p_dropout=0.3, p_drop_msg=0.2,
                      p_straggle=0.3, p_corrupt=0.2)
    for da, db in zip(_all_draws(a), _all_draws(b)):
        for ch in CHANNELS:
            np.testing.assert_array_equal(da[ch], db[ch])


def test_draws_order_independent():
    """A draw depends only on (seed, sid, turn) — not on which other
    sessions share the dispatch (batch composition must not leak)."""
    s = FaultSchedule(seed=3, p_dropout=0.4, p_corrupt=0.4)
    whole = s.draws(SIDS, 5)
    perm = np.random.default_rng(0).permutation(SIDS.size)
    shuffled = s.draws(SIDS[perm], 5)
    for ch in CHANNELS:
        np.testing.assert_array_equal(whole[ch][perm], shuffled[ch])
    solo = s.draws(SIDS[3:4], 5)
    for ch in CHANNELS:
        assert solo[ch][0] == whole[ch][3]


def test_seed_moves_every_channel():
    a = FaultSchedule(seed=0, p_dropout=0.5, p_drop_msg=0.5,
                      p_straggle=0.5, p_corrupt=0.5)
    b = FaultSchedule(seed=1, p_dropout=0.5, p_drop_msg=0.5,
                      p_straggle=0.5, p_corrupt=0.5)
    for ch in CHANNELS:
        assert any(
            not np.array_equal(da[ch], db[ch])
            for da, db in zip(_all_draws(a), _all_draws(b))), ch


def test_channels_use_distinct_salts():
    """At equal probabilities the channels must not fire in lockstep —
    each has its own salt."""
    s = FaultSchedule(seed=9, p_dropout=0.5, p_drop_msg=0.5,
                      p_straggle=0.5, p_corrupt=0.5)
    d = s.draws(np.arange(512), 0)
    assert not np.array_equal(d["dropout"], d["drop_msg"])
    assert not np.array_equal(d["dropout"], d["straggle"] > 0)
    assert not np.array_equal(d["dropout"], d["corrupt"] >= 0)


def test_fault_free_is_inert():
    assert not FAULT_FREE.any_faults
    for d in _all_draws(FAULT_FREE, turns=8):
        assert not d["dropout"].any()
        assert not d["drop_msg"].any()
        assert (d["straggle"] == 0).all()
        assert (d["corrupt"] == -1).all()


def test_probability_one_and_value_ranges():
    s = FaultSchedule(seed=2, p_dropout=1.0, p_drop_msg=1.0,
                      p_straggle=1.0, p_corrupt=1.0, straggle_max=4)
    for d in _all_draws(s, turns=8):
        assert d["dropout"].all() and d["drop_msg"].all()
        assert ((d["straggle"] >= 1) & (d["straggle"] <= 4)).all()
        assert np.isin(d["corrupt"],
                       np.arange(F.N_CORRUPT_KINDS)).all()
    # every corruption kind is reachable
    kinds = np.concatenate([d["corrupt"] for d in _all_draws(s, turns=8)])
    assert set(np.unique(kinds)) == set(range(F.N_CORRUPT_KINDS))


def test_empirical_rates_track_probabilities():
    s = FaultSchedule(seed=5, p_dropout=0.2, p_drop_msg=0.1,
                      p_straggle=0.3, p_corrupt=0.15)
    n = 0
    hits = dict.fromkeys(CHANNELS, 0)
    for d in _all_draws(s, sids=np.arange(256), turns=40):
        n += 256
        hits["dropout"] += int(d["dropout"].sum())
        hits["drop_msg"] += int(d["drop_msg"].sum())
        hits["straggle"] += int((d["straggle"] > 0).sum())
        hits["corrupt"] += int((d["corrupt"] >= 0).sum())
    for ch, p in (("dropout", 0.2), ("drop_msg", 0.1),
                  ("straggle", 0.3), ("corrupt", 0.15)):
        assert abs(hits[ch] / n - p) < 0.02, (ch, hits[ch] / n)


def test_retry_faces_fresh_draw():
    """Keying on the pool turn means a session hit at turn t is NOT
    deterministically hit at t+1 — the retry livelock guard."""
    s = FaultSchedule(seed=0, p_dropout=0.5)
    hit = np.stack([s.draws(SIDS, t)["dropout"] for t in range(16)])
    # some session recovers right after a hit, and none is hit forever
    assert (hit[:-1] & ~hit[1:]).any()
    assert not hit.all(axis=0).any()


def test_json_roundtrip():
    s = FaultSchedule(seed=13, p_dropout=0.05, p_drop_msg=0.03,
                      p_straggle=0.06, p_corrupt=0.01, straggle_max=5)
    assert FaultSchedule.from_json(s.to_json()) == s
    d0 = s.draws(SIDS, 3)
    d1 = FaultSchedule.from_json(s.to_json()).draws(SIDS, 3)
    for ch in CHANNELS:
        np.testing.assert_array_equal(d0[ch], d1[ch])


@pytest.mark.parametrize("bad", [
    dict(p_dropout=-0.1), dict(p_drop_msg=1.5),
    dict(p_straggle=2.0), dict(p_corrupt=-1e-9),
    dict(straggle_max=0),
])
def test_validation_rejects_bad_config(bad):
    with pytest.raises(ValueError):
        FaultSchedule(seed=0, **bad)
