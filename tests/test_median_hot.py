"""Differential gate for the MEDIAN hot path (fill-capped transcript reads +
batch compaction on the shared ``engine.hotloop``) against the cold padded
execution model.

Unlike MAXMARG's warm/compacted solver path, the MEDIAN compactions are
**bit-exact**, not merely decision-exact: the capped reads drop only label-0
rows (mask identities of the band/extremes max-min reductions) and every
remaining op is per-row, so hot and cold must agree float-for-float — this
module pins comm totals, rounds, convergence AND the exact final separator
across the engine test grid, the k-party case, and a staggered-convergence
batch that exercises the gather/scatter (``n_act < B``) dispatch path.
"""

import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro import engine
from repro.core import datasets

N_ANGLES = 512
MAX_EPOCHS = 24


def _grid():
    """The engine MEDIAN test grid (same shape as tests/test_engine.py)."""
    out = []
    for gen in (datasets.data1, datasets.data2, datasets.data3):
        for eps in (0.1, 0.05):
            for seed in (0, 1):
                out.append(engine.ProtocolInstance(
                    gen(n_per_node=100, k=2, seed=seed), eps))
    return out


@pytest.fixture(scope="module")
def hot_cold_runs():
    insts = _grid()
    hot = engine.run_instances(insts, n_angles=N_ANGLES,
                               max_epochs=MAX_EPOCHS)          # the default
    cold = engine.run_instances(insts, n_angles=N_ANGLES,
                                max_epochs=MAX_EPOCHS, compact=False)
    return insts, hot, cold


def test_hot_cold_identical_comm_rounds_convergence(hot_cold_runs):
    insts, hot, cold = hot_cold_runs
    assert len(insts) >= 12
    for i, (rh, rc) in enumerate(zip(hot, cold)):
        assert rh.comm == rc.comm, (i, rh.comm, rc.comm)
        assert rh.rounds == rc.rounds, i
        assert rh.converged == rc.converged and rh.converged, i


def test_hot_cold_same_separator_bit_for_bit(hot_cold_runs):
    """The capped reads only drop label-0 rows, so the hot path must emit
    the *identical* separator, not merely an equivalent one."""
    insts, hot, cold = hot_cold_runs
    for inst, rh, rc in zip(insts, hot, cold):
        np.testing.assert_array_equal(rh.classifier.w, rc.classifier.w)
        assert rh.classifier.b == rc.classifier.b
        X = np.concatenate([s[0] for s in inst.shards])
        np.testing.assert_array_equal(rh.classifier.predict(X),
                                      rc.classifier.predict(X))


def test_hot_cold_parity_kparty():
    """k=4 multi-party: stage-5 reads every node's transcript, so the width
    compaction keys on the max fill across nodes — this pins it."""
    for seed, eps in ((0, 0.1), (1, 0.05)):
        shards = datasets.data3(n_per_node=75, k=4, seed=seed)
        inst = [engine.ProtocolInstance(shards, eps)]
        rh = engine.run_instances(inst, n_angles=N_ANGLES,
                                  max_epochs=MAX_EPOCHS)[0]
        rc = engine.run_instances(inst, n_angles=N_ANGLES,
                                  max_epochs=MAX_EPOCHS, compact=False)[0]
        assert rh.comm == rc.comm
        assert rh.rounds == rc.rounds and rh.converged == rc.converged
        np.testing.assert_array_equal(rh.classifier.w, rc.classifier.w)


def test_staggered_convergence_exercises_batch_compaction():
    """A batch whose instances converge at different turns forces the
    gather/scatter (n_act < B) dispatches — the dropped instances' results
    must be untouched and the survivors' identical to the cold run."""
    insts = [engine.ProtocolInstance(
                 datasets.data1(n_per_node=60, k=2, seed=0), 0.3),
             engine.ProtocolInstance(
                 datasets.data2(n_per_node=80, k=2, seed=1), 0.02),
             engine.ProtocolInstance(
                 datasets.data3(n_per_node=100, k=2, seed=2), 0.02),
             engine.ProtocolInstance(
                 datasets.data1(n_per_node=50, k=2, seed=3), 0.3),
             engine.ProtocolInstance(
                 datasets.data3(n_per_node=70, k=2, seed=4), 0.05)]
    hot = engine.run_instances(insts, n_angles=N_ANGLES,
                               max_epochs=MAX_EPOCHS)
    cold = engine.run_instances(insts, n_angles=N_ANGLES,
                                max_epochs=MAX_EPOCHS, compact=False)
    for rh, rc in zip(hot, cold):
        assert rh.comm == rc.comm
        assert rh.rounds == rc.rounds and rh.converged == rc.converged
        np.testing.assert_array_equal(rh.classifier.w, rc.classifier.w)
        assert rh.classifier.b == rc.classifier.b


def test_hot_path_is_default_and_flagged():
    shards = datasets.data1(n_per_node=60, k=2, seed=5)
    r = engine.run_instances([engine.ProtocolInstance(shards, 0.05)],
                             n_angles=N_ANGLES, max_epochs=MAX_EPOCHS)[0]
    assert r.extra["compact"] and r.extra["selector"] == "median"
    r_cold = engine.run_instances([engine.ProtocolInstance(shards, 0.05)],
                                  n_angles=N_ANGLES, max_epochs=MAX_EPOCHS,
                                  compact=False)[0]
    assert not r_cold.extra["compact"]
    assert r.comm == r_cold.comm


def test_run_sweep_accepts_compact_option():
    shards = datasets.data1(n_per_node=60, k=2, seed=6)
    insts = [engine.ProtocolInstance(shards, 0.05)]
    r_hot = engine.run_sweep(insts, n_angles=N_ANGLES,
                             max_epochs=MAX_EPOCHS, compact=True)[0]
    r_cold = engine.run_sweep(insts, n_angles=N_ANGLES,
                              max_epochs=MAX_EPOCHS, compact=False)[0]
    assert r_hot.comm == r_cold.comm
