"""Documentation-layer gates (ISSUE 10 satellites).

* the doc-drift checker (`repro.analysis.doccheck`) passes on the
  committed docs — ARCHITECTURE.md module links resolve to real files and
  DESIGN.md anchors (the CI `lint` job runs the same check dep-free);
* the checker itself catches drift (broken link / stale anchor /
  dangling path fixtures fail);
* the required documentation surface exists: docs/ARCHITECTURE.md with a
  README pointer, and every public engine entry point documents its
  compile-key contract.
"""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

from repro.analysis import doccheck


def _check(relpath):
    return doccheck.check_file(os.path.join(ROOT, relpath), root=ROOT)


def test_committed_docs_have_no_drift():
    for doc in ("docs/ARCHITECTURE.md", "DESIGN.md", "README.md"):
        assert _check(doc) == [], doc


def test_checker_catches_broken_links(tmp_path):
    bad = tmp_path / "bad.md"
    bad.write_text(
        "see [gone](no/such/file.py) and "
        "[anchor](../DESIGN.md#no-such-heading) and `src/repro/ghost.py`\n")
    # resolve DESIGN.md relative to the temp doc's parent
    (tmp_path.parent / "DESIGN.md").write_text("# Real heading\n")
    problems = doccheck.check_file(str(bad), root=ROOT)
    msgs = "\n".join(m for _, m in problems)
    assert "broken link target" in msgs
    assert "broken anchor" in msgs
    assert "dangling path" in msgs


def test_checker_slugs_match_github_style():
    assert doccheck.slugify(
        "Session pool & failure model (streaming service, PR 7)"
    ) == "session-pool--failure-model-streaming-service-pr-7"
    assert doccheck.slugify(
        "Unified mixed-selector state (`engine/unified.py`, PR 10)"
    ) == "unified-mixed-selector-state-engineunifiedpy-pr-10"


def test_architecture_page_and_readme_pointer_exist():
    arch = open(os.path.join(ROOT, "docs/ARCHITECTURE.md"),
                encoding="utf-8").read()
    assert "Which entry point do I want" in arch
    for module in ("engine/median.py", "engine/maxmarg.py",
                   "engine/oneway.py", "engine/unified.py",
                   "engine/session_pool.py", "serve/service.py"):
        assert module in arch, f"ARCHITECTURE.md no longer maps {module}"
    readme = open(os.path.join(ROOT, "README.md"), encoding="utf-8").read()
    assert "docs/ARCHITECTURE.md" in readme


def test_public_entry_points_document_compile_key_contract():
    """The docstring pass the ISSUE names: each public engine surface
    states what is static vs what recompiles."""
    from repro import engine
    from repro.core import classifiers
    from repro.engine import maxmarg, median, oneway, unified
    from repro.engine.session_pool import SessionPool
    from repro.serve.service import ProtocolService

    for obj in (engine.run_sweep, median.run_instances,
                maxmarg.run_instances, oneway.run_instances,
                unified.run_instances, SessionPool, ProtocolService,
                classifiers._svm_solve_batch):
        doc = obj.__doc__ or ""
        assert "ompile-key contract" in doc, \
            f"{obj.__module__}.{obj.__qualname__} lacks a compile-key " \
            f"contract docstring"
