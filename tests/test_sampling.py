"""Reservoir sampling (Vitter) + ε-net size tests."""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import sampling


def test_epsilon_net_size_monotone():
    s1 = sampling.epsilon_net_size(0.1, vc_dim=3)
    s2 = sampling.epsilon_net_size(0.05, vc_dim=3)
    s3 = sampling.epsilon_net_size(0.05, vc_dim=6)
    assert s2 > s1 and s3 > s2


@given(st.integers(1, 30), st.integers(50, 400), st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_reservoir_size_and_membership(size, n, seed):
    rng = np.random.default_rng(seed)
    res = sampling.Reservoir(size, dim=2, rng=rng)
    X = rng.normal(size=(n, 2))
    y = np.where(rng.random(n) < 0.5, 1, -1)
    res.add_batch(X, y)
    RX, Ry = res.sample()
    assert RX.shape[0] == min(size, n)
    # every sampled point is a real input point
    for r in RX:
        assert np.any(np.all(np.isclose(X, r), axis=1))


def test_reservoir_uniformity():
    """Chi-square-ish sanity: each of n items lands in a k-reservoir with
    probability ~k/n."""
    n, k, trials = 40, 8, 1500
    counts = np.zeros(n)
    for t in range(trials):
        rng = np.random.default_rng(t)
        res = sampling.Reservoir(k, dim=1, rng=rng)
        X = np.arange(n, dtype=float).reshape(-1, 1)
        y = np.ones(n, dtype=np.int32)
        res.add_batch(X, y)
        RX, _ = res.sample()
        counts[RX.reshape(-1).astype(int)] += 1
    freq = counts / trials
    assert np.all(np.abs(freq - k / n) < 0.05)
