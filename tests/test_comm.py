"""Communication accounting invariants (the paper's Cost columns)."""

import numpy as np
import pytest

from repro.core.comm import CommLog, Node, make_nodes


def _shards(k=2, n=20, d=2, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(k):
        X = rng.normal(size=(n, d))
        y = np.where(rng.random(n) < 0.5, 1, -1)
        out.append((X, y))
    return out


def test_point_metering_exact():
    nodes, log = make_nodes(_shards())
    a, b = nodes
    a.send_points(b, a.X[:5], a.y[:5], tag="t")
    a.send_points(b, a.X[:3], a.y[:3], tag="t")
    assert log.cost_points() == 8
    assert log.stats.messages == 2
    assert b.recv_X.shape == (8, 2)


def test_bytes_formula():
    nodes, log = make_nodes(_shards(d=3))
    a, b = nodes
    a.send_points(b, a.X[:4], a.y[:4])
    a.send_scalars(b, np.zeros(6))
    a.send_bit(b, 1)
    s = log.summary()
    assert s["points"] == 4 and s["scalars"] == 6 and s["bits"] == 1
    # 4 points * (3 dims + label) * 4B + 6 scalars * 4B + 1 bit -> 1 byte
    assert s["bytes"] == 4 * 4 * 4 + 6 * 4 + 1


def test_empty_message_costs_no_points():
    nodes, log = make_nodes(_shards())
    a, b = nodes
    a.send_points(b, np.zeros((0, 2)), np.zeros((0,), np.int32))
    assert log.cost_points() == 0
    assert log.stats.messages == 1


def test_labels_validated():
    with pytest.raises(AssertionError):
        make_nodes([(np.zeros((2, 2)), np.array([0, 1]))])


def test_rounds_counter():
    nodes, log = make_nodes(_shards())
    log.new_round()
    log.new_round()
    assert log.stats.rounds == 2
