"""Communication accounting invariants (the paper's Cost columns)."""

import numpy as np
import pytest

from repro.core.comm import CommLog, Node, make_nodes


def _shards(k=2, n=20, d=2, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(k):
        X = rng.normal(size=(n, d))
        y = np.where(rng.random(n) < 0.5, 1, -1)
        out.append((X, y))
    return out


def test_point_metering_exact():
    nodes, log = make_nodes(_shards())
    a, b = nodes
    a.send_points(b, a.X[:5], a.y[:5], tag="t")
    a.send_points(b, a.X[:3], a.y[:3], tag="t")
    assert log.cost_points() == 8
    assert log.stats.messages == 2
    assert b.recv_X.shape == (8, 2)


def test_bytes_formula():
    nodes, log = make_nodes(_shards(d=3))
    a, b = nodes
    a.send_points(b, a.X[:4], a.y[:4])
    a.send_scalars(b, np.zeros(6))
    a.send_bit(b, 1)
    s = log.summary()
    assert s["points"] == 4 and s["scalars"] == 6 and s["bits"] == 1
    # 4 points * (3 dims + label) * 4B + 6 scalars * 4B + 1 bit -> 1 byte
    assert s["bytes"] == 4 * 4 * 4 + 6 * 4 + 1


def test_empty_message_costs_no_points():
    nodes, log = make_nodes(_shards())
    a, b = nodes
    a.send_points(b, np.zeros((0, 2)), np.zeros((0,), np.int32))
    assert log.cost_points() == 0
    assert log.stats.messages == 1


def test_labels_validated():
    with pytest.raises(AssertionError):
        make_nodes([(np.zeros((2, 2)), np.array([0, 1]))])


def test_rounds_counter():
    nodes, log = make_nodes(_shards())
    log.new_round()
    log.new_round()
    assert log.stats.rounds == 2


def test_message_nbytes_hand_computed():
    from repro.core.comm import Message
    m = Message("A", "B", points=7, scalars=3, bits=11)
    for dim in (1, 2, 10):
        # 7 points of (dim + label) float32s, 3 float32 scalars, 11 bits -> 2B
        assert m.nbytes(dim) == 7 * (dim + 1) * 4 + 3 * 4 + 2


def test_commstats_nbytes_matches_message_sum():
    nodes, log = make_nodes(_shards(d=2))
    a, b = nodes
    a.send_points(b, a.X[:5], a.y[:5])
    b.send_scalars(a, np.zeros(4))
    a.send_bit(b, 1)
    b.send_bit(a, 0)
    s = log.stats
    # 5 points of (2 dims + label) float32s + 4 scalars + 2 bits -> 1 byte
    assert s.nbytes(2) == 5 * (2 + 1) * 4 + 4 * 4 + 1
    # canonical per-message attribution (packed-stream deltas) sums exactly
    assert sum(log.message_nbytes()) == s.nbytes(2) == log.summary()["bytes"]
    # standalone-message ceiling is an upper bound, never the canon
    assert sum(m.nbytes(2) for m in log.messages) >= s.nbytes(2)


def test_message_nbytes_packed_on_two_way_trace():
    """Regression for the rounding drift: replay a two-way-shaped trace
    (support points + direction scalars + one accept bit per turn, the
    MAXMARG/MEDIAN message slots) and require per-message bytes to sum to
    summary()["bytes"] exactly.  Ceiling each 1-bit vote alone would charge
    a full byte per turn and overshoot by rounds-1 bytes."""
    nodes, log = make_nodes(_shards(d=2, n=30))
    a, b = nodes
    rounds = 5
    for r in range(rounds):
        log.new_round()
        src, dst = (a, b) if r % 2 == 0 else (b, a)
        src.send_points(dst, src.X[:2], src.y[:2], tag="support")
        src.send_scalars(dst, np.zeros(4), tag="direction")
        dst.send_bit(src, 0, tag="accept")
    per_msg = log.message_nbytes()
    assert len(per_msg) == log.stats.messages == 3 * rounds
    assert sum(per_msg) == log.summary()["bytes"]
    # 5 one-bit votes pack into 1 byte in the aggregate, not 5
    naive_sum = sum(m.nbytes(2) for m in log.messages)
    assert naive_sum - sum(per_msg) == rounds - 1


def test_empty_message_nbytes_zero_but_counted():
    """Node.send_points with an empty payload: one message-slot, zero points,
    zero wire bytes."""
    nodes, log = make_nodes(_shards())
    a, b = nodes
    a.send_points(b, np.zeros((0, 2)), np.zeros((0,), np.int32), tag="empty")
    assert log.stats.messages == 1
    assert log.messages[0].points == 0
    assert log.messages[0].nbytes(2) == 0
    assert log.summary()["bytes"] == 0
    assert b.recv_X.shape == (0, 2)


def test_batchcommlog_b1_matches_commlog():
    """Replaying identical traffic into a B=1 BatchCommLog must lower to the
    exact CommLog.summary() dict (the metered-channel invariant survives
    vectorization)."""
    import jax.numpy as jnp

    from repro.engine.state import BatchCommLog

    nodes, log = make_nodes(_shards(d=2))
    a, b = nodes
    log.new_round()
    a.send_points(b, a.X[:2], a.y[:2], tag="support")
    a.send_scalars(b, np.zeros(4), tag="direction")
    log.new_round()
    b.send_points(a, b.X[:1], b.y[:1], tag="extremes")
    b.send_bit(a, 1, tag="accept")

    batch = BatchCommLog.zeros(1)
    batch = batch._replace(
        points=batch.points + jnp.asarray([2 + 1]),
        scalars=batch.scalars + jnp.asarray([4]),
        bits=batch.bits + jnp.asarray([1]),
        messages=batch.messages + jnp.asarray([4]),
        rounds=batch.rounds + jnp.asarray([2]),
    )
    assert batch.summary(0, dim=2) == log.summary()
