"""Geometry primitive tests (hulls, projections, medians, SOU mask)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import geometry as geo


@given(st.integers(3, 60), st.integers(0, 10_000))
@settings(max_examples=50, deadline=None)
def test_hull_contains_all_points(n, seed):
    rng = np.random.default_rng(seed)
    P = rng.normal(size=(n, 2))
    idx = geo.convex_hull_2d(P)
    hull = P[idx]
    # every point is inside the hull: all cross products for CCW edges >= 0
    for q in P:
        a = hull
        b = np.roll(hull, -1, axis=0)
        cross = (b[:, 0] - a[:, 0]) * (q[1] - a[:, 1]) - (b[:, 1] - a[:, 1]) * (q[0] - a[:, 0])
        assert np.all(cross >= -1e-9)


def test_hull_ccw_order():
    P = np.array([[0, 0], [1, 0], [1, 1], [0, 1], [0.5, 0.5]])
    idx = geo.convex_hull_2d(P)
    hull = P[idx]
    # shoelace area positive for CCW
    x, y = hull[:, 0], hull[:, 1]
    area = 0.5 * np.sum(x * np.roll(y, -1) - np.roll(x, -1) * y)
    assert area > 0
    assert 4 not in idx  # interior point excluded


def test_edge_normals_outward():
    P = np.array([[0, 0], [2, 0], [2, 2], [0, 2]], dtype=float)
    idx = geo.convex_hull_2d(P)
    edges = geo.hull_edges(P, idx)
    normals = geo.edge_normals(edges)
    centroid = P.mean(0)
    mid = edges.mean(1)
    assert np.all(np.sum((mid - centroid) * normals, axis=1) > 0)


@given(st.integers(1, 50))
@settings(max_examples=30, deadline=None)
def test_weighted_median(n):
    rng = np.random.default_rng(n)
    w = rng.random(n)
    i = geo.weighted_median_index(w)
    c = np.cumsum(w)
    assert c[i] >= c[-1] / 2
    if i > 0:
        assert c[i - 1] < c[-1] / 2


def test_project_to_hull_boundary():
    P = np.array([[0, 0], [4, 0], [4, 4], [0, 4]], dtype=float)
    idx = geo.convex_hull_2d(P)
    edges = geo.hull_edges(P, idx)
    # a point near the bottom edge maps to the bottom edge
    q = np.array([[2.0, 0.1]])
    e = geo.project_to_hull_boundary(q, edges)[0]
    seg = edges[e]
    assert np.allclose(seg[:, 1], 0)  # bottom edge has y == 0


def test_classification_error_jax():
    X = jnp.array([[1.0, 0.0], [-1.0, 0.0]])
    y = jnp.array([1.0, -1.0])
    w = jnp.array([1.0, 0.0])
    assert float(geo.classification_error(w, jnp.array(0.0), X, y)) == 0.0
    assert float(geo.classification_error(-w, jnp.array(0.0), X, y)) == 1.0


def test_uncertain_mask_shrinks_with_transcript():
    """More transcript points can only shrink the SOU (monotonicity)."""
    rng = np.random.default_rng(3)
    V = np.asarray(geo.direction_grid(256))
    X = rng.normal(size=(200, 2))
    w = np.array([1.0, 0.4])
    y = np.where(X @ w > 0, 1, -1)
    ok = jnp.ones(256, bool)
    m1 = geo.uncertain_mask(V, ok, jnp.asarray(X[:5]), jnp.asarray(y[:5]),
                            jnp.asarray(X), jnp.asarray(y))
    m2 = geo.uncertain_mask(V, ok, jnp.asarray(X[:50]), jnp.asarray(y[:50]),
                            jnp.asarray(X), jnp.asarray(y))
    assert int(m2.sum()) <= int(m1.sum())
    # transcript points with both labels on a fixed direction set leave
    # fewer uncertain than the full shard
    assert int(m2.sum()) < X.shape[0]
