"""End-to-end behaviour tests for the paper's system.

The integration scenario is the paper's setting mapped onto the framework:
k data-parallel workers hold adversarially-partitioned labeled features
produced by a transformer (the assigned architectures), and learn a global
linear separator via the communication-metered protocols instead of
shipping raw activations.
"""

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.core import datasets
from repro.core.protocols import baselines, two_way
from repro.models import model as M

from conftest import global_err


def _transformer_features(arch="smollm-135m", n=400, seed=0):
    """Mean-pooled embedding features for synthetic token sequences + a
    linearly separable labeling in feature space (noiseless, per the paper)."""
    cfg = C.get_config(arch).reduced()
    params = M.init_lm(jax.random.PRNGKey(seed), cfg)
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(seed + 1),
                                         (n, 16), 0, cfg.vocab))
    emb = np.asarray(jax.tree.leaves({k: v for k, v in params.items()
                                      if "embed" in k})[0], np.float64)
    feats = emb[toks].mean(axis=1)
    # project to 2-D for the protocol geometry and label by a hidden separator
    rng = np.random.default_rng(seed)
    proj = rng.normal(size=(feats.shape[1], 2))
    X = feats @ proj
    X = (X - X.mean(0)) / (X.std(0) + 1e-9)
    w = rng.normal(size=2)
    margin = X @ w
    keep = np.abs(margin) > 0.2          # noiseless: enforce a margin
    X, margin = X[keep], margin[keep]
    y = np.where(margin > 0, 1, -1).astype(np.int32)
    return X, y


def test_distributed_probe_protocol_end_to_end():
    """Transformer features -> adversarial split -> IterativeSupports learns
    a global eps-classifier with >=10x less communication than NAIVE."""
    X, y = _transformer_features()
    # adversarial partition: sort along the second coordinate
    order = np.argsort(X[:, 1])
    half = len(order) // 2
    shards = [(X[order[:half]], y[order[:half]]), (X[order[half:]], y[order[half:]])]
    eps = 0.05
    naive = baselines.naive(shards)
    med = two_way.iterative_support_median(shards, eps=eps)
    assert global_err(med.classifier, shards) <= eps
    assert med.comm["points"] * 10 <= naive.comm["points"]


def test_protocol_cost_scales_logarithmically():
    """Thm 5.1 check on the system level: eps 0.2 -> 0.0125 (16x tighter)
    adds only additive rounds, not 16x cost."""
    shards = datasets.data3(n_per_node=500, k=2, seed=3)
    costs = {}
    for eps in (0.2, 0.05, 0.0125):
        r = two_way.iterative_support_median(shards, eps=eps)
        costs[eps] = r.comm["points"]
        assert global_err(r.classifier, shards) <= eps
    assert costs[0.0125] <= costs[0.2] + 40  # additive in log(1/eps), not multiplicative
