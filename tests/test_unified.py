"""Mixed-selector dispatch gates (ISSUE 10 tentpole).

The unified path's contract (DESIGN.md §unified mixed-selector state) in
test form:

* **oracle parity** — an interleaved MEDIAN + MAXMARG + SAMPLING grid run
  through ``run_sweep(unified_dispatch=True)`` matches the per-selector
  ``run_instances`` oracles row for row: MEDIAN bitwise (any covering
  transcript width is), MAXMARG and SAMPLING decision/comm-exact with
  separators allclose (padded solver widths reassociate float sums);
* **one pool, any mix** — a ``PoolConfig(selector="unified")`` pool absorbs
  all three families through one pinned dispatch key, decision/comm-exact
  vs the same oracles, and bitwise invariant to admission order;
* **supervision is selector-blind** — forced faults land on the targeted
  session whatever its family, trip the paired invariant, and leave every
  other session bitwise identical to the fault-free run;
* **checkpoint/restore** — a mixed pool snapshotted mid-stream (pending
  selector/seed tags and the per-slot selector codes included) resumes to
  bitwise-identical results.
"""

import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.core import datasets
from repro.engine import run_sweep, unified
from repro.engine.faults import CORRUPT_NAN
from repro.engine.session_pool import (
    ST_CONVERGED,
    ST_QUARANTINED,
    PoolConfig,
    SessionPool,
)
from repro.engine.state import ProtocolInstance

N_PAD = 16
N_ANGLES = 64
MAX_EPOCHS = 8
_GENS = (datasets.data1, datasets.data2, datasets.data3)
_MIX = ("median", "maxmarg", "sampling")


def _mixed_instances(n, k=2, n_per_node=N_PAD, seed0=0):
    """Interleaved families over staggered datasets/eps; uniform shard
    sizes keep the sampling rows' Threefry draws bitwise comparable."""
    return [ProtocolInstance(
        _GENS[i % 3](n_per_node=n_per_node, k=k, seed=seed0 + i),
        eps=(0.1, 0.05, 0.05)[i % 3], selector=_MIX[i % 3],
        seed=seed0 + i) for i in range(n)]


def _assert_matches_oracle(res, oracle, *, median_bitwise=False):
    for r, o in zip(res, oracle):
        sel = r.extra["selector"]
        assert r.comm == o.comm, sel
        assert r.rounds == o.rounds and r.converged == o.converged, sel
        w_r, w_o = np.asarray(r.classifier.w), np.asarray(o.classifier.w)
        if median_bitwise and sel == "median":
            assert np.array_equal(w_r, w_o)
            assert float(r.classifier.b) == float(o.classifier.b)
        else:
            np.testing.assert_allclose(w_r, w_o, rtol=1e-5, atol=1e-6)
            assert np.isclose(float(r.classifier.b), float(o.classifier.b),
                              rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# engine path: one dispatch vs the per-selector oracles
# ---------------------------------------------------------------------------


def test_mixed_sweep_matches_per_selector_oracles():
    insts = _mixed_instances(9)
    oracle = run_sweep(insts, n_angles=N_ANGLES, max_epochs=MAX_EPOCHS)
    res = run_sweep(insts, n_angles=N_ANGLES, max_epochs=MAX_EPOCHS,
                    unified_dispatch=True)
    assert all(r.extra.get("unified") for r in res)
    _assert_matches_oracle(res, oracle, median_bitwise=True)
    # family-specific extras survive the shared extraction
    for r in res:
        if r.extra["selector"] == "sampling":
            assert r.rounds == 1 and r.converged and "sample_size" in r.extra
        if r.extra["selector"] == "maxmarg":
            assert "warm_latches" in r.extra


def test_mixed_sweep_kparty_chains_and_carries():
    """k=3: multi-hop Vitter chains and per-node warm carries share the
    one dispatch with a k-party MEDIAN row."""
    insts = _mixed_instances(6, k=3, seed0=7)
    oracle = run_sweep(insts, n_angles=N_ANGLES, max_epochs=MAX_EPOCHS)
    res = run_sweep(insts, n_angles=N_ANGLES, max_epochs=MAX_EPOCHS,
                    unified_dispatch=True)
    _assert_matches_oracle(res, oracle, median_bitwise=True)


def test_unified_run_instances_median_free_mix():
    """A median-free mix carries stub arc leaves and skips the MEDIAN
    substep entirely — results still match the oracles."""
    insts = [inst for inst in _mixed_instances(8)
             if inst.selector != "median"]
    oracle = run_sweep(insts, max_epochs=MAX_EPOCHS)
    res = unified.run_instances(insts, max_epochs=MAX_EPOCHS)
    _assert_matches_oracle(res, oracle)


# ---------------------------------------------------------------------------
# pool path: one pool, any mix, any admission order
# ---------------------------------------------------------------------------


def _pool_cfg(**kw):
    base = dict(slots=4, k=2, n_pad=N_PAD, selector="unified",
                n_angles=N_ANGLES, max_epochs=MAX_EPOCHS)
    base.update(kw)
    return PoolConfig(**base)


def _submit_all(pool, insts):
    return [pool.submit(inst.shards, eps=inst.eps, selector=inst.selector,
                        seed=inst.seed) for inst in insts]


def _res_bitwise(a, b):
    return (np.array_equal(np.asarray(a.classifier.w),
                           np.asarray(b.classifier.w))
            and float(a.classifier.b) == float(b.classifier.b)
            and a.comm == b.comm and a.rounds == b.rounds
            and a.converged == b.converged)


def test_mixed_pool_matches_oracles_across_admission_orders():
    insts = _mixed_instances(9, seed0=20)
    oracle = run_sweep(insts, n_angles=N_ANGLES, max_epochs=MAX_EPOCHS)

    pool_a = SessionPool(_pool_cfg())
    sids_a = _submit_all(pool_a, insts)
    pool_a.run()
    _assert_matches_oracle([pool_a.results[s] for s in sids_a], oracle)
    for s, inst in zip(sids_a, insts):
        assert pool_a.results[s].extra["selector"] == inst.selector

    # reversed admission: different slot assignment and batch composition,
    # bitwise-identical per-session results (the single pinned key at work)
    perm = list(reversed(range(len(insts))))
    pool_b = SessionPool(_pool_cfg())
    sids_b = _submit_all(pool_b, [insts[i] for i in perm])
    pool_b.run()
    for j, i in enumerate(perm):
        assert _res_bitwise(pool_b.results[sids_b[j]],
                            pool_a.results[sids_a[i]]), insts[i].selector


class _ForcedSchedule:
    """Duck-typed fault schedule: fire exactly at (sid, turn) coordinates
    (``(sid, None)`` fires every turn) — the pool only reads ``draws`` /
    ``straggle_max`` / ``any_faults``."""

    straggle_max = 3
    any_faults = True

    def __init__(self, dropout=(), corrupt=None):
        self._drop = set(dropout)
        self._cor = dict(corrupt or {})

    def draws(self, sids, t):
        sids = [int(s) for s in np.asarray(sids)]
        return {
            "dropout": np.asarray(
                [(s, t) in self._drop or (s, None) in self._drop
                 for s in sids], bool),
            "drop_msg": np.zeros(len(sids), bool),
            "straggle": np.zeros(len(sids), np.int32),
            "corrupt": np.asarray(
                [self._cor.get((s, t), self._cor.get((s, None), -1))
                 for s in sids], np.int32),
        }


def test_mixed_pool_faults_land_on_the_right_session():
    """Targeted faults must hit their sid whatever its family, and leave
    every other session bitwise identical to the fault-free pool."""
    insts = _mixed_instances(6, seed0=40)
    clean = SessionPool(_pool_cfg())
    sids = _submit_all(clean, insts)
    clean.run()

    # sid 2 is a SAMPLING session (mix order), sid 0 a MEDIAN one
    sched = _ForcedSchedule(dropout=[(0, 0), (0, 2)],
                            corrupt={(2, None): CORRUPT_NAN})
    chaos = SessionPool(_pool_cfg(), schedule=sched)
    _submit_all(chaos, insts)
    chaos.run()

    assert chaos.sessions[2]["status"] == ST_QUARANTINED
    assert chaos.sessions[2]["quarantine_reason"] == "nan_separator"
    assert chaos.sessions[2]["selector"] == "sampling"
    assert chaos.sessions[0]["dropouts"] == 2
    assert chaos.sessions[0]["status"] == ST_CONVERGED
    for sid in sids:
        if sid == 2:
            assert sid not in chaos.results
            continue
        assert _res_bitwise(chaos.results[sid], clean.results[sid]), sid


def test_mixed_pool_checkpoint_restore_bitwise(tmp_path):
    insts = _mixed_instances(9, seed0=60)
    ref = SessionPool(_pool_cfg())
    _submit_all(ref, insts)
    ref.run()

    pool = SessionPool(_pool_cfg())
    _submit_all(pool, insts)
    pool.step_pool()
    pool.step_pool()
    pool.checkpoint(str(tmp_path))
    resumed = SessionPool.restore(str(tmp_path))
    assert np.array_equal(resumed.slot_sel, pool.slot_sel)
    resumed.run()
    for sid in ref.results:
        assert _res_bitwise(resumed.results[sid], ref.results[sid]), sid


def test_unified_submit_validation():
    pinned = SessionPool(PoolConfig(slots=2, k=2, n_pad=N_PAD,
                                    n_angles=N_ANGLES))
    shards = _mixed_instances(1)[0].shards
    with pytest.raises(ValueError, match="pinned to selector"):
        pinned.submit(shards, selector="maxmarg")

    pool = SessionPool(_pool_cfg(slots=2))
    with pytest.raises(ValueError, match="unified pools take"):
        pool.submit(shards, selector="voting")
    with pytest.raises(ValueError, match="reservoir"):
        # an ε-net far larger than the pool's pinned res_cap
        pool.submit(shards, eps=1e-4, selector="sampling")
    sid = pool.submit(shards, selector="sampling", seed=5)
    assert pool.sessions[sid]["selector"] == "sampling"
