"""Hypothesis property tests on the protocol-system invariants."""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import datasets
from repro.core.protocols import kparty, two_way

from conftest import global_err


def _random_separable(n, k, seed, gap=0.25):
    """Random linearly separable 2-D instance, random angular partition."""
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, 2)) * rng.uniform(0.5, 2.0, size=2)
    w = rng.normal(size=2)
    w /= np.linalg.norm(w)
    b = rng.normal() * 0.5
    m = X @ w + b
    X, m = X[np.abs(m) > gap], m[np.abs(m) > gap]
    y = np.where(m > 0, 1, -1).astype(np.int32)
    if len(np.unique(y)) < 2 or len(y) < 4 * k:
        return None
    mode = seed % 3
    if mode == 0:      # iid split
        order = rng.permutation(len(y))
    elif mode == 1:    # angular sectors
        order = np.argsort(np.arctan2(X[:, 1], X[:, 0]))
    else:              # sorted along the separator normal (adversarial)
        order = np.argsort(m)
    return [(X[c], y[c]) for c in np.array_split(order, k)]


@given(st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_two_party_median_always_reaches_eps(seed):
    """Invariant: on ANY noiseless separable instance the MEDIAN protocol
    reaches ε-error (the Thm 5.1 guarantee, property-tested)."""
    shards = _random_separable(300, 2, seed)
    if shards is None:
        return
    r = two_way.iterative_support_median(shards, eps=0.05)
    assert global_err(r.classifier, shards) <= 0.05


@given(st.integers(0, 10_000), st.integers(2, 5))
@settings(max_examples=20, deadline=None)
def test_kparty_median_always_reaches_eps(seed, k):
    shards = _random_separable(80 * k, k, seed)
    if shards is None:
        return
    r = kparty.iterative_support_kparty(shards, eps=0.05, selector="median")
    assert global_err(r.classifier, shards) <= 0.05


@given(st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_median_invariant_under_translation(seed):
    """Shifting all data by a constant must not change convergence (the
    protocol is affine-equivariant via its threshold offsets)."""
    shards = _random_separable(200, 2, seed)
    if shards is None:
        return
    t = np.asarray([37.5, -12.25])
    shifted = [(X + t, y) for X, y in shards]
    r = two_way.iterative_support_median(shifted, eps=0.05)
    assert global_err(r.classifier, shifted) <= 0.05


@given(st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_comm_cost_never_exceeds_naive(seed):
    """Sanity invariant: the protocol never ships more points than NAIVE
    would (the whole smaller shard)."""
    shards = _random_separable(300, 2, seed)
    if shards is None:
        return
    r = two_way.iterative_support_median(shards, eps=0.05)
    n_naive = min(len(s[1]) for s in shards)
    assert r.comm["points"] <= max(n_naive, 64)
