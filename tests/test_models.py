"""Per-architecture smoke tests: every assigned arch, reduced variant
(<=2 periods, d_model<=256, <=4 experts), one forward/train step on CPU,
asserting output shapes + no NaNs; plus decode-path consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

if not hasattr(jax.sharding, "get_abstract_mesh"):
    pytest.skip(
        "model stack requires jax.sharding.get_abstract_mesh (jax >= 0.5.x); "
        "pre-existing version skew on this container's jax, unrelated to the "
        "protocol/engine code (ROADMAP.md)", allow_module_level=True)

import repro.configs as C
from repro.data.pipeline import DataConfig, dec_len, synthetic_stream
from repro.models import model as M
from repro.models.config import INPUT_SHAPES
from repro.models.model import RunFlags

ARCHS = list(C.ARCHS)


def make_batch(cfg, B=2, S=32, seed=0):
    key = jax.random.PRNGKey(seed)
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
        "targets": jax.random.randint(key, (B, S), 0, cfg.vocab),
    }
    if cfg.family == "vlm":
        batch["vision_embed"] = jax.random.normal(key, (B, 8, cfg.d_model)) * 0.02
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        batch["rope_pos"] = jnp.broadcast_to(pos[None], (3, B, S)).astype(jnp.int32)
    if cfg.enc_dec:
        batch["audio_embed"] = jax.random.normal(key, (B, 64, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_forward_step(arch):
    cfg = C.get_config(arch).reduced()
    params = M.init_lm(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    loss, metrics = M.forward_train(params, cfg, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: loss not finite"
    assert 0.0 <= float(metrics["acc"]) <= 1.0


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_train_step_no_nans(arch):
    from repro.optim.adamw import AdamWConfig, adamw_init
    from repro.train.trainer import TrainConfig, make_train_step
    cfg = C.get_config(arch).reduced()
    params = M.init_lm(jax.random.PRNGKey(0), cfg)
    tc = TrainConfig(dtype=jnp.float32, optim=AdamWConfig())
    step = jax.jit(make_train_step(cfg, tc))
    opt = adamw_init(params)
    batch = make_batch(cfg)
    params2, opt2, metrics = step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    moved = jax.tree.reduce(
        lambda a, leaf: a + float(jnp.abs(leaf).sum()),
        jax.tree.map(lambda a, b: (a.astype(jnp.float32) - b.astype(jnp.float32)),
                     params2, params), 0.0)
    assert moved > 0.0
    for leaf in jax.tree.leaves(params2):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_decode_matches_prefill_logits(arch):
    """serve_step(token t | cache of 0..t-1) must agree with teacher-forced
    forward logits — the cache path is exact, not approximate."""
    cfg = C.get_config(arch).reduced()
    if cfg.enc_dec:
        pytest.skip("enc-dec decode path covered in test_serve")
    B, S = 2, 16
    params = M.init_lm(jax.random.PRNGKey(1), cfg)
    flags = RunFlags()
    # generate ONE (S+1)-token batch; the S-token batch is its prefix
    batch2 = make_batch(cfg, B=B, S=S + 1, seed=1)
    batch = {k: (v[:, :S] if k in ("tokens", "targets") else
                 (v[:, :, :S] if k == "rope_pos" else v))
             for k, v in batch2.items()}
    caches = M.make_caches(cfg, B, S + 1, jnp.float32)  # room for 1 decode step
    logits_pf, caches = M.prefill(params, cfg, {k: v for k, v in batch.items()
                                                if k != "targets"},
                                  caches, flags, dtype=jnp.float32)
    # decode one token at the end and compare against a longer prefill
    caches2 = M.make_caches(cfg, B, S + 1, jnp.float32)
    tok_next = batch2["tokens"][:, S:S + 1]
    batch2_prefill = {k: v for k, v in batch2.items() if k != "targets"}
    logits_full, _ = M.prefill(params, cfg, batch2_prefill, caches2, flags,
                               dtype=jnp.float32)
    logits_dec, _ = M.decode_step(params, cfg, caches, tok_next, jnp.int32(S),
                                  flags, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(logits_dec[:, 0]),
                               np.asarray(logits_full[:, S]),
                               rtol=2e-3, atol=2e-3)


def test_param_counts_match_configs():
    """Analytic param counts are in range of the models' advertised sizes."""
    expect = {
        "deepseek-v2-236b": (200e9, 260e9),
        "rwkv6-7b": (6e9, 9e9),
        "jamba-1.5-large-398b": (330e9, 430e9),
        "qwen2.5-14b": (12e9, 16e9),
        "whisper-medium": (0.25e9, 1.0e9),
        "qwen2-vl-2b": (1.2e9, 2.4e9),
        "grok-1-314b": (280e9, 340e9),
        "smollm-135m": (0.11e9, 0.16e9),
        "qwen1.5-110b": (95e9, 125e9),
        "deepseek-7b": (6e9, 8e9),
    }
    for arch, (lo, hi) in expect.items():
        n = C.get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n / 1e9:.1f}B outside [{lo / 1e9}, {hi / 1e9}]"


def test_moe_active_params_smaller():
    for arch in ("deepseek-v2-236b", "grok-1-314b", "jamba-1.5-large-398b"):
        cfg = C.get_config(arch)
        assert cfg.active_param_count() < 0.5 * cfg.param_count()


def test_exact_config_values():
    """Spot-check the assigned architecture table values."""
    c = C.get_config("deepseek-v2-236b")
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab) == (60, 5120, 128, 102400)
    assert c.moe.n_experts == 160 and c.moe.top_k == 6 and c.moe.n_shared == 2
    assert c.mla.kv_lora == 512
    c = C.get_config("jamba-1.5-large-398b")
    assert (c.n_layers, c.d_model, c.d_ff) == (72, 8192, 24576)
    assert c.moe.n_experts == 16 and c.moe.top_k == 2
    c = C.get_config("qwen1.5-110b")
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab) == (80, 8192, 49152, 152064)
    assert c.qkv_bias
    c = C.get_config("rwkv6-7b")
    assert c.attn_free and (c.n_layers, c.d_model) == (32, 4096)
    c = C.get_config("whisper-medium")
    assert c.enc_dec and (c.n_layers, c.d_model, c.vocab) == (24, 1024, 51865)
    c = C.get_config("grok-1-314b")
    assert (c.n_layers, c.d_model, c.d_ff) == (64, 6144, 32768)


def test_synthetic_stream_deterministic():
    cfg = C.get_config("smollm-135m").reduced()
    dc = DataConfig(seq_len=32, global_batch=4, seed=5)
    b1 = next(synthetic_stream(cfg, dc))
    b2 = next(synthetic_stream(cfg, dc))
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # different shards differ
    b3 = next(synthetic_stream(cfg, dc, shard=1, n_shards=2))
    assert b3["tokens"].shape[0] == 2
