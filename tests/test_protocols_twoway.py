"""Two-way protocol tests: IterativeSupports (paper §4-5) + k-party (§6.2)
+ the baselines it is compared against (§7)."""

import numpy as np
import pytest

from repro.core import datasets
from repro.core.protocols import baselines, kparty, two_way

from conftest import global_err

EPS = 0.05


@pytest.mark.parametrize("gen", [datasets.data1, datasets.data2, datasets.data3])
@pytest.mark.parametrize("fn", [two_way.iterative_support_median,
                                two_way.iterative_support_maxmarg])
def test_two_party_converges_to_eps(gen, fn):
    shards = gen(n_per_node=250, k=2, seed=0)
    r = fn(shards, eps=EPS)
    assert r.converged
    assert global_err(r.classifier, shards) <= EPS


@pytest.mark.parametrize("gen", [datasets.data1, datasets.data3])
def test_two_way_beats_naive_on_communication(gen):
    shards = gen(n_per_node=250, k=2, seed=0)
    naive_cost = baselines.naive(shards).comm["points"]
    for fn in (two_way.iterative_support_median, two_way.iterative_support_maxmarg):
        assert fn(shards, eps=EPS).comm["points"] < naive_cost / 5


def test_median_logarithmic_rounds():
    """Thm 5.1: rounds = O(log 1/eps); eps 0.1 -> 0.0125 may add ~3 rounds."""
    shards = datasets.data3(n_per_node=400, k=2, seed=1)
    r_coarse = two_way.iterative_support_median(shards, eps=0.1)
    r_fine = two_way.iterative_support_median(shards, eps=0.0125)
    assert r_fine.rounds <= r_coarse.rounds + 6
    assert global_err(r_fine.classifier, shards) <= 0.0125


def test_voting_fails_on_adversarial_data3():
    """Paper Table 2: VOTING is ~50% on Data3 while the protocols reach eps."""
    shards = datasets.data3(n_per_node=250, k=2, seed=0)
    v = baselines.voting(shards)
    assert global_err(v.classifier, shards) >= 0.3
    m = two_way.iterative_support_median(shards, eps=EPS)
    assert global_err(m.classifier, shards) <= EPS


def test_random_baseline_eps_but_expensive():
    shards = datasets.data3(n_per_node=250, k=2, seed=0)
    r = baselines.random(shards, eps=EPS)
    assert global_err(r.classifier, shards) <= EPS + 0.02
    med = two_way.iterative_support_median(shards, eps=EPS)
    assert med.comm["points"] < r.comm["points"]


@pytest.mark.parametrize("gen", [datasets.data1, datasets.data2, datasets.data3])
def test_kparty_converges(gen):
    shards = gen(n_per_node=150, k=4, seed=0)
    r = kparty.iterative_support_kparty(shards, eps=EPS, selector="median")
    assert global_err(r.classifier, shards) <= EPS


def test_kparty_maxmarg_converges():
    shards = datasets.data1(n_per_node=150, k=4, seed=0)
    r = kparty.iterative_support_kparty(shards, eps=EPS, selector="maxmarg")
    assert global_err(r.classifier, shards) <= EPS


def test_higher_dim_maxmarg():
    """Paper Table 3: the MAXMARG heuristic works in d=10."""
    shards = datasets.data1(n_per_node=250, k=2, seed=0)
    shards = datasets.lift_dim(shards, d=10, seed=7)
    r = two_way.iterative_support_maxmarg(shards, eps=EPS)
    assert global_err(r.classifier, shards) <= EPS
    assert r.comm["points"] < 100


def test_mixing_baseline_runs():
    shards = datasets.data1(n_per_node=100, k=2, seed=0)
    r = baselines.mixing(shards)
    assert r.comm["points"] == 0  # parameter mixing ships no raw points
    assert global_err(r.classifier, shards) <= 0.5


def test_single_class_shard_not_poisoned():
    """Regression: a node holding only one class must not ship a mislabeled
    stand-in point (the ∅ band edge); protocol still converges."""
    rng = np.random.default_rng(5)
    Xp = rng.normal(size=(120, 2)) + np.array([0.0, 2.5])
    Xn = rng.normal(size=(120, 2)) + np.array([0.0, -2.5])
    # node A: positives only; node B: everything else
    shards = [(Xp[:60], np.ones(60, np.int32)),
              (np.concatenate([Xp[60:], Xn]),
               np.concatenate([np.ones(60, np.int32), -np.ones(120, np.int32)]))]
    r = two_way.iterative_support_median(shards, eps=0.05)
    assert global_err(r.classifier, shards) <= 0.05


def test_kparty_sector_partition():
    """Regression: angular-sector adversarial partition (some nodes nearly
    single-class) converges with certified pivot pruning."""
    rng = np.random.default_rng(7)
    X = rng.normal(size=(800, 2)) * np.array([1.5, 1.0])
    w = np.array([0.8, -0.6])
    m = X @ w
    X, m = X[np.abs(m) > 0.2], m[np.abs(m) > 0.2]
    y = np.where(m > 0, 1, -1).astype(np.int32)
    ang = np.arctan2(X[:, 1], X[:, 0])
    order = np.argsort(ang)
    shards = [(X[c], y[c]) for c in np.array_split(order, 4)]
    r = kparty.iterative_support_kparty(shards, eps=0.05, selector="median")
    assert global_err(r.classifier, shards) <= 0.05
    naive_pts = sum(len(s[1]) for s in shards[:-1])
    assert r.comm["points"] < naive_pts / 4


def test_noisy_setting_recovers_clean_separator():
    """Paper §8.2 extension: with 5% flipped labels the noise-tolerant
    protocol still finds a separator that is ~clean-optimal."""
    shards = datasets.data3(n_per_node=250, k=2, seed=0)
    noisy = datasets.add_label_noise(shards, rate=0.05)
    r = two_way.iterative_support_noisy(noisy, eps=0.05)
    clean_err = global_err(r.classifier, shards)
    assert clean_err <= 0.05
    assert r.comm["points"] <= 60  # still two orders below NAIVE


def test_noisy_protocol_noise_floor():
    """Error on the NOISY labels cannot beat the noise floor; the protocol
    should sit near it, not chase it."""
    shards = datasets.data1(n_per_node=250, k=2, seed=1)
    noisy = datasets.add_label_noise(shards, rate=0.1, seed=3)
    r = two_way.iterative_support_noisy(noisy, eps=0.05)
    noisy_err = global_err(r.classifier, noisy)
    assert 0.05 <= noisy_err <= 0.2  # ~the 10% floor
