"""Pre-engine host-loop MEDIAN baseline (benchmark + differential oracle).

This is the certified-pivot k-party MEDIAN exactly as it executed before the
batched engine landed: a host-side Python loop over turns, numpy control
plane, and a device round-trip per round for the jit'd geometry scans.  It is
kept verbatim for two reasons only:

* ``benchmarks/engine_sweep.py`` measures the engine's speedup against the
  execution model it replaced (this one);
* it doubles as a differential-testing oracle for the engine's protocol
  logic (same selector, same pivot rule, float64 host arithmetic).

Production code paths must use :mod:`repro.engine` — do not import this from
``src/``.
"""

from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.core import classifiers as clf
from repro.core import geometry as geo
from repro.core.comm import Node, make_nodes
from repro.core.protocols.one_way import ProtocolResult
from repro.core.protocols.two_way import (
    _pick_median_direction,
    _risk_matrix,
    _support_along,
    _transcript,
)


def _extremes_along(node: Node, v: np.ndarray, Wx, Wy):
    """Node's extreme band points along v over (own ∪ transcript)."""
    X = np.concatenate([node.X, Wx])
    y = np.concatenate([node.y, Wy])
    proj = X @ v
    pos = y == 1
    p = X[int(np.argmax(np.where(pos, proj, -np.inf)))] if pos.any() else None
    q = X[int(np.argmin(np.where(~pos, proj, np.inf)))] if (~pos).any() else None
    return p, q


def kparty_median_hostloop(
    shards,
    eps: float = 0.05,
    max_epochs: int = 48,
    n_angles: int = 1024,
) -> ProtocolResult:
    """The pre-refactor per-instance execution path (host Python round loop)."""
    nodes, log = make_nodes(shards)
    k = len(nodes)
    n_total = sum(nd.n for nd in nodes)
    budget = int(np.floor(eps * n_total))

    V = np.asarray(geo.direction_grid(n_angles))
    dir_ok = np.ones(n_angles, dtype=bool)
    sent = {nd.name: ([], []) for nd in nodes}

    h: Optional[clf.LinearSeparator] = None
    for epoch in range(max_epochs):
        for ci in range(k):
            log.new_round()
            coord = nodes[ci]
            others = [nd for nd in nodes if nd is not coord]

            # --- coordinator: median direction of its SOU + support band ----
            Wx_c, Wy_c = _transcript(coord, *sent[coord.name])
            risk = _risk_matrix(coord, V, dir_ok, Wx_c, Wy_c)
            v_idx = _pick_median_direction(risk, dir_ok)
            v = V[v_idx]
            S_X, S_y, lo_c, hi_c = _support_along(coord, v, Wx_c, Wy_c)
            for nd in others:
                coord.send_points(nd, S_X, S_y, tag="kparty-support")
                coord.send_scalars(nd, np.concatenate([v, [lo_c, hi_c]]),
                                   tag="kparty-direction")
            sent[coord.name][0].extend(list(S_X))
            sent[coord.name][1].extend(list(S_y))

            # --- ε-early-exit: try the coordinator's band midpoint ----------
            if np.isfinite(lo_c) and np.isfinite(hi_c) and lo_c < hi_c:
                cand = clf.LinearSeparator(-v, 0.5 * (lo_c + hi_c))
                err_tot = 0
                for nd in nodes:
                    e = int(round(cand.error(nd.X, nd.y) * nd.n))
                    err_tot += e
                    if nd is not coord:
                        nd.send_scalars(coord, np.asarray([float(e)]),
                                        tag="kparty-err")
                if err_tot <= budget:
                    return ProtocolResult(cand, log.summary(),
                                          rounds=epoch + 1, converged=True)
                h = cand

            # --- replies: extreme band points along v (2 points each) -------
            best_p, best_q = None, None
            lo_g, hi_g = -np.inf, np.inf
            for nd in nodes:
                if nd is coord:
                    Wx_d, Wy_d = Wx_c, Wy_c
                else:
                    Wx_d, Wy_d = _transcript(nd, *sent[nd.name])
                p, q = _extremes_along(nd, v, Wx_d, Wy_d)
                pts, labs = [], []
                if p is not None:
                    if p @ v > lo_g:
                        lo_g, best_p = p @ v, p
                    pts.append(p); labs.append(1)
                if q is not None:
                    if q @ v < hi_g:
                        hi_g, best_q = q @ v, q
                    pts.append(q); labs.append(-1)
                if nd is not coord and pts:
                    nd.send_points(coord, np.stack(pts),
                                   np.asarray(labs, np.int32),
                                   tag="kparty-extremes")
                    sent[nd.name][0].extend(pts)
                    sent[nd.name][1].extend(labs)

            if lo_g < hi_g:
                if not np.isfinite(lo_g):      # no positives at all
                    lo_g = hi_g - 2.0
                if not np.isfinite(hi_g):      # no negatives at all
                    hi_g = lo_g + 2.0
                t_star = 0.5 * (lo_g + hi_g)
                cand = clf.LinearSeparator(-v, t_star)
                for nd in others:
                    nd.send_bit(coord, 1, tag="kparty-accept")
                return ProtocolResult(cand, log.summary(), rounds=epoch + 1,
                                      converged=True)

            # --- empty band: certified pivot prune (paper Fig. 2 right) -----
            constraint = V @ (best_q - best_p)
            new_ok = dir_ok & (constraint > 1e-12)
            for nd in others:
                coord.send_points(nd, np.stack([best_p, best_q]),
                                  np.asarray([1, -1], np.int32),
                                  tag="kparty-pivot")
            sent[coord.name][0].extend([best_p, best_q])
            sent[coord.name][1].extend([1, -1])
            if new_ok.any():
                dir_ok = new_ok
            if h is None:
                t_fb = 0.5 * (lo_c + hi_c) if (np.isfinite(lo_c) and
                                               np.isfinite(hi_c)) else 0.0
                h = clf.LinearSeparator(-v, t_fb)
    return ProtocolResult(h, log.summary(), rounds=max_epochs, converged=False)
