"""Roofline table from the dry-run records (EXPERIMENTS.md §Roofline source).

Reads benchmarks/results/dryrun.jsonl (written by repro.launch.dryrun),
prints the per-(arch × shape × mesh) three-term roofline with the dominant
bottleneck, the MODEL_FLOPS/HLO_FLOPs useful-compute ratio, and per-case
one-line "what would move the dominant term" notes.
"""

from __future__ import annotations

import json
import os
from collections import defaultdict
from typing import Dict, List

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun.jsonl")

# what would move the dominant term down, by (dominant, kind)
_NOTES = {
    ("memory", "train"): "raise arithmetic intensity: larger microbatch per device, bf16 master-less optimizer, fuse norms",
    ("memory", "prefill"): "KV/MLA cache layout + flash tiling (less HBM re-traffic)",
    ("memory", "decode"): "decode is weight-streaming-bound: quantize/shrink weights per chip or batch more requests",
    ("compute", "train"): "near roofline: only model/pipeline rebalance or kernel fusion helps",
    ("compute", "prefill"): "near roofline: attention kernel fusion (flash) to cut redundant FLOPs",
    ("compute", "decode"): "batch more requests per chip",
    ("collective", "train"): "shard differently: move all-reduce to reduce-scatter+all-gather (ZeRO), overlap with compute",
    ("collective", "prefill"): "cut tensor-parallel gathers: wider data axis, narrower model axis",
    ("collective", "decode"): "decode all-gathers dominate: replicate small weights, shrink model axis",
}


def load(mesh: str = "single") -> List[Dict]:
    recs = []
    with open(RESULTS) as f:
        for line in f:
            r = json.loads(line)
            if r.get("mesh") == mesh:
                recs.append(r)
    return recs


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}µs"


def kind_of(shape: str) -> str:
    return {"train_4k": "train", "prefill_32k": "prefill",
            "decode_32k": "decode", "long_500k": "decode"}[shape]


def main(mesh: str = "single") -> List[str]:
    recs = load(mesh)
    csv: List[str] = []
    rows = [f"### Roofline — {mesh} mesh ({'512' if mesh == 'multi' else '256'} chips)",
            "| arch | shape | compute | memory | collective | dominant | useful | note |",
            "|---|---|---|---|---|---|---|---|"]
    dom_count = defaultdict(int)
    for r in recs:
        a, s = r["arch"], r["shape"]
        if r["status"] == "skipped":
            rows.append(f"| {a} | {s} | — | — | — | skipped | — | {r['reason'][:60]} |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {a} | {s} | — | — | — | ERROR | — | {r['error'][:60]} |")
            continue
        rf = r["roofline"]
        dom = rf["dominant"]
        dom_count[dom] += 1
        useful = rf.get("useful_ratio")
        note = _NOTES.get((dom, kind_of(s)), "")
        rows.append(
            f"| {a} | {s} | {fmt_s(rf['compute_s'])} | {fmt_s(rf['memory_s'])} | "
            f"{fmt_s(rf['collective_s'])} | **{dom}** | "
            f"{useful:.2f} | {note[:60]} |" if useful is not None else
            f"| {a} | {s} | {fmt_s(rf['compute_s'])} | {fmt_s(rf['memory_s'])} | "
            f"{fmt_s(rf['collective_s'])} | **{dom}** | — | {note[:60]} |")
        csv.append(f"roofline/{a}/{s}/{mesh},0,"
                   f"compute_s={rf['compute_s']:.4g};memory_s={rf['memory_s']:.4g};"
                   f"collective_s={rf['collective_s']:.4g};dominant={dom};"
                   f"useful={useful if useful is not None else ''}")
    rows.append("")
    rows.append(f"Dominant-term census: {dict(dom_count)}")
    print("\n".join(rows))
    return csv


if __name__ == "__main__":
    import sys
    main(sys.argv[1] if len(sys.argv) > 1 else "single")
