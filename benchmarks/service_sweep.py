"""Fault-tolerant streaming service benchmark (BENCH_service.json).

The ROADMAP's persistent-service north star, measured end to end: ≥256
protocol sessions *streamed* through the ring-buffer session pool
(``repro.engine.session_pool`` behind ``repro.serve.ProtocolService``'s
``SessionPool``), with slots freed by converged/evicted sessions refilled
from the pending queue between turns.  Three arms:

  chaos (warmup)   a seeded ``FaultSchedule`` with dropout, lost-message,
                   straggler and corruption rates **all nonzero** drives the
                   full workload once — this run compiles every pinned
                   (n_pad, width) dispatch key and is the correctness
                   source: statuses, retry/backoff counters, quarantines;
  chaos (steady)   the *identical* run again on the warm caches — its
                   wall-clock is the reported faulted throughput, and the
                   jit cache-size delta across every pool entry point is
                   the headline ``steady_state_recompiles`` (gated == 0 by
                   ``check_bench_schema.py``: admission refills slots at
                   pinned cache keys, so a saturated pool never recompiles);
  fault-free       the same workload with the zero-probability schedule —
                   baseline throughput, and every result is checked
                   **bit-exact** against an ``engine.run_instances`` oracle.

The bit-exactness gate (``oracle_mismatches``, gated empty): every session
the chaos run reports as cleanly finished (converged / budget-exhausted)
must match the fault-free pool oracle bit for bit — separator, convergence,
rounds and metered comm — because transient faults only ever *delay* a
session's turns, never change what they compute, and the pool dispatches
every turn at ONE pinned compile key, so batch composition cannot leak
into results (DESIGN.md §session pool & failure model).  Quarantined
sessions are exactly the corrupted ones and carry no result.  The same
gate also holds every fault-free pool session to decision- and comm-exact
parity against a sweep-path ``engine.run_instances`` oracle (separators
there may differ by f32 ulps across the two paths' compile keys — the
engine's own hot-vs-cold caveat; ``engine_bitwise`` counts how many match
bitwise anyway).  The two chaos arms must also agree with each other
(``determinism_ok``): the fault schedule is a pure hash, so same seed ⇒
same decisions.

PR 10 adds the ``mixed_traffic`` series: the same streamed-admission loop
with MEDIAN + MAXMARG + SAMPLING sessions interleaved through ONE
``PoolConfig(selector="unified")`` pool, measured against three
per-family pools serving the identical sessions (equal counts, warm
caches both sides).  Gated: the unified pool's steady run adds zero jit
cache entries, dispatches every mixed turn at exactly ONE pinned compile
key, and every session's result matches its per-family pool twin —
MEDIAN and SAMPLING bitwise, MAXMARG decision/comm-exact with separators
allclose (the two paths fit at different padded transcript widths, the
engine's own unified-vs-per-selector caveat; ``bitwise`` counts how many
match bitwise anyway).

Usage:
  python benchmarks/service_sweep.py            # full size, BENCH_service.json
  python benchmarks/service_sweep.py --tiny     # CI chaos-smoke sizes,
                                                # BENCH_service.tiny.json
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List, Tuple

import numpy as np

from repro.engine import hotloop, median, run_instances, session_pool, unified
from repro.engine.faults import FaultSchedule
from repro.engine.session_pool import PoolConfig, SessionPool
from repro.engine.state import ProtocolInstance

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT = os.path.join(ROOT, "BENCH_service.json")

NOTES = (
    "Streamed session-pool service benchmark: chaos arm (dropout + lost "
    "message + straggler + corruption all nonzero, seeded) vs fault-free "
    "arm over the same workload.  Wall-clocks are machine-local and not "
    "gated; the gates are steady_state_recompiles == 0 (second identical "
    "chaos run adds zero jit cache entries across every pool entry point) "
    "and oracle_mismatches == [] (every cleanly-finished chaos session is "
    "bit-exact vs the fault-free pool oracle — guaranteed by the pool's "
    "single pinned dispatch key — and every fault-free session is "
    "decision- and comm-exact vs the engine.run_instances sweep oracle, "
    "whose differently-keyed compiles may move separator floats by ulps; "
    "engine_bitwise counts how many match bitwise anyway).  The "
    "mixed_traffic series streams interleaved MEDIAN+MAXMARG+SAMPLING "
    "sessions through ONE unified pool vs three per-family pools at equal "
    "session counts (warm caches both sides); gated on zero steady-state "
    "recompiles, exactly one pinned dispatch key, and empty mismatches "
    "(MEDIAN/SAMPLING bitwise, MAXMARG decision/comm-exact).  Produced by "
    "benchmarks/service_sweep.py; schema-gated by check_bench_schema.py."
)

# the chaos schedule: every channel nonzero (CI asserts the stats show
# every channel actually fired at full size)
CHAOS = dict(seed=11, p_dropout=0.05, p_drop_msg=0.03, p_straggle=0.06,
             p_corrupt=0.01, straggle_max=3)


def build_workload(n_sessions: int, k: int, n_pad: int,
                   seed: int = 0) -> List[List[Tuple[np.ndarray, np.ndarray]]]:
    """Separable 2-D instances, every shard exactly n_pad real points (no
    label-0 padding anywhere, so the pool and the oracle see byte-identical
    data and error budgets)."""
    rng = np.random.default_rng(seed)
    workload = []
    for _ in range(n_sessions):
        w = rng.normal(size=2)
        w /= np.linalg.norm(w)
        shards = []
        for _ in range(k):
            X = rng.normal(size=(n_pad, 2))
            yy = np.where(X @ w > 0, 1, -1).astype(np.int32)
            shards.append((X.astype(np.float32), yy))
        workload.append(shards)
    return workload


def run_streaming(pool: SessionPool, workload, low_water: int) -> float:
    """Stream the workload through the pool — submissions trickle in as the
    pending queue drains below ``low_water``, so admission interleaves with
    live mid-epoch sessions (the mixed-phase case).  Returns wall seconds."""
    it = iter(workload)
    exhausted = False
    t0 = time.perf_counter()
    guard = 0
    while True:
        while not exhausted and len(pool.pending) < low_water:
            try:
                pool.submit(next(it))
            except StopIteration:
                exhausted = True
                break
        if exhausted and pool.drained():
            break
        pool.step_pool()
        guard += 1
        if guard > 100_000:
            raise RuntimeError("service benchmark failed to drain")
    return time.perf_counter() - t0


def _pool_cache_entries() -> int:
    """Total jit cache entries across every entry point a MEDIAN pool turn
    can hit (dispatch, admission scatter, corruption, supervision view,
    eviction mark)."""
    fns = (median._hot_turn, session_pool._admit_rows,
           session_pool._corrupt_median, session_pool._view_median,
           session_pool._mark_done)
    return sum(f._cache_size() for f in fns)


def _unified_cache_entries() -> int:
    """Same census for a unified pool turn — ``_corrupt_unified`` /
    ``_view_unified`` are aliases of the maxmarg jits (jit re-keys on the
    pytree structure, and UnifiedState shares the leaf names they touch),
    so counting the alias targets counts them."""
    fns = (unified._hot_turn, session_pool._admit_rows,
           session_pool._corrupt_maxmarg, session_pool._view_maxmarg,
           session_pool._mark_done)
    return sum(f._cache_size() for f in fns)


MIXED_SELECTORS = ("median", "maxmarg", "sampling")


def build_mixed_workload(n_sessions: int, k: int, n_pad: int,
                         seed: int = 1000) -> List[dict]:
    """The mixed-traffic workload: the same separable 2-D instances, with
    the three protocol families interleaved round-robin and a per-session
    seed (feeds the SAMPLING reservoir chain; both arms use the same one,
    so SAMPLING results are bitwise-comparable)."""
    base = build_workload(n_sessions, k, n_pad, seed=seed)
    return [{"shards": s, "selector": MIXED_SELECTORS[i % 3], "seed": i}
            for i, s in enumerate(base)]


def run_streaming_mixed(pool: SessionPool, entries: List[dict],
                        low_water: int) -> float:
    """``run_streaming`` for per-session selector/seed submissions."""
    it = iter(entries)
    exhausted = False
    t0 = time.perf_counter()
    guard = 0
    while True:
        while not exhausted and len(pool.pending) < low_water:
            try:
                e = next(it)
            except StopIteration:
                exhausted = True
                break
            pool.submit(e["shards"], selector=e["selector"], seed=e["seed"])
        if exhausted and pool.drained():
            break
        pool.step_pool()
        guard += 1
        if guard > 100_000:
            raise RuntimeError("mixed service benchmark failed to drain")
    return time.perf_counter() - t0


def mixed_traffic_series(tiny: bool) -> Tuple[List[str], dict]:
    """One unified pool carrying interleaved MEDIAN/MAXMARG/SAMPLING
    sessions vs three per-family pools carrying the identical sessions.
    Both arms are timed on warm caches; the unified arm's warm run must
    add zero jit cache entries and dispatch at exactly one pinned key."""
    if tiny:
        sessions, slots, n_pad, n_angles, max_epochs = 12, 4, 16, 64, 8
    else:
        sessions, slots, n_pad, n_angles, max_epochs = 48, 12, 32, 128, 8
    k = 2
    low_water = max(2, slots // 2)
    entries = build_mixed_workload(sessions, k, n_pad)
    ucfg = PoolConfig(slots=slots, k=k, n_pad=n_pad, selector="unified",
                      n_angles=n_angles, max_epochs=max_epochs)
    lines = [f"mixed traffic: {sessions} sessions interleaved over "
             f"{MIXED_SELECTORS}, {slots} slots, one unified pool"]

    # -- unified arm: warmup compiles the ONE pinned key, warm run timed --
    run_streaming_mixed(SessionPool(ucfg), entries, low_water)
    entries0 = _unified_cache_entries()
    keys0 = len(hotloop.KEY_LOG)
    pool_u = SessionPool(ucfg)
    unified_s = run_streaming_mixed(pool_u, entries, low_water)
    recompiles = _unified_cache_entries() - entries0
    keys = sorted(set(hotloop.KEY_LOG[keys0:]))
    lines.append(f"unified pool: {unified_s:.2f}s, {recompiles} steady "
                 f"recompiles over {len(keys)} distinct dispatch keys")

    # -- per-family baseline: three pools, same sessions, warm too --------
    # (no pinned SAMPLING pool exists; a unified pool fed only SAMPLING
    # sessions is that family's dedicated path)
    fam_entries = {sel: [e for e in entries if e["selector"] == sel]
                   for sel in MIXED_SELECTORS}
    fam_cfg = {
        "median": PoolConfig(slots=slots, k=k, n_pad=n_pad,
                             n_angles=n_angles, max_epochs=max_epochs),
        "maxmarg": PoolConfig(slots=slots, k=k, n_pad=n_pad,
                              selector="maxmarg", max_epochs=max_epochs),
        "sampling": ucfg,
    }
    fam_s: Dict[str, float] = {}
    fam_results: Dict[str, dict] = {}
    for sel in MIXED_SELECTORS:
        run_streaming_mixed(SessionPool(fam_cfg[sel]), fam_entries[sel],
                            low_water)
        p = SessionPool(fam_cfg[sel])
        fam_s[sel] = run_streaming_mixed(p, fam_entries[sel], low_water)
        fam_results[sel] = p.results
    per_family_total = sum(fam_s.values())
    lines.append("per-family pools: " + ", ".join(
        f"{sel} {fam_s[sel]:.2f}s" for sel in MIXED_SELECTORS)
        + f" (total {per_family_total:.2f}s)")

    # -- parity: every unified-pool session vs its per-family twin --------
    mismatches = []
    bitwise = 0
    checked = 0
    fam_pos = {sel: 0 for sel in MIXED_SELECTORS}
    for sid, e in enumerate(entries):
        sel = e["selector"]
        fid = fam_pos[sel]
        fam_pos[sel] += 1
        r, o = pool_u.results[sid], fam_results[sel][fid]
        checked += 1
        wr = np.asarray(r.classifier.w)
        wo = np.asarray(o.classifier.w)
        decisions = (r.converged == o.converged and r.rounds == o.rounds
                     and r.comm == o.comm)
        exact = (decisions and np.array_equal(wr, wo)
                 and float(r.classifier.b) == float(o.classifier.b))
        if exact:
            bitwise += 1
        # MEDIAN is width-invariant bitwise; SAMPLING runs the identical
        # unified step both sides; MAXMARG fits at two transcript widths,
        # so its separators are held to allclose + decision/comm equality
        if sel == "maxmarg":
            ok = (decisions
                  and np.allclose(wr, wo, rtol=1e-5, atol=1e-6)
                  and np.isclose(float(r.classifier.b),
                                 float(o.classifier.b),
                                 rtol=1e-5, atol=1e-6))
        else:
            ok = exact
        if not ok:
            mismatches.append({"sid": sid, "selector": sel,
                               "arm": "unified_vs_per_family"})
    lines.append(f"mixed parity: {checked} sessions checked, "
                 f"{len(mismatches)} mismatches, {bitwise} bitwise")

    section = {
        "sessions": sessions,
        "slots": slots,
        "per_family_sessions": {sel: len(fam_entries[sel])
                                for sel in MIXED_SELECTORS},
        "unified_s": round(unified_s, 4),
        "per_family_s": {sel: round(fam_s[sel], 4)
                         for sel in MIXED_SELECTORS},
        "per_family_total_s": round(per_family_total, 4),
        "steady_state_recompiles": int(recompiles),
        "steady_state_dispatch_keys": [list(kk) for kk in keys],
        "checked": checked,
        "bitwise": bitwise,
        "mismatches": mismatches,
    }
    return lines, section


def _statuses(pool: SessionPool) -> Dict[str, int]:
    out = {"converged": 0, "budget_exhausted": 0, "quarantined": 0}
    for rec in pool.sessions.values():
        out[rec["status"]] += 1
    return out


def main(tiny: bool = False) -> List[str]:
    if tiny:
        sessions, slots, n_pad, n_angles, max_epochs = 24, 8, 16, 64, 8
    else:
        sessions, slots, n_pad, n_angles, max_epochs = 256, 32, 32, 128, 8
    k = 2
    cfg = PoolConfig(slots=slots, k=k, n_pad=n_pad, n_angles=n_angles,
                     max_epochs=max_epochs)
    chaos = FaultSchedule(**CHAOS)
    workload = build_workload(sessions, k, n_pad)
    low_water = max(2, slots // 2)

    lines = [f"service sweep: {sessions} sessions, {slots} slots, "
             f"k={k}, n_pad={n_pad}, selector=median"]

    # -- arm 1: chaos warmup (compiles every pinned key; correctness arm) --
    pool_a = SessionPool(cfg, chaos)
    run_streaming(pool_a, workload, low_water)
    stat_a = _statuses(pool_a)
    lines.append(f"chaos warmup: {stat_a}  stats={pool_a.stats}")

    # -- arm 2: identical chaos run on warm caches ------------------------
    entries0 = _pool_cache_entries()
    keys0 = len(hotloop.KEY_LOG)
    pool_b = SessionPool(cfg, chaos)
    faulted_s = run_streaming(pool_b, workload, low_water)
    steady_recompiles = _pool_cache_entries() - entries0
    steady_keys = sorted(set(hotloop.KEY_LOG[keys0:]))
    stat_b = _statuses(pool_b)
    determinism_ok = (
        stat_a == stat_b
        and pool_a.stats == pool_b.stats
        and all(pool_a.sessions[s] == pool_b.sessions[s]
                for s in pool_a.sessions))
    lines.append(f"chaos steady: {faulted_s:.2f}s, "
                 f"{steady_recompiles} recompiles over "
                 f"{len(steady_keys)} distinct dispatch keys, "
                 f"determinism_ok={determinism_ok}")

    # -- arm 3: fault-free baseline (warm too) ----------------------------
    pool_f = SessionPool(cfg)
    fault_free_s = run_streaming(pool_f, workload, low_water)
    lines.append(f"fault-free:   {fault_free_s:.2f}s, "
                 f"{_statuses(pool_f)}")

    # -- bit-exactness: chaos survivors vs the fault-free pool oracle -----
    mismatches = []
    checked = 0
    for sid in range(sessions):
        if pool_b.sessions[sid]["status"] not in ("converged",
                                                  "budget_exhausted"):
            continue
        r, o = pool_b.results[sid], pool_f.results[sid]
        checked += 1
        exact = (np.array_equal(np.asarray(r.classifier.w),
                                np.asarray(o.classifier.w))
                 and float(r.classifier.b) == float(o.classifier.b)
                 and r.converged == o.converged
                 and r.rounds == o.rounds
                 and r.comm == o.comm)
        if not exact:
            mismatches.append({"sid": sid, "arm": "chaos_vs_fault_free"})

    # -- engine cross-check: decision/comm parity vs run_instances --------
    insts = [ProtocolInstance(shards=s, eps=cfg.eps) for s in workload]
    oracle = run_instances(insts, n_angles=n_angles, max_epochs=max_epochs)
    engine_bitwise = 0
    for sid in range(sessions):
        r, o = pool_f.results[sid], oracle[sid]
        checked += 1
        if not (r.converged == o.converged and r.rounds == o.rounds
                and r.comm == o.comm
                and np.allclose(np.asarray(r.classifier.w),
                                np.asarray(o.classifier.w),
                                rtol=1e-5, atol=1e-6)
                and np.isclose(float(r.classifier.b),
                               float(o.classifier.b),
                               rtol=1e-5, atol=1e-6)):
            mismatches.append({"sid": sid, "arm": "fault_free_vs_engine"})
        elif (np.array_equal(np.asarray(r.classifier.w),
                             np.asarray(o.classifier.w))
              and float(r.classifier.b) == float(o.classifier.b)):
            engine_bitwise += 1
    lines.append(f"oracle: {checked} comparisons, "
                 f"{len(mismatches)} mismatches, "
                 f"{engine_bitwise}/{sessions} engine-bitwise")

    # -- mixed-traffic series: one unified pool vs three per-family pools -
    mixed_lines, mixed = mixed_traffic_series(tiny)
    lines += mixed_lines

    report = {
        "notes": NOTES,
        "tiny": tiny,
        "sessions": sessions,
        "slots": slots,
        "k": k,
        "n_pad": n_pad,
        "selector": cfg.selector,
        "n_angles": n_angles,
        "max_epochs": max_epochs,
        "schedule": chaos.to_json(),
        "statuses": stat_b,
        "stats": {kk: v for kk, v in pool_b.stats.items()
                  if isinstance(v, (int, float))},
        "fault_free_s": round(fault_free_s, 4),
        "faulted_s": round(faulted_s, 4),
        "sessions_per_s_fault_free": round(sessions / fault_free_s, 2),
        "sessions_per_s_faulted": round(sessions / faulted_s, 2),
        "steady_state_recompiles": int(steady_recompiles),
        "steady_state_dispatch_keys": [list(kk) for kk in steady_keys],
        "determinism_ok": bool(determinism_ok),
        "engine_bitwise": engine_bitwise,
        "oracle_checked": checked,
        "oracle_mismatches": mismatches,
        "mixed_traffic": mixed,
    }
    out = OUT.replace(".json", ".tiny.json") if tiny else OUT
    with open(out, "w") as f:
        json.dump(report, f, indent=1)
    lines.append(f"wrote {out}")
    return lines


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI chaos-smoke sizes (24 sessions, 8 slots)")
    args = ap.parse_args()
    for line in main(tiny=args.tiny):
        print(line)
