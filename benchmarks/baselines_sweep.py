"""Batched engine one-way/baselines vs the retired host loops
(BENCH_baselines.json).

Counterpart of ``engine_sweep.py`` / ``maxmarg_sweep.py`` for the third
compiled path: a paper-style grid (selector × dataset × ε × seed over the
one-way families RANDOM/NAIVE/VOTING/MIXING) runs three ways:

  sequential  the pre-engine execution model — host-side Python chains with
              one ``fit_max_margin`` dispatch per fit (k fits per VOTING or
              MIXING instance; benchmarks/legacy_oneway.py);
  engine B=1  the public per-instance APIs (engine at B=1) in a Python loop;
  batched     one ``engine.run_sweep`` call over the whole grid — bucketed
              per selector, each bucket one compiled dispatch (the VOTING
              and MIXING buckets fold all B·k local fits into a single
              batched Pegasos solve).

It asserts exact comm/rounds parity between the batched sweep and the
engine's B=1 path, cross-checks the legacy host loops as differential
oracles, and records the **one-way-vs-two-way communication gap** — the
paper's headline claim (§1, Tables 2–4): for each dataset × ε scenario a
*mixed* ``run_sweep`` call dispatches NAIVE + RANDOM + MEDIAN + MAXMARG
instances together and reports their measured comm costs side by side.
``--tiny`` shrinks the grid for the CI smoke job and writes
BENCH_baselines.tiny.json instead.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import List

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro import engine
from repro.core import datasets
from repro.core.protocols import baselines, one_way

from benchmarks.legacy_oneway import HOSTLOOPS

SELECTORS = ("sampling", "naive", "voting", "mixing")
# selectors with an ε guarantee (Thm 3.1 RANDOM; NAIVE is the central fit) —
# VOTING/MIXING are the paper's *failure* baselines on adversarial
# partitions, so their error is reported, never gated
GATED = ("sampling", "naive")
MAX_EPOCHS = 8    # two-way budget in the mixed gap sweep
OUT = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "BENCH_baselines.json")


def build_instances(n_per_node: int = 128,
                    seeds=(0, 1)) -> List[engine.ProtocolInstance]:
    """One-way grid: 4 selectors × 3 datasets × 2 ε × seeds."""
    insts = []
    for sel in SELECTORS:
        for gen in (datasets.data1, datasets.data2, datasets.data3):
            for eps in (0.1, 0.05):
                for seed in seeds:
                    insts.append(engine.ProtocolInstance(
                        gen(n_per_node=n_per_node, k=2, seed=seed), eps,
                        sel, seed))
    return insts


def _run_hostloop(insts):
    return [HOSTLOOPS[inst.selector](inst.shards, inst.eps, inst.seed)
            for inst in insts]


def _run_engine_b1(insts):
    api = {
        "sampling": lambda i: one_way.random_sampling(i.shards, eps=i.eps,
                                                      seed=i.seed),
        "naive": lambda i: baselines.naive(i.shards),
        "voting": lambda i: baselines.voting(i.shards),
        "mixing": lambda i: baselines.mixing(i.shards),
    }
    return [api[inst.selector](inst) for inst in insts]


def _run_batched(insts):
    return engine.run_sweep(insts)


def _gap_sweep(n_per_node: int) -> List[dict]:
    """The headline series: one *mixed* run_sweep call per the acceptance
    bar — one-way, MEDIAN, and MAXMARG instances in one dispatch — and the
    measured comm-cost gap per scenario."""
    scenarios = []
    insts = []
    for name, gen in (("data1", datasets.data1), ("data2", datasets.data2),
                      ("data3", datasets.data3)):
        for eps in (0.1, 0.05):
            shards = gen(n_per_node=n_per_node, k=2, seed=0)
            scenarios.append((name, eps))
            insts += [
                engine.ProtocolInstance(shards, eps, "naive"),
                engine.ProtocolInstance(shards, eps, "sampling", 0),
                engine.ProtocolInstance(shards, eps, "median"),
                engine.ProtocolInstance(shards, eps, "maxmarg"),
            ]
    out = engine.run_sweep(insts, max_epochs=MAX_EPOCHS)
    series = []
    for si, (name, eps) in enumerate(scenarios):
        rn, rs, rmed, rmm = out[4 * si:4 * si + 4]
        series.append({
            "dataset": name,
            "eps": eps,
            "naive_points": rn.comm["points"],
            "sampling_points": rs.comm["points"],
            "median_points": rmed.comm["points"],
            "maxmarg_points": rmm.comm["points"],
            "naive_over_maxmarg": round(
                rn.comm["points"] / max(rmm.comm["points"], 1), 2),
            "naive_over_median": round(
                rn.comm["points"] / max(rmed.comm["points"], 1), 2),
        })
    return series


def main(tiny: bool = False) -> List[str]:
    insts = build_instances(n_per_node=40, seeds=(0,)) if tiny \
        else build_instances()
    B = len(insts)

    # warm every selector's program shapes (the grid is multi-selector, so
    # warming one instance would leave three selectors compiling inside the
    # timed region) and the host solver cache, then time
    _run_batched(insts)
    _run_engine_b1(insts)
    _run_hostloop(insts)

    repeats = 1 if tiny else 3

    def timed(fn):
        times = []
        for _ in range(repeats):
            t0 = time.time()
            out = fn(insts)
            times.append(time.time() - t0)
        return out, float(np.median(times))

    seq, t_seq = timed(_run_hostloop)
    b1, t_b1 = timed(_run_engine_b1)
    bat, t_bat = timed(_run_batched)

    mismatches = []          # engine batched vs engine B=1 — must be exact
    legacy_disagree = []     # retired host loops — differential oracles
    per_instance = []
    for i, (inst, rs, r1, rb) in enumerate(zip(insts, seq, b1, bat)):
        X = np.concatenate([s[0] for s in inst.shards])
        y = np.concatenate([s[1] for s in inst.shards])
        err = float(np.mean(rb.classifier.predict(X) != y))
        ok = (r1.converged == rb.converged and r1.comm == rb.comm
              and r1.rounds == rb.rounds)
        if not ok:
            mismatches.append(i)
        if not (rs.converged == rb.converged and rs.comm == rb.comm
                and rs.rounds == rb.rounds):
            legacy_disagree.append(i)
        per_instance.append({
            "selector": inst.selector,
            "eps": inst.eps,
            "converged": bool(rb.converged),
            "rounds": rb.rounds,
            "points": rb.comm["points"],
            "bytes": rb.comm["bytes"],
            "global_err": err,
            "parity_b1": ok,
        })

    gated_ok = all(p["global_err"] <= p["eps"] for p in per_instance
                   if p["selector"] in GATED)
    gap = _gap_sweep(n_per_node=40 if tiny else 128)

    speedup = t_seq / max(t_bat, 1e-9)
    report = {
        "notes": (
            "sequential_s = the retired per-instance execution model for the "
            "one-way/baseline families (host-side Python chains, one "
            "fit_max_margin dispatch per fit; benchmarks/legacy_oneway.py). "
            " batched_s = one engine.run_sweep call bucketed per selector: "
            "the RANDOM reservoir chain is a lax.scan, and all VOTING/"
            "MIXING local fits run as a single batched Pegasos solve.  "
            "engine_b1_loop_s = the public per-instance APIs (engine at "
            "B=1) in a Python loop.  legacy_oracle_disagreements lists "
            "instances whose comm dicts / rounds / convergence differ from "
            "the host loops — acceptance bar is an empty list.  "
            "oneway_vs_twoway is the paper's headline gap: per scenario, "
            "one mixed run_sweep dispatch of NAIVE+RANDOM+MEDIAN+MAXMARG "
            "and their measured comm costs.  Error is gated only for the "
            "selectors with an ε guarantee (RANDOM, NAIVE); VOTING/MIXING "
            "are the paper's failure baselines.  Timings are medians of "
            "repeats on a warm cache."),
        "instances": B,
        "tiny": tiny,
        "sequential_s": round(t_seq, 4),
        "batched_s": round(t_bat, 4),
        "speedup": round(speedup, 2),
        "engine_b1_loop_s": round(t_b1, 4),
        "speedup_vs_engine_b1": round(t_b1 / max(t_bat, 1e-9), 2),
        "parity_b1_ok": not mismatches,
        "parity_b1_mismatch_indices": mismatches,
        "legacy_oracle_disagreements": legacy_disagree,
        "all_converged": all(p["converged"] for p in per_instance),
        "all_gated_err_within_eps": gated_ok,
        "oneway_vs_twoway": gap,
        "per_instance": per_instance,
    }
    out = OUT.replace(".json", ".tiny.json") if tiny else OUT
    with open(out, "w") as f:
        json.dump(report, f, indent=1)

    worst_gap = max(g["naive_over_maxmarg"] for g in gap)
    print(f"baselines sweep: {B} instances  sequential(host loops) "
          f"{t_seq:.2f}s  batched {t_bat:.2f}s  speedup {speedup:.1f}x  "
          f"B=1-parity={'OK' if not mismatches else mismatches}")
    print(f"(engine B=1 loop {t_b1:.2f}s; legacy-oracle disagreements: "
          f"{legacy_disagree or 'none'}; max naive/maxmarg comm gap "
          f"{worst_gap:.0f}x)")
    print(f"wrote {out}")
    return [f"baselines_sweep/batched,{t_bat * 1e6 / B:.0f},"
            f"speedup={speedup:.2f};instances={B}",
            f"baselines_sweep/sequential,{t_seq * 1e6 / B:.0f},"
            f"parity_b1={'ok' if not mismatches else 'FAIL'}"]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke sizes (small shards, 1 repeat)")
    main(tiny=ap.parse_args().tiny)
