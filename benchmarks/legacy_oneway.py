"""Pre-engine host-loop one-way/baseline protocols (benchmark + oracle).

These are RANDOM ε-net sampling (paper Thm 3.1/6.1) and the §7 baselines
exactly as they executed before the batched engine's one-way path landed:
host-side Python loops over metered ``repro.core.comm`` channels, one
``fit_max_margin`` device call per fit, numpy reservoir.  Kept for two
reasons only:

* ``benchmarks/baselines_sweep.py`` measures the engine's speedup against
  the execution model it replaced (this one);
* they double as differential-testing oracles for the engine's metering —
  ``tests/test_engine_oneway.py`` asserts identical comm dicts
  (points/scalars/bits/messages/rounds/bytes) and rounds across a grid.

The loops carry the PR's metering fixes (every protocol meters its rounds
via ``log.new_round()``; the shared ``sampling.EPSILON_NET_C`` ε-net
constant), so oracle and engine implement one contract.  Reservoir *contents*
are RNG-backend-specific (numpy here, ``jax.random`` on the engine) — comm
metering is capacity-determined and identical; classifier outputs agree only
distributionally.

Production code paths must use :mod:`repro.engine` — do not import this
from ``src/``.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core import classifiers as clf
from repro.core import sampling
from repro.core.comm import make_nodes
from repro.core.protocols.baselines import _MixedClassifier, _VotingClassifier
from repro.core.protocols.one_way import ProtocolResult


def random_sampling_hostloop(
    shards,
    eps: float,
    vc_dim: Optional[int] = None,
    seed: int = 0,
    c: float = sampling.EPSILON_NET_C,
) -> ProtocolResult:
    """The retired RANDOM chain: numpy reservoir down P_1 → … → P_k."""
    nodes, log = make_nodes(shards)
    d = nodes[0].d
    vc = vc_dim if vc_dim is not None else d + 1
    s_eps = sampling.epsilon_net_size(eps, vc, c=c)
    res = sampling.Reservoir(s_eps, d, np.random.default_rng(seed))
    for i, node in enumerate(nodes[:-1]):
        log.new_round()
        res.add_batch(node.X, node.y)
        RX, Ry = res.sample()
        node.send_points(nodes[i + 1], RX, Ry, tag="reservoir")
    last = nodes[-1]
    X = np.concatenate([last.X, last.recv_X])
    y = np.concatenate([last.y, last.recv_y])
    h = clf.fit_max_margin(X, y)
    return ProtocolResult(h, log.summary(), rounds=len(nodes) - 1,
                          converged=True, extra={"sample_size": s_eps})


def naive_hostloop(shards) -> ProtocolResult:
    nodes, log = make_nodes(shards)
    log.new_round()
    last = nodes[-1]
    for nd in nodes[:-1]:
        nd.send_points(last, nd.X, nd.y, tag="naive-all")
    X, y = last.all_known()
    h = clf.fit_max_margin(X, y)
    return ProtocolResult(h, log.summary(), rounds=1, converged=True)


def voting_hostloop(shards) -> ProtocolResult:
    nodes, log = make_nodes(shards)
    log.new_round()
    parts = [clf.fit_max_margin(nd.X, nd.y) for nd in nodes]
    last = nodes[-1]
    for nd in nodes[:-1]:
        nd.send_points(last, nd.X, nd.y, tag="voting-eval")
    return ProtocolResult(_VotingClassifier(parts), log.summary(), rounds=1,
                          converged=True)


def mixing_hostloop(shards) -> ProtocolResult:
    nodes, log = make_nodes(shards)
    log.new_round()
    last = nodes[-1]
    ws, bs = [], []
    for nd in nodes:
        h = clf.fit_max_margin(nd.X, nd.y)
        wn = h.w / (np.linalg.norm(h.w) + 1e-12)
        bn = h.b / (np.linalg.norm(h.w) + 1e-12)
        ws.append(wn)
        bs.append(bn)
        if nd is not last:
            nd.send_scalars(last, np.concatenate([wn, [bn]]),
                            tag="mixing-params")
    h = _MixedClassifier(np.mean(ws, axis=0), float(np.mean(bs)))
    return ProtocolResult(h, log.summary(), rounds=1, converged=True)


HOSTLOOPS = {
    "sampling": lambda inst_shards, eps, seed: random_sampling_hostloop(
        inst_shards, eps=eps, seed=seed),
    "naive": lambda inst_shards, eps, seed: naive_hostloop(inst_shards),
    "voting": lambda inst_shards, eps, seed: voting_hostloop(inst_shards),
    "mixing": lambda inst_shards, eps, seed: mixing_hostloop(inst_shards),
}
