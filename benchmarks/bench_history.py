"""Fold the per-PR BENCH_*.json headline numbers into BENCH_history.json.

Each PR's sweep benchmarks overwrite their BENCH_*.json acceptance records,
which loses the trajectory — whether `batched_s` kept improving or quietly
regressed across PRs.  This tool extracts the headline series from the
current BENCH artifacts and appends (or replaces, keyed by ``--label``) one
entry in a single cumulative BENCH_history.json, so speedups are *tracked*
across PRs instead of overwritten.

The history file's shape is a schema-gated contract
(``check_bench_schema.py BENCH_history.json``), like the per-PR artifacts.

Usage:
  python benchmarks/bench_history.py --label pr4
  python benchmarks/bench_history.py --label ci-smoke --tiny \
      --out BENCH_history.ci.json      # CI: smoke-size fold, never commits
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Optional

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# per-benchmark headline fields; optional fields are folded when present so
# the history survives schema growth (e.g. the PR 4 hot-path series)
HEADLINE = ("sequential_s", "batched_s", "speedup", "engine_b1_loop_s",
            "speedup_vs_engine_b1")
OPTIONAL = ("batched_cold_padded_s", "speedup_vs_cold_padded",
            "speedup_hot_vs_cold", "speedup_sharded_vs_hot")
BENCHES = ("engine", "maxmarg", "baselines", "kernels")

NOTES = (
    "Cumulative per-PR headline series folded from BENCH_engine.json / "
    "BENCH_maxmarg.json / BENCH_baselines.json / BENCH_kernels.json by "
    "benchmarks/bench_history.py.  One entry per label (latest fold wins); "
    "numbers are machine-local wall-clocks, so compare across entries only "
    "when they were folded on the same machine."
)


def _load_json(path: str, what: str) -> Dict:
    """Load a JSON artifact with a clear failure mode: a missing, truncated
    or non-object file exits with a one-line diagnosis, never a traceback
    (these artifacts are machine-written and a killed benchmark run leaves
    half-written files behind)."""
    try:
        with open(path) as f:
            data = json.load(f)
    except (json.JSONDecodeError, UnicodeDecodeError, OSError) as e:
        raise SystemExit(
            f"{path}: unreadable or truncated JSON ({e}) — the {what} is "
            f"corrupt; re-run the producing benchmark (or delete the file "
            f"to start a fresh history)")
    if not isinstance(data, dict):
        raise SystemExit(
            f"{path}: top level is {type(data).__name__}, wanted an object "
            f"— not a {what}")
    return data


def extract(path: str) -> Optional[Dict]:
    if not os.path.exists(path):
        return None
    report = _load_json(path, "BENCH artifact")
    out = {}
    for field in HEADLINE:
        if field in report:
            out[field] = report[field]
    for field in OPTIONAL:
        if field in report:
            out[field] = report[field]
    out["instances"] = report.get("instances")
    # the kernels artifact has no B=1 loop, so its parity anchor is its own
    # parity_clean flag (all three kernel mismatch lists empty)
    anchor = report.get("parity_b1_ok", report.get("parity_clean"))
    out["parity_ok"] = bool(
        anchor
        and not report.get("legacy_oracle_disagreements")
        and not report.get("warm_cold_mismatch_indices")
        and not report.get("hot_cold_mismatch_indices")
        and not report.get("sharded_mismatch_indices")
        and not report.get("per_node_mismatch_indices")
        and not report.get("parity_mismatch_indices")
        and not report.get("interpret_parity_mismatches")
        and not report.get("maxmarg_kernel_mismatch_indices"))
    return out


def fold(label: str, bench_dir: str, out_path: str,
         tiny: bool = False) -> Dict:
    suffix = ".tiny.json" if tiny else ".json"
    benches = {}
    for name in BENCHES:
        entry = extract(os.path.join(bench_dir, f"BENCH_{name}{suffix}"))
        if entry is not None:
            benches[name] = entry
    if not benches:
        raise SystemExit(f"no BENCH_*{suffix} artifacts found in {bench_dir}")

    history = {"notes": NOTES, "entries": []}
    if os.path.exists(out_path):
        history = _load_json(out_path, "history file")
        if not isinstance(history.get("entries", []), list):
            raise SystemExit(
                f"{out_path}: 'entries' is not a list — not a history file; "
                f"refusing to overwrite it")
    history["notes"] = NOTES
    entry = {"label": label, "tiny": tiny, "benches": benches}
    entries: List[Dict] = [e for e in history.get("entries", [])
                           if e.get("label") != label]
    entries.append(entry)
    history["entries"] = entries
    with open(out_path, "w") as f:
        json.dump(history, f, indent=1)
    return history


def main() -> int:
    ap = argparse.ArgumentParser(
        description="fold BENCH_*.json headlines into BENCH_history.json")
    ap.add_argument("--label", required=True,
                    help="entry key, e.g. pr4 (replaces an existing entry)")
    ap.add_argument("--dir", default=ROOT,
                    help="directory holding the BENCH_*.json artifacts")
    ap.add_argument("--out", default=os.path.join(ROOT, "BENCH_history.json"))
    ap.add_argument("--tiny", action="store_true",
                    help="fold the .tiny.json smoke artifacts instead")
    args = ap.parse_args()
    history = fold(args.label, args.dir, args.out, tiny=args.tiny)
    labels = [e["label"] for e in history["entries"]]
    print(f"{args.out}: {len(history['entries'])} entr"
          f"{'y' if len(labels) == 1 else 'ies'} ({', '.join(labels)})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
