"""Communication-vs-ε scaling (paper Table 1's rate claims, empirically).

Sweeps ε and k and reports measured cost in points for:
  RANDOM  (one-way ε-net)     — expected Θ((1/ε) log 1/ε)
  MEDIAN  (two-way)           — expected Θ(log 1/ε)        (Thm 5.1)
  k-party MEDIAN              — expected Θ(k² log 1/ε)     (Thm 6.3)
plus the 0-error constant-communication protocols (thresholds, intervals,
rectangles) as a function of k — expected Θ(k) (Thm 6.2).
"""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro import engine
from repro.core import datasets
from repro.core.protocols import baselines, kparty, one_way, two_way

EPS_GRID = (0.2, 0.1, 0.05, 0.025, 0.0125)


def eps_sweep() -> List[str]:
    csv, rows = [], []
    shards = datasets.data3(n_per_node=1000, k=2, seed=0)
    rows.append("| eps | RANDOM cost | MEDIAN cost | MEDIAN rounds |")
    rows.append("|---|---|---|---|")
    # the whole MEDIAN ε grid is one batched engine dispatch; time it warm
    # (compile excluded) — per-row MEDIAN time is the amortized share of the
    # shared dispatch, since a batched sweep has no per-instance wall-clock
    insts = [engine.ProtocolInstance(shards, eps) for eps in EPS_GRID]
    engine.run_instances(insts, n_angles=1024, max_epochs=32)  # warm/compile
    t0 = time.time()
    med = engine.run_instances(insts, n_angles=1024, max_epochs=32)
    t_med = (time.time() - t0) / len(EPS_GRID)
    for eps, mr in zip(EPS_GRID, med):
        t0 = time.time()
        rc = baselines.random(shards, eps=eps).comm["points"]
        mc = mr.comm["points"]
        rows.append(f"| {eps} | {rc} | {mc} | {mr.rounds} |")
        csv.append(f"comm_scaling/eps={eps},{(time.time() - t0 + t_med) * 1e6:.0f},"
                   f"random={rc};median={mc};rounds={mr.rounds}")
    print("\n".join(rows))
    return csv


def k_sweep() -> List[str]:
    csv, rows = [], []
    rows.append("| k | threshold cost | rectangle cost | kparty-median cost |")
    rows.append("|---|---|---|---|")
    for k in (2, 3, 4, 6, 8):
        t0 = time.time()
        tc = one_way.threshold_protocol(
            datasets.threshold_instance(n=100 * k, k=k, seed=0)).comm["points"]
        rc = one_way.rectangle_protocol(
            datasets.rectangle_instance(n=100 * k, k=k, d=3, seed=0)).comm["points"]
        mc = kparty.iterative_support_kparty(
            datasets.data2(n_per_node=100, k=k, seed=0), eps=0.05,
            selector="median").comm["points"]
        rows.append(f"| {k} | {tc} | {rc} | {mc} |")
        csv.append(f"comm_scaling/k={k},{(time.time() - t0) * 1e6:.0f},"
                   f"threshold={tc};rect={rc};kmedian={mc}")
    print("\n".join(rows))
    return csv


def main() -> List[str]:
    print("### ε sweep (Data3, 2-party)")
    csv = eps_sweep()
    print("\n### k sweep (0-error protocols + k-party median)")
    csv += k_sweep()
    return csv


if __name__ == "__main__":
    main()
