"""Batched engine vs sequential per-instance sweeps (BENCH_engine.json).

The paper's experiment grids are sweeps of independent protocol instances;
the engine runs a whole sweep as one compiled dispatch.  This benchmark runs
the same ≥32-instance grid (dataset × ε × seed, two-party MEDIAN) three
ways:

  sequential  the public per-instance API in a Python loop — one engine
              dispatch per instance (B=1), the pre-batching execution model;
  batched     one ``repro.engine`` sweep on the hot path (fill-capped
              transcript reads + batch compaction via the shared
              ``engine.hotloop`` — the default);
  cold        the same sweep on the cold padded ``run_compiled`` model (one
              while_loop dispatch at worst-case shapes — the pre-hot-path
              engine, and the in-file baseline for the ``hot_vs_cold``
              acceptance series).

It asserts exact parity (converged flags + comm totals) between the batched
sweep and the engine's B=1 path, **bit-exact** parity (including the final
separator) between hot and cold, cross-checks the legacy float64 host loop
as a differential oracle, and records wall-clocks to BENCH_engine.json at
the repo root.

A fourth series times the **sharded** hot loop (DESIGN.md §sharded hot
loop): the same engine sweep with its leading B axis split over a 1-D
("data",) mesh with donated state buffers and the double-buffered host loop
— against the unchanged single-device hot path on a wide grid with an
engineered convergence tail.  ``--devices N`` (script mode only) sets
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before jax
initializes; when imported (``benchmarks/run.py``) the series runs on
whatever devices the process already has.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List

# --devices must take effect before jax initializes, so script-mode argument
# parsing happens *above* the repro imports.  Importers (benchmarks/run.py)
# skip this block and call main() with the process's existing devices.
_ARGS = None
if __name__ == "__main__":
    _ap = argparse.ArgumentParser()
    _ap.add_argument("--tiny", action="store_true",
                     help="CI smoke sizes (small shards, 1 repeat)")
    _ap.add_argument("--devices", type=int, default=8,
                     help="fake host devices for the sharded series "
                          "(sets XLA_FLAGS before jax init; default 8)")
    _ARGS = _ap.parse_args()
    if _ARGS.devices > 1 and "jax" not in sys.modules:
        os.environ.setdefault(
            "XLA_FLAGS",
            f"--xla_force_host_platform_device_count={_ARGS.devices}")

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax

from repro import engine
from repro.core import datasets
from repro.core.protocols import kparty
from repro.launch.mesh import make_data_mesh

from benchmarks import _timing as timing
from benchmarks.legacy_median import kparty_median_hostloop

N_ANGLES = 1024
MAX_EPOCHS = 32
# sharded series: wide grid, coarser angle net, engineered long tail
SHARDED_B = 12288
SHARDED_B_TINY = 64
SHARDED_N_ANGLES = 256
SHARDED_MAX_EPOCHS = 24
OUT = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "BENCH_engine.json")


def build_instances(n_per_node: int = 1000,
                    seeds=(0, 1, 2)) -> List[engine.ProtocolInstance]:
    """Two-party MEDIAN instances: 3 datasets × 4 ε × seeds."""
    insts = []
    for gen in (datasets.data1, datasets.data2, datasets.data3):
        for eps in (0.2, 0.1, 0.05, 0.025):
            for seed in seeds:
                insts.append(engine.ProtocolInstance(
                    gen(n_per_node=n_per_node, k=2, seed=seed), eps))
    return insts


def build_sharded_instances(B: int, n_per_node: int = 24,
                            noisy_every: int = 24,
                            noise: float = 0.1) -> List[engine.ProtocolInstance]:
    """Wide MEDIAN grid with an engineered convergence tail.

    Separable instances converge in one round, so a uniform grid never
    exercises the compacted tail where donation and the double-buffered
    loop pay off.  Every ``noisy_every``-th instance gets label noise and a
    sub-resolution ε (mistake budget ⌊0.02·48⌋ = 0 → never converges), so
    the sweep runs to max_epochs on a shrinking live set — the shape the
    sharded path is built for.
    """
    gens = (datasets.data1, datasets.data2, datasets.data3)
    insts = []
    for i in range(B):
        shards = gens[i % 3](n_per_node=n_per_node, k=2, seed=i)
        if i % noisy_every == 0:
            shards = datasets.add_label_noise(shards, noise, seed=i)
            eps = 0.02
        else:
            eps = (0.1, 0.05)[i % 2]
        insts.append(engine.ProtocolInstance(shards, eps))
    return insts


def _run_hostloop(insts):
    """The sequential loop the engine replaced: one host-side Python round
    loop per instance, a device round-trip per round."""
    return [kparty_median_hostloop(inst.shards, eps=inst.eps,
                                   max_epochs=MAX_EPOCHS, n_angles=N_ANGLES)
            for inst in insts]


def _run_engine_b1(insts):
    """Per-instance public API (engine with B=1), in a Python loop."""
    return [kparty.iterative_support_kparty(
                inst.shards, eps=inst.eps, max_epochs=MAX_EPOCHS,
                n_angles=N_ANGLES, selector="median")
            for inst in insts]


def _run_batched(insts, compact=True):
    return engine.run_instances(insts, n_angles=N_ANGLES,
                                max_epochs=MAX_EPOCHS, compact=compact)


def main(tiny: bool = False, devices: int = 8) -> List[str]:
    insts = build_instances(n_per_node=50, seeds=(0,)) if tiny \
        else build_instances()
    B = len(insts)

    # warm up every engine program shape (hot + cold padded, full B and B=1)
    # so the steady-state sweep cost is measured, then time everything on
    # the shared interleaved harness (see benchmarks/_timing.py for the
    # min-of-repeats / median-of-round-ratios rationale).
    _run_batched(insts)
    _run_batched(insts, compact=False)
    _run_engine_b1(insts[:1])

    repeats = 1 if tiny else 7
    series = {
        "seq": lambda: _run_hostloop(insts),
        "b1": lambda: _run_engine_b1(insts),
        "bat": lambda: _run_batched(insts),                # hot (default)
        "cold": lambda: _run_batched(insts, compact=False),
    }
    out, times = timing.interleaved(series, repeats)
    seq, t_seq = out["seq"], timing.tmin(times, "seq")
    b1, t_b1 = out["b1"], timing.tmin(times, "b1")
    bat, t_bat = out["bat"], timing.tmin(times, "bat")
    cold, t_cold = out["cold"], timing.tmin(times, "cold")

    def ratio(num, den):
        return timing.ratio(times, num, den)

    mismatches = []          # engine batched vs engine B=1 — must be exact
    legacy_disagree = []     # float64 host loop — differential oracle
    hot_cold_bad = []        # hot vs cold padded — must be bit-exact
    per_instance = []
    for i, (inst, rs, r1, rb, rc) in enumerate(zip(insts, seq, b1, bat,
                                                   cold)):
        X = np.concatenate([s[0] for s in inst.shards])
        y = np.concatenate([s[1] for s in inst.shards])
        err = float(np.mean(rb.classifier.predict(X) != y))
        ok = (r1.converged == rb.converged and r1.comm == rb.comm
              and r1.rounds == rb.rounds)
        if not ok:
            mismatches.append(i)
        if not (rs.converged == rb.converged
                and rs.comm["points"] == rb.comm["points"]):
            legacy_disagree.append(i)
        if not (rc.converged == rb.converged and rc.comm == rb.comm
                and rc.rounds == rb.rounds
                and np.array_equal(rc.classifier.w, rb.classifier.w)
                and rc.classifier.b == rb.classifier.b):
            hot_cold_bad.append(i)
        per_instance.append({
            "eps": inst.eps,
            "converged": bool(rb.converged),
            "rounds": rb.rounds,
            "points": rb.comm["points"],
            "global_err": err,
            "err_within_eps": bool(err <= inst.eps),
            "parity_b1": ok,
        })

    # ---- sharded series: mesh dispatch vs single-device hot path --------
    n_dev = max(1, min(devices, len(jax.devices())))
    mesh = make_data_mesh(n_dev)
    sh_insts = build_sharded_instances(SHARDED_B_TINY if tiny else SHARDED_B)
    B_sh = len(sh_insts)

    def _run_hot_wide():
        return engine.run_instances(sh_insts, n_angles=SHARDED_N_ANGLES,
                                    max_epochs=SHARDED_MAX_EPOCHS)

    def _run_sharded():
        return engine.run_instances(sh_insts, n_angles=SHARDED_N_ANGLES,
                                    max_epochs=SHARDED_MAX_EPOCHS, mesh=mesh)

    _run_hot_wide()          # warm both program sets (the wide grid walks
    _run_sharded()           # ~dozens of width buckets — compile once here)
    out_sh, times_sh = timing.interleaved(
        {"hot_wide": _run_hot_wide, "sharded": _run_sharded},
        1 if tiny else 3)
    hot_wide, shd = out_sh["hot_wide"], out_sh["sharded"]
    t_hot_wide = timing.tmin(times_sh, "hot_wide")
    t_shd = timing.tmin(times_sh, "sharded")
    sharded_bad = []         # sharded vs hot — must be bit-exact
    for i, (a, b) in enumerate(zip(shd, hot_wide)):
        if not (a.converged == b.converged and a.comm == b.comm
                and a.rounds == b.rounds
                and np.array_equal(a.classifier.w, b.classifier.w)
                and a.classifier.b == b.classifier.b):
            sharded_bad.append(i)
    speedup_sharded = timing.ratio(times_sh, "hot_wide", "sharded")

    speedup = ratio("seq", "bat")
    speedup_hot_cold = ratio("cold", "bat")
    report = {
        "notes": (
            "sequential_s = the pre-engine per-instance execution model "
            "(host-side Python round loop, device round-trip per round; "
            "benchmarks/legacy_median.py).  batched_s = one repro.engine "
            "sweep on the hot path (fill-capped transcript reads + batch "
            "compaction on the shared engine.hotloop — the default).  "
            "hot_vs_cold replays the cold padded while_loop model "
            "(run_instances(compact=False), the pre-hot-path engine) "
            "against it on the same grid — speedup_hot_vs_cold is the hot "
            "path's acceptance number, and hot_cold_mismatch_indices (bar: "
            "empty) lists instances whose comm/rounds/convergence or exact "
            "final separator differ (the MEDIAN compactions must be "
            "bit-exact, not merely decision-exact).  engine_b1_loop_s = "
            "the public per-instance API (engine at B=1) in a Python loop "
            "— itself compiled end-to-end, so on a CPU-only host it "
            "already captures most of the engine win; the batch axis pays "
            "off where per-dispatch overhead dominates (accelerators, many "
            "small instances).  sharded = the same hot loop with the B "
            "axis split over a ('data',) mesh (donated buffers + "
            "double-buffered dispatch, the mesh defaults) vs the unchanged "
            "single-device hot path, on a wide grid whose every "
            "24th instance carries label noise and a sub-resolution eps so "
            "the sweep runs a long compacted tail; sharded_mismatch_indices "
            "(bar: empty) holds the same bit-exactness standard.  On a "
            "single-core host the sharded win is donation (no full-state "
            "copy per tail turn) + per-shard locality, not parallelism.  "
            "Timings are minima of interleaved repeats on a warm cache."),
        "instances": B,
        "tiny": tiny,
        "n_angles": N_ANGLES,
        "max_epochs": MAX_EPOCHS,
        "sequential_s": round(t_seq, 4),       # legacy host round loop
        "batched_s": round(t_bat, 4),          # one hot engine sweep
        "speedup": round(speedup, 2),
        "engine_b1_loop_s": round(t_b1, 4),    # per-instance engine loop
        "speedup_vs_engine_b1": round(ratio("b1", "bat"), 2),
        "hot_vs_cold": {
            "hot_s": round(t_bat, 4),
            "cold_s": round(t_cold, 4),        # padded while_loop model
            "speedup": round(speedup_hot_cold, 2),
        },
        "speedup_hot_vs_cold": round(speedup_hot_cold, 2),
        "hot_cold_mismatch_indices": hot_cold_bad,
        "sharded": {
            "instances": B_sh,
            "n_devices": n_dev,
            "n_angles": SHARDED_N_ANGLES,
            "max_epochs": SHARDED_MAX_EPOCHS,
            "hot_s": round(t_hot_wide, 4),     # single-device hot path
            "sharded_s": round(t_shd, 4),      # mesh dispatch
            "speedup": round(speedup_sharded, 2),
        },
        "speedup_sharded_vs_hot": round(speedup_sharded, 2),
        "sharded_mismatch_indices": sharded_bad,
        "parity_b1_ok": not mismatches,
        "parity_b1_mismatch_indices": mismatches,
        "legacy_oracle_disagreements": legacy_disagree,
        "all_converged": all(p["converged"] for p in per_instance),
        "all_err_within_eps": all(p["err_within_eps"] for p in per_instance),
        "per_instance": per_instance,
    }
    # --tiny must never clobber the committed full-size acceptance record
    out = OUT.replace(".json", ".tiny.json") if tiny else OUT
    with open(out, "w") as f:
        json.dump(report, f, indent=1)

    print(f"engine sweep: {B} instances  sequential(host loop) {t_seq:.2f}s  "
          f"batched(hot) {t_bat:.3f}s  cold-padded {t_cold:.3f}s  "
          f"hot-vs-cold {speedup_hot_cold:.2f}x  "
          f"B=1-parity={'OK' if not mismatches else mismatches}")
    print(f"(engine B=1 loop {t_b1:.2f}s; legacy-oracle disagreements: "
          f"{legacy_disagree or 'none'}; hot-cold mismatches: "
          f"{hot_cold_bad or 'none'})")
    print(f"sharded sweep: {B_sh} instances on {n_dev} device(s)  "
          f"hot {t_hot_wide:.2f}s  sharded {t_shd:.2f}s  "
          f"{speedup_sharded:.2f}x  mismatches: {sharded_bad or 'none'}")
    print(f"wrote {out}")
    return [f"engine_sweep/batched,{t_bat * 1e6 / B:.0f},"
            f"speedup={speedup:.2f};instances={B};"
            f"hot_vs_cold={speedup_hot_cold:.2f}",
            f"engine_sweep/sequential,{t_seq * 1e6 / B:.0f},"
            f"parity_b1={'ok' if not mismatches else 'FAIL'}",
            f"engine_sweep/sharded,{t_shd * 1e6 / B_sh:.0f},"
            f"speedup_vs_hot={speedup_sharded:.2f};devices={n_dev};"
            f"instances={B_sh}"]


if __name__ == "__main__":
    main(tiny=_ARGS.tiny, devices=_ARGS.devices)
