"""Batched engine vs sequential per-instance sweeps (BENCH_engine.json).

The paper's experiment grids are sweeps of independent protocol instances;
the engine runs a whole sweep as one compiled dispatch.  This benchmark runs
the same ≥32-instance grid (dataset × ε × seed, two-party MEDIAN) both ways:

  sequential  the public per-instance API in a Python loop — one engine
              dispatch per instance (B=1), the pre-batching execution model;
  batched     one ``repro.engine`` sweep with B = #instances.

It asserts exact parity (converged flags + comm totals) between the batched
sweep and the engine's B=1 path, cross-checks the legacy float64 host loop
as a differential oracle, and records wall-clocks to BENCH_engine.json at
the repo root.
"""

from __future__ import annotations

import json
import os
import sys
import time
from typing import List

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro import engine
from repro.core import datasets
from repro.core.protocols import kparty

from benchmarks.legacy_median import kparty_median_hostloop

N_ANGLES = 1024
MAX_EPOCHS = 32
OUT = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "BENCH_engine.json")


def build_instances(n_per_node: int = 1000) -> List[engine.ProtocolInstance]:
    """36 two-party MEDIAN instances: 3 datasets × 4 ε × 3 seeds."""
    insts = []
    for gen in (datasets.data1, datasets.data2, datasets.data3):
        for eps in (0.2, 0.1, 0.05, 0.025):
            for seed in (0, 1, 2):
                insts.append(engine.ProtocolInstance(
                    gen(n_per_node=n_per_node, k=2, seed=seed), eps))
    return insts


def _run_hostloop(insts):
    """The sequential loop the engine replaced: one host-side Python round
    loop per instance, a device round-trip per round."""
    return [kparty_median_hostloop(inst.shards, eps=inst.eps,
                                   max_epochs=MAX_EPOCHS, n_angles=N_ANGLES)
            for inst in insts]


def _run_engine_b1(insts):
    """Per-instance public API (engine with B=1), in a Python loop."""
    return [kparty.iterative_support_kparty(
                inst.shards, eps=inst.eps, max_epochs=MAX_EPOCHS,
                n_angles=N_ANGLES, selector="median")
            for inst in insts]


def _run_batched(insts):
    return engine.run_instances(insts, n_angles=N_ANGLES,
                                max_epochs=MAX_EPOCHS)


def main() -> List[str]:
    insts = build_instances()
    B = len(insts)

    # warm up both engine program shapes (full B and B=1) so the steady-state
    # sweep cost is measured, then time everything (median of repeats).
    _run_batched(insts)
    _run_engine_b1(insts[:1])

    def timed(fn, repeats=3):
        times = []
        for _ in range(repeats):
            t0 = time.time()
            out = fn(insts)
            times.append(time.time() - t0)
        return out, float(np.median(times))

    seq, t_seq = timed(_run_hostloop)
    b1, t_b1 = timed(_run_engine_b1)
    bat, t_bat = timed(_run_batched)

    mismatches = []          # engine batched vs engine B=1 — must be exact
    legacy_disagree = []     # float64 host loop — differential oracle
    per_instance = []
    for i, (inst, rs, r1, rb) in enumerate(zip(insts, seq, b1, bat)):
        X = np.concatenate([s[0] for s in inst.shards])
        y = np.concatenate([s[1] for s in inst.shards])
        err = float(np.mean(rb.classifier.predict(X) != y))
        ok = (r1.converged == rb.converged and r1.comm == rb.comm
              and r1.rounds == rb.rounds)
        if not ok:
            mismatches.append(i)
        if not (rs.converged == rb.converged
                and rs.comm["points"] == rb.comm["points"]):
            legacy_disagree.append(i)
        per_instance.append({
            "eps": inst.eps,
            "converged": bool(rb.converged),
            "rounds": rb.rounds,
            "points": rb.comm["points"],
            "global_err": err,
            "err_within_eps": bool(err <= inst.eps),
            "parity_b1": ok,
        })

    speedup = t_seq / max(t_bat, 1e-9)
    report = {
        "notes": (
            "sequential_s = the pre-engine per-instance execution model "
            "(host-side Python round loop, device round-trip per round; "
            "benchmarks/legacy_median.py).  batched_s = one repro.engine "
            "dispatch for the whole sweep.  engine_b1_loop_s = the public "
            "per-instance API (engine at B=1) in a Python loop — itself "
            "compiled end-to-end, so on a CPU-only host it already captures "
            "most of the engine win; the batch axis pays off where per-"
            "dispatch overhead dominates (accelerators, many small "
            "instances).  Timings are medians of repeats on a warm cache."),
        "instances": B,
        "n_angles": N_ANGLES,
        "max_epochs": MAX_EPOCHS,
        "sequential_s": round(t_seq, 4),       # legacy host round loop
        "batched_s": round(t_bat, 4),          # one engine dispatch
        "speedup": round(speedup, 2),
        "engine_b1_loop_s": round(t_b1, 4),    # per-instance engine loop
        "speedup_vs_engine_b1": round(t_b1 / max(t_bat, 1e-9), 2),
        "parity_b1_ok": not mismatches,
        "parity_b1_mismatch_indices": mismatches,
        "legacy_oracle_disagreements": legacy_disagree,
        "all_converged": all(p["converged"] for p in per_instance),
        "all_err_within_eps": all(p["err_within_eps"] for p in per_instance),
        "per_instance": per_instance,
    }
    with open(OUT, "w") as f:
        json.dump(report, f, indent=1)

    print(f"engine sweep: {B} instances  sequential(host loop) {t_seq:.2f}s  "
          f"batched {t_bat:.2f}s  speedup {speedup:.1f}x  "
          f"B=1-parity={'OK' if not mismatches else mismatches}")
    print(f"(engine B=1 loop {t_b1:.2f}s; legacy-oracle disagreements: "
          f"{legacy_disagree or 'none'})")
    print(f"wrote {OUT}")
    return [f"engine_sweep/batched,{t_bat * 1e6 / B:.0f},"
            f"speedup={speedup:.2f};instances={B}",
            f"engine_sweep/sequential,{t_seq * 1e6 / B:.0f},"
            f"parity_b1={'ok' if not mismatches else 'FAIL'}"]


if __name__ == "__main__":
    main()
