"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from dryrun JSONL files.

Usage: PYTHONPATH=src python -m benchmarks.render_experiments \
    benchmarks/results/dryrun_baseline.jsonl benchmarks/results/dryrun_optimized.jsonl
"""

from __future__ import annotations

import json
import sys
from collections import defaultdict


def load(path):
    out = {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            out[(r["arch"], r["shape"], r["mesh"])] = r
    return out


def fmt(x):
    if x >= 100:
        return f"{x:.0f}s"
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}µs"


def dryrun_summary(recs):
    rows = ["| arch | shape | mesh | status | lower | compile | fits HBM | coll bytes/dev |",
            "|---|---|---|---|---|---|---|---|"]
    for (a, s, m), r in sorted(recs.items()):
        if r["status"] == "skipped":
            rows.append(f"| {a} | {s} | {m} | SKIP ({r['reason'][:40]}…) | — | — | — | — |")
            continue
        rf = r["roofline"]
        rows.append(f"| {a} | {s} | {m} | ok | {r['lower_s']}s | {r['compile_s']}s | "
                    f"{'✓' if rf['fits_hbm'] else '✗'} | {rf['collective_bytes'] / 1e9:.1f}GB |")
    return "\n".join(rows)


def roofline_table(base, opt, mesh="single"):
    rows = ["| arch | shape | base c/m/x (dom) | opt c/m/x (dom) | dom speedup | useful b→o |",
            "|---|---|---|---|---|---|"]
    doms = defaultdict(int)
    for (a, s, m) in sorted(base):
        if m != mesh:
            continue
        rb = base[(a, s, m)]
        ro = opt.get((a, s, m))
        if rb["status"] != "ok":
            rows.append(f"| {a} | {s} | skipped | skipped | — | — |")
            continue
        fb = rb["roofline"]
        fo = ro["roofline"] if ro and ro["status"] == "ok" else None
        base_dom = max(fb["compute_s"], fb["memory_s"], fb["collective_s"])
        b = f"{fmt(fb['compute_s'])}/{fmt(fb['memory_s'])}/{fmt(fb['collective_s'])} ({fb['dominant'][:4]})"
        if fo:
            opt_dom = max(fo["compute_s"], fo["memory_s"], fo["collective_s"])
            o = f"{fmt(fo['compute_s'])}/{fmt(fo['memory_s'])}/{fmt(fo['collective_s'])} ({fo['dominant'][:4]})"
            sp = f"{base_dom / opt_dom:.2f}×"
            ub = fb.get("useful_ratio")
            uo = fo.get("useful_ratio")
            us = f"{ub:.2f}→{uo:.2f}" if ub is not None and uo is not None else "—"
            doms[fo["dominant"]] += 1
        else:
            o, sp, us = "ERROR", "—", "—"
        rows.append(f"| {a} | {s} | {b} | {o} | {sp} | {us} |")
    rows.append("")
    rows.append(f"Optimized dominant-term census ({mesh}): {dict(doms)}")
    return "\n".join(rows)


def main():
    base = load(sys.argv[1])
    opt = load(sys.argv[2]) if len(sys.argv) > 2 else base
    print("## Dry-run (optimized build)\n")
    print(dryrun_summary(opt))
    print("\n## Roofline — single-pod (256 chips), baseline vs optimized\n")
    print(roofline_table(base, opt, "single"))
    print("\n## Roofline — multi-pod (512 chips), baseline vs optimized\n")
    print(roofline_table(base, opt, "multi"))


if __name__ == "__main__":
    main()
