"""Tiled Pegasos solver bench — tuning/regression harness (BENCH_kernels.json).

The solver-speedup record for the tiled kernel path of
``core.classifiers._svm_solve_batch``: at each d ∈ {2, 16, 64} the same
batched refit runs two ways on the jitted CPU path,

  baseline   ``kernel=False`` — the classic vmapped-XLA Pegasos loop with
             its d-unrolled broadcast contractions (the paper-regime
             d = 2..10 fast form, solver-bound at d ≫ 2);
  tiled      ``kernel=True`` — the fused-stage dispatch
             (``kernels.ops.pegasos_stage``): on CPU the dot-contraction
             jnp twin of the Pallas kernel, on TPU the kernel itself,

interleaved min-of-N (``benchmarks/_timing.py``) so the recorded speedups
survive shared-box noise.  Pallas correctness is recorded in interpret
mode (the CPU stand-in for TPU execution, like every kernel test):
bit-for-bit vs the jnp twin at lane-aligned single-tile shapes, allclose +
bit-equal latch bits across the tiled multi-block grid.  A MAXMARG
differential gate re-runs a small sweep with ``solver_kernel`` on vs off
and requires every protocol decision (converged / rounds / comm) to match.

All three mismatch lists are schema-gated empty
(``check_bench_schema.py``), and the d = 64 headline carries the ≥ 2×
acceptance bar.  ``--tiny`` shrinks sizes for the CI smoke job and writes
BENCH_kernels.tiny.json (never the committed record); ``--tune`` runs the
``analysis/autotune.py`` block-shape search first and merges winners into
the committed tuning cache.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import jax.numpy as jnp

from repro.core import datasets
from repro.core.classifiers import _svm_solve_batch
from repro.engine import maxmarg as MM
from repro.engine import ProtocolInstance
from repro.kernels import ops, ref

from benchmarks import _timing as timing

OUT = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "BENCH_kernels.json")

DIMS = (2, 16, 64)

NOTES = (
    "Solver-speedup series for the tiled Pegasos kernel path of "
    "_svm_solve_batch (kernel=True) vs the classic vmapped-XLA loop "
    "(kernel=False), interleaved min-of-N on the jitted CPU path; Pallas "
    "parity recorded in interpret mode; decision-level parity + the "
    "MAXMARG solver_kernel differential gated exact.  Wall-clocks are "
    "machine-local; the speedup ratios are the contract."
)


def build_solver_case(d: int, B: int, N: int, seed: int = 0):
    """B independent refit instances packed (B, N, d) with label-0 pad rows
    (the compacted hot-loop fill shape the masked-pad path must ride)."""
    n_pad = max(N // 16, 2)
    n_fit = N - n_pad
    Xs, ys = [], []
    for i in range(B):
        shards = datasets.data_highd(n_per_node=(n_fit + 1) // 2, k=2, d=d,
                                     seed=seed * 1000 + i, margin=0.25)
        X = np.concatenate([s[0] for s in shards])[:n_fit]
        lab = np.concatenate([s[1] for s in shards])[:n_fit]
        Xp = np.zeros((N, d), np.float32)
        yp = np.zeros((N,), np.float32)
        Xp[:n_fit] = X
        yp[:n_fit] = lab
        Xs.append(Xp)
        ys.append(yp)
    return jnp.asarray(np.stack(Xs)), jnp.asarray(np.stack(ys))


def solver_series(d: int, B: int, N: int, steps: int, stages: int,
                  repeats: int) -> Dict:
    """Time baseline vs tiled on one (B, N, d) case + decision parity."""
    X, y = build_solver_case(d, B, N)
    lam = jnp.float32(1e-3)

    def base():
        return jax.block_until_ready(
            _svm_solve_batch(X, y, lam, steps, stages, kernel=False))

    def tiled():
        return jax.block_until_ready(
            _svm_solve_batch(X, y, lam, steps, stages, kernel=True))

    base(), tiled()                                    # compile outside timing
    out, times = timing.interleaved({"baseline": base, "tiled": tiled},
                                    repeats=repeats)
    wb, bb, cb = (np.asarray(a) for a in out["baseline"])
    wt, bt, ct = (np.asarray(a) for a in out["tiled"])
    Xn, yn = np.asarray(X), np.asarray(y)
    db = np.einsum("bnd,bd->bn", Xn, wb) + bb[:, None]
    dt = np.einsum("bnd,bd->bn", Xn, wt) + bt[:, None]
    valid = yn != 0.0
    mism = [int(i) for i in range(Xn.shape[0])
            if cb[i] != ct[i]
            or not np.array_equal(np.sign(db[i][valid[i]]),
                                  np.sign(dt[i][valid[i]]))]
    return {
        "d": d, "B": B, "N": N, "steps": steps, "stages": stages,
        "baseline_s": timing.tmin(times, "baseline"),
        "tiled_s": timing.tmin(times, "tiled"),
        "speedup": timing.ratio(times, "baseline", "tiled"),
        "all_converged": bool(cb.all() and ct.all()),
        "parity_mismatch_indices": mism,
    }


def interpret_parity() -> List[str]:
    """Pallas-vs-twin parity through the interpreter: names of failed
    checks (gated empty).  Exact at lane-aligned single-tile shapes —
    identical op sequence — allclose + bit-equal latch bits on the tiled
    multi-block grid (d-lane padding reassociates the contraction)."""
    fails: List[str] = []
    rng = np.random.default_rng(7)

    def case(B, N, d, nsteps, **kw):
        X = jnp.asarray(rng.standard_normal((B, N, d)), jnp.float32)
        y = jnp.asarray(rng.choice([-1.0, 1.0], (B, N)), jnp.float32)
        y = y.at[:, -max(N // 8, 1):].set(0.0)
        nv = jnp.sum(y != 0, axis=1).astype(jnp.float32)
        w = jnp.zeros((B, d), jnp.float32)
        b = jnp.zeros((B,), jnp.float32)
        lam = jnp.full((B,), 1e-2, jnp.float32)
        found = jnp.asarray(rng.random(B) < 0.3)
        wbest = jnp.asarray(rng.standard_normal((B, d)), jnp.float32)
        bbest = jnp.asarray(rng.standard_normal(B), jnp.float32)
        args = (X, y, nv, w, b, lam, found, wbest, bbest)
        r = ref.pegasos_stage_batch_ref(*args, nsteps=nsteps)
        k = ops.pegasos_stage(*args, nsteps=nsteps, use_pallas=True,
                              interpret=True, **kw)
        return [np.asarray(a) for a in r], [np.asarray(a) for a in k]

    r, k = case(6, 48, 8, 60, block_b=8, block_n=64, unroll=1)
    for name, a, c in zip(("w", "b", "mmin", "found", "w_best", "b_best"),
                          r, k):
        if not np.array_equal(a, c):
            fails.append(f"exact_single_tile:{name}")

    r, k = case(5, 70, 12, 60, block_b=2, block_n=16, unroll=1)
    for name, a, c in zip(("w", "b", "mmin", "found", "w_best", "b_best"),
                          r, k):
        if name == "found":
            if not np.array_equal(a, c):
                fails.append(f"tiled_grid:{name}")
        elif not np.allclose(a, c, rtol=1e-5, atol=1e-6):
            fails.append(f"tiled_grid:{name}")
    return fails


def maxmarg_differential(tiny: bool) -> List[int]:
    """Protocol-decision differential: the same MAXMARG sweep with the
    solver kernel on vs off must match in every converged / rounds / comm
    field (indices of disagreeing instances; gated empty)."""
    npn = 48 if tiny else 128
    buckets = [
        [ProtocolInstance(datasets.data1(n_per_node=npn, k=2, seed=s),
                          0.05, "maxmarg") for s in (0, 1)],
        # run_instances is shape-monomorphic (d static per sweep), so the
        # high-d regime gets its own bucketed call
        [ProtocolInstance(
            datasets.data_highd(n_per_node=npn, k=2, d=16, seed=0,
                                margin=0.2), 0.05, "maxmarg")],
    ]
    kw = dict(max_epochs=8, steps=300 if tiny else 2000,
              stages=2 if tiny else 3)
    mism, off = [], 0
    for insts in buckets:
        ra = MM.run_instances(insts, solver_kernel=False, **kw)
        rb = MM.run_instances(insts, solver_kernel=True, **kw)
        mism += [off + i for i, (a, b) in enumerate(zip(ra, rb))
                 if (a.converged, a.rounds, a.comm)
                 != (b.converged, b.rounds, b.comm)]
        off += len(insts)
    return mism


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="smoke sizes; writes BENCH_kernels.tiny.json")
    ap.add_argument("--repeats", type=int, default=None)
    ap.add_argument("--tune", action="store_true",
                    help="run the autotune search first and merge winners "
                         "into the committed kernels/tuning_cache.json")
    args = ap.parse_args()

    tiny = args.tiny
    B, N = (4, 96) if tiny else (12, 384)
    steps, stages = (60, 2) if tiny else (1200, 2)
    repeats = args.repeats or (2 if tiny else 5)

    if args.tune:
        from repro.analysis import autotune
        autotune.main(["--shapes"] + [f"{B}x{N}x{d}" for d in DIMS]
                      + ["--write"])

    solver = []
    for d in DIMS:
        entry = solver_series(d, B, N, steps, stages, repeats)
        print(f"d={d:>3}: baseline {entry['baseline_s']*1e3:8.1f} ms   "
              f"tiled {entry['tiled_s']*1e3:8.1f} ms   "
              f"speedup {entry['speedup']:.2f}x   "
              f"parity_mismatches={entry['parity_mismatch_indices']}")
        solver.append(entry)

    interp = interpret_parity()
    print(f"interpret parity: {'ok' if not interp else interp}")
    mm = maxmarg_differential(tiny)
    print(f"maxmarg solver_kernel differential: {'ok' if not mm else mm}")

    head = next(e for e in solver if e["d"] == 64)
    parity = [i for e in solver for i in e["parity_mismatch_indices"]]
    report = {
        "notes": NOTES,
        "tiny": tiny,
        "instances": B,
        "device": str(jax.devices()[0].device_kind),
        # headline triple mirrors the other BENCH artifacts: the d=64
        # bucket, where the acceptance bar (≥ 2× on the full size) lives
        "sequential_s": head["baseline_s"],
        "batched_s": head["tiled_s"],
        "speedup": head["speedup"],
        "solver": solver,
        "parity_mismatch_indices": parity,
        "interpret_parity_mismatches": interp,
        "maxmarg_kernel_mismatch_indices": mm,
        "all_converged": bool(all(e["all_converged"] for e in solver)),
        "parity_clean": bool(not parity and not interp and not mm),
    }
    out = OUT.replace(".json", ".tiny.json") if tiny else OUT
    with open(out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
