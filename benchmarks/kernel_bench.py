"""Micro-benchmarks of the data-plane hot loops.

On this CPU container the Pallas kernels run in interpret mode (orders of
magnitude slower than compiled TPU code), so the *timed* path is the jitted
XLA data plane (the same math the kernels implement) — giving a meaningful
protocol-scaling curve — while the Pallas path is timed at a reduced size
purely to record interpret-mode correctness cost.
"""

from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from repro.core import geometry as geo
from repro.kernels import ops


def _time(fn, *args, reps=5, **kw) -> float:
    out = fn(*args, **kw)          # compile
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # µs


def main() -> List[str]:
    csv = []
    key = jax.random.PRNGKey(0)
    print("### protocol data plane (jitted XLA, CPU)")
    for n in (1_000, 10_000, 100_000):
        m = 1024
        ks = jax.random.split(jax.random.fold_in(key, n), 3)
        V = geo.direction_grid(m)
        X = jax.random.normal(ks[0], (n, 2))
        y = jnp.where(jax.random.bernoulli(ks[1], 0.5, (n,)), 1, -1)
        ok = jnp.ones((m,), bool)
        us = _time(geo.uncertain_mask, V, ok, X[:64], y[:64], X, y)
        print(f"uncertain_mask n={n:>7d} m={m}: {us:10.1f} µs")
        csv.append(f"kernel/uncertain_mask/n={n},{us:.0f},m={m}")
    print("### batched sweep data plane (jitted XLA, CPU)")
    from repro.kernels import ref
    for B in (8, 32):
        m, n = 1024, 4096
        ks = jax.random.split(jax.random.fold_in(key, B), 3)
        V = geo.direction_grid(m)
        Xw = jax.random.normal(ks[0], (B, n, 2))
        yw = jnp.where(jax.random.bernoulli(ks[1], 0.5, (B, n)), 1, -1)
        us = _time(ref.threshold_ranges_batch_ref, V, Xw, yw)
        print(f"threshold_ranges_batch B={B:>3d} n={n} m={m}: {us:10.1f} µs")
        csv.append(f"kernel/threshold_ranges_batch/B={B},{us:.0f},n={n};m={m}")
    print("### Pallas interpret-mode (correctness-scale)")
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    us = _time(ops.attention, q, k, v, causal=True, interpret=True, reps=2)
    print(f"flash_attention interpret (1,256,4,64): {us:10.1f} µs")
    csv.append(f"kernel/flash_attention_interp,{us:.0f},B1S256H4")
    return csv


if __name__ == "__main__":
    main()
