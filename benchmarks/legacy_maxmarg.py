"""Pre-engine host-loop MAXMARG baselines (benchmark + differential oracle).

These are the MAXMARG protocols exactly as they executed before the batched
engine's MAXMARG selector landed: host-side Python loops over rounds, one
``fit_max_margin`` device call per round, numpy control plane.  Kept for two
reasons only:

* ``benchmarks/maxmarg_sweep.py`` measures the engine's speedup against the
  execution model it replaced (this one);
* ``kparty_maxmarg_hostloop`` doubles as a differential-testing oracle for
  the engine's protocol logic (same selector, same support/violation
  shipping, host-side control flow) — ``tests/test_engine_maxmarg.py``
  asserts identical comm-byte totals across a grid.

One normalization relative to the retired ``src`` code, noted for the
record: per-node error counts are exact integer sums
(``int(np.sum(pred != y))``) rather than ``int(error_rate * n)`` — the
float64 round-trip in the latter could truncate an exact count by one ulp,
which is an artifact of the old accounting, not protocol behavior.

Production code paths must use :mod:`repro.engine` — do not import this
from ``src/``.
"""

from __future__ import annotations

import numpy as np

from repro.core import classifiers as clf
from repro.core.comm import make_nodes
from repro.core.protocols.one_way import ProtocolResult


def _errors(h: clf.LinearSeparator, X: np.ndarray, y: np.ndarray) -> int:
    return int(np.sum(h.predict(X) != y))


def kparty_maxmarg_hostloop(
    shards,
    eps: float = 0.05,
    max_epochs: int = 48,
    max_support: int = 4,
) -> ProtocolResult:
    """The retired k-party MAXMARG host loop (paper §7 variant): the epoch
    coordinator fits on everything it knows, broadcasts support points, and
    the others reply with their own most-violated points."""
    nodes, log = make_nodes(shards)
    k = len(nodes)
    n_total = sum(nd.n for nd in nodes)
    budget = int(np.floor(eps * n_total))

    h = None
    for epoch in range(max_epochs):
        for ci in range(k):
            log.new_round()
            coord = nodes[ci]
            X, y = coord.all_known()
            h = clf.fit_max_margin(X, y)
            sidx = clf.support_points(h, X, y, max_support=max_support)
            errs = []
            for nd in nodes:
                if nd is coord:
                    errs.append(_errors(h, nd.X, nd.y))
                    continue
                coord.send_points(nd, X[sidx], y[sidx],
                                  tag="kparty-maxmarg-support")
                e = _errors(h, nd.X, nd.y)
                errs.append(e)
                nd.send_bit(coord, int(e == 0), tag="kparty-maxmarg-ok")
                if e > 0:
                    # reply with the most-violated points (stable: margin
                    # ties break by index, matching the engine's ranking)
                    m = nd.y * (nd.X @ h.w + h.b)
                    worst = np.argsort(m, kind="stable")[:2]
                    nd.send_points(coord, nd.X[worst], nd.y[worst],
                                   tag="kparty-maxmarg-viol")
            if sum(errs) <= budget:
                return ProtocolResult(h, log.summary(), rounds=epoch + 1,
                                      converged=True)
    return ProtocolResult(h, log.summary(), rounds=max_epochs,
                          converged=False)


def two_party_maxmarg_hostloop(
    shards,
    eps: float = 0.05,
    max_rounds: int = 64,
    max_support: int = 6,
) -> ProtocolResult:
    """The retired asymmetric two-party MAXMARG host loop (alternating
    senders, value-level dedup of reshipped support points).  Benchmark
    reference only — the public two-party API is now the k=2 instance of the
    k-party support-exchange protocol on the engine."""
    nodes, log = make_nodes(shards[:2])
    A, B = nodes
    n_total = A.n + B.n
    budget = int(np.floor(eps * n_total))

    sent_ids = {A.name: set(), B.name: set()}
    h = None
    for rnd in range(max_rounds):
        log.new_round()
        src, dst = (A, B) if rnd % 2 == 0 else (B, A)
        Xk, yk = src.all_known()
        h = clf.fit_max_margin(Xk, yk)
        sidx = clf.support_points(h, Xk, yk, max_support=max_support)
        # ship only points the peer has not seen from us (dedup by value)
        new_pts, new_labs = [], []
        for i in sidx:
            if i >= src.n:  # a received point — the peer may already know it
                key = (round(float(Xk[i, 0]), 9),
                       round(float(Xk[i, 1] if Xk.shape[1] > 1 else 0.0), 9),
                       int(yk[i]))
            else:
                key = (int(i), int(yk[i]), "own")
            if key in sent_ids[src.name]:
                continue
            sent_ids[src.name].add(key)
            new_pts.append(Xk[i])
            new_labs.append(yk[i])
        if new_pts:
            src.send_points(dst, np.stack(new_pts),
                            np.asarray(new_labs, dtype=np.int32),
                            tag="maxmarg-support")
        err = _errors(h, src.X, src.y) + _errors(h, dst.X, dst.y)
        dst.send_bit(src, int(err <= budget), tag="accept")
        if err <= budget:
            return ProtocolResult(h, log.summary(), rounds=rnd + 1,
                                  converged=True)
    return ProtocolResult(h, log.summary(), rounds=max_rounds,
                          converged=False)
