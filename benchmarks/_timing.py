"""Shared interleaved-timing harness for the sweep benchmarks.

Every series is measured min-over-repeats with the series *interleaved*
round-robin: one-sided scheduler/frequency noise on a small shared box only
ever inflates a wall-clock, and interleaving shows every series the same
machine phases — so the recorded speedup ratios are stable even when
absolute wall-clocks drift between runs.  Speedups are reported as the
median of per-round ratios: within one interleaved round both series saw
the same machine phase, so common-mode drift cancels where a ratio of
cross-round minima would not (which is why a report's ``speedup`` need not
equal the quotient of its two recorded minima).
"""

from __future__ import annotations

import time
from typing import Callable, Dict, Tuple

import numpy as np


def interleaved(series: Dict[str, Callable], repeats: int
                ) -> Tuple[dict, Dict[str, list]]:
    """Run each named thunk round-robin ``repeats`` times.

    Returns ``(out, times)``: the last result and the per-round wall-clock
    list per series.  Thunks take no arguments — bind their inputs when
    building ``series``.
    """
    times: Dict[str, list] = {name: [] for name in series}
    out: dict = {}
    for _ in range(repeats):
        for name, fn in series.items():
            t0 = time.perf_counter()
            out[name] = fn()
            times[name].append(time.perf_counter() - t0)
    return out, times


def tmin(times: Dict[str, list], name: str) -> float:
    """The recorded wall-clock for a series: min over interleaved rounds."""
    return float(np.min(times[name]))


def ratio(times: Dict[str, list], num: str, den: str) -> float:
    """Speedup of ``den`` over ``num`` as the median of per-round ratios."""
    return float(np.median(np.asarray(times[num])
                           / np.maximum(np.asarray(times[den]), 1e-9)))
