"""Schema gate for the BENCH_*.json artifacts (CI bench-smoke job).

The sweep benchmarks are the repo's perf acceptance record; downstream
tooling (and the next PR's reviewer) reads the JSON, so its shape is a
contract.  This validator checks required keys, types, and the invariants
the engine guarantees at any size (parity flags true, disagreement lists
empty, every instance converged within its ε) — it does NOT gate on
wall-clock numbers, which the tiny CI sizes make meaningless.

Usage:  python benchmarks/check_bench_schema.py BENCH_engine.json ...
"""

from __future__ import annotations

import json
import sys

_NUM = (int, float)

# field -> required type(s); shared by BENCH_engine.json / BENCH_maxmarg.json
COMMON_SCHEMA = {
    "notes": str,
    "instances": int,
    "max_epochs": int,
    "sequential_s": _NUM,
    "batched_s": _NUM,
    "speedup": _NUM,
    "engine_b1_loop_s": _NUM,
    "speedup_vs_engine_b1": _NUM,
    "parity_b1_ok": bool,
    "parity_b1_mismatch_indices": list,
    "legacy_oracle_disagreements": list,
    "all_converged": bool,
    "all_err_within_eps": bool,
    "per_instance": list,
}

PER_INSTANCE_SCHEMA = {
    "eps": _NUM,
    "converged": bool,
    "rounds": int,
    "points": int,
    "global_err": _NUM,
    "err_within_eps": bool,
    "parity_b1": bool,
}


def check(path: str) -> list:
    with open(path) as f:
        report = json.load(f)
    errors = []

    def expect(obj, field, typ, where):
        if field not in obj:
            errors.append(f"{where}: missing key {field!r}")
        elif not isinstance(obj[field], typ):
            errors.append(f"{where}: {field!r} has type "
                          f"{type(obj[field]).__name__}, wanted {typ}")

    for field, typ in COMMON_SCHEMA.items():
        expect(report, field, typ, path)
    for i, inst in enumerate(report.get("per_instance", [])):
        for field, typ in PER_INSTANCE_SCHEMA.items():
            expect(inst, field, typ, f"{path}[per_instance][{i}]")

    # size-independent invariants
    if report.get("per_instance") is not None and \
            len(report["per_instance"]) != report.get("instances"):
        errors.append(f"{path}: per_instance length != instances")
    for flag in ("parity_b1_ok", "all_converged", "all_err_within_eps"):
        if report.get(flag) is not True:
            errors.append(f"{path}: {flag} is not true")
    for lst in ("parity_b1_mismatch_indices", "legacy_oracle_disagreements"):
        if report.get(lst):
            errors.append(f"{path}: {lst} is non-empty: {report[lst]}")
    return errors


def main(paths) -> int:
    all_errors = []
    for path in paths:
        errs = check(path)
        status = "OK" if not errs else f"{len(errs)} problem(s)"
        print(f"{path}: {status}")
        all_errors += errs
    for e in all_errors:
        print(f"  !! {e}")
    return 1 if all_errors else 0


if __name__ == "__main__":
    if len(sys.argv) < 2:
        print(__doc__)
        sys.exit(2)
    sys.exit(main(sys.argv[1:]))
