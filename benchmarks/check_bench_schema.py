"""Schema gate for the BENCH_*.json artifacts (CI bench-smoke job).

The sweep benchmarks are the repo's perf acceptance record; downstream
tooling (and the next PR's reviewer) reads the JSON, so its shape is a
contract.  This validator checks required keys, types, and the invariants
the engine guarantees at any size (parity flags true, disagreement lists
empty, every instance converged within its ε) — it does NOT gate on
wall-clock numbers, which the tiny CI sizes make meaningless.

Usage:  python benchmarks/check_bench_schema.py BENCH_engine.json ...
"""

from __future__ import annotations

import json
import os
import sys

_NUM = (int, float)

# field -> required type(s); shared by BENCH_engine.json / BENCH_maxmarg.json
COMMON_SCHEMA = {
    "notes": str,
    "instances": int,
    "max_epochs": int,
    "sequential_s": _NUM,
    "batched_s": _NUM,
    "speedup": _NUM,
    "engine_b1_loop_s": _NUM,
    "speedup_vs_engine_b1": _NUM,
    "parity_b1_ok": bool,
    "parity_b1_mismatch_indices": list,
    "legacy_oracle_disagreements": list,
    "all_converged": bool,
    "all_err_within_eps": bool,
    "per_instance": list,
}

# BENCH_engine.json additionally carries the MEDIAN hot-path series (PR 5):
# the cold padded while_loop model replayed on the same grid, and the
# hot/cold decision+separator parity list (bar: empty — the MEDIAN
# compactions are bit-exact).  PR 6 adds the sharded series: the same hot
# loop with its B axis split over a ("data",) mesh (donated buffers +
# double-buffered dispatch) vs the single-device hot path on a wide
# long-tail grid, held to the same bit-exactness bar.
ENGINE_EXTRA_SCHEMA = {
    "hot_vs_cold": dict,
    "speedup_hot_vs_cold": _NUM,
    "hot_cold_mismatch_indices": list,
    "sharded": dict,
    "speedup_sharded_vs_hot": _NUM,
    "sharded_mismatch_indices": list,
}

HOT_COLD_SCHEMA = {"hot_s": _NUM, "cold_s": _NUM, "speedup": _NUM}
SHARDED_SCHEMA = {"instances": int, "n_devices": int, "hot_s": _NUM,
                  "sharded_s": _NUM, "speedup": _NUM}

# BENCH_maxmarg.json additionally carries the hot-path series (PR 4): the
# cold-padded PR 2 execution model as in-file baseline, the per-layer
# warm-vs-cold / compacted-vs-padded toggles, the warm/cold decision parity
# list (bar: empty), and (PR 5) the per-node-vs-single warm-carry series
# with its own parity list.
MAXMARG_EXTRA_SCHEMA = {
    "max_support": int,
    "batched_cold_padded_s": _NUM,
    "speedup_vs_cold_padded": _NUM,
    "warm_vs_cold": dict,
    "compacted_vs_padded": dict,
    "warm_cold_mismatch_indices": list,
    "per_node_warm": dict,
    "per_node_mismatch_indices": list,
}

WARM_COLD_SCHEMA = {"warm_s": _NUM, "cold_s": _NUM, "speedup": _NUM}
COMPACT_SCHEMA = {"compacted_s": _NUM, "padded_s": _NUM, "speedup": _NUM}
PER_NODE_SCHEMA = {"instances": int, "rounds": list, "per_node_s": _NUM,
                   "single_carry_s": _NUM, "speedup": _NUM,
                   "latches_per_node": int, "latches_single_carry": int}

# BENCH_history.json: the cumulative per-PR headline series folded by
# benchmarks/bench_history.py.
HISTORY_ENTRY_SCHEMA = {"label": str, "tiny": bool, "benches": dict}
HISTORY_BENCH_SCHEMA = {"batched_s": _NUM, "speedup": _NUM,
                        "parity_ok": bool}

PER_INSTANCE_SCHEMA = {
    "eps": _NUM,
    "converged": bool,
    "rounds": int,
    "points": int,
    "global_err": _NUM,
    "err_within_eps": bool,
    "parity_b1": bool,
}

# BENCH_baselines.json: the one-way/baselines sweep has no epoch loop, its
# error gate covers only the ε-guaranteed selectors (VOTING/MIXING are the
# paper's failure baselines), and it carries the one-way-vs-two-way
# comm-gap headline series from the mixed run_sweep dispatch.
BASELINES_SCHEMA = {
    "notes": str,
    "instances": int,
    "sequential_s": _NUM,
    "batched_s": _NUM,
    "speedup": _NUM,
    "engine_b1_loop_s": _NUM,
    "speedup_vs_engine_b1": _NUM,
    "parity_b1_ok": bool,
    "parity_b1_mismatch_indices": list,
    "legacy_oracle_disagreements": list,
    "all_converged": bool,
    "all_gated_err_within_eps": bool,
    "oneway_vs_twoway": list,
    "per_instance": list,
}

BASELINES_PER_INSTANCE = {
    "selector": str,
    "eps": _NUM,
    "converged": bool,
    "rounds": int,
    "points": int,
    "bytes": int,
    "global_err": _NUM,
    "parity_b1": bool,
}

# BENCH_service.json: the fault-tolerant streaming session-pool benchmark
# (benchmarks/service_sweep.py).  Wall-clocks are machine-local and not
# gated; what IS gated is the robustness contract: zero steady-state
# recompiles (admission refills slots at pinned cache keys), healthy
# sessions bit-exact against the fault-free run_instances oracle, and a
# seeded chaos run that actually exercised every fault channel it claims.
SERVICE_SCHEMA = {
    "notes": str,
    "sessions": int,
    "slots": int,
    "k": int,
    "n_pad": int,
    "selector": str,
    "schedule": dict,
    "statuses": dict,
    "stats": dict,
    "fault_free_s": _NUM,
    "faulted_s": _NUM,
    "sessions_per_s_fault_free": _NUM,
    "sessions_per_s_faulted": _NUM,
    "steady_state_recompiles": int,
    "oracle_checked": int,
    "oracle_mismatches": list,
    "mixed_traffic": dict,
}

SERVICE_STATUSES = ("converged", "budget_exhausted", "quarantined")

# the mixed_traffic series (PR 10): interleaved MEDIAN+MAXMARG+SAMPLING
# sessions through ONE unified pool vs three per-family pools at equal
# session counts.  Gated: zero steady-state recompiles on the unified
# pool's warm run, exactly ONE pinned dispatch key for the whole mixed
# stream, and an empty unified-vs-per-family mismatch list.
SERVICE_MIXED_SCHEMA = {
    "sessions": int,
    "slots": int,
    "per_family_sessions": dict,
    "unified_s": _NUM,
    "per_family_s": dict,
    "per_family_total_s": _NUM,
    "steady_state_recompiles": int,
    "steady_state_dispatch_keys": list,
    "checked": int,
    "bitwise": int,
    "mismatches": list,
}


GAP_ENTRY_SCHEMA = {
    "dataset": str,
    "eps": _NUM,
    "naive_points": int,
    "sampling_points": int,
    "median_points": int,
    "maxmarg_points": int,
    "naive_over_maxmarg": _NUM,
    "naive_over_median": _NUM,
}


def _check_history(path: str, report: dict) -> list:
    errors = []

    def expect(obj, field, typ, where):
        if field not in obj:
            errors.append(f"{where}: missing key {field!r}")
        elif not isinstance(obj[field], typ):
            errors.append(f"{where}: {field!r} has type "
                          f"{type(obj[field]).__name__}, wanted {typ}")

    expect(report, "notes", str, path)
    entries = report.get("entries")
    if not isinstance(entries, list) or not entries:
        errors.append(f"{path}: entries is missing or empty")
        return errors
    for i, entry in enumerate(entries):
        where = f"{path}[entries][{i}]"
        for field, typ in HISTORY_ENTRY_SCHEMA.items():
            expect(entry, field, typ, where)
        benches = entry.get("benches") or {}
        if not benches:
            errors.append(f"{where}: benches is empty")
        for name, bench in benches.items():
            for field, typ in HISTORY_BENCH_SCHEMA.items():
                expect(bench, field, typ, f"{where}[{name}]")
            if bench.get("parity_ok") is not True:
                errors.append(f"{where}[{name}]: parity_ok is not true")
    labels = [e.get("label") for e in entries]
    if len(labels) != len(set(labels)):
        errors.append(f"{path}: duplicate entry labels: {labels}")
    return errors


KERNELS_SCHEMA = {
    "notes": str, "tiny": bool, "instances": int, "device": str,
    "sequential_s": _NUM, "batched_s": _NUM, "speedup": _NUM,
    "solver": list, "parity_mismatch_indices": list,
    "interpret_parity_mismatches": list,
    "maxmarg_kernel_mismatch_indices": list,
    "all_converged": bool, "parity_clean": bool,
}
KERNELS_SOLVER_SCHEMA = {"d": int, "B": int, "N": int, "steps": int,
                         "stages": int, "baseline_s": _NUM, "tiled_s": _NUM,
                         "speedup": _NUM, "all_converged": bool,
                         "parity_mismatch_indices": list}
KERNELS_DIMS = (2, 16, 64)


def _check_kernels(path: str, report: dict) -> list:
    """BENCH_kernels.json: the tiled-solver speedup series (one entry per
    d bucket, all three required) and three parity-mismatch lists that are
    gated empty — interpret-mode Pallas parity, solver decision parity, and
    the MAXMARG solver_kernel differential.  Wall-clock magnitudes are not
    gated (smoke sizes time nothing meaningful); emptiness and coverage
    are."""
    errors = []

    def expect(obj, field, typ, where):
        if field not in obj:
            errors.append(f"{where}: missing key {field!r}")
        elif not isinstance(obj[field], typ):
            errors.append(f"{where}: {field!r} has type "
                          f"{type(obj[field]).__name__}, wanted {typ}")

    for field, typ in KERNELS_SCHEMA.items():
        expect(report, field, typ, path)
    solver = report.get("solver", [])
    for i, entry in enumerate(solver):
        for field, typ in KERNELS_SOLVER_SCHEMA.items():
            expect(entry, field, typ, f"{path}[solver][{i}]")
    dims = sorted(e.get("d") for e in solver if isinstance(e, dict))
    if dims != sorted(KERNELS_DIMS):
        errors.append(f"{path}: solver series covers d={dims}, wanted "
                      f"{sorted(KERNELS_DIMS)}")
    for flag in ("all_converged", "parity_clean"):
        if report.get(flag) is not True:
            errors.append(f"{path}: {flag} is not true")
    for lst in ("parity_mismatch_indices", "interpret_parity_mismatches",
                "maxmarg_kernel_mismatch_indices"):
        if report.get(lst):
            errors.append(f"{path}: {lst} is non-empty: {report[lst]}")
    return errors


def _check_service(path: str, report: dict) -> list:
    errors = []

    def expect(obj, field, typ, where):
        if field not in obj:
            errors.append(f"{where}: missing key {field!r}")
        elif not isinstance(obj[field], typ):
            errors.append(f"{where}: {field!r} has type "
                          f"{type(obj[field]).__name__}, wanted {typ}")

    for field, typ in SERVICE_SCHEMA.items():
        expect(report, field, typ, path)

    statuses = report.get("statuses") or {}
    for s in SERVICE_STATUSES:
        if not isinstance(statuses.get(s), int):
            errors.append(f"{path}[statuses]: missing int count for {s!r}")
    if isinstance(report.get("sessions"), int) and \
            all(isinstance(statuses.get(s), int) for s in SERVICE_STATUSES):
        total = sum(statuses[s] for s in SERVICE_STATUSES)
        if total != report["sessions"]:
            errors.append(f"{path}: statuses sum to {total}, not "
                          f"sessions={report['sessions']} — some sessions "
                          f"never reached a terminal state")

    # the robustness gates (size-independent)
    if report.get("steady_state_recompiles") != 0:
        errors.append(
            f"{path}: steady_state_recompiles is "
            f"{report.get('steady_state_recompiles')!r}, wanted 0 — "
            f"admission/dispatch moved a compile-cache key")
    if report.get("oracle_mismatches"):
        errors.append(f"{path}: oracle_mismatches is non-empty: "
                      f"{report['oracle_mismatches']} — healthy sessions "
                      f"must be bit-exact vs the fault-free oracle")
    if report.get("oracle_checked") == 0:
        errors.append(f"{path}: oracle_checked is 0 — the bit-exactness "
                      f"gate never ran")

    # a chaos artifact must have exercised the channels it claims
    sched = report.get("schedule") or {}
    if any(sched.get(p, 0) > 0 for p in
           ("p_dropout", "p_drop_msg", "p_straggle", "p_corrupt")):
        stats = report.get("stats") or {}
        injected = sum(stats.get(c, 0) for c in
                       ("dropouts", "drop_msgs", "straggles", "corruptions"))
        if injected == 0:
            errors.append(f"{path}: schedule has nonzero fault rates but "
                          f"stats show zero injected faults")

    # the mixed-traffic gates: one pool, one key, zero drift vs per-family
    mixed = report.get("mixed_traffic")
    if isinstance(mixed, dict):
        for field, typ in SERVICE_MIXED_SCHEMA.items():
            expect(mixed, field, typ, f"{path}[mixed_traffic]")
        if mixed.get("steady_state_recompiles") != 0:
            errors.append(
                f"{path}[mixed_traffic]: steady_state_recompiles is "
                f"{mixed.get('steady_state_recompiles')!r}, wanted 0 — "
                f"mixed admission moved a compile-cache key")
        keys = mixed.get("steady_state_dispatch_keys")
        if isinstance(keys, list) and len(keys) != 1:
            errors.append(
                f"{path}[mixed_traffic]: {len(keys)} distinct dispatch "
                f"keys, wanted exactly 1 — the unified pool must drive "
                f"the whole mixed stream at ONE pinned key")
        if mixed.get("mismatches"):
            errors.append(
                f"{path}[mixed_traffic]: mismatches is non-empty: "
                f"{mixed['mismatches']} — unified-pool sessions must "
                f"match their per-family pool twins")
        if mixed.get("checked") == 0:
            errors.append(f"{path}[mixed_traffic]: checked is 0 — the "
                          f"unified-vs-per-family parity gate never ran")
        fam = mixed.get("per_family_sessions")
        if isinstance(fam, dict) and isinstance(mixed.get("sessions"), int) \
                and sum(fam.values()) != mixed["sessions"]:
            errors.append(f"{path}[mixed_traffic]: per-family session "
                          f"counts {fam} do not sum to "
                          f"sessions={mixed['sessions']}")
    return errors


def check(path: str) -> list:
    if not os.path.exists(path):
        return [f"{path}: artifact not found — run the producing benchmark "
                f"first (benchmarks/*_sweep.py writes it)"]
    try:
        with open(path) as f:
            report = json.load(f)
    except (json.JSONDecodeError, UnicodeDecodeError, OSError) as e:
        return [f"{path}: unreadable or truncated JSON ({e}) — the artifact "
                f"is corrupt; re-run the producing benchmark"]
    if not isinstance(report, dict):
        return [f"{path}: top level is {type(report).__name__}, wanted an "
                f"object — not a BENCH artifact"]
    if "history" in os.path.basename(path):
        return _check_history(path, report)
    if "service" in os.path.basename(path):
        return _check_service(path, report)
    if "kernels" in os.path.basename(path):
        return _check_kernels(path, report)
    errors = []
    is_baselines = "baselines" in os.path.basename(path)
    is_maxmarg = "maxmarg" in os.path.basename(path)
    is_engine = "engine" in os.path.basename(path)
    schema = BASELINES_SCHEMA if is_baselines else dict(COMMON_SCHEMA)
    if is_maxmarg:
        schema.update(MAXMARG_EXTRA_SCHEMA)
    if is_engine:
        schema.update(ENGINE_EXTRA_SCHEMA)
    per_inst = BASELINES_PER_INSTANCE if is_baselines else PER_INSTANCE_SCHEMA
    flags = ("parity_b1_ok", "all_converged",
             "all_gated_err_within_eps" if is_baselines
             else "all_err_within_eps")

    def expect(obj, field, typ, where):
        if field not in obj:
            errors.append(f"{where}: missing key {field!r}")
        elif not isinstance(obj[field], typ):
            errors.append(f"{where}: {field!r} has type "
                          f"{type(obj[field]).__name__}, wanted {typ}")

    for field, typ in schema.items():
        expect(report, field, typ, path)
    for i, inst in enumerate(report.get("per_instance", [])):
        for field, typ in per_inst.items():
            expect(inst, field, typ, f"{path}[per_instance][{i}]")
    if is_maxmarg:
        for field, typ in WARM_COLD_SCHEMA.items():
            expect(report.get("warm_vs_cold", {}), field, typ,
                   f"{path}[warm_vs_cold]")
        for field, typ in COMPACT_SCHEMA.items():
            expect(report.get("compacted_vs_padded", {}), field, typ,
                   f"{path}[compacted_vs_padded]")
        for field, typ in PER_NODE_SCHEMA.items():
            expect(report.get("per_node_warm", {}), field, typ,
                   f"{path}[per_node_warm]")
    if is_engine:
        for field, typ in HOT_COLD_SCHEMA.items():
            expect(report.get("hot_vs_cold", {}), field, typ,
                   f"{path}[hot_vs_cold]")
        for field, typ in SHARDED_SCHEMA.items():
            expect(report.get("sharded", {}), field, typ,
                   f"{path}[sharded]")

    # size-independent invariants
    if report.get("per_instance") is not None and \
            len(report["per_instance"]) != report.get("instances"):
        errors.append(f"{path}: per_instance length != instances")
    for flag in flags:
        if report.get(flag) is not True:
            errors.append(f"{path}: {flag} is not true")
    lists = ["parity_b1_mismatch_indices", "legacy_oracle_disagreements"]
    if is_maxmarg:
        lists += ["warm_cold_mismatch_indices", "per_node_mismatch_indices"]
    if is_engine:
        lists += ["hot_cold_mismatch_indices", "sharded_mismatch_indices"]
    for lst in lists:
        if report.get(lst):
            errors.append(f"{path}: {lst} is non-empty: {report[lst]}")

    if is_baselines:
        gap = report.get("oneway_vs_twoway", [])
        if not gap:
            errors.append(f"{path}: oneway_vs_twoway is empty")
        for i, g in enumerate(gap):
            for field, typ in GAP_ENTRY_SCHEMA.items():
                expect(g, field, typ, f"{path}[oneway_vs_twoway][{i}]")
            # the paper's headline direction must hold at any size: the
            # two-way protocols beat shipping the whole dataset
            if g.get("naive_points", 0) < g.get("maxmarg_points", 0):
                errors.append(f"{path}[oneway_vs_twoway][{i}]: two-way "
                              f"MAXMARG cost exceeds NAIVE")
    return errors


def main(paths) -> int:
    all_errors = []
    for path in paths:
        errs = check(path)
        status = "OK" if not errs else f"{len(errs)} problem(s)"
        print(f"{path}: {status}")
        all_errors += errs
    for e in all_errors:
        print(f"  !! {e}")
    return 1 if all_errors else 0


if __name__ == "__main__":
    if len(sys.argv) < 2:
        print(__doc__)
        sys.exit(2)
    sys.exit(main(sys.argv[1:]))
