"""Empirical verification of the paper's lower bounds (Thm 3.3 / App A, B).

Appendix A (Ω(1/ε) one-way for linear separators): build the indexing
construction; show (a) any one-way protocol that ships o(1/ε) points leaves
B guessing the targeted pair's bit — error ~1/2 over random instances, and
(b) the two-way MEDIAN protocol solves the same instances with O(log 1/ε)
communication — the exponential separation of Table 1.

Appendix B (Ω(|D_A|) noise detection): A-side points at even integers decide
perfect-classifier existence; any sketch of o(n) points misses the decisive
point with probability -> 1.
"""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core import datasets
from repro.core.classifiers import fit_max_margin
from repro.core.protocols import two_way


def one_way_indexing(eps: float = 0.05, trials: int = 20, budget_frac: float = 0.25):
    """B fits on its point + a random ``budget_frac`` fraction of A's pairs
    (an o(1/eps) one-way message); reports how often the targeted pair is
    misclassified."""
    wrong = 0
    total_pairs = None
    for t in range(trials):
        (XA, yA), (XB, yB), bits = datasets.indexing_instance(eps, seed=t)
        n_pairs = len(bits)
        total_pairs = n_pairs
        rng = np.random.default_rng(1000 + t)
        keep_pairs = rng.choice(n_pairs, size=max(1, int(budget_frac * n_pairs)),
                                replace=False)
        keep = np.concatenate([[2 * j, 2 * j + 1] for j in keep_pairs])
        X = np.concatenate([XA[keep], XB])
        y = np.concatenate([yA[keep], yB])
        h = fit_max_margin(X, y)
        # evaluate on the full instance: the targeted pair decides
        err = h.error(np.concatenate([XA, XB]), np.concatenate([yA, yB]))
        wrong += err > 0
    return wrong / trials, total_pairs


def two_way_same_instances(eps: float = 0.05, trials: int = 10):
    """MEDIAN on the indexing instances: solves them with tiny cost."""
    costs, errs = [], []
    for t in range(trials):
        (XA, yA), (XB, yB), _ = datasets.indexing_instance(eps, seed=t)
        r = two_way.iterative_support_median([(XA, yA), (XB, yB)], eps=eps)
        X = np.concatenate([XA, XB])
        y = np.concatenate([yA, yB])
        errs.append(r.classifier.error(X, y))
        costs.append(r.comm["points"])
    return float(np.mean(errs)), float(np.mean(costs))


def noise_detection(n: int = 200, trials: int = 30, budget_frac: float = 0.3):
    """App B: sketching o(n) of A's points cannot decide separability."""
    missed = 0
    for t in range(trials):
        rng = np.random.default_rng(t)
        i = int(rng.integers(1, n // 2))
        has_blocker = bool(rng.integers(0, 2))
        A_vals = set(rng.choice(np.arange(1, n + 1), size=n // 2, replace=False) * 2)
        if has_blocker:
            A_vals.add(2 * i)
        else:
            A_vals.discard(2 * i)
        # B checks a random o(n) subset of A's points (the one-way sketch)
        sketch = rng.choice(sorted(A_vals), size=int(budget_frac * len(A_vals)),
                            replace=False)
        decided_separable = 2 * i not in set(sketch)
        truly_separable = not has_blocker
        missed += decided_separable != truly_separable
    return missed / trials


def main() -> List[str]:
    csv = []
    t0 = time.time()
    err_rate, n_pairs = one_way_indexing()
    csv.append(f"lower_bound/one_way_indexing,{(time.time() - t0) * 1e6:.0f},"
               f"err_rate={err_rate:.2f};pairs={n_pairs}")
    print(f"App A one-way, 25% of the Ω(1/ε) pairs shipped: "
          f"{100 * err_rate:.0f}% of instances misclassified (need ~0% to win)")
    t0 = time.time()
    err, cost = two_way_same_instances()
    csv.append(f"lower_bound/two_way_median,{(time.time() - t0) * 1e6:.0f},"
               f"err={err:.4f};cost={cost:.1f}")
    print(f"Two-way MEDIAN on the same instances: mean err {err:.4f}, "
          f"mean cost {cost:.1f} points (vs Ω(1/ε)={1 / 0.05:.0f} one-way)")
    t0 = time.time()
    miss = noise_detection()
    csv.append(f"lower_bound/noise_detection,{(time.time() - t0) * 1e6:.0f},"
               f"miss_rate={miss:.2f}")
    print(f"App B noise detection with 30% sketch: {100 * miss:.0f}% wrong "
          f"(Ω(|D_A|) is required)")
    return csv


if __name__ == "__main__":
    main()
