"""Reproduction of the paper's experiment tables (§7, Tables 2-4).

Table 2: two-party, 2-D Data1/2/3 — NAIVE / VOTING / RANDOM / MAXMARG / MEDIAN
Table 3: two-party, the same data lifted to d=10
Table 4: four-party (k=4) versions

Each run reports accuracy on D = ∪ D_i and communication cost in points
(the paper's units), from the metered CommLog — measured, never estimated.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro import engine
from repro.core import datasets
from repro.core.protocols import baselines, kparty, two_way

EPS = 0.05


def _engine_median_batch(shard_sets: Dict[str, List], eps: float,
                         max_epochs: int):
    """All of a table's MEDIAN runs as one batched engine dispatch.

    Returns (per-dataset results, per-dataset amortized seconds) — the
    dispatch is shared, so each dataset's recorded time is its 1/N share,
    measured warm (compile excluded)."""
    names = list(shard_sets)
    insts = [engine.ProtocolInstance(shard_sets[d], eps) for d in names]
    engine.run_instances(insts, n_angles=1024, max_epochs=max_epochs)  # warm
    t0 = time.time()
    rs = engine.run_instances(insts, n_angles=1024, max_epochs=max_epochs)
    t_each = (time.time() - t0) / len(names)
    return dict(zip(names, rs)), t_each


def _acc(clf, shards) -> float:
    X = np.concatenate([s[0] for s in shards])
    y = np.concatenate([s[1] for s in shards])
    return float(np.mean(clf.predict(X) == y))


def _two_party_methods() -> Dict[str, Callable]:
    return {
        "naive": lambda sh: baselines.naive(sh),
        "voting": lambda sh: baselines.voting(sh),
        "random": lambda sh: baselines.random(sh, eps=EPS),
        "maxmarg": lambda sh: two_way.iterative_support_maxmarg(sh, eps=EPS),
        "median": lambda sh: two_way.iterative_support_median(sh, eps=EPS),
    }


def _k_party_methods() -> Dict[str, Callable]:
    return {
        "naive": lambda sh: baselines.naive(sh),
        "voting": lambda sh: baselines.voting(sh),
        "random": lambda sh: baselines.random(sh, eps=EPS),
        "maxmarg": lambda sh: kparty.iterative_support_kparty(sh, eps=EPS, selector="maxmarg"),
        "median": lambda sh: kparty.iterative_support_kparty(sh, eps=EPS, selector="median"),
    }


def _run_table(shard_sets: Dict[str, List], methods: Dict[str, Callable],
               table_name: str, paper: Dict[str, Dict[str, tuple]],
               precomputed: Optional[Dict[str, Dict[str, object]]] = None,
               pre_times: Optional[Dict[str, float]] = None,
               ) -> List[str]:
    rows = [f"### {table_name}",
            f"| method | " + " | ".join(f"{d} acc | {d} cost" for d in shard_sets) +
            " | paper (acc, cost) |",
            "|---" * (2 * len(shard_sets) + 2) + "|"]
    csv = []
    for mname, fn in methods.items():
        cells = []
        t0 = time.time()
        # precomputed methods ran outside this loop; their amortized
        # per-dataset dispatch time re-enters the CSV via pre_times
        t_pre = (pre_times or {}).get(mname, 0.0)
        for dname, shards in shard_sets.items():
            pre = (precomputed or {}).get(mname, {})
            r = pre[dname] if dname in pre else fn(shards)
            a = _acc(r.classifier, shards)
            c = r.comm["points"]
            cells.append(f"{100 * a:.1f}% | {c}")
            csv.append(f"{table_name}/{dname}/{mname},"
                       f"{(time.time() - t0 + t_pre) * 1e6:.0f},"
                       f"acc={a:.4f};cost={c}")
        ref = paper.get(mname, {})
        ref_s = "; ".join(f"{d}:{v}" for d, v in ref.items()) if ref else "—"
        rows.append(f"| {mname} | " + " | ".join(cells) + f" | {ref_s} |")
    return rows, csv


# paper-reported numbers for the comparison column (Tables 2-4)
_PAPER_T2 = {
    "naive": {"d1": "100,500", "d2": "100,500", "d3": "100,500"},
    "voting": {"d1": "100,500", "d2": "100,500", "d3": "50,500"},
    "random": {"d1": "100,65", "d2": "100,65", "d3": "99.6,65"},
    "maxmarg": {"d1": "100,4", "d2": "100,4", "d3": "100,12"},
    "median": {"d1": "100,6", "d2": "100,6", "d3": "100,10"},
}
_PAPER_T3 = {
    "naive": {"d1": "100,500", "d2": "100,500", "d3": "100,500"},
    "voting": {"d1": "100,500", "d2": "100,500", "d3": "81.8,500"},
    "random": {"d1": "100,100", "d2": "100,100", "d3": "99.1,100"},
    "maxmarg": {"d1": "100,4", "d2": "100,4", "d3": "98.3,40"},
}
_PAPER_T4 = {
    "naive": {"d1": "100,1500", "d2": "100,1500", "d3": "100,1500"},
    "voting": {"d1": "98.8,1500", "d2": "100,1500", "d3": "50,1500"},
    "random": {"d1": "100,195", "d2": "100,195", "d3": "99.8,195"},
    "maxmarg": {"d1": "97.6,14", "d2": "100,2", "d3": "97.4,38"},
    "median": {"d1": "99.0,36", "d2": "100,6", "d3": "98.8,29"},
}


def table2():
    sets = {f"d{i}": gen(n_per_node=250, k=2, seed=0)
            for i, gen in ((1, datasets.data1), (2, datasets.data2), (3, datasets.data3))}
    med, t_med = _engine_median_batch(sets, EPS, max_epochs=32)
    return _run_table(sets, _two_party_methods(), "Table 2 (2-party, 2-D)",
                      _PAPER_T2, precomputed={"median": med},
                      pre_times={"median": t_med})


def table3():
    sets = {f"d{i}": datasets.lift_dim(gen(n_per_node=250, k=2, seed=0), d=10, seed=i)
            for i, gen in ((1, datasets.data1), (2, datasets.data2), (3, datasets.data3))}
    methods = _two_party_methods()
    methods.pop("median")  # paper Table 3 runs MAXMARG only in d=10 (MEDIAN is 2-D)
    return _run_table(sets, methods, "Table 3 (2-party, d=10)", _PAPER_T3)


def table4():
    sets = {f"d{i}": gen(n_per_node=125, k=4, seed=0)
            for i, gen in ((1, datasets.data1), (2, datasets.data2), (3, datasets.data3))}
    med, t_med = _engine_median_batch(sets, EPS, max_epochs=48)
    return _run_table(sets, _k_party_methods(), "Table 4 (4-party, 2-D)",
                      _PAPER_T4, precomputed={"median": med},
                      pre_times={"median": t_med})


def main() -> List[str]:
    all_rows, all_csv = [], []
    for fn in (table2, table3, table4):
        rows, csv = fn()
        all_rows += rows + [""]
        all_csv += csv
    print("\n".join(all_rows))
    return all_csv


if __name__ == "__main__":
    main()
