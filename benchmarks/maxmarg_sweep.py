"""Batched engine MAXMARG vs the retired host loop (BENCH_maxmarg.json).

Counterpart of ``engine_sweep.py`` for the second compiled selector: the
same ≥12-instance paper-style grid (dataset × ε × seed, two-party MAXMARG)
runs three ways:

  sequential  the pre-engine execution model — a host-side Python round
              loop with one ``fit_max_margin`` device call per turn
              (benchmarks/legacy_maxmarg.py);
  engine B=1  the public per-instance API (engine at B=1) in a Python loop;
  batched     one ``repro.engine.maxmarg`` sweep on the hot path
              (warm-started, compacted refits — the default).

Two additional batched series isolate the hot path's layers (DESIGN.md
§warm-start & transcript compaction): ``batched_cold_padded_s`` replays the
pre-hot-path execution model (cold refits at worst-case padding, one
while_loop dispatch — the PR 2 number on this machine, and the ≥1.5×
acceptance baseline), and the ``warm_vs_cold`` / ``compacted_vs_padded``
series toggle one layer each.

It asserts exact parity (converged flags + comm totals + rounds) between
the batched sweep and the engine's B=1 path AND between warm and cold
execution, cross-checks the legacy host loop as a differential oracle, and
records wall-clocks to BENCH_maxmarg.json at the repo root.  ``--tiny``
shrinks the grid for the CI smoke job and writes BENCH_maxmarg.tiny.json
instead (same schema, including every warm/compaction field), so a smoke
run can never clobber the committed full-size acceptance record.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro import engine
from repro.core import datasets
from repro.core.protocols import kparty

from benchmarks import _timing as timing
from benchmarks.legacy_maxmarg import kparty_maxmarg_hostloop

# MAXMARG converges in 1-4 epochs on every paper grid; a tight epoch bound
# keeps the engine's static transcript capacity (and with it the padded
# per-turn refit width n_max + cap) proportionate.  The sweep regime is the
# engine's target: many small-to-mid instances, where the host loop's
# per-instance fit dispatches dominate (BENCH notes).
MAX_EPOCHS = 8
MAX_SUPPORT = 4   # pinned and passed to all three execution models below
OUT = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "BENCH_maxmarg.json")


def build_instances(n_per_node: int = 128,
                    seeds=(0, 1, 2)) -> List[engine.ProtocolInstance]:
    """Two-party MAXMARG grid: 3 datasets × 3 ε × seeds (≥12 instances)."""
    insts = []
    for gen in (datasets.data1, datasets.data2, datasets.data3):
        for eps in (0.05, 0.02, 0.01):
            for seed in seeds:
                insts.append(engine.ProtocolInstance(
                    gen(n_per_node=n_per_node, k=2, seed=seed), eps,
                    "maxmarg"))
    return insts


def build_pn_instances(n_per_node: int = 100) -> List[engine.ProtocolInstance]:
    """k=4 multi-epoch grid for the per-node warm-carry series.  The k=2
    headline grid cannot exercise it (per-node adoption at k=2 provably
    implies termination, so the mechanism is statically skipped there);
    these mixed hard/easy partitions run ≥ 2 epochs and actually latch."""
    return [engine.ProtocolInstance(
                datasets.data_mixed_hardness(n_per_node=n_per_node, k=4,
                                             seed=0), eps, "maxmarg")
            for eps in (0.05, 0.02)]


def _run_hostloop(insts):
    """The sequential loop the engine replaced: one host-side Python round
    loop per instance, one solver dispatch per round."""
    return [kparty_maxmarg_hostloop(inst.shards, eps=inst.eps,
                                    max_epochs=MAX_EPOCHS,
                                    max_support=MAX_SUPPORT)
            for inst in insts]


def _run_engine_b1(insts):
    """Per-instance public API (engine with B=1), in a Python loop."""
    return [kparty.iterative_support_kparty(
                inst.shards, eps=inst.eps, max_epochs=MAX_EPOCHS,
                selector="maxmarg", max_support=MAX_SUPPORT)
            for inst in insts]


def _run_batched(insts, warm=True, compact=True, per_node=True):
    return engine.maxmarg.run_instances(insts, max_epochs=MAX_EPOCHS,
                                        max_support=MAX_SUPPORT,
                                        warm=warm, compact=compact,
                                        per_node=per_node)


def main(tiny: bool = False) -> List[str]:
    insts = build_instances(n_per_node=40, seeds=(0,)) if tiny \
        else build_instances()
    pn_insts = build_pn_instances(n_per_node=40 if tiny else 100)
    B = len(insts)

    # warm up every engine program shape (hot/cold × padded/compacted, B=1,
    # the k=4 per-node grid in all three warm modes) and the host loop's
    # solver cache, then time everything on the shared interleaved harness
    # (benchmarks/_timing.py).
    for w, c in ((True, True), (False, True), (False, False)):
        _run_batched(insts, warm=w, compact=c)
    for pn in (True, False):
        _run_batched(pn_insts, per_node=pn)
    _run_engine_b1(insts[:1])
    _run_hostloop(insts[:1])

    # the hot/cold batched dispatches are tens of ms — take enough repeats
    # that the recorded minima are stable against machine noise
    repeats = 1 if tiny else 15
    series = {
        "seq": lambda: _run_hostloop(insts),
        "b1": lambda: _run_engine_b1(insts),
        "bat": lambda: _run_batched(insts),               # hot: warm+compact
        "cold_c": lambda: _run_batched(insts, warm=False, compact=True),
        "cold_p": lambda: _run_batched(insts, warm=False, compact=False),
        # per-node-vs-single warm carries, on the k=4 multi-epoch grid
        # where the mechanism actually engages
        "pn": lambda: _run_batched(pn_insts),
        "pn_single": lambda: _run_batched(pn_insts, per_node=False),
    }
    out, times = timing.interleaved(series, repeats)
    seq, t_seq = out["seq"], timing.tmin(times, "seq")
    b1, t_b1 = out["b1"], timing.tmin(times, "b1")
    bat, t_bat = out["bat"], timing.tmin(times, "bat")
    cold_c, t_cold_c = out["cold_c"], timing.tmin(times, "cold_c")
    cold_p, t_cold_p = out["cold_p"], timing.tmin(times, "cold_p")
    pn_res, t_pn = out["pn"], timing.tmin(times, "pn")
    pn_single, t_pn_single = out["pn_single"], timing.tmin(times, "pn_single")

    def ratio(num, den):
        return timing.ratio(times, num, den)

    mismatches = []          # engine batched vs engine B=1 — must be exact
    legacy_disagree = []     # retired host loop — differential oracle
    warm_cold_bad = []       # warm vs cold decisions — must be exact
    per_node_bad = []        # per-node grid: both warm modes vs cold — exact
    pn_cold = _run_batched(pn_insts, warm=False, compact=False)
    for i, (rp, rn, rc) in enumerate(zip(pn_res, pn_single, pn_cold)):
        for r in (rp, rn):
            if not (r.converged == rc.converged and r.comm == rc.comm
                    and r.rounds == rc.rounds):
                per_node_bad.append(i)
                break
    per_instance = []
    for i, (inst, rs, r1, rb, rc) in enumerate(
            zip(insts, seq, b1, bat, cold_p)):
        X = np.concatenate([s[0] for s in inst.shards])
        y = np.concatenate([s[1] for s in inst.shards])
        err = float(np.mean(rb.classifier.predict(X) != y))
        ok = (r1.converged == rb.converged and r1.comm == rb.comm
              and r1.rounds == rb.rounds)
        if not ok:
            mismatches.append(i)
        if not (rs.converged == rb.converged and rs.comm == rb.comm
                and rs.rounds == rb.rounds):
            legacy_disagree.append(i)
        if not (rc.converged == rb.converged and rc.comm == rb.comm
                and rc.rounds == rb.rounds):
            warm_cold_bad.append(i)
        per_instance.append({
            "eps": inst.eps,
            "converged": bool(rb.converged),
            "rounds": rb.rounds,
            "points": rb.comm["points"],
            "bytes": rb.comm["bytes"],
            "global_err": err,
            "err_within_eps": bool(err <= inst.eps),
            "parity_b1": ok,
        })

    speedup = ratio("seq", "bat")
    speedup_cold_padded = ratio("cold_p", "bat")
    report = {
        "notes": (
            "sequential_s = the retired per-instance execution model for the "
            "MAXMARG selector (host-side Python round loop, one "
            "fit_max_margin dispatch per turn; benchmarks/legacy_maxmarg.py)."
            "  batched_s = the engine hot path for the whole sweep "
            "(warm-started refits + width/batch-compacted dispatches, "
            "repro.engine.maxmarg.run_hot).  batched_cold_padded_s replays "
            "the pre-hot-path engine (cold refits at worst-case padded "
            "width, one while_loop dispatch) — the PR 2 execution model on "
            "this machine, so speedup_vs_cold_padded is the hot path's "
            "acceptance number (bar: >= 1.5).  warm_vs_cold and "
            "compacted_vs_padded each toggle one hot-path layer at a time.  "
            "per_node_warm compares the default per-node warm-carry mode "
            "(each node polishes the last proposal it verified clean; "
            "latches_* total the solver's warm-gate hits) against the PR 4 "
            "single previous-turn carry, measured on a separate k=4 "
            "multi-epoch mixed-hardness grid "
            "(datasets.data_mixed_hardness) — per-node adoption at k=2 "
            "provably implies termination, so the headline grid cannot "
            "engage the mechanism.  engine_b1_loop_s = the public "
            "per-instance API (engine at B=1) "
            "in a Python loop.  legacy_oracle_disagreements, "
            "warm_cold_mismatch_indices and per_node_mismatch_indices list "
            "instances whose comm totals / "
            "rounds / convergence differ from the host-loop oracle resp. "
            "between warm modes — the acceptance bar is all "
            "empty.  Timings are minima of interleaved repeats on a warm "
            "cache (one-sided scheduler noise only inflates wall-clocks, "
            "and interleaving shows every series the same machine phases, "
            "stabilizing the recorded ratios)."),
        "instances": B,
        "tiny": tiny,
        "max_epochs": MAX_EPOCHS,
        "max_support": MAX_SUPPORT,
        "sequential_s": round(t_seq, 4),       # legacy host round loop
        "batched_s": round(t_bat, 4),          # hot path (the default)
        "speedup": round(speedup, 2),
        "engine_b1_loop_s": round(t_b1, 4),    # per-instance engine loop
        "speedup_vs_engine_b1": round(ratio("b1", "bat"), 2),
        "batched_cold_padded_s": round(t_cold_p, 4),   # PR 2 model
        "speedup_vs_cold_padded": round(speedup_cold_padded, 2),
        "warm_vs_cold": {
            "warm_s": round(t_bat, 4),
            "cold_s": round(t_cold_c, 4),      # compacted either way
            "speedup": round(ratio("cold_c", "bat"), 2),
        },
        "compacted_vs_padded": {
            "compacted_s": round(t_cold_c, 4),  # cold either way
            "padded_s": round(t_cold_p, 4),
            "speedup": round(ratio("cold_p", "cold_c"), 2),
        },
        "per_node_warm": {
            "instances": len(pn_insts),         # the k=4 multi-epoch grid
            "rounds": [r.rounds for r in pn_res],
            "per_node_s": round(t_pn, 4),       # default warm-carry mode
            "single_carry_s": round(t_pn_single, 4),
            "speedup": round(ratio("pn_single", "pn"), 2),
            "latches_per_node": sum(r.extra["warm_latches"] for r in pn_res),
            "latches_single_carry": sum(r.extra["warm_latches"]
                                        for r in pn_single),
        },
        "per_node_mismatch_indices": per_node_bad,
        "parity_b1_ok": not mismatches,
        "parity_b1_mismatch_indices": mismatches,
        "legacy_oracle_disagreements": legacy_disagree,
        "warm_cold_mismatch_indices": warm_cold_bad,
        "all_converged": all(p["converged"] for p in per_instance),
        "all_err_within_eps": all(p["err_within_eps"] for p in per_instance),
        "per_instance": per_instance,
    }
    out = OUT.replace(".json", ".tiny.json") if tiny else OUT
    with open(out, "w") as f:
        json.dump(report, f, indent=1)

    print(f"maxmarg sweep: {B} instances  sequential(host loop) {t_seq:.2f}s  "
          f"batched(hot) {t_bat:.3f}s  cold-padded {t_cold_p:.3f}s  "
          f"hot-vs-PR2 {report['speedup_vs_cold_padded']:.2f}x  "
          f"B=1-parity={'OK' if not mismatches else mismatches}")
    print(f"(engine B=1 loop {t_b1:.2f}s; legacy-oracle disagreements: "
          f"{legacy_disagree or 'none'}; warm-cold mismatches: "
          f"{warm_cold_bad or 'none'})")
    print(f"wrote {out}")
    return [f"maxmarg_sweep/batched,{t_bat * 1e6 / B:.0f},"
            f"speedup={speedup:.2f};instances={B};"
            f"hot_vs_cold_padded={report['speedup_vs_cold_padded']:.2f}",
            f"maxmarg_sweep/sequential,{t_seq * 1e6 / B:.0f},"
            f"parity_b1={'ok' if not mismatches else 'FAIL'}"]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke sizes (small shards, 1 repeat)")
    main(tiny=ap.parse_args().tiny)
