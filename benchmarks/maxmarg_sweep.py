"""Batched engine MAXMARG vs the retired host loop (BENCH_maxmarg.json).

Counterpart of ``engine_sweep.py`` for the second compiled selector: the
same ≥12-instance paper-style grid (dataset × ε × seed, two-party MAXMARG)
runs three ways:

  sequential  the pre-engine execution model — a host-side Python round
              loop with one ``fit_max_margin`` device call per turn
              (benchmarks/legacy_maxmarg.py);
  engine B=1  the public per-instance API (engine at B=1) in a Python loop;
  batched     one ``repro.engine.maxmarg`` sweep, every per-turn hard-margin
              refit one vmapped Pegasos dispatch for the whole batch.

It asserts exact parity (converged flags + comm totals + rounds) between
the batched sweep and the engine's B=1 path, cross-checks the legacy host
loop as a differential oracle, and records wall-clocks to BENCH_maxmarg.json
at the repo root.  ``--tiny`` shrinks the grid for the CI smoke job and
writes BENCH_maxmarg.tiny.json instead, so a smoke run can never clobber
the committed full-size acceptance record.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import List

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro import engine
from repro.core import datasets
from repro.core.protocols import kparty

from benchmarks.legacy_maxmarg import kparty_maxmarg_hostloop

# MAXMARG converges in 1-4 epochs on every paper grid; a tight epoch bound
# keeps the engine's static transcript capacity (and with it the padded
# per-turn refit width n_max + cap) proportionate.  The sweep regime is the
# engine's target: many small-to-mid instances, where the host loop's
# per-instance fit dispatches dominate (BENCH notes).
MAX_EPOCHS = 8
MAX_SUPPORT = 4   # pinned and passed to all three execution models below
OUT = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "BENCH_maxmarg.json")


def build_instances(n_per_node: int = 128,
                    seeds=(0, 1, 2)) -> List[engine.ProtocolInstance]:
    """Two-party MAXMARG grid: 3 datasets × 3 ε × seeds (≥12 instances)."""
    insts = []
    for gen in (datasets.data1, datasets.data2, datasets.data3):
        for eps in (0.05, 0.02, 0.01):
            for seed in seeds:
                insts.append(engine.ProtocolInstance(
                    gen(n_per_node=n_per_node, k=2, seed=seed), eps,
                    "maxmarg"))
    return insts


def _run_hostloop(insts):
    """The sequential loop the engine replaced: one host-side Python round
    loop per instance, one solver dispatch per round."""
    return [kparty_maxmarg_hostloop(inst.shards, eps=inst.eps,
                                    max_epochs=MAX_EPOCHS,
                                    max_support=MAX_SUPPORT)
            for inst in insts]


def _run_engine_b1(insts):
    """Per-instance public API (engine with B=1), in a Python loop."""
    return [kparty.iterative_support_kparty(
                inst.shards, eps=inst.eps, max_epochs=MAX_EPOCHS,
                selector="maxmarg", max_support=MAX_SUPPORT)
            for inst in insts]


def _run_batched(insts):
    return engine.maxmarg.run_instances(insts, max_epochs=MAX_EPOCHS,
                                        max_support=MAX_SUPPORT)


def main(tiny: bool = False) -> List[str]:
    insts = build_instances(n_per_node=40, seeds=(0,)) if tiny \
        else build_instances()
    B = len(insts)

    # warm up both engine program shapes (full B and B=1) and the host
    # loop's solver cache, then time everything (median of repeats).
    _run_batched(insts)
    _run_engine_b1(insts[:1])
    _run_hostloop(insts[:1])

    repeats = 1 if tiny else 3

    def timed(fn):
        times = []
        for _ in range(repeats):
            t0 = time.time()
            out = fn(insts)
            times.append(time.time() - t0)
        return out, float(np.median(times))

    seq, t_seq = timed(_run_hostloop)
    b1, t_b1 = timed(_run_engine_b1)
    bat, t_bat = timed(_run_batched)

    mismatches = []          # engine batched vs engine B=1 — must be exact
    legacy_disagree = []     # retired host loop — differential oracle
    per_instance = []
    for i, (inst, rs, r1, rb) in enumerate(zip(insts, seq, b1, bat)):
        X = np.concatenate([s[0] for s in inst.shards])
        y = np.concatenate([s[1] for s in inst.shards])
        err = float(np.mean(rb.classifier.predict(X) != y))
        ok = (r1.converged == rb.converged and r1.comm == rb.comm
              and r1.rounds == rb.rounds)
        if not ok:
            mismatches.append(i)
        if not (rs.converged == rb.converged and rs.comm == rb.comm
                and rs.rounds == rb.rounds):
            legacy_disagree.append(i)
        per_instance.append({
            "eps": inst.eps,
            "converged": bool(rb.converged),
            "rounds": rb.rounds,
            "points": rb.comm["points"],
            "bytes": rb.comm["bytes"],
            "global_err": err,
            "err_within_eps": bool(err <= inst.eps),
            "parity_b1": ok,
        })

    speedup = t_seq / max(t_bat, 1e-9)
    report = {
        "notes": (
            "sequential_s = the retired per-instance execution model for the "
            "MAXMARG selector (host-side Python round loop, one "
            "fit_max_margin dispatch per turn; benchmarks/legacy_maxmarg.py)."
            "  batched_s = one repro.engine.maxmarg dispatch for the whole "
            "sweep: per turn, every instance's hard-margin refit runs as one "
            "vmapped annealed-Pegasos solve.  engine_b1_loop_s = the public "
            "per-instance API (engine at B=1) in a Python loop.  "
            "legacy_oracle_disagreements lists instances where the engine's "
            "comm totals / rounds / convergence differ from the host loop — "
            "the acceptance bar is an empty list.  Timings are medians of "
            "repeats on a warm cache."),
        "instances": B,
        "tiny": tiny,
        "max_epochs": MAX_EPOCHS,
        "max_support": MAX_SUPPORT,
        "sequential_s": round(t_seq, 4),       # legacy host round loop
        "batched_s": round(t_bat, 4),          # one engine dispatch
        "speedup": round(speedup, 2),
        "engine_b1_loop_s": round(t_b1, 4),    # per-instance engine loop
        "speedup_vs_engine_b1": round(t_b1 / max(t_bat, 1e-9), 2),
        "parity_b1_ok": not mismatches,
        "parity_b1_mismatch_indices": mismatches,
        "legacy_oracle_disagreements": legacy_disagree,
        "all_converged": all(p["converged"] for p in per_instance),
        "all_err_within_eps": all(p["err_within_eps"] for p in per_instance),
        "per_instance": per_instance,
    }
    out = OUT.replace(".json", ".tiny.json") if tiny else OUT
    with open(out, "w") as f:
        json.dump(report, f, indent=1)

    print(f"maxmarg sweep: {B} instances  sequential(host loop) {t_seq:.2f}s  "
          f"batched {t_bat:.2f}s  speedup {speedup:.1f}x  "
          f"B=1-parity={'OK' if not mismatches else mismatches}")
    print(f"(engine B=1 loop {t_b1:.2f}s; legacy-oracle disagreements: "
          f"{legacy_disagree or 'none'})")
    print(f"wrote {out}")
    return [f"maxmarg_sweep/batched,{t_bat * 1e6 / B:.0f},"
            f"speedup={speedup:.2f};instances={B}",
            f"maxmarg_sweep/sequential,{t_seq * 1e6 / B:.0f},"
            f"parity_b1={'ok' if not mismatches else 'FAIL'}"]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke sizes (small shards, 1 repeat)")
    main(tiny=ap.parse_args().tiny)
