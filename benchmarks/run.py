"""Benchmark aggregator — one section per paper table/figure + system perf.

Sections:
  paper_tables    Tables 2 / 3 / 4 (accuracy + communication cost)
  comm_scaling    Table 1 rate claims: cost vs ε and vs k
  engine_sweep    batched engine vs sequential per-instance sweeps
                  (writes BENCH_engine.json at the repo root)
  maxmarg_sweep   batched MAXMARG selector vs the retired host loop
                  (writes BENCH_maxmarg.json at the repo root)
  lower_bound     Appendix A (Ω(1/ε)) and Appendix B (Ω(|D_A|)) constructions
  kernel_bench    data-plane hot-loop timings
  roofline_table  §Roofline terms from the dry-run artifacts (if present)

Prints a final ``name,us_per_call,derived`` CSV block.
"""

from __future__ import annotations

import os
import sys
import traceback
from typing import List

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import comm_scaling, engine_sweep, kernel_bench, lower_bound
from benchmarks import maxmarg_sweep, paper_tables, roofline_table


def main() -> None:
    csv: List[str] = []
    sections = [
        ("paper tables (2/3/4)", paper_tables.main),
        ("communication scaling (Table 1 rates)", comm_scaling.main),
        ("engine sweep (batched vs sequential)", engine_sweep.main),
        ("maxmarg sweep (batched vs host loop)", maxmarg_sweep.main),
        ("lower bounds (App A/B)", lower_bound.main),
        ("kernel micro-bench", kernel_bench.main),
    ]
    for title, fn in sections:
        print(f"\n{'=' * 72}\n== {title}\n{'=' * 72}")
        try:
            csv += fn() or []
        except Exception:  # noqa: BLE001 — keep the suite running
            traceback.print_exc()
            csv.append(f"{title},0,ERROR")
    if os.path.exists(roofline_table.RESULTS):
        for mesh in ("single", "multi"):
            print(f"\n{'=' * 72}\n== roofline ({mesh})\n{'=' * 72}")
            try:
                csv += roofline_table.main(mesh) or []
            except Exception:
                traceback.print_exc()
    else:
        print("\n(no dryrun.jsonl — run `python -m repro.launch.dryrun` for the "
              "roofline section)")

    print(f"\n{'=' * 72}\n== CSV\n{'=' * 72}")
    print("name,us_per_call,derived")
    for line in csv:
        print(line)


if __name__ == "__main__":
    main()
