"""Sharding rules: param/optimizer/cache/batch pytrees → NamedShardings.

Scheme (Megatron-style tensor parallel on the "model" axis + data parallel
on ("pod","data") + ZeRO-1 optimizer-state sharding):

* column-parallel (shard output dim): wq/wk/wv/wi/up-projections, router,
  expert dim of MoE weights (expert parallel) when divisible;
* row-parallel (shard input dim): wo/down-projections;
* embeddings shard the vocab dim (fallback d_model when vocab % model != 0,
  e.g. whisper's 51865);
* stacked-period leading axes are never sharded;
* anything non-divisible falls back to the next divisible dim, else
  replication — this is what absorbs head counts (9, 12, 40, 48) that do not
  divide the 16-way model axis;
* optimizer moments inherit the param spec plus "data" on the largest
  remaining free dim (ZeRO-1) — required for the 398B/314B configs to fit
  16 GB/chip;
* decode caches shard batch on "data" ("pod","data" multi-pod); the batch=1
  long-context shape shards the cache *sequence* dim on "data" instead
  (cache sequence parallelism).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import InputShape, ModelConfig

# param keys that are column-parallel (shard LAST dim) / row-parallel (shard
# first non-stack dim).  Keys not listed fall back to shape-driven choice.
_COL = {"wq", "wk", "wv", "wi", "wg", "wgate", "wup", "wr", "wdq", "wuq",
        "wdkv", "wuk", "wuv", "wkr", "in_x", "in_z", "dt_proj",
        "shared_wg", "shared_wu", "router", "wA", "wB",
        "bq", "bk", "bv", "conv_b", "dt_bias", "D"}
_ROW = {"wo", "out_proj", "shared_wo"}
# MoE expert weights: shard expert dim when divisible (expert parallel)
_EXPERT = {"we_g", "we_u", "we_o"}


def _div(n: int, size: int) -> bool:
    return size > 0 and n % size == 0


def _spec_for(path: Tuple, shape: Tuple[int, ...], model: int) -> P:
    keys = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
    name = keys[-1]
    stacked = "blocks" in "/".join(keys)  # leading n_periods axis
    nd = len(shape)
    lead = 1 if (stacked and nd >= 2) else 0
    spec = [None] * nd

    def try_dim(i: int) -> bool:
        if i < nd and _div(shape[i], model):
            spec[i] = "model"
            return True
        return False

    if name in _EXPERT and nd >= 3:
        # (L, E, d, f) or (E, d, f): expert dim first after stack
        if try_dim(lead):
            return P(*spec)
        # fallback: Megatron TP inside experts — up-projections shard their
        # OUTPUT dim (f, last), the down-projection its CONTRACTING dim
        # (ffe, second-to-last).  Sharding we_o's output dim instead forces
        # an all-gather of the full (B, E, cap, ffe) intermediate (observed
        # 20 TB/device on grok-1 — EXPERIMENTS.md §Perf iteration 1).
        if name == "we_o":
            if try_dim(nd - 2) or try_dim(nd - 1):
                return P(*spec)
        else:
            if try_dim(nd - 1) or try_dim(nd - 2):
                return P(*spec)
        return P(*spec)
    if name == "embed":
        if try_dim(0) or try_dim(1):
            return P(*spec)
        return P(*spec)
    if name == "lm_head":
        if try_dim(1) or try_dim(0):
            return P(*spec)
        return P(*spec)
    if name in _COL:
        for i in range(nd - 1, lead - 1, -1):
            if try_dim(i):
                return P(*spec)
        return P(*spec)
    if name in _ROW:
        if try_dim(lead) or try_dim(nd - 1):
            return P(*spec)
        return P(*spec)
    # fallback for 2D+: prefer last dim, then earlier ones
    if nd - lead >= 2:
        for i in range(nd - 1, lead - 1, -1):
            if try_dim(i):
                return P(*spec)
    elif nd - lead == 1 and shape[lead] >= 4096 and _div(shape[lead], model):
        spec[lead] = "model"
    return P(*spec)


def param_shardings(mesh: Mesh, param_tree: Any, fsdp: bool = False,
                    pure_dp: bool = False) -> Any:
    """NamedSharding tree for a param (or param-shape) pytree.

    ``fsdp=True`` additionally shards the largest remaining free dim over
    "data" (fully-sharded weights; GSPMD all-gathers per layer).  Required
    for the 100B+ configs — 16-way tensor parallel alone leaves >16 GB of
    weights per chip.

    ``pure_dp=True`` replicates all weights (no tensor parallelism) — the
    right choice for models whose head counts do not divide the model axis
    (e.g. smollm's 9 heads vs 16 ranks replicate the whole attention
    computation 16× under TP; EXPERIMENTS.md §Perf iteration 2).
    """
    model = mesh.shape.get("model", 1)
    data = mesh.shape.get("data", 1)

    def f(path, leaf):
        spec = [None] * len(leaf.shape) if pure_dp else \
            list(_spec_for(path, leaf.shape, model))
        if fsdp:
            free = [i for i, s in enumerate(spec) if s is None]
            free.sort(key=lambda i: -leaf.shape[i])
            for i in free:
                if _div(leaf.shape[i], data) and leaf.shape[i] >= data * 8:
                    spec[i] = "data"
                    break
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(f, param_tree)


def opt_shardings(mesh: Mesh, opt_tree: Any, fsdp: bool = False,
                  pure_dp: bool = False) -> Any:
    """Moments: param spec + ZeRO-1 "data" sharding on the largest free dim
    (skipped when FSDP already spent the data axis on that leaf)."""
    model = mesh.shape.get("model", 1)
    data = mesh.shape.get("data", 1)

    def f(path, leaf):
        keys = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        if keys and keys[-1] == "step":
            return NamedSharding(mesh, P())
        # strip the leading "mu"/"nu" path element for rule lookup
        sub = tuple(k for k in path if str(getattr(k, "key", "")) not in ("mu", "nu"))
        spec = [None] * len(leaf.shape) if pure_dp else \
            list(_spec_for(sub or path, leaf.shape, model))
        free = [i for i, s in enumerate(spec) if s is None]
        free.sort(key=lambda i: -leaf.shape[i])
        for i in free:
            if _div(leaf.shape[i], data) and leaf.shape[i] >= data * 8:
                spec[i] = "data"
                break
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(f, opt_tree)


def batch_shardings(mesh: Mesh, batch_tree: Any, shape: InputShape,
                    pure_dp: bool = False) -> Any:
    """Batch dim over ("pod","data") when divisible; batch=1 long-context
    replicates (its parallelism lives in the cache sequence dim).

    ``pure_dp=True`` additionally folds the idle "model" axis into the batch
    axes (the whole mesh becomes data-parallel)."""
    wanted = ("pod", "data", "model") if pure_dp else ("pod", "data")
    axes = [a for a in wanted if a in mesh.shape]
    dp = 1
    for a in axes:
        dp *= mesh.shape[a]
    dp_axes = tuple(axes) if len(axes) > 1 else (axes[0] if axes else None)

    def f(path, leaf):
        keys = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        nd = len(leaf.shape)
        bdim = 1 if keys and keys[-1] == "rope_pos" else 0  # (3, B, S)
        spec = [None] * nd
        if leaf.shape[bdim] % dp == 0 and leaf.shape[bdim] >= dp:
            spec[bdim] = dp_axes
        elif "data" in mesh.shape and leaf.shape[bdim] % mesh.shape["data"] == 0 \
                and leaf.shape[bdim] >= mesh.shape["data"]:
            spec[bdim] = "data"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(f, batch_tree)


def cache_shardings(mesh: Mesh, cache_tree: Any, shape: InputShape,
                    cfg: ModelConfig, pure_dp: bool = False) -> Any:
    """Decode caches.  Leaf layouts (with stacked period lead dim L):
    k/v (L,B,S,KV,hd) · ckv (L,B,S,kvl) · krope (L,B,S,r) · mamba h
    (L,B,di,ds) · conv (L,B,dc-1,di) · rwkv wkv (L,B,H,hd,hd) · shifts
    (L,B,d) · cross k/v (L,B,Se,KV,hd)."""
    model = 0 if pure_dp else mesh.shape.get("model", 1)  # 0: _div() rejects
    data = mesh.shape.get("data", 1)
    wanted = ("pod", "data", "model") if pure_dp else ("pod", "data")
    axes = [a for a in wanted if a in mesh.shape]
    dp = 1
    for a in axes:
        dp *= mesh.shape[a]
    dp_axes = tuple(axes) if len(axes) > 1 else (axes[0] if axes else None)
    B = shape.global_batch
    seq_shard = B == 1  # long-context single stream: shard the cache seq dim

    def f(path, leaf):
        keys = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        name = keys[-1]
        nd = len(leaf.shape)
        spec = [None] * nd
        # batch dim is axis 1 (stack lead at 0); fall back to smaller axis
        # subsets when the batch does not divide the full dp product
        if not seq_shard and nd >= 2:
            for cand in (axes, axes[:-1], axes[:1]):
                cdp = 1
                for a in cand:
                    cdp *= mesh.shape[a]
                if cand and _div(leaf.shape[1], cdp) and leaf.shape[1] >= cdp:
                    spec[1] = tuple(cand) if len(cand) > 1 else cand[0]
                    break
        if name in ("k", "v", "cross_k", "cross_v"):  # (L,B,S,KV,hd)
            if seq_shard and _div(leaf.shape[2], data):
                spec[2] = "data"
            if _div(leaf.shape[3], model):
                spec[3] = "model"
            elif _div(leaf.shape[4], model):
                spec[4] = "model"
        elif name in ("ckv", "krope"):  # (L,B,S,lat)
            if seq_shard and _div(leaf.shape[2], data):
                spec[2] = "data"
            if _div(leaf.shape[3], model):
                spec[3] = "model"
        elif name == "h":  # (L,B,di,ds)
            if _div(leaf.shape[2], model):
                spec[2] = "model"
        elif name == "conv":  # (L,B,dc-1,di)
            if _div(leaf.shape[3], model):
                spec[3] = "model"
        elif name == "tmix_wkv":  # (L,B,H,hd,hd)
            if _div(leaf.shape[2], model):
                spec[2] = "model"
        elif name in ("tmix_shift", "cmix_shift"):  # (L,B,d)
            if _div(leaf.shape[2], model):
                spec[2] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(f, cache_tree)
