"""Activation sharding constraints, mesh-optional.

Model code calls :func:`constrain` with a logical spec; under a jit that
carries a mesh (the production lowering path) the constraint pins GSPMD's
propagation (batch dim stays on the data axes through microbatch slicing,
MoE dispatch and attention).  With no ambient mesh (CPU smoke tests) it is a
no-op, so the model stays mesh-agnostic.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P


def _mesh_axes() -> Optional[Tuple[str, ...]]:
    m = jax.sharding.get_abstract_mesh()
    if m is None or not m.axis_names:
        return None
    return tuple(m.axis_names)


_DP_OVERRIDE: Optional[Tuple[str, ...]] = None


def set_dp_axes(axes: Optional[Tuple[str, ...]]) -> None:
    """Override which mesh axes count as data-parallel (the launcher sets
    ("pod","data","model") for pure-DP small-model policies)."""
    global _DP_OVERRIDE
    _DP_OVERRIDE = axes


def batch_axes() -> Optional[Any]:
    axes = _mesh_axes()
    if axes is None:
        return None
    wanted = _DP_OVERRIDE if _DP_OVERRIDE is not None else ("pod", "data")
    dp = tuple(a for a in wanted if a in axes)
    if not dp:
        return None
    return dp if len(dp) > 1 else dp[0]


def model_axis_size() -> int:
    """Size of the "model" mesh axis (0 when absent / no mesh)."""
    m = jax.sharding.get_abstract_mesh()
    if m is None or not m.axis_names or "model" not in m.axis_names:
        return 0
    if _DP_OVERRIDE and "model" in _DP_OVERRIDE:
        return 0  # pure-DP: the model axis is spent on the batch
    return m.shape["model"]


def constrain(x, *spec):
    """with_sharding_constraint that degrades to identity without a mesh."""
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:  # noqa: BLE001 — no mesh / axis absent: stay agnostic
        return x


def constrain_batch_dim(x, bdim: int = 0):
    """Pin x's ``bdim`` to the data-parallel axes (if the dim divides)."""
    dp = batch_axes()
    if dp is None:
        return x
    names = dp if isinstance(dp, tuple) else (dp,)
    m = jax.sharding.get_abstract_mesh()
    total = 1
    for a in names:
        total *= m.shape[a]
    if x.shape[bdim] % total != 0 or x.shape[bdim] < total:
        return x
    spec = [None] * x.ndim
    spec[bdim] = dp
    return constrain(x, *spec)
