from repro.distribution.sharding import (  # noqa: F401
    batch_shardings,
    cache_shardings,
    opt_shardings,
    param_shardings,
)
