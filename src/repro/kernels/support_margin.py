"""Pallas TPU kernels for the paper's data-plane hot loop.

The IterativeSupports protocol (paper §4–5) spends its cycles in two bulk
scans over a node's local shard, both of the shape "project n points onto m
candidate directions and reduce":

  1. ``threshold_ranges``: per direction v, the consistent-threshold interval
     (lo, hi) = (max_{y=+1} v·x, min_{y=-1} v·x) over the protocol transcript
     — a (m, n) matmul with a masked row max/min fused in, never
     materializing the (m, n) projection matrix in HBM.
  2. ``uncertain_count``: given (lo, hi, dir_ok) per direction, decide for
     every local point whether *some* consistent classifier can still
     misclassify it (SOU membership, paper §4.1) — the same matmul shape
     with an any-reduce over directions.

On a v5e these tiles are MXU work: the d-dim contraction is zero-padded to
the 128 lane width by the wrapper in ``ops.py`` (the paper's experiments are
d=2..10; padding is free relative to restructuring).  Grid layout puts the
reduction axis innermost/sequential so the running reduction lives in a VMEM
scratch accumulator.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BIG = 1e30


def _ranges_kernel(v_ref, x_ref, y_ref, lo_ref, hi_ref, acc_lo, acc_hi, *,
                   num_n_blocks: int):
    ni = pl.program_id(1)

    @pl.when(ni == 0)
    def _init():
        acc_lo[...] = jnp.full_like(acc_lo, -BIG)
        acc_hi[...] = jnp.full_like(acc_hi, BIG)

    V = v_ref[...].astype(jnp.float32)           # (bm, d)
    X = x_ref[...].astype(jnp.float32)           # (bn, d)
    y = y_ref[...].astype(jnp.float32)           # (bn,) ±1, 0 = padding
    proj = V @ X.T                               # (bm, bn) — MXU
    pos = (y == 1.0)[None, :]
    neg = (y == -1.0)[None, :]
    acc_lo[...] = jnp.maximum(acc_lo[...], jnp.where(pos, proj, -BIG).max(axis=1))
    acc_hi[...] = jnp.minimum(acc_hi[...], jnp.where(neg, proj, BIG).min(axis=1))

    @pl.when(ni == num_n_blocks - 1)
    def _emit():
        lo_ref[...] = acc_lo[...]
        hi_ref[...] = acc_hi[...]


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def threshold_ranges(
    V: jnp.ndarray,                # (m, d) directions
    Xw: jnp.ndarray,               # (n, d) transcript points
    yw: jnp.ndarray,               # (n,) ±1 (0 = padding row)
    *,
    block_m: int = 256,
    block_n: int = 512,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused (lo, hi) consistent-threshold ranges.  Shapes must tile evenly
    (the ops.py wrapper pads)."""
    m, d = V.shape
    n = Xw.shape[0]
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    assert m % block_m == 0 and n % block_n == 0, (m, block_m, n, block_n)
    nm, nn = m // block_m, n // block_n

    kernel = functools.partial(_ranges_kernel, num_n_blocks=nn)
    lo, hi = pl.pallas_call(
        kernel,
        grid=(nm, nn),
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
            pl.BlockSpec((block_n,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((block_m,), lambda i, j: (i,)),
            pl.BlockSpec((block_m,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m,), jnp.float32),
            jax.ShapeDtypeStruct((m,), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_m,), jnp.float32),
                        pltpu.VMEM((block_m,), jnp.float32)],
        interpret=interpret,
    )(V, Xw, yw)
    return lo, hi


def _ranges_kernel_batched(v_ref, x_ref, y_ref, lo_ref, hi_ref, acc_lo, acc_hi,
                           *, num_n_blocks: int):
    """Batched variant: grid (B, nm, nn); the batch axis indexes independent
    protocol instances (each with its own transcript) while V is shared."""
    ni = pl.program_id(2)

    @pl.when(ni == 0)
    def _init():
        acc_lo[...] = jnp.full_like(acc_lo, -BIG)
        acc_hi[...] = jnp.full_like(acc_hi, BIG)

    V = v_ref[...].astype(jnp.float32)           # (bm, d) — shared across B
    X = x_ref[0].astype(jnp.float32)             # (bn, d) — this instance
    y = y_ref[0].astype(jnp.float32)             # (bn,) ±1, 0 = padding
    proj = V @ X.T                               # (bm, bn) — MXU
    pos = (y == 1.0)[None, :]
    neg = (y == -1.0)[None, :]
    acc_lo[...] = jnp.maximum(acc_lo[...], jnp.where(pos, proj, -BIG).max(axis=1))
    acc_hi[...] = jnp.minimum(acc_hi[...], jnp.where(neg, proj, BIG).min(axis=1))

    @pl.when(ni == num_n_blocks - 1)
    def _emit():
        lo_ref[0] = acc_lo[...]
        hi_ref[0] = acc_hi[...]


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def threshold_ranges_batched(
    V: jnp.ndarray,                # (m, d) directions, shared over the batch
    Xw: jnp.ndarray,               # (B, n, d) per-instance transcript points
    yw: jnp.ndarray,               # (B, n) ±1 (0 = padding row)
    *,
    block_m: int = 256,
    block_n: int = 512,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(lo, hi) consistent-threshold ranges for a whole sweep batch in one
    pallas_call with a leading batch-grid dimension.  Returns (B, m) each."""
    m, d = V.shape
    B, n = Xw.shape[0], Xw.shape[1]
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    assert m % block_m == 0 and n % block_n == 0, (m, block_m, n, block_n)
    nm, nn = m // block_m, n // block_n

    kernel = functools.partial(_ranges_kernel_batched, num_n_blocks=nn)
    lo, hi = pl.pallas_call(
        kernel,
        grid=(B, nm, nn),
        in_specs=[
            pl.BlockSpec((block_m, d), lambda b, i, j: (i, 0)),
            pl.BlockSpec((1, block_n, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_n), lambda b, i, j: (b, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_m), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, block_m), lambda b, i, j: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, m), jnp.float32),
            jax.ShapeDtypeStruct((B, m), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_m,), jnp.float32),
                        pltpu.VMEM((block_m,), jnp.float32)],
        interpret=interpret,
    )(V, Xw, yw)
    return lo, hi


def _uncertain_kernel(x_ref, y_ref, v_ref, ok_ref, lo_ref, hi_ref, out_ref,
                      acc_ref, *, num_m_blocks: int):
    mi = pl.program_id(1)

    @pl.when(mi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    X = x_ref[...].astype(jnp.float32)           # (bn, d)
    y = y_ref[...].astype(jnp.float32)           # (bn,)
    V = v_ref[...].astype(jnp.float32)           # (bm, d)
    lo = lo_ref[...]                             # (bm,)
    hi = hi_ref[...]
    ok = ok_ref[...]                             # (bm,) 1.0/0.0

    proj = V @ X.T                               # (bm, bn) — MXU
    nonempty = (lo < hi) & (ok != 0.0)           # (bm,)
    pos_risk = proj > lo[:, None]
    neg_risk = proj < hi[:, None]
    at_risk = jnp.where((y == 1.0)[None, :], pos_risk, neg_risk)
    hit = jnp.any(at_risk & nonempty[:, None], axis=0)  # (bn,)
    acc_ref[...] = jnp.maximum(acc_ref[...], hit.astype(jnp.float32))

    @pl.when(mi == num_m_blocks - 1)
    def _emit():
        out_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def uncertain_mask(
    V: jnp.ndarray,                # (m, d)
    dir_ok: jnp.ndarray,           # (m,) float 1.0/0.0
    lo: jnp.ndarray,               # (m,)
    hi: jnp.ndarray,               # (m,)
    X: jnp.ndarray,                # (n, d)
    y: jnp.ndarray,                # (n,) ±1
    *,
    block_m: int = 256,
    block_n: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """SOU membership (float 1.0/0.0 per point; caller thresholds)."""
    m, d = V.shape
    n = X.shape[0]
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    assert m % block_m == 0 and n % block_n == 0, (m, block_m, n, block_n)
    nm, nn = m // block_m, n // block_n

    kernel = functools.partial(_uncertain_kernel, num_m_blocks=nm)
    out = pl.pallas_call(
        kernel,
        grid=(nn, nm),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n,), lambda i, j: (i,)),
            pl.BlockSpec((block_m, d), lambda i, j: (j, 0)),
            pl.BlockSpec((block_m,), lambda i, j: (j,)),
            pl.BlockSpec((block_m,), lambda i, j: (j,)),
            pl.BlockSpec((block_m,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_n,), jnp.float32)],
        interpret=interpret,
    )(X, y, V, dir_ok, lo, hi)
    return out


def _rank_rows(key: jnp.ndarray, member: jnp.ndarray, cap: int) -> jnp.ndarray:
    """Counting rank under ascending (key, index) order over member rows,
    capped at ``cap``: member rows among the cap smallest keep their rank,
    everything else gets the sentinel ``len(key)``.  Computes the same
    integers as ``ref._topr_ranks`` (which spells it as cap argmin passes —
    the CPU-friendly form) via an (n, n) compare matrix — VPU-friendly, and
    n is a protocol transcript width (hundreds), not a model axis."""
    n = key.shape[0]
    ii = lax.broadcasted_iota(jnp.int32, (n, n), 0)      # row index i
    jj = lax.broadcasted_iota(jnp.int32, (n, n), 1)      # col index j
    kj = key[None, :]
    ki = key[:, None]
    lt = (kj < ki) | ((kj == ki) & (jj < ii))
    rank = jnp.sum((lt & member[None, :]).astype(jnp.int32), axis=1)
    return jnp.where(member & (rank < cap), rank, n)


def _maxmarg_turn_kernel(w_ref, b_ref, kx_ref, ky_ref, x_ref, y_ref,
                         sup_ref, err_ref, viol_ref, *, rtol: float, k: int,
                         max_support: int, viol_ship: int):
    """Fused MAXMARG turn scan for one instance (grid (B,)).

    Folds the three per-turn passes that followed each refit — the fit-set
    margin scan + band ranking (support selection), the per-node error
    counts (all-clear bits / ε-termination), and the per-node most-violated
    ranking — into one kernel, so the proposal (w, b) streams through VMEM
    once per turn instead of driving a multi-pass jnp chain through HBM.
    """
    w = w_ref[0].astype(jnp.float32)                     # (d,)
    b = b_ref[0].astype(jnp.float32)                     # scalar via (1,)

    Kx = kx_ref[0].astype(jnp.float32)                   # (N, d)
    yK = ky_ref[0].astype(jnp.float32)                   # (N,)
    mK = yK * (Kx @ w + b)                               # fit-set margins
    valid_K = yK != 0.0
    mmin = jnp.maximum(
        jnp.min(jnp.where(valid_K, mK, jnp.inf)), 1e-12)
    band = valid_K & (mK <= mmin * (1.0 + rtol))
    sup_ref[0] = _rank_rows(jnp.where(band, mK, jnp.inf), band, max_support)

    errs, viols = [], []
    for j in range(k):                                   # k is static, small
        Xj = x_ref[0, j].astype(jnp.float32)             # (n, d)
        yj = y_ref[0, j].astype(jnp.float32)             # (n,)
        dec = Xj @ w + b
        pred = jnp.where(dec > 0.0, 1.0, -1.0)
        validj = yj != 0.0
        errs.append(jnp.sum(((pred != yj) & validj).astype(jnp.int32)))
        mj = yj * dec
        viols.append(_rank_rows(jnp.where(validj, mj, jnp.inf), validj,
                                viol_ship))
    err_ref[0] = jnp.stack(errs)
    viol_ref[0] = jnp.stack(viols)


@functools.partial(jax.jit, static_argnames=("rtol", "max_support",
                                             "viol_ship", "interpret"))
def maxmarg_turn_scan_batched(
    w: jnp.ndarray,                # (B, d) per-instance refit separators
    b: jnp.ndarray,                # (B,)
    K: jnp.ndarray,                # (B, N, d) own ∪ transcript fit sets
    yK: jnp.ndarray,               # (B, N) ±1 (0 = padding row)
    X: jnp.ndarray,                # (B, k, n, d) per-node shards
    y: jnp.ndarray,                # (B, k, n) ±1 (0 = padding row)
    *,
    rtol: float = 0.15,
    max_support: int = 4,
    viol_ship: int = 2,
    interpret: bool = False,
):
    """Fused support/violation scan for a whole MAXMARG sweep in one
    pallas_call (grid (B,); each instance is one block — protocol fit sets
    are hundreds of rows, so the (N, d) tiles and (N, N)/(n, n) rank
    matrices sit comfortably in VMEM).  Returns
    ``(sup_rank (B, N) i32, err_k (B, k) i32, viol_rank (B, k, n) i32)``
    matching ``ref.maxmarg_turn_batch_ref`` bit-for-bit (integer outputs
    only — see the bit-for-bit note on ``kernels.median_cut``)."""
    B, N, d = K.shape
    k, n = X.shape[1], X.shape[2]

    kernel = functools.partial(_maxmarg_turn_kernel, rtol=rtol, k=k,
                               max_support=max_support, viol_ship=viol_ship)
    sup, err, viol = pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1, N, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, N), lambda i: (i, 0)),
            pl.BlockSpec((1, k, n, d), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, k, n), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, N), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (i, 0)),
            pl.BlockSpec((1, k, n), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, N), jnp.int32),
            jax.ShapeDtypeStruct((B, k), jnp.int32),
            jax.ShapeDtypeStruct((B, k, n), jnp.int32),
        ],
        interpret=interpret,
    )(w, b, K, yK, X, y)
    return sup, err, viol


def _median_extremes_kernel(v_ref, x_ref, y_ref, ip_ref, iq_ref, *, k: int):
    """MEDIAN's fused per-turn extremes scan for one instance (grid (B,)).

    One pass over every node's own ∪ transcript rows at the hot loop's
    fill-capped width: project onto the proposed direction and pick, per
    node, the first-index max over positive rows and first-index min over
    negative rows — the band extreme each node would ship.  First-index tie
    resolution is spelled as a counting min over an iota (``argmax`` picks
    the first maximum in the jnp reference), so the integer row choices
    match ``ref.median_extremes_ref`` bit-for-bit.
    """
    v = v_ref[0].astype(jnp.float32)                     # (d,)
    ips, iqs = [], []
    for j in range(k):                                   # k is static, small
        Xj = x_ref[0, j].astype(jnp.float32)             # (nW, d)
        yj = y_ref[0, j].astype(jnp.float32)             # (nW,) ±1, 0 = pad
        pj = Xj @ v                                      # (nW,) — MXU
        n = pj.shape[0]
        iota = lax.broadcasted_iota(jnp.int32, (n, 1), 0)[:, 0]
        pj_pos = jnp.where(yj == 1.0, pj, -BIG)
        pj_neg = jnp.where(yj == -1.0, pj, BIG)
        # first index attaining the masked max/min; all-masked rows reduce
        # to the mask constant, whose first index is 0 — the same fallback
        # the reference's argmax-over-(-inf) yields
        ips.append(jnp.min(jnp.where(pj_pos == jnp.max(pj_pos), iota, n)))
        iqs.append(jnp.min(jnp.where(pj_neg == jnp.min(pj_neg), iota, n)))
    ip_ref[0] = jnp.stack(ips)
    iq_ref[0] = jnp.stack(iqs)


@functools.partial(jax.jit, static_argnames=("interpret",))
def median_extremes_batched(
    v: jnp.ndarray,                # (B, d) per-instance proposed directions
    XW: jnp.ndarray,               # (B, k, nW, d) own ∪ capped transcripts
    yW: jnp.ndarray,               # (B, k, nW) ±1 (0 = padding row)
    *,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused extremes scan for a whole MEDIAN sweep in one pallas_call
    (grid (B,); protocol row counts are hundreds, so the (nW, d) tiles sit
    comfortably in VMEM).  ``nW`` is whatever width the caller passes — the
    hot loop's live fill cap, not the static transcript capacity.  Returns
    ``(i_p (B, k) i32, i_q (B, k) i32)`` matching
    ``ref.median_extremes_batch_ref`` bit-for-bit (integer row choices
    only)."""
    B, k, nW, d = XW.shape

    kernel = functools.partial(_median_extremes_kernel, k=k)
    ip, iq = pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1, k, nW, d), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, k, nW), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, k), jnp.int32),
            jax.ShapeDtypeStruct((B, k), jnp.int32),
        ],
        interpret=interpret,
    )(v, XW, yW)
    return ip, iq


def _uncertain_kernel_batched(x_ref, y_ref, v_ref, ok_ref, lo_ref, hi_ref,
                              out_ref, acc_ref, *, num_m_blocks: int):
    """Batched variant: grid (B, nn, nm); per-instance dir_ok/lo/hi masks."""
    mi = pl.program_id(2)

    @pl.when(mi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    X = x_ref[0].astype(jnp.float32)             # (bn, d)
    y = y_ref[0].astype(jnp.float32)             # (bn,)
    V = v_ref[...].astype(jnp.float32)           # (bm, d) — shared across B
    lo = lo_ref[0]                               # (bm,)
    hi = hi_ref[0]
    ok = ok_ref[0]                               # (bm,) 1.0/0.0

    proj = V @ X.T                               # (bm, bn) — MXU
    nonempty = (lo < hi) & (ok != 0.0)           # (bm,)
    pos_risk = proj > lo[:, None]
    neg_risk = proj < hi[:, None]
    at_risk = jnp.where((y == 1.0)[None, :], pos_risk, neg_risk)
    hit = jnp.any(at_risk & nonempty[:, None], axis=0)  # (bn,)
    acc_ref[...] = jnp.maximum(acc_ref[...], hit.astype(jnp.float32))

    @pl.when(mi == num_m_blocks - 1)
    def _emit():
        out_ref[0] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def uncertain_mask_batched(
    V: jnp.ndarray,                # (m, d), shared over the batch
    dir_ok: jnp.ndarray,           # (B, m) float 1.0/0.0 — per instance
    lo: jnp.ndarray,               # (B, m)
    hi: jnp.ndarray,               # (B, m)
    X: jnp.ndarray,                # (B, n, d)
    y: jnp.ndarray,                # (B, n) ±1 (0 = padding row)
    *,
    block_m: int = 256,
    block_n: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """SOU membership for a whole sweep batch in one pallas_call; returns
    (B, n) float 1.0/0.0 (caller thresholds)."""
    m, d = V.shape
    B, n = X.shape[0], X.shape[1]
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    assert m % block_m == 0 and n % block_n == 0, (m, block_m, n, block_n)
    nm, nn = m // block_m, n // block_n

    kernel = functools.partial(_uncertain_kernel_batched, num_m_blocks=nm)
    out = pl.pallas_call(
        kernel,
        grid=(B, nn, nm),
        in_specs=[
            pl.BlockSpec((1, block_n, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_n), lambda b, i, j: (b, i)),
            pl.BlockSpec((block_m, d), lambda b, i, j: (j, 0)),
            pl.BlockSpec((1, block_m), lambda b, i, j: (b, j)),
            pl.BlockSpec((1, block_m), lambda b, i, j: (b, j)),
            pl.BlockSpec((1, block_m), lambda b, i, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda b, i, j: (b, i)),
        out_shape=jax.ShapeDtypeStruct((B, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_n,), jnp.float32)],
        interpret=interpret,
    )(X, y, V, dir_ok, lo, hi)
    return out
