"""Pallas TPU kernel for the RWKV-6 (Finch) WKV recurrence.

TPU adaptation (recorded in DESIGN.md): instead of a step-per-token VPU loop
(the GPU CUDA kernel's shape), the sequence is processed in chunks with the
*closed-form intra-chunk expansion*, which turns the recurrence into three
MXU matmuls per chunk plus one rank-1 state update:

  P_t   = prod_{s<=t} w_s                      (cumulative decay, per k-dim)
  y_t   = (r_t ⊙ P_{t-1}) · S_chunk0
          + Σ_{s<t} [(r_t ⊙ P_{t-1}/P_s) · k_s] v_s
          + (r_t ⊙ u) · k_t  v_t
  S_next = diag(P_T) S_chunk0 + Σ_s diag(P_T/P_s) k_s v_sᵀ

The (chunk, chunk) inner term is a strictly-lower-triangular masked matmul —
exactly a flash-attention-shaped tile.  The running state S (hd × hd per
head) persists in VMEM scratch across the innermost sequential chunk grid
dimension.  Division by P_s is the standard chunked-linear-attention
normalization; chunks are kept short (<=64) and all math is f32 so the
decay ratio stays in range (w ∈ (0,1), so P is monotone decreasing and
P_{t-1}/P_s <= 1 for s <= t-1; k_s/P_s can grow but only over one chunk).

Grid: (B, H, num_chunks) — chunks innermost/sequential.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rwkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, sT_ref, S_ref, *,
                  chunk: int, num_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        S_ref[...] = jnp.zeros_like(S_ref)

    r = r_ref[0, 0].astype(jnp.float32)          # (T, hd)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)             # (hd,)
    S = S_ref[...]                               # (hd, hd) state, k-major

    logw = jnp.log(w)                            # w ∈ (0,1) ⇒ logw < 0
    P = jnp.exp(jnp.cumsum(logw, axis=0))        # (T, hd)  P_t
    Pprev = jnp.exp(jnp.cumsum(logw, axis=0) - logw)  # P_{t-1} (P_0 = 1)

    # inter-chunk: y_t += (r_t ⊙ P_{t-1}) @ S
    y = (r * Pprev) @ S                          # (T, hd) — MXU

    # intra-chunk: A[t,s] = (r_t ⊙ P_{t-1}) · (k_s / P_s)   for s < t
    #              A[t,t] = (r_t ⊙ u) · k_t
    kscaled = k / P                              # (T, hd)
    A = (r * Pprev) @ kscaled.T                  # (T, T) — MXU
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    A = jnp.where(s_idx < t_idx, A, 0.0)
    diag = jnp.sum((r * u) * k, axis=-1)         # (T,)
    A = A + jnp.where(s_idx == t_idx, diag[:, None], 0.0)
    y = y + A @ v                                # (T, hd) — MXU

    y_ref[0, 0, :, :] = y.astype(y_ref.dtype)

    # state update: S' = diag(P_T) S + (k ⊙ P_T/P)ᵀ v
    PT = P[-1]                                   # (hd,)
    S_ref[...] = PT[:, None] * S + (kscaled * PT).T @ v

    @pl.when(ci == num_chunks - 1)
    def _emit_state():
        sT_ref[0, 0, :, :] = S_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_chunked(
    r: jnp.ndarray,                # (B, S, H, hd)
    k: jnp.ndarray,
    v: jnp.ndarray,
    w: jnp.ndarray,                # decay ∈ (0,1)
    u: jnp.ndarray,                # (H, hd)
    *,
    chunk: int = 32,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked WKV pass.  Returns (y (B,S,H,hd), final state (B,H,hd,hd))."""
    B, S, H, hd = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    # head-major time stripes: (B, H, S, hd)
    rt, kt, vt, wt = (a.transpose(0, 2, 1, 3) for a in (r, k, v, w))

    kernel = functools.partial(_rwkv6_kernel, chunk=chunk, num_chunks=nc)
    y, sT = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, hd), lambda b, h, c: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, hd), r.dtype),
            jax.ShapeDtypeStruct((B, H, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(rt, kt, vt, wt, u)
    return y.transpose(0, 2, 1, 3), sT
