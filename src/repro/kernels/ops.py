"""Public jit'd wrappers around the Pallas kernels.

Each wrapper: (a) pads inputs to kernel tile boundaries, (b) dispatches to
``interpret=True`` automatically off-TPU (this container is CPU-only; the
kernel body then runs as a Python/XLA emulation, proving correctness while
the BlockSpec tiling stays the TPU deployment artifact), (c) restores the
caller's shapes.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import mamba as _mamba
from repro.kernels import median_cut as _mc
from repro.kernels import pegasos as _pg
from repro.kernels import rwkv6 as _rwkv6
from repro.kernels import ref as _ref
from repro.kernels import support_margin as _sm
from repro.analysis import autotune as _autotune


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jnp.ndarray, axis: int, mult: int, value=0.0) -> jnp.ndarray:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

def attention(
    q: jnp.ndarray,                # (B, Sq, H, hd)
    k: jnp.ndarray,                # (B, Skv, KV, hd)
    v: jnp.ndarray,                # (B, Skv, KV, hdv)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    kv_valid: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Flash attention; pads Sq/Skv to block multiples (padding keys are
    masked out via ``kv_valid``)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    bq = min(block_q, max(Sq, 8))
    bk = min(block_k, max(Skv, 8))
    qp = _pad_to(q, 1, bq)
    kp = _pad_to(k, 1, bk)
    vp = _pad_to(v, 1, bk)
    if kp.shape[1] != Skv and kv_valid is None:
        kv_valid = Skv
    out = _fa.flash_attention(qp, kp, vp, causal=causal, window=window,
                              kv_valid=kv_valid, block_q=bq, block_k=bk,
                              interpret=interpret)
    return out[:, :Sq]


# ---------------------------------------------------------------------------
# rwkv6
# ---------------------------------------------------------------------------

def rwkv6(
    r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, w: jnp.ndarray,
    u: jnp.ndarray, *, chunk: int = 32, interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked WKV; pads S to the chunk multiple (w=1, k=0 padding steps are
    state no-ops)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    B, S, H, hd = r.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        r = _pad_to(r, 1, chunk)
        k = _pad_to(k, 1, chunk)
        v = _pad_to(v, 1, chunk)
        w = _pad_to(w, 1, chunk, value=1.0)   # decay 1.0 ⇒ state unchanged
    y, sT = _rwkv6.rwkv6_chunked(r, k, v, w, u, chunk=chunk, interpret=interpret)
    return y[:, :S], sT


# ---------------------------------------------------------------------------
# mamba selective scan
# ---------------------------------------------------------------------------

def selective_scan(
    xc: jnp.ndarray, delta: jnp.ndarray, A: jnp.ndarray,
    Bs: jnp.ndarray, Cs: jnp.ndarray, *,
    chunk: int = 64, block_di: int = 256, interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Selective scan; pads S to the chunk multiple (Δ=0 steps are state
    no-ops) and d_inner to the block multiple."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    B, S, di = xc.shape
    chunk = min(chunk, S)
    block_di = min(block_di, di)
    xp = _pad_to(_pad_to(xc, 1, chunk), 2, block_di)
    dp = _pad_to(_pad_to(delta, 1, chunk), 2, block_di)
    Ap = _pad_to(A, 0, block_di)
    Bp = _pad_to(Bs, 1, chunk)
    Cp = _pad_to(Cs, 1, chunk)
    y, hT = _mamba.mamba_scan(xp, dp, Ap, Bp, Cp, chunk=chunk,
                              block_di=block_di, interpret=interpret)
    return y[:, :S, :di], hT[:, :di]


# ---------------------------------------------------------------------------
# support margin (paper data plane)
# ---------------------------------------------------------------------------

_LANE = 8  # contraction padding for the tiny-d protocol geometry


def support_ranges(
    V: jnp.ndarray, Xw: jnp.ndarray, yw: jnp.ndarray, *,
    block_m: int = 256, block_n: int = 512, interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Consistent-threshold (lo, hi) per direction; pads m/n/d (padding
    points get label 0 and are ignored by the masked reductions)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    m, n = V.shape[0], Xw.shape[0]
    bm = min(block_m, max(m, 8))
    bn = min(block_n, max(n, 8))
    Vp = _pad_to(_pad_to(V, 0, bm), 1, _LANE)
    Xp = _pad_to(_pad_to(Xw, 0, bn), 1, _LANE)
    yp = _pad_to(yw.astype(jnp.float32), 0, bn)
    lo, hi = _sm.threshold_ranges(Vp, Xp, yp, block_m=bm, block_n=bn,
                                  interpret=interpret)
    return lo[:m], hi[:m]


def support_uncertain(
    V: jnp.ndarray, dir_ok: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray,
    X: jnp.ndarray, y: jnp.ndarray, *,
    block_m: int = 256, block_n: int = 512, interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """SOU membership mask (bool, (n,)); pads m (dir_ok=0) and n."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    m, n = V.shape[0], X.shape[0]
    bm = min(block_m, max(m, 8))
    bn = min(block_n, max(n, 8))
    Vp = _pad_to(_pad_to(V, 0, bm), 1, _LANE)
    okp = _pad_to(dir_ok.astype(jnp.float32), 0, bm)
    lop = _pad_to(lo, 0, bm)
    hip = _pad_to(hi, 0, bm, value=-1.0)  # padded dirs: empty interval
    Xp = _pad_to(_pad_to(X, 0, bn), 1, _LANE)
    yp = _pad_to(y.astype(jnp.float32), 0, bn)
    out = _sm.uncertain_mask(Vp, okp, lop, hip, Xp, yp, block_m=bm,
                             block_n=bn, interpret=interpret)
    return out[:n] > 0.5


def support_ranges_batch(
    V: jnp.ndarray, Xw: jnp.ndarray, yw: jnp.ndarray, *,
    block_m: int = 256, block_n: int = 512, interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Batched consistent-threshold ranges: V (m, d) shared, Xw (B, n, d),
    yw (B, n) with label-0 padding rows.  One pallas_call over the whole
    sweep; returns (B, m) lo/hi."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    m, n = V.shape[0], Xw.shape[1]
    bm = min(block_m, max(m, 8))
    bn = min(block_n, max(n, 8))
    Vp = _pad_to(_pad_to(V, 0, bm), 1, _LANE)
    Xp = _pad_to(_pad_to(Xw, 1, bn), 2, _LANE)
    yp = _pad_to(yw.astype(jnp.float32), 1, bn)
    lo, hi = _sm.threshold_ranges_batched(Vp, Xp, yp, block_m=bm, block_n=bn,
                                          interpret=interpret)
    return lo[:, :m], hi[:, :m]


def support_median_cut_batch(
    V: jnp.ndarray, dir_ok: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray,
    X: jnp.ndarray, y: jnp.ndarray, *,
    block_n: int = 512, interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Batched median-cut scores: per-instance dir_ok/lo/hi (B, m) and
    shards X (B, n, d) / y (B, n); returns int32 (B, m), -1 at disallowed
    cuts.  Pads m (dir_ok=0 ⇒ score -1, sliced off), n (label-0 rows are
    never live) and d."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    m, n = V.shape[0], X.shape[1]
    bn = min(block_n, max(n, 8))
    Vp = _pad_to(_pad_to(V, 0, 8), 1, _LANE)
    okp = _pad_to(dir_ok.astype(jnp.float32), 1, 8)
    lop = _pad_to(lo, 1, 8)
    hip = _pad_to(hi, 1, 8, value=-1.0)  # padded dirs: empty interval
    Xp = _pad_to(_pad_to(X, 1, bn), 2, _LANE)
    yp = _pad_to(y.astype(jnp.float32), 1, bn)
    out = _mc.median_cut_scores_batched(Vp, okp, lop, hip, Xp, yp,
                                        block_n=bn, interpret=interpret)
    return out[:, :m]


def support_violation_batch(
    w: jnp.ndarray, b: jnp.ndarray, K: jnp.ndarray, yK: jnp.ndarray,
    X: jnp.ndarray, y: jnp.ndarray, *,
    rtol: float = 0.15, max_support: int = 4, viol_ship: int = 2,
    interpret: Optional[bool] = None,
):
    """Fused MAXMARG turn scan (support band ranks + per-node error counts +
    most-violated ranks) for a whole sweep; pads N/n/d (label-0 rows are
    never band members, never valid, never miscounted) and restores the
    reference's rank sentinels (N for non-band fit rows, n for invalid shard
    rows) after slicing the padding off.  Returns
    ``(sup_rank (B, N) i32, err_k (B, k) i32, viol_rank (B, k, n) i32)`` —
    bit-for-bit ``ref.maxmarg_turn_batch_ref``."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    N, n = K.shape[1], X.shape[2]
    Kp = _pad_to(_pad_to(K, 1, 8), 2, _LANE)
    yKp = _pad_to(yK.astype(jnp.float32), 1, 8)
    Xp = _pad_to(_pad_to(X, 2, 8), 3, _LANE)
    yp = _pad_to(y.astype(jnp.float32), 2, 8)
    wp = _pad_to(w, 1, _LANE)
    sup, err, viol = _sm.maxmarg_turn_scan_batched(
        wp, b, Kp, yKp, Xp, yp, rtol=rtol, max_support=max_support,
        viol_ship=viol_ship, interpret=interpret)
    # padded widths inflate the non-member sentinel; members rank < N (resp.
    # n), so a min against the true width restores the reference sentinel
    sup = jnp.minimum(sup[:, :N], N)
    viol = jnp.minimum(viol[:, :, :n], n)
    return sup, err, viol


def support_extremes_batch(
    v: jnp.ndarray, XW: jnp.ndarray, yW: jnp.ndarray, *,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused MEDIAN extremes scan (per-node extreme band point indices along
    the proposed direction) for a whole sweep: v (B, d), XW (B, k, nW, d),
    yW (B, k, nW) with label-0 padding rows.  ``nW`` is *fill-capped* — the
    hot loop passes transcripts sliced to the live width, and this wrapper
    only re-pads to tile boundaries (padding rows get label 0 and are never
    selected; a class with no members yields index 0, gated by the caller's
    presence flags).  Returns ``(i_p, i_q)`` each (B, k) i32, bit-for-bit
    ``ref.median_extremes_batch_ref``."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    vp = _pad_to(v, 1, _LANE)
    Xp = _pad_to(_pad_to(XW, 2, 8), 3, _LANE)
    yp = _pad_to(yW.astype(jnp.float32), 2, 8)
    return _sm.median_extremes_batched(vp, Xp, yp, interpret=interpret)


def support_uncertain_batch(
    V: jnp.ndarray, dir_ok: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray,
    X: jnp.ndarray, y: jnp.ndarray, *,
    block_m: int = 256, block_n: int = 512, interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Batched SOU membership: per-instance dir_ok/lo/hi (B, m) and shards
    X (B, n, d) / y (B, n); returns bool (B, n)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    m, n = V.shape[0], X.shape[1]
    bm = min(block_m, max(m, 8))
    bn = min(block_n, max(n, 8))
    Vp = _pad_to(_pad_to(V, 0, bm), 1, _LANE)
    okp = _pad_to(dir_ok.astype(jnp.float32), 1, bm)
    lop = _pad_to(lo, 1, bm)
    hip = _pad_to(hi, 1, bm, value=-1.0)  # padded dirs: empty interval
    Xp = _pad_to(_pad_to(X, 1, bn), 2, _LANE)
    yp = _pad_to(y.astype(jnp.float32), 1, bn)
    out = _sm.uncertain_mask_batched(Vp, okp, lop, hip, Xp, yp, block_m=bm,
                                     block_n=bn, interpret=interpret)
    return out[:, :n] > 0.5


# ---------------------------------------------------------------------------
# tiled Pegasos solver stage (MAXMARG refit inner loop)
# ---------------------------------------------------------------------------

def pegasos_stage(
    X: jnp.ndarray,                # (B, N, d) f32; label-0 rows = padding
    y: jnp.ndarray,                # (B, N) f32 in {+1, -1, 0}
    nv: jnp.ndarray,               # (B,) f32 valid row counts (≥ 1)
    w: jnp.ndarray,                # (B, d)
    b: jnp.ndarray,                # (B,)
    lam: jnp.ndarray,              # (B,) per-instance stage λ
    found: jnp.ndarray,            # (B,) bool first-0-error latch state
    w_best: jnp.ndarray,           # (B, d)
    b_best: jnp.ndarray,           # (B,)
    *,
    nsteps: int,
    t0: float = 0.0,
    use_pallas: Optional[bool] = None,
    interpret: Optional[bool] = None,
    block_b: Optional[int] = None,
    block_n: Optional[int] = None,
    unroll: Optional[int] = None,
) -> Tuple[jnp.ndarray, ...]:
    """One fused Pegasos λ stage + first-0-error latch behind one call.

    The solver's single dispatch point (``_svm_solve_batch(kernel=True)``):
    Pallas tiled kernel on TPU (auto-interpret elsewhere, like every other
    wrapper here), dot-contraction jnp twin (``ref.pegasos_stage_batch_ref``)
    when ``use_pallas`` resolves False — the CPU fast path for d ≫ 2.
    Block shapes / unroll default from the committed autotune cache
    (``analysis.autotune.lookup_tile``) with its deterministic fallback.
    Returns ``(w, b, mmin, found, w_best, b_best)``; ``mmin`` follows the
    kernel mask convention (``pegasos.BIG`` where no valid rows).
    """
    B, N, d = X.shape
    use_pallas = _on_tpu() if use_pallas is None else use_pallas
    if block_b is None or block_n is None or unroll is None:
        kind = jax.devices()[0].device_kind
        cfg = _autotune.lookup_tile(kind, B, N, d)
        block_b = cfg.block_b if block_b is None else block_b
        block_n = cfg.block_n if block_n is None else block_n
        unroll = cfg.unroll if unroll is None else unroll

    if not use_pallas:
        return _ref.pegasos_stage_batch_ref(
            X, y, nv, w, b, lam, found, w_best, b_best,
            nsteps=nsteps, t0=t0, unroll=unroll)

    interpret = (not _on_tpu()) if interpret is None else interpret
    bb = min(block_b, max(B, 1))
    bn = min(block_n, max(N, 8))
    # pads are inert by construction: label-0 rows never violate, zero d
    # columns stay zero through the update, pad instances get nv=1 / λ=1
    Xp = _pad_to(_pad_to(_pad_to(X, 0, bb), 1, bn), 2, _LANE)
    yp = _pad_to(y.astype(jnp.float32), 0, bb)
    yp = _pad_to(yp, 1, bn)
    nvp = _pad_to(nv.astype(jnp.float32), 0, bb, value=1.0)
    lamp = _pad_to(lam.astype(jnp.float32), 0, bb, value=1.0)
    wp = _pad_to(_pad_to(w.astype(jnp.float32), 0, bb), 1, _LANE)
    bp = _pad_to(b.astype(jnp.float32), 0, bb)
    fp = _pad_to(found.astype(jnp.int32), 0, bb)
    wbp = _pad_to(_pad_to(w_best.astype(jnp.float32), 0, bb), 1, _LANE)
    bbp = _pad_to(b_best.astype(jnp.float32), 0, bb)
    w_o, b_o, mm_o, f_o, wb_o, bb_o = _pg.pegasos_stage_batched(
        Xp, yp, nvp, wp, bp, lamp, fp, wbp, bbp, nsteps=nsteps, t0=t0,
        block_b=bb, block_n=bn, interpret=interpret)
    return (w_o[:B, :d], b_o[:B], mm_o[:B], f_o[:B] != 0,
            wb_o[:B, :d], bb_o[:B])
