"""Pallas TPU kernel for the Mamba-1 selective scan.

TPU adaptation: the GPU reference is a warp-parallel sequential scan with the
state in registers.  Here the inner-dim (d_inner) axis is blocked so each
grid step owns a (block_di, d_state) state tile resident in VMEM scratch, and
time is the innermost sequential grid dimension processed one chunk at a
time.  Within a chunk the recurrence stays a fori_loop (d_state = 16 makes
the per-step work a (block_di, 16) VPU elementwise op — the MXU has nothing
to chew on, which is exactly why Mamba papers report it memory-bound), but
chunking amortizes HBM↔VMEM traffic: Δ/B/C/x tiles stream in once per chunk
and y streams out once, instead of per-token round trips.

Grid: (B, num_di_blocks, num_chunks) — chunks innermost/sequential.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mamba_kernel(delta_ref, x_ref, A_ref, B_ref, C_ref, y_ref, hT_ref,
                  h_ref, *, chunk: int, num_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    delta = delta_ref[0].astype(jnp.float32)     # (T, bdi)
    x = x_ref[0].astype(jnp.float32)             # (T, bdi)
    A = A_ref[...].astype(jnp.float32)           # (bdi, ds)
    Bs = B_ref[0].astype(jnp.float32)            # (T, ds)
    Cs = C_ref[0].astype(jnp.float32)            # (T, ds)

    def step(t, carry):
        h, ys = carry
        d_t = delta[t]                           # (bdi,)
        dA = jnp.exp(d_t[:, None] * A)           # (bdi, ds)
        dBx = (d_t * x[t])[:, None] * Bs[t][None, :]
        h = dA * h + dBx
        y_t = h @ Cs[t]                          # (bdi,)
        ys = jax.lax.dynamic_update_slice(ys, y_t[None, :], (t, 0))
        return h, ys

    h0 = h_ref[...]
    ys0 = jnp.zeros_like(y_ref[0], jnp.float32)
    hT, ys = jax.lax.fori_loop(0, chunk, step, (h0, ys0))
    y_ref[0, :, :] = ys.astype(y_ref.dtype)
    h_ref[...] = hT

    @pl.when(ci == num_chunks - 1)
    def _emit_state():
        hT_ref[0, :, :] = h_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "block_di", "interpret"))
def mamba_scan(
    xc: jnp.ndarray,               # (B, S, di) conv'd+silu'd inputs
    delta: jnp.ndarray,            # (B, S, di)
    A: jnp.ndarray,                # (di, ds) negative
    Bs: jnp.ndarray,               # (B, S, ds)
    Cs: jnp.ndarray,               # (B, S, ds)
    *,
    chunk: int = 64,
    block_di: int = 256,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Selective scan.  Returns (y (B,S,di), final state (B,di,ds))."""
    B, S, di = xc.shape
    ds = A.shape[1]
    chunk = min(chunk, S)
    block_di = min(block_di, di)
    assert S % chunk == 0 and di % block_di == 0, (S, chunk, di, block_di)
    nc, nd = S // chunk, di // block_di

    kernel = functools.partial(_mamba_kernel, chunk=chunk, num_chunks=nc)
    y, hT = pl.pallas_call(
        kernel,
        grid=(B, nd, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, block_di), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, chunk, block_di), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((block_di, ds), lambda b, d, c: (d, 0)),
            pl.BlockSpec((1, chunk, ds), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, ds), lambda b, d, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_di), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, block_di, ds), lambda b, d, c: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, di), xc.dtype),
            jax.ShapeDtypeStruct((B, di, ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_di, ds), jnp.float32)],
        interpret=interpret,
    )(delta, xc, A, Bs, Cs)
    return y, hT
