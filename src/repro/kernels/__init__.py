"""Pallas TPU kernels for the framework's compute hot-spots.

Six kernels, each with the ``<name>.py`` (pl.pallas_call + BlockSpec) /
``ops.py`` (jit'd padding + dispatch wrapper) / ``ref.py`` (pure-jnp oracle)
layout:

  flash_attention  tiled online-softmax GQA attention (causal/sliding-window)
  rwkv6            chunked closed-form WKV recurrence (Finch)
  mamba            blocked selective scan
  support_margin   the paper's data-plane hot loop: fused direction×point
                   projection with masked range / any reductions
  median_cut       the MEDIAN selector's (B, m, n) weighted-median cut scan
                   (running risk counts down the direction axis, integer
                   side counts per cut)
  pegasos          the MAXMARG refit solver: one whole Pegasos λ stage per
                   launch (hinge gradient accumulated across N-tiles in f32
                   VMEM scratch, first-0-error latch fused), block shapes
                   from the committed autotune cache

All are validated on CPU via ``interpret=True`` against the oracles
(tests/test_kernels.py); the BlockSpec tilings target TPU v5e VMEM/MXU.
"""

from repro.kernels import ops, ref  # noqa: F401
from repro.kernels.flash_attention import flash_attention  # noqa: F401
from repro.kernels.mamba import mamba_scan  # noqa: F401
from repro.kernels.median_cut import median_cut_scores_batched  # noqa: F401
from repro.kernels.pegasos import pegasos_stage_batched  # noqa: F401
from repro.kernels.rwkv6 import rwkv6_chunked  # noqa: F401
from repro.kernels.support_margin import threshold_ranges, uncertain_mask  # noqa: F401
