"""Pallas TPU flash attention (GQA, causal, sliding-window).

Online-softmax tiling after Rabe-Staats / FlashAttention, adapted to the TPU
memory hierarchy: the (block_q, head_dim) query tile and the (block_k,
head_dim) key/value tiles live in VMEM, the running (m, l, acc) statistics in
SMEM-resident scratch, and every contraction is MXU-shaped (block sizes are
multiples of 128 where the head dim allows).  GQA never materializes the
broadcast K/V: the kv-head index is folded into the BlockSpec ``index_map``
so each q-head grid step streams its shared kv head straight from HBM.

Grid: (batch, q_heads, num_q_blocks, num_k_blocks) — the k dimension is the
innermost (sequential on TPU) axis, so the scratch accumulators carry across
k-blocks of one q-block and are re-initialized when ``k_idx == 0``.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: Optional[int],
                  block_q: int, block_k: int, num_k_blocks: int,
                  kv_valid: Optional[int]):
    """One (q-block, k-block) step of the online-softmax recursion."""
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)          # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)          # (bk, hdv)

    s = (q * scale) @ k.T                        # (bq, bk) — MXU
    rows = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    cols = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    if kv_valid is not None:
        mask &= cols < kv_valid
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                          # (bq,)
    m_cur = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_cur)              # rescale of old stats
    p = jnp.exp(s - m_cur[:, None])
    p = jnp.where(mask, p, 0.0)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
    m_ref[...] = m_cur

    @pl.when(ki == num_k_blocks - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)          # fully-masked rows -> 0 output
        o_ref[0, 0, :, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "kv_valid", "block_q", "block_k",
                     "interpret"),
)
def flash_attention(
    q: jnp.ndarray,                # (B, Sq, H, hd)
    k: jnp.ndarray,                # (B, Skv, KV, hd)
    v: jnp.ndarray,                # (B, Skv, KV, hdv)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    kv_valid: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Tiled exact attention.  Returns (B, Sq, H, hdv)."""
    B, Sq, H, hd = q.shape
    _, Skv, KV, hdv = v.shape
    G = H // KV
    assert H % KV == 0, (H, KV)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0, (Sq, block_q, Skv, block_k)
    nq, nk = Sq // block_q, Skv // block_k
    scale = 1.0 / math.sqrt(hd)

    # head-major layout so each (b, h) grid step reads a contiguous stripe
    qt = q.transpose(0, 2, 1, 3)   # (B, H, Sq, hd)
    kt = k.transpose(0, 2, 1, 3)   # (B, KV, Skv, hd)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, num_k_blocks=nk, kv_valid=kv_valid)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, hdv), lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hdv), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hdv), q.dtype),
        scratch_shapes=[
            # (m, l, acc) online-softmax carries; VMEM-resident, persist
            # across the sequential innermost k grid dimension
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hdv), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)  # (B, Sq, H, hdv)
