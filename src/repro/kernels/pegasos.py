"""Tiled Pallas kernel for the batched Pegasos λ-stage (the MAXMARG refit).

``core.classifiers._svm_solve_batch`` runs every hard-margin refit as plain
vmapped XLA Pegasos over ``(B, N, d)``: one ``fori_loop`` step per gradient
pass, with the d-contraction spelled as d broadcast multiply-adds (the fast
form at the paper's d = 2..10, but *solver-bound* at d ≫ 2 — ROADMAP's TPU
kernel item).  This kernel is the tiled deployment artifact for that loop:

* grid ``(B/block_b, nsteps+1, N/block_n)`` — instances in parallel blocks,
  the Pegasos step axis sequential, N-tiles innermost;
* the hinge-gradient reduction is accumulated across N-tiles in an f32 VMEM
  scratch (``g_s``/``gb_s``); ``d`` stays fully resident per block, so each
  step's two contractions (margins ``X·w``, gradient ``violᵀ·X``) are real
  MXU matmuls instead of d strided passes;
* the separator itself lives in VMEM scratch across the whole stage — one
  kernel launch covers a *whole λ stage* (nsteps updates + the trailing
  margin scan), not one ``fori_loop`` step per dispatch;
* the first-0-error latch of ``_svm_solve_batch`` is fused: the final grid
  step folds the stage's min-margin scan into the ``found``/``w_best``/
  ``b_best`` latch update, so the stage-annealing caller reads latched
  results straight out of the launch;
* masked-pad path: label-0 rows contribute no hinge violations and the
  gradient normalizes by the caller-supplied per-instance valid count
  ``nv`` — compacted hot-loop fills and tile padding ride the same mask.

Block shapes come from the committed tuning cache
(``kernels/tuning_cache.json`` via ``analysis.autotune.lookup_tile``); the
``ops.pegasos_stage`` wrapper pads/dispatches and falls back to the
dot-contraction jnp twin (``ref.pegasos_stage_batch_ref``) off-TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BIG = 1e30  # mask constant; jnp.inf is avoided inside kernels (see support_margin)


def _pegasos_stage_kernel(
    x_ref, y_ref, nv_ref, w0_ref, b0_ref, lam_ref, found_ref, wb_ref, bb_ref,
    w_out, b_out, mmin_out, found_out, wbest_out, bbest_out,
    w_s, b_s, g_s, gb_s, mm_s,
    *, nsteps: int, num_n_blocks: int, t0: float,
):
    """One λ stage for a ``block_b`` slab of instances.

    Grid ``(bi, s, ni)``: ``s < nsteps`` are Pegasos steps (N-tiles
    accumulate the hinge gradient, the last tile applies the update +
    ball projection), ``s == nsteps`` is the stage's min-margin scan whose
    last tile emits the latched outputs.  ``program_id`` values are only
    ever *compared* (`pl.when` step/tile selection), never used as
    addresses — block addressing is entirely BlockSpec-driven.
    """
    s = pl.program_id(1)
    ni = pl.program_id(2)

    @pl.when((s == 0) & (ni == 0))
    def _load():
        w_s[...] = w0_ref[...].astype(jnp.float32)
        b_s[...] = b0_ref[...].astype(jnp.float32)

    @pl.when(ni == 0)
    def _zero():
        g_s[...] = jnp.zeros_like(g_s)
        gb_s[...] = jnp.zeros_like(gb_s)
        mm_s[...] = jnp.full_like(mm_s, BIG)

    X = x_ref[...].astype(jnp.float32)                   # (bb, bn, d)
    yv = y_ref[...].astype(jnp.float32)                  # (bb, bn)
    valid = yv != 0.0
    w = w_s[...]                                         # (bb, d)
    # margins of the current iterate on this tile — MXU batched matvec
    m = yv * (jnp.einsum("bnd,bd->bn", X, w,
                         preferred_element_type=jnp.float32)
              + b_s[...][:, None])

    @pl.when(s < nsteps)
    def _grad():
        viol = ((m < 1.0) & valid).astype(jnp.float32)
        vy = viol * yv
        g_s[...] += jnp.einsum("bn,bnd->bd", vy, X,
                               preferred_element_type=jnp.float32)
        gb_s[...] += jnp.sum(vy, axis=1)

    @pl.when(s == nsteps)
    def _margin():
        mm_s[...] = jnp.minimum(mm_s[...],
                                jnp.min(jnp.where(valid, m, BIG), axis=1))

    @pl.when((s < nsteps) & (ni == num_n_blocks - 1))
    def _update():
        lam = lam_ref[...].astype(jnp.float32)           # (bb,)
        nv = nv_ref[...].astype(jnp.float32)
        eta = 1.0 / (lam * (s.astype(jnp.float32) + 2.0 + t0))
        gw = lam[:, None] * w_s[...] - g_s[...] / nv[:, None]
        gb = -gb_s[...] / nv
        w2 = w_s[...] - eta[:, None] * gw
        b2 = b_s[...] - eta * gb
        nrm = jnp.sqrt(jnp.sum(w2 * w2, axis=1))
        scale = jnp.minimum(1.0, (1.0 / jnp.sqrt(lam)) / (nrm + 1e-12))
        w_s[...] = w2 * scale[:, None]
        b_s[...] = b2 * scale

    @pl.when((s == nsteps) & (ni == num_n_blocks - 1))
    def _emit():
        mm = mm_s[...]
        ok = mm > 0.0                                    # BIG ⇒ no valid rows
        found_in = found_ref[...] != 0
        take = ok & ~found_in
        w_out[...] = w_s[...]
        b_out[...] = b_s[...]
        mmin_out[...] = mm
        found_out[...] = (found_in | ok).astype(jnp.int32)
        wbest_out[...] = jnp.where(take[:, None], w_s[...],
                                   wb_ref[...].astype(jnp.float32))
        bbest_out[...] = jnp.where(take, b_s[...],
                                   bb_ref[...].astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("nsteps", "t0", "block_b",
                                             "block_n", "interpret"))
def pegasos_stage_batched(
    X: jnp.ndarray,                # (B, N, d) f32; label-0 rows are padding
    y: jnp.ndarray,                # (B, N) f32 in {+1, -1, 0}
    nv: jnp.ndarray,               # (B,) f32 — per-instance valid row count
    w: jnp.ndarray,                # (B, d) stage-entry separator
    b: jnp.ndarray,                # (B,)
    lam: jnp.ndarray,              # (B,) per-instance stage λ
    found: jnp.ndarray,            # (B,) i32 — latch state in
    w_best: jnp.ndarray,           # (B, d) latched separator in
    b_best: jnp.ndarray,           # (B,)
    *,
    nsteps: int,
    t0: float = 0.0,
    block_b: int = 8,
    block_n: int = 512,
    interpret: bool = False,
):
    """One fused Pegasos λ stage + first-0-error latch as one pallas_call.

    Shapes must tile evenly (the ``ops.pegasos_stage`` wrapper pads).
    Returns ``(w, b, mmin, found, w_best, b_best)``; ``mmin`` uses the
    kernel mask constant ``BIG`` (not inf) for instances with no valid
    rows — callers that need the inf convention recompute margins
    themselves (``_svm_solve_batch`` does, for canonicalization only).
    """
    B, N, d = X.shape
    block_b = min(block_b, B)
    block_n = min(block_n, N)
    assert B % block_b == 0 and N % block_n == 0, (B, block_b, N, block_n)
    nb, nn = B // block_b, N // block_n

    kernel = functools.partial(_pegasos_stage_kernel, nsteps=nsteps,
                               num_n_blocks=nn, t0=t0)
    vec = pl.BlockSpec((block_b,), lambda bi, s, ni: (bi,))
    mat = pl.BlockSpec((block_b, d), lambda bi, s, ni: (bi, 0))
    w_o, b_o, mm_o, f_o, wb_o, bb_o = pl.pallas_call(
        kernel,
        grid=(nb, nsteps + 1, nn),
        in_specs=[
            pl.BlockSpec((block_b, block_n, d),
                         lambda bi, s, ni: (bi, ni, 0)),
            pl.BlockSpec((block_b, block_n), lambda bi, s, ni: (bi, ni)),
            vec, mat, vec, vec, vec, mat, vec,
        ],
        out_specs=[mat, vec, vec, vec, mat, vec],
        out_shape=[
            jax.ShapeDtypeStruct((B, d), jnp.float32),
            jax.ShapeDtypeStruct((B,), jnp.float32),
            jax.ShapeDtypeStruct((B,), jnp.float32),
            jax.ShapeDtypeStruct((B,), jnp.int32),
            jax.ShapeDtypeStruct((B, d), jnp.float32),
            jax.ShapeDtypeStruct((B,), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_b, d), jnp.float32),       # w iterate
            pltpu.VMEM((block_b,), jnp.float32),         # b iterate
            pltpu.VMEM((block_b, d), jnp.float32),       # hinge-gradient acc
            pltpu.VMEM((block_b,), jnp.float32),         # offset-gradient acc
            pltpu.VMEM((block_b,), jnp.float32),         # running min margin
        ],
        interpret=interpret,
    )(X, y, nv, w, b, lam, found, w_best, b_best)
    return w_o, b_o, mm_o, f_o, wb_o, bb_o
