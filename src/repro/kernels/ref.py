"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the direct, unblocked mathematical definition — no tiling,
no online rescaling — used by the per-kernel ``assert_allclose`` sweeps in
``tests/test_kernels.py``.
"""

from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def attention_ref(
    q: jnp.ndarray,                # (B, Sq, H, hd)
    k: jnp.ndarray,                # (B, Skv, KV, hd)
    v: jnp.ndarray,                # (B, Skv, KV, hdv)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    kv_valid: Optional[int] = None,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Dense softmax attention with GQA broadcast."""
    B, Sq, H, hd = q.shape
    _, Skv, KV, hdv = v.shape
    G = H // KV
    k = jnp.repeat(k, G, axis=2)
    v = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bshd->bhqs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    rows = q_offset + jnp.arange(Sq)
    cols = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= cols[None, :] <= rows[:, None]
    if window is not None:
        mask &= cols[None, :] > rows[:, None] - window
    if kv_valid is not None:
        mask &= cols[None, :] < kv_valid
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return jnp.einsum("bhqs,bshd->bqhd", p, v.astype(jnp.float32)).astype(q.dtype)


def rwkv6_ref(
    r: jnp.ndarray,                # (B, S, H, hd)
    k: jnp.ndarray,
    v: jnp.ndarray,
    w: jnp.ndarray,                # data-dependent decay in (0, 1)
    u: jnp.ndarray,                # (H, hd) bonus
    S0: Optional[jnp.ndarray] = None,  # (B, H, hd, hd)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential RWKV-6 recurrence (the Finch time-mix WKV loop).

      y_t = r_t · (S_{t-1} + diag(u) k_t v_tᵀ);  S_t = diag(w_t) S_{t-1} + k_t v_tᵀ
    """
    B, S, H, hd = r.shape
    Sst = jnp.zeros((B, H, hd, hd), jnp.float32) if S0 is None else S0.astype(jnp.float32)

    def step(Swkv, t):
        r_t, k_t, v_t, w_t = t
        kv = k_t[..., :, None] * v_t[..., None, :]
        y = jnp.einsum("bhk,bhkv->bhv", r_t, Swkv + u.astype(jnp.float32)[..., :, None] * kv)
        Swkv = w_t[..., :, None] * Swkv + kv
        return Swkv, y

    xs = tuple(a.astype(jnp.float32).transpose(1, 0, 2, 3) for a in (r, k, v, w))
    ST, ys = jax.lax.scan(step, Sst, xs)
    return ys.transpose(1, 0, 2, 3).astype(r.dtype), ST


def mamba_ref(
    xc: jnp.ndarray,               # (B, S, di) conv'd+silu'd inputs
    delta: jnp.ndarray,            # (B, S, di) softplus'd step sizes
    A: jnp.ndarray,                # (di, ds) negative
    Bs: jnp.ndarray,               # (B, S, ds)
    Cs: jnp.ndarray,               # (B, S, ds)
    h0: Optional[jnp.ndarray] = None,  # (B, di, ds)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential Mamba-1 selective scan.

      h_t = exp(Δ_t A) h_{t-1} + (Δ_t x_t) B_t;   y_t = h_t C_tᵀ
    """
    B, S, di = xc.shape
    ds = A.shape[1]
    h_init = jnp.zeros((B, di, ds), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, t):
        d_t, B_t, C_t, x_t = t
        dA = jnp.exp(d_t[..., None].astype(jnp.float32) * A.astype(jnp.float32))
        dBx = (d_t * x_t)[..., None].astype(jnp.float32) * B_t[:, None, :].astype(jnp.float32)
        h = dA * h + dBx
        y_t = jnp.einsum("bds,bs->bd", h, C_t.astype(jnp.float32))
        return h, y_t

    xs = (delta.transpose(1, 0, 2), Bs.transpose(1, 0, 2),
          Cs.transpose(1, 0, 2), xc.transpose(1, 0, 2))
    hT, ys = jax.lax.scan(step, h_init, xs)
    return ys.transpose(1, 0, 2).astype(xc.dtype), hT


def threshold_ranges_ref(
    V: jnp.ndarray,                # (m, d) directions
    Xw: jnp.ndarray,               # (n, d) transcript points
    yw: jnp.ndarray,               # (n,) ±1
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-direction consistent-threshold interval (lo, hi).

    Convention matches ``repro.core.geometry.consistent_threshold_ranges``:
    predict +1 iff v·x < t, so lo = max over positives, hi = min over
    negatives.  Label-0 rows (the padding convention) constrain neither side.
    """
    proj = V @ Xw.T
    big = jnp.inf
    pos = yw == 1
    neg = yw == -1
    lo = jnp.max(jnp.where(pos[None, :], proj, -big), axis=1, initial=-big)
    hi = jnp.min(jnp.where(neg[None, :], proj, big), axis=1, initial=big)
    return lo, hi


def uncertain_mask_ref(
    V: jnp.ndarray,                # (m, d)
    dir_ok: jnp.ndarray,           # (m,) bool
    lo: jnp.ndarray,               # (m,)
    hi: jnp.ndarray,               # (m,)
    X: jnp.ndarray,                # (n, d)
    y: jnp.ndarray,                # (n,) ±1
) -> jnp.ndarray:
    """Set-of-uncertainty membership for each point of (X, y)."""
    nonempty = (lo < hi) & dir_ok
    proj = V @ X.T                 # (m, n)
    pos_risk = proj > lo[:, None]
    neg_risk = proj < hi[:, None]
    at_risk = jnp.where((y == 1)[None, :], pos_risk, neg_risk)
    return jnp.any(at_risk & nonempty[:, None], axis=0)


def median_cut_scores_ref(
    V: jnp.ndarray,                # (m, d)
    dir_ok: jnp.ndarray,           # (m,) bool
    lo: jnp.ndarray,               # (m,)
    hi: jnp.ndarray,               # (m,)
    X: jnp.ndarray,                # (n, d)
    y: jnp.ndarray,                # (n,) ±1 (0 = padding row)
) -> jnp.ndarray:
    """Median-cut scores (int32, (m,)): for each allowed cut angle, the
    smaller of the two counts of points whose whole at-risk arc lies
    strictly on one side — the discretized weighted-median hull edge the
    MEDIAN coordinator proposes (``argmax``).  Disallowed cuts score -1.

    Integer counts, so the Pallas kernel
    (``kernels.median_cut.median_cut_scores_batched``) matches bit-for-bit.
    """
    m = V.shape[0]
    proj = V @ X.T                                      # (m, n)
    nonempty = (lo < hi) & dir_ok
    lo_r = jnp.where(nonempty, lo, jnp.inf)
    hi_r = jnp.where(nonempty, hi, -jnp.inf)
    risk = jnp.where((y == 1)[None, :],
                     proj > lo_r[:, None], proj < hi_r[:, None])
    c = jnp.cumsum(risk.astype(jnp.int32), axis=0)      # (m, n)
    total = c[-1:, :]
    live = (total > 0) & ((y != 0)[None, :])
    below = jnp.sum(live & (c == total), axis=1)
    above = jnp.sum(live & (c == 0), axis=1)
    return jnp.where(dir_ok, jnp.minimum(below, above), -1).astype(jnp.int32)


def median_extremes_ref(
    v: jnp.ndarray,                # (d,) proposed direction
    XW: jnp.ndarray,               # (k, nW, d) per-node own ∪ capped transcript
    yW: jnp.ndarray,               # (k, nW) ±1 (0 = padding row)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """MEDIAN's per-turn extremes scan (single instance): for each node, the
    row index of its extreme band point along ``v`` per class — the first
    argmax of the projection over positive rows (``i_p``) and the first
    argmin over negative rows (``i_q``); index 0 when the class is absent
    (callers gate on presence, derived from ``yW`` directly).

    Integer row choices only, so the fill-capped Pallas kernel
    (``kernels.support_margin.median_extremes_batched``) matches
    bit-for-bit.  "Fill-capped": the hot loop passes transcripts sliced to
    the live width, not the static capacity — any ``nW`` is valid under the
    label-0 padding convention.
    """
    pj = XW @ v                                          # (k, nW)
    i_p = jnp.argmax(jnp.where(yW == 1, pj, -jnp.inf), axis=1)
    i_q = jnp.argmin(jnp.where(yW == -1, pj, jnp.inf), axis=1)
    return i_p.astype(jnp.int32), i_q.astype(jnp.int32)


def _topr_ranks(key: jnp.ndarray, member: jnp.ndarray, r: int) -> jnp.ndarray:
    """Rank of the ``r`` smallest member entries under ascending (key, index)
    order; everything else gets the sentinel ``n``.

    Equivalent to a capped stable-argsort rank (exact ties resolve to the
    lowest index — ``argmin`` returns the first minimum), but costs r
    argmin+mask passes instead of a sort: r is a protocol constant (≤ 8
    shipped support points), so this is the cheap CPU spelling of the same
    integer decision the Pallas kernel computes via counting comparisons.
    """
    n = key.shape[0]
    idx = jnp.arange(n)
    k2 = jnp.where(member, key, jnp.inf)
    out = jnp.full((n,), n, jnp.int32)
    for t in range(r):
        i = jnp.argmin(k2)
        hit = (idx == i) & jnp.isfinite(k2[i])
        out = jnp.where(hit, t, out)
        k2 = jnp.where(hit, jnp.inf, k2)
    return out


def maxmarg_turn_ref(
    w: jnp.ndarray,                # (d,) refit separator weights
    b: jnp.ndarray,                # ()   refit separator offset
    K: jnp.ndarray,                # (N, d) coordinator's own ∪ transcript
    yK: jnp.ndarray,               # (N,) ±1 (0 = padding row)
    X: jnp.ndarray,                # (k, n, d) per-node shards
    y: jnp.ndarray,                # (k, n) ±1 (0 = padding row)
    *,
    rtol: float = 0.15,
    max_support: int = 4,
    viol_ship: int = 2,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One MAXMARG turn's fused margin scan (single instance; see the Pallas
    kernel ``kernels.support_margin.maxmarg_turn_scan_batched``).

    Returns integer decisions only, so the kernel matches bit-for-bit:

    * ``sup_rank`` (N,) i32 — stable (margin, index) rank of the
      ``max_support`` tightest fit-set rows *within the active-margin band*
      (functional margin ≤ (1+rtol)·min); every other row gets the sentinel
      N.  The caller's support selection is ``sup_rank < max_support`` and
      the ranks are the host loop's ship order.
    * ``err_k`` (k,) i32 — per-node error counts of the proposal (the
      all-clear bit is ``err_k == 0``, the ε-termination sum ``err_k.sum()``).
    * ``viol_rank`` (k, n) i32 — per-node stable margin rank of the
      ``viol_ship`` most-violated valid rows (sentinel n elsewhere): both
      the most-violated selection (``rank < viol_ship``) and the host
      loop's ``argsort(m)[:2]`` wire order.
    """
    valid_K = yK != 0
    mK = yK.astype(K.dtype) * (K @ w + b)
    mmin = jnp.maximum(jnp.min(jnp.where(valid_K, mK, jnp.inf)), 1e-12)
    band = valid_K & (mK <= mmin * (1.0 + rtol))
    sup_rank = _topr_ranks(mK, band, max_support)

    dec = X @ w + b                                      # (k, n)
    pred = jnp.where(dec > 0, 1, -1)
    valid = y != 0
    err_k = jnp.sum((pred != y) & valid, axis=1).astype(jnp.int32)
    m_all = y.astype(K.dtype) * dec
    viol_rank = jax.vmap(
        lambda key, mem: _topr_ranks(key, mem, viol_ship))(m_all, valid)
    return sup_rank, err_k, viol_rank


# Batched (sweep) oracles: the engine's CPU/interpret data-plane path and the
# parity reference for the batch-grid Pallas kernels.  V is shared across the
# batch; everything else carries a leading instance axis B.

threshold_ranges_batch_ref = jax.jit(
    jax.vmap(threshold_ranges_ref, in_axes=(None, 0, 0)))

uncertain_mask_batch_ref = jax.jit(
    jax.vmap(uncertain_mask_ref, in_axes=(None, 0, 0, 0, 0, 0)))

median_cut_scores_batch_ref = jax.jit(
    jax.vmap(median_cut_scores_ref, in_axes=(None, 0, 0, 0, 0, 0)))

median_extremes_batch_ref = jax.jit(
    jax.vmap(median_extremes_ref, in_axes=(0, 0, 0)))

@functools.partial(jax.jit, static_argnames=("rtol", "max_support",
                                             "viol_ship"))
def maxmarg_turn_batch_ref(w, b, K, yK, X, y, *, rtol: float = 0.15,
                           max_support: int = 4, viol_ship: int = 2):
    """Batched :func:`maxmarg_turn_ref` — the engine's CPU scan path and the
    bit-for-bit parity reference for the fused support/violation kernel."""
    return jax.vmap(functools.partial(
        maxmarg_turn_ref, rtol=rtol, max_support=max_support,
        viol_ship=viol_ship))(w, b, K, yK, X, y)


@functools.partial(jax.jit, static_argnames=("nsteps", "t0", "unroll"))
def pegasos_stage_batch_ref(
    X: jnp.ndarray,                # (B, N, d) f32; label-0 rows are padding
    y: jnp.ndarray,                # (B, N) f32 in {+1, -1, 0}
    nv: jnp.ndarray,               # (B,) f32 valid row counts
    w: jnp.ndarray,                # (B, d)
    b: jnp.ndarray,                # (B,)
    lam: jnp.ndarray,              # (B,)
    found: jnp.ndarray,            # (B,) bool first-0-error latch state
    w_best: jnp.ndarray,           # (B, d)
    b_best: jnp.ndarray,           # (B,)
    *,
    nsteps: int,
    t0: float = 0.0,
    unroll: int = 2,
):
    """One fused Pegasos λ stage + first-0-error latch: the jnp twin of
    ``kernels.pegasos.pegasos_stage_batched`` and the solver's CPU fast
    path (``_svm_solve_batch(kernel=True)`` off-TPU).

    Same op sequence as the kernel body — einsum d-contractions (real
    GEMMs, unlike the classic solver's per-d broadcast unroll, which is
    what makes this the d ≫ 2 fast path even on CPU), hinge gradient
    normalized by ``nv``, L2-ball projection, and a trailing min-margin
    scan folded into the latch.  ``mmin`` uses the kernel mask constant
    ``pegasos.BIG`` (not inf) for instances with no valid rows.  ``unroll``
    is the CPU tuning knob from the autotune cache; it never changes the
    math, only the fori_loop unrolling.
    """
    big = 1e30
    valid = y != 0.0

    def step(i, carry):
        wi, bi = carry
        m = y * (jnp.einsum("bnd,bd->bn", X, wi) + bi[:, None])
        vy = ((m < 1.0) & valid).astype(X.dtype) * y
        g = jnp.einsum("bn,bnd->bd", vy, X)
        gb = -jnp.sum(vy, axis=1) / nv
        eta = 1.0 / (lam * (i.astype(X.dtype) + 2.0 + t0))
        w2 = wi - eta[:, None] * (lam[:, None] * wi - g / nv[:, None])
        b2 = bi - eta * gb
        nrm = jnp.sqrt(jnp.sum(w2 * w2, axis=1))
        scale = jnp.minimum(1.0, (1.0 / jnp.sqrt(lam)) / (nrm + 1e-12))
        return w2 * scale[:, None], b2 * scale

    w, b = jax.lax.fori_loop(0, nsteps, step, (w, b), unroll=unroll)
    m = y * (jnp.einsum("bnd,bd->bn", X, w,
                        preferred_element_type=jnp.float32) + b[:, None])
    mmin = jnp.min(jnp.where(valid, m, big), axis=1)
    ok = mmin > 0.0
    take = ok & ~found
    return (w, b, mmin, found | ok,
            jnp.where(take[:, None], w, w_best),
            jnp.where(take, b, b_best))
