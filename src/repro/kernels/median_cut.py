"""Pallas TPU kernel for the batched (B, m, n) median-cut scan.

The MEDIAN selector's per-turn hot loop (engine ``median.step`` part 2):
for every allowed cut angle θ_i, count the points whose whole at-risk arc
lies strictly on each side of the cut, and score the cut by the smaller
count — the discretized weighted-median hull edge of paper Alg. 2.  The
coordinator proposes ``argmax score``.

Formulation: with the per-point running risk count ``c[i, p] = |{j ≤ i :
risk[j, p]}|`` down the direction axis,

  below[i] = #{p live : c[i, p] == c[m-1, p]}   (arc entirely ≤ cut i)
  above[i] = #{p live : c[i, p] == 0}           (arc entirely > cut i)
  score[i] = dir_ok[i] ? min(below[i], above[i]) : -1

where ``live`` means the point is at risk somewhere and is not a label-0
padding row.  All counts are integers, so the kernel matches the pure-jnp
reference (``kernels.ref.median_cut_scores_ref``) bit-for-bit.

Grid layout ``(B, n_blocks)``: the whole direction axis m lives in one block
(the cumulative count couples all m rows of a point's risk column), points
stream through VMEM in ``block_n`` tiles, and the two (m,) count
accumulators live in VMEM scratch across the n sweep.  The (m, bn)
projection is one MXU matmul per tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _median_cut_kernel(v_ref, ok_ref, lo_ref, hi_ref, x_ref, y_ref, out_ref,
                       acc_below, acc_above, *, num_n_blocks: int):
    ni = pl.program_id(1)

    @pl.when(ni == 0)
    def _init():
        acc_below[...] = jnp.zeros_like(acc_below)
        acc_above[...] = jnp.zeros_like(acc_above)

    V = v_ref[...].astype(jnp.float32)           # (m, d) — shared across B
    X = x_ref[0].astype(jnp.float32)             # (bn, d) — this instance
    y = y_ref[0].astype(jnp.float32)             # (bn,) ±1, 0 = padding
    ok = ok_ref[0]                               # (m,) 1.0/0.0
    lo = lo_ref[0]                               # (m,) — ±inf sentinels OK
    hi = hi_ref[0]

    proj = V @ X.T                               # (m, bn) — MXU
    nonempty = (lo < hi) & (ok != 0.0)           # (m,)
    # folding the row mask into the bounds (±inf ⇒ comparison always false)
    # keeps the risk pipeline to one fused select pass, as in the engine
    lo_r = jnp.where(nonempty, lo, jnp.inf)
    hi_r = jnp.where(nonempty, hi, -jnp.inf)
    risk = jnp.where((y == 1.0)[None, :],
                     proj > lo_r[:, None], proj < hi_r[:, None])
    c = jnp.cumsum(risk.astype(jnp.int32), axis=0)      # (m, bn)
    total = c[-1:, :]                                   # (1, bn)
    live = (total > 0) & ((y != 0.0)[None, :])
    acc_below[...] += jnp.sum(live & (c == total), axis=1).astype(jnp.int32)
    acc_above[...] += jnp.sum(live & (c == 0), axis=1).astype(jnp.int32)

    @pl.when(ni == num_n_blocks - 1)
    def _emit():
        out_ref[0] = jnp.where(
            ok_ref[0] != 0.0,
            jnp.minimum(acc_below[...], acc_above[...]),
            -1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def median_cut_scores_batched(
    V: jnp.ndarray,                # (m, d) directions, shared over the batch
    dir_ok: jnp.ndarray,           # (B, m) float 1.0/0.0 — per instance
    lo: jnp.ndarray,               # (B, m) consistent-threshold lows
    hi: jnp.ndarray,               # (B, m) consistent-threshold highs
    X: jnp.ndarray,                # (B, n, d) shard points
    y: jnp.ndarray,                # (B, n) ±1 (0 = padding row)
    *,
    block_n: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Median-cut scores for a whole sweep batch in one pallas_call; returns
    (B, m) int32 (the caller argmaxes).  Shapes must tile evenly (the
    ops.py wrapper pads); the full m axis is one block."""
    m, d = V.shape
    B, n = X.shape[0], X.shape[1]
    block_n = min(block_n, n)
    assert n % block_n == 0, (n, block_n)
    nn = n // block_n

    kernel = functools.partial(_median_cut_kernel, num_n_blocks=nn)
    out = pl.pallas_call(
        kernel,
        grid=(B, nn),
        in_specs=[
            pl.BlockSpec((m, d), lambda b, j: (0, 0)),
            pl.BlockSpec((1, m), lambda b, j: (b, 0)),
            pl.BlockSpec((1, m), lambda b, j: (b, 0)),
            pl.BlockSpec((1, m), lambda b, j: (b, 0)),
            pl.BlockSpec((1, block_n, d), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, block_n), lambda b, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, m), lambda b, j: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, m), jnp.int32),
        scratch_shapes=[pltpu.VMEM((m,), jnp.int32),
                        pltpu.VMEM((m,), jnp.int32)],
        interpret=interpret,
    )(V, dir_ok, lo, hi, X, y)
    return out
