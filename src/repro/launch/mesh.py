"""Production mesh definitions.

Target: TPU v5e pods — 256 chips/pod in a (16, 16) ("data", "model") layout;
multi-pod adds a leading "pod" axis (2 pods = 512 chips) used for data
parallelism across pods (DCN-ish axis).  Built on demand — importing this
module never touches jax device state.

``make_data_mesh`` is the engine-facing entry point: a 1-D ("data",) mesh
over the host's devices, the axis the sharded hot loop
(:mod:`repro.engine.hotloop`) splits the instance batch over.  On a CPU
host, fake devices come from ``XLA_FLAGS=--xla_force_host_platform_
device_count=N`` — set *before* jax import (the sharded tests and
``benchmarks/engine_sweep.py --devices N`` both do).
"""

from __future__ import annotations

from typing import Optional

import jax


def _axis_kw(n_axes: int) -> dict:
    """``axis_types`` kwarg when this jax version has explicit axis kinds
    (0.5+); older versions (0.4.x) have Auto-only meshes and no AxisType."""
    if hasattr(jax.sharding, "AxisType"):
        return {"axis_types": (jax.sharding.AxisType.Auto,) * n_axes}
    return {}


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kw(len(axes)))


def make_host_mesh(model: int = 1, data: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    model = min(model, n)
    data = max(1, min(data, n // model))
    return jax.make_mesh((data, model), ("data", "model"), **_axis_kw(2))


def make_data_mesh(n_devices: Optional[int] = None) -> jax.sharding.Mesh:
    """1-D ("data",) mesh for the engine's sharded hot loop.

    Uses ``n_devices`` devices (default: all available).  The engine shards
    its leading instance axis B over this axis — ``pack_instances(...,
    mesh=...)`` pads B to a multiple of the axis size with born-done dummy
    instances so every shard carries an equal slice.
    """
    avail = len(jax.devices())
    n = avail if n_devices is None else n_devices
    if not 1 <= n <= avail:
        raise ValueError(f"need 1 <= n_devices <= {avail}, got {n}")
    return jax.make_mesh((n,), ("data",), **_axis_kw(1))


# TPU v5e hardware constants (per chip) used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW_PER_LINK = 50e9        # bytes/s/link
CHIP_HBM_BYTES = 16e9         # 16 GB
