"""Production mesh definitions.

Target: TPU v5e pods — 256 chips/pod in a (16, 16) ("data", "model") layout;
multi-pod adds a leading "pod" axis (2 pods = 512 chips) used for data
parallelism across pods (DCN-ish axis).  Built on demand — importing this
module never touches jax device state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    kinds = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=kinds)


def make_host_mesh(model: int = 1, data: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    model = min(model, n)
    data = max(1, min(data, n // model))
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


# TPU v5e hardware constants (per chip) used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW_PER_LINK = 50e9        # bytes/s/link
CHIP_HBM_BYTES = 16e9         # 16 GB
