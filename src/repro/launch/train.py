"""Training launcher: the cluster entry point.

Builds the mesh from whatever devices exist (the production (16,16) /
(2,16,16) meshes on a real pod; a 1×N host mesh on CPU), applies the same
sharding rules and case policy the dry-run validates, and runs the jit'd
train step with the synthetic pipeline.

Examples:
  # reduced smoke run on this host
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --reduced \
      --steps 50 --batch 8 --seq 128
  # full config on a pod (device count must match the mesh)
  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-14b --steps 1000
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs import ARCHS, get_config
from repro.data.pipeline import DataConfig, synthetic_stream
from repro.distribution.sharding import batch_shardings, opt_shardings, param_shardings
from repro.launch.mesh import make_production_mesh
from repro.models.config import InputShape
from repro.models.model import init_lm
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.train.trainer import TrainConfig, make_train_step


def make_host_mesh() -> Mesh:
    """Best mesh for the devices we actually have."""
    devs = jax.devices()
    n = len(devs)
    if n >= 512:
        return make_production_mesh(multi_pod=True)
    if n >= 256:
        return make_production_mesh(multi_pod=False)
    import numpy as np
    return Mesh(np.asarray(devs).reshape(1, n), ("data", "model"))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm-135m", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh()
    n_dev = mesh.devices.size
    pure_dp = cfg.param_count() < 3e9
    print(f"arch={cfg.name} params={cfg.param_count() / 1e6:.1f}M "
          f"mesh={dict(mesh.shape)} pure_dp={pure_dp}")

    tc = TrainConfig(steps=args.steps, warmup=max(5, args.steps // 20),
                     log_every=max(1, args.steps // 20), ckpt_dir=args.ckpt,
                     dtype=jnp.float32 if n_dev == 1 else jnp.bfloat16,
                     microbatches=args.microbatches,
                     optim=AdamWConfig(lr=args.lr))
    dc = DataConfig(seq_len=args.seq, global_batch=args.batch)
    shape = InputShape("cli", args.seq, args.batch, "train")

    with jax.set_mesh(mesh):
        params = init_lm(jax.random.PRNGKey(0), cfg)
        opt = adamw_init(params)
        psh = param_shardings(mesh, params, pure_dp=pure_dp)
        osh = opt_shardings(mesh, opt, pure_dp=pure_dp)
        params = jax.device_put(params, psh)
        opt = jax.device_put(opt, osh)
        step = jax.jit(make_train_step(cfg, tc),
                       in_shardings=(psh, osh, None),
                       out_shardings=(psh, osh, None),
                       donate_argnums=(0, 1))
        data = synthetic_stream(cfg, dc)
        t0 = time.time()
        last = {}
        for i in range(args.steps):
            batch = {k: jnp.asarray(v) for k, v in next(data).items()}
            params, opt, metrics = step(params, opt, batch)
            if i % tc.log_every == 0 or i == args.steps - 1:
                last = {k: float(v) for k, v in metrics.items()}
                print(f"step {i:5d} loss {last['loss']:.4f} "
                      f"acc {last.get('acc', 0):.3f} ({time.time() - t0:.1f}s)")
        if args.ckpt:
            from repro.train.checkpoint import save_checkpoint
            save_checkpoint(args.ckpt, params, opt, step=args.steps)
            print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
