import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT lower + compile every (arch × input-shape × mesh).

The two lines above MUST stay the first statements — jax locks the device
count at first init, and the production meshes need 512 host placeholders.
Never set that flag globally (smoke tests and benches must see 1 device).

For every case this script:
  1. builds ShapeDtypeStruct stand-ins for params / optimizer / batch / caches
     (zero allocation),
  2. lowers the jit'd step with explicit in/out shardings on the production
     mesh — train_4k lowers ``train_step``, prefill_32k lowers ``prefill``,
     decode shapes lower ``serve_step`` (one token against seq_len caches),
  3. compiles, prints memory_analysis() (proof of fit) and cost_analysis()
     (roofline terms), parses collective bytes from the HLO,
  4. appends a JSON record consumed by EXPERIMENTS.md §Dry-run/§Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out benchmarks/results/dryrun.jsonl
"""

import argparse
import dataclasses
import json
import time
import traceback
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.analysis.roofline import analyze_compiled, model_flops_estimate
from repro.configs import ARCHS, get_config
from repro.data.pipeline import dec_len, make_batch_specs
from repro.distribution.sharding import (
    batch_shardings, cache_shardings, opt_shardings, param_shardings)
from repro.launch.mesh import make_production_mesh
from repro.models.config import INPUT_SHAPES, InputShape, ModelConfig
from repro.models.model import RunFlags, decode_step, init_lm, make_caches, prefill
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.train.trainer import TrainConfig, make_train_step


@dataclasses.dataclass
class CasePolicy:
    """Execution policy for one (arch, shape): what the launcher would set."""
    skip: Optional[str] = None
    window: Optional[int] = None
    cache_len: int = 0
    enc_len: int = 0
    microbatches: int = 1
    param_dtype: Any = jnp.float32
    moment_dtype: str = "f32"
    fsdp: bool = False
    pure_dp: bool = False
    mla_absorb: bool = False
    remat: bool = True
    block_q: int = 1024
    loss_chunk: int = 512


def case_policy(cfg: ModelConfig, shape: InputShape) -> CasePolicy:
    pol = CasePolicy()
    n = cfg.param_count()
    pol.fsdp = n > 20e9
    # small models: tensor parallelism replicates whole mixers when head
    # counts don't divide the model axis — run them pure data-parallel.
    # Train shapes: the global batch divides the full mesh, so pure-DP wins
    # for everything under ~3B.  Serving shapes keep TP unless the model is
    # tiny (<0.5B — replicated weights are free and smollm's 9-head TP
    # prefill was 1700× collective-over-compute); mid-size serving under
    # pure-DP regressed 4-9× in the sweep (EXPERIMENTS.md §Perf).
    # decode always keeps TP: even when heads replicate, TP shards the KV
    # cache head_dim 16× (smollm pure-DP decode regressed 15× on memory).
    if shape.kind == "train":
        pol.pure_dp = n < 3e9
    elif shape.kind == "prefill":
        pol.pure_dp = n < 0.5e9
    else:
        pol.pure_dp = False
    pol.param_dtype = jnp.float32 if (shape.kind == "train" and n <= 20e9) else jnp.bfloat16
    pol.moment_dtype = "bf16" if n > 20e9 else "f32"
    pol.microbatches = 8 if n > 50e9 else (4 if n > 3e9 else 1)
    if cfg.enc_dec:
        pol.enc_len = shape.seq_len if shape.kind != "decode" else 1500
    if shape.kind == "decode":
        pol.cache_len = shape.seq_len
        if shape.name == "long_500k":
            if cfg.enc_dec:
                pol.skip = ("enc-dec full-attention decoder: 500k-token decode is "
                            "out of family scope (DESIGN.md §Arch-applicability)")
            elif cfg.sliding_window and not cfg.has_state_mixer and cfg.mla is None:
                # dense/vlm/standard-MoE attention: sliding-window variant
                pol.window = cfg.sliding_window
                pol.cache_len = cfg.sliding_window
            # SSM/hybrid run natively; MLA runs on its compressed latent cache
    if shape.kind != "train":
        pol.remat = False
    pol.loss_chunk = min(512, dec_len(cfg, shape.seq_len))
    return pol


def lower_case(cfg: ModelConfig, shape: InputShape, mesh, pol: CasePolicy):
    """Build + lower the jitted step for one case. Returns (lowered, meta)."""
    from repro.distribution.constraints import set_dp_axes
    if pol.pure_dp and shape.global_batch % mesh.devices.size != 0:
        # pure-DP only pays when the global batch fills the whole mesh
        # (256 % 512 ≠ 0 regressed smollm 2× on the multi-pod sweep)
        pol.pure_dp = False
    set_dp_axes(("pod", "data", "model") if pol.pure_dp else None)
    flags = RunFlags(window=pol.window, mla_absorb=pol.mla_absorb,
                     block_q=pol.block_q, remat=pol.remat,
                     loss_chunk=pol.loss_chunk)
    pshapes = jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), cfg, pol.param_dtype))
    psh = param_shardings(mesh, pshapes, fsdp=pol.fsdp, pure_dp=pol.pure_dp)

    if shape.kind == "train":
        tc = TrainConfig(dtype=jnp.bfloat16, microbatches=pol.microbatches,
                         optim=AdamWConfig(moment_dtype=pol.moment_dtype),
                         flags=flags)
        step = make_train_step(cfg, tc)
        oshapes = jax.eval_shape(partial(adamw_init, moment_dtype=pol.moment_dtype),
                                 pshapes)
        osh = opt_shardings(mesh, oshapes, fsdp=pol.fsdp, pure_dp=pol.pure_dp)
        bspecs = make_batch_specs(cfg, shape)
        bsh = batch_shardings(mesh, bspecs, shape, pure_dp=pol.pure_dp)
        fn = jax.jit(step, in_shardings=(psh, osh, bsh),
                     out_shardings=(psh, osh, None), donate_argnums=(0, 1))
        return fn.lower(pshapes, oshapes, bspecs)

    if shape.kind == "prefill":
        Sd = dec_len(cfg, shape.seq_len)
        cshapes = jax.eval_shape(lambda: make_caches(
            cfg, shape.global_batch, Sd, jnp.bfloat16, enc_len=pol.enc_len))
        csh = cache_shardings(mesh, cshapes, shape, cfg, pure_dp=pol.pure_dp)
        bspecs = make_batch_specs(cfg, shape)
        bsh = batch_shardings(mesh, bspecs, shape, pure_dp=pol.pure_dp)

        def prefill_fn(params, batch, caches):
            return prefill(params, cfg, batch, caches, flags, dtype=jnp.bfloat16)

        fn = jax.jit(prefill_fn, in_shardings=(psh, bsh, csh),
                     out_shardings=(None, csh), donate_argnums=(2,))
        return fn.lower(pshapes, bspecs, cshapes)

    # decode
    cshapes = jax.eval_shape(lambda: make_caches(
        cfg, shape.global_batch, pol.cache_len, jnp.bfloat16, enc_len=pol.enc_len))
    csh = cache_shardings(mesh, cshapes, shape, cfg, pure_dp=pol.pure_dp)
    bspecs = make_batch_specs(cfg, shape)
    bsh = batch_shardings(mesh, bspecs, shape, pure_dp=pol.pure_dp)

    def serve_step(params, caches, tokens, pos):
        return decode_step(params, cfg, caches, tokens, pos, flags, dtype=jnp.bfloat16)

    fn = jax.jit(serve_step, in_shardings=(psh, csh, bsh["tokens"], None),
                 out_shardings=(None, csh), donate_argnums=(1,))
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return fn.lower(pshapes, cshapes, bspecs["tokens"], pos)


def run_case(arch: str, shape_name: str, mesh_kind: str,
             overrides: Optional[Dict] = None, verbose: bool = True) -> Dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    pol = case_policy(cfg, shape)
    for k, v in (overrides or {}).items():
        setattr(pol, k, v)
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                           "policy": {k: str(v) for k, v in dataclasses.asdict(pol).items()}}
    if pol.skip:
        rec["status"] = "skipped"
        rec["reason"] = pol.skip
        return rec
    multi = mesh_kind == "multi"
    chips = 512 if multi else 256
    mesh = make_production_mesh(multi_pod=multi)
    try:
        t0 = time.time()
        with jax.set_mesh(mesh):  # ambient mesh: activation constraints bind
            lowered = lower_case(cfg, shape, mesh, pol)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        rep = analyze_compiled(f"{arch}/{shape_name}/{mesh_kind}", compiled,
                               chips=chips,
                               model_flops=model_flops_estimate(cfg, shape))
        rec.update(status="ok", lower_s=round(t1 - t0, 2),
                   compile_s=round(t2 - t1, 2), roofline=rep.as_dict())
        if verbose:
            print(f"[ok] {arch:24s} {shape_name:12s} {mesh_kind:6s} "
                  f"compile={t2 - t1:6.1f}s flops/dev={rep.flops:.3e} "
                  f"mem/dev={(rep.arg_bytes + rep.temp_bytes) / 1e9:6.2f}GB "
                  f"coll/dev={rep.collective_bytes / 1e6:8.1f}MB dom={rep.dominant}")
            print("   memory_analysis:", compiled.memory_analysis())
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
        if verbose:
            print(f"[ERR] {arch} {shape_name} {mesh_kind}: {e}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", choices=["all"] + list(INPUT_SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default=None, help="append JSONL records here")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()

    archs = list(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    n_ok = n_skip = n_err = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                rec = run_case(arch, shape, mesh_kind, verbose=not args.quiet)
                if args.out:
                    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
                    with open(args.out, "a") as f:
                        f.write(json.dumps(rec) + "\n")
                n_ok += rec["status"] == "ok"
                n_skip += rec["status"] == "skipped"
                n_err += rec["status"] == "error"
    print(f"\nDRY-RUN SUMMARY: ok={n_ok} skipped={n_skip} errors={n_err}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
