# The paper's primary contribution: communication-metered protocols for
# learning classifiers on adversarially-partitioned data.
from repro.core import classifiers, comm, datasets, geometry, sampling  # noqa: F401
from repro.core.protocols import baselines, kparty, one_way, two_way  # noqa: F401

__all__ = [
    "classifiers",
    "comm",
    "datasets",
    "geometry",
    "sampling",
    "one_way",
    "two_way",
    "kparty",
    "baselines",
]
