"""Hypothesis classes from the paper.

Thresholds (R^1), intervals (R^1), axis-aligned rectangles (R^d), and linear
separators (R^d).  Each provides ``fit`` (0-error learner under the noiseless
assumption), ``predict`` and ``error``.  The linear-separator max-margin
solver is a jit'd JAX routine (Pegasos-style projected subgradient on the
hard-margin objective with margin renormalization); support points are the
active-margin points — exactly what the protocols ship.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as _kernel_ops


# ---------------------------------------------------------------------------
# Thresholds (predict +1 iff x < t)  — paper Lemma 3.1
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Threshold:
    t: float

    def predict(self, X: np.ndarray) -> np.ndarray:
        x = np.asarray(X).reshape(-1)
        return np.where(x < self.t, 1, -1)

    def error(self, X: np.ndarray, y: np.ndarray) -> float:
        return float(np.mean(self.predict(X) != y)) if len(y) else 0.0

    @staticmethod
    def fit(X: np.ndarray, y: np.ndarray) -> "Threshold":
        """Any 0-error threshold on (X, y); assumes separability."""
        x = np.asarray(X).reshape(-1)
        pos = x[y == 1]
        neg = x[y == -1]
        lo = pos.max() if len(pos) else -np.inf  # t must exceed all positives
        hi = neg.min() if len(neg) else np.inf   # and be below all negatives
        if not lo < hi:
            raise ValueError("not separable by a threshold")
        if np.isinf(lo) and np.isinf(hi):
            t = 0.0
        elif np.isinf(lo):
            t = hi - 1.0
        elif np.isinf(hi):
            t = lo + 1.0
        else:
            t = 0.5 * (lo + hi)
        return Threshold(float(t))


# ---------------------------------------------------------------------------
# Intervals (predict +1 iff a <= x <= b) — paper Lemma 3.2
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Interval:
    a: float
    b: float

    def predict(self, X: np.ndarray) -> np.ndarray:
        x = np.asarray(X).reshape(-1)
        return np.where((x >= self.a) & (x <= self.b), 1, -1)

    def error(self, X: np.ndarray, y: np.ndarray) -> float:
        return float(np.mean(self.predict(X) != y)) if len(y) else 0.0

    @staticmethod
    def fit(X: np.ndarray, y: np.ndarray) -> "Interval":
        """Minimal enclosing interval of the positives (paper's choice: 'as
        small as possible'); assumes noiseless separability."""
        x = np.asarray(X).reshape(-1)
        pos = x[y == 1]
        if len(pos) == 0:
            return Interval(0.0, -1.0)  # empty interval
        a, b = float(pos.min()), float(pos.max())
        neg = x[y == -1]
        if len(neg) and np.any((neg >= a) & (neg <= b)):
            raise ValueError("not separable by an interval")
        return Interval(a, b)


# ---------------------------------------------------------------------------
# Axis-aligned rectangles in R^d — paper Theorem 3.2
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class AxisAlignedRectangle:
    lo: np.ndarray  # (d,)
    hi: np.ndarray  # (d,)
    positive_inside: bool = True

    def predict(self, X: np.ndarray) -> np.ndarray:
        X = np.atleast_2d(X)
        inside = np.all((X >= self.lo) & (X <= self.hi), axis=1)
        lab = np.where(inside, 1, -1)
        return lab if self.positive_inside else -lab

    def error(self, X: np.ndarray, y: np.ndarray) -> float:
        return float(np.mean(self.predict(X) != y)) if len(y) else 0.0

    @staticmethod
    def minimal(X: np.ndarray) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """Minimum enclosing rectangle (the 2d values A ships, Thm 3.2);
        None plays the paper's ∅ sentinel."""
        X = np.atleast_2d(X)
        if X.shape[0] == 0:
            return None
        return X.min(axis=0), X.max(axis=0)

    @staticmethod
    def merge(
        r1: Optional[Tuple[np.ndarray, np.ndarray]],
        r2: Optional[Tuple[np.ndarray, np.ndarray]],
    ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """Coordinate-wise merge: R^+_{A∪B} from R^+_A and R^+_B."""
        if r1 is None:
            return r2
        if r2 is None:
            return r1
        return np.minimum(r1[0], r2[0]), np.maximum(r1[1], r2[1])

    @staticmethod
    def from_bounds(
        rect: Tuple[np.ndarray, np.ndarray], positive_inside: bool = True
    ) -> "AxisAlignedRectangle":
        return AxisAlignedRectangle(np.asarray(rect[0]), np.asarray(rect[1]), positive_inside)


# ---------------------------------------------------------------------------
# Linear separators — jit'd max-margin solver
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LinearSeparator:
    w: np.ndarray  # (d,)
    b: float
    margin: float = 0.0  # geometric margin on the fit set

    def predict(self, X: np.ndarray) -> np.ndarray:
        return np.where(np.atleast_2d(X) @ self.w + self.b > 0, 1, -1)

    def decision(self, X: np.ndarray) -> np.ndarray:
        return np.atleast_2d(X) @ self.w + self.b

    def error(self, X: np.ndarray, y: np.ndarray) -> float:
        return float(np.mean(self.predict(X) != y)) if len(y) else 0.0


@functools.partial(jax.jit, static_argnames=("steps",))
def _svm_solve(X: jnp.ndarray, y: jnp.ndarray, lam: jnp.ndarray, steps: int = 2000):
    """Pegasos projected subgradient on  λ/2 ||w||² + mean hinge(w·x+b)."""
    n, d = X.shape

    def body(i, carry):
        w, b = carry
        eta = 1.0 / (lam * (i + 2.0))
        m = y * (X @ w + b)
        viol = (m < 1.0).astype(X.dtype)
        gw = lam * w - (viol * y) @ X / n
        gb = -jnp.sum(viol * y) / n
        w = w - eta * gw
        b = b - eta * gb
        # pegasos projection onto ball of radius 1/sqrt(lam)
        nrm = jnp.linalg.norm(w)
        scale = jnp.minimum(1.0, (1.0 / jnp.sqrt(lam)) / (nrm + 1e-12))
        return w * scale, b * scale

    w0 = jnp.zeros((d,), X.dtype)
    b0 = jnp.zeros((), X.dtype)
    w, b = jax.lax.fori_loop(0, steps, body, (w0, b0))
    return w, b


# Warm-polish defaults: a quarter of a stage's step budget refines the
# carried separator, and the eta schedule starts as if WARM_OFFSET steps had
# already elapsed, so the first polish steps are gentle refinements instead
# of the stage-restart kicks that would wipe out the warm iterate.
WARM_STEPS = 500
WARM_OFFSET = 1024.0


@functools.partial(jax.jit, static_argnames=("steps", "stages", "warm_steps",
                                             "warm_offset", "return_gate",
                                             "kernel"))
def _svm_solve_batch(
    X: jnp.ndarray,                # (B, N, d) f32; rows with label 0 are padding
    y: jnp.ndarray,                # (B, N) f32 in {+1, -1, 0}
    lam0: jnp.ndarray,             # scalar f32 — stage-0 λ
    steps: int = 2000,
    stages: int = 3,
    w0: Optional[jnp.ndarray] = None,    # (B, d) warm-init separator
    b0: Optional[jnp.ndarray] = None,    # (B,)
    warm_ok: Optional[jnp.ndarray] = None,   # (B,) bool — init is trustworthy
    warm_steps: int = WARM_STEPS,
    warm_offset: float = WARM_OFFSET,
    return_gate: bool = False,
    kernel: Optional[bool] = None,
):
    """Batched hard-margin-annealed Pegasos: B independent fits in lock-step.

    The λ schedule (λ0, λ0/10, …) runs as one compiled loop over stages
    (``lax.while_loop`` that exits as soon as *every* instance separates —
    skipped stages could only have touched latched slots, so the early exit
    is results-identical); each stage warm-starts from the previous stage's
    (w, b) instead of re-initializing at zero, so later stages only have to
    *tighten* an already-separating direction (fewer total steps for the
    same margin — regression-tested in tests/test_svm_batch.py).  Per
    instance, the result is latched at the first stage that reaches 0
    training error (exactly the legacy early-break); instances that never
    separate keep the last stage's iterate.  Label-0 rows are inert: they
    contribute no hinge violations and the gradient normalizes by the
    per-instance *valid* row count.

    **Warm entry** (``w0``/``b0`` given, e.g. the previous MAXMARG turn's
    separator): before the anneal, a short *polish* stage runs ``warm_steps``
    Pegasos steps from (w0, b0) at the stage-0 λ — the λ whose optimum the
    first-0-error latch keys to whenever separation is easy, so polish and
    cold approximate the same fixed point — with the eta schedule
    offset by ``warm_offset`` so the early steps refine instead of
    re-initializing.  A polished instance that reaches 0 training error is
    latched through the existing first-0-error latch — for it, every
    annealing stage is skipped (the stage loop exits immediately once all
    instances latch).  Instances whose polish does not separate fall through
    to the cold anneal from zeros, bit-identically to the cold entry.  With
    ``w0=None`` the computation is exactly the cold path.  Warm vs cold can
    differ at the float level (two approximations of the same
    transcript-determined hard-margin optimum), never at the decision level
    on the tested grids — enforced by tests/test_maxmarg_warm.py.

    Returns ``(w, b, converged)`` with shapes (B, d), (B,), (B,) — already
    canonicalized to functional margin 1 at the support points (a positive
    rescale, so every margin-order/sign decision downstream is unaffected by
    whether canonicalization happened).  ``return_gate=True`` (static)
    additionally returns the polish gate bits — the carried separator
    classified the fit set cleanly (all-False on the cold entry) — so
    callers instrumenting latch behaviour read the solver's own gate
    instead of recomputing the margin scan.

    ``kernel`` (static) selects the solver's inner loop: ``True`` routes
    every λ stage through ``kernels.ops.pegasos_stage`` — the tiled Pallas
    kernel on TPU, its dot-contraction jnp twin elsewhere — with the
    first-0-error latch fused into the stage launch; ``False`` keeps the
    classic d-unrolled vmapped-XLA loop below, bit-identical to before
    this flag existed.  ``None`` (default) resolves to the backend: Pallas
    kernels are TPU-default, so the kernel path is chosen exactly when
    running on TPU.  The two paths are two float approximations of the
    same transcript-determined optimum — decision-level agreement on the
    tested grids is enforced by the kernel-parity gates, not bit equality
    (same contract as warm vs cold).

    Compile-key contract: this function is jitted with static
    ``steps``/``stages``/``warm_steps``/``warm_offset``/``return_gate``/
    ``kernel`` — plus, implicitly, the (B, N, d) shapes of ``X``/``y``
    and whether ``w0``/``warm_ok`` are present.  Everything else
    (data, λ, warm iterates) is traced.  Engine callers pin B and N via
    their own padding/quantization so repeated refits hit one cache
    entry; calling this directly with ragged batch shapes recompiles
    per shape.
    """
    B, N, d = X.shape
    valid = y != 0.0
    nv = jnp.maximum(jnp.sum(valid, axis=1), 1).astype(X.dtype)  # (B,)
    use_kernel = _kernel_ops._on_tpu() if kernel is None else bool(kernel)

    # the d-contractions are spelled as broadcast multiply-adds: XLA:CPU
    # lowers the K=d (=2..10) dot through a generic GEMM path that is ~5×
    # slower than the fused elementwise form, and these two run `steps`
    # times per stage (cf. the same note on engine/median._proj_grid)
    def decide(w, b):
        return sum(X[:, :, i] * w[:, None, i] for i in range(d)) + b[:, None]

    def margins_min(w, b):
        m = y * decide(w, b)
        return jnp.min(jnp.where(valid, m, jnp.inf), axis=1)

    def pegasos_stage(w, b, lam, nsteps, t0=0.0):
        def body(i, carry):
            w, b = carry
            eta = 1.0 / (lam * (i + 2.0 + t0))                  # (B,)
            m = y * decide(w, b)
            viol = ((m < 1.0) & valid).astype(X.dtype)          # (B, N)
            vy = viol * y
            gsum = jnp.stack([jnp.sum(vy * X[:, :, i], axis=1)
                              for i in range(d)], axis=1)       # (B, d)
            gw = lam[:, None] * w - gsum / nv[:, None]
            gb = -jnp.sum(vy, axis=1) / nv
            w = w - eta[:, None] * gw
            b = b - eta * gb
            nrm = jnp.sqrt(jnp.sum(w * w, axis=1))
            scale = jnp.minimum(1.0, (1.0 / jnp.sqrt(lam)) / (nrm + 1e-12))
            return w * scale[:, None], b * scale

        # unroll=2 shaves the XLA:CPU loop-machinery overhead off the hot
        # 2000-iteration dispatch; the op sequence (and so every float
        # result) is bit-identical to the rolled loop
        return jax.lax.fori_loop(0, nsteps, body, (w, b), unroll=2)

    zeros_w = jnp.zeros((B, d), X.dtype)
    zeros_b = jnp.zeros((B,), X.dtype)
    if w0 is not None:
        # polish: refine the carried separator at the *stage-0* λ — the
        # stage the first-0-error latch keys to whenever separation is easy,
        # so polish and cold approximate the same regularized optimum.  The
        # latch is gated on the *carried* separator already classifying the
        # fit set cleanly: only then is the refit optimum a small
        # perturbation the short polish reliably tracks — an init with
        # training errors falls through to the cold anneal instead (a
        # half-converged cold iterate's decisions are not reproducible from
        # a different starting point).
        ok0 = margins_min(w0.astype(X.dtype), b0.astype(X.dtype)) > 0.0
        if warm_ok is not None:
            ok0 = ok0 & warm_ok
        gate = ok0
        lam_p = jnp.full((B,), lam0, X.dtype)
        if use_kernel:
            # polish runs un-latched (found=False in): the gate below is
            # the composition ok0 & (polished margin > 0), not the
            # kernel's own latch — same formula as the classic branch
            w_p, b_p, mm_p, _f, _wb, _bb = _kernel_ops.pegasos_stage(
                X, y, nv, w0.astype(X.dtype), b0.astype(X.dtype), lam_p,
                jnp.zeros((B,), bool), zeros_w, zeros_b,
                nsteps=warm_steps, t0=float(warm_offset))
            ok_p = ok0 & (mm_p > 0.0)
        else:
            w_p, b_p = pegasos_stage(w0.astype(X.dtype), b0.astype(X.dtype),
                                     lam_p, warm_steps,
                                     jnp.float32(warm_offset))
            ok_p = ok0 & (margins_min(w_p, b_p) > 0.0)
        found0 = ok_p
        w_best0 = jnp.where(ok_p[:, None], w_p, zeros_w)
        b_best0 = jnp.where(ok_p, b_p, zeros_b)
    else:
        found0 = jnp.zeros((B,), bool)
        gate = jnp.zeros((B,), bool)
        w_best0, b_best0 = zeros_w, zeros_b

    def stage_cond(carry):
        s, _w, _b, _wb, _bb, found = carry
        # once every instance separates, later stages can only touch latched
        # slots — exit early (identical results, none of the arithmetic)
        return (s < stages) & ~jnp.all(found)

    def stage(carry):
        s, w, b, w_best, b_best, found = carry
        lam_s = lam0 * 0.1 ** s.astype(X.dtype)
        lam_v = jnp.full((B,), lam_s, X.dtype)
        if use_kernel:
            # whole stage + first-0-error latch in one fused launch
            w, b, _mm, found, w_best, b_best = _kernel_ops.pegasos_stage(
                X, y, nv, w, b, lam_v, found, w_best, b_best, nsteps=steps)
            return (s + 1, w, b, w_best, b_best, found)
        w, b = pegasos_stage(w, b, lam_v, steps)
        ok = margins_min(w, b) > 0.0
        take = ok & ~found
        w_best = jnp.where(take[:, None], w, w_best)
        b_best = jnp.where(take, b, b_best)
        return (s + 1, w, b, w_best, b_best, found | ok)

    _, w, b, w_best, b_best, found = jax.lax.while_loop(
        stage_cond, stage,
        (jnp.zeros((), jnp.int32), zeros_w, zeros_b, w_best0, b_best0,
         found0))
    w = jnp.where(found[:, None], w_best, w)
    b = jnp.where(found, b_best, b)

    # canonicalize: functional margin 1 at the support points
    mmin = margins_min(w, b)
    can = found & jnp.isfinite(mmin) & (mmin > 0.0)
    scale = jnp.where(can, 1.0 / jnp.where(can, mmin, 1.0), 1.0)
    if return_gate:
        return w * scale[:, None], b * scale, found, gate
    return w * scale[:, None], b * scale, found


def anneal_hard_margin(
    X: np.ndarray,
    y: np.ndarray,
    lam: float = 1e-3,
    steps: int = 2000,
    stages: int = 3,
    kernel: Optional[bool] = None,
) -> Tuple[np.ndarray, float, bool]:
    """Single-instance entry to the warm-started annealed solver (B=1).

    Returns ``(w, b, converged)`` in float64/bool host types.  This *is* the
    batched engine's per-turn fit at B=1 — the engine's MAXMARG selector and
    the host API share one solver, so batched-vs-sequential parity is a
    property of the program, not of tolerances.  ``kernel`` follows
    ``_svm_solve_batch``'s solver-path contract (None = TPU-default).
    """
    Xj = jnp.asarray(np.atleast_2d(X), dtype=jnp.float32)[None]
    yj = jnp.asarray(y, dtype=jnp.float32)[None]
    w, b, ok = _svm_solve_batch(Xj, yj, jnp.float32(lam), steps, stages,
                                kernel=kernel)
    return (np.asarray(w[0], dtype=np.float64), float(b[0]), bool(ok[0]))


def fit_max_margin(
    X: np.ndarray,
    y: np.ndarray,
    steps: int = 2000,
    lam: float = 1e-3,
    refine: int = 2,
) -> LinearSeparator:
    """Approximate hard-margin SVM.

    Pegasos with decreasing λ (hard-margin annealing): the paper's protocols
    need a 0-training-error max-margin separator on separable data.  Stages
    run warm-started on device (``_svm_solve_batch`` at B=1, ``refine + 1``
    λ stages) and the first 0-error stage wins; the result is canonicalized
    so that min functional margin = 1.
    """
    w, b, _ = anneal_hard_margin(X, y, lam=lam, steps=steps, stages=refine + 1)
    geo = (y * (X @ w + b)).min() / (np.linalg.norm(w) + 1e-30)
    return LinearSeparator(w, float(b), margin=float(geo))


def support_points(
    clf: LinearSeparator, X: np.ndarray, y: np.ndarray, rtol: float = 0.15, max_support: int = 8
) -> np.ndarray:
    """Indices of active-margin points (functional margin within (1+rtol) of
    the minimum).  These are the points MAXMARG ships each round."""
    m = y * (X @ clf.w + clf.b)
    mmin = max(m.min(), 1e-12)
    idx = np.where(m <= mmin * (1.0 + rtol))[0]
    if len(idx) > max_support:  # keep the tightest ones from each class
        # stable: exact margin ties truncate by ascending index, the same
        # (margin, index) order the batched engine's selection ranks by —
        # an unstable sort here could make host and engine ship different
        # tied points and break the exact-parity gates
        order = np.argsort(m[idx], kind="stable")
        keep = []
        for i in order:
            keep.append(idx[i])
            if len(keep) >= max_support:
                break
        idx = np.asarray(sorted(keep))
    return idx
