"""Hypothesis classes from the paper.

Thresholds (R^1), intervals (R^1), axis-aligned rectangles (R^d), and linear
separators (R^d).  Each provides ``fit`` (0-error learner under the noiseless
assumption), ``predict`` and ``error``.  The linear-separator max-margin
solver is a jit'd JAX routine (Pegasos-style projected subgradient on the
hard-margin objective with margin renormalization); support points are the
active-margin points — exactly what the protocols ship.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Thresholds (predict +1 iff x < t)  — paper Lemma 3.1
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Threshold:
    t: float

    def predict(self, X: np.ndarray) -> np.ndarray:
        x = np.asarray(X).reshape(-1)
        return np.where(x < self.t, 1, -1)

    def error(self, X: np.ndarray, y: np.ndarray) -> float:
        return float(np.mean(self.predict(X) != y)) if len(y) else 0.0

    @staticmethod
    def fit(X: np.ndarray, y: np.ndarray) -> "Threshold":
        """Any 0-error threshold on (X, y); assumes separability."""
        x = np.asarray(X).reshape(-1)
        pos = x[y == 1]
        neg = x[y == -1]
        lo = pos.max() if len(pos) else -np.inf  # t must exceed all positives
        hi = neg.min() if len(neg) else np.inf   # and be below all negatives
        if not lo < hi:
            raise ValueError("not separable by a threshold")
        if np.isinf(lo) and np.isinf(hi):
            t = 0.0
        elif np.isinf(lo):
            t = hi - 1.0
        elif np.isinf(hi):
            t = lo + 1.0
        else:
            t = 0.5 * (lo + hi)
        return Threshold(float(t))


# ---------------------------------------------------------------------------
# Intervals (predict +1 iff a <= x <= b) — paper Lemma 3.2
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Interval:
    a: float
    b: float

    def predict(self, X: np.ndarray) -> np.ndarray:
        x = np.asarray(X).reshape(-1)
        return np.where((x >= self.a) & (x <= self.b), 1, -1)

    def error(self, X: np.ndarray, y: np.ndarray) -> float:
        return float(np.mean(self.predict(X) != y)) if len(y) else 0.0

    @staticmethod
    def fit(X: np.ndarray, y: np.ndarray) -> "Interval":
        """Minimal enclosing interval of the positives (paper's choice: 'as
        small as possible'); assumes noiseless separability."""
        x = np.asarray(X).reshape(-1)
        pos = x[y == 1]
        if len(pos) == 0:
            return Interval(0.0, -1.0)  # empty interval
        a, b = float(pos.min()), float(pos.max())
        neg = x[y == -1]
        if len(neg) and np.any((neg >= a) & (neg <= b)):
            raise ValueError("not separable by an interval")
        return Interval(a, b)


# ---------------------------------------------------------------------------
# Axis-aligned rectangles in R^d — paper Theorem 3.2
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class AxisAlignedRectangle:
    lo: np.ndarray  # (d,)
    hi: np.ndarray  # (d,)
    positive_inside: bool = True

    def predict(self, X: np.ndarray) -> np.ndarray:
        X = np.atleast_2d(X)
        inside = np.all((X >= self.lo) & (X <= self.hi), axis=1)
        lab = np.where(inside, 1, -1)
        return lab if self.positive_inside else -lab

    def error(self, X: np.ndarray, y: np.ndarray) -> float:
        return float(np.mean(self.predict(X) != y)) if len(y) else 0.0

    @staticmethod
    def minimal(X: np.ndarray) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """Minimum enclosing rectangle (the 2d values A ships, Thm 3.2);
        None plays the paper's ∅ sentinel."""
        X = np.atleast_2d(X)
        if X.shape[0] == 0:
            return None
        return X.min(axis=0), X.max(axis=0)

    @staticmethod
    def merge(
        r1: Optional[Tuple[np.ndarray, np.ndarray]],
        r2: Optional[Tuple[np.ndarray, np.ndarray]],
    ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """Coordinate-wise merge: R^+_{A∪B} from R^+_A and R^+_B."""
        if r1 is None:
            return r2
        if r2 is None:
            return r1
        return np.minimum(r1[0], r2[0]), np.maximum(r1[1], r2[1])

    @staticmethod
    def from_bounds(
        rect: Tuple[np.ndarray, np.ndarray], positive_inside: bool = True
    ) -> "AxisAlignedRectangle":
        return AxisAlignedRectangle(np.asarray(rect[0]), np.asarray(rect[1]), positive_inside)


# ---------------------------------------------------------------------------
# Linear separators — jit'd max-margin solver
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LinearSeparator:
    w: np.ndarray  # (d,)
    b: float
    margin: float = 0.0  # geometric margin on the fit set

    def predict(self, X: np.ndarray) -> np.ndarray:
        return np.where(np.atleast_2d(X) @ self.w + self.b > 0, 1, -1)

    def decision(self, X: np.ndarray) -> np.ndarray:
        return np.atleast_2d(X) @ self.w + self.b

    def error(self, X: np.ndarray, y: np.ndarray) -> float:
        return float(np.mean(self.predict(X) != y)) if len(y) else 0.0


@functools.partial(jax.jit, static_argnames=("steps",))
def _svm_solve(X: jnp.ndarray, y: jnp.ndarray, lam: jnp.ndarray, steps: int = 2000):
    """Pegasos projected subgradient on  λ/2 ||w||² + mean hinge(w·x+b)."""
    n, d = X.shape

    def body(i, carry):
        w, b = carry
        eta = 1.0 / (lam * (i + 2.0))
        m = y * (X @ w + b)
        viol = (m < 1.0).astype(X.dtype)
        gw = lam * w - (viol * y) @ X / n
        gb = -jnp.sum(viol * y) / n
        w = w - eta * gw
        b = b - eta * gb
        # pegasos projection onto ball of radius 1/sqrt(lam)
        nrm = jnp.linalg.norm(w)
        scale = jnp.minimum(1.0, (1.0 / jnp.sqrt(lam)) / (nrm + 1e-12))
        return w * scale, b * scale

    w0 = jnp.zeros((d,), X.dtype)
    b0 = jnp.zeros((), X.dtype)
    w, b = jax.lax.fori_loop(0, steps, body, (w0, b0))
    return w, b


def fit_max_margin(
    X: np.ndarray,
    y: np.ndarray,
    steps: int = 4000,
    lam: float = 1e-3,
    refine: int = 2,
) -> LinearSeparator:
    """Approximate hard-margin SVM.

    Pegasos with decreasing λ (hard-margin annealing): the paper's protocols
    need a 0-training-error max-margin separator on separable data.  We solve
    at successively smaller λ until 0 error, then renormalize so that
    min margin = 1 (canonical form).
    """
    Xj = jnp.asarray(X, dtype=jnp.float32)
    yj = jnp.asarray(y, dtype=jnp.float32)
    best = None
    cur_lam = lam
    for _ in range(refine + 1):
        w, b = _svm_solve(Xj, yj, jnp.float32(cur_lam), steps)
        m = np.asarray(yj * (Xj @ w + b))
        best = (np.asarray(w, dtype=np.float64), float(b))
        if m.min() > 0:
            break
        cur_lam /= 10.0
    w, b = best
    margins = y * (X @ w + b)
    mmin = margins.min()
    if mmin > 0:  # canonicalize: functional margin 1 at the support points
        w = w / mmin
        b = b / mmin
    geo = (y * (X @ w + b)).min() / (np.linalg.norm(w) + 1e-30)
    return LinearSeparator(w, float(b), margin=float(geo))


def support_points(
    clf: LinearSeparator, X: np.ndarray, y: np.ndarray, rtol: float = 0.15, max_support: int = 8
) -> np.ndarray:
    """Indices of active-margin points (functional margin within (1+rtol) of
    the minimum).  These are the points MAXMARG ships each round."""
    m = y * (X @ clf.w + clf.b)
    mmin = max(m.min(), 1e-12)
    idx = np.where(m <= mmin * (1.0 + rtol))[0]
    if len(idx) > max_support:  # keep the tightest ones from each class
        order = np.argsort(m[idx])
        keep = []
        for i in order:
            keep.append(idx[i])
            if len(keep) >= max_support:
                break
        idx = np.asarray(sorted(keep))
    return idx
