"""Synthetic datasets reproducing the paper's experimental setup (§7).

Data1/Data2/Data3 follow Figure 3/4 qualitatively: 500 points per node
(250 positive / 250 negative), noiseless (a perfect linear separator exists
on the union), with partitions ranging from benign (Data1: iid split) to
adversarial (Data3: each node's local max-margin classifier badly misleads
voting — the paper's 50%-accuracy voting failure case).

Also provides: threshold/interval/rectangle instances, the d-dimensional
extension used for Table 3, and the Appendix-A indexing construction for the
one-way Ω(1/ε) lower bound.
"""

from __future__ import annotations

import math
from typing import List, Tuple

import numpy as np

Shard = Tuple[np.ndarray, np.ndarray]


def _blob(rng, center, n, scale=0.25):
    return rng.normal(0.0, scale, size=(n, len(center))) + np.asarray(center)


def _box(rng, lo, hi, n):
    lo = np.asarray(lo, dtype=np.float64)
    hi = np.asarray(hi, dtype=np.float64)
    return rng.uniform(lo, hi, size=(n, len(lo)))


def data1(n_per_node: int = 500, k: int = 2, seed: int = 0) -> List[Shard]:
    """Easy: iid split of two well-separated blobs (global separator x=0)."""
    rng = np.random.default_rng(seed)
    shards = []
    half = n_per_node // 2
    for _ in range(k):
        Xp = _blob(rng, (-1.5, 0.0), half)
        Xn = _blob(rng, (+1.5, 0.0), half)
        X = np.concatenate([Xp, Xn])
        y = np.concatenate([np.ones(half), -np.ones(half)]).astype(np.int32)
        shards.append((X, y))
    return shards


def data2(n_per_node: int = 500, k: int = 2, seed: int = 1) -> List[Shard]:
    """Adversarial-by-region: nodes occupy disjoint y-bands of one globally
    separable set (separator x=0); local classifiers still roughly agree."""
    rng = np.random.default_rng(seed)
    shards = []
    half = n_per_node // 2
    for i in range(k):
        y0 = -2.0 + 4.0 * i / max(k - 1, 1)
        Xp = _box(rng, (-2.5, y0 - 0.4), (-0.5, y0 + 0.4), half)
        Xn = _box(rng, (0.5, y0 - 0.4), (2.5, y0 + 0.4), half)
        X = np.concatenate([Xp, Xn])
        y = np.concatenate([np.ones(half), -np.ones(half)]).astype(np.int32)
        shards.append((X, y))
    return shards


def data3(n_per_node: int = 500, k: int = 2, seed: int = 2) -> List[Shard]:
    """The voting-killer (paper Data3, Table 2: VOTING = 50%).

    Global separator is the slanted line y = x/2 (positives above).  Node i
    sits in a narrow x-column, so its *local* max-margin separator is nearly
    horizontal at its own column's height — each local classifier is ~50%
    wrong on the other nodes' points, and majority voting collapses.
    """
    rng = np.random.default_rng(seed)
    shards = []
    half = n_per_node // 2
    xs = np.linspace(-2.5, 2.5, k)
    for i in range(k):
        cx = xs[i]
        ly = cx / 2.0  # global line height at this column
        Xp = _box(rng, (cx - 0.3, ly + 0.5), (cx + 0.3, ly + 1.0), half)
        Xn = _box(rng, (cx - 0.3, ly - 1.0), (cx + 0.3, ly - 0.5), half)
        X = np.concatenate([Xp, Xn])
        y = np.concatenate([np.ones(half), -np.ones(half)]).astype(np.int32)
        shards.append((X, y))
    return shards


def data_mixed_hardness(n_per_node: int = 100, k: int = 4, seed: int = 0,
                        gap: float = 0.15, n_hard: int = 2) -> List[Shard]:
    """k-party partition with heterogeneous hardness: ``n_hard`` nodes hold
    tight near-margin bands around the slanted separator y = x/2 in their
    own x-columns (driving a multi-epoch MAXMARG support exchange), the
    rest hold far easy blobs.  The regime the per-node warm carries target:
    an easy node verifies a mid-epoch proposal clean, adopts it, and its
    next refit latches through the warm polish."""
    rng = np.random.default_rng(seed)
    half = n_per_node // 2
    xs = np.linspace(-2.0, 2.0, k)
    shards = []
    for i in range(k):
        cx, ly = xs[i], xs[i] / 2.0
        if i < n_hard:
            Xp = rng.uniform((cx - 0.3, ly + gap), (cx + 0.3, ly + 2.5 * gap),
                             size=(half, 2))
            Xn = rng.uniform((cx - 0.3, ly - 2.5 * gap), (cx + 0.3, ly - gap),
                             size=(half, 2))
        else:
            Xp = rng.uniform((cx - 0.3, ly + 1.2), (cx + 0.3, ly + 2.0),
                             size=(half, 2))
            Xn = rng.uniform((cx - 0.3, ly - 2.0), (cx + 0.3, ly - 1.2),
                             size=(half, 2))
        X = np.concatenate([Xp, Xn])
        y = np.concatenate([np.ones(half), -np.ones(half)]).astype(np.int32)
        shards.append((X, y))
    return shards


def data_highd(n_per_node: int = 200, k: int = 2, d: int = 16, seed: int = 0,
               margin: float = 0.2, scale: float = 1.0) -> List[Shard]:
    """Separable Gaussians in R^d with a controllable geometric margin —
    the d ≫ 2 regime the tiled Pegasos solver targets (d ∈ {16, 64, 256}
    in the kernel bench; any d ≥ 2 works).

    Points are iid N(0, scale²·I) projected out of a ``margin``-wide slab
    around a random unit separator w*: each point is shifted along ±w* so
    its distance to the hyperplane is at least ``margin`` on its own side.
    Labels are the side of w*.  The margin is *geometric* (units of the
    feature space), so ``margin → 0`` produces near-degenerate instances
    whose support set is decided at float precision — the knob the
    warm-latch adversarial tests turn.  Shards split round-robin so every
    node sees both classes."""
    if d < 2:
        raise ValueError("data_highd needs d >= 2")
    rng = np.random.default_rng(seed)
    wstar = rng.standard_normal(d)
    wstar /= np.linalg.norm(wstar)
    n = n_per_node * k
    X = rng.normal(0.0, scale, size=(n, d))
    proj = X @ wstar
    y = np.where(proj >= 0.0, 1, -1).astype(np.int32)
    # push each point out of the slab: along-w* distance becomes
    # sign(proj)·(margin + |proj|) ≥ margin, leaving the orthogonal
    # complement untouched (labels unchanged by construction)
    X = X + np.outer(y * margin, wstar)
    return [(X[i::k], y[i::k]) for i in range(k)]


def lift_dim(shards: List[Shard], d: int, seed: int = 7, noise: float = 0.05) -> List[Shard]:
    """Embed 2-D shards into R^d (Table 3's high-dimensional variant): the
    informative structure stays in the first two coordinates, the remaining
    d-2 are small iid noise, so the union stays linearly separable."""
    rng = np.random.default_rng(seed)
    out = []
    for X, y in shards:
        pad = rng.normal(0.0, noise, size=(X.shape[0], d - 2))
        out.append((np.concatenate([X, pad], axis=1), y))
    return out


# ---------------------------------------------------------------------------
# Simple geometric hypothesis classes
# ---------------------------------------------------------------------------

def threshold_instance(n: int = 400, k: int = 2, t: float = 0.37, seed: int = 3) -> List[Shard]:
    """1-D data labeled +1 iff x < t; arbitrary (sorted-adversarial) split."""
    rng = np.random.default_rng(seed)
    x = rng.uniform(-1, 1, size=(n,))
    y = np.where(x < t, 1, -1).astype(np.int32)
    order = np.argsort(x)  # adversarial: node 0 gets the left chunk, etc.
    chunks = np.array_split(order, k)
    return [(x[c].reshape(-1, 1), y[c]) for c in chunks]


def interval_instance(n: int = 400, k: int = 2, a: float = -0.4, b: float = 0.5, seed: int = 4) -> List[Shard]:
    rng = np.random.default_rng(seed)
    x = rng.uniform(-1, 1, size=(n,))
    y = np.where((x >= a) & (x <= b), 1, -1).astype(np.int32)
    idx = rng.permutation(n)
    chunks = np.array_split(idx, k)
    return [(x[c].reshape(-1, 1), y[c]) for c in chunks]


def rectangle_instance(n: int = 600, k: int = 2, d: int = 3, seed: int = 5) -> List[Shard]:
    """Points in [-1,1]^d labeled +1 iff inside a random rectangle."""
    rng = np.random.default_rng(seed)
    lo = rng.uniform(-0.6, -0.1, size=(d,))
    hi = rng.uniform(0.1, 0.6, size=(d,))
    X = rng.uniform(-1, 1, size=(n, d))
    inside = np.all((X >= lo) & (X <= hi), axis=1)
    # ensure both classes present
    y = np.where(inside, 1, -1).astype(np.int32)
    idx = rng.permutation(n)
    chunks = np.array_split(idx, k)
    return [(X[c], y[c]) for c in chunks]


# ---------------------------------------------------------------------------
# Appendix A: indexing construction for the one-way Ω(1/ε) lower bound
# ---------------------------------------------------------------------------

def indexing_instance(eps: float, seed: int = 6, radius: float = 10.0) -> Tuple[Shard, Shard, np.ndarray]:
    """A holds 1/(2ε) near-circle negative point *pairs* (each pair in one of
    two configurations = one index bit); B holds a single positive point b+
    aimed at a random pair.  Returns (shard_A, shard_B, bits).

    Any ε-error classifier must effectively know the bit of the targeted
    pair, so any one-way protocol that succeeds on all instances carries
    Ω(1/ε) bits (paper Thm A.1).
    """
    rng = np.random.default_rng(seed)
    n_pairs = max(2, int(round(1.0 / (2 * eps))))
    bits = rng.integers(0, 2, size=(n_pairs,))
    thetas = 2 * np.pi * (np.arange(n_pairs) + 0.25) / n_pairs
    delta_t = (2 * np.pi / n_pairs) * 0.12  # angular gap inside a pair
    dr = 0.02 * radius                      # radial in/out perturbation
    pts = []
    for j, th in enumerate(thetas):
        # left point at th - delta, right at th + delta (clockwise order)
        for side, sign in (("L", -1.0), ("R", +1.0)):
            ang = th + sign * delta_t
            inside = (bits[j] == 0) == (side == "L")  # case1: L in, R out
            r = radius - dr if inside else radius + dr
            pts.append((r * math.cos(ang), r * math.sin(ang)))
    XA = np.asarray(pts)
    yA = -np.ones(len(pts), dtype=np.int32)
    tgt = int(rng.integers(0, n_pairs))
    th = thetas[tgt]
    bp = np.asarray([[(radius - 2.2 * dr) * math.cos(th), (radius - 2.2 * dr) * math.sin(th)]])
    yB = np.ones(1, dtype=np.int32)
    return (XA, yA), (bp, yB), bits


def add_label_noise(shards: List[Shard], rate: float, seed: int = 11) -> List[Shard]:
    """Flip a ``rate`` fraction of labels per shard (paper §8.2 noisy setting)."""
    rng = np.random.default_rng(seed)
    out = []
    for X, y in shards:
        y2 = y.copy()
        n_flip = int(round(rate * len(y)))
        idx = rng.choice(len(y), size=n_flip, replace=False)
        y2[idx] = -y2[idx]
        out.append((X, y2))
    return out
