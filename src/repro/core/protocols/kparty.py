"""k-party two-way protocol for halfspaces in R^2 (paper §6.2, Thm 6.3).

Epoch structure: each player takes one turn as *coordinator*.  On its turn
the coordinator broadcasts a proposed direction v (the weighted-median
direction of its set of uncertainty) together with its own support band; each
non-coordinator replies with its extreme band points along v — its largest
positive and smallest negative projections over (own ∪ transcript).  Two
certified outcomes follow (noiseless setting):

* the global band  (max_i v·p_i , min_i v·q_i)  is NON-EMPTY: any threshold
  inside it classifies every node's points perfectly (each p_i/q_i is that
  node's own extreme), so the coordinator terminates with a 0-error
  classifier;
* the band is EMPTY: the extreme pair (p*, q*) — a positive from one node
  projecting above a negative from another, exactly the paper's rightmost
  Figure-2 case — certifies that every transcript-consistent direction v'
  must satisfy v'·(q* − p*) > 0.  All players intersect their direction arc
  with that half-circle (the pivoting rule of §5.2), which always discards
  the current v and, because v is the SOU-median, about half the uncertain
  mass.

An ε-early-exit check (1 scalar per node) runs each turn so the protocol can
also stop at ε-error before exact separation, per §4.3.  Communication per
turn is O(k) points; an epoch of k turns is O(k²) — Thm 6.3.

Both selectors' data planes live in :mod:`repro.engine`: one turn is a pure
jitted ``step(state) -> state`` advanced under ``lax.while_loop``, batched
over independent instances — MEDIAN as the certified-pivot direction search,
MAXMARG as a per-turn batched hard-margin refit
(:mod:`repro.engine.maxmarg`).  This module is the thin single-instance
entry point (an engine sweep with B=1).  The retired host round loops
survive as differential oracles under ``benchmarks/``.
"""

from __future__ import annotations

from repro.core.protocols.one_way import ProtocolResult


def iterative_support_kparty(
    shards,
    eps: float = 0.05,
    max_epochs: int = 48,
    n_angles: int = 1024,
    selector: str = "median",
    max_support: int = 4,
) -> ProtocolResult:
    from repro import engine

    d = shards[0][0].shape[1]
    if selector == "maxmarg" or d != 2:
        # MAXMARG works in any dimension; MEDIAN is specified for R^2
        # (paper §8.2), so d≠2 routes to the MAXMARG selector too.
        return engine.maxmarg.run_instances(
            [engine.ProtocolInstance(shards, eps, "maxmarg")],
            max_epochs=max_epochs, max_support=max_support)[0]

    return engine.run_instances(
        [engine.ProtocolInstance(shards, eps)],
        n_angles=n_angles, max_epochs=max_epochs)[0]
