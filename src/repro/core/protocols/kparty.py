"""k-party two-way protocol for halfspaces in R^2 (paper §6.2, Thm 6.3).

Epoch structure: each player takes one turn as *coordinator*.  On its turn
the coordinator broadcasts a proposed direction v (the weighted-median
direction of its set of uncertainty) together with its own support band; each
non-coordinator replies with its extreme band points along v — its largest
positive and smallest negative projections over (own ∪ transcript).  Two
certified outcomes follow (noiseless setting):

* the global band  (max_i v·p_i , min_i v·q_i)  is NON-EMPTY: any threshold
  inside it classifies every node's points perfectly (each p_i/q_i is that
  node's own extreme), so the coordinator terminates with a 0-error
  classifier;
* the band is EMPTY: the extreme pair (p*, q*) — a positive from one node
  projecting above a negative from another, exactly the paper's rightmost
  Figure-2 case — certifies that every transcript-consistent direction v'
  must satisfy v'·(q* − p*) > 0.  All players intersect their direction arc
  with that half-circle (the pivoting rule of §5.2), which always discards
  the current v and, because v is the SOU-median, about half the uncertain
  mass.

An ε-early-exit check (1 scalar per node) runs each turn so the protocol can
also stop at ε-error before exact separation, per §4.3.  Communication per
turn is O(k) points; an epoch of k turns is O(k²) — Thm 6.3.
"""

from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np
import jax.numpy as jnp

from repro.core import classifiers as clf
from repro.core import geometry as geo
from repro.core.comm import Node, make_nodes
from repro.core.protocols.one_way import ProtocolResult
from repro.core.protocols.two_way import (
    _pick_median_direction,
    _risk_matrix,
    _support_along,
    _transcript,
)


def _extremes_along(node: Node, v: np.ndarray, Wx, Wy):
    """Node's extreme band points along v over (own ∪ transcript):
    (positive argmax-projection, negative argmin-projection); either may be
    None if that class is absent."""
    X = np.concatenate([node.X, Wx])
    y = np.concatenate([node.y, Wy])
    proj = X @ v
    pos = y == 1
    p = X[int(np.argmax(np.where(pos, proj, -np.inf)))] if pos.any() else None
    q = X[int(np.argmin(np.where(~pos, proj, np.inf)))] if (~pos).any() else None
    return p, q


def iterative_support_kparty(
    shards,
    eps: float = 0.05,
    max_epochs: int = 48,
    n_angles: int = 1024,
    selector: str = "median",
) -> ProtocolResult:
    nodes, log = make_nodes(shards)
    k = len(nodes)
    d = nodes[0].d
    n_total = sum(nd.n for nd in nodes)
    budget = int(np.floor(eps * n_total))

    if selector == "maxmarg" or d != 2:
        return _kparty_maxmarg(nodes, log, budget, max_epochs)

    V = np.asarray(geo.direction_grid(n_angles))
    dir_ok = np.ones(n_angles, dtype=bool)   # shared: transcript is broadcast
    sent = {nd.name: ([], []) for nd in nodes}

    h: Optional[clf.LinearSeparator] = None
    for epoch in range(max_epochs):
        for ci in range(k):
            log.new_round()
            coord = nodes[ci]
            others = [nd for nd in nodes if nd is not coord]

            # --- coordinator: median direction of its SOU + support band ----
            Wx_c, Wy_c = _transcript(coord, *sent[coord.name])
            risk = _risk_matrix(coord, V, dir_ok, Wx_c, Wy_c)
            v_idx = _pick_median_direction(risk, dir_ok)
            v = V[v_idx]
            S_X, S_y, lo_c, hi_c = _support_along(coord, v, Wx_c, Wy_c)
            for nd in others:
                coord.send_points(nd, S_X, S_y, tag="kparty-support")
                coord.send_scalars(nd, np.concatenate([v, [lo_c, hi_c]]),
                                   tag="kparty-direction")
            sent[coord.name][0].extend(list(S_X))
            sent[coord.name][1].extend(list(S_y))

            # --- ε-early-exit: try the coordinator's band midpoint ----------
            if np.isfinite(lo_c) and np.isfinite(hi_c) and lo_c < hi_c:
                cand = clf.LinearSeparator(-v, 0.5 * (lo_c + hi_c))
                err_tot = 0
                for nd in nodes:
                    e = int(round(cand.error(nd.X, nd.y) * nd.n))
                    err_tot += e
                    if nd is not coord:
                        nd.send_scalars(coord, np.asarray([float(e)]),
                                        tag="kparty-err")
                if err_tot <= budget:
                    return ProtocolResult(cand, log.summary(),
                                          rounds=epoch + 1, converged=True)
                h = cand

            # --- replies: extreme band points along v (2 points each) -------
            best_p, best_q = None, None   # global argmax-positive / argmin-neg
            lo_g, hi_g = -np.inf, np.inf
            all_pts: List[Tuple[np.ndarray, int, Node]] = []
            for nd in nodes:
                if nd is coord:
                    Wx_d, Wy_d = Wx_c, Wy_c
                else:
                    Wx_d, Wy_d = _transcript(nd, *sent[nd.name])
                p, q = _extremes_along(nd, v, Wx_d, Wy_d)
                pts, labs = [], []
                if p is not None:
                    if p @ v > lo_g:
                        lo_g, best_p = p @ v, p
                    pts.append(p); labs.append(1)
                if q is not None:
                    if q @ v < hi_g:
                        hi_g, best_q = q @ v, q
                    pts.append(q); labs.append(-1)
                if nd is not coord and pts:
                    nd.send_points(coord, np.stack(pts),
                                   np.asarray(labs, np.int32),
                                   tag="kparty-extremes")
                    sent[nd.name][0].extend(pts)
                    sent[nd.name][1].extend(labs)
                all_pts += [(x, l, nd) for x, l in zip(pts, labs)]

            if lo_g < hi_g:
                # global band non-empty ⇒ 0 error on every node's points
                if not np.isfinite(lo_g):      # no positives at all
                    lo_g = hi_g - 2.0
                if not np.isfinite(hi_g):      # no negatives at all
                    hi_g = lo_g + 2.0
                t_star = 0.5 * (lo_g + hi_g)
                cand = clf.LinearSeparator(-v, t_star)
                for nd in others:
                    nd.send_bit(coord, 1, tag="kparty-accept")
                return ProtocolResult(cand, log.summary(), rounds=epoch + 1,
                                      converged=True)

            # --- empty band: certified pivot prune (paper Fig. 2 right) -----
            # every consistent direction must put q* strictly above p*
            constraint = V @ (best_q - best_p)        # (n_angles,)
            new_ok = dir_ok & (constraint > 1e-12)
            # rebroadcast the violating pair so every player prunes identically
            for nd in others:
                coord.send_points(nd, np.stack([best_p, best_q]),
                                  np.asarray([1, -1], np.int32),
                                  tag="kparty-pivot")
            sent[coord.name][0].extend([best_p, best_q])
            sent[coord.name][1].extend([1, -1])
            if new_ok.any():
                dir_ok = new_ok
            if h is None:
                t_fb = 0.5 * (lo_c + hi_c) if (np.isfinite(lo_c) and
                                               np.isfinite(hi_c)) else 0.0
                h = clf.LinearSeparator(-v, t_fb)
    return ProtocolResult(h, log.summary(), rounds=max_epochs, converged=False)


def _kparty_maxmarg(nodes, log, budget: int, max_epochs: int) -> ProtocolResult:
    """MAXMARG generalized to k players (the paper's §7 k-party variant):
    the epoch coordinator fits on everything it knows, broadcasts support
    points, and the others reply with their own violated support points."""
    k = len(nodes)
    h = None
    for epoch in range(max_epochs):
        for ci in range(k):
            log.new_round()
            coord = nodes[ci]
            X, y = coord.all_known()
            h = clf.fit_max_margin(X, y)
            sidx = clf.support_points(h, X, y, max_support=4)
            errs = []
            for nd in nodes:
                if nd is coord:
                    errs.append(int(h.error(nd.X, nd.y) * nd.n))
                    continue
                coord.send_points(nd, X[sidx], y[sidx], tag="kparty-maxmarg-support")
                e = int(h.error(nd.X, nd.y) * nd.n)
                errs.append(e)
                nd.send_bit(coord, int(e == 0), tag="kparty-maxmarg-ok")
                if e > 0:
                    # reply with the most-violated points
                    m = nd.y * (nd.X @ h.w + h.b)
                    worst = np.argsort(m)[:2]
                    nd.send_points(coord, nd.X[worst], nd.y[worst], tag="kparty-maxmarg-viol")
            if sum(errs) <= budget:
                return ProtocolResult(h, log.summary(), rounds=epoch + 1, converged=True)
    return ProtocolResult(h, log.summary(), rounds=max_epochs, converged=False)
