"""k-party two-way protocol for halfspaces in R^2 (paper §6.2, Thm 6.3).

Epoch structure: each player takes one turn as *coordinator*.  On its turn
the coordinator broadcasts a proposed direction v (the weighted-median
direction of its set of uncertainty) together with its own support band; each
non-coordinator replies with its extreme band points along v — its largest
positive and smallest negative projections over (own ∪ transcript).  Two
certified outcomes follow (noiseless setting):

* the global band  (max_i v·p_i , min_i v·q_i)  is NON-EMPTY: any threshold
  inside it classifies every node's points perfectly (each p_i/q_i is that
  node's own extreme), so the coordinator terminates with a 0-error
  classifier;
* the band is EMPTY: the extreme pair (p*, q*) — a positive from one node
  projecting above a negative from another, exactly the paper's rightmost
  Figure-2 case — certifies that every transcript-consistent direction v'
  must satisfy v'·(q* − p*) > 0.  All players intersect their direction arc
  with that half-circle (the pivoting rule of §5.2), which always discards
  the current v and, because v is the SOU-median, about half the uncertain
  mass.

An ε-early-exit check (1 scalar per node) runs each turn so the protocol can
also stop at ε-error before exact separation, per §4.3.  Communication per
turn is O(k) points; an epoch of k turns is O(k²) — Thm 6.3.

The MEDIAN data plane lives in :mod:`repro.engine`: one turn is a pure
jitted ``step(state) -> state`` advanced under ``lax.while_loop``, batched
over independent instances.  This module is the thin single-instance entry
point (an engine sweep with B=1); the MAXMARG selector (and d≠2) keeps its
host-side loop because it needs per-round SVM refits.
"""

from __future__ import annotations

import numpy as np

from repro.core import classifiers as clf
from repro.core.comm import make_nodes
from repro.core.protocols.one_way import ProtocolResult


def iterative_support_kparty(
    shards,
    eps: float = 0.05,
    max_epochs: int = 48,
    n_angles: int = 1024,
    selector: str = "median",
) -> ProtocolResult:
    d = shards[0][0].shape[1]
    if selector == "maxmarg" or d != 2:
        nodes, log = make_nodes(shards)
        n_total = sum(nd.n for nd in nodes)
        budget = int(np.floor(eps * n_total))
        return _kparty_maxmarg(nodes, log, budget, max_epochs)

    from repro import engine
    return engine.run_instances(
        [engine.ProtocolInstance(shards, eps)],
        n_angles=n_angles, max_epochs=max_epochs)[0]


def _kparty_maxmarg(nodes, log, budget: int, max_epochs: int) -> ProtocolResult:
    """MAXMARG generalized to k players (the paper's §7 k-party variant):
    the epoch coordinator fits on everything it knows, broadcasts support
    points, and the others reply with their own violated support points."""
    k = len(nodes)
    h = None
    for epoch in range(max_epochs):
        for ci in range(k):
            log.new_round()
            coord = nodes[ci]
            X, y = coord.all_known()
            h = clf.fit_max_margin(X, y)
            sidx = clf.support_points(h, X, y, max_support=4)
            errs = []
            for nd in nodes:
                if nd is coord:
                    errs.append(int(h.error(nd.X, nd.y) * nd.n))
                    continue
                coord.send_points(nd, X[sidx], y[sidx], tag="kparty-maxmarg-support")
                e = int(h.error(nd.X, nd.y) * nd.n)
                errs.append(e)
                nd.send_bit(coord, int(e == 0), tag="kparty-maxmarg-ok")
                if e > 0:
                    # reply with the most-violated points
                    m = nd.y * (nd.X @ h.w + h.b)
                    worst = np.argsort(m)[:2]
                    nd.send_points(coord, nd.X[worst], nd.y[worst], tag="kparty-maxmarg-viol")
            if sum(errs) <= budget:
                return ProtocolResult(h, log.summary(), rounds=epoch + 1, converged=True)
    return ProtocolResult(h, log.summary(), rounds=max_epochs, converged=False)
