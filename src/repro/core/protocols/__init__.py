from repro.core.protocols import baselines, kparty, one_way, two_way  # noqa: F401
