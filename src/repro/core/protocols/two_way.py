"""Two-way two-party ITERATIVESUPPORTS (paper §4–5).

Two support-point selectors are provided, exactly as in the paper:

* **MAXMARG** (§4.4): each round a node fits a max-margin separator on
  everything it knows and ships the active-margin support points.  Fast in
  practice, no worst-case guarantee.  Works in any dimension.

* **MEDIAN** (§4.4, Alg. 2 + §5 basic protocol): nodes additionally maintain
  a *direction interval* (v_l, v_r) ⊂ S¹ and a *set of uncertainty* (SOU) —
  the points that some transcript-consistent classifier could still
  misclassify.  Each round the sender picks the direction that splits its SOU
  mass in half (the discretized analogue of the weighted-median hull edge);
  the receiver either terminates early (a consistent classifier along that
  direction has ≤ ε error) or answers with a rotation bit that provably
  discards half the sender's SOU.  O(log 1/ε) rounds.  2-D, per the paper
  (higher-d MEDIAN is listed as an open problem in §8.2).

Implementation note (logged in DESIGN.md): the direction continuum S¹ is
discretized to ``n_angles`` unit vectors; SOU membership and consistent-
threshold ranges are dense jit'd JAX computations over the (angles × points)
grid, replacing exact computational geometry with an MXU-friendly data plane.
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np
import jax.numpy as jnp

from repro.core import classifiers as clf
from repro.core import geometry as geo
from repro.core.comm import Node, make_nodes
from repro.core.protocols.one_way import ProtocolResult


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

def _global_error(h, nodes) -> float:
    n_err = sum(int(h.error(nd.X, nd.y) * nd.n) for nd in nodes)
    n_tot = sum(nd.n for nd in nodes)
    return n_err / n_tot


def _fit_known(node: Node) -> clf.LinearSeparator:
    X, y = node.all_known()
    return clf.fit_max_margin(X, y)


# ---------------------------------------------------------------------------
# MAXMARG
# ---------------------------------------------------------------------------

def iterative_support_maxmarg(
    shards,
    eps: float = 0.05,
    max_rounds: int = 64,
    max_support: int = 4,
) -> ProtocolResult:
    """Paper §4.4 MAXMARG for two parties.

    Two-party MAXMARG is the k=2 instance of the k-party support-exchange
    epoch protocol, which executes on the batched engine
    (:mod:`repro.engine.maxmarg`) with B=1: each turn one party refits
    max-margin on everything it knows, ships its active-margin support
    points, and the peer answers with an all-clear bit or its most-violated
    points.  ``max_rounds`` counts turns and maps to ``max_rounds // 2``
    two-turn epochs (floored, min 1 — same convention as
    ``iterative_support_median``); the result's ``rounds`` field counts
    epochs, ``comm["rounds"]`` counts turns.  The engine's differential
    oracle is the k-party host loop in ``benchmarks/legacy_maxmarg.py``;
    the retired *asymmetric* two-party loop (alternating senders with
    value-level dedup) is kept there too, for reference only — its
    dedup-based comm profile differs from this protocol's by design.
    """
    from repro.core.protocols.kparty import iterative_support_kparty
    return iterative_support_kparty(shards[:2], eps=eps,
                                    max_epochs=max(1, max_rounds // 2),
                                    selector="maxmarg",
                                    max_support=max_support)


# ---------------------------------------------------------------------------
# MEDIAN
# ---------------------------------------------------------------------------

def _transcript(node: Node, sent_X, sent_y):
    X = np.concatenate([node.recv_X] + ([np.stack(sent_X)] if sent_X else []))
    y = np.concatenate([node.recv_y] + ([np.asarray(sent_y, dtype=np.int32)] if sent_y else []))
    if X.size == 0:
        X = np.zeros((0, node.d))
        y = np.zeros((0,), dtype=np.int32)
    return X, y


def _sou(node: Node, V, dir_ok, Wx, Wy) -> np.ndarray:
    """Boolean SOU mask over node's own points (jit'd grid computation)."""
    if Wx.shape[0] == 0:
        return np.ones(node.n, dtype=bool)
    mask = geo.uncertain_mask(
        jnp.asarray(V), jnp.asarray(dir_ok), jnp.asarray(Wx), jnp.asarray(Wy),
        jnp.asarray(node.X), jnp.asarray(node.y))
    return np.asarray(mask)


def _risk_matrix(node: Node, V, dir_ok, Wx, Wy) -> np.ndarray:
    """(m_angles, n_points) at-risk booleans for median splitting."""
    if Wx.shape[0] == 0:
        return np.ones((V.shape[0], node.n), dtype=bool) & dir_ok[:, None]
    lo, hi = geo.consistent_threshold_ranges(jnp.asarray(V), jnp.asarray(Wx), jnp.asarray(Wy))
    lo = np.asarray(lo); hi = np.asarray(hi)
    nonempty = (lo < hi) & dir_ok
    proj = V @ node.X.T
    pos = node.y == 1
    risk = np.where(pos[None, :], proj > lo[:, None], proj < hi[:, None])
    return risk & nonempty[:, None]


def _pick_median_direction(risk: np.ndarray, dir_ok: np.ndarray) -> int:
    """Pick the allowed direction index that best halves the at-risk mass.

    Discretized analogue of Alg. 2's weighted-median hull edge: for every
    candidate cut angle θ, count the points whose entire risk arc lies
    (strictly) on each side; choose θ maximizing the smaller count, so that
    whichever side the receiver's bit discards, ≥ that many points leave the
    SOU.
    """
    idxs = np.where(dir_ok)[0]
    if len(idxs) <= 1:
        return int(idxs[0]) if len(idxs) else 0
    sub = risk[idxs]  # (m_ok, n) — ordered along the allowed arc
    csum = np.cumsum(sub, axis=0)
    total = csum[-1]
    active = total > 0
    # point's arc entirely below cut i  <=>  csum[i] == total (no risk above);
    # entirely above  <=>  csum[i] == 0.  Full vectorized scan over every
    # allowed cut (a strided subsample can miss the true halving cut once
    # more than ~128 directions remain).
    below = np.sum((csum == total[None, :]) & active[None, :], axis=1)
    above = np.sum((csum == 0) & active[None, :], axis=1)
    score = np.minimum(below, above)
    return int(idxs[int(np.argmax(score))])


def _support_along(node: Node, v: np.ndarray, Wx, Wy):
    """Support points of the max-margin 0-error classifier along fixed
    direction v on (own ∪ transcript): the extreme positive and negative
    projections (the band edges) — the constant-size S of paper §5.1(1).

    A missing class (single-class shard, the paper's ∅ case) contributes no
    point and an infinite band edge — it must NOT contribute a mislabeled
    stand-in, or the shared transcript is poisoned."""
    X = np.concatenate([node.X, Wx]); y = np.concatenate([node.y, Wy])
    proj = X @ v
    pos = y == 1
    pts, labs = [], []
    lo, hi = -np.inf, np.inf
    # predict +1 iff v·x < t  =>  band is (max_+ proj, min_- proj)
    if pos.any():
        i_pos = int(np.argmax(np.where(pos, proj, -np.inf)))
        lo = float(proj[i_pos])
        pts.append(X[i_pos]); labs.append(1)
    if (~pos).any():
        i_neg = int(np.argmin(np.where(~pos, proj, np.inf)))
        hi = float(proj[i_neg])
        pts.append(X[i_neg]); labs.append(-1)
    S_X = np.stack(pts) if pts else np.zeros((0, X.shape[1]))
    return S_X, np.asarray(labs, dtype=np.int32), lo, hi


def _best_threshold(node: Node, v: np.ndarray, lo: float, hi: float, Wx, Wy) -> Tuple[float, int]:
    """Receiver's early-termination scan (§4.3): best consistent threshold
    t ∈ (lo', hi') along v, where (lo', hi') also respects the receiver's
    transcript; returns (t, #errors on own shard)."""
    if Wx.shape[0]:
        projW = Wx @ v
        lo = max(lo, float(np.max(np.where(Wy == 1, projW, -np.inf))))
        hi = min(hi, float(np.min(np.where(Wy == -1, projW, np.inf))))
    if not lo < hi:
        return 0.5 * (lo + hi), 10 ** 9
    proj = node.X @ v
    cand = np.unique(np.clip(np.concatenate([proj, [lo + 1e-12, hi - 1e-12]]), lo + 1e-12, hi - 1e-12))
    pred = proj[None, :] < cand[:, None]  # predict +1
    errs = np.sum(pred != (node.y == 1)[None, :], axis=1)
    i = int(np.argmin(errs))
    return float(cand[i]), int(errs[i])


def iterative_support_median(
    shards,
    eps: float = 0.05,
    max_rounds: int = 64,
    n_angles: int = 1024,
) -> ProtocolResult:
    """Paper §5 protocol with the *certified pivot* reply (see DESIGN.md).

    The literal rotation-bit reply assumes the receiver's consistent
    directions all lie on one side of the proposal; with a discretized S¹
    and arbitrary partitions they can straddle it, and a wrong bit discards
    the jointly-consistent arc (hypothesis testing falsified the bit
    variant on random separable instances: tests/test_protocol_properties).
    The certified variant replies with the receiver's extreme band points —
    the paper's own §5.2 pivoting rule — which provably never discards a
    consistent direction.  Two-party is the k=2 instance of the k-party
    epoch protocol, which executes on the batched engine (``repro.engine``)
    with B=1.
    """
    from repro.core.protocols.kparty import iterative_support_kparty
    return iterative_support_kparty(shards[:2], eps=eps,
                                    max_epochs=max_rounds // 2,
                                    n_angles=n_angles, selector="median")


def iterative_support_median_bit(
    shards,
    eps: float = 0.05,
    max_rounds: int = 64,
    n_angles: int = 1024,
) -> ProtocolResult:
    """Paper §5 basic protocol, literal rotation-bit replies (kept for
    comparison; see `iterative_support_median` for why it is not the
    default), symmetric extension (§5.3), discretized S¹."""
    nodes, log = make_nodes(shards[:2])
    A, B = nodes
    assert A.d == 2, "MEDIAN is specified for R^2 (paper §8.2)"
    n_total = A.n + B.n
    budget = int(np.floor(eps * n_total))
    V = np.asarray(geo.direction_grid(n_angles))
    dir_ok = {A.name: np.ones(n_angles, dtype=bool), B.name: np.ones(n_angles, dtype=bool)}
    sent: dict = {A.name: ([], []), B.name: ([], [])}

    h = None
    for rnd in range(max_rounds):
        log.new_round()
        src, dst = (A, B) if rnd % 2 == 0 else (B, A)

        # --- src picks its median direction over its SOU -------------------
        Wx_s, Wy_s = _transcript(src, *sent[src.name])
        risk = _risk_matrix(src, V, dir_ok[src.name], Wx_s, Wy_s)
        v_idx = _pick_median_direction(risk, dir_ok[src.name])
        v = V[v_idx]
        S_X, S_y, lo, hi = _support_along(src, v, Wx_s, Wy_s)
        src.send_points(dst, S_X, S_y, tag="median-support")
        sent[src.name][0].extend(list(S_X)); sent[src.name][1].extend(list(S_y))
        src.send_scalars(dst, np.concatenate([v, [lo, hi]]), tag="median-direction")

        # --- dst: early termination or rotation bit ------------------------
        Wx_d, Wy_d = _transcript(dst, *sent[dst.name])
        t, err_dst = _best_threshold(dst, v, lo, hi, Wx_d, Wy_d)
        cand = clf.LinearSeparator(-v, t)  # predict +1 iff v·x < t
        err_src = int(cand.error(src.X, src.y) * src.n)
        if err_dst + err_src <= budget:
            dst.send_bit(src, 0, tag="terminate")
            dst.send_scalars(src, np.asarray([t]), tag="final-threshold")
            return ProtocolResult(cand, log.summary(), rounds=rnd + 1, converged=True)

        # rotation bit: which side of v do dst's consistent directions lie on?
        Xd = np.concatenate([dst.X, Wx_d]); yd = np.concatenate([dst.y, Wy_d])
        lo_d, hi_d = geo.consistent_threshold_ranges(jnp.asarray(V), jnp.asarray(Xd), jnp.asarray(yd))
        sep = np.asarray(lo_d < hi_d) & dir_ok[dst.name]
        order = np.where(dir_ok[src.name])[0]
        pos_in_arc = np.searchsorted(order, v_idx)
        sep_arc = sep[order]
        left_ok = bool(np.any(sep_arc[:pos_in_arc]))
        bit = +1 if left_ok else -1
        dst.send_bit(src, 1 if bit == 1 else 0, tag="rotate")

        # --- src (and dst, symmetrically) shrink their intervals -----------
        for name in (src.name, dst.name):
            ok = dir_ok[name]
            arc = np.where(ok)[0]
            cut = np.searchsorted(arc, v_idx)
            keep = arc[:cut] if bit == +1 else arc[cut + 1:]
            new_ok = np.zeros_like(ok)
            new_ok[keep] = True
            if new_ok.any():
                dir_ok[name] = new_ok

        h = cand
    return ProtocolResult(h, log.summary(), rounds=max_rounds, converged=False)


# ---------------------------------------------------------------------------
# Noisy setting (paper §8.2 outline, implemented)
# ---------------------------------------------------------------------------

def iterative_support_noisy(
    shards,
    eps: float = 0.05,
    noise_margin: float = 0.1,
    max_rounds: int = 64,
    max_support: int = 6,
) -> ProtocolResult:
    """MAXMARG adapted to noisy data per the paper's §8.2 heuristic: players
    never propose 0-error classifiers — each round's fit tolerates an
    ε-error slack (soft-margin: fixed λ, no hard-margin annealing) and ships
    the support points of the *slack-margin band* rather than the exact
    margin.  Termination accepts any classifier whose measured global error
    is within ε of the best seen so far (the noise floor is unknowable
    without labels, so the budget is relative).
    """
    import numpy as _np
    from repro.core.classifiers import LinearSeparator, _svm_solve
    import jax.numpy as _jnp

    nodes, log = make_nodes(shards[:2])
    A, B = nodes
    n_total = A.n + B.n
    budget = int(_np.floor(eps * n_total))

    def soft_fit(X, y):
        Xj = _jnp.asarray(X, dtype=_jnp.float32)
        yj = _jnp.asarray(y, dtype=_jnp.float32)
        w, b = _svm_solve(Xj, yj, _jnp.float32(1e-2), 3000)  # soft margin
        w = _np.asarray(w, dtype=_np.float64)
        return LinearSeparator(w, float(b))

    best_h, best_err = None, 10 ** 9
    for rnd in range(max_rounds):
        log.new_round()
        src, dst = (A, B) if rnd % 2 == 0 else (B, A)
        Xk, yk = src.all_known()
        h = soft_fit(Xk, yk)
        # ship points inside the slack band (|functional margin| <= 1 + slack)
        m = yk * (Xk @ h.w + h.b)
        scale = max(_np.median(_np.abs(m)), 1e-9)
        band = _np.where(_np.abs(m) / scale <= 1.0 + noise_margin)[0]
        order = band[_np.argsort(_np.abs(m[band]))][:max_support]
        if len(order):
            src.send_points(dst, Xk[order], yk[order], tag="noisy-support")
        err = int(h.error(src.X, src.y) * src.n) + int(h.error(dst.X, dst.y) * dst.n)
        if err < best_err:
            best_err, best_h = err, h
        dst.send_bit(src, int(err <= best_err + budget), tag="noisy-accept")
        if rnd >= 3 and err <= best_err + budget and err <= 2 * budget + best_err:
            return ProtocolResult(best_h, log.summary(), rounds=rnd + 1,
                                  converged=True, extra={"best_err": best_err})
    return ProtocolResult(best_h, log.summary(), rounds=max_rounds,
                          converged=False, extra={"best_err": best_err})
