"""Baselines from the paper's experiments (§7) plus parameter-mixing (§8.1).

* NAIVE   — ship every point to the last node, learn centrally.
* VOTING  — each node learns locally; predictions are majority-voted with
            confidence tie-break (paper's (b)).
* RANDOM  — one-way ε-net sample (paper's (c); == protocols.one_way.random_sampling
            with the paper's (d/ε)log(d/ε) size).
* MIXING  — parameter averaging of local linear classifiers (McDonald et al.,
            Mann et al.; the paper's §8.1 comparison point).

With the default max-margin learner every baseline is the batched engine's
one-way path at B=1 (:mod:`repro.engine.oneway`): the per-node/terminal fits
run as one batched annealed-Pegasos dispatch and communication is metered in
``BatchCommLog`` at exactly these host message slots (the retired host loops
survive as differential oracles in ``benchmarks/legacy_oneway.py``).  A
custom ``fit`` callable runs the metered host loops kept below.  Every
baseline meters its single one-way round (``log.new_round()``), so
``comm["rounds"]`` always equals ``ProtocolResult.rounds``.
"""

from __future__ import annotations

from typing import Callable, List, Optional

import numpy as np

from repro.core import classifiers as clf
from repro.core.comm import make_nodes
from repro.core.protocols.one_way import ProtocolResult, random_sampling


def naive(shards, fit: Optional[Callable] = None) -> ProtocolResult:
    if fit is None:
        from repro import engine
        return engine.oneway.run_instances(
            [engine.ProtocolInstance(shards, selector="naive")])[0]
    nodes, log = make_nodes(shards)
    log.new_round()
    last = nodes[-1]
    for nd in nodes[:-1]:
        nd.send_points(last, nd.X, nd.y, tag="naive-all")
    X, y = last.all_known()
    h = fit(X, y)
    return ProtocolResult(h, log.summary(), rounds=1, converged=True)


class _VotingClassifier:
    def __init__(self, parts: List[clf.LinearSeparator]):
        self.parts = parts

    def decision(self, X):
        return np.stack([h.decision(X) for h in self.parts], axis=0)

    def predict(self, X):
        dec = self.decision(X)
        votes = np.sign(dec)
        s = votes.sum(axis=0)
        # confidence tie-break: label whose prediction has higher |margin|
        conf = dec[np.argmax(np.abs(dec), axis=0), np.arange(dec.shape[1])]
        out = np.where(s != 0, np.sign(s), np.sign(conf))
        return np.where(out == 0, 1, out).astype(np.int32)

    def error(self, X, y):
        return float(np.mean(self.predict(np.atleast_2d(X)) != y)) if len(y) else 0.0


def voting(shards, fit: Optional[Callable] = None) -> ProtocolResult:
    """Local classifiers + majority vote.  Communication: every node ships its
    points' predictions?  No — the paper charges VOTING the full dataset cost
    (Tables 2-4 list Cost = all points), since evaluating the vote on D
    requires the data (or equivalently shipping every local classifier to
    every datum).  We meter it the same way."""
    if fit is None:
        from repro import engine
        return engine.oneway.run_instances(
            [engine.ProtocolInstance(shards, selector="voting")])[0]
    nodes, log = make_nodes(shards)
    log.new_round()
    parts = [fit(nd.X, nd.y) for nd in nodes]
    last = nodes[-1]
    for nd in nodes[:-1]:
        nd.send_points(last, nd.X, nd.y, tag="voting-eval")
    h = _VotingClassifier(parts)
    return ProtocolResult(h, log.summary(), rounds=1, converged=True)


def random(shards, eps: float = 0.05, seed: int = 0) -> ProtocolResult:
    """Paper's RANDOM: an ε-net of size (d/ε)log(d/ε) sent one-way.

    Same ``sampling.EPSILON_NET_C`` constant as ``one_way.random_sampling``
    (the entry points used to pass different c's into ``epsilon_net_size``,
    making Table 2's cost column depend on the API used)."""
    d = shards[0][0].shape[1]
    return random_sampling(shards, eps=eps, vc_dim=d, seed=seed)


class _MixedClassifier(clf.LinearSeparator):
    pass


def mixing(shards, fit: Optional[Callable] = None) -> ProtocolResult:
    """Parameter averaging: each node ships (w_i, b_i); coordinator averages.
    Communication: k·(d+1) scalars — cheap, but no error guarantee under
    adversarial partitions (paper §8.1)."""
    if fit is None:
        from repro import engine
        return engine.oneway.run_instances(
            [engine.ProtocolInstance(shards, selector="mixing")])[0]
    nodes, log = make_nodes(shards)
    log.new_round()
    last = nodes[-1]
    ws, bs = [], []
    for nd in nodes:
        h = fit(nd.X, nd.y)
        wn = h.w / (np.linalg.norm(h.w) + 1e-12)
        bn = h.b / (np.linalg.norm(h.w) + 1e-12)
        ws.append(wn)
        bs.append(bn)
        if nd is not last:
            nd.send_scalars(last, np.concatenate([wn, [bn]]), tag="mixing-params")
    h = _MixedClassifier(np.mean(ws, axis=0), float(np.mean(bs)))
    return ProtocolResult(h, log.summary(), rounds=1, converged=True)
