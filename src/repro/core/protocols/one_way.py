"""One-way protocols (paper §2, §3, §6.1).

All protocols communicate down a fixed chain P_1 → P_2 → … → P_k (two-party
is k=2) and the *last* node outputs the classifier.  Costs are metered by the
shared :class:`~repro.core.comm.CommLog`; every chain hop is one
``log.new_round()``, so ``summary()["rounds"]`` always equals the
``ProtocolResult.rounds`` field (the metering contract the engine's
``BatchCommLog`` reproduces slot-for-slot).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional

import numpy as np

from repro.core import classifiers as clf
from repro.core import sampling
from repro.core.comm import CommLog, Node, make_nodes


@dataclasses.dataclass
class ProtocolResult:
    classifier: Any
    comm: dict
    rounds: int
    converged: bool
    extra: Optional[dict] = None

    def error_on(self, X: np.ndarray, y: np.ndarray) -> float:
        return self.classifier.error(X, y)

    def accuracy_on(self, X: np.ndarray, y: np.ndarray) -> float:
        return 1.0 - self.error_on(X, y)


# ---------------------------------------------------------------------------
# Theorem 2.1 — random partition: learn locally, communicate nothing
# ---------------------------------------------------------------------------

def local_only(shards, fit: Callable = clf.fit_max_margin) -> ProtocolResult:
    nodes, log = make_nodes(shards)
    h = fit(nodes[0].X, nodes[0].y)
    return ProtocolResult(h, log.summary(), rounds=0, converged=True)


# ---------------------------------------------------------------------------
# Theorem 3.1 / 6.1 — ε-net sampling down the chain (reservoir for k-party)
# ---------------------------------------------------------------------------

def random_sampling(
    shards,
    eps: float,
    vc_dim: Optional[int] = None,
    fit: Optional[Callable] = None,
    seed: int = 0,
    c: float = sampling.EPSILON_NET_C,
) -> ProtocolResult:
    """P_i forwards a reservoir sample of ∪_{j<=i} D_j; P_k fits on
    reservoir ∪ D_k.  Two-party instance is exactly paper Thm 3.1.

    With the default max-margin learner this is the batched engine's
    ``"sampling"`` selector at B=1 (:mod:`repro.engine.oneway`: compiled
    reservoir chain + batched terminal fit, identical comm metering — the
    retired host loop survives as the differential oracle in
    ``benchmarks/legacy_oneway.py``).  A custom ``fit`` callable runs the
    metered host chain below instead.
    """
    if fit is None:
        from repro import engine
        return engine.oneway.run_instances(
            [engine.ProtocolInstance(shards, eps, "sampling", seed)],
            vc_dim=vc_dim, c=c)[0]

    nodes, log = make_nodes(shards)
    d = nodes[0].d
    vc = vc_dim if vc_dim is not None else d + 1
    s_eps = sampling.epsilon_net_size(eps, vc, c=c)
    rng = np.random.default_rng(seed)

    res = sampling.Reservoir(s_eps, d, rng)
    for i, node in enumerate(nodes[:-1]):
        log.new_round()
        res.add_batch(node.X, node.y)
        RX, Ry = res.sample()
        node.send_points(nodes[i + 1], RX, Ry, tag="reservoir")
        # chain semantics: next node's reservoir continues from the stream;
        # the received points already live in nodes[i+1].recv_*
    last = nodes[-1]
    X = np.concatenate([last.X, last.recv_X])
    y = np.concatenate([last.y, last.recv_y])
    h = fit(X, y)
    return ProtocolResult(h, log.summary(), rounds=len(nodes) - 1, converged=True,
                          extra={"sample_size": s_eps})


# ---------------------------------------------------------------------------
# Lemma 3.1 / Thm 6.2 — thresholds, 0-error, O(1) per hop
# ---------------------------------------------------------------------------

def threshold_protocol(shards) -> ProtocolResult:
    """Each node forwards its largest positive and smallest negative."""
    nodes, log = make_nodes(shards)
    for i, node in enumerate(nodes[:-1]):
        log.new_round()
        X, y = node.all_known()
        x = X.reshape(-1)
        parts = []
        pos = x[y == 1]
        neg = x[y == -1]
        if len(pos):
            parts.append((pos.max(), 1))
        if len(neg):
            parts.append((neg.min(), -1))
        if parts:
            P = np.asarray([[p] for p, _ in parts])
            L = np.asarray([l for _, l in parts], dtype=np.int32)
            node.send_points(nodes[i + 1], P, L, tag="threshold-extremes")
    last = nodes[-1]
    X, y = last.all_known()
    h = clf.Threshold.fit(X, y)
    return ProtocolResult(h, log.summary(), rounds=len(nodes) - 1, converged=True)


# ---------------------------------------------------------------------------
# Lemma 3.2 — intervals: two threshold instances back to back
# ---------------------------------------------------------------------------

def interval_protocol(shards) -> ProtocolResult:
    """Each node forwards the 2 boundary pairs of its local optimal interval
    (or nothing, the paper's ∅ case)."""
    nodes, log = make_nodes(shards)
    for i, node in enumerate(nodes[:-1]):
        log.new_round()
        X, y = node.all_known()
        x = X.reshape(-1)
        pos = x[y == 1]
        neg = x[y == -1]
        sendx: List[float] = []
        sendy: List[int] = []
        if len(pos):
            a, b = pos.min(), pos.max()
            sendx += [a, b]
            sendy += [1, 1]
            # nearest blocking negatives on each side, if any
            left = neg[neg < a]
            right = neg[neg > b]
            if len(left):
                sendx.append(left.max()); sendy.append(-1)
            if len(right):
                sendx.append(right.min()); sendy.append(-1)
        if sendx:
            node.send_points(nodes[i + 1], np.asarray(sendx).reshape(-1, 1),
                             np.asarray(sendy, dtype=np.int32), tag="interval-endpoints")
    last = nodes[-1]
    X, y = last.all_known()
    h = clf.Interval.fit(X, y)
    return ProtocolResult(h, log.summary(), rounds=len(nodes) - 1, converged=True)


# ---------------------------------------------------------------------------
# Theorem 3.2 / 6.2 — axis-aligned rectangles, O(d) per hop
# ---------------------------------------------------------------------------

def rectangle_protocol(shards) -> ProtocolResult:
    """Each node forwards the corners of the minimum enclosing rectangles of
    its positives and negatives (2 points each = the paper's 4d values)."""
    nodes, log = make_nodes(shards)
    rect_p = rect_n = None
    for i, node in enumerate(nodes):
        rect_p = clf.AxisAlignedRectangle.merge(rect_p, clf.AxisAlignedRectangle.minimal(node.pos()))
        rect_n = clf.AxisAlignedRectangle.merge(rect_n, clf.AxisAlignedRectangle.minimal(node.neg()))
        if i == len(nodes) - 1:
            break
        log.new_round()
        pts, labs = [], []
        if rect_p is not None:
            pts += [rect_p[0], rect_p[1]]; labs += [1, 1]
        if rect_n is not None:
            pts += [rect_n[0], rect_n[1]]; labs += [-1, -1]
        if pts:
            node.send_points(nodes[i + 1], np.stack(pts), np.asarray(labs, dtype=np.int32),
                             tag="rect-corners")
    # decide polarity: the smaller enclosing box is the inside class (paper proof)
    def _vol(r):
        return float(np.prod(r[1] - r[0])) if r is not None else np.inf
    if rect_p is None:
        # the paper's ∅ sentinel on the positive class everywhere: the
        # minimal consistent rectangle is empty, so the hypothesis is the
        # degenerate always-negative box (lo > hi ⇒ nothing is inside) —
        # NOT a box around the negatives, whose outside would flip to +1
        d = nodes[0].d
        h = clf.AxisAlignedRectangle(np.full(d, np.inf), np.full(d, -np.inf),
                                     positive_inside=True)
    elif rect_n is None or _vol(rect_p) <= _vol(rect_n):
        h = clf.AxisAlignedRectangle.from_bounds(rect_p, positive_inside=True)
    else:
        h = clf.AxisAlignedRectangle.from_bounds(rect_n, positive_inside=False)
    return ProtocolResult(h, log.summary(), rounds=len(nodes) - 1, converged=True)
