"""Sampling utilities: reservoir sampling (Vitter 1985) and ε-net sizes.

Used by the one-way k-party sampling protocol (paper Thm 6.1): player P_i
maintains a reservoir R_i of size s_ε over ∪_{j<=i} D_j and forwards it down
the chain.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import numpy as np


def epsilon_net_size(eps: float, vc_dim: int, c: float = 1.0) -> int:
    """s_ε = O((ν/ε) log(ν/ε)) — paper Thm 3.1 (noiseless ε-net bound)."""
    assert 0 < eps < 1
    r = vc_dim / eps
    return max(1, int(math.ceil(c * r * max(1.0, math.log(max(r, 2.0))))))


def epsilon_sample_size(eps: float, vc_dim: int, c: float = 0.5) -> int:
    """s = O(ν/ε²) — the noisy-setting ε-sample bound (paper §3/§8)."""
    assert 0 < eps < 1
    return max(1, int(math.ceil(c * vc_dim / (eps * eps))))


class Reservoir:
    """Classic reservoir sampler over a stream of labeled points.

    Supports merging a downstream node's data into an upstream reservoir with
    the correct inclusion probabilities (weighted by stream position), which
    is what the chain protocol needs.
    """

    def __init__(self, capacity: int, dim: int, rng: Optional[np.random.Generator] = None):
        self.capacity = int(capacity)
        self.X = np.zeros((capacity, dim))
        self.y = np.zeros((capacity,), dtype=np.int32)
        self.seen = 0
        self.filled = 0
        self.rng = rng or np.random.default_rng(0)

    def add(self, x: np.ndarray, label: int) -> None:
        self.seen += 1
        if self.filled < self.capacity:
            self.X[self.filled] = x
            self.y[self.filled] = label
            self.filled += 1
            return
        j = self.rng.integers(0, self.seen)
        if j < self.capacity:
            self.X[j] = x
            self.y[j] = label

    def add_batch(self, X: np.ndarray, y: np.ndarray) -> None:
        for xi, yi in zip(np.atleast_2d(X), np.atleast_1d(y)):
            self.add(xi, int(yi))

    def sample(self) -> Tuple[np.ndarray, np.ndarray]:
        return self.X[: self.filled].copy(), self.y[: self.filled].copy()
