"""Sampling utilities: reservoir sampling (Vitter 1985) and ε-net sizes.

Used by the one-way k-party sampling protocol (paper Thm 6.1): player P_i
maintains a reservoir R_i of size s_ε over ∪_{j<=i} D_j and forwards it down
the chain.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import numpy as np

# The ε-net leading constant used by *every* RANDOM entry point
# (one_way.random_sampling, baselines.random, engine.oneway "sampling").
# c = 1.0 is the paper's literal Table-2 size (d/ε)·log(d/ε); keeping one
# shared constant makes RANDOM's cost column reproducible from any API —
# the entry points used to disagree (0.35 vs 1.0), which silently changed
# both the sample cost and the achieved error depending on the call site.
EPSILON_NET_C = 1.0


def epsilon_net_size(eps: float, vc_dim: int, c: float = EPSILON_NET_C) -> int:
    """s_ε = O((ν/ε) log(ν/ε)) — paper Thm 3.1 (noiseless ε-net bound)."""
    assert 0 < eps < 1
    r = vc_dim / eps
    return max(1, int(math.ceil(c * r * max(1.0, math.log(max(r, 2.0))))))


def epsilon_sample_size(eps: float, vc_dim: int, c: float = 0.5) -> int:
    """s = O(ν/ε²) — the noisy-setting ε-sample bound (paper §3/§8)."""
    assert 0 < eps < 1
    return max(1, int(math.ceil(c * vc_dim / (eps * eps))))


class Reservoir:
    """Classic reservoir sampler over a stream of labeled points.

    Supports merging a downstream node's data into an upstream reservoir with
    the correct inclusion probabilities (weighted by stream position), which
    is what the chain protocol needs.
    """

    def __init__(self, capacity: int, dim: int, rng: Optional[np.random.Generator] = None):
        self.capacity = int(capacity)
        self.X = np.zeros((capacity, dim))
        self.y = np.zeros((capacity,), dtype=np.int32)
        self.seen = 0
        self.filled = 0
        self.rng = rng or np.random.default_rng(0)

    def add(self, x: np.ndarray, label: int) -> None:
        self.seen += 1
        if self.filled < self.capacity:
            self.X[self.filled] = x
            self.y[self.filled] = label
            self.filled += 1
            return
        j = self.rng.integers(0, self.seen)
        if j < self.capacity:
            self.X[j] = x
            self.y[j] = label

    def add_batch(self, X: np.ndarray, y: np.ndarray) -> None:
        """Vectorized ingest of a whole shard — one RNG draw and two fancy
        assignments instead of O(n) Python-level ``add`` calls.

        Identical process to repeated :meth:`add`: the item at global stream
        position t draws j ~ U[0, t) and replaces slot j iff j < capacity.
        Later items overwrite earlier ones on slot collisions (numpy fancy
        assignment keeps the last write), matching sequential order, so
        inclusion probabilities are exactly Vitter's k/t.
        """
        X = np.atleast_2d(np.asarray(X, dtype=np.float64))
        y = np.atleast_1d(np.asarray(y))
        n = X.shape[0]
        if n == 0:
            return
        start = 0
        if self.filled < self.capacity:
            take = min(self.capacity - self.filled, n)
            self.X[self.filled:self.filled + take] = X[:take]
            self.y[self.filled:self.filled + take] = y[:take]
            self.filled += take
            self.seen += take
            start = take
        rest = n - start
        if rest == 0:
            return
        positions = self.seen + 1 + np.arange(rest)   # 1-based stream counts
        j = self.rng.integers(0, positions)           # j ~ U[0, t) per item
        hit = j < self.capacity
        self.X[j[hit]] = X[start:][hit]
        self.y[j[hit]] = y[start:][hit]
        self.seen += rest

    def sample(self) -> Tuple[np.ndarray, np.ndarray]:
        return self.X[: self.filled].copy(), self.y[: self.filled].copy()

    def sample_padded(self, n_pad: int) -> Tuple[np.ndarray, np.ndarray]:
        """Snapshot padded to exactly ``n_pad`` rows with the engine's
        label-0 convention (zero rows are inert in every masked reduction).

        The streaming session pool admits sessions at *pinned* shard shapes
        so the compacted dispatch's compile-cache keys never move
        (``engine/session_pool``): each ingest node keeps a reservoir of
        capacity ≤ n_pad and admission takes this fixed-shape snapshot, not
        the ragged :meth:`sample` one.
        """
        if self.capacity > n_pad:
            raise ValueError(
                f"reservoir capacity {self.capacity} exceeds the pool's "
                f"pinned shard shape n_pad={n_pad}")
        X = np.zeros((n_pad, self.X.shape[1]))
        y = np.zeros((n_pad,), np.int32)
        X[: self.filled] = self.X[: self.filled]
        y[: self.filled] = self.y[: self.filled]
        return X, y
