"""Communication accounting — the paper's scarce resource, metered.

The paper (§1) treats inter-node communication as the resource to optimize and
reports protocol cost in *points communicated* (Tables 2-4).  Every protocol in
``repro.core.protocols`` moves data exclusively through :class:`Channel`
objects owned by a :class:`CommLog`, so costs are measured, never estimated.

Units
-----
``points``   number of labeled points shipped (the paper's unit).
``scalars``  number of raw floats (directions, offsets, thresholds).
``bits``     control bits (the ±1 votes of the two-way protocol).
``bytes``    derived from the exact wire bit count
             points * (d+1) * 32 + scalars * 32 + bits, ceiled to bytes
             **once, over the aggregate** (float32 wire format, control bits
             packed across the whole trace).  Used to compare against
             gradient-synchronization baselines in the trainer integration.

The aggregate convention is canonical: per-message byte attribution must use
:meth:`CommLog.message_nbytes` (packed-stream deltas), which sums exactly to
``summary()["bytes"]``.  Ceiling each message separately overstates the total
whenever a protocol sends multiple sub-byte bit votes.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np


def wire_bits(points: int, scalars: int, bits: int, dim: int) -> int:
    """Exact wire size in bits: a labeled point is d+1 float32s, a scalar one
    float32, control bits count as themselves.  The exact-bit form is the
    primitive every byte figure derives from — it is additive across
    messages, so packed-stream accounting stays consistent at any
    granularity."""
    return (points * (dim + 1) + scalars) * 32 + bits


def wire_bytes(points: int, scalars: int, bits: int, dim: int) -> int:
    """Canonical float32 wire size: ``ceil(wire_bits / 8)`` — the bit total
    is ceiled to bytes once, over whatever aggregate is being priced.  Single
    source of truth for every accounting path (Message, CommStats, and the
    engine's BatchCommLog).  Float payloads are byte-aligned, so this equals
    the historical ``points*(d+1)*4 + scalars*4 + ceil(bits/8)`` form."""
    return -(-wire_bits(points, scalars, bits, dim) // 8)


@dataclasses.dataclass
class Message:
    """One transmission between two nodes."""

    src: str
    dst: str
    points: int = 0
    scalars: int = 0
    bits: int = 0
    tag: str = ""
    payload: Any = None

    def wire_bits(self, dim: int) -> int:
        return wire_bits(self.points, self.scalars, self.bits, dim)

    def nbytes(self, dim: int) -> int:
        """Byte cost of this message priced as a standalone trace (its bit
        payload ceiled alone).  Inside a trace this is an upper bound: the
        canonical per-message attribution packs bits across the stream —
        use :meth:`CommLog.message_nbytes`, which sums exactly to
        ``summary()["bytes"]``."""
        return wire_bytes(self.points, self.scalars, self.bits, dim)


@dataclasses.dataclass
class CommStats:
    points: int = 0
    scalars: int = 0
    bits: int = 0
    messages: int = 0
    rounds: int = 0

    def nbytes(self, dim: int) -> int:
        return wire_bytes(self.points, self.scalars, self.bits, dim)


class CommLog:
    """Ledger of all communication in one protocol execution."""

    def __init__(self, dim: int):
        self.dim = dim
        self.messages: List[Message] = []
        self.rounds = 0

    def send(
        self,
        src: str,
        dst: str,
        *,
        points: Optional[np.ndarray] = None,
        labels: Optional[np.ndarray] = None,
        scalars: int = 0,
        bits: int = 0,
        tag: str = "",
        payload: Any = None,
    ) -> Any:
        """Record a message; returns the payload (simulating the wire)."""
        n_points = 0 if points is None else int(np.atleast_2d(points).shape[0])
        msg = Message(
            src=src,
            dst=dst,
            points=n_points,
            scalars=scalars,
            bits=bits,
            tag=tag,
            payload=payload if payload is not None else (points, labels),
        )
        self.messages.append(msg)
        return msg.payload

    def new_round(self) -> None:
        self.rounds += 1

    def message_nbytes(self) -> List[int]:
        """Per-message byte attribution under the canonical aggregate
        convention: message i is charged the growth of the packed stream,
        ``ceil(cum_bits_i / 8) - ceil(cum_bits_{i-1} / 8)``, so the list sums
        to ``summary()["bytes"]`` exactly — unlike ceiling each message alone,
        which double-charges partial bytes of consecutive bit votes."""
        out, cum, prev = [], 0, 0
        for m in self.messages:
            cum += m.wire_bits(self.dim)
            ceiled = -(-cum // 8)
            out.append(ceiled - prev)
            prev = ceiled
        return out

    @property
    def stats(self) -> CommStats:
        s = CommStats(rounds=self.rounds, messages=len(self.messages))
        for m in self.messages:
            s.points += m.points
            s.scalars += m.scalars
            s.bits += m.bits
        return s

    def cost_points(self) -> int:
        """The paper's 'Cost' column: total labeled points shipped."""
        return self.stats.points

    def summary(self) -> Dict[str, Any]:
        s = self.stats
        return {
            "points": s.points,
            "scalars": s.scalars,
            "bits": s.bits,
            "messages": s.messages,
            "rounds": s.rounds,
            "bytes": s.nbytes(self.dim),
        }


class Node:
    """One party holding a disjoint shard ``(X, y)`` of the global dataset.

    ``X`` is (n, d) float array, ``y`` is (n,) in {-1, +1}.  Nodes interact
    only through :meth:`send`, which meters the channel.
    """

    def __init__(self, name: str, X: np.ndarray, y: np.ndarray, log: CommLog):
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.int32)
        assert X.ndim == 2 and y.shape == (X.shape[0],), (X.shape, y.shape)
        assert set(np.unique(y)).issubset({-1, 1}), "labels must be +-1"
        self.name = name
        self.X = X
        self.y = y
        self.log = log
        # points received from other nodes (accumulated protocol transcript W)
        self.recv_X: np.ndarray = np.zeros((0, X.shape[1]))
        self.recv_y: np.ndarray = np.zeros((0,), dtype=np.int32)

    # -- data views ---------------------------------------------------------
    @property
    def d(self) -> int:
        return self.X.shape[1]

    @property
    def n(self) -> int:
        return self.X.shape[0]

    def pos(self) -> np.ndarray:
        return self.X[self.y == 1]

    def neg(self) -> np.ndarray:
        return self.X[self.y == -1]

    def all_known(self) -> Tuple[np.ndarray, np.ndarray]:
        """Own points plus everything received so far."""
        X = np.concatenate([self.X, self.recv_X], axis=0)
        y = np.concatenate([self.y, self.recv_y], axis=0)
        return X, y

    # -- communication ------------------------------------------------------
    def send_points(self, dst: "Node", X: np.ndarray, y: np.ndarray, tag: str = "") -> None:
        X = np.atleast_2d(np.asarray(X, dtype=np.float64))
        y = np.atleast_1d(np.asarray(y, dtype=np.int32))
        if X.shape[0] == 0:
            # empty messages still cost one message-slot but no points
            self.log.send(self.name, dst.name, points=None, tag=tag)
            return
        self.log.send(self.name, dst.name, points=X, labels=y, tag=tag)
        dst.recv_X = np.concatenate([dst.recv_X, X], axis=0)
        dst.recv_y = np.concatenate([dst.recv_y, y], axis=0)

    def send_scalars(self, dst: "Node", values: np.ndarray, tag: str = "") -> np.ndarray:
        values = np.atleast_1d(np.asarray(values, dtype=np.float64))
        self.log.send(self.name, dst.name, scalars=values.size, tag=tag, payload=values)
        return values

    def send_bit(self, dst: "Node", bit: int, tag: str = "") -> int:
        self.log.send(self.name, dst.name, bits=1, tag=tag, payload=bit)
        return bit


def make_nodes(
    shards: List[Tuple[np.ndarray, np.ndarray]], names: Optional[List[str]] = None
) -> Tuple[List[Node], CommLog]:
    """Build k nodes sharing one CommLog from a list of (X, y) shards."""
    assert shards, "need at least one shard"
    d = shards[0][0].shape[1]
    log = CommLog(dim=d)
    if names is None:
        names = [chr(ord("A") + i) if i < 26 else f"P{i}" for i in range(len(shards))]
    nodes = [Node(nm, X, y, log) for nm, (X, y) in zip(names, shards)]
    return nodes, log
