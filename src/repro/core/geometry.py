"""Geometric primitives for the protocols.

Control-plane geometry (convex hulls, medians) runs on host numpy — protocol
rounds are tiny.  Data-plane bulk operations (margins over big shards,
set-of-uncertainty scans over direction space) are jit'd JAX, and the margin
hot loop has a Pallas kernel in ``repro.kernels.support_margin``.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Convex hulls (2D, host-side; monotone chain)
# ---------------------------------------------------------------------------

def convex_hull_2d(points: np.ndarray) -> np.ndarray:
    """Indices of the convex hull of 2-D ``points`` in counter-clockwise order.

    Andrew's monotone chain; O(n log n).  Degenerate inputs (<=2 points or
    collinear) return all unique points.
    """
    pts = np.asarray(points, dtype=np.float64)
    n = pts.shape[0]
    if n == 0:
        return np.zeros((0,), dtype=np.int64)
    order = np.lexsort((pts[:, 1], pts[:, 0]))
    pts_sorted = pts[order]

    def cross(o, a, b):
        return (a[0] - o[0]) * (b[1] - o[1]) - (a[1] - o[1]) * (b[0] - o[0])

    if n < 3:
        return order

    lower: list = []
    for i in range(n):
        while len(lower) >= 2 and cross(pts_sorted[lower[-2]], pts_sorted[lower[-1]], pts_sorted[i]) <= 0:
            lower.pop()
        lower.append(i)
    upper: list = []
    for i in range(n - 1, -1, -1):
        while len(upper) >= 2 and cross(pts_sorted[upper[-2]], pts_sorted[upper[-1]], pts_sorted[i]) <= 0:
            upper.pop()
        upper.append(i)
    hull_sorted = lower[:-1] + upper[:-1]
    if not hull_sorted:  # fully collinear
        hull_sorted = [0, n - 1]
    return order[np.asarray(hull_sorted, dtype=np.int64)]


def hull_edges(points: np.ndarray, hull_idx: np.ndarray) -> np.ndarray:
    """(m, 2, 2) array of hull edge segments in CCW order."""
    h = points[hull_idx]
    return np.stack([h, np.roll(h, -1, axis=0)], axis=1)


def edge_normals(edges: np.ndarray) -> np.ndarray:
    """Outward normals of CCW hull edges, unit length. edges: (m,2,2)."""
    d = edges[:, 1] - edges[:, 0]
    n = np.stack([d[:, 1], -d[:, 0]], axis=-1)  # rotate -90deg: outward for CCW
    norm = np.linalg.norm(n, axis=-1, keepdims=True)
    norm = np.where(norm == 0, 1.0, norm)
    return n / norm


def project_to_hull_boundary(points: np.ndarray, edges: np.ndarray) -> np.ndarray:
    """For each point return the index of the nearest hull edge.

    Implements the MEDIAN subroutine's 'project U_A onto ∂P_A' step (paper
    Alg. 2, line 3): each uncertain point is charged to the closest boundary
    edge, producing the per-edge weights used for the weighted median.
    """
    if len(points) == 0:
        return np.zeros((0,), dtype=np.int64)
    a = edges[:, 0][None, :, :]  # (1, m, 2)
    b = edges[:, 1][None, :, :]
    p = np.asarray(points)[:, None, :]  # (n, 1, 2)
    ab = b - a
    denom = np.maximum((ab * ab).sum(-1), 1e-30)
    t = np.clip(((p - a) * ab).sum(-1) / denom, 0.0, 1.0)
    proj = a + t[..., None] * ab
    dist = np.linalg.norm(p - proj, axis=-1)  # (n, m)
    return np.argmin(dist, axis=1)


def weighted_median_index(weights: np.ndarray) -> int:
    """Index of the weighted median item (first index where cumsum >= half)."""
    w = np.asarray(weights, dtype=np.float64)
    total = w.sum()
    if total <= 0:
        return 0
    c = np.cumsum(w)
    return int(np.searchsorted(c, total / 2.0))


# ---------------------------------------------------------------------------
# Margins / separability (JAX data plane)
# ---------------------------------------------------------------------------

@jax.jit
def signed_margins(w: jnp.ndarray, b: jnp.ndarray, X: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """y * (X @ w + b) — positive iff correctly classified."""
    return y * (X @ w + b)


@jax.jit
def classification_error(w: jnp.ndarray, b: jnp.ndarray, X: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Fraction of misclassified points (ties count as errors)."""
    return jnp.mean(signed_margins(w, b, X, y) <= 0)


@functools.partial(jax.jit, static_argnames=("n_angles",))
def direction_grid(n_angles: int) -> jnp.ndarray:
    """Unit vectors covering S^1: (n_angles, 2)."""
    theta = jnp.linspace(0.0, 2.0 * jnp.pi, n_angles, endpoint=False)
    return jnp.stack([jnp.cos(theta), jnp.sin(theta)], axis=-1)


@jax.jit
def consistent_threshold_ranges(
    V: jnp.ndarray, Xw: jnp.ndarray, yw: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-direction interval of thresholds consistent with transcript W.

    Classifier convention: predict +1 iff v·x < t.  For direction v the
    consistent thresholds are ( max_{+} v·x , min_{-} v·x ); the interval is
    empty (lo >= hi) iff W is not separable along v.

    V: (m, 2) unit directions; Xw: (n, 2) transcript points; yw: (n,) ±1.
    Returns (lo, hi): each (m,).  With an empty transcript lo=-inf, hi=+inf.
    """
    proj = V @ Xw.T  # (m, n)
    big = jnp.inf
    pos = yw == 1
    neg = yw == -1   # explicit: label-0 padding rows constrain neither side
    lo = jnp.max(jnp.where(pos[None, :], proj, -big), axis=1, initial=-big)
    hi = jnp.min(jnp.where(neg[None, :], proj, big), axis=1, initial=big)
    return lo, hi


@jax.jit
def uncertain_mask(
    V: jnp.ndarray,
    dir_ok: jnp.ndarray,
    Xw: jnp.ndarray,
    yw: jnp.ndarray,
    X: jnp.ndarray,
    y: jnp.ndarray,
) -> jnp.ndarray:
    """Set of uncertainty: which of (X, y) can a transcript-consistent
    classifier (direction allowed by ``dir_ok``) still misclassify?

    Convention: predict +1 iff v·x < t, consistent t ∈ (lo, hi).  A positive
    point q is misclassified by some consistent classifier along v iff a
    consistent t ≤ v·q exists, i.e. v·q > lo.  A negative q is misclassified
    iff a consistent t > v·q exists, i.e. v·q < hi.  Returns boolean (n,)
    mask — the SOU of paper §4.1.
    """
    lo, hi = consistent_threshold_ranges(V, Xw, yw)  # (m,)
    nonempty = (lo < hi) & dir_ok
    proj = V @ X.T  # (m, n)
    pos_risk = proj > lo[:, None]
    neg_risk = proj < hi[:, None]
    at_risk = jnp.where((y == 1)[None, :], pos_risk, neg_risk)
    return jnp.any(at_risk & nonempty[:, None], axis=0)
