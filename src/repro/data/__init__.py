from repro.data.pipeline import DataConfig, make_batch_specs, synthetic_stream  # noqa: F401
