"""Data pipeline: deterministic synthetic token streams + batch specs.

The synthetic stream is a seeded Markov-ish token generator (cheap, infinite,
reproducible across hosts by shard index) used by the training examples and
smoke tests; ``make_batch_specs`` builds the ShapeDtypeStruct stand-ins the
dry-run lowers against (the same structure, no allocation).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import InputShape, ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    seed: int = 0
    n_vis: int = 64          # vlm: patch tokens per sample
    enc_ratio: int = 4       # audio: encoder frames = seq_len, decoder = seq/ratio


def dec_len(cfg: ModelConfig, seq_len: int) -> int:
    """Decoder-side length for enc-dec models (audio frames dominate)."""
    return max(128, seq_len // 8) if cfg.enc_dec else seq_len


def synthetic_stream(cfg: ModelConfig, dc: DataConfig, shard: int = 0,
                     n_shards: int = 1) -> Iterator[Dict[str, np.ndarray]]:
    """Infinite stream of host-side batches for this data shard."""
    rng = np.random.default_rng(dc.seed * 9973 + shard)
    B = dc.global_batch // n_shards
    S = dc.seq_len
    Sd = dec_len(cfg, S)
    V = cfg.vocab
    # low-entropy structured stream: tokens follow a noisy linear recurrence,
    # so a real model can actually reduce loss on it
    while True:
        base = rng.integers(0, V, size=(B, 1))
        steps = rng.integers(1, 17, size=(B, Sd + 1))
        toks = (base + np.cumsum(steps, axis=1)) % V
        batch: Dict[str, np.ndarray] = {
            "tokens": toks[:, :Sd].astype(np.int32),
            "targets": toks[:, 1:Sd + 1].astype(np.int32),
        }
        if cfg.family == "vlm":
            nv = min(dc.n_vis, Sd // 2)
            batch["vision_embed"] = rng.normal(0, 0.02, size=(B, nv, cfg.d_model)).astype(np.float32)
            pos = np.broadcast_to(np.arange(Sd)[None], (B, Sd))
            batch["rope_pos"] = np.broadcast_to(pos[None], (3, B, Sd)).astype(np.int32)
        if cfg.enc_dec:
            batch["audio_embed"] = rng.normal(0, 0.02, size=(B, S, cfg.d_model)).astype(np.float32)
        yield batch


def make_batch_specs(cfg: ModelConfig, shape: InputShape,
                     dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this shape —
    weak-type-correct, shardable, no device allocation (dry-run contract)."""
    B, S = shape.global_batch, shape.seq_len
    Sd = dec_len(cfg, S)
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        specs = {"tokens": sds((B, Sd), jnp.int32), "targets": sds((B, Sd), jnp.int32)}
        if cfg.family == "vlm":
            specs["vision_embed"] = sds((B, 64, cfg.d_model), dtype)
            specs["rope_pos"] = sds((3, B, Sd), jnp.int32)
        if cfg.enc_dec:
            specs["audio_embed"] = sds((B, S, cfg.d_model), dtype)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": sds((B, Sd), jnp.int32)}
        if cfg.family == "vlm":
            specs["vision_embed"] = sds((B, 64, cfg.d_model), dtype)
            specs["rope_pos"] = sds((3, B, Sd), jnp.int32)
        if cfg.enc_dec:
            specs["audio_embed"] = sds((B, S, cfg.d_model), dtype)
        return specs
    # decode: one new token; caches are built separately
    return {"tokens": sds((B, 1), jnp.int32)}
