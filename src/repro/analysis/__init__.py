from repro.analysis.roofline import RooflineReport, analyze_compiled, parse_collectives  # noqa: F401
