"""Analysis tooling: roofline/HLO cost models and the static linter.

The roofline re-exports are lazy (PEP 562) so that the stdlib-only lint
CLI (``python -m repro.analysis.lint``) can run on an interpreter with no
jax installed — CI's dep-free ``lint`` job depends on this.
"""

_ROOFLINE_EXPORTS = ("RooflineReport", "analyze_compiled", "parse_collectives")

__all__ = list(_ROOFLINE_EXPORTS)


def __getattr__(name):
    if name in _ROOFLINE_EXPORTS:
        from repro.analysis import roofline

        return getattr(roofline, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
