"""Structural HLO cost model: while-loop-aware flops / bytes / collectives.

``compiled.cost_analysis()`` counts every ``while`` body ONCE regardless of
trip count (verified empirically: a scan of 10 matmuls reports the flops of
1).  Our models run layers, microbatches, attention q-blocks and loss chunks
under ``lax.scan`` / ``lax.map``, so the naive numbers undercount by 2-3
orders of magnitude.  This module re-derives the three roofline inputs by
walking the compiled HLO call graph:

  flops        2·M·N·K·B for every ``dot`` (fusion-internal dots included),
               scaled by the product of enclosing while trip counts
               (``backend_config={"known_trip_count":{"n":...}}``).
  bytes        per top-level op at fusion boundaries: operand + output
               payloads (the standard bytes-accessed model), same scaling.
  collectives  per op wire bytes = output payload × ring factor for the
               replica-group size, same scaling.

Elementwise / reduce flops are ignored (dots dominate transformer cost);
this is the documented convention for MFU accounting.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(pred|[a-z]+\d+[a-z0-9]*)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s+->\s+.+\{\s*$")
_OP_RE = re.compile(
    r"^\s+(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*((?:\([^)]*\))|(?:\S+))\s+([\w\-]+)\((.*)$")
_TRIP_RE = re.compile(r"known_trip_count\W+n\W+(\d+)")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_GROUPS_PAIR_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# ops whose "traffic" is bookkeeping, not HBM payload
_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "partition-id", "replica-id", "iota", "reshape"}


def _payload_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        n = 1
        dims = m.group(2)
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(m.group(1), 4)
    return total


def _dims(attr: str, key: str) -> List[int]:
    m = re.search(key + r"=\{([\d,]*)\}", attr)
    if not m or not m.group(1):
        return []
    return [int(x) for x in m.group(1).split(",")]


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(x) for x in m.group(2).split(",")]


def _ring_factor(op: str, g: int) -> float:
    if g <= 1:
        return 0.0
    if op == "all-gather":
        return (g - 1) / g
    if op == "reduce-scatter":
        return float(g - 1)
    if op == "all-reduce":
        return 2.0 * (g - 1) / g
    if op == "all-to-all":
        return (g - 1) / g
    return 1.0


@dataclasses.dataclass
class _Op:
    name: str
    type_str: str
    kind: str
    rest: str           # everything after the opening paren
    operands: List[str]


@dataclasses.dataclass
class _Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_op: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_counts: Dict[str, float] = dataclasses.field(default_factory=dict)

    def __iadd__(self, o: "_Cost") -> "_Cost":
        self.flops += o.flops
        self.bytes += o.bytes
        self.coll_bytes += o.coll_bytes
        for k, v in o.coll_by_op.items():
            self.coll_by_op[k] = self.coll_by_op.get(k, 0.0) + v
        for k, v in o.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0.0) + v
        return self

    def scaled(self, f: float) -> "_Cost":
        return _Cost(self.flops * f, self.bytes * f, self.coll_bytes * f,
                     {k: v * f for k, v in self.coll_by_op.items()},
                     {k: v * f for k, v in self.coll_counts.items()})


def _parse_computations(hlo: str) -> Tuple[Dict[str, List[_Op]], Optional[str]]:
    comps: Dict[str, List[_Op]] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    for line in hlo.splitlines():
        if cur is None:
            m = _COMP_HDR_RE.match(line)
            if m:
                cur = m.group(1)
                comps[cur] = []
                if line.startswith("ENTRY"):
                    entry = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, type_str, kind, rest = m.groups()
        # operand refs appear before any attribute section; cut at '), '
        arg_part = rest.split("),")[0]
        operands = _OPERAND_RE.findall(arg_part)
        comps[cur].append(_Op(name, type_str, kind, rest, operands))
    return comps, entry


def _dot_flops(op: _Op, symtab: Dict[str, str]) -> float:
    lhs = op.operands[0] if op.operands else None
    lhs_t = symtab.get(lhs, "")
    ldims = _shape_dims(lhs_t)
    out_dims = _shape_dims(op.type_str)
    lc = _dims(op.rest, "lhs_contracting_dims")
    lb = _dims(op.rest, "lhs_batch_dims")
    k = 1
    for i in lc:
        if i < len(ldims):
            k *= ldims[i]
    out_n = 1
    for d in out_dims:
        out_n *= d
    return 2.0 * out_n * k


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps, self.entry = _parse_computations(hlo_text)
        self.symtabs: Dict[str, Dict[str, str]] = {
            c: {o.name: o.type_str for o in ops} for c, ops in self.comps.items()}
        # parameters also need shapes; they are ops too (parsed as kind
        # 'parameter' with type) — included above.
        self._memo: Dict[Tuple[str, bool], _Cost] = {}

    # ------------------------------------------------------------------
    def _comp_cost(self, comp: str, boundary_bytes: bool) -> _Cost:
        """Cost of one execution of ``comp``.

        ``boundary_bytes``: count byte traffic of this computation's ops
        (True at top level and while bodies; False inside fusions, where
        only flops escape — the fusion's own boundary traffic is charged at
        the call site)."""
        key = (comp, boundary_bytes)
        if key in self._memo:
            return self._memo[key]
        total = _Cost()
        symtab = self.symtabs.get(comp, {})
        for op in self.comps.get(comp, []):
            total += self._op_cost(op, symtab, boundary_bytes)
        self._memo[key] = total
        return total

    def _op_cost(self, op: _Op, symtab: Dict[str, str],
                 boundary_bytes: bool) -> _Cost:
        c = _Cost()
        kind = op.kind
        if kind == "while":
            body = _BODY_RE.search(op.rest)
            cond = _COND_RE.search(op.rest)
            trip_m = _TRIP_RE.search(op.rest)
            trip = int(trip_m.group(1)) if trip_m else 1
            inner = _Cost()
            if body:
                inner += self._comp_cost(body.group(1), True)
            if cond:
                inner += self._comp_cost(cond.group(1), True)
            return inner.scaled(float(trip))
        if kind == "conditional":
            # max over branches (decode paths); branches named in calls list
            branches = _CALLS_RE.findall(op.rest)
            best = _Cost()
            for b in branches:
                bc = self._comp_cost(b, True)
                if bc.flops + bc.bytes > best.flops + best.bytes:
                    best = bc
            return best
        if kind in ("fusion", "call", "async-start"):
            m = _CALLS_RE.search(op.rest)
            if m:
                # flops (and collectives) inside the fusion escape; bytes are
                # charged at this boundary below
                inner = self._comp_cost(m.group(1), False)
                c += _Cost(inner.flops, 0.0, inner.coll_bytes,
                           dict(inner.coll_by_op), dict(inner.coll_counts))
        if kind == "dot" or kind == "convolution":
            c.flops += _dot_flops(op, symtab)
        base_kind = kind[:-6] if kind.endswith("-start") else kind
        if base_kind in _COLLECTIVES:
            payload = _payload_bytes(op.type_str)
            gm = _GROUPS_PAIR_RE.search(op.rest)
            if gm:
                g = int(gm.group(2))
            else:
                gl = _GROUPS_LIST_RE.search(op.rest)
                g = len(gl.group(1).split(",")) if gl and gl.group(1) else 2
            wire = payload * _ring_factor(base_kind, g)
            c.coll_bytes += wire
            c.coll_by_op[base_kind] = c.coll_by_op.get(base_kind, 0.0) + wire
            c.coll_counts[base_kind] = c.coll_counts.get(base_kind, 0.0) + 1
        if boundary_bytes and kind not in _FREE_OPS and not kind.endswith("-done"):
            b = _payload_bytes(op.type_str)
            for ref in op.operands:
                t = symtab.get(ref)
                if t is not None:
                    b += _payload_bytes(t)
            c.bytes += b
        return c

    # ------------------------------------------------------------------
    def total(self) -> _Cost:
        if self.entry is None:
            return _Cost()
        return self._comp_cost(self.entry, True)


def analyze_hlo(hlo_text: str) -> Dict[str, float]:
    """Returns while-aware {flops, bytes, collective_bytes, bytes_by_op,
    counts} for one per-device compiled module."""
    cost = HloCostModel(hlo_text).total()
    return {
        "flops": cost.flops,
        "bytes": cost.bytes,
        "collective_bytes": cost.coll_bytes,
        "bytes_by_op": {k: int(v) for k, v in cost.coll_by_op.items()},
        "counts": {k: int(v) for k, v in cost.coll_counts.items()},
    }


_META_RE = re.compile(r'op_name="([^"]*)"')


def top_collectives(hlo_text: str, k: int = 20) -> List[Dict]:
    """Top-k collective ops by trip-multiplied wire bytes, with the JAX
    op_name metadata that caused them — the §Perf diagnosis tool."""
    model = HloCostModel(hlo_text)
    # trip multiplier per computation: product of enclosing while trips
    mult: Dict[str, float] = {}

    def walk(comp: str, m: float):
        if comp in mult and mult[comp] >= m:
            return
        mult[comp] = max(mult.get(comp, 0.0), m)
        for op in model.comps.get(comp, []):
            if op.kind == "while":
                body = _BODY_RE.search(op.rest)
                cond = _COND_RE.search(op.rest)
                tm = _TRIP_RE.search(op.rest)
                trip = int(tm.group(1)) if tm else 1
                for ref in (body, cond):
                    if ref:
                        walk(ref.group(1), m * trip)
            else:
                cm = _CALLS_RE.search(op.rest)
                if cm:
                    walk(cm.group(1), m)

    if model.entry:
        walk(model.entry, 1.0)
    rows = []
    for comp, ops in model.comps.items():
        m = mult.get(comp, 0.0)
        if m <= 0:
            continue
        for op in ops:
            base = op.kind[:-6] if op.kind.endswith("-start") else op.kind
            if base not in _COLLECTIVES:
                continue
            payload = _payload_bytes(op.type_str)
            gm = _GROUPS_PAIR_RE.search(op.rest)
            if gm:
                g = int(gm.group(2))
            else:
                gl = _GROUPS_LIST_RE.search(op.rest)
                g = len(gl.group(1).split(",")) if gl and gl.group(1) else 2
            wire = payload * _ring_factor(base, g) * m
            meta = _META_RE.search(op.rest)
            rows.append({"op": base, "bytes": int(wire), "trips": int(m),
                         "group": g, "shape": op.type_str[:60],
                         "src": meta.group(1)[:110] if meta else ""})
    rows.sort(key=lambda r: -r["bytes"])
    return rows[:k]
