"""Block-shape autotuner for the tiled Pegasos solver kernel.

The solver wrapper (``kernels.ops.pegasos_stage``) asks :func:`lookup_tile`
for ``(block_b, block_n, unroll)`` given the launch shape.  Lookup is pure
and deterministic:

1. the launch shape is bucketed (next power of two per axis, floors
   ``B ≥ 1``, ``N ≥ 8``, ``d ≥ 2``) — one tuning entry covers a bucket,
   not an exact shape, so compacted hot-loop fills with ragged ``N`` hit
   the same entry as their padded siblings;
2. the committed cache ``src/repro/kernels/tuning_cache.json`` is consulted
   with the key ``"{device_kind}|B{bB}_N{bN}_d{bd}"``;
3. on a miss (unknown device, untuned bucket, or a deleted cache file) the
   deterministic fallback table applies — keyed by device kind and the
   d bucket only, so behaviour off the tuned grid is still reproducible
   and documented rather than an accident of search order.

``unroll`` only affects the jnp ref twin's ``fori_loop`` (the CPU fast
path); ``block_b``/``block_n`` only affect the Pallas launch.  Both live in
one entry so a bucket is tuned once per device kind.

The search half (:func:`search_bucket` / the ``__main__`` CLI) times each
candidate with the interleaved min-of-N harness (``benchmarks/_timing``),
filters candidates whose VMEM working set cannot fit, and records the
``roofline.analyze_compiled`` cost model of the winning configuration's
compiled stage next to the measured score, so the cache documents *why*
each winner won.  Winners are merged into the committed cache with
``--write``; CI never regenerates the cache (it is a committed artifact,
like ``BENCH_*.json``).
"""

from __future__ import annotations

import argparse
import functools
import json
import os
from dataclasses import asdict, dataclass
from typing import Dict, Optional, Tuple

CACHE_PATH = os.path.join(os.path.dirname(__file__), os.pardir, "kernels",
                          "tuning_cache.json")

#: VMEM budget (bytes) a candidate's resident working set must fit in:
#: the X/y tiles plus the five f32 scratch buffers, double-buffered.
VMEM_BUDGET = 96 * 1024 * 1024 // 8


@dataclass(frozen=True)
class TileConfig:
    """One tuning decision: Pallas block shape + ref-twin unroll."""
    block_b: int
    block_n: int
    unroll: int


# Deterministic fallback: device kind -> d-bucket ceiling -> config.  The
# last row of each table (ceiling 0 == "anything larger") must always be
# present.  Chosen from the measured CPU sweep / TPU VMEM arithmetic, not
# per-shape search — good enough to be safe, never tuned-optimal.
_FALLBACK: Dict[str, Tuple[Tuple[int, TileConfig], ...]] = {
    "cpu": (
        (16, TileConfig(block_b=8, block_n=512, unroll=2)),
        (0, TileConfig(block_b=8, block_n=512, unroll=1)),
    ),
    "tpu": (
        (64, TileConfig(block_b=8, block_n=512, unroll=1)),
        (256, TileConfig(block_b=8, block_n=256, unroll=1)),
        (0, TileConfig(block_b=4, block_n=128, unroll=1)),
    ),
}
_DEFAULT_KIND = "cpu"


def _bucket_pow2(x: int, floor: int) -> int:
    x = max(int(x), floor)
    return 1 << (x - 1).bit_length()


def bucket(B: int, N: int, d: int) -> Tuple[int, int, int]:
    """Shape bucket for cache keying: next pow-2 with per-axis floors."""
    return _bucket_pow2(B, 1), _bucket_pow2(N, 8), _bucket_pow2(d, 2)


def cache_key(device_kind: str, B: int, N: int, d: int) -> str:
    bB, bN, bd = bucket(B, N, d)
    return f"{device_kind}|B{bB}_N{bN}_d{bd}"


def _normalize_kind(device_kind: str) -> str:
    """Map a jax ``device_kind`` string to a fallback-table family."""
    kind = device_kind.lower()
    if "tpu" in kind:
        return "tpu"
    if kind in _FALLBACK:
        return kind
    return _DEFAULT_KIND


@functools.lru_cache(maxsize=1)
def _load_cache(path: str = CACHE_PATH) -> Dict[str, dict]:
    try:
        with open(path) as fh:
            data = json.load(fh)
    except (OSError, ValueError):
        return {}
    return data.get("entries", {}) if isinstance(data, dict) else {}


def fallback_tile(device_kind: str, d: int) -> TileConfig:
    """The deterministic no-cache answer (also the final lookup stage)."""
    table = _FALLBACK[_normalize_kind(device_kind)]
    for ceiling, cfg in table:
        if ceiling == 0 or d <= ceiling:
            return cfg
    return table[-1][1]


@functools.lru_cache(maxsize=256)
def lookup_tile(device_kind: str, B: int, N: int, d: int) -> TileConfig:
    """Resolve ``(block_b, block_n, unroll)`` for a solver launch shape.

    Committed-cache hit first (exact device kind, exact shape bucket),
    deterministic fallback otherwise.  Never raises on a malformed cache —
    a bad entry is a miss, not a crash (the fallback rule is the contract).
    """
    entry = _load_cache().get(cache_key(device_kind, B, N, d))
    if isinstance(entry, dict):
        try:
            return TileConfig(block_b=int(entry["block_b"]),
                              block_n=int(entry["block_n"]),
                              unroll=int(entry["unroll"]))
        except (KeyError, TypeError, ValueError):
            pass
    return fallback_tile(device_kind, d)


# ----------------------------------------------------------------------
# Search half — imports jax/benchmarks lazily so lookup stays dep-free.
# ----------------------------------------------------------------------

#: candidate axes; the cross-product is pruned by the VMEM fit check
CANDIDATE_BLOCK_N = (128, 256, 512, 1024)
CANDIDATE_BLOCK_B = (1, 4, 8, 16)
CANDIDATE_UNROLL = (1, 2, 4)


def vmem_bytes(block_b: int, block_n: int, d: int) -> int:
    """Resident f32 working set of one grid step (double-buffered tiles)."""
    tiles = block_b * block_n * (d + 1) * 2          # X + y, double-buffered
    scratch = block_b * (2 * d + 3)                  # w, g, b, gb, mm
    return 4 * (tiles + scratch)


def _candidates(B: int, N: int, d: int):
    for bn in CANDIDATE_BLOCK_N:
        if bn > _bucket_pow2(N, 8):
            continue
        for bb in CANDIDATE_BLOCK_B:
            if bb > _bucket_pow2(B, 1):
                continue
            if vmem_bytes(bb, bn, d) > VMEM_BUDGET:
                continue
            for u in CANDIDATE_UNROLL:
                yield TileConfig(block_b=bb, block_n=bn, unroll=u)


def search_bucket(B: int, N: int, d: int, *, nsteps: int = 200,
                  repeats: int = 5, seed: int = 0) -> dict:
    """Tune one shape bucket on the *current* backend.

    Off-TPU the measured path is the jnp ref twin, so the search axis that
    matters is ``unroll`` (block shapes are carried along and scored by the
    VMEM model only); on TPU the Pallas launch itself is timed, so all
    three axes are live.  Returns the winning cache entry (not yet merged).
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks import _timing
    from repro.analysis import roofline
    from repro.kernels import ops, ref

    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.standard_normal((B, N, d)), jnp.float32)
    y = jnp.asarray(rng.choice([-1.0, 1.0], size=(B, N)), jnp.float32)
    nv = jnp.full((B,), float(N), jnp.float32)
    w = jnp.zeros((B, d), jnp.float32)
    b = jnp.zeros((B,), jnp.float32)
    lam = jnp.full((B,), 1e-3, jnp.float32)
    found = jnp.zeros((B,), bool)
    wb = jnp.zeros((B, d), jnp.float32)
    bb0 = jnp.zeros((B,), jnp.float32)
    on_tpu = jax.default_backend() == "tpu"

    series = {}
    cfgs = list(_candidates(B, N, d))
    for cfg in cfgs:
        def run(cfg=cfg):
            out = ops.pegasos_stage(
                X, y, nv, w, b, lam, found, wb, bb0, nsteps=nsteps,
                use_pallas=on_tpu, block_b=cfg.block_b,
                block_n=cfg.block_n, unroll=cfg.unroll)
            return jax.block_until_ready(out[0])
        run()                                        # compile outside timing
        series[f"b{cfg.block_b}_n{cfg.block_n}_u{cfg.unroll}"] = run
    _, times = _timing.interleaved(series, repeats=repeats)
    scored = sorted(
        (( _timing.tmin(times, f"b{c.block_b}_n{c.block_n}_u{c.unroll}"), c)
         for c in cfgs), key=lambda t: t[0])
    best_s, best = scored[0]

    # cost model of the winner, recorded alongside the measurement
    fn = jax.jit(functools.partial(
        ref.pegasos_stage_batch_ref, nsteps=nsteps, unroll=best.unroll))
    compiled = fn.lower(X, y, nv, w, b, lam, found, wb, bb0).compile()
    report = roofline.analyze_compiled(
        f"pegasos_B{B}_N{N}_d{d}", compiled, chips=1)
    model_s = max(report.compute_s, report.memory_s, report.collective_s)
    intensity = report.flops / max(report.bytes_accessed, 1.0)
    return {
        "key": cache_key(jax.devices()[0].device_kind, B, N, d),
        "entry": {
            **asdict(best),
            "score_us": best_s * 1e6,
            "nsteps": nsteps,
            "measured_path": "pallas" if on_tpu else "ref",
            "roofline": {"dominant": report.dominant,
                         "intensity": round(intensity, 3),
                         "model_us": model_s * 1e6},
        },
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--shapes", nargs="+", default=["16x512x2", "16x512x16",
                                                    "16x512x64"],
                    help="BxNxd launch shapes to tune (one bucket each)")
    ap.add_argument("--nsteps", type=int, default=200)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--write", action="store_true",
                    help="merge winners into the committed tuning cache")
    args = ap.parse_args(argv)

    entries: Dict[str, dict] = dict(_load_cache())
    for spec in args.shapes:
        B, N, d = (int(t) for t in spec.split("x"))
        won = search_bucket(B, N, d, nsteps=args.nsteps,
                            repeats=args.repeats)
        print(f"{won['key']}: {won['entry']}")
        entries[won["key"]] = won["entry"]
    if args.write:
        payload = {"format": 1, "entries": dict(sorted(entries.items()))}
        with open(CACHE_PATH, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
            fh.write("\n")
        _load_cache.cache_clear()
        lookup_tile.cache_clear()
        print(f"wrote {CACHE_PATH} ({len(entries)} entries)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
