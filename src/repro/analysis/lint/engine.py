"""Lint driver: walk paths, parse, run every registered rule, apply
inline disables, and enforce the mandatory-reason contract on them."""

from __future__ import annotations

import ast
import dataclasses
import os
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from .config import LintConfig
from .context import FileContext, Project
from .registry import META_RULE, Finding, all_rules


@dataclasses.dataclass
class LintResult:
    findings: List[Finding]
    suppressed: List[Finding]           # disabled with a valid reason
    files: List[str]                    # files actually linted
    skipped: List[Tuple[str, str]]      # (path, manifest reason)

    def keys(self, contexts: Dict[str, "FileContext"]) -> List[Tuple[str, str, str]]:
        out = []
        for f in self.findings:
            fc = contexts.get(f.path)
            line_text = fc.line_text(f.line) if fc is not None else ""
            out.append(f.key(line_text))
        return out


def collect_files(
    paths: Sequence[str], config: LintConfig
) -> Tuple[List[str], List[Tuple[str, str]]]:
    """Expand files/dirs into .py files, honoring the exclusion manifest."""
    files: List[str] = []
    skipped: List[Tuple[str, str]] = []
    seen = set()

    def add(p: str) -> None:
        ap = os.path.abspath(p)
        if ap in seen:
            return
        seen.add(ap)
        ex = config.excluded(p)
        if ex is not None:
            skipped.append((p, ex.reason))
            return
        files.append(p)

    for path in paths:
        if os.path.isfile(path):
            add(path)
        elif os.path.isdir(path):
            for dirpath, dirnames, filenames in os.walk(path):
                dirnames[:] = sorted(
                    d for d in dirnames
                    if d not in {"__pycache__", ".git", ".pytest_cache"})
                for fn in sorted(filenames):
                    if fn.endswith(".py"):
                        add(os.path.join(dirpath, fn))
    return files, skipped


def lint_tree(
    paths: Sequence[str], config: LintConfig
) -> Tuple[LintResult, Dict[str, FileContext]]:
    files, skipped = collect_files(paths, config)
    contexts: Dict[str, FileContext] = {}
    findings: List[Finding] = []
    for path in files:
        try:
            with open(path, "r", encoding="utf-8") as fh:
                src = fh.read()
        except (OSError, UnicodeDecodeError) as e:
            findings.append(Finding(META_RULE, path, 1, 0,
                                    f"unreadable file ({e})"))
            continue
        try:
            tree = ast.parse(src)
        except SyntaxError as e:
            findings.append(Finding(
                META_RULE, path, e.lineno or 1, (e.offset or 1) - 1,
                f"syntax error: {e.msg}"))
            continue
        contexts[path] = FileContext(path, src, tree)

    project = Project(contexts.values(), config=config)
    for path, fc in contexts.items():
        for rule in all_rules():
            assert rule.check is not None
            findings.extend(rule.check(fc, project))
    findings = _dedupe(findings)

    kept, suppressed = _apply_disables(findings, contexts)
    kept.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return (
        LintResult(findings=kept, suppressed=suppressed, files=files,
                   skipped=skipped),
        contexts,
    )


def _dedupe(findings: List[Finding]) -> List[Finding]:
    seen = set()
    out = []
    for f in findings:
        key = (f.rule, f.path, f.line, f.col, f.message)
        if key not in seen:
            seen.add(key)
            out.append(f)
    return out


def _apply_disables(
    findings: List[Finding], contexts: Dict[str, FileContext]
) -> Tuple[List[Finding], List[Finding]]:
    kept: List[Finding] = []
    suppressed: List[Finding] = []
    for f in findings:
        fc = contexts.get(f.path)
        if fc is None or f.rule == META_RULE:
            kept.append(f)
            continue
        d = fc.disable_for(f.line)
        if d is None or f.rule not in d.rules:
            kept.append(f)
        elif not d.reason:
            # Disabled, but the mandatory reason string is missing: the
            # suppression is void AND the malformed comment is itself a
            # finding.
            kept.append(f)
        else:
            suppressed.append(f)
    # Every disable comment must carry a reason, used or not.
    for path, fc in contexts.items():
        for d in fc.disables.values():
            if not d.reason:
                kept.append(Finding(
                    META_RULE, path, d.line, 0,
                    "disable comment without a reason — write "
                    "'# lint: disable=R00x (why this is a false positive)'"))
    kept.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return kept, suppressed


def lint_paths(paths: Sequence[str], config: LintConfig) -> List[Finding]:
    """Convenience wrapper used by tests: findings only."""
    result, _ = lint_tree(paths, config)
    return result.findings
