"""R002 unpinned-dispatch-key hazard — the static twin of
tests/test_recompile.py and the session pool's pinned-key determinism.

The hot loops compile against a pinned ``(n_pad, width, warm)`` key set;
steady-state recompiles are gated == 0, and the pool's bit-exactness
across admission timing depends on ONE key.  A Python-varying value —
a loop variable, a raw ``len()``/``.shape`` read, an f-string — flowing
into a static/shape-determining kwarg of a jitted dispatch inside a turn
loop mints a fresh compile key every iteration.

A value is blessed when it passes through a configured quantizer
(``_round_up`` — the width-growth lattice) or is a comparison (bounded
bool, e.g. ``first_turn=(t == 0)``).  Only provable hazards fire: a kwarg
whose provenance is unknown is silent.
"""

from __future__ import annotations

import ast
from typing import List, Optional, Set

from ..context import FileContext, Project
from ..registry import Finding, Rule, register
from . import _shared


def _loop_vars(loop: ast.AST) -> Set[str]:
    """Names that vary per iteration: For targets plus names aug-assigned
    in the body (the ``t += 1`` of a while-loop turn counter)."""
    out: Set[str] = set()
    if isinstance(loop, (ast.For, ast.AsyncFor)):
        for n in ast.walk(loop.target):
            if isinstance(n, ast.Name):
                out.add(n.id)
    for node in ast.walk(loop):
        if isinstance(node, ast.AugAssign) and isinstance(node.target, ast.Name):
            out.add(node.target.id)
    return out


def _hazard(expr: ast.AST, loop_vars: Set[str], quantizers: Set[str]) -> Optional[str]:
    if _shared.contains_call_to(expr, quantizers):
        return None                      # quantized onto the key lattice
    if isinstance(expr, ast.Compare):
        return None                      # bounded bool (first_turn=(t == 0))
    for node in ast.walk(expr):
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
            if node.id in loop_vars:
                return f"loop-varying value '{node.id}'"
        elif isinstance(node, ast.Call):
            seg = _shared.last_segment(node.func)
            if seg == "len":
                return "raw len() read"
            if seg in {"str", "repr", "format"}:
                return "python string"
        elif isinstance(node, ast.Attribute) and node.attr == "shape":
            return "raw .shape read"
        elif isinstance(node, ast.JoinedStr):
            return "f-string"
    return None


@register(Rule(
    id="R002",
    name="unpinned-dispatch-key",
    gate="tests/test_recompile.py + pinned-key determinism "
         "(DESIGN.md §session pool)",
    summary="Python-varying values must not flow into static/"
            "shape-determining kwargs of jitted dispatches inside turn loops",
))
def check(fc: FileContext, project: Project) -> List[Finding]:
    cfg = project.config
    dispatch_pats = _shared.compile_patterns(cfg.dispatch_patterns)
    quantizers = set(cfg.quantizers)
    base_static = set(cfg.static_kwargs)
    findings: List[Finding] = []
    seen = set()

    for _, fn in _shared.iter_functions(fc.tree):
        for loop in ast.walk(fn):
            if not isinstance(loop, (ast.For, ast.AsyncFor, ast.While)):
                continue
            lvars = _loop_vars(loop)
            if not lvars:
                continue
            for call in ast.walk(loop):
                if not isinstance(call, ast.Call):
                    continue
                seg = _shared.last_segment(call.func)
                if seg is None:
                    continue
                binding = fc.jit_bindings.get(seg)
                is_dispatch = binding is not None or _shared.matches_any(
                    seg, dispatch_pats)
                if not is_dispatch:
                    continue
                statics = set(base_static)
                if binding is not None and binding.static_resolved:
                    statics |= binding.static_names
                for kw in call.keywords:
                    if kw.arg not in statics:
                        continue
                    why = _hazard(kw.value, lvars, quantizers)
                    if why is None:
                        continue
                    key = (kw.value.lineno, kw.value.col_offset, kw.arg)
                    if key in seen:
                        continue
                    seen.add(key)
                    findings.append(Finding(
                        "R002", fc.path, kw.value.lineno,
                        kw.value.col_offset,
                        f"{why} flows into static kwarg '{kw.arg}' of "
                        f"dispatch '{seg}' inside a turn loop — this mints "
                        "a new compile key every iteration; pin it or pass "
                        "it through the width quantizer "
                        "[gate: tests/test_recompile.py]"))
    return findings
