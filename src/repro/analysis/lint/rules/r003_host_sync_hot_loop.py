"""R003 host-sync-in-hot-loop.

The hot loops overlap dispatch with host work through exactly one
blessed transfer: the packed ``(3, B)`` i32 host view (``host_view`` /
``_view_median`` / ``_view_maxmarg``).  Any other device→host sync inside
a turn loop — ``.item()``, ``.tolist()``, ``.block_until_ready()``,
``np.asarray`` on device values, ``jax.device_get``, ``float()``/``int()``
on device leaves — serializes the pipeline and silently destroys the
double-buffered overlap PR 6 measured.

Scope is deliberately tight: loop bodies of the configured hot-loop
functions (``run_hot``, ``step_pool``) only.  Values derived from a
blessed view call are host data and may be inspected freely.
"""

from __future__ import annotations

import ast
from typing import List, Set

from ..context import FileContext, Project, assigned_names
from ..registry import Finding, Rule, register
from . import _shared

_SYNC_ATTRS = {"item", "tolist", "block_until_ready"}
_SYNC_CALLS = {"numpy.asarray", "numpy.array", "jax.device_get"}
_CAST_CALLS = {"float", "int"}


def _root_name(node: ast.AST) -> str:
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    if isinstance(node, ast.Name):
        return node.id
    return ""


class _HotFn:
    def __init__(self, fc: FileContext, fn: ast.FunctionDef, cfg):
        self.fc = fc
        self.fn = fn
        self.view_pat = cfg.blessed_view_pattern
        self.device_roots = set(cfg.device_roots)
        self.blessed = self._collect_blessed()

    _HOST_BUILTINS = {
        "int", "float", "bool", "min", "max", "len", "abs", "sum", "any",
        "all", "range", "sorted", "enumerate", "zip", "list", "tuple",
    }

    def _is_view_call(self, call: ast.Call) -> bool:
        seg = _shared.last_segment(call.func)
        return seg is not None and self.view_pat in seg

    def _expr_blessed(self, expr: ast.AST) -> bool:
        """Structurally host data: pulled through a blessed view call, or
        a host-side (numpy/builtin) combination of blessed values.  A
        dispatch or any other unknown call BLOCKS propagation — its result
        is a fresh device value."""
        if isinstance(expr, ast.Name):
            return expr.id in self.blessed
        if isinstance(expr, (ast.Attribute, ast.Subscript, ast.Starred)):
            return self._expr_blessed(expr.value)
        if isinstance(expr, ast.Call):
            if self._is_view_call(expr):
                return True
            args = list(expr.args) + [kw.value for kw in expr.keywords]
            canon = self.fc.call_canonical(expr) or ""
            if canon.startswith("numpy."):
                return any(self._expr_blessed(a) for a in args)
            if (isinstance(expr.func, ast.Name)
                    and expr.func.id in self._HOST_BUILTINS):
                return any(self._expr_blessed(a) for a in args)
            if isinstance(expr.func, ast.Attribute):
                # host method on blessed data: done.all(), vh[0].max()
                return self._expr_blessed(expr.func.value)
            return False
        if isinstance(expr, (ast.Tuple, ast.List, ast.Set)):
            return any(self._expr_blessed(e) for e in expr.elts)
        if isinstance(expr, ast.BinOp):
            return self._expr_blessed(expr.left) or self._expr_blessed(expr.right)
        if isinstance(expr, ast.BoolOp):
            return any(self._expr_blessed(v) for v in expr.values)
        if isinstance(expr, ast.UnaryOp):
            return self._expr_blessed(expr.operand)
        if isinstance(expr, ast.Compare):
            return (self._expr_blessed(expr.left)
                    or any(self._expr_blessed(c) for c in expr.comparators))
        if isinstance(expr, ast.IfExp):
            return (self._expr_blessed(expr.body)
                    and self._expr_blessed(expr.orelse))
        return False

    def _collect_blessed(self) -> Set[str]:
        """Names holding host data pulled through the blessed view, to a
        fixpoint so chains (``vh = host_view(...)``, ``view =
        np.asarray(vh)``, ``done, _, fills = view``) stay blessed."""
        self.blessed: Set[str] = set()
        for _ in range(6):
            grew = False
            for stmt in _shared.walk_pruned(self.fn):
                if not isinstance(stmt, ast.Assign):
                    continue
                if not self._expr_blessed(stmt.value):
                    continue
                for t in stmt.targets:
                    for name in assigned_names(t):
                        if name not in self.blessed:
                            self.blessed.add(name)
                            grew = True
            if not grew:
                break
        return self.blessed

    def scan(self) -> List[Finding]:
        findings: List[Finding] = []
        seen = set()

        def flag(node: ast.AST, what: str) -> None:
            key = (node.lineno, node.col_offset, what)
            if key in seen:
                return
            seen.add(key)
            findings.append(Finding(
                "R003", self.fc.path, node.lineno, node.col_offset,
                f"{what} inside the hot turn loop of "
                f"'{self.fn.name}' — only the packed (3,B) host view may "
                "cross to host per turn; route this through the view or "
                "hoist it out of the loop [gate: hot-path-parity + "
                "double-buffered overlap, DESIGN.md §sharded hot loop]"))

        for loop in [n for n in _shared.walk_pruned(self.fn)
                     if isinstance(n, (ast.For, ast.AsyncFor, ast.While))]:
            for node in _shared.walk_pruned(loop):
                if not isinstance(node, ast.Call):
                    continue
                # method-style syncs: x.item(), x.tolist(), ...
                if (isinstance(node.func, ast.Attribute)
                        and node.func.attr in _SYNC_ATTRS):
                    root = _root_name(node.func.value)
                    if root not in self.blessed:
                        flag(node, f"device sync '.{node.func.attr}()'")
                    continue
                canon = self.fc.call_canonical(node)
                seg = _shared.last_segment(node.func)
                if canon in _SYNC_CALLS:
                    arg = node.args[0] if node.args else None
                    if arg is not None and not self._expr_blessed(arg):
                        flag(node, f"device transfer '{seg}(...)'")
                    continue
                if (isinstance(node.func, ast.Name)
                        and node.func.id in _CAST_CALLS and node.args):
                    arg = node.args[0]
                    if self._expr_blessed(arg):
                        continue
                    root = _root_name(arg)
                    if root in self.device_roots:
                        flag(node, f"host cast '{node.func.id}()' on device "
                                   f"value '{root}'")
        return findings


@register(Rule(
    id="R003",
    name="host-sync-in-hot-loop",
    gate="hot-path overlap (DESIGN.md §sharded hot loop; "
         "benchmarks/engine_sweep.py double-buffered host loop)",
    summary=".item()/.tolist()/np.asarray/.block_until_ready on device "
            "values inside run_hot/pool turn loops, outside the blessed "
            "(3,B) view transfer",
))
def check(fc: FileContext, project: Project) -> List[Finding]:
    cfg = project.config
    hot_names = set(cfg.hot_loop_functions)
    findings: List[Finding] = []
    for _, fn in _shared.iter_functions(fc.tree):
        if fn.name in hot_names:
            findings.extend(_HotFn(fc, fn, cfg).scan())
    return findings
