"""R005 tracer-unsafe control flow.

Python ``if``/``while``/``assert`` on a traced value either crashes at
trace time (ConcretizationTypeError) or — worse, under ``jit`` with
weak-typed inputs — silently bakes one branch into the compiled
dispatch, breaking the every-node-same-transcript property the paper's
communication bounds rest on.  Branching belongs in ``lax.cond`` /
``jnp.where``; Python control flow may only touch *static* values.

Traced contexts are resolved within the module: defs decorated with
``jax.jit`` (or ``functools.partial(jax.jit, ...)``), names wrapped via
``X = jax.jit(fn, static_argnames=...)``, and callbacks handed to
``lax.while_loop``/``scan``/``cond``/``shard_map``/``vmap``.  Static
params (resolved ``static_argnames``/``argnums``) are exempt; functions
whose statics cannot be resolved are skipped rather than guessed at.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from ..context import FileContext, Project, assigned_names
from ..registry import Finding, Rule, register
from . import _shared

_STATIC_ATTRS = {"shape", "ndim", "dtype", "size"}
_STATIC_CALLS = {"len", "isinstance", "type", "hasattr", "getattr", "callable"}

# funcs whose Nth positional args are traced callbacks
_CALLBACK_SLOTS = {
    "while_loop": (0, 1),
    "fori_loop": (2,),
    "scan": (0,),
    "cond": (1, 2),
    "switch": (1,),
    "shard_map": (0,),
    "vmap": (0,),
    "pmap": (0,),
    "jit": (0,),
    "grad": (0,),
    "value_and_grad": (0,),
}


def _tainted(expr: ast.AST, tainted: Set[str], fc: FileContext) -> bool:
    if isinstance(expr, ast.Name):
        return expr.id in tainted
    if isinstance(expr, ast.Attribute):
        if expr.attr in _STATIC_ATTRS:
            return False
        return _tainted(expr.value, tainted, fc)
    if isinstance(expr, ast.Subscript):
        return _tainted(expr.value, tainted, fc)
    if isinstance(expr, ast.Call):
        seg = _shared.last_segment(expr.func)
        if seg in _STATIC_CALLS:
            return False
        canon = fc.call_canonical(expr) or ""
        if canon.startswith(("jax.numpy.", "jax.lax.", "jax.random.", "jax.nn.")):
            return True
        if isinstance(expr.func, ast.Attribute) and _tainted(
                expr.func.value, tainted, fc):
            return True                  # method on a traced value: .sum()
        args = list(expr.args) + [kw.value for kw in expr.keywords]
        return any(_tainted(a, tainted, fc) for a in args)
    if isinstance(expr, ast.Compare):
        # `x is None` / `x is not None` is a static structural check even
        # on traced args — tracers are never None.
        if all(isinstance(op, (ast.Is, ast.IsNot)) for op in expr.ops):
            return False
        return (_tainted(expr.left, tainted, fc)
                or any(_tainted(c, tainted, fc) for c in expr.comparators))
    if isinstance(expr, ast.BoolOp):
        return any(_tainted(v, tainted, fc) for v in expr.values)
    if isinstance(expr, ast.BinOp):
        return (_tainted(expr.left, tainted, fc)
                or _tainted(expr.right, tainted, fc))
    if isinstance(expr, ast.UnaryOp):
        return _tainted(expr.operand, tainted, fc)
    if isinstance(expr, ast.IfExp):
        return (_tainted(expr.test, tainted, fc)
                or _tainted(expr.body, tainted, fc)
                or _tainted(expr.orelse, tainted, fc))
    return False


def _scan_traced_fn(
    fc: FileContext, fn: ast.FunctionDef, statics: Set[str]
) -> List[Finding]:
    params = set(fc.param_names(fn))
    tainted: Set[str] = params - statics
    # grow the taint set to a fixpoint over local assignments
    for _ in range(4):
        grew = False
        for node in _shared.walk_pruned(fn):
            if not isinstance(node, ast.Assign):
                continue
            if _tainted(node.value, tainted, fc):
                for t in node.targets:
                    for name in assigned_names(t):
                        if name not in tainted:
                            tainted.add(name)
                            grew = True
        if not grew:
            break

    findings: List[Finding] = []
    for node in _shared.walk_pruned(fn):
        test = None
        kind = None
        if isinstance(node, ast.If):
            test, kind = node.test, "if"
        elif isinstance(node, ast.While):
            test, kind = node.test, "while"
        elif isinstance(node, ast.Assert):
            test, kind = node.test, "assert"
        if test is None or not _tainted(test, tainted, fc):
            continue
        findings.append(Finding(
            "R005", fc.path, node.lineno, node.col_offset,
            f"python '{kind}' on a traced value inside '{fn.name}' "
            "(jitted/traced context) — this concretizes a tracer or bakes "
            "one branch into the compiled dispatch; use lax.cond/jnp.where "
            "[gate: every-node-same-transcript determinism]"))
    return findings


@register(Rule(
    id="R005",
    name="tracer-unsafe-control-flow",
    gate="trace-time soundness of every jitted dispatch "
         "(tests/test_engine.py parity gates)",
    summary="python if/while/assert on values computed in a traced "
            "context (non-static params, jnp/lax results)",
))
def check(fc: FileContext, project: Project) -> List[Finding]:
    findings: List[Finding] = []
    traced = dict(fc.traced_functions())
    # callbacks passed positionally to lax control flow / shard_map / vmap
    for node in ast.walk(fc.tree):
        if not isinstance(node, ast.Call):
            continue
        seg = _shared.last_segment(node.func)
        slots = _CALLBACK_SLOTS.get(seg or "")
        if slots is None:
            continue
        canon = fc.call_canonical(node) or ""
        if not canon.startswith(("jax.", "functools.")) and "shard_map" not in canon:
            continue
        for i in slots:
            if i < len(node.args) and isinstance(node.args[i], ast.Name):
                name = node.args[i].id
                if name in fc.functions:
                    traced.setdefault(name, set())
    for name, statics in traced.items():
        if statics is None:
            continue                      # unresolvable statics: skip
        fn = fc.functions.get(name)
        if fn is None:
            continue
        findings.extend(_scan_traced_fn(fc, fn, statics))
    return findings
