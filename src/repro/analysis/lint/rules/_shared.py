"""Execution-order walking and small expression predicates shared by the
dataflow rules (R001/R004) and the scoped scanners (R002/R003)."""

from __future__ import annotations

import ast
import re
from typing import Callable, Dict, Iterable, List, Optional, Set


class StmtRule:
    """Protocol for :func:`walk_body`: a rule supplies leaf-statement and
    expression handlers plus branch-state copy/merge.

    ``walk_body`` approximates execution order: loop bodies run twice (so
    loop-carried hazards surface on the second pass), ``if``/``try``
    branches run on copies and merge conservatively (a hazard survives the
    merge only if every branch agrees — under-approximate, zero false
    positives by construction).
    """

    def on_stmt(self, stmt: ast.stmt, state: dict) -> None:  # leaf
        raise NotImplementedError

    def on_expr(self, expr: ast.AST, state: dict) -> None:   # header expr
        raise NotImplementedError

    def on_bind(self, target: ast.AST, state: dict) -> None:
        raise NotImplementedError

    def copy(self, state: dict) -> dict:
        raise NotImplementedError

    def merge(self, state: dict, branches: List[dict]) -> None:
        raise NotImplementedError


def walk_body(body: Iterable[ast.stmt], state: dict, rule: StmtRule) -> None:
    for stmt in body:
        if isinstance(stmt, ast.If):
            rule.on_expr(stmt.test, state)
            b1 = rule.copy(state)
            b2 = rule.copy(state)
            walk_body(stmt.body, b1, rule)
            walk_body(stmt.orelse, b2, rule)
            rule.merge(state, [b1, b2])
        elif isinstance(stmt, (ast.For, ast.AsyncFor)):
            rule.on_expr(stmt.iter, state)
            rule.on_bind(stmt.target, state)
            for _ in range(2):
                walk_body(stmt.body, state, rule)
            walk_body(stmt.orelse, state, rule)
        elif isinstance(stmt, ast.While):
            for _ in range(2):
                rule.on_expr(stmt.test, state)
                walk_body(stmt.body, state, rule)
            walk_body(stmt.orelse, state, rule)
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                rule.on_expr(item.context_expr, state)
                if item.optional_vars is not None:
                    rule.on_bind(item.optional_vars, state)
            walk_body(stmt.body, state, rule)
        elif isinstance(stmt, ast.Try):
            walk_body(stmt.body, state, rule)
            for h in stmt.handlers:
                walk_body(h.body, rule.copy(state), rule)
            walk_body(stmt.orelse, state, rule)
            walk_body(stmt.finalbody, state, rule)
        elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
            # Nested defs are separate scopes; rules that care about them
            # visit them explicitly.
            continue
        else:
            rule.on_stmt(stmt, state)


def load_names(node: ast.AST) -> List[ast.Name]:
    """Name nodes read (Load ctx) anywhere under ``node``."""
    return [
        n for n in ast.walk(node)
        if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)
    ]


def last_segment(func: ast.AST) -> Optional[str]:
    """Trailing identifier of a call target: ``median._hot_turn`` → ``_hot_turn``."""
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return None


def compile_patterns(patterns: Iterable[str]) -> List[re.Pattern]:
    return [re.compile(p) for p in patterns]


def matches_any(name: Optional[str], patterns: List[re.Pattern]) -> bool:
    return name is not None and any(p.search(name) for p in patterns)


def contains_call_to(expr: ast.AST, names: Set[str]) -> bool:
    for node in ast.walk(expr):
        if isinstance(node, ast.Call):
            seg = last_segment(node.func)
            if seg in names:
                return True
    return False


def walk_pruned(node: ast.AST, prune=(ast.FunctionDef, ast.AsyncFunctionDef,
                                      ast.Lambda)):
    """ast.walk that does not descend into nested function scopes."""
    stack = list(ast.iter_child_nodes(node))
    while stack:
        n = stack.pop()
        yield n
        if not isinstance(n, prune):
            stack.extend(ast.iter_child_nodes(n))


def iter_functions(tree: ast.AST):
    """(qualname, FunctionDef) for every def, outermost first."""
    def visit(node: ast.AST, prefix: str):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = f"{prefix}{child.name}"
                yield qual, child
                yield from visit(child, qual + ".")
            elif isinstance(child, ast.ClassDef):
                yield from visit(child, f"{prefix}{child.name}.")
            else:
                yield from visit(child, prefix)

    yield from visit(tree, "")
