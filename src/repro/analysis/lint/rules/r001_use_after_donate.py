"""R001 use-after-donate — the static twin of tests/test_hotloop_donate.py.

A donated buffer is single-consumer: once a name is passed in a donated
position of a dispatch jitted with ``donate_argnames``/``donate_argnums``,
XLA may alias its memory for the outputs, and any later read of that name
observes garbage.  The runtime gate catches this only on exercised paths;
here we track it as dataflow over the function body.

Donating callees are resolved two ways:

* precisely, from ``X = jax.jit(fn, donate_arg...)`` bindings in the same
  module (including one alias hop, e.g. ``step_d = _step_jit_don if
  donate else _step_jit`` — donating if ANY reaching binding donates);
* by configured name pattern (``donating_patterns``) for factory-made
  dispatches whose jit call is out of view (``full_j``/``sub_j`` from
  ``_sharded_dispatches``); there the donated argument is any bare name
  argument listed in ``donated_arg_names``.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from ..context import FileContext, Project, assigned_names
from ..registry import Finding, Rule, register
from . import _shared

# state maps name -> None (live) | (donor_name, line) (donated, unread)


class _Walker(_shared.StmtRule):
    def __init__(self, fc: FileContext, cfg):
        self.fc = fc
        self.cfg = cfg
        self.donating_pats = _shared.compile_patterns(cfg.donating_patterns)
        self.donated_args = set(cfg.donated_arg_names)
        self.findings: List[Finding] = []
        self._seen: Set[Tuple[int, int, str]] = set()

    # -- donation resolution --------------------------------------------

    def _donated_in_call(self, call: ast.Call) -> List[str]:
        seg = _shared.last_segment(call.func)
        if seg is None:
            return []
        out: List[str] = []
        binding = self.fc.jit_bindings.get(seg)
        if binding is not None and (binding.donated_nums or binding.donated_params):
            for idx in binding.donated_nums:
                if idx < len(call.args) and isinstance(call.args[idx], ast.Name):
                    out.append(call.args[idx].id)
            for kw in call.keywords:
                if kw.arg in binding.donated_params and isinstance(kw.value, ast.Name):
                    out.append(kw.value.id)
            return out
        if _shared.matches_any(seg, self.donating_pats):
            for a in call.args:
                if isinstance(a, ast.Name) and a.id in self.donated_args:
                    out.append(a.id)
            for kw in call.keywords:
                if (kw.arg in self.donated_args
                        and isinstance(kw.value, ast.Name)):
                    out.append(kw.value.id)
        return out

    # -- events ----------------------------------------------------------

    def _check_reads(self, node: ast.AST, state: dict) -> None:
        for name in _shared.load_names(node):
            dead = state.get(name.id)
            if dead is not None:
                key = (name.lineno, name.col_offset, name.id)
                if key in self._seen:
                    continue
                self._seen.add(key)
                donor, line = dead
                self.findings.append(Finding(
                    "R001", self.fc.path, name.lineno, name.col_offset,
                    f"'{name.id}' is read after being donated to "
                    f"'{donor}' (line {line}); donated buffers are "
                    "single-consumer — rebind the name first "
                    "[gate: tests/test_hotloop_donate.py]"))

    def _apply_donations(self, node: ast.AST, state: dict) -> None:
        for call in ast.walk(node):
            if isinstance(call, ast.Call):
                for name in self._donated_in_call(call):
                    state[name] = (_shared.last_segment(call.func), call.lineno)

    def on_expr(self, expr: ast.AST, state: dict) -> None:
        self._check_reads(expr, state)
        self._apply_donations(expr, state)

    def on_bind(self, target: ast.AST, state: dict) -> None:
        for name in assigned_names(target):
            state[name] = None

    def on_stmt(self, stmt: ast.stmt, state: dict) -> None:
        if isinstance(stmt, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            value = stmt.value
            if value is not None:
                self._check_reads(value, state)
                self._apply_donations(value, state)
            if isinstance(stmt, ast.Assign):
                for t in stmt.targets:
                    self.on_bind(t, state)
            elif stmt.target is not None:
                if isinstance(stmt, ast.AugAssign):
                    self._check_reads(stmt.target, state)
                self.on_bind(stmt.target, state)
        else:
            self._check_reads(stmt, state)
            self._apply_donations(stmt, state)

    def copy(self, state: dict) -> dict:
        return dict(state)

    def merge(self, state: dict, branches: List[dict]) -> None:
        # A name stays donated only if every branch left it donated —
        # under-approximate so exclusive branches never cross-talk.
        names = set(state)
        for b in branches:
            names |= set(b)
        for n in names:
            marks = [b.get(n) for b in branches]
            if all(m is not None for m in marks):
                state[n] = marks[0]
            else:
                state[n] = None


@register(Rule(
    id="R001",
    name="use-after-donate",
    gate="tests/test_hotloop_donate.py",
    summary="a name passed in a donated position of a jitted dispatch must "
            "not be read again before rebinding",
))
def check(fc: FileContext, project: Project) -> List[Finding]:
    cfg = project.config
    findings: List[Finding] = []
    for qual, fn in _shared.iter_functions(fc.tree):
        walker = _Walker(fc, cfg)
        _shared.walk_body(fn.body, {}, walker)
        findings.extend(walker.findings)
    return findings
