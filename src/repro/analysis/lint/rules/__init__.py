"""Rule modules self-register on import; one module per rule so the
registry-completeness gate can map rule → module → fixtures 1:1."""

from . import r001_use_after_donate  # noqa: F401
from . import r002_unpinned_dispatch_key  # noqa: F401
from . import r003_host_sync_hot_loop  # noqa: F401
from . import r004_prng_key_reuse  # noqa: F401
from . import r005_tracer_control_flow  # noqa: F401
from . import r006_pallas_hygiene  # noqa: F401
