"""R004 PRNG-key reuse — the static twin of FaultSchedule statelessness.

The engine's reproducibility story (stateless splitmix64 fault draws,
seeded per-instance sampling, the reservoir chi-square gate) assumes
functional PRNG discipline: a key is consumed by exactly one
``jax.random.*`` sampling call; further randomness comes from
``split``/``fold_in`` derivatives.  Consuming a key twice silently
correlates draws that every node must instead agree are independent —
the distributed transcripts stay identical, but the statistics they
certify are wrong.

Events per key binding, in execution order (loops walked twice so a
consume-in-loop of a key bound outside the loop surfaces):

* **consume** — key passed as the first argument (or ``key=``) to a
  sampling ``jax.random.*`` call;
* **derive** — key passed to ``split``/``fold_in`` (allowed repeatedly:
  ``fold_in(key, i)`` per step is the idiom);
* **rebind** — assignment to the name resets it.

Flagged: consume→consume, consume→derive, derive→consume without a
rebind in between.  Constant-index subscripts (``ks[0]``) are tracked
per element; varying subscripts (``ks[i]`` in a loop) are skipped — that
is the idiomatic batched pattern, not reuse.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Tuple

from ..context import FileContext, Project, assigned_names
from ..registry import Finding, Rule, register
from . import _shared

_PRODUCERS = {"PRNGKey", "key", "key_data", "wrap_key_data"}
_DERIVERS = {"split", "fold_in", "clone"}

# key state: ("fresh"|"derived"|"consumed", line_of_last_event)
_RANK = {"fresh": 0, "derived": 1, "consumed": 2}


class _Walker(_shared.StmtRule):
    def __init__(self, fc: FileContext):
        self.fc = fc
        self.findings: List[Finding] = []
        self._seen = set()

    # -- helpers ---------------------------------------------------------

    def _key_id(self, node: ast.AST) -> Optional[str]:
        if isinstance(node, ast.Name):
            return node.id
        if isinstance(node, ast.Subscript) and isinstance(node.value, ast.Name):
            sl = node.slice
            if isinstance(sl, ast.Constant) and isinstance(sl.value, int):
                return f"{node.value.id}[{sl.value}]"
            return None                     # varying subscript: skip
        return None

    def _random_call(self, call: ast.Call) -> Optional[str]:
        """Return the jax.random function name, else None."""
        canon = self.fc.call_canonical(call)
        if canon and canon.startswith("jax.random."):
            return canon.rsplit(".", 1)[1]
        return None

    def _flag(self, node: ast.AST, key: str, prev: Tuple[str, int],
              event: str) -> None:
        k = (node.lineno, node.col_offset, key)
        if k in self._seen:
            return
        self._seen.add(k)
        prev_state, prev_line = prev
        if prev_state == "consumed":
            what = f"already consumed at line {prev_line}"
        else:
            what = f"already split/folded at line {prev_line} — use the " \
                   "derived keys"
        self.findings.append(Finding(
            "R004", self.fc.path, node.lineno, node.col_offset,
            f"PRNG key '{key}' {what}; split or fold_in before reuse "
            "[gate: FaultSchedule statelessness + reservoir chi-square]"))

    # -- events ----------------------------------------------------------

    def _process(self, node: ast.AST, state: dict) -> None:
        for call in ast.walk(node):
            if not isinstance(call, ast.Call):
                continue
            fn = self._random_call(call)
            if fn is None or fn in _PRODUCERS:
                continue
            arg = None
            if call.args:
                arg = call.args[0]
            else:
                for kw in call.keywords:
                    if kw.arg == "key":
                        arg = kw.value
            if arg is None:
                continue
            key = self._key_id(arg)
            if key is None:
                continue
            event = "derive" if fn in _DERIVERS else "consume"
            prev = state.get(key, ("fresh", 0))
            if prev[0] == "consumed" or (prev[0] == "derived"
                                         and event == "consume"):
                self._flag(call, key, prev, event)
            new_state = event + "d" if event == "consume" else "derived"
            if _RANK[new_state] > _RANK[prev[0]]:
                state[key] = (new_state, call.lineno)

    def on_expr(self, expr: ast.AST, state: dict) -> None:
        self._process(expr, state)

    def on_bind(self, target: ast.AST, state: dict) -> None:
        for name in assigned_names(target):
            state[name] = ("fresh", 0)
            for k in list(state):
                if k.startswith(name + "["):
                    state[k] = ("fresh", 0)

    def on_stmt(self, stmt: ast.stmt, state: dict) -> None:
        if isinstance(stmt, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            if stmt.value is not None:
                self._process(stmt.value, state)
            targets = (stmt.targets if isinstance(stmt, ast.Assign)
                       else [stmt.target])
            for t in targets:
                if t is not None:
                    self.on_bind(t, state)
        else:
            self._process(stmt, state)

    def copy(self, state: dict) -> dict:
        return dict(state)

    def merge(self, state: dict, branches: List[dict]) -> None:
        names = set()
        for b in branches:
            names |= set(b)
        for n in names:
            marks = [b.get(n, ("fresh", 0)) for b in branches]
            # keep the LEAST advanced state — exclusive branches must not
            # combine into a phantom reuse
            state[n] = min(marks, key=lambda m: _RANK[m[0]])


@register(Rule(
    id="R004",
    name="prng-key-reuse",
    gate="FaultSchedule statelessness (tests/test_session_pool.py) + "
         "sampling determinism",
    summary="a PRNG key consumed by two jax.random.* calls without an "
            "intervening split/fold_in rebinding",
))
def check(fc: FileContext, project: Project) -> List[Finding]:
    findings: List[Finding] = []
    for _, fn in _shared.iter_functions(fc.tree):
        walker = _Walker(fc)
        _shared.walk_body(fn.body, {}, walker)
        findings.extend(walker.findings)
    # module-level statements too (scripts/benchmarks)
    walker = _Walker(fc)
    _shared.walk_body(fc.tree.body, {}, walker)
    findings.extend(walker.findings)
    return findings
