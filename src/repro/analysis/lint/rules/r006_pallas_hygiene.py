"""R006 Pallas kernel hygiene — the static twin of the interpret-mode
parity gates (tests/test_kernels.py vs kernels/ref.py).

Three checks over modules that touch ``jax.experimental.pallas``:

* **(a) unclamped program-id index** — a ``pl.program_id``-derived value
  used as an index in ``pl.load``/``pl.store``/ref subscripts without
  passing through ``clip``/``minimum``/``maximum``: on the last grid step
  the block origin may run past the padded extent.  Comparisons
  (``@pl.when(ni == 0)``) are not indices and never fire.
* **(b) missing jnp ref counterpart** — every public entry point of a
  ``kernels/`` module that launches a ``pallas_call`` owes a
  ``*_ref``/``*_batch_ref`` twin in the sibling ``ref.py``; the parity
  tests and the differential oracles both dispatch on that name.
* **(c) narrow accumulation** — a float VMEM scratch accumulator at a
  narrower dtype than the kernel output silently rounds partial sums
  that the jnp ref computes at full width, so parity fails only at
  sizes the fixtures never reach.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from ..context import FileContext, Project
from ..registry import Finding, Rule, register
from . import _shared

_CLAMPS = {"clip", "minimum", "maximum", "min", "max", "mod", "remainder"}
_FLOAT_WIDTH = {"float16": 16, "bfloat16": 16, "float32": 32, "float64": 64}


def _uses_pallas(fc: FileContext) -> bool:
    return any("pallas" in v for v in fc.aliases.values())


def _is_pallas_call(fc: FileContext, call: ast.Call) -> bool:
    canon = fc.call_canonical(call) or ""
    return canon.endswith(".pallas_call") or canon == "pallas_call"


def _kernel_functions(fc: FileContext) -> Set[str]:
    """Defs passed (directly or via functools.partial) to a pallas_call,
    plus any def that reads pl.program_id."""
    kernels: Set[str] = set()
    for node in ast.walk(fc.tree):
        if isinstance(node, ast.Call) and _is_pallas_call(fc, node):
            if node.args:
                k = node.args[0]
                if isinstance(k, ast.Call) and k.args:
                    k = k.args[0]
                if isinstance(k, ast.Name):
                    kernels.add(k.id)
    for name, fn in fc.functions.items():
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                canon = fc.call_canonical(node) or ""
                if canon.endswith(".program_id"):
                    kernels.add(name)
                    break
    return kernels


def _check_pid_indices(fc: FileContext, fn: ast.FunctionDef) -> List[Finding]:
    pid: Set[str] = set()
    for _ in range(3):
        grew = False
        for node in _shared.walk_pruned(fn):
            if not isinstance(node, ast.Assign):
                continue
            rhs_pid = False
            for sub in ast.walk(node.value):
                if isinstance(sub, ast.Call):
                    canon = fc.call_canonical(sub) or ""
                    if canon.endswith(".program_id"):
                        rhs_pid = True
                elif (isinstance(sub, ast.Name)
                      and isinstance(sub.ctx, ast.Load) and sub.id in pid):
                    rhs_pid = True
            if rhs_pid and not _shared.contains_call_to(node.value, _CLAMPS):
                for t in node.targets:
                    if isinstance(t, ast.Name) and t.id not in pid:
                        pid.add(t.id)
                        grew = True
        if not grew:
            break

    def hazardous(idx: ast.AST) -> Optional[str]:
        if _shared.contains_call_to(idx, _CLAMPS):
            return None
        if isinstance(idx, ast.Compare):
            return None
        for sub in ast.walk(idx):
            if isinstance(sub, ast.Call):
                canon = fc.call_canonical(sub) or ""
                if canon.endswith(".program_id"):
                    return "pl.program_id(...)"
            if (isinstance(sub, ast.Name) and isinstance(sub.ctx, ast.Load)
                    and sub.id in pid):
                return f"'{sub.id}'"
        return None

    findings: List[Finding] = []
    params = set(fc.param_names(fn))

    def flag(node: ast.AST, what: str, where: str) -> None:
        findings.append(Finding(
            "R006", fc.path, node.lineno, node.col_offset,
            f"unclamped program-id-derived index {what} in {where} inside "
            f"kernel '{fn.name}' — clip it to the padded extent before "
            "addressing [gate: interpret-mode parity vs kernels/ref.py]"))

    for node in _shared.walk_pruned(fn):
        if isinstance(node, ast.Call):
            canon = fc.call_canonical(node) or ""
            if canon.endswith((".load", ".store")) and "pallas" in canon:
                for idx in node.args[1:]:
                    what = hazardous(idx)
                    if what:
                        flag(node, what, canon.rsplit(".", 1)[1])
        elif isinstance(node, ast.Subscript):
            base = node.value
            if (isinstance(base, ast.Name) and base.id in params):
                what = hazardous(node.slice)
                if what:
                    flag(node, what, f"'{base.id}[...]'")
    return findings


def _entry_points(fc: FileContext) -> List[ast.FunctionDef]:
    out = []
    for name, fn in fc.functions.items():
        if name.startswith("_"):
            continue
        for node in ast.walk(fn):
            if isinstance(node, ast.Call) and _is_pallas_call(fc, node):
                out.append(fn)
                break
    return out


def _ref_stems(ref_fc: FileContext) -> Set[str]:
    stems = set()
    for name in ref_fc.functions:
        if name.endswith("_ref"):
            stem = name[:-4]
            if stem.endswith("_batch"):
                stem = stem[:-6]
            stems.add(stem)
    return stems


def _check_ref_counterparts(
    fc: FileContext, project: Project
) -> List[Finding]:
    frag = project.config.kernels_fragment
    norm = fc.path.replace("\\", "/")
    if f"/{frag}/" not in norm and not norm.startswith(f"{frag}/"):
        return []
    entries = _entry_points(fc)
    if not entries:
        return []
    ref = project.sibling(fc.path, "ref")
    stems = _ref_stems(ref) if ref is not None else set()
    findings = []
    for fn in entries:
        stem = fn.name
        if stem.endswith("_batched"):
            stem = stem[: -len("_batched")]
        ok = any(s == stem or s in stem or stem in s for s in stems)
        if not ok:
            findings.append(Finding(
                "R006", fc.path, fn.lineno, fn.col_offset,
                f"pallas entry point '{fn.name}' has no jnp ref "
                "counterpart in the sibling ref.py (expected "
                f"'{stem}_ref' or '{stem}_batch_ref') — the parity tests "
                "and differential oracles need one "
                "[gate: interpret-mode parity, tests/test_kernels.py]"))
    return findings


def _dtype_name(node: ast.AST) -> Optional[str]:
    name = None
    if isinstance(node, (ast.Attribute, ast.Name)):
        seg = _shared.last_segment(node)
        name = seg
    elif isinstance(node, ast.Constant) and isinstance(node.value, str):
        name = node.value
    return name


def _check_scratch_dtypes(fc: FileContext) -> List[Finding]:
    findings = []
    for call in ast.walk(fc.tree):
        if not isinstance(call, ast.Call) or not _is_pallas_call(fc, call):
            continue
        out_widths: List[int] = []
        scratch: List = []
        for kw in call.keywords:
            if kw.arg == "out_shape":
                structs = (kw.value.elts
                           if isinstance(kw.value, (ast.Tuple, ast.List))
                           else [kw.value])
                for s in structs:
                    if isinstance(s, ast.Call):
                        dt = None
                        for skw in s.keywords:
                            if skw.arg == "dtype":
                                dt = _dtype_name(skw.value)
                        if dt is None and len(s.args) >= 2:
                            dt = _dtype_name(s.args[1])
                        if dt in _FLOAT_WIDTH:
                            out_widths.append(_FLOAT_WIDTH[dt])
            elif kw.arg == "scratch_shapes":
                if isinstance(kw.value, (ast.Tuple, ast.List)):
                    scratch = list(kw.value.elts)
        if not out_widths or not scratch:
            continue
        out_w = max(out_widths)
        for s in scratch:
            if not isinstance(s, ast.Call) or len(s.args) < 2:
                continue
            dt = _dtype_name(s.args[1])
            w = _FLOAT_WIDTH.get(dt or "")
            if w is not None and w < out_w:
                findings.append(Finding(
                    "R006", fc.path, s.lineno, s.col_offset,
                    f"float scratch accumulator is {dt} but the kernel "
                    f"output is {out_w}-bit — partial sums round before "
                    "the ref does; accumulate at least at output width "
                    "[gate: interpret-mode parity vs kernels/ref.py]"))
    return findings


@register(Rule(
    id="R006",
    name="pallas-kernel-hygiene",
    gate="interpret-mode kernel parity (tests/test_kernels.py + "
         "kernels/ref.py differential oracles)",
    summary="unclamped program-id indices in pl.load/pl.store, missing "
            "jnp ref counterpart in ref.py, float accumulation narrower "
            "than the kernel output",
))
def check(fc: FileContext, project: Project) -> List[Finding]:
    if not _uses_pallas(fc):
        return []
    findings: List[Finding] = []
    for name in sorted(_kernel_functions(fc)):
        fn = fc.functions.get(name)
        if fn is not None:
            findings.extend(_check_pid_indices(fc, fn))
    findings.extend(_check_ref_counterparts(fc, project))
    findings.extend(_check_scratch_dtypes(fc))
    return findings
