"""Rule registry and the Finding record.

A rule is a pure function ``check(fc, project) -> list[Finding]`` over a
parsed :class:`~repro.analysis.lint.context.FileContext`.  Registration
carries the metadata the satellite gates assert on: the runtime gate the
rule mirrors and the DESIGN.md anchor documenting it.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

# Meta rule id used for checker-level diagnostics that are not part of
# the registered invariant set: malformed disable comments (a disable
# without a mandatory reason string) and unparseable files.
META_RULE = "R000"


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str           # path as given on the command line (posix-ish)
    line: int           # 1-indexed
    col: int            # 0-indexed
    message: str

    def key(self, line_text: str) -> tuple:
        """Baseline identity: stable across pure line-number drift."""
        import hashlib

        h = hashlib.sha1(line_text.strip().encode("utf-8")).hexdigest()[:12]
        return (self.rule, self.path, h)

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str             # "R001"
    name: str           # "use-after-donate"
    gate: str           # runtime gate this rule mirrors
    summary: str        # one line, shown by --list-rules
    check: Optional[Callable] = None


REGISTRY: Dict[str, Rule] = {}


def register(rule: Rule):
    """Decorator: attach a check function to ``rule`` and register it."""

    def deco(fn: Callable) -> Callable:
        if rule.id in REGISTRY:
            raise ValueError(f"duplicate lint rule id {rule.id}")
        REGISTRY[rule.id] = dataclasses.replace(rule, check=fn)
        return fn

    return deco


def all_rules() -> List[Rule]:
    # Import for side effect: rule modules self-register on first use.
    from . import rules as _rules  # noqa: F401

    return [REGISTRY[k] for k in sorted(REGISTRY)]
