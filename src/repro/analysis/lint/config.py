"""Lint configuration: ``[tool.repro-lint]`` in pyproject.toml.

Two halves:

* tuning knobs for the heuristic rules (hot-loop function names, the
  blessed host-view pattern, dispatch/donating name patterns, static
  kwarg names) — all default to the engine's committed conventions so the
  tool works on a bare checkout; and
* the **exclusion manifest**: an explicit committed list of seed
  model-stack paths outside the protocol-engine contract.  Every entry
  MUST carry a one-line ``reason`` — silent path filtering is exactly
  what the satellite forbids — and a missing reason is a one-line config
  error, same convention as ``benchmarks/check_bench_schema.py``.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Dict, List, Optional, Tuple

try:  # Python 3.11+
    import tomllib as _toml
except ImportError:  # this container: 3.10 + tomli
    import tomli as _toml  # type: ignore[no-redef]


class LintConfigError(Exception):
    """Raised with a single human-readable line; the CLI prints it as-is."""


@dataclasses.dataclass
class Exclude:
    path: str       # posix-relative to the config file's directory
    reason: str


_DEFAULTS: Dict[str, object] = {
    # R003: functions that ARE the hot loop; syncs are judged only inside
    # loop bodies of these.
    "hot_loop_functions": ["run_hot", "step_pool"],
    # R003: a call whose target name contains this substring produces the
    # blessed packed-(3,B) host view; derived values may cross to host.
    "blessed_view_pattern": "view",
    # R003: parameter names holding device pytrees inside hot loops.
    "device_roots": ["state", "data", "s", "sub"],
    # R002: kwargs that feed the (n_pad, width, warm) compile key or
    # otherwise determine shapes inside a dispatch.
    "static_kwargs": [
        "trans_width", "width", "n_pad", "first_turn", "use_warm", "warm",
        "per_node", "k", "cap",
    ],
    # R002: passing a value through one of these blesses it (quantized /
    # pinned to the compile-key lattice).
    "quantizers": ["_round_up", "round_up"],
    # R002/R001: call-target name patterns that mark a jitted dispatch
    # when the jit binding itself is out of view (factory-made sharded
    # dispatches, dispatch closures passed as parameters).
    "dispatch_patterns": [
        r"^dispatch", r"_jit\b", r"_don$", r"_hot_turn", r"^full_j$",
        r"^sub_j$", r"^step_d$", r"^turn_d$",
    ],
    # R001: patterns for donating callees whose jit binding is out of
    # view; the donated argument is any bare name in donated_arg_names.
    "donating_patterns": [r"_don$", r"^full_j$", r"^sub_j$", r"^dispatch"],
    "donated_arg_names": ["state", "s", "sub"],
    # R006: only packages matching this path fragment owe a jnp ref
    # counterpart in a sibling ref.py.
    "kernels_fragment": "kernels",
}


@dataclasses.dataclass
class LintConfig:
    root: str                       # directory the config was loaded from
    excludes: List[Exclude] = dataclasses.field(default_factory=list)
    hot_loop_functions: List[str] = dataclasses.field(
        default_factory=lambda: list(_DEFAULTS["hot_loop_functions"]))
    blessed_view_pattern: str = str(_DEFAULTS["blessed_view_pattern"])
    device_roots: List[str] = dataclasses.field(
        default_factory=lambda: list(_DEFAULTS["device_roots"]))
    static_kwargs: List[str] = dataclasses.field(
        default_factory=lambda: list(_DEFAULTS["static_kwargs"]))
    quantizers: List[str] = dataclasses.field(
        default_factory=lambda: list(_DEFAULTS["quantizers"]))
    dispatch_patterns: List[str] = dataclasses.field(
        default_factory=lambda: list(_DEFAULTS["dispatch_patterns"]))
    donating_patterns: List[str] = dataclasses.field(
        default_factory=lambda: list(_DEFAULTS["donating_patterns"]))
    donated_arg_names: List[str] = dataclasses.field(
        default_factory=lambda: list(_DEFAULTS["donated_arg_names"]))
    kernels_fragment: str = str(_DEFAULTS["kernels_fragment"])

    def excluded(self, path: str) -> Optional[Exclude]:
        """Match ``path`` against the manifest (file or subtree prefix)."""
        rel = os.path.relpath(os.path.abspath(path), self.root)
        rel = rel.replace(os.sep, "/")
        for ex in self.excludes:
            p = ex.path.rstrip("/")
            if rel == p or rel.startswith(p + "/"):
                return ex
        return None


_LIST_KEYS = (
    "hot_loop_functions", "device_roots", "static_kwargs", "quantizers",
    "dispatch_patterns", "donating_patterns", "donated_arg_names",
)


def load_config(pyproject: Optional[str]) -> LintConfig:
    """Load ``[tool.repro-lint]``; a missing file or table means defaults.

    All failure modes diagnose in one line (LintConfigError), never a
    traceback: unreadable TOML, a non-table entry, an exclude without a
    path, and — deliberately hard — an exclude without a reason.
    """
    if pyproject is None or not os.path.exists(pyproject):
        root = os.getcwd() if pyproject is None else os.path.dirname(
            os.path.abspath(pyproject)) or os.getcwd()
        return LintConfig(root=root)
    try:
        with open(pyproject, "rb") as fh:
            data = _toml.load(fh)
    except OSError as e:
        raise LintConfigError(f"lint config error: {pyproject}: unreadable ({e})")
    except _toml.TOMLDecodeError as e:
        raise LintConfigError(
            f"lint config error: {pyproject}: invalid TOML ({e}) — "
            "fix the [tool.repro-lint] table")
    table = data.get("tool", {}).get("repro-lint", {})
    if not isinstance(table, dict):
        raise LintConfigError(
            f"lint config error: {pyproject}: [tool.repro-lint] is not a table")
    cfg = LintConfig(root=os.path.dirname(os.path.abspath(pyproject)))
    for key in _LIST_KEYS:
        if key in table:
            val = table[key]
            if not isinstance(val, list) or not all(isinstance(x, str) for x in val):
                raise LintConfigError(
                    f"lint config error: {pyproject}: {key} must be a list "
                    "of strings")
            setattr(cfg, key, list(val))
    for key in ("blessed_view_pattern", "kernels_fragment"):
        if key in table:
            if not isinstance(table[key], str):
                raise LintConfigError(
                    f"lint config error: {pyproject}: {key} must be a string")
            setattr(cfg, key, table[key])
    raw_excludes = table.get("exclude", [])
    if not isinstance(raw_excludes, list):
        raise LintConfigError(
            f"lint config error: {pyproject}: exclude must be an array of "
            "tables ([[tool.repro-lint.exclude]])")
    for i, entry in enumerate(raw_excludes):
        if not isinstance(entry, dict) or "path" not in entry:
            raise LintConfigError(
                f"lint config error: {pyproject}: exclude[{i}] needs a "
                "'path' key")
        reason = entry.get("reason", "")
        if not isinstance(reason, str) or not reason.strip():
            raise LintConfigError(
                f"lint config error: {pyproject}: exclude[{i}] "
                f"({entry['path']}) has no 'reason' — every manifest entry "
                "must say why it is outside the lint contract")
        cfg.excludes.append(Exclude(path=str(entry["path"]), reason=reason.strip()))
    return cfg


def find_pyproject(start: str) -> Optional[str]:
    """Walk up from ``start`` to the nearest pyproject.toml."""
    cur = os.path.abspath(start)
    while True:
        cand = os.path.join(cur, "pyproject.toml")
        if os.path.exists(cand):
            return cand
        parent = os.path.dirname(cur)
        if parent == cur:
            return None
        cur = parent
