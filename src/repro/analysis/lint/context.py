"""Shared AST infrastructure for the lint rules.

One :class:`FileContext` per linted file: the parsed tree, source lines,
import-alias canonicalization, inline-disable comments, and the
module-level facts several rules share — which names are bound to
``jax.jit`` wrappers (with their resolved ``static_argnames`` /
``donate_argnames``), and which functions run in a traced context.

Everything here is conservative by construction: a rule only fires on
facts it can prove from this file (plus, for R006, a sibling ``ref.py``),
never on "might be".
"""

from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

_DISABLE_RE = re.compile(
    r"#\s*lint:\s*disable=([A-Za-z0-9,\s]+?)\s*(?:\(([^)#]*)\))?\s*(?:#.*)?$"
)


@dataclasses.dataclass
class Disable:
    line: int                   # line the comment sits on
    rules: Set[str]
    reason: str                 # may be "" — enforced as META finding


@dataclasses.dataclass
class JitBinding:
    """``name = jax.jit(fn, static_argnames=..., donate_argnames=...)``."""

    name: str
    wrapped: Optional[str]              # wrapped function name if a Name
    static_names: Set[str]
    static_resolved: bool               # False → could not resolve statics
    donated_params: Set[str]            # by param name (resolved)
    donated_nums: Set[int]              # by positional index


def dotted_name(node: ast.AST) -> Optional[str]:
    """Render a Name/Attribute chain as ``a.b.c``; None otherwise."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def str_tuple(node: ast.AST) -> Optional[Tuple[str, ...]]:
    """Resolve a literal tuple/list of strings (or a lone string)."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for elt in node.elts:
            if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                out.append(elt.value)
            else:
                return None
        return tuple(out)
    return None


def int_tuple(node: ast.AST) -> Optional[Tuple[int, ...]]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for elt in node.elts:
            if isinstance(elt, ast.Constant) and isinstance(elt.value, int):
                out.append(elt.value)
            else:
                return None
        return tuple(out)
    return None


class FileContext:
    def __init__(self, path: str, src: str, tree: ast.Module):
        self.path = path
        self.src = src
        self.tree = tree
        self.lines = src.splitlines()
        self.aliases = self._collect_aliases(tree)
        self.disables = self._collect_disables(self.lines)
        self.module_constants = self._collect_module_constants(tree)
        self.functions = self._collect_functions(tree)
        self.jit_bindings = self._collect_jit_bindings(tree)

    # -- source helpers --------------------------------------------------

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""

    # -- imports ---------------------------------------------------------

    @staticmethod
    def _collect_aliases(tree: ast.Module) -> Dict[str, str]:
        """Map local alias → canonical dotted module path."""
        aliases: Dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    aliases[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0]
                    )
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    aliases[a.asname or a.name] = f"{node.module}.{a.name}"
        return aliases

    def canonical(self, node: ast.AST) -> Optional[str]:
        """Canonical dotted name of a call target, alias-resolved.

        ``pl.program_id`` → ``jax.experimental.pallas.program_id`` when the
        module did ``from jax.experimental import pallas as pl``.
        """
        name = dotted_name(node)
        if name is None:
            return None
        head, _, rest = name.partition(".")
        base = self.aliases.get(head, head)
        return f"{base}.{rest}" if rest else base

    def call_canonical(self, call: ast.Call) -> Optional[str]:
        return self.canonical(call.func)

    # -- disable comments ------------------------------------------------

    @staticmethod
    def _collect_disables(lines: Sequence[str]) -> Dict[int, Disable]:
        out: Dict[int, Disable] = {}
        for i, text in enumerate(lines, start=1):
            if "lint:" not in text:
                continue
            m = _DISABLE_RE.search(text)
            if not m:
                continue
            rules = {r.strip().upper() for r in m.group(1).split(",") if r.strip()}
            reason = (m.group(2) or "").strip()
            out[i] = Disable(line=i, rules=rules, reason=reason)
        return out

    def disable_for(self, lineno: int) -> Optional[Disable]:
        """Disable applying to ``lineno``: same line, or a bare comment line
        immediately above."""
        d = self.disables.get(lineno)
        if d is not None:
            return d
        d = self.disables.get(lineno - 1)
        if d is not None and self.line_text(d.line).lstrip().startswith("#"):
            return d
        return None

    # -- module constants ------------------------------------------------

    @staticmethod
    def _collect_module_constants(tree: ast.Module) -> Dict[str, ast.AST]:
        consts: Dict[str, ast.AST] = {}
        for node in tree.body:
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                t = node.targets[0]
                if isinstance(t, ast.Name):
                    consts[t.id] = node.value
        return consts

    def resolve_str_tuple(self, node: ast.AST) -> Optional[Tuple[str, ...]]:
        got = str_tuple(node)
        if got is not None:
            return got
        if isinstance(node, ast.Name) and node.id in self.module_constants:
            return str_tuple(self.module_constants[node.id])
        return None

    # -- functions -------------------------------------------------------

    @staticmethod
    def _collect_functions(tree: ast.Module) -> Dict[str, ast.FunctionDef]:
        """All function defs by name, outermost wins on collision."""
        fns: Dict[str, ast.FunctionDef] = {}
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                fns.setdefault(node.name, node)
        return fns

    def param_names(self, fn: ast.FunctionDef) -> List[str]:
        a = fn.args
        return (
            [p.arg for p in a.posonlyargs]
            + [p.arg for p in a.args]
            + [p.arg for p in a.kwonlyargs]
        )

    def positional_params(self, fn: ast.FunctionDef) -> List[str]:
        a = fn.args
        return [p.arg for p in a.posonlyargs] + [p.arg for p in a.args]

    # -- jax.jit bindings ------------------------------------------------

    _JIT_NAMES = {"jax.jit", "jit", "jax.api.jit"}

    def _is_jit_call(self, call: ast.Call) -> bool:
        name = self.call_canonical(call)
        return name in self._JIT_NAMES

    def _jit_binding_from(self, target: str, call: ast.Call) -> JitBinding:
        wrapped = None
        if call.args and isinstance(call.args[0], ast.Name):
            wrapped = call.args[0].id
        static_names: Set[str] = set()
        static_resolved = True
        donated_params: Set[str] = set()
        donated_nums: Set[int] = set()
        for kw in call.keywords:
            if kw.arg == "static_argnames":
                got = self.resolve_str_tuple(kw.value)
                if got is None:
                    static_resolved = False
                else:
                    static_names |= set(got)
            elif kw.arg == "static_argnums":
                nums = int_tuple(kw.value)
                if nums is None:
                    static_resolved = False
                elif wrapped and wrapped in self.functions:
                    pos = self.positional_params(self.functions[wrapped])
                    for n in nums:
                        if 0 <= n < len(pos):
                            static_names.add(pos[n])
            elif kw.arg == "donate_argnames":
                got = self.resolve_str_tuple(kw.value)
                if got is not None:
                    donated_params |= set(got)
            elif kw.arg == "donate_argnums":
                nums = int_tuple(kw.value)
                if nums is not None:
                    donated_nums |= set(nums)
        # Resolve donated param names to positional indices when we know
        # the wrapped function's signature.
        if wrapped and wrapped in self.functions:
            pos = self.positional_params(self.functions[wrapped])
            for p in donated_params:
                if p in pos:
                    donated_nums.add(pos.index(p))
        return JitBinding(
            name=target,
            wrapped=wrapped,
            static_names=static_names,
            static_resolved=static_resolved,
            donated_params=donated_params,
            donated_nums=donated_nums,
        )

    def _collect_jit_bindings(self, tree: ast.Module) -> Dict[str, JitBinding]:
        """``X = jax.jit(fn, ...)`` assignments anywhere in the module,
        plus one level of alias propagation (``Y = X`` / conditional)."""
        out: Dict[str, JitBinding] = {}
        for node in ast.walk(tree):
            if not isinstance(node, ast.Assign) or len(node.targets) != 1:
                continue
            t = node.targets[0]
            if not isinstance(t, ast.Name):
                continue
            v = node.value
            if isinstance(v, ast.Call) and self._is_jit_call(v):
                out[t.id] = self._jit_binding_from(t.id, v)
        # alias pass: Y = X, Y = X if c else Z — donate/statics union
        for node in ast.walk(tree):
            if not isinstance(node, ast.Assign) or len(node.targets) != 1:
                continue
            t = node.targets[0]
            if not isinstance(t, ast.Name) or t.id in out:
                continue
            v = node.value
            sources: List[str] = []
            if isinstance(v, ast.Name):
                sources = [v.id]
            elif isinstance(v, ast.IfExp):
                for side in (v.body, v.orelse):
                    if isinstance(side, ast.Name):
                        sources.append(side.id)
            hits = [out[s] for s in sources if s in out]
            if hits:
                out[t.id] = JitBinding(
                    name=t.id,
                    wrapped=hits[0].wrapped,
                    static_names=set().union(*(h.static_names for h in hits)),
                    static_resolved=all(h.static_resolved for h in hits),
                    donated_params=set().union(*(h.donated_params for h in hits)),
                    donated_nums=set().union(*(h.donated_nums for h in hits)),
                )
        return out

    # -- traced-context detection (R005) ---------------------------------

    _TRACING_WRAPPERS = {
        "jax.jit",
        "jax.vmap",
        "jax.pmap",
        "jax.grad",
        "jax.value_and_grad",
        "jax.experimental.shard_map.shard_map",
        "shard_map.shard_map",
        "jax.lax.while_loop",
        "jax.lax.scan",
        "jax.lax.cond",
        "jax.lax.fori_loop",
        "lax.while_loop",
        "lax.scan",
        "lax.cond",
        "lax.fori_loop",
    }

    def _decorator_statics(self, fn: ast.FunctionDef) -> Optional[Set[str]]:
        """If ``fn`` is traced via decorator, return its static param names
        (None → not traced via decorator)."""
        for dec in fn.decorator_list:
            name = self.canonical(dec) if not isinstance(dec, ast.Call) else None
            if name in self._TRACING_WRAPPERS:
                return set()
            if isinstance(dec, ast.Call):
                cname = self.canonical(dec.func)
                if cname in self._TRACING_WRAPPERS:
                    return self._statics_from_kwargs(dec, fn)
                if cname in {"functools.partial", "partial"} and dec.args:
                    inner = self.canonical(dec.args[0])
                    if inner in self._TRACING_WRAPPERS:
                        return self._statics_from_kwargs(dec, fn)
        return None

    def _statics_from_kwargs(
        self, call: ast.Call, fn: ast.FunctionDef
    ) -> Optional[Set[str]]:
        statics: Set[str] = set()
        for kw in call.keywords:
            if kw.arg == "static_argnames":
                got = self.resolve_str_tuple(kw.value)
                if got is None:
                    return None          # unresolvable → skip function
                statics |= set(got)
            elif kw.arg == "static_argnums":
                nums = int_tuple(kw.value)
                if nums is None:
                    return None
                pos = self.positional_params(fn)
                for n in nums:
                    if 0 <= n < len(pos):
                        statics.add(pos[n])
        return statics

    def traced_functions(self) -> Dict[str, Optional[Set[str]]]:
        """Functions that run under a tracer in this module.

        Maps function name → set of *static* (python-value) param names,
        or None when the statics could not be resolved (rule should skip
        such functions rather than guess).
        """
        traced: Dict[str, Optional[Set[str]]] = {}
        # (a) decorated defs
        for name, fn in self.functions.items():
            statics = self._decorator_statics(fn)
            if statics is not None or self._has_tracing_decorator(fn):
                traced[name] = statics
        # (b) module-level jax.jit(fn, ...) wrappings
        for b in self.jit_bindings.values():
            if b.wrapped and b.wrapped in self.functions:
                statics = b.static_names if b.static_resolved else None
                prev = traced.get(b.wrapped)
                if prev is not None and statics is not None:
                    statics = set(prev) | statics
                traced[b.wrapped] = statics
        # Callbacks handed positionally to lax control flow / shard_map /
        # vmap are resolved slot-aware by R005 itself.
        return traced

    def _has_tracing_decorator(self, fn: ast.FunctionDef) -> bool:
        for dec in fn.decorator_list:
            target = dec.func if isinstance(dec, ast.Call) else dec
            if self.canonical(target) in self._TRACING_WRAPPERS:
                return True
        return False


class Project:
    """The set of files under lint, plus on-demand sibling parsing (R006
    matches kernel entry points against a ``ref.py`` that may or may not be
    part of the linted path set)."""

    def __init__(self, files: Iterable[FileContext], config=None):
        from .config import LintConfig

        self.config = config if config is not None else LintConfig(root=os.getcwd())
        self.files = list(files)
        self._by_path = {os.path.abspath(fc.path): fc for fc in self.files}
        self._sibling_cache: Dict[str, Optional[FileContext]] = {}

    def sibling(self, path: str, module: str) -> Optional[FileContext]:
        """FileContext for ``<dir(path)>/<module>.py``, linted or not."""
        target = os.path.abspath(os.path.join(os.path.dirname(path), module + ".py"))
        if target in self._by_path:
            return self._by_path[target]
        if target in self._sibling_cache:
            return self._sibling_cache[target]
        fc: Optional[FileContext] = None
        if os.path.isfile(target):
            try:
                with open(target, "r", encoding="utf-8") as fh:
                    src = fh.read()
                fc = FileContext(target, src, ast.parse(src))
            except (OSError, SyntaxError):
                fc = None
        self._sibling_cache[target] = fc
        return fc


# ---------------------------------------------------------------------------
# Statement-order walking shared by the dataflow rules (R001/R004)
# ---------------------------------------------------------------------------


def assigned_names(target: ast.AST) -> Set[str]:
    """Names (re)bound by an assignment target, incl. tuple unpacking."""
    out: Set[str] = set()
    for node in ast.walk(target):
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
            out.add(node.id)
    return out


def iter_calls(node: ast.AST) -> Iterable[ast.Call]:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            yield sub
