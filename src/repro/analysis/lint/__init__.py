"""repro.analysis.lint — tracing-invariant static analyzer.

Enforces the engine's dispatch-key, donation, and RNG contracts at lint
time.  Every rule is the static twin of a named runtime gate (see
DESIGN.md §Static invariants): R001 mirrors tests/test_hotloop_donate.py,
R002 mirrors tests/test_recompile.py + the session pool's pinned-key
determinism, R003 the blessed packed-(3,B) host-view transfer, R004 the
FaultSchedule statelessness discipline, R005 jit-tracing soundness, and
R006 the Pallas-kernel / jnp-ref parity contract.

Pure stdlib ``ast`` — no third-party dependencies beyond what the repo
already ships (``tomli`` as the pre-3.11 ``tomllib`` fallback).
"""

from .registry import REGISTRY, Finding, Rule, register
from .engine import lint_paths, lint_tree
from .config import LintConfig, LintConfigError, load_config
from .baseline import Baseline, BaselineError, load_baseline

__all__ = [
    "REGISTRY",
    "Finding",
    "Rule",
    "register",
    "lint_paths",
    "lint_tree",
    "LintConfig",
    "LintConfigError",
    "load_config",
    "Baseline",
    "BaselineError",
    "load_baseline",
]
