"""Baseline suppression with a shrink-only ratchet.

The baseline file records the accepted debt: a list of finding keys
``(rule, path, line-content-hash)`` plus a ``budget`` — the historical
minimum count.  The gate is two-sided:

* a finding NOT in the baseline is **new** → fail;
* a baseline entry matching NO finding is **stale** → fail (the debt
  shrank; the file must be re-written so it can never silently grow
  back).

``--write-baseline`` refuses to grow the budget unless ``--allow-growth``
is passed, which is the CI shrink-only gate in file form.

All failure modes diagnose in one line (BaselineError), mirroring the
bench tooling convention from ``benchmarks/check_bench_schema.py``.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional, Tuple

VERSION = 1


class BaselineError(Exception):
    """Raised with a single human-readable line; the CLI prints it as-is."""


@dataclasses.dataclass
class Baseline:
    path: str
    budget: int
    entries: List[Tuple[str, str, str]]     # (rule, path, content-hash)

    def counts(self) -> Dict[Tuple[str, str, str], int]:
        out: Dict[Tuple[str, str, str], int] = {}
        for e in self.entries:
            out[e] = out.get(e, 0) + 1
        return out


def load_baseline(path: str) -> Baseline:
    if not os.path.exists(path):
        raise BaselineError(
            f"lint baseline error: {path}: not found — create it with "
            "--write-baseline")
    try:
        with open(path, "r", encoding="utf-8") as fh:
            data = json.load(fh)
    except (OSError, ValueError, UnicodeDecodeError):
        raise BaselineError(
            f"lint baseline error: {path}: unreadable or truncated — "
            "re-create it with --write-baseline")
    if not isinstance(data, dict):
        raise BaselineError(
            f"lint baseline error: {path}: top level is "
            f"{type(data).__name__}, wanted object")
    if data.get("version") != VERSION:
        raise BaselineError(
            f"lint baseline error: {path}: version {data.get('version')!r}, "
            f"this tool writes version {VERSION}")
    budget = data.get("budget")
    if not isinstance(budget, int) or budget < 0:
        raise BaselineError(
            f"lint baseline error: {path}: budget must be a non-negative "
            "integer")
    raw = data.get("findings")
    if not isinstance(raw, list):
        raise BaselineError(
            f"lint baseline error: {path}: findings must be a list")
    entries: List[Tuple[str, str, str]] = []
    for i, e in enumerate(raw):
        if (not isinstance(e, dict)
                or not all(isinstance(e.get(k), str)
                           for k in ("rule", "path", "hash"))):
            raise BaselineError(
                f"lint baseline error: {path}: findings[{i}] needs string "
                "keys rule/path/hash")
        entries.append((e["rule"], e["path"], e["hash"]))
    if len(entries) > budget:
        raise BaselineError(
            f"lint baseline error: {path}: {len(entries)} entries exceed "
            f"budget {budget} — the baseline may only shrink")
    return Baseline(path=path, budget=budget, entries=entries)


def write_baseline(
    path: str,
    keys: List[Tuple[str, str, str]],
    previous: Optional[Baseline],
    allow_growth: bool = False,
) -> Baseline:
    budget = len(keys)
    if previous is not None and budget > previous.budget and not allow_growth:
        raise BaselineError(
            f"lint baseline error: {path}: refusing to grow the baseline "
            f"({previous.budget} -> {budget} findings); fix the new "
            "findings or pass --allow-growth")
    data = {
        "version": VERSION,
        "budget": budget,
        "findings": [
            {"rule": r, "path": p, "hash": h}
            for (r, p, h) in sorted(keys)
        ],
    }
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(data, fh, indent=2, sort_keys=True)
        fh.write("\n")
    return Baseline(path=path, budget=budget, entries=list(keys))
