"""CLI: ``python -m repro.analysis.lint src benchmarks [options]``.

Exit codes: 0 clean (modulo baseline), 1 findings / stale baseline,
2 config or baseline file errors (diagnosed in one line, never a
traceback).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Optional, Sequence, Tuple

from .baseline import Baseline, BaselineError, load_baseline, write_baseline
from .config import LintConfig, LintConfigError, find_pyproject, load_config
from .engine import lint_tree
from .registry import Finding, all_rules


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="Tracing-invariant static analyzer for the repro engine "
                    "(dispatch-key, donation, RNG, and Pallas contracts).")
    p.add_argument("paths", nargs="*", default=["src", "benchmarks"],
                   help="files or directories to lint (default: src benchmarks)")
    p.add_argument("--baseline", default=None, metavar="FILE",
                   help="baseline suppression file (default: "
                        ".lint-baseline.json when present)")
    p.add_argument("--write-baseline", action="store_true",
                   help="re-write the baseline from this run's findings "
                        "(shrink-only unless --allow-growth)")
    p.add_argument("--allow-growth", action="store_true",
                   help="permit --write-baseline to grow the budget")
    p.add_argument("--format", choices=("text", "json"), default="text")
    p.add_argument("--annotate", action="store_true",
                   help="emit GitHub Actions ::error annotations")
    p.add_argument("--config", default=None, metavar="PYPROJECT",
                   help="pyproject.toml to read [tool.repro-lint] from "
                        "(default: nearest pyproject.toml)")
    p.add_argument("--list-rules", action="store_true",
                   help="print the rule registry and exit")
    p.add_argument("-v", "--verbose", action="store_true",
                   help="also print suppressed findings and manifest skips")
    return p


def _relativize(findings: List[Finding], root: str) -> List[Finding]:
    out = []
    for f in findings:
        rel = os.path.relpath(os.path.abspath(f.path), root).replace(os.sep, "/")
        out.append(Finding(f.rule, rel, f.line, f.col, f.message))
    return out


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = build_parser().parse_args(argv)

    if args.list_rules:
        for rule in all_rules():
            print(f"{rule.id} {rule.name:28s} gate: {rule.gate}")
            print(f"     {rule.summary}")
        return 0

    config_path = args.config
    if config_path is None:
        start = args.paths[0] if args.paths else os.getcwd()
        config_path = find_pyproject(
            start if os.path.isdir(start) else os.path.dirname(start) or ".")
    try:
        config = load_config(config_path)
    except LintConfigError as e:
        print(str(e))
        return 2

    result, contexts = lint_tree(args.paths, config)

    baseline_path = args.baseline
    if baseline_path is None and os.path.exists(
            os.path.join(config.root, ".lint-baseline.json")):
        baseline_path = os.path.join(config.root, ".lint-baseline.json")

    # Baseline keys use config-root-relative paths so CI and local runs
    # agree regardless of cwd.
    rel_findings = _relativize(result.findings, config.root)
    keys = []
    for f, rel in zip(result.findings, rel_findings):
        fc = contexts.get(f.path)
        keys.append(rel.key(fc.line_text(f.line) if fc else ""))

    if args.write_baseline:
        if baseline_path is None:
            baseline_path = os.path.join(config.root, ".lint-baseline.json")
        previous: Optional[Baseline] = None
        if os.path.exists(baseline_path):
            try:
                previous = load_baseline(baseline_path)
            except BaselineError as e:
                print(str(e))
                return 2
        try:
            write_baseline(baseline_path, keys, previous,
                           allow_growth=args.allow_growth)
        except BaselineError as e:
            print(str(e))
            return 2
        print(f"wrote {baseline_path}: {len(keys)} finding(s)")
        return 0

    new: List[Tuple[Finding, Finding]] = []      # (abs-path finding, rel)
    stale: List[Tuple[str, str, str]] = []
    if baseline_path is not None:
        try:
            baseline = load_baseline(baseline_path)
        except BaselineError as e:
            print(str(e))
            return 2
        remaining = baseline.counts()
        for f, rel, key in zip(result.findings, rel_findings, keys):
            if remaining.get(key, 0) > 0:
                remaining[key] -= 1
            else:
                new.append((f, rel))
        stale = [k for k, n in remaining.items() for _ in range(n)]
    else:
        new = list(zip(result.findings, rel_findings))

    return _report(args, result, contexts, new, stale, baseline_path)


def _report(args, result, contexts, new, stale, baseline_path) -> int:
    ok = not new and not stale
    if args.format == "json":
        payload = {
            "version": 1,
            "findings": [
                {"rule": rel.rule, "path": rel.path, "line": rel.line,
                 "col": rel.col, "message": rel.message}
                for _, rel in new
            ],
            "stale_baseline": [
                {"rule": r, "path": p, "hash": h} for (r, p, h) in stale
            ],
            "counts": {
                "files": len(result.files),
                "findings": len(new),
                "suppressed": len(result.suppressed),
                "baselined": len(result.findings) - len(new),
                "stale": len(stale),
            },
            "ok": ok,
        }
        print(json.dumps(payload, indent=2, sort_keys=True))
    else:
        for f, _ in new:
            print(f.render())
        for (r, p, h) in stale:
            print(f"{p}: stale baseline entry {r}/{h} — the finding is "
                  "gone; shrink the baseline with --write-baseline")
        if args.verbose:
            for f in result.suppressed:
                print(f"suppressed: {f.render()}")
            for path, reason in result.skipped:
                print(f"skipped (manifest): {path} — {reason}")
        summary = (f"{len(result.files)} file(s), {len(new)} finding(s), "
                   f"{len(result.suppressed)} suppressed")
        if baseline_path is not None:
            summary += f", {len(result.findings) - len(new)} baselined"
            if stale:
                summary += f", {len(stale)} stale baseline entr(y/ies)"
        print(summary)
    if args.annotate:
        for f, rel in new:
            msg = f.message.replace("\n", " ")
            print(f"::error file={rel.path},line={f.line},"
                  f"title={f.rule}::{msg}")
        for (r, p, h) in stale:
            print(f"::error file={p},title=stale-baseline::stale baseline "
                  f"entry {r}/{h}; shrink the baseline")
    return 0 if ok else 1
