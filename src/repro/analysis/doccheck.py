"""Doc-drift gate: documentation links must resolve to real code.

``docs/ARCHITECTURE.md`` is a map of the tree; a map that names modules
that moved, or DESIGN.md anchors that were reworded, is worse than no map.
This checker is the CI twin of that promise, in the stdlib-only idiom of
:mod:`repro.analysis.lint` (the dep-free ``lint`` job runs it with no
project deps installed):

* every relative **markdown link** target must exist on disk (resolved
  against the linking file's own directory, the way GitHub renders it);
* every ``#fragment`` on a markdown link into a ``.md`` file must match a
  real heading of that file under GitHub's anchor slugging;
* every backticked **path token** (```` `src/.../x.py` ````,
  ```` `benchmarks/x.py` ````, ```` `engine/hotloop.py` ````, …) must
  exist either at the repo root or under ``src/repro/`` (the short module
  spelling DESIGN.md uses).

Run as ``PYTHONPATH=src python -m repro.analysis.doccheck FILE...`` —
one ``file:line: message`` diagnostic per problem, exit 1 if any.
"""

from __future__ import annotations

import os
import re
import sys
from typing import Iterable, List, Tuple

# [text](target) — target split into path + optional #fragment below
_MD_LINK = re.compile(r"\[[^\]]+\]\(([^)\s]+)\)")
# `path/with/slash.ext` — only slashed tokens; bare names are prose
_CODE_PATH = re.compile(r"`([A-Za-z0-9_.-]+(?:/[A-Za-z0-9_.-]+)+"
                        r"\.(?:py|md|json|toml|yml))`")
_HEADING = re.compile(r"^#{1,6}\s+(.*)$")

# roots a short backticked path may resolve against (DESIGN.md writes
# `engine/hotloop.py` for src/repro/engine/hotloop.py)
_PATH_ROOTS = ("", "src/repro")


def slugify(heading: str) -> str:
    """GitHub's heading→anchor rule: drop markup, lowercase, keep
    alphanumerics/underscores/hyphens, spaces become hyphens."""
    text = heading.replace("`", "").strip().lower()
    out = []
    for ch in text:
        if ch.isalnum() or ch in "_-":
            out.append(ch)
        elif ch == " ":
            out.append("-")
    return "".join(out)


def _headings(md_path: str) -> List[str]:
    slugs = []
    with open(md_path, encoding="utf-8") as f:
        in_fence = False
        for line in f:
            if line.lstrip().startswith("```"):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            m = _HEADING.match(line)
            if m:
                slugs.append(slugify(m.group(1)))
    return slugs


def check_file(doc_path: str, root: str = ".") -> List[Tuple[int, str]]:
    """Return (line, message) problems for one markdown file."""
    problems: List[Tuple[int, str]] = []
    doc_dir = os.path.dirname(os.path.abspath(doc_path))
    heading_cache = {}

    def anchors_of(md_file: str) -> List[str]:
        if md_file not in heading_cache:
            heading_cache[md_file] = _headings(md_file)
        return heading_cache[md_file]

    with open(doc_path, encoding="utf-8") as f:
        in_fence = False
        for lineno, line in enumerate(f, 1):
            if line.lstrip().startswith("```"):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            for target in _MD_LINK.findall(line):
                if "://" in target or target.startswith("mailto:"):
                    continue
                path, _, frag = target.partition("#")
                dest = (os.path.normpath(os.path.join(doc_dir, path))
                        if path else os.path.abspath(doc_path))
                if path and not os.path.exists(dest):
                    problems.append(
                        (lineno, f"broken link target {target!r}: "
                                 f"{path} does not exist"))
                    continue
                if frag:
                    if not dest.endswith(".md"):
                        continue
                    slugs = anchors_of(dest)
                    if frag not in slugs:
                        problems.append(
                            (lineno, f"broken anchor {target!r}: no "
                                     f"heading slugs to {frag!r} in "
                                     f"{path or os.path.basename(dest)}"))
            for token in _CODE_PATH.findall(line):
                if not any(os.path.exists(os.path.join(root, base, token))
                           for base in _PATH_ROOTS):
                    problems.append(
                        (lineno, f"dangling path `{token}`: not found at "
                                 f"repo root or under src/repro/"))
    return problems


def main(argv: Iterable[str]) -> int:
    files = list(argv)
    if not files:
        print("usage: python -m repro.analysis.doccheck FILE.md ...")
        return 2
    n_bad = 0
    for doc in files:
        if not os.path.exists(doc):
            print(f"{doc}: file not found")
            n_bad += 1
            continue
        for lineno, msg in check_file(doc):
            print(f"{doc}:{lineno}: {msg}")
            n_bad += 1
    if n_bad:
        print(f"doccheck: {n_bad} problem(s)")
        return 1
    print(f"doccheck: {len(files)} file(s) OK")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
