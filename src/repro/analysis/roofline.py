"""Roofline analysis from AOT-compiled artifacts (no hardware required).

Sources:
* ``compiled.cost_analysis()``  — per-device HLO FLOPs and bytes accessed
  (XLA reports the post-SPMD per-device program);
* ``compiled.as_text()``        — per-device HLO, parsed for collective ops
  (all-gather / all-reduce / reduce-scatter / all-to-all /
  collective-permute); per-op cost = sum of operand payload bytes.

Terms (seconds, per device == per step for the whole machine under SPMD):
    compute    = flops / PEAK_FLOPS
    memory     = bytes_accessed / HBM_BW
    collective = collective_bytes / ICI_BW_PER_LINK

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Any, Dict, Optional

from repro.launch.mesh import CHIP_HBM_BYTES, HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?:\()?\s*((?:pred|[a-z]+\d+[a-z0-9]*)\[[\d,]*\][^ ]*)"
    r"(?:[^=\n]*?)\s(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(-start|-done)?\(", )
_SHAPE_RE = re.compile(r"(pred|[a-z]+\d+[a-z0-9]*)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _ring_factor(op: str, g: int) -> float:
    """Per-device wire bytes as a multiple of the op's *output* bytes, under
    standard ring-algorithm accounting with group size g."""
    if g <= 1:
        return 0.0
    if op == "all-gather":
        return (g - 1) / g
    if op == "reduce-scatter":
        return float(g - 1)           # input = g × output; (g-1)/g × input
    if op == "all-reduce":
        return 2.0 * (g - 1) / g
    if op == "all-to-all":
        return (g - 1) / g
    return 1.0                        # collective-permute


def parse_collectives(hlo_text: str) -> Dict[str, Any]:
    """Per-device collective wire bytes from post-SPMD HLO.

    Compiled HLO lists operands as bare %refs, so payloads are derived from
    each collective's *output* shape (per-device shard) scaled by the ring
    cost factor for its replica-group size."""
    per_op: Dict[str, float] = defaultdict(float)
    counts: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        out_shapes, op, suffix = m.group(1), m.group(2), m.group(3)
        if suffix == "-done":
            continue  # payload was counted at -start
        total = 0
        for sm in _SHAPE_RE.finditer(out_shapes):
            total += _shape_bytes(sm.group(1), sm.group(2))
        gm = _GROUPS_RE.search(line)
        if gm:
            g = int(gm.group(2))
        else:
            gl = _GROUPS_LIST_RE.search(line)
            g = len(gl.group(1).split(",")) if gl else 2
        per_op[op] += total * _ring_factor(op, g)
        counts[op] += 1
    return {"bytes_by_op": {k: int(v) for k, v in per_op.items()},
            "counts": dict(counts),
            "total_bytes": int(sum(per_op.values()))}


@dataclasses.dataclass
class RooflineReport:
    name: str
    flops: float                    # per device
    bytes_accessed: float           # per device
    collective_bytes: float         # per device
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: Optional[float] = None   # 6·N·D (or 6·N_active·D) global
    useful_ratio: Optional[float] = None  # model_flops / (flops · chips)
    arg_bytes: int = 0
    temp_bytes: int = 0
    out_bytes: int = 0
    fits_hbm: Optional[bool] = None
    collectives: Optional[Dict] = None

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def analyze_compiled(name: str, compiled, *, chips: int,
                     model_flops: Optional[float] = None) -> RooflineReport:
    """Roofline terms via the while-aware structural HLO model.

    ``compiled.cost_analysis()`` counts scan/while bodies once (verified —
    see analysis/hlo_cost.py), which undercounts layer-scanned models by the
    trip-count product; the structural walk multiplies loop bodies out."""
    from repro.analysis.hlo_cost import analyze_hlo
    hlo = compiled.as_text()
    structural = analyze_hlo(hlo)
    flops = float(structural["flops"])
    byts = float(structural["bytes"])
    # cost_analysis() is a dict on newer jax, a per-computation list of
    # dicts on older versions — normalize before reading
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    colls = {"bytes_by_op": structural["bytes_by_op"],
             "counts": structural["counts"],
             "total_bytes": int(structural["collective_bytes"]),
             # naive (loop-body-once) numbers kept for reference
             "xla_flops_once": float((ca or {}).get("flops", 0.0))}
    cbytes = float(structural["collective_bytes"])

    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = byts / HBM_BW
    coll_s = cbytes / ICI_BW_PER_LINK
    dom = max(("compute", compute_s), ("memory", memory_s),
              ("collective", coll_s), key=lambda kv: kv[1])[0]

    mem = compiled.memory_analysis()
    arg_b = getattr(mem, "argument_size_in_bytes", 0)
    tmp_b = getattr(mem, "temp_size_in_bytes", 0)
    out_b = getattr(mem, "output_size_in_bytes", 0)
    alias_b = getattr(mem, "alias_size_in_bytes", 0)
    fits = (arg_b + tmp_b + out_b - alias_b) < CHIP_HBM_BYTES

    useful = None
    if model_flops:
        useful = model_flops / max(flops * chips, 1.0)
    return RooflineReport(
        name=name, flops=flops, bytes_accessed=byts, collective_bytes=cbytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        dominant=dom, model_flops=model_flops, useful_ratio=useful,
        arg_bytes=arg_b, temp_bytes=tmp_b, out_bytes=out_b, fits_hbm=fits,
        collectives=colls)


def model_flops_estimate(cfg, shape) -> float:
    """6·N·D for training, 2·N·D for a forward/prefill, 2·N_active per
    decoded token (N = active params)."""
    n_act = cfg.active_param_count()
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_act * tokens
    if shape.kind == "prefill":
        return 2.0 * n_act * tokens
    return 2.0 * n_act * shape.global_batch  # decode: one token per request
