"""Mixture-of-Experts FFN: top-k routing, capacity-bounded sort-based dispatch,
optional shared experts (DeepSeek-V2 style), load-balance auxiliary loss.

Dispatch strategy (TPU adaptation): tokens are flattened, expanded top-k ways,
sorted by expert id, and scattered into a dense (E, capacity, d) buffer that
feeds one batched einsum per projection — so expert compute is
``E · cap · d · d_ff`` real FLOPs (≈ tokens · top_k · d · d_ff), not the
``· n_experts`` blow-up of a dense one-hot dispatch.  With experts sharded on
the "model" mesh axis this layout is what GSPMD turns into the expert-parallel
all-to-all.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distribution.constraints import (batch_axes, constrain,
                                            constrain_batch_dim,
                                            model_axis_size)
from repro.models.config import ModelConfig
from repro.models.layers import dense_init

Params = Dict[str, Any]


def _batch_spec_if_divisible(B: int):
    """Data-parallel axes for a batch of B rows, or None when B does not
    divide them (decode reshapes to B=1: forcing a shard is a pessimization)."""
    dp = batch_axes()
    if dp is None:
        return None
    import jax as _jax
    m = _jax.sharding.get_abstract_mesh()
    names = dp if isinstance(dp, tuple) else (dp,)
    total = 1
    for a in names:
        total *= m.shape[a]
    return dp if (B % total == 0 and B >= total) else None


def moe_capacity(cfg: ModelConfig, n_tokens: int) -> int:
    mo = cfg.moe
    if n_tokens * mo.top_k <= 256:
        # tiny sequences (smoke tests, small decode batches): drop-free
        # capacity so the dense dispatch agrees exactly with the gather path
        return n_tokens * mo.top_k
    cap = int(math.ceil(n_tokens * mo.top_k / mo.n_experts * mo.capacity_factor))
    # round up to a lane-friendly multiple
    return max(8, -(-cap // 8) * 8)


def init_moe(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    mo = cfg.moe
    d, ffe, E = cfg.d_model, mo.d_expert, mo.n_experts
    ks = jax.random.split(key, 4)
    kg, ku = jax.random.split(ks[1])
    p = {
        "router": dense_init(ks[0], d, E, dtype, scale=0.02),
        # separate gate/up per expert (see layers.init_mlp: fused + split =
        # cross-shard redistribution); down: (E, ffe, d) row-parallel
        "we_g": (jax.random.normal(kg, (E, d, ffe)) / math.sqrt(d)).astype(dtype),
        "we_u": (jax.random.normal(ku, (E, d, ffe)) / math.sqrt(d)).astype(dtype),
        "we_o": (jax.random.normal(ks[2], (E, ffe, d)) / math.sqrt(ffe)).astype(dtype),
    }
    if mo.n_shared:
        k1, k2, k3 = jax.random.split(ks[3], 3)
        p["shared_wg"] = dense_init(k1, d, ffe * mo.n_shared, dtype)
        p["shared_wu"] = dense_init(k2, d, ffe * mo.n_shared, dtype)
        p["shared_wo"] = dense_init(k3, ffe * mo.n_shared, d, dtype)
    return p


def apply_moe(p: Params, cfg: ModelConfig, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output (B,S,d), aux load-balance loss scalar).

    Dispatch is **per batch row**: each row sorts its own S·top_k slots into
    an (E, cap, d) buffer.  Independent rows keep the batch dim shardable on
    "data" (a global token sort would force GSPMD to replicate the whole
    token stream — observed as 100+ GB/device temps before this change), and
    the (B, E, cap, d) layout against experts sharded on "model" is what
    lowers to the expert-parallel exchange.
    """
    mo = cfg.moe
    B0, S0, d = x.shape
    if S0 == 1 and B0 > 1:
        # decode: the batch *is* the token stream — dispatch it as one row so
        # expert buffers stay (E, cap≈B·K/E) instead of B separate buffers
        x = x.reshape(1, B0, d)
    B, S, _ = x.shape
    E, K = mo.n_experts, mo.top_k
    cap = moe_capacity(cfg, S)

    if B * S <= 16:
        out, aux = _moe_gather_path(p, cfg, x)
        return out.reshape(B0, S0, d), aux

    logits = (x @ p["router"]).astype(jnp.float32)             # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)            # (B, S, K)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    # load-balance aux loss (Switch/GShard form), global means
    me = probs.mean(axis=(0, 1))                               # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[expert_ids.reshape(-1)].add(
        1.0 / (B * S * K))
    aux = E * jnp.sum(me * ce) * mo.router_aux_weight

    # ---- per-row sort-based dispatch ---------------------------------------
    TK = S * K
    flat_e = expert_ids.reshape(B, TK)
    flat_t = jnp.broadcast_to(jnp.repeat(jnp.arange(S), K)[None], (B, TK))
    flat_g = gate_vals.reshape(B, TK)
    order = jnp.argsort(flat_e, axis=1)                        # (B, TK) stable
    se = jnp.take_along_axis(flat_e, order, axis=1)
    st = jnp.take_along_axis(flat_t, order, axis=1)
    sg = jnp.take_along_axis(flat_g, order, axis=1)
    # rank within expert segment, per row
    seg_start = jax.vmap(lambda row: jnp.searchsorted(row, jnp.arange(E)))(se)
    pos_in_e = jnp.arange(TK)[None] - jnp.take_along_axis(seg_start, se, axis=1)
    keep = pos_in_e < cap
    dest = se * cap + jnp.where(keep, pos_in_e, 0)             # (B, TK)

    xe = jnp.zeros((B, E * cap, d), x.dtype)
    # keep the token gather purely local per data rank: if GSPMD lets the
    # operand drift to a model-sharded layout the gather becomes a partial
    # sum + 0.9 TB/device of all-reduces (deepseek-v2, §Perf).  All pins are
    # divisibility-checked — decode reshapes to B=1 rows, where forcing a
    # batch shard would be a pessimization (observed on grok decode).
    x = constrain_batch_dim(x)
    st = constrain_batch_dim(st)
    src = jnp.take_along_axis(x, st[..., None], axis=1)        # (B, TK, d)
    src = constrain_batch_dim(src)
    xe = jax.vmap(lambda buf, idx, val: buf.at[idx].add(val))(
        xe, dest, jnp.where(keep[..., None], src, 0))
    # Pin the dispatch buffer: the vmap scatter is per-row independent, but
    # GSPMD propagates the replicated zeros-init through it, making every
    # data rank's expert buffer a PARTIAL sum — the downstream einsums then
    # all-reduce (B,E,cap,f)-sized tensors over the data axis (observed
    # 5 TB/device per einsum on grok-1; EXPERIMENTS.md §Perf).  When the
    # expert count divides the model axis, additionally shard the flattened
    # (E·cap) dim on "model" — expert parallelism; pinning it replicated
    # instead costs 0.5 TB/device of gathers on deepseek-v2 (160 experts).
    mdl = model_axis_size()
    espec = "model" if (mdl and E % mdl == 0) else None
    bspec = _batch_spec_if_divisible(B)
    xe = constrain(xe, bspec, espec, None)
    xe = xe.reshape(B, E, cap, d)
    xe = constrain(xe, bspec, espec, None, None)

    g = jnp.einsum("becd,edf->becf", xe, p["we_g"])            # (B, E, cap, ffe)
    u = jnp.einsum("becd,edf->becf", xe, p["we_u"])
    ye = jnp.einsum("becf,efd->becd", jax.nn.silu(g) * u, p["we_o"])
    ye = ye.reshape(B, E * cap, d)

    contrib = jnp.take_along_axis(ye, dest[..., None], axis=1)
    contrib = contrib * (sg * keep)[..., None].astype(ye.dtype)
    out = jax.vmap(lambda buf, idx, val: buf.at[idx].add(val))(
        jnp.zeros((B, S, d), x.dtype), st, contrib.astype(x.dtype))
    out = constrain_batch_dim(out)  # same scatter-propagation hazard as xe

    if mo.n_shared:
        out = out + (jax.nn.silu(x @ p["shared_wg"]) * (x @ p["shared_wu"])) @ p["shared_wo"]
    return out.reshape(B0, S0, d), aux


def _moe_gather_path(p: Params, cfg: ModelConfig, x: jnp.ndarray):
    """Few-token path (e.g. batch-1 long-context decode): gather the top-k
    experts' weights per token instead of running the dense (E, cap) dispatch
    — E/K× less FLOPs when almost every expert slot would be padding."""
    mo = cfg.moe
    B, S, d = x.shape
    E, K = mo.n_experts, mo.top_k
    xt = x.reshape(B * S, d)
    logits = (xt @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)            # (T, K)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)
    aux = jnp.zeros((), jnp.float32)  # no load-balance pressure at decode

    wg = p["we_g"][expert_ids]                                 # (T, K, d, ffe)
    wu = p["we_u"][expert_ids]
    wo = p["we_o"][expert_ids]                                 # (T, K, ffe, d)
    g = jnp.einsum("td,tkdf->tkf", xt, wg)
    u = jnp.einsum("td,tkdf->tkf", xt, wu)
    ye = jnp.einsum("tkf,tkfd->tkd", jax.nn.silu(g) * u, wo)
    out = jnp.einsum("tkd,tk->td", ye, gate_vals.astype(ye.dtype))
    if mo.n_shared:
        out = out + (jax.nn.silu(xt @ p["shared_wg"]) * (xt @ p["shared_wu"])) @ p["shared_wo"]
    return out.reshape(B, S, d), aux
