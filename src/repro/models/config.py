"""Model configuration system.

One :class:`ModelConfig` describes any of the six assigned architecture
families (dense / moe / ssm / hybrid / audio / vlm).  A model is a stack of
*periods*: a period is a short tuple of (mixer, ffn) layer descriptors that
repeats ``n_layers / len(period)`` times — period length 1 for homogeneous
stacks, 8 for Jamba's 1:7 attention:mamba interleave.  The period structure
is what lets the runtime ``lax.scan`` over stacked per-period parameters and
keep the HLO small enough to AOT-compile 80 (arch × shape × mesh) dry-runs.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# mixer kinds: "attn" (GQA), "mla", "mamba", "rwkv", "none"
# ffn kinds:   "mlp" (SwiGLU), "gelu_mlp", "moe", "rwkv_cmix"


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora: int = 512
    q_lora: Optional[int] = None
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    # Mamba-1 selective SSM (Jamba's mixer)
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None  # default ceil(d_model / 16)


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64   # rank of the data-dependent decay LoRA (Finch)
    tokenshift_lora: int = 32


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None   # default d_model // n_heads
    period: Tuple[Tuple[str, str], ...] = (("attn", "mlp"),)
    qkv_bias: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    rope: str = "rope"               # rope | mrope | none
    rope_theta: float = 1e6
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    enc_dec: bool = False            # whisper-style encoder-decoder
    n_enc_layers: int = 0
    sliding_window: Optional[int] = None  # used by long_500k attention variant
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    act: str = "silu"
    # source citation for the numbers above
    source: str = ""

    # ---------------- derived ----------------
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def n_periods(self) -> int:
        assert self.n_layers % len(self.period) == 0, (self.name, self.n_layers, len(self.period))
        return self.n_layers // len(self.period)

    @property
    def attn_free(self) -> bool:
        return all(m not in ("attn", "mla") for m, _ in self.period)

    @property
    def has_state_mixer(self) -> bool:
        return any(m in ("mamba", "rwkv") for m, _ in self.period)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab
        total = V * d  # embed
        if not self.tie_embeddings:
            total += d * V
        per_period = 0
        for mixer, ffn in self.period:
            per_period += 2 * d  # two pre-norms
            if mixer == "attn":
                hd = self.hd
                per_period += d * self.n_heads * hd + 2 * d * self.n_kv * hd + self.n_heads * hd * d
                if self.qkv_bias:
                    per_period += (self.n_heads + 2 * self.n_kv) * hd
            elif mixer == "mla":
                m = self.mla
                qk = m.qk_nope_dim + m.qk_rope_dim
                per_period += d * self.n_heads * qk          # W_q
                per_period += d * m.kv_lora + d * m.qk_rope_dim
                per_period += m.kv_lora * self.n_heads * (m.qk_nope_dim + m.v_head_dim)
                per_period += self.n_heads * m.v_head_dim * d
            elif mixer == "mamba":
                s = self.ssm
                di = s.expand * d
                dtr = s.dt_rank or -(-d // 16)
                per_period += d * 2 * di + di * s.d_conv + di * (dtr + 2 * s.d_state)
                per_period += dtr * di + di * s.d_state + di + di * d
            elif mixer == "rwkv":
                per_period += 4 * d * d + d * d  # r,k,v,o,gate
                per_period += 2 * d * self.rwkv.decay_lora  # decay lora
            if ffn == "mlp":
                per_period += 3 * d * ff
            elif ffn == "gelu_mlp":
                per_period += 2 * d * ff
            elif ffn == "moe":
                mo = self.moe
                per_period += d * mo.n_experts
                per_period += mo.n_experts * 3 * d * mo.d_expert
                per_period += mo.n_shared * 3 * d * mo.d_expert
            elif ffn == "rwkv_cmix":
                per_period += d * int(3.5 * d) + int(3.5 * d) * d
        total += per_period * self.n_periods
        if self.enc_dec:
            # encoder blocks (attn + gelu_mlp) + decoder cross-attn
            hd = self.hd
            enc = self.n_enc_layers * (2 * d + d * self.n_heads * hd * 2 +
                                       2 * d * self.n_kv * hd + 2 * d * ff + 2 * d)
            cross = self.n_layers * (d + d * self.n_heads * hd + 2 * d * self.n_kv * hd +
                                     self.n_heads * hd * d)
            total += enc + cross
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k + shared only)."""
        if self.moe is None:
            return self.param_count()
        mo = self.moe
        moe_layers = sum(1 for _, f in self.period if f == "moe") * self.n_periods
        inactive = (mo.n_experts - mo.top_k) * 3 * self.d_model * mo.d_expert * moe_layers
        return self.param_count() - inactive

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: <=2 periods, d_model<=256, <=4 experts."""
        d = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        ratio = max(1, self.n_heads // max(self.n_kv, 1))
        n_kv = max(1, n_heads // min(ratio, n_heads))
        hd = 64
        moe = None
        if self.moe:
            moe = dataclasses.replace(self.moe, n_experts=4, top_k=min(self.moe.top_k, 2),
                                      d_expert=128, n_shared=min(self.moe.n_shared, 1))
        mla = dataclasses.replace(self.mla, kv_lora=64, qk_nope_dim=32, qk_rope_dim=16,
                                  v_head_dim=32) if self.mla else None
        rwkv = dataclasses.replace(self.rwkv, head_dim=32, decay_lora=16) if self.rwkv else None
        n_layers = len(self.period) * min(self.n_periods, 2 if len(self.period) == 1 else 1)
        sec = self.mrope_sections
        if self.rope == "mrope" and sum(sec) != hd // 2:
            s = hd // 2
            sec = (s // 4, s // 4 + s // 8, s - s // 4 - (s // 4 + s // 8))
        return dataclasses.replace(
            self, name=self.name + "-reduced", n_layers=n_layers, d_model=d,
            n_heads=n_heads, n_kv=n_kv, head_dim=hd, d_ff=min(self.d_ff, 512),
            vocab=min(self.vocab, 1024), moe=moe, mla=mla, rwkv=rwkv,
            mrope_sections=sec,
            n_enc_layers=min(self.n_enc_layers, 2), sliding_window=None,
        )


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
