"""Top-level language model: embedding → period stack → norm → head.

Covers all six assigned families behind one functional API:

* ``init_lm``            — parameter pytree (or its shape tree via eval_shape)
* ``forward_train``      — tokens → loss (chunked vocab cross-entropy)
* ``prefill``            — tokens → (last-position logits, filled caches)
* ``decode_step``        — one token with caches (serve_step's core)
* ``make_caches``        — decode-state pytree for a (cfg, batch, cache_len)

VLM (qwen2-vl): precomputed patch embeddings are spliced over the first
``n_vis`` token positions and M-RoPE takes (3, B, S) position ids.
Audio (whisper): precomputed frame embeddings feed a bidirectional encoder;
the decoder cross-attends (frontends stubbed per assignment carve-out).
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distribution.constraints import constrain_batch_dim
from repro.models.config import ModelConfig
from repro.models.layers import dense_init, rmsnorm
from repro.models.transformer import apply_stack, init_stack, stack_cache_init

Params = Dict[str, Any]

ENC_PERIOD = (("attn", "gelu_mlp"),)  # whisper encoder layers


@dataclasses.dataclass(frozen=True)
class RunFlags:
    """Execution knobs (not architecture): set by launcher / perf configs."""
    window: Optional[int] = None       # sliding-window attention (long_500k)
    mla_absorb: bool = False           # MLA latent-space decode
    block_q: int = 1024                # q-block size of the online attention
    remat: bool = False                # activation checkpointing over periods
    loss_chunk: int = 512              # seq chunk for vocab cross-entropy


def cast_params(p: Params, dtype) -> Params:
    """Mixed precision: compute in ``dtype`` against f32 master params.
    The cast is differentiable, so grads flow back to the f32 leaves."""
    return jax.tree.map(
        lambda a: a.astype(dtype) if a.dtype in (jnp.float32, jnp.bfloat16) else a, p)


def sinusoid_pos(S: int, d: int, dtype) -> jnp.ndarray:
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d)
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    return pe.astype(dtype)


def init_lm(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 5)
    p: Params = {
        "embed": (jax.random.normal(ks[0], (cfg.vocab, cfg.d_model)) * 0.02).astype(dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "blocks": init_stack(ks[1], cfg, dtype, with_cross=cfg.enc_dec),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[2], cfg.d_model, cfg.vocab, dtype, scale=0.02)
    if cfg.enc_dec:
        p["enc_blocks"] = init_stack(ks[3], cfg, dtype, period=ENC_PERIOD,
                                     n_periods=cfg.n_enc_layers)
        p["enc_norm"] = jnp.ones((cfg.d_model,), dtype)
    return p


def _embed(p: Params, cfg: ModelConfig, tokens: jnp.ndarray,
           vision_embed: Optional[jnp.ndarray], dtype) -> jnp.ndarray:
    x = p["embed"][tokens].astype(dtype)
    if vision_embed is not None:
        nv = vision_embed.shape[1]
        x = jnp.concatenate([vision_embed.astype(dtype), x[:, nv:, :]], axis=1)
    return constrain_batch_dim(x)


def _head(p: Params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    x = rmsnorm(x, p["final_norm"], cfg.norm_eps)
    w = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    return x @ w.astype(x.dtype)


def _encode(p: Params, cfg: ModelConfig, audio_embed: jnp.ndarray,
            flags: RunFlags) -> jnp.ndarray:
    x = audio_embed + sinusoid_pos(audio_embed.shape[1], cfg.d_model, audio_embed.dtype)
    pos = jnp.broadcast_to(jnp.arange(x.shape[1])[None], x.shape[:2])
    x, _, _ = apply_stack(p["enc_blocks"], cfg, x, pos, period=ENC_PERIOD,
                          causal=False, block_q=flags.block_q, remat=flags.remat)
    return rmsnorm(x, p["enc_norm"], cfg.norm_eps)


def _positions(cfg: ModelConfig, batch: Dict[str, jnp.ndarray], B: int, S: int):
    if cfg.rope == "mrope":
        if "rope_pos" in batch:
            return batch["rope_pos"]
        base = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        return jnp.broadcast_to(base[None], (3, B, S))
    return jnp.broadcast_to(jnp.arange(S)[None], (B, S))


def forward_train(
    p: Params,
    cfg: ModelConfig,
    batch: Dict[str, jnp.ndarray],
    flags: RunFlags = RunFlags(),
    dtype=jnp.bfloat16,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Returns (loss, metrics).  batch: tokens, targets [, vision_embed,
    rope_pos, audio_embed]."""
    p = cast_params(p, dtype)
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = _embed(p, cfg, tokens, batch.get("vision_embed"), dtype)
    cross_y = None
    if cfg.enc_dec:
        cross_y = _encode(p, cfg, batch["audio_embed"].astype(dtype), flags)
        x = x + sinusoid_pos(S, cfg.d_model, x.dtype)
    positions = _positions(cfg, batch, B, S)
    x, _, aux = apply_stack(p["blocks"], cfg, x, positions, causal=True,
                            cross_y=cross_y, block_q=flags.block_q,
                            remat=flags.remat)
    loss, metrics = chunked_ce_loss(p, cfg, x, batch["targets"], flags)
    loss = loss + aux
    metrics["aux_loss"] = aux
    return loss, metrics


def chunked_ce_loss(p: Params, cfg: ModelConfig, x: jnp.ndarray,
                    targets: jnp.ndarray, flags: RunFlags):
    """Cross-entropy without materializing (B, S, vocab) at once: lax.map
    over sequence chunks keeps live logits at (B, chunk, vocab)."""
    B, S, d = x.shape
    chunk = min(flags.loss_chunk, S)
    nb = S // chunk
    assert S % chunk == 0, (S, chunk)
    xb = x.reshape(B, nb, chunk, d).transpose(1, 0, 2, 3)
    tb = targets.reshape(B, nb, chunk).transpose(1, 0, 2)

    @partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable)
    def one(args):
        xc, tc = args
        logits = _head(p, cfg, xc).astype(jnp.float32)  # (B, chunk, V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        return (lse - tgt).sum(), (logits.argmax(-1) == tc).sum()

    losses, hits = jax.lax.map(one, (xb, tb))
    n = B * S
    return losses.sum() / n, {"acc": hits.sum() / n}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def make_caches(cfg: ModelConfig, batch: int, cache_len: int, dtype=jnp.bfloat16,
                enc_len: int = 0) -> Params:
    return stack_cache_init(cfg, batch, cache_len, dtype,
                            with_cross=cfg.enc_dec, enc_len=enc_len)


def prefill(
    p: Params,
    cfg: ModelConfig,
    batch: Dict[str, jnp.ndarray],
    caches: Params,
    flags: RunFlags = RunFlags(),
    dtype=jnp.bfloat16,
) -> Tuple[jnp.ndarray, Params]:
    """Run the prompt through the model, filling ``caches`` from index 0.
    Returns (logits at last position, caches)."""
    p = cast_params(p, dtype)
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = _embed(p, cfg, tokens, batch.get("vision_embed"), dtype)
    cross_y = None
    if cfg.enc_dec:
        cross_y = _encode(p, cfg, batch["audio_embed"].astype(dtype), flags)
        x = x + sinusoid_pos(S, cfg.d_model, x.dtype)
    positions = _positions(cfg, batch, B, S)
    x, new_caches, _ = apply_stack(
        p["blocks"], cfg, x, positions, causal=True, window=flags.window,
        caches=caches, cache_index=jnp.int32(0), cross_y=cross_y,
        block_q=flags.block_q)
    logits = _head(p, cfg, x[:, -1:, :])
    return logits, new_caches


def decode_step(
    p: Params,
    cfg: ModelConfig,
    caches: Params,
    tokens: jnp.ndarray,        # (B, 1)
    pos: jnp.ndarray,           # scalar int32: absolute position
    flags: RunFlags = RunFlags(),
    dtype=jnp.bfloat16,
) -> Tuple[jnp.ndarray, Params]:
    """One decode step: logits for the new token, updated caches."""
    B, S = tokens.shape
    p = cast_params(p, dtype)
    x = _embed(p, cfg, tokens, None, dtype)
    if cfg.enc_dec:
        # sinusoid at the (traced) absolute position — no table needed
        dim = jnp.arange(0, cfg.d_model, 2, dtype=jnp.float32)[None, :]
        ang = pos.astype(jnp.float32) / jnp.power(10000.0, dim / cfg.d_model)
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
        x = x + pe[None].astype(x.dtype)
    if cfg.rope == "mrope":
        positions = jnp.broadcast_to(pos[None, None], (B, 1))[None].repeat(3, 0)
    else:
        positions = jnp.broadcast_to(pos[None, None], (B, 1))
    x, new_caches, _ = apply_stack(
        p["blocks"], cfg, x, positions, causal=True, window=flags.window,
        caches=caches, cache_index=pos, mla_absorb=flags.mla_absorb,
        block_q=flags.block_q)
    logits = _head(p, cfg, x)
    return logits, new_caches
