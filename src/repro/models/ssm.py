"""State-space / recurrent mixers: Mamba-1 selective SSM and RWKV-6 (Finch).

Both offer a full-sequence ``apply_*`` (training/prefill; ``lax.scan`` over
time carrying only the O(1)-per-token state, never materializing the
(S, d_inner, d_state) tensor — the TPU-memory-hierarchy adaptation recorded
in DESIGN.md) and a single-token ``*_decode_step`` used by ``serve_step``.
Pallas chunked kernels for the same recurrences live in ``repro.kernels``.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init, rmsnorm

Params = Dict[str, Any]


def chunked_scan(step, carry, xs, chunk: int = 64):
    """Two-level ``lax.scan`` with a rematerialized inner scan.

    Backward through a plain length-S scan would store the O(d·d_state) carry
    at every step (hundreds of GB for these mixers).  Chunking stores carries
    only at chunk boundaries (S/chunk of them) and recomputes inside each
    chunk — the standard linear-RNN memory/compute trade, matched to TPU HBM.
    ``xs`` leaves are (S, ...) time-major; S must divide by ``chunk`` (the
    caller pads or picks chunk accordingly).
    """
    S = jax.tree.leaves(xs)[0].shape[0]
    if S <= chunk or S % chunk != 0:
        return jax.lax.scan(step, carry, xs)
    nb = S // chunk
    xs_b = jax.tree.map(lambda a: a.reshape((nb, chunk) + a.shape[1:]), xs)

    @jax.checkpoint
    def outer(c, xb):
        return jax.lax.scan(step, c, xb)

    carry, ys_b = jax.lax.scan(outer, carry, xs_b)
    ys = jax.tree.map(lambda a: a.reshape((S,) + a.shape[2:]), ys_b)
    return carry, ys


# ===========================================================================
# Mamba-1 selective SSM (Jamba's mixer)
# ===========================================================================

def mamba_dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    s = cfg.ssm
    di = s.expand * cfg.d_model
    dtr = s.dt_rank or -(-cfg.d_model // 16)
    return di, s.d_state, s.d_conv, dtr


def init_mamba(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    d = cfg.d_model
    di, ds, dc, dtr = mamba_dims(cfg)
    ks = jax.random.split(key, 6)
    k_in_x, k_in_z = jax.random.split(ks[0])
    return {
        # separate x/z in-projections (fused + split = cross-shard
        # redistribution when column-sharded; see layers.init_mlp)
        "in_x": dense_init(k_in_x, d, di, dtype),
        "in_z": dense_init(k_in_z, d, di, dtype),
        "conv_w": (jax.random.normal(ks[1], (dc, di)) / math.sqrt(dc)).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], di, dtr + 2 * ds, dtype),
        "dt_proj": dense_init(ks[3], dtr, di, dtype),
        "dt_bias": jnp.full((di,), -4.6, dtype),  # softplus^-1(~0.01)
        "A_log": jnp.log(jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))).astype(dtype),
        "D": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[4], di, d, dtype),
    }


def _mamba_conv_full(xs: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Causal depthwise conv over time. xs: (B,S,di), w: (dc,di)."""
    dc = w.shape[0]
    pad = jnp.pad(xs, ((0, 0), (dc - 1, 0), (0, 0)))
    out = jnp.zeros_like(xs)
    for i in range(dc):  # dc is 4: unrolled adds, no conv primitive needed
        out = out + pad[:, i:i + xs.shape[1], :] * w[i]
    return out + b


def _mamba_ssm_inputs(p: Params, cfg: ModelConfig, xc: jnp.ndarray):
    """From conv'd activations to (Δ, B, C) selective parameters."""
    di, ds, _, dtr = mamba_dims(cfg)
    proj = xc @ p["x_proj"]
    dt, Bs, Cs = jnp.split(proj, [dtr, dtr + ds], axis=-1)
    delta = jax.nn.softplus(dt @ p["dt_proj"] + p["dt_bias"].astype(dt.dtype))
    return delta, Bs, Cs


def apply_mamba(
    p: Params,
    cfg: ModelConfig,
    x: jnp.ndarray,  # (B, S, d)
    state: Optional[Params] = None,  # decode: {"conv": (B,dc-1,di), "h": (B,di,ds)}
) -> Tuple[jnp.ndarray, Optional[Params]]:
    B, S, d = x.shape
    di, ds, dc, dtr = mamba_dims(cfg)
    xin = x @ p["in_x"]
    z = x @ p["in_z"]

    if state is None or S > 1:
        # training, or prefill continuing from a carried state
        if state is not None:
            pad = jnp.concatenate([state["conv"].astype(xin.dtype), xin], axis=1)
            xc = _mamba_conv_full(pad, p["conv_w"], p["conv_b"])[:, dc - 1:, :]
        else:
            xc = _mamba_conv_full(xin, p["conv_w"], p["conv_b"])
        xc = jax.nn.silu(xc)
        delta, Bs, Cs = _mamba_ssm_inputs(p, cfg, xc)
        A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (di, ds)

        def step(h, t):
            d_t, B_t, C_t, x_t = t  # (B,di) (B,ds) (B,ds) (B,di)
            dA = jnp.exp(d_t[..., None].astype(jnp.float32) * A)         # (B,di,ds)
            dBx = (d_t * x_t)[..., None] * B_t[:, None, :]               # (B,di,ds)
            h = dA * h + dBx.astype(jnp.float32)
            y_t = jnp.einsum("bds,bs->bd", h, C_t.astype(jnp.float32))
            return h, y_t.astype(x.dtype)

        h0 = jnp.zeros((B, di, ds), jnp.float32) if state is None else state["h"]
        xs = (delta.transpose(1, 0, 2), Bs.transpose(1, 0, 2),
              Cs.transpose(1, 0, 2), xc.transpose(1, 0, 2))
        hT, ys = chunked_scan(step, h0, xs)
        y = ys.transpose(1, 0, 2) + xc * p["D"].astype(xc.dtype)
        out = (y * jax.nn.silu(z)) @ p["out_proj"]
        new_state = None
        if state is not None:
            new_state = {"conv": jnp.concatenate([state["conv"].astype(xin.dtype), xin],
                                                 axis=1)[:, -(dc - 1):, :].astype(state["conv"].dtype),
                         "h": hT}
        return out, new_state

    # ---- decode: single token ------------------------------------------------
    assert S == 1
    conv_st = state["conv"]  # (B, dc-1, di)
    window = jnp.concatenate([conv_st, xin], axis=1)  # (B, dc, di)
    xc = jax.nn.silu(jnp.einsum("bci,ci->bi", window, p["conv_w"]) + p["conv_b"])[:, None, :]
    delta, Bs, Cs = _mamba_ssm_inputs(p, cfg, xc)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    d_t, B_t, C_t, x_t = delta[:, 0], Bs[:, 0], Cs[:, 0], xc[:, 0]
    h = state["h"]
    dA = jnp.exp(d_t[..., None].astype(jnp.float32) * A)
    dBx = (d_t * x_t)[..., None] * B_t[:, None, :]
    h = dA * h + dBx.astype(jnp.float32)
    y = jnp.einsum("bds,bs->bd", h, C_t.astype(jnp.float32)).astype(x.dtype)
    y = y[:, None, :] + xc * p["D"].astype(xc.dtype)
    out = (y * jax.nn.silu(z)) @ p["out_proj"]
    new_state = {"conv": window[:, 1:, :], "h": h}
    return out, new_state


def mamba_state_init(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Params:
    di, ds, dc, _ = mamba_dims(cfg)
    return {"conv": jnp.zeros((batch, dc - 1, di), dtype),
            "h": jnp.zeros((batch, di, ds), jnp.float32)}


# ===========================================================================
# RWKV-6 "Finch" time-mix + channel-mix
# ===========================================================================

def rwkv_dims(cfg: ModelConfig) -> Tuple[int, int]:
    hd = cfg.rwkv.head_dim
    assert cfg.d_model % hd == 0
    return cfg.d_model // hd, hd


def init_rwkv_tmix(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    d = cfg.d_model
    H, hd = rwkv_dims(cfg)
    lora = cfg.rwkv.decay_lora
    ks = jax.random.split(key, 8)
    return {
        # static token-shift mixing coefficients (Finch uses LoRA-dynamic ones;
        # we keep the decay LoRA — the architecture's core novelty — and use
        # static shift mixes; recorded in DESIGN.md)
        "mu_r": jnp.full((d,), 0.5, dtype), "mu_k": jnp.full((d,), 0.5, dtype),
        "mu_v": jnp.full((d,), 0.5, dtype), "mu_w": jnp.full((d,), 0.5, dtype),
        "mu_g": jnp.full((d,), 0.5, dtype),
        "wr": dense_init(ks[0], d, d, dtype), "wk": dense_init(ks[1], d, d, dtype),
        "wv": dense_init(ks[2], d, d, dtype), "wg": dense_init(ks[3], d, d, dtype),
        "wo": dense_init(ks[4], d, d, dtype),
        # data-dependent decay LoRA:  w_t = exp(-exp(w0 + tanh(x̃ A) B))
        "w0": jnp.full((d,), -2.0, dtype),
        "wA": dense_init(ks[5], d, lora, dtype),
        "wB": dense_init(ks[6], lora, d, dtype, scale=0.01),
        "u": (jax.random.normal(ks[7], (H, hd)) * 0.1).astype(dtype),
        "ln_scale": jnp.ones((d,), dtype),
    }


def _token_shift(x: jnp.ndarray, prev: Optional[jnp.ndarray]) -> jnp.ndarray:
    """x_{t-1} stream: zeros (or carried last token) at t=0."""
    if prev is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1, :]
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _rwkv_gates(p: Params, cfg: ModelConfig, x: jnp.ndarray, xprev: jnp.ndarray):
    H, hd = rwkv_dims(cfg)
    B, S, d = x.shape

    def mix(mu):
        return x + (xprev - x) * mu.astype(x.dtype)

    r = (mix(p["mu_r"]) @ p["wr"]).reshape(B, S, H, hd)
    k = (mix(p["mu_k"]) @ p["wk"]).reshape(B, S, H, hd)
    v = (mix(p["mu_v"]) @ p["wv"]).reshape(B, S, H, hd)
    g = jax.nn.silu(mix(p["mu_g"]) @ p["wg"])
    logw = p["w0"].astype(jnp.float32) + jnp.tanh(mix(p["mu_w"]).astype(jnp.float32)
                                                  @ p["wA"].astype(jnp.float32)) @ p["wB"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(logw)).reshape(B, S, H, hd)  # data-dependent decay ∈ (0,1)
    return r, k, v, g, w


def apply_rwkv_tmix(
    p: Params,
    cfg: ModelConfig,
    x: jnp.ndarray,
    state: Optional[Params] = None,  # {"shift": (B,d), "wkv": (B,H,hd,hd)}
) -> Tuple[jnp.ndarray, Optional[Params]]:
    B, S, d = x.shape
    H, hd = rwkv_dims(cfg)
    xprev = _token_shift(x, None if state is None else state["shift"])
    r, k, v, g, w = _rwkv_gates(p, cfg, x, xprev)
    u = p["u"].astype(jnp.float32)

    def step(Swkv, t):
        r_t, k_t, v_t, w_t = t  # (B,H,hd) each
        kv = k_t[..., :, None] * v_t[..., None, :]           # (B,H,hd,hd)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, Swkv + u[..., None] * kv)
        Swkv = w_t[..., :, None] * Swkv + kv
        return Swkv, y

    S0 = jnp.zeros((B, H, hd, hd), jnp.float32) if state is None else state["wkv"]
    xs = tuple(a.astype(jnp.float32).transpose(1, 0, 2, 3) for a in (r, k, v, w))
    ST, ys = chunked_scan(step, S0, xs)
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, d)
    # per-head group norm
    y = rmsnorm(y.reshape(B, S, H, hd), jnp.ones((hd,), x.dtype), cfg.norm_eps).reshape(B, S, d)
    y = y * p["ln_scale"].astype(x.dtype)
    out = (y.astype(x.dtype) * g) @ p["wo"]
    new_state = None
    if state is not None:
        new_state = {"shift": x[:, -1, :], "wkv": ST}
    return out, new_state


def rwkv_tmix_state_init(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Params:
    H, hd = rwkv_dims(cfg)
    return {"shift": jnp.zeros((batch, cfg.d_model), dtype),
            "wkv": jnp.zeros((batch, H, hd, hd), jnp.float32)}


def init_rwkv_cmix(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.full((d,), 0.5, dtype), "mu_r": jnp.full((d,), 0.5, dtype),
        "wk": dense_init(ks[0], d, ff, dtype),
        "wv": dense_init(ks[1], ff, d, dtype),
        "wr": dense_init(ks[2], d, d, dtype),
    }


def apply_rwkv_cmix(
    p: Params,
    cfg: ModelConfig,
    x: jnp.ndarray,
    state: Optional[Params] = None,  # {"shift": (B,d)}
) -> Tuple[jnp.ndarray, Optional[Params]]:
    xprev = _token_shift(x, None if state is None else state["shift"])

    def mix(mu):
        return x + (xprev - x) * mu.astype(x.dtype)

    k = jnp.square(jax.nn.relu(mix(p["mu_k"]) @ p["wk"]))
    r = jax.nn.sigmoid(mix(p["mu_r"]) @ p["wr"])
    out = r * (k @ p["wv"])
    new_state = None if state is None else {"shift": x[:, -1, :]}
    return out, new_state


def rwkv_cmix_state_init(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Params:
    return {"shift": jnp.zeros((batch, cfg.d_model), dtype)}
