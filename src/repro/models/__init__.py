from repro.models import config, layers, model, moe, ssm, transformer  # noqa: F401
