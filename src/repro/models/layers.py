"""Shared neural layers: norms, RoPE / M-RoPE, GQA + MLA attention, MLPs.

Everything is functional: ``init_*`` builds a param dict, ``apply_*`` consumes
it.  Attention uses a query-block online pass (``lax.map`` over q-blocks) so
long-context prefill never materializes the full (Sq, Skv) score matrix —
the XLA analogue of the Pallas flash kernel in ``repro.kernels``.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------

def rope_freqs(hd_rot: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies for a rotary block of ``hd_rot`` dims."""
    return 1.0 / (theta ** (jnp.arange(0, hd_rot, 2, dtype=jnp.float32) / hd_rot))


def rope_apply(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
               mrope_sections: Optional[Tuple[int, int, int]] = None) -> jnp.ndarray:
    """Rotate ``x`` (..., S, H, hd) by position-dependent angles.

    positions: (B, S) for standard RoPE, (3, B, S) for M-RoPE where the three
    planes are (temporal, height, width) ids and the frequency dims are split
    into ``mrope_sections`` groups (Qwen2-VL §2.1).
    """
    *_, S, H, hd = x.shape
    inv = rope_freqs(hd, theta)  # (hd/2,)
    if mrope_sections is None:
        pos = positions.astype(jnp.float32)  # (B, S)
        ang = pos[..., None] * inv[None, None, :]  # (B, S, hd/2)
    else:
        assert positions.ndim == 3, "M-RoPE needs (3, B, S) position ids"
        sec = mrope_sections
        assert sum(sec) == hd // 2, (sec, hd)
        pos = positions.astype(jnp.float32)  # (3, B, S)
        ang_full = pos[..., None] * inv[None, None, None, :]  # (3, B, S, hd/2)
        parts = []
        start = 0
        for i, s in enumerate(sec):
            parts.append(ang_full[i, :, :, start:start + s])
            start += s
        ang = jnp.concatenate(parts, axis=-1)  # (B, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]  # (B, S, 1, hd/2)
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention core: query-block online pass
# ---------------------------------------------------------------------------

_ATTN_IMPL = "xla"  # "xla" (lax.map online pass) | "pallas" (repro.kernels)


def set_attention_impl(impl: str) -> None:
    """Select the attention backend for cache-less (train/prefill) paths.

    "pallas" routes through the flash kernel in ``repro.kernels`` (on CPU it
    runs interpret=True — correctness path; on TPU the compiled kernel).
    Decode paths (cache writes, ragged validity) always use the XLA pass.
    """
    global _ATTN_IMPL
    assert impl in ("xla", "pallas"), impl
    _ATTN_IMPL = impl


def attention_core(
    q: jnp.ndarray,           # (B, Sq, H, hd)
    k: jnp.ndarray,           # (B, Skv, KV, hd)
    v: jnp.ndarray,           # (B, Skv, KV, hdv)
    *,
    causal: bool,
    q_offset: int | jnp.ndarray = 0,
    window: Optional[int] = None,
    kv_valid_len: Optional[jnp.ndarray] = None,
    block_q: int = 1024,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Exact attention, O(block_q · Skv) live memory.

    ``q_offset``: absolute position of q[0] (decode: the cache index).
    ``window``: sliding-window width (None = full).
    ``kv_valid_len``: mask out cache slots >= this length (decode).
    """
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    if (_ATTN_IMPL == "pallas" and kv_valid_len is None and scale is None
            and isinstance(q_offset, int) and q_offset == 0
            and q.shape[-1] == v.shape[-1]):
        from repro.kernels import ops as _kops
        return _kops.attention(q, k, v, causal=causal, window=window)
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(B, Sq, KV, G, hd)
    kv_idx = jnp.arange(Skv)

    # flash-style: the block body is rematerialized in the backward pass, so
    # the (bq, Skv) score/prob tiles are never stored across blocks.
    @partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable)
    def one_block(args):
        qb, row0 = args  # (B, bq, KV, G, hd), scalar index of first row
        bq = qb.shape[1]
        s = jnp.einsum("bqkgh,bskh->bkgqs", qb.astype(jnp.float32) * scale,
                       k.astype(jnp.float32))
        rows = row0 + jnp.arange(bq) + q_offset  # absolute positions
        mask = jnp.ones((bq, Skv), dtype=bool)
        if causal:
            mask &= kv_idx[None, :] <= rows[:, None]
        if window is not None:
            mask &= kv_idx[None, :] > rows[:, None] - window
        if kv_valid_len is not None:
            mask &= kv_idx[None, :] < kv_valid_len
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
        o = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v)
        return o.reshape(B, bq, H, -1)

    if Sq <= block_q:
        return one_block((qg, jnp.int32(0)))

    nb = Sq // block_q
    assert Sq % block_q == 0, (Sq, block_q)
    qblocks = qg.reshape(B, nb, block_q, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)
    row0s = jnp.arange(nb, dtype=jnp.int32) * block_q
    outs = jax.lax.map(one_block, (qblocks, row0s))  # (nb, B, bq, H, hdv)
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, -1)


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, H * hd, dtype),
        "wk": dense_init(ks[1], d, KV * hd, dtype),
        "wv": dense_init(ks[2], d, KV * hd, dtype),
        "wo": dense_init(ks[3], H * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KV * hd,), dtype)
        p["bv"] = jnp.zeros((KV * hd,), dtype)
    return p


def apply_attention(
    p: Params,
    cfg: ModelConfig,
    x: jnp.ndarray,                      # (B, S, d)
    positions: jnp.ndarray,              # (B, S) or (3, B, S)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    cache: Optional[Params] = None,      # {"k": (B,Sc,KV,hd), "v": ..., } decode
    cache_index: Optional[jnp.ndarray] = None,
    cross_y: Optional[jnp.ndarray] = None,           # encoder output (prefill)
    kv_override: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,  # cross-attn decode
    block_q: int = 1024,
) -> Tuple[jnp.ndarray, Optional[Params]]:
    B, S, d = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    q = x @ p["wq"]
    if "bq" in p:
        q = q + p["bq"].astype(q.dtype)
    q = q.reshape(B, S, H, hd)

    if cross_y is not None:
        # cross-attention: keys/values from the encoder sequence, no RoPE
        Se = cross_y.shape[1]
        k = (cross_y @ p["wk"]).reshape(B, Se, KV, hd)
        v = (cross_y @ p["wv"]).reshape(B, Se, KV, hd)
        out = attention_core(q, k, v, causal=False, block_q=block_q)
        out = out.reshape(B, S, H * hd) @ p["wo"]
        return out, {"k": k, "v": v}  # static cross cache for decode
    if kv_override is not None:
        k, v = kv_override
        out = attention_core(q, k, v, causal=False, block_q=block_q)
        out = out.reshape(B, S, H * hd) @ p["wo"]
        return out, None
    # self-attention path
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bk" in p:
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    if cfg.rope != "none":
        sec = cfg.mrope_sections if cfg.rope == "mrope" else None
        q = rope_apply(q, positions, cfg.rope_theta, sec)
        k = rope_apply(k, positions, cfg.rope_theta, sec)

    new_cache = None
    if cache is not None:
        # decode: write new k/v at cache_index, attend over the cache
        ck, cv = cache["k"], cache["v"]
        if window is not None:
            slot = jnp.mod(cache_index, ck.shape[1])  # ring buffer
        else:
            slot = cache_index
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, slot, 0, 0))
        k, v = ck, cv
        new_cache = {"k": ck, "v": cv}
        q_offset = cache_index
        kv_valid = jnp.minimum(cache_index + S, ck.shape[1])
        if window is not None:
            # Ring buffer: it holds exactly the last `window` positions, so all
            # filled slots are attendable and absolute-position masks don't apply.
            causal_here = False
        else:
            causal_here = causal
        q_offset = cache_index
        out = attention_core(q, k, v, causal=causal_here, q_offset=q_offset,
                             window=None, kv_valid_len=kv_valid, block_q=block_q)
    else:
        out = attention_core(q, k, v, causal=causal, window=window, block_q=block_q)

    out = out.reshape(B, S, H * hd) @ p["wo"]
    return out, new_cache


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, DeepSeek-V2)
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_dim + m.qk_rope_dim
    ks = jax.random.split(key, 6)
    p: Params = {}
    if m.q_lora:
        p["wdq"] = dense_init(ks[0], d, m.q_lora, dtype)
        p["q_norm"] = jnp.ones((m.q_lora,), dtype)
        p["wuq"] = dense_init(ks[1], m.q_lora, H * qk, dtype)
    else:
        p["wq"] = dense_init(ks[0], d, H * qk, dtype)
    p["wdkv"] = dense_init(ks[2], d, m.kv_lora, dtype)
    p["kv_norm"] = jnp.ones((m.kv_lora,), dtype)
    # separate K-up / V-up weights (a fused (kvl, H·(nope+hdv)) weight makes
    # the per-head nope/v split a cross-shard redistribution; see init_mlp)
    kk, kv2 = jax.random.split(ks[3])
    p["wuk"] = dense_init(kk, m.kv_lora, H * m.qk_nope_dim, dtype)
    p["wuv"] = dense_init(kv2, m.kv_lora, H * m.v_head_dim, dtype)
    p["wkr"] = dense_init(ks[4], d, m.qk_rope_dim, dtype)
    p["wo"] = dense_init(ks[5], H * m.v_head_dim, d, dtype)
    return p


def _mla_q(p, cfg, x):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    qk = m.qk_nope_dim + m.qk_rope_dim
    if "wdq" in p:
        q = rmsnorm(x @ p["wdq"], p["q_norm"], cfg.norm_eps) @ p["wuq"]
    else:
        q = x @ p["wq"]
    return q.reshape(B, S, H, qk)


def apply_mla(
    p: Params,
    cfg: ModelConfig,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    *,
    window: Optional[int] = None,
    cache: Optional[Params] = None,      # {"ckv": (B,Sc,kv_lora), "krope": (B,Sc,rope)}
    cache_index: Optional[jnp.ndarray] = None,
    absorb: bool = False,
    block_q: int = 1024,
) -> Tuple[jnp.ndarray, Optional[Params]]:
    m = cfg.mla
    B, S, d = x.shape
    H = cfg.n_heads
    nope, rope_d, hdv = m.qk_nope_dim, m.qk_rope_dim, m.v_head_dim
    scale = 1.0 / math.sqrt(nope + rope_d)

    q = _mla_q(p, cfg, x)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = rope_apply(q_rope, positions, cfg.rope_theta)

    ckv = rmsnorm(x @ p["wdkv"], p["kv_norm"], cfg.norm_eps)  # (B, S, kv_lora)
    krope = rope_apply((x @ p["wkr"]).reshape(B, S, 1, rope_d), positions,
                       cfg.rope_theta).reshape(B, S, rope_d)

    new_cache = None
    q_offset = 0
    kv_valid = None
    causal = True
    if cache is not None:
        if window is not None:
            slot = jnp.mod(cache_index, cache["ckv"].shape[1])
            causal = False
        else:
            slot = cache_index
        cc = jax.lax.dynamic_update_slice(cache["ckv"], ckv.astype(cache["ckv"].dtype),
                                          (0, slot, 0))
        cr = jax.lax.dynamic_update_slice(cache["krope"], krope.astype(cache["krope"].dtype),
                                          (0, slot, 0))
        ckv, krope = cc, cr
        new_cache = {"ckv": cc, "krope": cr}
        q_offset = cache_index
        kv_valid = jnp.minimum(cache_index + S, cc.shape[1])

    Skv = ckv.shape[1]
    wuk = p["wuk"].reshape(m.kv_lora, H, nope)
    wuv = p["wuv"].reshape(m.kv_lora, H, hdv)

    if absorb:
        # ---- absorbed decode (beyond-paper perf path) ----------------------
        # score = q_nope·(ckv @ Wk)ᵀ = (q_nope @ Wkᵀ)·ckvᵀ : attention in the
        # 512-dim latent space; V-side Wv is absorbed into the output proj.
        wk = wuk                                    # (kvl, H, nope)
        wv = wuv                                    # (kvl, H, hdv)
        q_lat = jnp.einsum("bqhn,lhn->bqhl", q_nope.astype(jnp.float32),
                           wk.astype(jnp.float32))  # (B,S,H,kvl)
        kv_idx = jnp.arange(Skv)
        s = jnp.einsum("bqhl,bsl->bhqs", q_lat * scale, ckv.astype(jnp.float32))
        s += jnp.einsum("bqhr,bsr->bhqs", q_rope.astype(jnp.float32) * scale,
                        krope.astype(jnp.float32))
        rows = q_offset + jnp.arange(S)
        mask = jnp.ones((S, Skv), bool)
        if causal:
            mask &= kv_idx[None, :] <= rows[:, None]
        if kv_valid is not None:
            mask &= kv_idx[None, :] < kv_valid
        s = jnp.where(mask[None, None], s, -jnp.inf)
        pw = jax.nn.softmax(s, axis=-1)
        pw = jnp.where(jnp.isnan(pw), 0.0, pw)
        o_lat = jnp.einsum("bhqs,bsl->bqhl", pw, ckv.astype(jnp.float32))  # (B,S,H,kvl)
        out = jnp.einsum("bqhl,lhv->bqhv", o_lat, wv.astype(jnp.float32))
        out = out.reshape(B, S, H * hdv).astype(x.dtype) @ p["wo"]
        return out, new_cache

    # ---- faithful reconstruct path -----------------------------------------
    k_nope = jnp.einsum("bsl,lhe->bshe", ckv, wuk.astype(ckv.dtype))  # (B,Skv,H,nope)
    v = jnp.einsum("bsl,lhe->bshe", ckv, wuv.astype(ckv.dtype))       # (B,Skv,H,hdv)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(krope[:, :, None, :],
                                                  (B, Skv, H, rope_d)).astype(k_nope.dtype)],
                        axis=-1)
    qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = attention_core(qfull, k, v, causal=causal, q_offset=q_offset,
                         kv_valid_len=kv_valid, block_q=block_q, scale=scale)
    out = out.reshape(B, S, H * hdv) @ p["wo"]
    return out, new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, d_ff: int, kind: str = "mlp", dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    if kind == "mlp":
        # SwiGLU with SEPARATE gate/up weights: a fused (d, 2·d_ff) weight
        # sharded on its last dim makes the later jnp.split a cross-shard
        # redistribution (observed as TB-scale collective-permutes in the
        # dry-run HLO — EXPERIMENTS.md §Perf); separate weights keep both
        # halves column-sharded with zero comm.
        return {"wgate": dense_init(k1, d, d_ff, dtype),
                "wup": dense_init(k2, d, d_ff, dtype),
                "wo": dense_init(k3, d_ff, d, dtype)}
    return {"wi": dense_init(k1, d, d_ff, dtype), "wo": dense_init(k2, d_ff, d, dtype)}


def apply_mlp(p: Params, x: jnp.ndarray, kind: str = "mlp") -> jnp.ndarray:
    if kind == "mlp":
        return (jax.nn.silu(x @ p["wgate"]) * (x @ p["wup"])) @ p["wo"]
    return jax.nn.gelu(x @ p["wi"]) @ p["wo"]
