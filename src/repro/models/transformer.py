"""Block assembly and the scan-over-periods stack.

A *period* is a tuple of (mixer, ffn) descriptors (len 1 for homogeneous
models, 8 for Jamba).  Parameters of all periods are stacked on a leading
``n_periods`` axis and the stack is traversed with ``jax.lax.scan`` — one
compiled period body regardless of depth, which keeps AOT compiles of
60-80-layer models tractable and is the standard TPU deep-stack idiom.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distribution.constraints import constrain_batch_dim
from repro.models import moe as moe_mod
from repro.models import ssm
from repro.models.config import ModelConfig
from repro.models.layers import (
    apply_attention,
    apply_mla,
    apply_mlp,
    init_attention,
    init_mla,
    init_mlp,
    rmsnorm,
)

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# single layer
# ---------------------------------------------------------------------------

def init_layer(key, cfg: ModelConfig, mixer: str, ffn: str, dtype, with_cross: bool = False) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.d_model
    p: Params = {"mixer_norm": jnp.ones((d,), dtype), "ffn_norm": jnp.ones((d,), dtype)}
    if mixer == "attn":
        p["mixer"] = init_attention(k1, cfg, dtype)
    elif mixer == "mla":
        p["mixer"] = init_mla(k1, cfg, dtype)
    elif mixer == "mamba":
        p["mixer"] = ssm.init_mamba(k1, cfg, dtype)
    elif mixer == "rwkv":
        p["mixer"] = ssm.init_rwkv_tmix(k1, cfg, dtype)
    else:
        raise ValueError(mixer)
    if ffn == "moe":
        p["ffn"] = moe_mod.init_moe(k2, cfg, dtype)
    elif ffn == "rwkv_cmix":
        p["ffn"] = ssm.init_rwkv_cmix(k2, cfg, dtype)
    elif ffn in ("mlp", "gelu_mlp"):
        p["ffn"] = init_mlp(k2, d, cfg.d_ff, ffn, dtype)
    else:
        raise ValueError(ffn)
    if with_cross:
        p["cross"] = init_attention(k3, cfg, dtype)
        p["cross_norm"] = jnp.ones((d,), dtype)
    return p


def layer_cache_init(cfg: ModelConfig, mixer: str, ffn: str, batch: int, cache_len: int,
                     dtype, with_cross: bool = False, enc_len: int = 0) -> Params:
    """Decode-time state for one layer (zeros; shapes are what matters)."""
    c: Params = {}
    if mixer == "attn":
        c["k"] = jnp.zeros((batch, cache_len, cfg.n_kv, cfg.hd), dtype)
        c["v"] = jnp.zeros((batch, cache_len, cfg.n_kv, cfg.hd), dtype)
    elif mixer == "mla":
        m = cfg.mla
        c["ckv"] = jnp.zeros((batch, cache_len, m.kv_lora), dtype)
        c["krope"] = jnp.zeros((batch, cache_len, m.qk_rope_dim), dtype)
    elif mixer == "mamba":
        c.update(ssm.mamba_state_init(cfg, batch, dtype))
    elif mixer == "rwkv":
        c.update({"tmix_" + k: v for k, v in ssm.rwkv_tmix_state_init(cfg, batch, dtype).items()})
    if ffn == "rwkv_cmix":
        c["cmix_shift"] = jnp.zeros((batch, cfg.d_model), dtype)
    if with_cross:
        c["cross_k"] = jnp.zeros((batch, enc_len, cfg.n_kv, cfg.hd), dtype)
        c["cross_v"] = jnp.zeros((batch, enc_len, cfg.n_kv, cfg.hd), dtype)
    return c


def apply_layer(
    p: Params,
    cfg: ModelConfig,
    mixer: str,
    ffn: str,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    cache: Optional[Params] = None,
    cache_index: Optional[jnp.ndarray] = None,
    cross_y: Optional[jnp.ndarray] = None,
    mla_absorb: bool = False,
    block_q: int = 1024,
) -> Tuple[jnp.ndarray, Optional[Params], jnp.ndarray]:
    """Pre-norm residual layer.  Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: Params = {}

    h = rmsnorm(x, p["mixer_norm"], cfg.norm_eps)
    if mixer == "attn":
        attn_cache = None
        if cache is not None and "k" in cache:
            attn_cache = {"k": cache["k"], "v": cache["v"]}
        h, nc = apply_attention(p["mixer"], cfg, h, positions, causal=causal,
                                window=window, cache=attn_cache,
                                cache_index=cache_index, block_q=block_q)
        if nc is not None:
            new_cache.update(nc)
    elif mixer == "mla":
        mla_cache = None
        if cache is not None and "ckv" in cache:
            mla_cache = {"ckv": cache["ckv"], "krope": cache["krope"]}
        h, nc = apply_mla(p["mixer"], cfg, h, positions, window=window,
                          cache=mla_cache, cache_index=cache_index,
                          absorb=mla_absorb, block_q=block_q)
        if nc is not None:
            new_cache.update(nc)
    elif mixer == "mamba":
        st = None
        if cache is not None and "h" in cache:
            st = {"conv": cache["conv"], "h": cache["h"]}
        h, nst = ssm.apply_mamba(p["mixer"], cfg, h, st)
        if nst is not None:
            new_cache.update(nst)
    elif mixer == "rwkv":
        st = None
        if cache is not None and "tmix_wkv" in cache:
            st = {"shift": cache["tmix_shift"], "wkv": cache["tmix_wkv"]}
        h, nst = ssm.apply_rwkv_tmix(p["mixer"], cfg, h, st)
        if nst is not None:
            new_cache.update({"tmix_" + k: v for k, v in nst.items()})
    # pin the residual stream to (batch=data axes, seq/d replicated): without
    # this GSPMD carries the row-parallel output's d-sharding into the FFN,
    # and the MoE dispatch then all-reduces every (B,E,cap,f) partial —
    # observed as the dominant 5 TB/device term on grok-1 (§Perf iteration 3)
    x = constrain_batch_dim(x + h)

    if "cross" in p:
        h = rmsnorm(x, p["cross_norm"], cfg.norm_eps)
        if cache is not None and "cross_k" in cache and cross_y is None:
            h, _ = apply_attention(p["cross"], cfg, h, positions,
                                   kv_override=(cache["cross_k"], cache["cross_v"]),
                                   block_q=block_q)
            new_cache["cross_k"] = cache["cross_k"]
            new_cache["cross_v"] = cache["cross_v"]
        else:
            h, cc = apply_attention(p["cross"], cfg, h, positions, cross_y=cross_y,
                                    block_q=block_q)
            if cache is not None:
                new_cache["cross_k"] = cc["k"].astype(cache["cross_k"].dtype) if cache else cc["k"]
                new_cache["cross_v"] = cc["v"].astype(cache["cross_v"].dtype)
        x = x + h

    h = rmsnorm(x, p["ffn_norm"], cfg.norm_eps)
    if ffn == "moe":
        h, aux = moe_mod.apply_moe(p["ffn"], cfg, h)
    elif ffn == "rwkv_cmix":
        st = None
        if cache is not None and "cmix_shift" in cache:
            st = {"shift": cache["cmix_shift"]}
        h, nst = ssm.apply_rwkv_cmix(p["ffn"], cfg, h, st)
        if nst is not None:
            new_cache["cmix_shift"] = nst["shift"]
    else:
        h = apply_mlp(p["ffn"], h, ffn)
    x = constrain_batch_dim(x + h)
    return x, (new_cache or None), aux


# ---------------------------------------------------------------------------
# stacked periods
# ---------------------------------------------------------------------------

def init_stack(key, cfg: ModelConfig, dtype, *, period=None, n_periods=None,
               with_cross: bool = False) -> Params:
    """Stacked params: {"pos0": tree, "pos1": ...}, leaves (n_periods, ...)."""
    period = period if period is not None else cfg.period
    n_periods = n_periods if n_periods is not None else cfg.n_layers // len(period)
    keys = jax.random.split(key, n_periods * len(period)).reshape(n_periods, len(period), 2)
    out: Params = {}
    for j, (mixer, ffn) in enumerate(period):
        per = [init_layer(jax.random.fold_in(key, i * 131 + j), cfg, mixer, ffn, dtype,
                          with_cross=with_cross) for i in range(n_periods)]
        out[f"pos{j}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
    return out


def stack_cache_init(cfg: ModelConfig, batch: int, cache_len: int, dtype, *,
                     period=None, n_periods=None, with_cross=False, enc_len=0) -> Params:
    period = period if period is not None else cfg.period
    n_periods = n_periods if n_periods is not None else cfg.n_layers // len(period)
    out: Params = {}
    for j, (mixer, ffn) in enumerate(period):
        one = layer_cache_init(cfg, mixer, ffn, batch, cache_len, dtype,
                               with_cross=with_cross, enc_len=enc_len)
        if one:
            out[f"pos{j}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (n_periods,) + a.shape), one)
    return out


def apply_stack(
    params: Params,
    cfg: ModelConfig,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    *,
    period=None,
    causal: bool = True,
    window: Optional[int] = None,
    caches: Optional[Params] = None,
    cache_index: Optional[jnp.ndarray] = None,
    cross_y: Optional[jnp.ndarray] = None,
    mla_absorb: bool = False,
    block_q: int = 1024,
    remat: bool = False,
) -> Tuple[jnp.ndarray, Optional[Params], jnp.ndarray]:
    period = period if period is not None else cfg.period

    def one_layer(j, mixer, ffn, layer_pj, h, c_j):
        return apply_layer(
            layer_pj, cfg, mixer, ffn, h, positions,
            causal=causal, window=window, cache=c_j, cache_index=cache_index,
            cross_y=cross_y, mla_absorb=mla_absorb, block_q=block_q)

    def body(carry, xs):
        h, aux = carry
        h = constrain_batch_dim(h)  # keep batch pinned to the data axes
        layer_p, layer_c = xs
        new_c: Params = {}
        for j, (mixer, ffn) in enumerate(period):
            c_j = layer_c.get(f"pos{j}") if layer_c is not None else None
            fn = functools.partial(one_layer, j, mixer, ffn)
            if remat and len(period) > 1:
                # per-layer remat inside the period: the backward pass holds
                # one layer's internals at a time, not all 8 of Jamba's
                fn = jax.checkpoint(fn)
            h, nc, a = fn(layer_p[f"pos{j}"], h, c_j)
            if nc:
                new_c[f"pos{j}"] = nc
            aux = aux + a
        return (h, aux), (new_c or None)

    if remat:
        body = jax.checkpoint(body)

    aux0 = jnp.zeros((), jnp.float32)
    xs = (params, caches) if caches is not None else (params, None)
    if caches is None:
        # scan needs a pytree with a leading axis; use params only
        (x, aux), _ = jax.lax.scan(lambda c, p: body(c, (p, None)), (x, aux0), params)
        return x, None, aux
    (x, aux), new_caches = jax.lax.scan(body, (x, aux0), (params, caches))
    return x, new_caches, aux
