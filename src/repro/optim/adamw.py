"""AdamW with decoupled weight decay and global-norm gradient clipping.

Functional, pytree-generic.  Moments are f32 regardless of param dtype
(mixed-precision policy); the optimizer-state sharding rules in
``repro.distribution.sharding`` additionally spread moments over the "data"
axis (ZeRO-1) so 400B-class configs fit v5e HBM.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # moment dtype: f32 default; bf16 for the 200B+ configs so params+moments
    # fit 16 GB/chip on a single pod (8-bit-Adam-style memory/precision trade,
    # recorded in EXPERIMENTS.md)
    moment_dtype: str = "f32"


def adamw_init(params, moment_dtype: str = "f32") -> Dict[str, Any]:
    mdt = jnp.bfloat16 if moment_dtype == "bf16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, params, grads, state, lr_scale=1.0):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state["step"] + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, mu, nu):
        mdt = mu.dtype
        g = g.astype(jnp.float32) * clip
        mu = cfg.b1 * mu.astype(jnp.float32) + (1 - cfg.b1) * g
        nu = cfg.b2 * nu.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = mu / b1c
        nhat = nu / b2c
        step_ = mhat / (jnp.sqrt(nhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_).astype(p.dtype), mu.astype(mdt), nu.astype(mdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, {"grad_norm": gnorm}
