"""SmolLM-135M [hf:HuggingFaceTB/SmolLM-135M] — llama-arch small.

30L, d_model 576, 9 heads (GQA kv=3), d_ff 1536, vocab 49152.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv=3,
    d_ff=1536,
    vocab=49152,
    period=(("attn", "mlp"),),
    rope="rope",
    rope_theta=1e4,
    tie_embeddings=True,
    sliding_window=16384,  # long_500k variant only
    source="hf:HuggingFaceTB/SmolLM-135M",
)
