"""Registry of the assigned architectures (``--arch <id>``)."""

from __future__ import annotations

from typing import Dict

from repro.models.config import ModelConfig

from repro.configs import (  # noqa: E402
    deepseek_7b,
    deepseek_v2_236b,
    grok_1_314b,
    jamba_1_5_large_398b,
    qwen1_5_110b,
    qwen2_5_14b,
    qwen2_vl_2b,
    rwkv6_7b,
    smollm_135m,
    whisper_medium,
)

ARCHS: Dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        deepseek_v2_236b,
        rwkv6_7b,
        jamba_1_5_large_398b,
        qwen2_5_14b,
        whisper_medium,
        qwen2_vl_2b,
        grok_1_314b,
        smollm_135m,
        qwen1_5_110b,
        deepseek_7b,
    )
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]
