"""Qwen2-VL-2B [arXiv:2409.12191] — VLM backbone; ViT frontend stubbed.

28L, d_model 1536, 12 heads (GQA kv=2), d_ff 8960, vocab 151936, M-RoPE with
(16, 24, 24) sections over the 128-dim head.  ``input_specs`` provides
precomputed patch embeddings + 3-D (temporal, h, w) position ids.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv=2,
    d_ff=8960,
    vocab=151936,
    qkv_bias=True,
    period=(("attn", "mlp"),),
    rope="mrope",
    mrope_sections=(16, 24, 24),
    sliding_window=16384,  # long_500k variant only
    source="arXiv:2409.12191",
)
