"""Whisper-medium [arXiv:2212.04356] — encoder-decoder; conv/mel frontend stubbed.

24 encoder + 24 decoder layers, d_model 1024, 16 heads (MHA), GELU d_ff 4096,
vocab 51865.  ``input_specs`` provides precomputed frame embeddings (the
carve-out allowed for audio frontends).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv=16,
    d_ff=4096,
    vocab=51865,
    period=(("attn", "gelu_mlp"),),
    enc_dec=True,
    n_enc_layers=24,
    rope="none",  # whisper uses learned/sinusoidal absolute positions
    act="gelu",
    source="arXiv:2212.04356",
)
