"""Grok-1 314B [hf:xai-org/grok-1] — MoE, 8 experts top-2.

64L, d_model 6144, 48 heads (GQA kv=8), expert d_ff 32768, vocab 131072.
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv=8,
    d_ff=32768,
    vocab=131072,
    period=(("attn", "moe"),),
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=32768),
    rope="rope",
    sliding_window=16384,  # long_500k variant only
    source="hf:xai-org/grok-1",
)
