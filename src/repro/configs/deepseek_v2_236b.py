"""DeepSeek-V2 236B [arXiv:2405.04434] — MoE with Multi-head Latent Attention.

60L, d_model 5120, 128 heads (MLA: qk = 128 nope + 64 rope, v 128,
kv compression rank 512), 160 routed experts top-6 + 2 shared, expert
d_ff 1536, vocab 102400.
"""
from repro.models.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv=128,
    d_ff=1536,
    vocab=102400,
    head_dim=128,
    period=(("mla", "moe"),),
    moe=MoEConfig(n_experts=160, top_k=6, d_expert=1536, n_shared=2),
    mla=MLAConfig(kv_lora=512, q_lora=1536, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
    rope="rope",
    rope_theta=1e4,
    sliding_window=16384,  # long_500k variant only
    source="arXiv:2405.04434",
)
