"""Qwen1.5-110B [hf:Qwen/Qwen1.5-0.5B family card] — dense, QKV bias.

80L, d_model 8192, 64 heads (GQA kv=8), d_ff 49152, vocab 152064.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv=8,
    d_ff=49152,
    vocab=152064,
    qkv_bias=True,
    period=(("attn", "mlp"),),
    rope="rope",
    sliding_window=16384,  # long_500k variant only
    source="hf:Qwen/Qwen1.5-0.5B",
)
