"""RWKV-6 (Finch) 7B [arXiv:2404.05892] — attention-free, data-dependent decay.

32L, d_model 4096 (64 WKV heads x 64), channel-mix d_ff 14336, vocab 65536.
"""
from repro.models.config import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,      # wkv heads = d_model / rwkv.head_dim
    n_kv=64,
    d_ff=14336,
    vocab=65536,
    period=(("rwkv", "rwkv_cmix"),),
    rwkv=RWKVConfig(head_dim=64, decay_lora=64),
    rope="none",
    source="arXiv:2404.05892",
)
