"""Jamba-1.5-Large 398B [arXiv:2403.19887] — Mamba + attention 1:7, MoE 16e top-2.

72L = 9 periods of 8 (attention at in-period index 4, Mamba elsewhere; MoE on
odd in-period indices, dense MLP on even), d_model 8192, 64 heads (GQA kv=8),
d_ff 24576, vocab 65536.
"""
from repro.models.config import ModelConfig, MoEConfig, SSMConfig

_PERIOD = tuple(
    ("attn" if i == 4 else "mamba", "moe" if i % 2 == 1 else "mlp") for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv=8,
    d_ff=24576,
    vocab=65536,
    period=_PERIOD,
    moe=MoEConfig(n_experts=16, top_k=2, d_expert=24576),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    rope="rope",
    source="arXiv:2403.19887",
)
