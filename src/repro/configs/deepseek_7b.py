"""DeepSeek-LLM 7B [arXiv:2401.02954] — llama-arch dense, MHA.

30L, d_model 4096, 32 heads (kv=32 = MHA), d_ff 11008, vocab 102400.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    family="dense",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv=32,
    d_ff=11008,
    vocab=102400,
    period=(("attn", "mlp"),),
    rope="rope",
    rope_theta=1e4,
    sliding_window=16384,  # long_500k variant only
    source="arXiv:2401.02954",
)
