"""Qwen2.5-14B [hf:Qwen/Qwen2.5-0.5B family card] — dense, GQA kv=8, QKV bias.

48L, d_model 5120, 40 heads, d_ff 13824, vocab 152064.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv=8,
    d_ff=13824,
    vocab=152064,
    qkv_bias=True,
    period=(("attn", "mlp"),),
    rope="rope",
    sliding_window=16384,  # long_500k variant only
    source="hf:Qwen/Qwen2.5-0.5B",
)
