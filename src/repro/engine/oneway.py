"""One-way chain protocols + §7 baselines as the engine's third compiled path.

The paper's other half (§2–3, §6.1 RANDOM ε-net sampling; §7 NAIVE / VOTING /
MIXING baselines) is one-way: data flows down a fixed chain P_1 → … → P_k (or
star-in to P_k) and only the last node learns.  There is no turn loop to
unroll — the whole protocol is *one* chain pass plus a terminal fit — so the
compiled shape is different from MEDIAN/MAXMARG's ``while_loop``:

* **Batched reservoir chain** (selector ``"sampling"``, paper Thm 3.1/6.1):
  a ``jax.random``-keyed reservoir sampler vmapped over B with per-instance
  capacities s_ε, advanced by one ``lax.scan`` over the k−1 chain hops.  Each
  hop ingests shard i under Vitter's j ~ U[0, t) rule (fill phase first,
  last-write-wins on slot collisions via a scatter-max of stream positions —
  the same process ``sampling.Reservoir.add_batch`` runs on the host) and
  meters the reservoir forward at exactly the host loop's message slot:
  ``min(seen, s_ε)`` points, one message, one round per hop.
* **Star baselines** (``"naive"``, ``"voting"``, ``"mixing"``): closed-form
  metering at the host loops' slots (all points / all points / k−1 parameter
  vectors) plus the batched terminal or per-node fits.

All terminal fits reuse :func:`repro.core.classifiers._svm_solve_batch`, so a
whole sweep of B instances is one batched annealed-Pegasos dispatch (VOTING
and MIXING fold their B·k per-node fits into a single (B·k)-batch solve).
Communication is metered in :class:`BatchCommLog` at the same message slots
as the host ``CommLog`` and lowers to identical summary dicts — the retired
host loops survive as differential oracles in ``benchmarks/legacy_oneway.py``
and the B=1 public APIs (``one_way.random_sampling``,
``baselines.{naive,voting,random,mixing}``) delegate here with exact
comm/rounds parity.

Padding follows the engine conventions (DESIGN.md): label-0 rows are inert in
the fit and never enter the reservoir (stream positions count valid rows
only), and unfilled reservoir slots keep label 0, so the terminal concat
needs no compaction.
"""

from __future__ import annotations

import functools
from typing import List, Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from repro.core.classifiers import _svm_solve_batch
from repro.core.sampling import EPSILON_NET_C, epsilon_net_size
from repro.engine.state import BatchCommLog, ProtocolInstance, _round_up

ONEWAY_SELECTORS = ("sampling", "naive", "voting", "mixing")


def _pack_shards(instances: Sequence[ProtocolInstance]):
    """Pad a one-way sweep onto (B, k, n_max, d) label-0 static shapes.

    All instances must share the party count k and dimension d (any d — no
    direction grid anywhere in the one-way family); shard sizes may be
    ragged.
    """
    assert instances, "need at least one instance"
    ks = {len(inst.shards) for inst in instances}
    assert len(ks) == 1, f"instances must share the party count, got {ks}"
    k = ks.pop()
    ds = {s[0].shape[1] for inst in instances for s in inst.shards}
    assert len(ds) == 1, f"instances must share the dimension, got {ds}"
    d = ds.pop()
    B = len(instances)
    n_max = _round_up(max(s[0].shape[0] for inst in instances
                          for s in inst.shards), 8)
    X = np.zeros((B, k, n_max, d), np.float32)
    y = np.zeros((B, k, n_max), np.int32)
    for b, inst in enumerate(instances):
        for j, (Xs, ys) in enumerate(inst.shards):
            n = Xs.shape[0]
            assert set(np.unique(ys)).issubset({-1, 1}), "labels must be +-1"
            X[b, j, :n] = Xs
            y[b, j, :n] = ys
    return jnp.asarray(X), jnp.asarray(y), k, d


# ---------------------------------------------------------------------------
# batched reservoir (Vitter 1985 on device)
# ---------------------------------------------------------------------------

def _make_ingest(cap: int):
    """Single-instance shard ingest with static capacity bound ``cap``;
    per-instance effective capacity ``capb`` ≤ cap masks the tail slots."""

    def ingest(resX, resy, seen, key, Xi, yi, capb):
        n_max = Xi.shape[0]
        valid = yi != 0
        # 1-based global stream position of each valid row (padding rows get
        # a stale position but are masked out of every write below)
        t = seen + jnp.cumsum(valid.astype(jnp.int32))
        draw = jax.random.randint(key, (n_max,), 0, jnp.maximum(t, 1))
        j = jnp.where(t <= capb, t - 1, draw)      # fill phase is positional
        hit = valid & (j < capb)
        # last-write-wins on slot collisions = sequential order: the slot
        # keeps the item with the greatest stream position (scatter-max is
        # well-defined under duplicate indices, unlike scatter-set)
        tgt = jnp.where(hit, j, cap)               # out-of-range rows dropped
        pos = jnp.where(hit, jnp.arange(n_max, dtype=jnp.int32), -1)
        winner = (jnp.full((cap + 1,), -1, jnp.int32).at[tgt].max(pos))[:cap]
        take = winner >= 0
        safe = jnp.maximum(winner, 0)
        resX = jnp.where(take[:, None], Xi[safe], resX)
        resy = jnp.where(take, yi[safe], resy)
        return resX, resy, seen + jnp.sum(valid, dtype=jnp.int32)

    return ingest


@functools.partial(jax.jit, static_argnames=("k", "cap", "steps", "stages"))
def _run_sampling(X, y, caps, keys, lam0, *, k: int, cap: int,
                  steps: int, stages: int):
    """RANDOM ε-net chain (paper Thm 3.1, k-party Thm 6.1): P_i forwards a
    reservoir over ∪_{j≤i} D_j; P_k fits on own ∪ reservoir."""
    B, _, n_max, d = X.shape
    resX = jnp.zeros((B, cap, d), X.dtype)
    resy = jnp.zeros((B, cap), jnp.int32)
    seen = jnp.zeros((B,), jnp.int32)
    comm = BatchCommLog.zeros(B)
    ingest = jax.vmap(_make_ingest(cap))

    if k > 1:
        hop_keys = jnp.swapaxes(
            jax.vmap(lambda kk: jax.random.split(kk, k - 1))(keys), 0, 1)
        Xs = jnp.swapaxes(X, 0, 1)[:-1]            # (k-1, B, n_max, d)
        ys = jnp.swapaxes(y, 0, 1)[:-1]

        def hop(carry, inp):
            rX, ry, sn, cm = carry
            Xi, yi, hk = inp
            rX, ry, sn = ingest(rX, ry, sn, hk, Xi, yi, caps)
            # the host loop's message slot: P_i ships its current reservoir
            # (possibly empty — still one message) and the hop is one round
            cm = cm._replace(points=cm.points + jnp.minimum(sn, caps),
                             messages=cm.messages + 1,
                             rounds=cm.rounds + 1)
            return (rX, ry, sn, cm), None

        (resX, resy, seen, comm), _ = lax.scan(
            hop, (resX, resy, seen, comm), (Xs, ys, hop_keys))

    Kx = jnp.concatenate([X[:, k - 1], resX], axis=1)
    Ky = jnp.concatenate([y[:, k - 1], resy], axis=1)
    w, b, ok = _svm_solve_batch(Kx, Ky.astype(Kx.dtype), lam0, steps, stages)
    return w, b, ok, comm


@functools.partial(jax.jit, static_argnames=("k", "steps", "stages"))
def _run_naive(X, y, lam0, *, k: int, steps: int, stages: int):
    """NAIVE: every node ships its whole shard to P_k; central fit."""
    B, _, n_max, d = X.shape
    Kx = X.reshape(B, k * n_max, d)
    Ky = y.reshape(B, k * n_max)
    w, b, ok = _svm_solve_batch(Kx, Ky.astype(Kx.dtype), lam0, steps, stages)
    comm = _star_points_comm(y, k)
    return w, b, ok, comm


@functools.partial(jax.jit, static_argnames=("k", "steps", "stages"))
def _run_voting(X, y, lam0, *, k: int, steps: int, stages: int):
    """VOTING: B·k local fits as one batched solve; the vote is evaluated on
    the full dataset, which the paper charges at full data cost."""
    B, _, n_max, d = X.shape
    w, b, ok = _svm_solve_batch(
        X.reshape(B * k, n_max, d),
        y.reshape(B * k, n_max).astype(X.dtype), lam0, steps, stages)
    comm = _star_points_comm(y, k)
    return w.reshape(B, k, d), b.reshape(B, k), ok.reshape(B, k), comm


@functools.partial(jax.jit, static_argnames=("k", "steps", "stages"))
def _run_mixing(X, y, lam0, *, k: int, steps: int, stages: int):
    """MIXING: B·k local fits, ship normalized (w_i, b_i), average."""
    B, _, n_max, d = X.shape
    w, b, _ok = _svm_solve_batch(
        X.reshape(B * k, n_max, d),
        y.reshape(B * k, n_max).astype(X.dtype), lam0, steps, stages)
    w = w.reshape(B, k, d)
    b = b.reshape(B, k)
    nrm = jnp.sqrt(jnp.sum(w * w, axis=2)) + 1e-12
    w_mix = jnp.mean(w / nrm[:, :, None], axis=1)
    b_mix = jnp.mean(b / nrm, axis=1)
    z = jnp.zeros((B,), jnp.int32)
    comm = BatchCommLog(points=z, scalars=z + (k - 1) * (d + 1), bits=z,
                        messages=z + (k - 1), rounds=z + 1)
    return w_mix, b_mix, comm


def _star_points_comm(y, k: int) -> BatchCommLog:
    """k−1 star messages into P_k carrying every non-last shard's points —
    the NAIVE/VOTING cost row of Tables 2–4 (empty shards still cost their
    message slot, matching ``Node.send_points``)."""
    B = y.shape[0]
    pts = jnp.sum(jnp.sum(y[:, :-1] != 0, axis=2), axis=1).astype(jnp.int32)
    z = jnp.zeros((B,), jnp.int32)
    return BatchCommLog(points=pts, scalars=z, bits=z,
                        messages=z + (k - 1), rounds=z + 1)


# ---------------------------------------------------------------------------
# sweep entry point
# ---------------------------------------------------------------------------

def run_instances(
    instances: Sequence[ProtocolInstance],
    *,
    eps: Optional[float] = None,
    vc_dim: Optional[int] = None,
    c: Optional[float] = None,
    steps: int = 2000,
    stages: int = 3,
    lam: float = 1e-3,
):
    """Run a batch of one-way/baseline instances as one compiled dispatch.

    All instances must share one selector (``run_sweep`` buckets mixed
    sweeps).  Returns :class:`~repro.core.protocols.one_way.ProtocolResult`
    per instance, shaped exactly like the retired host loops' (which survive
    as differential oracles in ``benchmarks/legacy_oneway.py``).  ``vc_dim``
    and ``c`` parameterize the ``"sampling"`` ε-net size exactly as on the
    host API; per-instance RNG comes from ``ProtocolInstance.seed``.

    Compile-key contract: the padded reservoir cap (max ε-net size over
    the batch, rounded to 8), ``steps``, ``stages``, ``k``, and ``d`` are
    static — a batch with a larger max eps-driven reservoir compiles a
    new dispatch.  Shard contents, per-instance caps, seeds, ``lam``,
    and B are traced data and never recompile.
    """
    from repro.core import classifiers as clf
    from repro.core.protocols.one_way import ProtocolResult

    sels = {inst.selector for inst in instances}
    assert len(sels) == 1, f"one bucket must share a selector, got {sels}"
    sel = sels.pop()
    assert sel in ONEWAY_SELECTORS, sel
    if eps is not None:
        instances = [ProtocolInstance(inst.shards, eps, sel, inst.seed)
                     for inst in instances]
    X, y, k, d = _pack_shards(instances)
    B = len(instances)
    lam0 = jnp.float32(lam)

    extra_common = {"engine": True, "batch": B, "selector": sel}
    results: List[ProtocolResult] = []
    if sel == "sampling":
        vc = vc_dim if vc_dim is not None else d + 1
        cc = c if c is not None else EPSILON_NET_C
        sizes = [epsilon_net_size(inst.eps, vc, c=cc) for inst in instances]
        cap = _round_up(max(sizes), 8)
        caps = jnp.asarray(sizes, jnp.int32)
        keys = jnp.stack([jax.random.PRNGKey(inst.seed)
                          for inst in instances])
        w, b, _ok, comm = _run_sampling(X, y, caps, keys, lam0, k=k, cap=cap,
                                        steps=steps, stages=stages)
        w = np.asarray(w, np.float64)
        b = np.asarray(b, np.float64)
        comm_np = type(comm)(*(np.asarray(a) for a in comm))
        for i in range(B):
            results.append(ProtocolResult(
                clf.LinearSeparator(w[i], float(b[i])),
                comm_np.summary(i, dim=d), rounds=k - 1, converged=True,
                extra={**extra_common, "sample_size": sizes[i]}))
        return results

    if sel == "naive":
        w, b, _ok, comm = _run_naive(X, y, lam0, k=k, steps=steps,
                                     stages=stages)
        w = np.asarray(w, np.float64)
        b = np.asarray(b, np.float64)
        comm_np = type(comm)(*(np.asarray(a) for a in comm))
        for i in range(B):
            results.append(ProtocolResult(
                clf.LinearSeparator(w[i], float(b[i])),
                comm_np.summary(i, dim=d), rounds=1, converged=True,
                extra=dict(extra_common)))
        return results

    if sel == "voting":
        from repro.core.protocols.baselines import _VotingClassifier
        w, b, _ok, comm = _run_voting(X, y, lam0, k=k, steps=steps,
                                      stages=stages)
        w = np.asarray(w, np.float64)
        b = np.asarray(b, np.float64)
        comm_np = type(comm)(*(np.asarray(a) for a in comm))
        for i in range(B):
            parts = [clf.LinearSeparator(w[i, j], float(b[i, j]))
                     for j in range(k)]
            results.append(ProtocolResult(
                _VotingClassifier(parts),
                comm_np.summary(i, dim=d), rounds=1, converged=True,
                extra=dict(extra_common)))
        return results

    # mixing
    from repro.core.protocols.baselines import _MixedClassifier
    w, b, comm = _run_mixing(X, y, lam0, k=k, steps=steps, stages=stages)
    w = np.asarray(w, np.float64)
    b = np.asarray(b, np.float64)
    comm_np = type(comm)(*(np.asarray(a) for a in comm))
    for i in range(B):
        results.append(ProtocolResult(
            _MixedClassifier(w[i], float(b[i])),
            comm_np.summary(i, dim=d), rounds=1, converged=True,
            extra=dict(extra_common)))
    return results
