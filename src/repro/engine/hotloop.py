"""Selector-generic host-driven hot loop (DESIGN.md §shared hot loop).

Both two-way selectors share the same transcript-driven round structure —
and therefore the same per-round waste: a ``lax.while_loop`` sweep must run
every turn at the worst-case transcript width with every instance still in
the batch.  This module owns the machinery that removes it, extracted from
the MAXMARG-only PR 4 implementation so the MEDIAN selector (and any future
transcript-driven selector) rides the identical code path:

* **host-driven turn loop** — drive the selector's jitted ``step`` one turn
  at a time so shapes can change between turns (a while_loop cannot);
* **packed host transfers** — everything the host needs per turn (done
  flags, warm-carry flags, live transcript fills) crosses as one (3, B)
  int32 array;
* **width compaction** — the per-turn transcript reads run at
  ``round_up(max live fill + slack, 8)`` rows instead of the static
  capacity (widths are monotone, so a sweep compiles a handful of step
  variants that later sweeps of the same shape reuse);
* **batch compaction** — finished instances drop out of the dispatch: the
  live set rounds up to a multiple of 4 and pads with *out-of-range*
  indices, which JAX gathers fill with inert zero rows and JAX scatters
  drop, so the live count stays a traced value and the compile cache keys
  only on ``(n_pad, width, warm)``;
* **warm-carry threading** — the host reads the selector's per-turn
  warm-latch flags and skips the polish dispatch on turns where no live
  instance can latch;
* **sharded dispatch** (DESIGN.md §sharded hot loop) — with ``shards=S``
  the per-turn sub-batch index is built *per shard* (``balanced_index``):
  the live set splits into S local slices padded to a common multiple of
  ``BATCH_MULT``, so every device runs the same shapes and none idles while
  another runs live rows; the selector's sharded dispatches map them over a
  1-D ("data",) mesh;
* **double buffering** (``overlap=True``) — turn t+1 is dispatched from the
  one-turn-*stale* host view before the host blocks on turn t's view
  decode, overlapping host decision logic with device compute.  Sound
  because ``done`` is monotone (stale active sets are supersets whose extra
  rows are masked no-ops) and the stale fill plus the selector's
  ``width_growth`` bound covers the true fill; at most one wasted all-done
  masked dispatch runs at termination.

The selector supplies three callables (see :func:`run_hot`); everything it
must guarantee about padding rows is the engine's standing label-0
convention plus a ``pad_fix`` that marks gathered out-of-range rows inert
(``done=True``, and for warm selectors: carries trusted, so zero-data pad
rows latch instantly and can never force solver work the live rows don't
need).
"""

from __future__ import annotations

from typing import Callable, List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.engine.state import _round_up, shard_specs  # noqa: F401 (re-export)

BATCH_MULT = 4   # live batch rounds up to this (compile-cache granularity)
WIDTH_MULT = 8   # live transcript width rounds up to this

# every compacted dispatch appends its compile-cache key here:
# (n_pad, width, use_warm, first_turn) with n_pad = B for full-batch turns.
# tests/test_recompile.py pins that the number of step lowerings never
# exceeds the distinct keys — i.e. the cache keys on (n_pad, width, warm)
# only, and shard-aware padding can't silently reintroduce per-turn
# recompiles.  Bounded observability: the driver clears it per sweep-test.
KEY_LOG: List[Tuple[int, int, bool, bool]] = []


def quantize_width(w: int, cap: int, policy: str = "linear") -> int:
    """Round a live transcript width up to a dispatchable bucket.

    ``"linear"`` is the classic rule — ``min(cap, round_up(w, WIDTH_MULT))``
    — and is byte-identical to what every hot path shipped before the policy
    knob existed.  ``"geometric"`` rounds up to the next bucket of the
    series 8, 16, 24, 40, 64, 96, 144, ... (each ≈1.5× the last, re-rounded
    to ``WIDTH_MULT``): mixed-selector traffic spreads live fills across
    families with very different transcript growth, and linear rounding then
    visits O(cap / WIDTH_MULT) distinct widths — each a fresh compile —
    where geometric rounding visits O(log cap) at ≤ 50% padding waste
    (DESIGN.md §unified mixed-selector state).

    Both policies preserve ``w = 0`` exactly: a zero width is meaningful
    (MAXMARG's empty-transcript first turn compiles a skip-concat branch)
    and must not be promoted into a padded nonzero bucket.
    """
    w = min(cap, _round_up(w, WIDTH_MULT))
    if policy == "linear" or w <= WIDTH_MULT:
        return w
    if policy != "geometric":
        raise ValueError(f"unknown width policy {policy!r}")
    b = WIDTH_MULT
    while b < w:
        b = _round_up((b * 3) // 2, WIDTH_MULT)
    return min(cap, b)


def gather_rows(arr, idx):
    """arr (B, N, ...), idx (B,) -> (B, ...): per-instance row gather.

    The engine's turn counter is per-instance, so the coordinator index
    ``ci = turn % k`` is a (B,) vector and every "the coordinator's shard /
    transcript" access is this vmapped gather rather than a shared-axis
    ``jnp.take``.  Gathers are exact, so vectorizing ci changes no float."""
    return jax.vmap(lambda a, i: a[i])(arr, idx)


def take_instances(tree, idx):
    """Gather instance rows ``idx`` from every (B, ...) leaf (scalar leaves —
    the shared turn counter — pass through).  Out-of-range indices gather
    zero-filled rows: an all-label-0 instance is the engine's inert element
    (no valid rows ⇒ every masked selection is empty, every masked reduction
    hits its identity), which is exactly what a hot turn's padding rows must
    be."""
    return jax.tree_util.tree_map(
        lambda a: a if a.ndim == 0
        else jnp.take(a, idx, axis=0, mode="fill", fill_value=0), tree)


def put_instances(full, sub, idx):
    """Scatter ``sub`` rows back into ``full`` at ``idx`` (scalar leaves take
    the sub value — the advanced turn counter).  Padding rows carry an
    out-of-range index, which a JAX scatter *drops*, so they never land."""
    return jax.tree_util.tree_map(
        lambda f, s: s if f.ndim == 0 else f.at[idx].set(s), full, sub)


def gathered_turn(step_fn, pad_fix, data, state, idx, n_act):
    """One compacted turn as gather → pad-fix → step → scatter.

    The selector wraps this in its own ``jax.jit`` (its static options
    differ), so the whole turn stays one device computation: eager per-leaf
    gathers/scatters cost more than the step they wrap on CPU.  ``idx`` is
    (n_pad,) i32 with the live rows in front and out-of-range tail indices;
    ``n_act`` is the traced live count; ``pad_fix(sub_state, pad_row)``
    marks the gathered tail rows inert for this selector.
    """
    sub_data = take_instances(data, idx)
    sub = take_instances(state, idx)
    pad_row = jnp.arange(idx.shape[0]) >= n_act
    sub = pad_fix(sub, pad_row)
    sub = step_fn(sub_data, sub)
    return put_instances(state, sub, idx)


def shard_skew(counts: np.ndarray) -> float:
    """Imbalance of a per-shard live-count vector as the max/mean ratio.

    1.0 is perfectly balanced; S (the shard count) means one shard owns the
    whole live set.  The common padded length L in :func:`balanced_index`
    is set by the *max* count, so every device pays the skewed shard's
    shapes — this ratio is exactly the padding-waste factor and the signal
    any future cross-shard rebalancing must drive down (ROADMAP).  An
    all-dead vector reports 0.0 (no dispatch, no waste)."""
    counts = np.asarray(counts, dtype=np.float64)
    mean = counts.mean() if counts.size else 0.0
    if mean <= 0:
        return 0.0
    return float(counts.max() / mean)


def balanced_index(act: np.ndarray, B: int, shards: int):
    """Shard-balanced compacted index for a sharded sub-batch dispatch.

    Splits the sorted global active set into per-shard *local* index slices
    (shard s owns global rows ``[s·B/S, (s+1)·B/S)``), pads every slice to
    the common ``L = round_up(max per-shard live count, BATCH_MULT)`` with
    the out-of-range index B (gather-fill / scatter-drop, same convention
    as the single-device tail), and returns ``(idx, n_act)``: ``idx`` is
    (S·L,) i32 — shard s's slice at ``idx[s·L:(s+1)·L]`` — and ``n_act`` is
    the (S,) per-shard live count the sharded dispatch reads locally.  The
    common L is the balance contract: every device runs the same compacted
    shapes, so none idles while another runs live rows, and the compile
    cache keys on L exactly like the single-device path keys on n_pad.
    """
    B_loc = B // shards
    shard_of = act // B_loc
    counts = np.bincount(shard_of, minlength=shards).astype(np.int32)
    L = max(BATCH_MULT, _round_up(int(counts.max()), BATCH_MULT))
    idx = np.full((shards, L), B, np.int32)
    local = (act - shard_of * B_loc).astype(np.int32)
    offs = np.concatenate([[0], np.cumsum(counts)])
    for s in range(shards):          # act is sorted -> slices stay ordered
        idx[s, :counts[s]] = local[offs[s]:offs[s + 1]]
    return idx.reshape(-1), counts


def run_hot(
    state,
    *,
    k: int,
    max_turns: int,
    cap: int,
    host_view: Callable,      # (state, ci) -> (3, B) i32 [done, warm, fill]
    dispatch_full: Callable,  # (state, *, t, width, use_warm) -> state
    dispatch_sub: Callable,   # (state, idx, n_act, *, t, width, use_warm)
    warm: bool = False,
    compact: bool = True,
    width_slack: int = 0,
    width_growth: int = 0,
    width_policy: str = "linear",
    overlap: bool = False,
    shards: Optional[int] = None,
    stats: Optional[dict] = None,
):
    """The generic host-driven sweep loop over a selector's jitted ``step``.

    ``host_view`` must be jitted and return the packed per-turn host
    knowledge: row 0 done flags, row 1 warm-latch flags for the upcoming
    coordinator ``ci`` (all zero for selectors without a warm carry), row 2
    the transcript fills the width compaction keys on.  ``width_slack``
    widens the compacted read past the turn-start fill — a selector whose
    step *reads* transcripts after appending to them (MEDIAN's post-S
    extremes scan) passes the per-turn append bound.  ``width_policy``
    selects the :func:`quantize_width` bucketing rule ("linear" default,
    "geometric" for mixed-width traffic where linear rounding would churn
    the compile cache).

    ``dispatch_full`` runs the whole batch at a compacted ``width``
    (``None`` on the non-compacted path); ``dispatch_sub`` additionally
    gathers the ``idx`` rows and scatters them back (see
    :func:`gathered_turn`).  ``t`` is the host-known turn index, from which
    a selector derives host-static flags (MEDIAN's constant-folded first
    turn).

    Donation contract: the dispatches MAY donate their ``state`` argument
    (the sharded path does — the scatter-back then reuses the transcript
    buffers in place instead of copying them every turn).  The loop keeps a
    strict single-consumer chain: each state handle is passed to exactly
    one dispatch, and the ``host_view`` of a handle is always enqueued
    before the dispatch that donates it.

    ``shards=S`` routes sub-batch turns through :func:`balanced_index` —
    ``dispatch_sub`` then receives the (S·L,) per-shard index block and the
    (S,) per-shard live counts instead of a flat prefix index.

    ``overlap=True`` double-buffers the loop: after dispatching turn t from
    a fresh view, turn t+1 is dispatched immediately from the same —
    now one-turn-stale — view before the host blocks on turn t's view
    decode.  Stale parameters are always sound: ``done`` is monotone, so
    the stale active set is a superset whose extra rows are masked no-ops,
    and the stale fill plus the selector's ``width_growth`` (its worst-case
    one-turn transcript growth) covers the true fill.  MEDIAN results stay
    bit-exact (any covering width is); warm selectors may make different —
    equally valid — polish-skip choices, which is decision-preserving (the
    warm gate re-checks on device).  At most one wasted all-done masked
    dispatch runs at termination.

    ``stats`` (optional dict) collects host-side observability: on sharded
    sweeps every :func:`balanced_index` call folds its per-shard live-count
    skew (:func:`shard_skew`) into ``stats["shard_skew_max"]`` /
    ``stats["shard_skew_last"]`` and counts dispatches in
    ``stats["shard_dispatches"]`` — the measurable rebalancing signal the
    ROADMAP's skewed-shard item asks for.  Never read for decisions.
    """
    B = int(state.done.shape[0])
    # the scatter-drop tail is a host-side constant: every pad slot carries
    # the same out-of-range index B, so build it once, not once per turn
    pad_tail = np.full(B, B, dtype=np.int32)
    # turn is per-instance; a sweep advances every row in lock-step, so the
    # host-side loop counter resumes from the common (max) value
    t = int(np.asarray(state.turn).max(initial=0))

    if not compact:
        while t < max_turns:
            done, warm_ok, fills = np.asarray(host_view(state, t % k))
            if bool(done.all()):
                break
            act = np.flatnonzero(done == 0)
            use_warm = warm and t > 0 and bool(warm_ok[act].any())
            state = dispatch_full(state, t=t, width=None, use_warm=use_warm)
            t += 1
        return state

    def params(done, warm_ok, fills, t, growth):
        """Dispatch parameters for turn t from a view (``growth`` is the
        extra width slack when the view is one turn stale)."""
        act = np.flatnonzero(done == 0)
        # polish only when it can latch: turn 0 has no carry to polish, and
        # a turn where no live instance's carried separator can latch falls
        # through to the cold anneal anyway — skip the polish dispatch
        use_warm = warm and t > 0 and bool(warm_ok[act].any())
        width = quantize_width(int(fills[act].max(initial=0))
                               + width_slack + growth, cap, width_policy)
        return act, width, use_warm

    def dispatch(state, act, width, use_warm, t):
        n_act = len(act)
        if n_act == B:
            # full batch: the width compaction is the whole win — skip the
            # gather/scatter round-trip entirely
            KEY_LOG.append((B, width, use_warm, t == 0))
            return dispatch_full(state, t=t, width=width, use_warm=use_warm)
        if shards:
            idx, n_vec = balanced_index(act, B, shards)
            if stats is not None:
                skew = shard_skew(n_vec)
                stats["shard_skew_last"] = skew
                stats["shard_skew_max"] = max(
                    stats.get("shard_skew_max", 0.0), skew)
                stats["shard_dispatches"] = \
                    stats.get("shard_dispatches", 0) + 1
            KEY_LOG.append((len(idx), width, use_warm, t == 0))
            return dispatch_sub(state, jnp.asarray(idx), jnp.asarray(n_vec),
                                t=t, width=width, use_warm=use_warm)
        n_pad = min(B, _round_up(n_act, BATCH_MULT))
        idx = np.concatenate([act.astype(np.int32),
                              pad_tail[:n_pad - n_act]])
        KEY_LOG.append((n_pad, width, use_warm, t == 0))
        return dispatch_sub(state, jnp.asarray(idx), jnp.int32(n_act),
                            t=t, width=width, use_warm=use_warm)

    # one packed transfer per turn for everything the host needs; the seed
    # view is decoded synchronously (nothing to overlap with yet)
    view = np.asarray(host_view(state, t % k))
    while t < max_turns:
        done, warm_ok, fills = view
        if bool(done.all()):
            break
        act, width, use_warm = params(done, warm_ok, fills, t, 0)
        state = dispatch(state, act, width, use_warm, t)
        vh = host_view(state, (t + 1) % k)     # enqueue BEFORE donation of
        t += 1                                 # this handle (next dispatch)
        if overlap and t < max_turns:
            # double buffer: dispatch turn t from the now-stale view before
            # blocking on the decode of turn t-1's view (vh)
            act_s, width_s, warm_s = params(done, warm_ok, fills, t,
                                            width_growth)
            state = dispatch(state, act_s, width_s, warm_s, t)
            vh2 = host_view(state, (t + 1) % k)
            t += 1
            if bool(np.asarray(vh)[0].all()):
                # the speculated turn ran on an all-done batch: a masked
                # no-op — results are untouched, only the turn counter moved
                break
            view = np.asarray(vh2)
        else:
            view = np.asarray(vh)
    return state
