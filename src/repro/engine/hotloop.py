"""Selector-generic host-driven hot loop (DESIGN.md §shared hot loop).

Both two-way selectors share the same transcript-driven round structure —
and therefore the same per-round waste: a ``lax.while_loop`` sweep must run
every turn at the worst-case transcript width with every instance still in
the batch.  This module owns the machinery that removes it, extracted from
the MAXMARG-only PR 4 implementation so the MEDIAN selector (and any future
transcript-driven selector) rides the identical code path:

* **host-driven turn loop** — drive the selector's jitted ``step`` one turn
  at a time so shapes can change between turns (a while_loop cannot);
* **packed host transfers** — everything the host needs per turn (done
  flags, warm-carry flags, live transcript fills) crosses as one (3, B)
  int32 array;
* **width compaction** — the per-turn transcript reads run at
  ``round_up(max live fill + slack, 8)`` rows instead of the static
  capacity (widths are monotone, so a sweep compiles a handful of step
  variants that later sweeps of the same shape reuse);
* **batch compaction** — finished instances drop out of the dispatch: the
  live set rounds up to a multiple of 4 and pads with *out-of-range*
  indices, which JAX gathers fill with inert zero rows and JAX scatters
  drop, so the live count stays a traced value and the compile cache keys
  only on ``(n_pad, width, warm)``;
* **warm-carry threading** — the host reads the selector's per-turn
  warm-latch flags and skips the polish dispatch on turns where no live
  instance can latch.

The selector supplies three callables (see :func:`run_hot`); everything it
must guarantee about padding rows is the engine's standing label-0
convention plus a ``pad_fix`` that marks gathered out-of-range rows inert
(``done=True``, and for warm selectors: carries trusted, so zero-data pad
rows latch instantly and can never force solver work the live rows don't
need).
"""

from __future__ import annotations

from typing import Callable, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.engine.state import _round_up

BATCH_MULT = 4   # live batch rounds up to this (compile-cache granularity)
WIDTH_MULT = 8   # live transcript width rounds up to this


def take_instances(tree, idx):
    """Gather instance rows ``idx`` from every (B, ...) leaf (scalar leaves —
    the shared turn counter — pass through).  Out-of-range indices gather
    zero-filled rows: an all-label-0 instance is the engine's inert element
    (no valid rows ⇒ every masked selection is empty, every masked reduction
    hits its identity), which is exactly what a hot turn's padding rows must
    be."""
    return jax.tree_util.tree_map(
        lambda a: a if a.ndim == 0
        else jnp.take(a, idx, axis=0, mode="fill", fill_value=0), tree)


def put_instances(full, sub, idx):
    """Scatter ``sub`` rows back into ``full`` at ``idx`` (scalar leaves take
    the sub value — the advanced turn counter).  Padding rows carry an
    out-of-range index, which a JAX scatter *drops*, so they never land."""
    return jax.tree_util.tree_map(
        lambda f, s: s if f.ndim == 0 else f.at[idx].set(s), full, sub)


def gathered_turn(step_fn, pad_fix, data, state, idx, n_act):
    """One compacted turn as gather → pad-fix → step → scatter.

    The selector wraps this in its own ``jax.jit`` (its static options
    differ), so the whole turn stays one device computation: eager per-leaf
    gathers/scatters cost more than the step they wrap on CPU.  ``idx`` is
    (n_pad,) i32 with the live rows in front and out-of-range tail indices;
    ``n_act`` is the traced live count; ``pad_fix(sub_state, pad_row)``
    marks the gathered tail rows inert for this selector.
    """
    sub_data = take_instances(data, idx)
    sub = take_instances(state, idx)
    pad_row = jnp.arange(idx.shape[0]) >= n_act
    sub = pad_fix(sub, pad_row)
    sub = step_fn(sub_data, sub)
    return put_instances(state, sub, idx)


def run_hot(
    state,
    *,
    k: int,
    max_turns: int,
    cap: int,
    host_view: Callable,      # (state, ci) -> (3, B) i32 [done, warm, fill]
    dispatch_full: Callable,  # (state, *, t, width, use_warm) -> state
    dispatch_sub: Callable,   # (state, idx, n_act, *, t, width, use_warm)
    warm: bool = False,
    compact: bool = True,
    width_slack: int = 0,
):
    """The generic host-driven sweep loop over a selector's jitted ``step``.

    ``host_view`` must be jitted and return the packed per-turn host
    knowledge: row 0 done flags, row 1 warm-latch flags for the upcoming
    coordinator ``ci`` (all zero for selectors without a warm carry), row 2
    the transcript fills the width compaction keys on.  ``width_slack``
    widens the compacted read past the turn-start fill — a selector whose
    step *reads* transcripts after appending to them (MEDIAN's post-S
    extremes scan) passes the per-turn append bound.

    ``dispatch_full`` runs the whole batch at a compacted ``width``
    (``None`` on the non-compacted path); ``dispatch_sub`` additionally
    gathers the ``idx`` rows and scatters them back (see
    :func:`gathered_turn`).  ``t`` is the host-known turn index, from which
    a selector derives host-static flags (MEDIAN's constant-folded first
    turn).
    """
    B = int(state.done.shape[0])
    # the scatter-drop tail is a host-side constant: every pad slot carries
    # the same out-of-range index B, so build it once, not once per turn
    pad_tail = np.full(B, B, dtype=np.int32)
    t = int(state.turn)                    # advanced host-side: one step = +1
    while t < max_turns:
        ci = t % k
        # one packed transfer per turn for everything the host needs
        done, warm_ok, fills = np.asarray(host_view(state, ci))
        if bool(done.all()):
            break
        act = np.flatnonzero(done == 0)
        # polish only when it can latch: turn 0 has no carry to polish, and
        # a turn where no live instance's carried separator can latch falls
        # through to the cold anneal anyway — skip the polish dispatch
        use_warm = warm and t > 0 and bool(warm_ok[act].any())
        turn_t = t
        t += 1
        if not compact:
            state = dispatch_full(state, t=turn_t, width=None,
                                  use_warm=use_warm)
            continue
        n_act = len(act)
        width = min(cap, _round_up(int(fills[act].max(initial=0))
                                   + width_slack, WIDTH_MULT))
        if n_act == B:
            # full batch: the width compaction is the whole win — skip the
            # gather/scatter round-trip entirely
            state = dispatch_full(state, t=turn_t, width=width,
                                  use_warm=use_warm)
            continue
        n_pad = min(B, _round_up(n_act, BATCH_MULT))
        idx = np.concatenate([act.astype(np.int32),
                              pad_tail[:n_pad - n_act]])
        state = dispatch_sub(state, jnp.asarray(idx), jnp.int32(n_act),
                             t=turn_t, width=width, use_warm=use_warm)
    return state
