"""Seeded deterministic fault model for the streaming session pool.

The paper's protocols assume every node answers every round; a persistent
service does not get that luxury (cf. the resilient-boosting setting of
arXiv:2206.04713).  This module is the *failure half* of the session-pool
contract (DESIGN.md §session pool & failure model): a stateless, seeded
schedule that decides — per (session, pool turn) — whether that session's
next protocol turn

* **drops out** (a node never answers: the turn is aborted host-side and
  retried with exponential backoff),
* **loses a message** (a transcript message is dropped in flight — same
  host-visible outcome as a dropout, counted separately),
* **straggles** (the turn completes but only after a deterministic number
  of extra pool turns — the session is simply absent from dispatches in
  the meantime; no retry is charged), or
* **is corrupted** (the turn runs and then one of three state corruptions
  lands, each paired with exactly one supervisor invariant check:
  ``CORRUPT_NAN`` → NaN separator, ``CORRUPT_FILL`` → non-monotone
  transcript fill, ``CORRUPT_COMM`` → comm-budget blowout).

Determinism is the load-bearing property: draws are a pure splitmix64-style
hash of ``(seed, session_id, pool_turn)`` with one salt per channel, so

* there is **no RNG state to checkpoint** — a restored pool replays the
  identical schedule for the identical (session, turn) pairs;
* two runs with the same seed produce identical eviction sets, retry
  counts and surviving-session decisions (tests/test_faults.py,
  tests/test_session_pool.py);
* keying on the *pool* turn (not the session's protocol turn) means a
  retried turn faces a **fresh draw** — a transient fault cannot pin a
  session in a deterministic retry livelock; persistent bad luck exhausts
  the retry budget and quarantines instead.

What the injector may and may not touch (the metering invariant): dropouts,
lost messages and stragglers only *delay* dispatches — they never mutate
protocol state, so a session that survives them reaches the exact same
final separator as a fault-free run (the pool's bit-exactness criterion).
Corruption mutates the victim's own state only, after the turn's metered
appends — delivered messages are always metered exactly; a corrupted
session is detected and quarantined, never silently served.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

# corruption kinds — each maps 1:1 onto a supervisor invariant check
CORRUPT_NAN = 0    # separator turns NaN            → NaN invariant
CORRUPT_FILL = 1   # transcript fill decremented    → monotone-fill invariant
CORRUPT_COMM = 2   # comm bits counter spiked       → comm-budget invariant
N_CORRUPT_KINDS = 3

# the comm-counter spike CORRUPT_COMM adds — far beyond any legitimate
# per-turn bit cost (k-1 bits/turn), so the blowout check cannot false-fire
COMM_SPIKE_BITS = 1 << 20

_MASK = np.uint64(0xFFFFFFFFFFFFFFFF)
_GAMMA = np.uint64(0x9E3779B97F4A7C15)
_M1 = np.uint64(0xBF58476D1CE4E5B9)
_M2 = np.uint64(0x94D049BB133111EB)

# per-channel salts (arbitrary distinct odd constants)
_SALT = {
    "dropout": np.uint64(0xD1B54A32D192ED03),
    "drop_msg": np.uint64(0x8CB92BA72F3D8DD7),
    "straggle": np.uint64(0xABC98388FB8FAC03),
    "straggle_len": np.uint64(0x49BEB2B3D3BBF853),
    "corrupt": np.uint64(0x7E46CA1B0BC29F43),
    "corrupt_kind": np.uint64(0x93D765DD3F5B1F2D),
}


def _mix(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer: uint64 array -> uint64 array, bijective."""
    with np.errstate(over="ignore"):
        z = (x + _GAMMA) & _MASK
        z = ((z ^ (z >> np.uint64(30))) * _M1) & _MASK
        z = ((z ^ (z >> np.uint64(27))) * _M2) & _MASK
        return z ^ (z >> np.uint64(31))


def _hash_u01(seed: int, sids: np.ndarray, pool_turn: int,
              salt: np.uint64) -> np.ndarray:
    """Uniform [0, 1) draw per session id — pure in (seed, sid, turn, salt)."""
    sids = np.asarray(sids, np.uint64)
    with np.errstate(over="ignore"):
        h = _mix(np.uint64(seed) ^ salt)
        h = _mix(h ^ _mix(sids))
        h = _mix(h ^ _mix(np.uint64(pool_turn) + salt))
    return h.astype(np.float64) / float(2 ** 64)


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """A seeded fault schedule: probabilities per channel plus the seed.

    ``draws(session_ids, pool_turn)`` is the whole API — a pure function,
    so the schedule itself carries no state (nothing to checkpoint).  All
    probabilities default to 0, making ``FaultSchedule(seed)`` an explicit
    fault-free schedule (useful as the oracle arm of differential tests).
    """

    seed: int = 0
    p_dropout: float = 0.0     # node never answers: abort + retry/backoff
    p_drop_msg: float = 0.0    # transcript message lost: abort + retry
    p_straggle: float = 0.0    # turn delayed, no retry charged
    p_corrupt: float = 0.0     # state corrupted post-turn: detect + evict
    straggle_max: int = 3      # straggle duration drawn from [1, straggle_max]

    def __post_init__(self):
        for f in ("p_dropout", "p_drop_msg", "p_straggle", "p_corrupt"):
            p = getattr(self, f)
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{f}={p} outside [0, 1]")
        if self.straggle_max < 1:
            raise ValueError("straggle_max must be >= 1")

    @property
    def any_faults(self) -> bool:
        return (self.p_dropout > 0 or self.p_drop_msg > 0
                or self.p_straggle > 0 or self.p_corrupt > 0)

    def draws(self, session_ids: np.ndarray,
              pool_turn: int) -> Dict[str, np.ndarray]:
        """Fault draws for each session about to be dispatched on this pool
        turn.  Returns numpy arrays aligned with ``session_ids``:

        * ``dropout``  (bool) — node dropout aborts the turn;
        * ``drop_msg`` (bool) — lost message aborts the turn;
        * ``straggle`` (i32)  — extra pool turns the session stays absent
          (0 = on time); drawn uniformly from [1, straggle_max] when hit;
        * ``corrupt``  (i32)  — corruption kind (``CORRUPT_*``) applied
          after the turn, -1 for none.

        Channels are independent; the pool resolves precedence (abort
        beats straggle beats corrupt — an aborted turn never ran, so there
        is nothing to corrupt).
        """
        sids = np.asarray(session_ids, np.int64)
        u_drop = _hash_u01(self.seed, sids, pool_turn, _SALT["dropout"])
        u_msg = _hash_u01(self.seed, sids, pool_turn, _SALT["drop_msg"])
        u_str = _hash_u01(self.seed, sids, pool_turn, _SALT["straggle"])
        u_len = _hash_u01(self.seed, sids, pool_turn, _SALT["straggle_len"])
        u_cor = _hash_u01(self.seed, sids, pool_turn, _SALT["corrupt"])
        u_knd = _hash_u01(self.seed, sids, pool_turn, _SALT["corrupt_kind"])

        straggle = np.where(
            u_str < self.p_straggle,
            1 + (u_len * self.straggle_max).astype(np.int32), 0)
        corrupt = np.where(
            u_cor < self.p_corrupt,
            (u_knd * N_CORRUPT_KINDS).astype(np.int32), -1)
        return {
            "dropout": u_drop < self.p_dropout,
            "drop_msg": u_msg < self.p_drop_msg,
            "straggle": straggle.astype(np.int32),
            "corrupt": corrupt.astype(np.int32),
        }

    def to_json(self) -> Dict[str, float]:
        """Schedule as a plain dict (checkpoint manifests, bench reports)."""
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: Dict[str, float]) -> "FaultSchedule":
        return FaultSchedule(**d)


FAULT_FREE = FaultSchedule(seed=0)
