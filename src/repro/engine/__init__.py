"""Batched protocol engine: full scenario sweeps as one compiled dispatch.

The paper's experiments are sweeps — ε × partition × dataset × protocol —
and every instance is independent, so the data plane batches them: a
:class:`ProtocolState` pytree with a leading instance axis, one pure jitted
``step`` advanced under ``lax.while_loop`` (fused inline scans plus
append-time threshold-range maintenance), and vectorized on-device
communication accounting (:class:`BatchCommLog`) lowered to the classic
``CommLog.summary`` dicts at the end.  The batch-grid Pallas kernels for the
bulk scans live in :mod:`repro.kernels.support_margin` and are reachable via
:mod:`repro.engine.dataplane` (SOU diagnostics and the rescan oracle that
cross-checks the incremental ranges).

The single-instance protocol API (``iterative_support_median``,
``iterative_support_kparty``) delegates here with B=1, so batched and
sequential execution are the same compiled program — parity by construction.

Three compiled execution paths share the conventions: MEDIAN
(:mod:`repro.engine.median`), MAXMARG (:mod:`repro.engine.maxmarg`), and the
one-way chain protocols + §7 baselines (:mod:`repro.engine.oneway` —
reservoir chain scan plus batched terminal fits).  ``run_sweep`` buckets a
mixed grid across all of them — or, with ``unified_dispatch=True``, routes
MEDIAN + MAXMARG + SAMPLING through :mod:`repro.engine.unified`'s
mixed-selector superset state, where the selector is traced per-row data
and one compiled step drives any mix (DESIGN.md §unified mixed-selector
state).
"""

from repro.engine.state import (
    BatchCommLog,
    EngineData,
    MaxMargState,
    ProtocolInstance,
    ProtocolState,
    SELECTOR_CODES,
    SELECTOR_NAMES,
    UnifiedState,
    maxmarg_transcript_capacity,
    pack_instances,
    pack_instances_maxmarg,
    pack_instances_unified,
    transcript_capacity,
    unified_transcript_capacity,
)
from repro.engine.median import run_compiled, run_instances, step
from repro.engine import dataplane, hotloop, maxmarg, oneway, unified


def run_sweep(instances, *, unified_dispatch=False, **kwargs):
    """Dispatch a heterogeneous sweep and return results in input order.

    Two dispatch modes:

    * **bucketed** (default): one engine dispatch per distinct
      (selector, k, d) — the engine's per-selector compiled ``step`` is
      selector- and shape-monomorphic, see DESIGN.md §selector abstraction.
      The full paper grid (two-way MEDIAN/MAXMARG + one-way sampling + the
      §7 baselines) is one ``run_sweep`` call.
    * **unified** (``unified_dispatch=True``): MEDIAN, MAXMARG and
      SAMPLING instances bucket by (k, d) *only* and run through
      :mod:`repro.engine.unified`'s mixed-selector state — the selector
      becomes traced per-row data, so any interleaving of those families
      at equal shapes shares one compiled step (the §7 baselines keep
      their own closed-form dispatches either way).

    Compile-key contract (the invariant callers break first): each
    bucket's compiled variants key on the *static* scenario shape — party
    count k, dimension d, padded sizes (n_max, cap rounded to multiples of
    8), the compacted (n_pad, width, warm) hot-loop key, and static solver
    options (``max_epochs``, ``max_support``, ``steps``/``stages``,
    kernel flags) — never on per-instance values (ε, seeds, shard
    contents, or — under unified dispatch — the selector mix).  Repeating
    a sweep of the same shapes therefore recompiles nothing
    (tests/test_recompile.py gates this); changing any static option or
    shape bucket compiles a fresh variant.

    Keyword arguments are forwarded to each bucket's runner (a selector
    ignores options that don't apply to it), but a kwarg no selector in the
    sweep understands raises — a typo must not silently run with defaults.
    """
    _FIT = ("steps", "stages", "lam")
    _ALLOWED = {
        "maxmarg": ("eps", "max_epochs", "max_support", "warm", "per_node",
                    "compact", "fused_kernel", "solver_kernel", "mesh",
                    "donate", "overlap", "stats") + _FIT,
        "median": ("eps", "n_angles", "max_epochs", "cut_kernel",
                   "extremes_kernel", "compact", "mesh", "donate",
                   "overlap", "stats"),
        "sampling": ("eps", "vc_dim", "c") + _FIT,
        "naive": _FIT,
        "voting": _FIT,
        "mixing": _FIT,
        "unified": ("eps", "n_angles", "max_epochs", "max_support", "warm",
                    "per_node", "compact", "vc_dim", "c", "solver_kernel",
                    "width_policy", "stats") + _FIT,
    }
    buckets = {}
    for i, inst in enumerate(instances):
        if inst.selector not in _ALLOWED or inst.selector == "unified":
            raise ValueError(f"unknown selector {inst.selector!r}")
        sel_key = ("unified" if unified_dispatch
                   and inst.selector in SELECTOR_CODES else inst.selector)
        key = (sel_key, len(inst.shards), inst.shards[0][0].shape[1])
        buckets.setdefault(key, []).append(i)
    understood = set().union(*(_ALLOWED[sel] for (sel, _k, _d) in buckets))
    unknown = set(kwargs) - understood
    if unknown:
        raise TypeError(f"run_sweep got option(s) {sorted(unknown)} that no "
                        f"selector in this sweep accepts")
    out = [None] * len(instances)
    for (selector, _k, _d), idxs in buckets.items():
        group = [instances[i] for i in idxs]
        allowed = _ALLOWED[selector]
        opts = {a: kwargs[a] for a in allowed if a in kwargs}
        if selector == "unified":
            res = unified.run_instances(group, **opts)
        elif selector == "maxmarg":
            res = maxmarg.run_instances(group, **opts)
        elif selector in oneway.ONEWAY_SELECTORS:
            res = oneway.run_instances(group, **opts)
        else:
            res = run_instances(group, **opts)
        for i, r in zip(idxs, res):
            out[i] = r
    return out


__all__ = [
    "BatchCommLog",
    "EngineData",
    "MaxMargState",
    "ProtocolInstance",
    "ProtocolState",
    "SELECTOR_CODES",
    "SELECTOR_NAMES",
    "UnifiedState",
    "dataplane",
    "hotloop",
    "maxmarg",
    "maxmarg_transcript_capacity",
    "oneway",
    "pack_instances",
    "pack_instances_maxmarg",
    "pack_instances_unified",
    "run_compiled",
    "run_instances",
    "run_sweep",
    "step",
    "transcript_capacity",
    "unified",
    "unified_transcript_capacity",
]
