"""Batched protocol engine: full scenario sweeps as one compiled dispatch.

The paper's experiments are sweeps — ε × partition × dataset × protocol —
and every instance is independent, so the data plane batches them: a
:class:`ProtocolState` pytree with a leading instance axis, one pure jitted
``step`` advanced under ``lax.while_loop`` (fused inline scans plus
append-time threshold-range maintenance), and vectorized on-device
communication accounting (:class:`BatchCommLog`) lowered to the classic
``CommLog.summary`` dicts at the end.  The batch-grid Pallas kernels for the
bulk scans live in :mod:`repro.kernels.support_margin` and are reachable via
:mod:`repro.engine.dataplane` (SOU diagnostics and the rescan oracle that
cross-checks the incremental ranges).

The single-instance protocol API (``iterative_support_median``,
``iterative_support_kparty``) delegates here with B=1, so batched and
sequential execution are the same compiled program — parity by construction.
"""

from repro.engine.state import (
    BatchCommLog,
    EngineData,
    ProtocolInstance,
    ProtocolState,
    pack_instances,
    transcript_capacity,
)
from repro.engine.median import run_compiled, run_instances, step
from repro.engine import dataplane

__all__ = [
    "BatchCommLog",
    "EngineData",
    "ProtocolInstance",
    "ProtocolState",
    "dataplane",
    "pack_instances",
    "run_compiled",
    "run_instances",
    "step",
    "transcript_capacity",
]
