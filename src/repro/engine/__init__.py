"""Batched protocol engine: full scenario sweeps as one compiled dispatch.

The paper's experiments are sweeps — ε × partition × dataset × protocol —
and every instance is independent, so the data plane batches them: a
:class:`ProtocolState` pytree with a leading instance axis, one pure jitted
``step`` advanced under ``lax.while_loop`` (fused inline scans plus
append-time threshold-range maintenance), and vectorized on-device
communication accounting (:class:`BatchCommLog`) lowered to the classic
``CommLog.summary`` dicts at the end.  The batch-grid Pallas kernels for the
bulk scans live in :mod:`repro.kernels.support_margin` and are reachable via
:mod:`repro.engine.dataplane` (SOU diagnostics and the rescan oracle that
cross-checks the incremental ranges).

The single-instance protocol API (``iterative_support_median``,
``iterative_support_kparty``) delegates here with B=1, so batched and
sequential execution are the same compiled program — parity by construction.

Three compiled execution paths share the conventions: MEDIAN
(:mod:`repro.engine.median`), MAXMARG (:mod:`repro.engine.maxmarg`), and the
one-way chain protocols + §7 baselines (:mod:`repro.engine.oneway` —
reservoir chain scan plus batched terminal fits).  ``run_sweep`` buckets a
mixed grid across all of them.
"""

from repro.engine.state import (
    BatchCommLog,
    EngineData,
    MaxMargState,
    ProtocolInstance,
    ProtocolState,
    maxmarg_transcript_capacity,
    pack_instances,
    pack_instances_maxmarg,
    transcript_capacity,
)
from repro.engine.median import run_compiled, run_instances, step
from repro.engine import dataplane, hotloop, maxmarg, oneway


def run_sweep(instances, **kwargs):
    """Dispatch a heterogeneous sweep: bucket instances by scenario spec
    (selector, party count, dimension), run each bucket as one compiled
    batch, and return results in input order.

    The engine's compiled ``step`` is selector- and shape-monomorphic (k and
    d are static), so a mixed sweep is *bucketed dispatch*: one engine
    dispatch per distinct (selector, k, d) — see DESIGN.md §selector
    abstraction.  The full paper grid (two-way MEDIAN/MAXMARG + one-way
    sampling + the §7 baselines) is therefore one ``run_sweep`` call.
    Keyword arguments are forwarded to each bucket's runner (a selector
    ignores options that don't apply to it), but a kwarg no selector in the
    sweep understands raises — a typo must not silently run with defaults.
    """
    _FIT = ("steps", "stages", "lam")
    _ALLOWED = {
        "maxmarg": ("eps", "max_epochs", "max_support", "warm", "per_node",
                    "compact", "fused_kernel", "solver_kernel", "mesh",
                    "donate", "overlap", "stats") + _FIT,
        "median": ("eps", "n_angles", "max_epochs", "cut_kernel",
                   "extremes_kernel", "compact", "mesh", "donate",
                   "overlap", "stats"),
        "sampling": ("eps", "vc_dim", "c") + _FIT,
        "naive": _FIT,
        "voting": _FIT,
        "mixing": _FIT,
    }
    buckets = {}
    for i, inst in enumerate(instances):
        key = (inst.selector, len(inst.shards), inst.shards[0][0].shape[1])
        if inst.selector not in _ALLOWED:
            raise ValueError(f"unknown selector {inst.selector!r}")
        buckets.setdefault(key, []).append(i)
    understood = set().union(*(_ALLOWED[sel] for (sel, _k, _d) in buckets))
    unknown = set(kwargs) - understood
    if unknown:
        raise TypeError(f"run_sweep got option(s) {sorted(unknown)} that no "
                        f"selector in this sweep accepts")
    out = [None] * len(instances)
    for (selector, _k, _d), idxs in buckets.items():
        group = [instances[i] for i in idxs]
        allowed = _ALLOWED[selector]
        opts = {a: kwargs[a] for a in allowed if a in kwargs}
        if selector == "maxmarg":
            res = maxmarg.run_instances(group, **opts)
        elif selector in oneway.ONEWAY_SELECTORS:
            res = oneway.run_instances(group, **opts)
        else:
            res = run_instances(group, **opts)
        for i, r in zip(idxs, res):
            out[i] = r
    return out


__all__ = [
    "BatchCommLog",
    "EngineData",
    "MaxMargState",
    "ProtocolInstance",
    "ProtocolState",
    "dataplane",
    "hotloop",
    "maxmarg",
    "maxmarg_transcript_capacity",
    "oneway",
    "pack_instances",
    "pack_instances_maxmarg",
    "run_compiled",
    "run_instances",
    "run_sweep",
    "step",
    "transcript_capacity",
]
