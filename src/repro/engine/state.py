"""Batched protocol state: pytrees + packing/lowering for the sweep engine.

A *sweep* is B independent MEDIAN/k-party protocol instances (same party
count k, possibly different datasets, shard sizes, error budgets and seeds)
advanced in lock-step by one compiled ``step``.  Everything lives in fixed
static shapes:

* shards are padded to a common ``n_max`` with **label-0 rows** (the same
  zero-label padding convention the Pallas kernels use — padding rows are
  inert in every masked reduction);
* per-node transcript buffers have static capacity ``cap`` plus a fill
  counter; rows at or beyond the fill always carry label 0, so a transcript
  is valid under the same convention without ever being compacted;
* communication is accounted in :class:`BatchCommLog` — per-instance integer
  arrays updated on device exactly where the metered :class:`~repro.core.comm`
  channels would record a message, and lowered to ``CommLog.summary()``-shaped
  dicts at the end (the metered-channel invariant: costs are measured by the
  data plane itself, never re-derived).

See DESIGN.md §"Batched engine" for the capacity bound and padding rules.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, NamedTuple, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.comm import wire_bytes
from repro.core.sampling import EPSILON_NET_C, epsilon_net_size

# static per-instance selector codes for the unified mixed-selector state
# (DESIGN.md §unified mixed-selector state).  The codes live in a *traced*
# (B,) i32 leaf — mixing selectors changes data, never the compiled program —
# and 0 doubles as the inert value gather-filled into padding rows (a
# label-0 MEDIAN row is the engine's no-op instance).
SEL_MEDIAN = 0
SEL_MAXMARG = 1
SEL_SAMPLING = 2
SELECTOR_CODES = {"median": SEL_MEDIAN, "maxmarg": SEL_MAXMARG,
                  "sampling": SEL_SAMPLING}
SELECTOR_NAMES = {v: k for k, v in SELECTOR_CODES.items()}


class BatchCommLog(NamedTuple):
    """Vectorized communication ledger: one integer counter per instance.

    Mirrors :class:`repro.core.comm.CommStats` field-for-field; ``rounds``
    counts protocol *turns* exactly like ``CommLog.new_round()``.
    """

    points: jnp.ndarray    # (B,) i32
    scalars: jnp.ndarray   # (B,) i32
    bits: jnp.ndarray      # (B,) i32
    messages: jnp.ndarray  # (B,) i32
    rounds: jnp.ndarray    # (B,) i32

    @staticmethod
    def zeros(batch: int) -> "BatchCommLog":
        z = jnp.zeros((batch,), jnp.int32)
        return BatchCommLog(z, z, z, z, z)

    def summary(self, i: int, dim: int) -> Dict[str, Any]:
        """Lower instance ``i`` to the exact dict ``CommLog.summary()`` emits."""
        p = int(self.points[i])
        s = int(self.scalars[i])
        b = int(self.bits[i])
        return {
            "points": p,
            "scalars": s,
            "bits": b,
            "messages": int(self.messages[i]),
            "rounds": int(self.rounds[i]),
            "bytes": wire_bytes(p, s, b, dim),
        }

    def summaries(self, dim: int) -> List[Dict[str, Any]]:
        return [self.summary(i, dim) for i in range(self.points.shape[0])]


class ProtocolState(NamedTuple):
    """Per-instance protocol state advanced by ``median.step`` (a pytree).

    All leading axes are the batch axis B, including ``turn``: the
    coordinator index ``ci = turn % k`` is *per-instance*, so one dispatch
    may mix sessions at different protocol phases (the streaming session
    pool admits into freed slots mid-stream).  A lock-step sweep keeps
    every row's turn identical — ``step`` advances all of them together —
    so the sweep paths behave exactly like the old shared scalar counter.
    """

    dir_ok: jnp.ndarray     # (B, m) bool — allowed direction arc
    wx: jnp.ndarray         # (B, k, cap, d) f32 — per-node transcript points
    wy: jnp.ndarray         # (B, k, cap) i32 — transcript labels (0 = empty)
    w_fill: jnp.ndarray     # (B, k) i32 — transcript fill counters
    lo_w: jnp.ndarray       # (B, k, m) f32 — running per-node threshold lo
    hi_w: jnp.ndarray       # (B, k, m) f32 — running per-node threshold hi
    turn: jnp.ndarray       # (B,) i32 — per-instance turn counter
    done: jnp.ndarray       # (B,) bool
    converged: jnp.ndarray  # (B,) bool
    epochs: jnp.ndarray     # (B,) i32 — 1-based epoch at termination
    h_v: jnp.ndarray        # (B, d) f32 — current hypothesis direction
    h_t: jnp.ndarray        # (B,) f32 — current hypothesis threshold
    h_valid: jnp.ndarray    # (B,) bool
    comm: BatchCommLog


class EngineData(NamedTuple):
    """Per-instance constants (traced inputs to the jitted runner)."""

    X: jnp.ndarray       # (B, k, n_max, d) f32, zero-padded rows
    y: jnp.ndarray       # (B, k, n_max) i32 ±1 (0 = padding row)
    budget: jnp.ndarray  # (B,) i32 — floor(eps * n_total)


class MaxMargState(NamedTuple):
    """Per-instance MAXMARG protocol state advanced by ``maxmarg.step``.

    Same conventions as :class:`ProtocolState` (leading batch axis B,
    per-instance ``turn``, label-0 transcript padding) but no direction grid: the
    MAXMARG selector refits a max-margin separator per turn instead of
    maintaining a consistent-direction arc.  Transcripts hold *received*
    points only (the legacy host loop's ``Node.recv`` — MAXMARG nodes fit on
    own ∪ received, never on a sent-ledger).

    Several fields carry the hot path's perf state between turns (DESIGN.md
    §warm-start & transcript compaction, §shared hot loop): ``w_fill`` is
    the per-instance *live transcript length* per node, from which the
    host-driven runner picks the compacted read width each turn;
    ``h_w``/``h_b``/``h_valid`` hold the latest proposal (the result
    hypothesis, and the init the *single-carry* warm mode polishes); the
    ``(k,)``-leading leaves ``c_w``/``c_b``/``c_valid`` hold each node's
    carried separator — the most recent *proposal that node verified clean*
    on everything it knows — which the default per-node warm mode polishes
    when that node next coordinates, with ``warm_node`` tracking
    incrementally whether the carry still classifies the node's grown
    transcript cleanly (the polish-latch precondition the hot runner's skip
    logic reads).  ``latches`` counts refits whose warm gate passed, purely
    observability (never a protocol decision).
    """

    wx: jnp.ndarray         # (B, k, cap, d) f32 — received-point transcripts
    wy: jnp.ndarray         # (B, k, cap) i32 — transcript labels (0 = empty)
    w_fill: jnp.ndarray     # (B, k) i32 — live transcript length per node
    turn: jnp.ndarray       # (B,) i32 — per-instance turn counter
    done: jnp.ndarray       # (B,) bool
    converged: jnp.ndarray  # (B,) bool
    epochs: jnp.ndarray     # (B,) i32 — 1-based epoch at termination
    h_w: jnp.ndarray        # (B, d) f32 — current hypothesis weights
    h_b: jnp.ndarray        # (B,) f32 — current hypothesis offset
    h_valid: jnp.ndarray    # (B,) bool — (h_w, h_b) is a fitted separator
    warm_turn: jnp.ndarray  # (B,) bool — latest proposal cleanly classified
    #                         the next coordinator's shard (the single-carry
    #                         warm mode's latch precondition)
    c_w: jnp.ndarray        # (B, k, d) f32 — per-node carried separators
    c_b: jnp.ndarray        # (B, k) f32
    c_valid: jnp.ndarray    # (B, k) bool — node has a previous fit to carry
    warm_node: jnp.ndarray  # (B, k) bool — node's carry still classifies its
    #                         grown transcript cleanly (per-node latch
    #                         precondition; maintained incrementally at
    #                         append time)
    latches: jnp.ndarray    # (B,) i32 — warm-gate hits (observability only)
    comm: BatchCommLog


@dataclasses.dataclass(frozen=True)
class ProtocolInstance:
    """One protocol problem: k shards plus an error budget ε and a selector
    — the scenario spec the engine dispatches on.  Selectors are the two-way
    support selectors ("median", "maxmarg") and the one-way/baseline
    families ("sampling", "naive", "voting", "mixing";
    :mod:`repro.engine.oneway`).  ``seed`` keys per-instance randomness
    (only the "sampling" reservoir uses it)."""

    shards: Sequence[Tuple[np.ndarray, np.ndarray]]
    eps: float = 0.05
    selector: str = "median"
    seed: int = 0


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def shard_specs(tree):
    """The engine's one sharding rule as a PartitionSpec pytree: axis 0 of
    every batched leaf splits over the mesh's "data" axis, scalar leaves
    replicate.  Works on any engine pytree — :class:`EngineData`,
    :class:`ProtocolState`, :class:`MaxMargState`."""
    from jax.sharding import PartitionSpec
    return jax.tree_util.tree_map(
        lambda a: PartitionSpec() if np.ndim(a) == 0
        else PartitionSpec("data", *([None] * (np.ndim(a) - 1))), tree)


def device_put_sharded(tree, mesh):
    """Place an engine pytree on ``mesh`` under :func:`shard_specs` — host
    (numpy) leaves upload straight to their shards, so a packed sweep is
    *born sharded* rather than materialized on one device and resharded."""
    from jax.sharding import NamedSharding
    return jax.tree_util.tree_map(
        lambda a, p: jax.device_put(a, NamedSharding(mesh, p)),
        tree, shard_specs(tree))


def _mesh_batch(B: int, mesh) -> int:
    """Pad the instance count to a multiple of the mesh's "data" axis so
    every shard carries an equal slice; the pad rows are *born-done* dummy
    instances (zero data, zero budget) that never join a dispatch's active
    set and accrue nothing."""
    if mesh is None:
        return B
    return _round_up(B, mesh.shape["data"])


def maxmarg_transcript_capacity(k: int, max_epochs: int,
                                max_support: int) -> int:
    """Static per-node transcript bound for the MAXMARG selector.  Per epoch
    a node *receives* at most ``max_support`` points on each of the k-1 turns
    where it is not coordinator, plus (as coordinator) a 2-point violation
    reply from each of the k-1 others: ``(max_support + 2)(k-1)`` rows.  +8
    slack keeps the block writes in bounds (requires max_support ≤ 8)."""
    if not 1 <= max_support <= 8:
        raise ValueError(
            f"max_support must be in [1, 8] (block appends write at most 8 "
            f"rows past the fill), got {max_support}")
    return _round_up(max_epochs * (max_support + 2) * (k - 1) + 8, 8)


def pack_instances_maxmarg(
    instances: Sequence[ProtocolInstance],
    *,
    max_epochs: int,
    max_support: int,
    mesh=None,
) -> Tuple[EngineData, MaxMargState, int, int]:
    """Pad a MAXMARG sweep onto the engine's static shapes.

    Returns ``(data, state0, k, cap)``.  All instances must share the party
    count k and the dimension d (any d ≥ 2 — MAXMARG has no direction grid);
    shard sizes may be ragged (label-0 padding).  With ``mesh`` the batch
    pads to a multiple of the data-axis size with born-done dummy rows and
    uploads born-sharded (:func:`device_put_sharded`).
    """
    assert instances, "need at least one instance"
    ks = {len(inst.shards) for inst in instances}
    assert len(ks) == 1, f"instances must share the party count, got {ks}"
    k = ks.pop()
    ds = {s[0].shape[1] for inst in instances for s in inst.shards}
    assert len(ds) == 1, f"instances must share the dimension, got {ds}"
    d = ds.pop()
    B = _mesh_batch(len(instances), mesh)
    n_max = _round_up(max(s[0].shape[0] for inst in instances
                          for s in inst.shards), 8)
    cap = maxmarg_transcript_capacity(k, max_epochs, max_support)

    X = np.zeros((B, k, n_max, d), np.float32)
    y = np.zeros((B, k, n_max), np.int32)
    budget = np.zeros((B,), np.int32)
    for b, inst in enumerate(instances):
        n_total = 0
        for j, (Xs, ys) in enumerate(inst.shards):
            n = Xs.shape[0]
            assert (np.abs(ys) == 1).all(), "labels must be +-1"
            X[b, j, :n] = Xs
            y[b, j, :n] = ys
            n_total += n
        budget[b] = int(np.floor(inst.eps * n_total))
    done0 = np.zeros((B,), bool)
    done0[len(instances):] = True                    # born-done mesh padding

    data = EngineData(X, y, budget)
    # numpy zeros for the initial state: the leaves upload at the first
    # dispatch like any jit input, without one eager device op per field
    # (a dozen tiny dispatches of pure overhead per sweep otherwise)
    state0 = MaxMargState(
        wx=np.zeros((B, k, cap, d), np.float32),
        wy=np.zeros((B, k, cap), np.int32),
        w_fill=np.zeros((B, k), np.int32),
        turn=np.zeros((B,), np.int32),
        done=done0,
        converged=np.zeros((B,), bool),
        epochs=np.zeros((B,), np.int32),
        h_w=np.zeros((B, d), np.float32),
        h_b=np.zeros((B,), np.float32),
        h_valid=np.zeros((B,), bool),
        warm_turn=np.zeros((B,), bool),
        c_w=np.zeros((B, k, d), np.float32),
        c_b=np.zeros((B, k), np.float32),
        c_valid=np.zeros((B, k), bool),
        warm_node=np.zeros((B, k), bool),
        latches=np.zeros((B,), np.int32),
        comm=BatchCommLog(*(np.zeros((B,), np.int32)
                            for _ in BatchCommLog._fields)),
    )
    if mesh is not None:
        return (device_put_sharded(data, mesh),
                device_put_sharded(state0, mesh), k, cap)
    data = EngineData(jnp.asarray(X), jnp.asarray(y), jnp.asarray(budget))
    return data, state0, k, cap


def transcript_capacity(k: int, max_epochs: int) -> int:
    """Static per-node transcript bound.  Per epoch a node appends at most
    ``8k - 4`` rows: one coordinator turn (its own ≤2 band points, ≤2 extreme
    points from each of k-1 repliers, a 2-point pivot pair) plus k-1
    non-coordinator turns (≤2 received band points, its own ≤2 extremes,
    a 2-point pivot pair).  +8 slack keeps the 2-row block writes in bounds.
    """
    return _round_up(max_epochs * (8 * k - 4) + 8, 8)


def pack_instances(
    instances: Sequence[ProtocolInstance],
    *,
    n_angles: int,
    max_epochs: int,
    mesh=None,
) -> Tuple[EngineData, ProtocolState, int, int]:
    """Pad a sweep onto the engine's static shapes.

    Returns ``(data, state0, k, cap)``.  All instances must share the party
    count k and dimension d=2; shard sizes may be ragged (label-0 padding).
    ``n_max`` and ``cap`` are rounded up to multiples of 8 so repeated sweeps
    of similar sizes reuse the compiled runner.  With ``mesh`` the batch
    pads to a multiple of the data-axis size with born-done dummy rows and
    uploads born-sharded (:func:`device_put_sharded`).
    """
    assert instances, "need at least one instance"
    ks = {len(inst.shards) for inst in instances}
    assert len(ks) == 1, f"instances must share the party count, got {ks}"
    k = ks.pop()
    ds = {s[0].shape[1] for inst in instances for s in inst.shards}
    assert ds == {2}, f"MEDIAN engine is specified for R^2, got d={ds}"
    B = _mesh_batch(len(instances), mesh)
    n_max = _round_up(max(s[0].shape[0] for inst in instances
                          for s in inst.shards), 8)
    cap = transcript_capacity(k, max_epochs)

    X = np.zeros((B, k, n_max, 2), np.float32)
    y = np.zeros((B, k, n_max), np.int32)
    budget = np.zeros((B,), np.int32)
    for b, inst in enumerate(instances):
        n_total = 0
        for j, (Xs, ys) in enumerate(inst.shards):
            n = Xs.shape[0]
            assert (np.abs(ys) == 1).all(), "labels must be +-1"
            X[b, j, :n] = Xs
            y[b, j, :n] = ys
            n_total += n
        budget[b] = int(np.floor(inst.eps * n_total))
    done0 = np.zeros((B,), bool)
    done0[len(instances):] = True                    # born-done mesh padding

    data = EngineData(X, y, budget)
    state0 = ProtocolState(
        dir_ok=np.ones((B, n_angles), bool),
        wx=np.zeros((B, k, cap, 2), np.float32),
        wy=np.zeros((B, k, cap), np.int32),
        w_fill=np.zeros((B, k), np.int32),
        lo_w=np.full((B, k, n_angles), -np.inf, np.float32),
        hi_w=np.full((B, k, n_angles), np.inf, np.float32),
        turn=np.zeros((B,), np.int32),
        done=done0,
        converged=np.zeros((B,), bool),
        epochs=np.zeros((B,), np.int32),
        h_v=np.zeros((B, 2), np.float32),
        h_t=np.zeros((B,), np.float32),
        h_valid=np.zeros((B,), bool),
        comm=BatchCommLog(*(np.zeros((B,), np.int32)
                            for _ in BatchCommLog._fields)),
    )
    if mesh is not None:
        return (device_put_sharded(data, mesh),
                device_put_sharded(state0, mesh), k, cap)
    data = EngineData(jnp.asarray(X), jnp.asarray(y), jnp.asarray(budget))
    # jnp leaves on the legacy path: callers step this state eagerly (the
    # constant-fold differential test) and functional .at updates need them
    state0 = jax.tree_util.tree_map(jnp.asarray, state0)
    return data, state0, k, cap


class UnifiedState(NamedTuple):
    """Superset protocol state for mixed-selector dispatch — the union of
    :class:`ProtocolState`, :class:`MaxMargState` and the one-way sampling
    chain's reservoir carry, keyed by a *traced* per-instance selector code
    (``SEL_MEDIAN`` / ``SEL_MAXMARG`` / ``SEL_SAMPLING``).

    Leaf sharing is the whole design (DESIGN.md §unified mixed-selector
    state):

    * **transcripts** ``wx``/``wy``/``w_fill`` are shared: MEDIAN and MAXMARG
      append per the usual label-0 convention; a SAMPLING row keeps its
      Vitter reservoir in node slot ``k-1``'s transcript (so the terminal
      fit — which concatenates shard ``k-1`` with the coordinator
      transcript — *is* the sampling oracle's ``own ∪ reservoir`` fit);
    * **separator** ``h_w``/``h_b``/``h_valid`` are shared: a MEDIAN row
      stores its direction in ``h_w`` and threshold in ``h_b`` (result
      extraction negates ``h_w`` to recover ``LinearSeparator(-h_v, h_t)``);
    * **control** ``turn``/``done``/``converged``/``epochs``/``comm`` are
      shared and per-instance, so one dispatch mixes sessions at different
      phases of different protocols;
    * **selector-private** leaves are simply carried untouched by the other
      selectors' masked substeps: the MEDIAN arc (``dir_ok``/``lo_w``/
      ``hi_w``), the MAXMARG warm carries (``warm_turn``/``c_w``/``c_b``/
      ``c_valid``/``warm_node``/``latches``), and the sampling reservoir
      counters (``seen``/``res_cap``/``hop_keys``).

    ``sel`` is data, not structure: two sweeps with different selector mixes
    share one compiled ``unified.step``, and the session pool admits any mix
    into one slot array at one pinned dispatch key.
    """

    sel: jnp.ndarray        # (B,) i32 — SEL_* code per instance
    # --- median-private (m = n_angles, or 1 when the mix has no median) ---
    dir_ok: jnp.ndarray     # (B, m) bool — allowed direction arc
    lo_w: jnp.ndarray       # (B, k, m) f32 — running per-node threshold lo
    hi_w: jnp.ndarray       # (B, k, m) f32 — running per-node threshold hi
    # --- shared transcript + control ---
    wx: jnp.ndarray         # (B, k, cap, d) f32 — transcripts / reservoir
    wy: jnp.ndarray         # (B, k, cap) i32 — labels (0 = empty)
    w_fill: jnp.ndarray     # (B, k) i32 — fill counters
    turn: jnp.ndarray       # (B,) i32 — per-instance turn counter
    done: jnp.ndarray       # (B,) bool
    converged: jnp.ndarray  # (B,) bool
    epochs: jnp.ndarray     # (B,) i32
    # --- shared separator (median: h_w = h_v, h_b = h_t) ---
    h_w: jnp.ndarray        # (B, d) f32
    h_b: jnp.ndarray        # (B,) f32
    h_valid: jnp.ndarray    # (B,) bool
    # --- maxmarg-private warm carries ---
    warm_turn: jnp.ndarray  # (B,) bool
    c_w: jnp.ndarray        # (B, k, d) f32
    c_b: jnp.ndarray        # (B, k) f32
    c_valid: jnp.ndarray    # (B, k) bool
    warm_node: jnp.ndarray  # (B, k) bool
    latches: jnp.ndarray    # (B,) i32
    # --- sampling-private reservoir carry ---
    seen: jnp.ndarray       # (B,) i32 — valid stream rows ingested so far
    res_cap: jnp.ndarray    # (B,) i32 — per-instance ε-net reservoir size
    hop_keys: jnp.ndarray   # (B, k-1, 2) u32 — per-hop Vitter PRNG keys
    comm: BatchCommLog


def unified_transcript_capacity(k: int, max_epochs: int, max_support: int,
                                res_cap: int = 0,
                                has_median: bool = True) -> int:
    """Static shared transcript bound for a mixed-selector sweep: the max of
    every family's own bound (:func:`transcript_capacity` for MEDIAN,
    :func:`maxmarg_transcript_capacity` for MAXMARG, the largest per-instance
    ε-net reservoir for SAMPLING), so one (B, k, cap, d) buffer holds any
    mix.  Already a multiple of 8 (each family bound is)."""
    cap = maxmarg_transcript_capacity(k, max_epochs, max_support)
    if has_median:
        cap = max(cap, transcript_capacity(k, max_epochs))
    return max(cap, _round_up(max(res_cap, 0), 8))


def pack_instances_unified(
    instances: Sequence[ProtocolInstance],
    *,
    n_angles: int,
    max_epochs: int,
    max_support: int,
    vc_dim: Optional[int] = None,
    c: Optional[float] = None,
) -> Tuple[EngineData, UnifiedState, int, int]:
    """Pad a mixed MEDIAN + MAXMARG + SAMPLING sweep onto one static shape.

    Returns ``(data, state0, k, cap)``.  All instances must share the party
    count k and dimension d; any MEDIAN instance in the mix requires d=2
    (its direction grid is planar) and sizes the arc leaves to ``n_angles``
    — a median-free mix carries 1-wide stub arc leaves instead.  SAMPLING
    rows get their per-instance ε-net size in ``res_cap`` (``vc_dim``/``c``
    default exactly like :func:`repro.engine.oneway.run_instances`) and
    their Vitter hop keys pre-split from ``ProtocolInstance.seed``, so the
    reservoir stream is bitwise the one-way oracle's.
    """
    assert instances, "need at least one instance"
    ks = {len(inst.shards) for inst in instances}
    assert len(ks) == 1, f"instances must share the party count, got {ks}"
    k = ks.pop()
    ds = {s[0].shape[1] for inst in instances for s in inst.shards}
    assert len(ds) == 1, f"instances must share the dimension, got {ds}"
    d = ds.pop()
    sels = [inst.selector for inst in instances]
    unknown = set(sels) - set(SELECTOR_CODES)
    if unknown:
        raise ValueError(
            f"unified packing covers {sorted(SELECTOR_CODES)}, got "
            f"{sorted(unknown)}")
    has_median = "median" in sels
    if has_median and d != 2:
        raise ValueError(f"MEDIAN instances require d=2, got d={d}")
    m = n_angles if has_median else 1

    B = len(instances)
    n_max = _round_up(max(s[0].shape[0] for inst in instances
                          for s in inst.shards), 8)
    vc = vc_dim if vc_dim is not None else d + 1
    cc = c if c is not None else EPSILON_NET_C
    res_cap = np.zeros((B,), np.int32)
    hop_keys = np.zeros((B, max(k - 1, 1), 2), np.uint32)
    for b, inst in enumerate(instances):
        if inst.selector == "sampling":
            res_cap[b] = epsilon_net_size(inst.eps, vc, c=cc)
            if k > 1:
                hop_keys[b] = np.asarray(jax.random.split(
                    jax.random.PRNGKey(inst.seed), k - 1))
    cap = unified_transcript_capacity(k, max_epochs, max_support,
                                      res_cap=int(res_cap.max()),
                                      has_median=has_median)

    X = np.zeros((B, k, n_max, d), np.float32)
    y = np.zeros((B, k, n_max), np.int32)
    budget = np.zeros((B,), np.int32)
    for b, inst in enumerate(instances):
        n_total = 0
        for j, (Xs, ys) in enumerate(inst.shards):
            n = Xs.shape[0]
            assert (np.abs(ys) == 1).all(), "labels must be +-1"
            X[b, j, :n] = Xs
            y[b, j, :n] = ys
            n_total += n
        budget[b] = int(np.floor(inst.eps * n_total))

    state0 = UnifiedState(
        sel=np.asarray([SELECTOR_CODES[s] for s in sels], np.int32),
        dir_ok=np.ones((B, m), bool),
        lo_w=np.full((B, k, m), -np.inf, np.float32),
        hi_w=np.full((B, k, m), np.inf, np.float32),
        wx=np.zeros((B, k, cap, d), np.float32),
        wy=np.zeros((B, k, cap), np.int32),
        w_fill=np.zeros((B, k), np.int32),
        turn=np.zeros((B,), np.int32),
        done=np.zeros((B,), bool),
        converged=np.zeros((B,), bool),
        epochs=np.zeros((B,), np.int32),
        h_w=np.zeros((B, d), np.float32),
        h_b=np.zeros((B,), np.float32),
        h_valid=np.zeros((B,), bool),
        warm_turn=np.zeros((B,), bool),
        c_w=np.zeros((B, k, d), np.float32),
        c_b=np.zeros((B, k), np.float32),
        c_valid=np.zeros((B, k), bool),
        warm_node=np.zeros((B, k), bool),
        latches=np.zeros((B,), np.int32),
        seen=np.zeros((B,), np.int32),
        res_cap=res_cap,
        hop_keys=hop_keys,
        comm=BatchCommLog(*(np.zeros((B,), np.int32)
                            for _ in BatchCommLog._fields)),
    )
    data = EngineData(jnp.asarray(X), jnp.asarray(y), jnp.asarray(budget))
    return data, state0, k, cap
