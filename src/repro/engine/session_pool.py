"""Fault-tolerant streaming session pool over the compacted hot loop.

The ROADMAP's north star is a persistent service absorbing protocol traffic,
not a one-shot sweep.  This module turns the hot loop's admit/evict batch
compaction (PRs 4-6) into *admission-aware* streaming: a ring buffer of W
session slots where slots freed by converged/evicted sessions refill from a
pending queue **between turns**, at pinned ``(n_pad, width, warm)`` compile
cache keys, so a saturated pool's steady-state recompile count is 0
(``benchmarks/service_sweep.py`` measures it; the key-log machinery of
tests/test_recompile.py gates it).

Mixed-phase dispatch is what PR 7's per-instance ``turn`` refactor buys:
admitted sessions start at turn 0 while their slot neighbours are mid-epoch,
and one dispatch advances them all (the coordinator index ``ci = turn % k``
is a (B,) gather).  ``PoolConfig(selector="unified")`` extends the same move
to mixed-*family* dispatch: the selector becomes traced per-row data in the
superset :class:`~repro.engine.state.UnifiedState`, so ONE pool absorbs
interleaved MEDIAN + MAXMARG + SAMPLING sessions with no per-family
bucketing and no extra compile keys (:mod:`repro.engine.unified`).  The pool's bit-exactness contract is **compiled-program
identity**: every dispatch uses one pinned (full-block, full-width) cache
key (see ``_dispatch`` for why — XLA's shape-dependent fusion perturbs
separator floats by ulps across keys), so a session's results are a pure
function of its own data and are **bit-exact across any admission timing,
batch composition, fault delays and checkpoint/restore**.  Against the
sweep-oriented ``engine.run_instances`` (which compiles at its own
fill-capped keys) the pool is decision- and comm-exact, with separators
typically bitwise equal and at worst a few f32 ulps apart — the same
cross-shape caveat as the engine's own hot-vs-cold series.

Failure model (``engine/faults.py``, DESIGN.md §session pool & failure
model): a seeded deterministic schedule injects per-turn node dropouts and
lost messages (the turn aborts before dispatch — a missed one-pool-turn
deadline — and retries under exponential backoff, bounded by
``retry_budget``), stragglers (the session sits out a drawn number of pool
turns, no retry charged), and post-turn state corruption.  Supervision is
host-side and never crashes the pool: every live slot is screened each turn
against three invariants — NaN separator, non-monotone transcript fill
(every healthy continuing turn strictly grows some transcript, so a
dispatched live row whose max fill fails to stay positive and monotone is
corrupt), and comm-budget blowout — and a tripped invariant or exhausted
retry budget quarantines the session, which is then evicted with its
retry/backoff counters surfaced (slot lifecycle: pending → live →
quarantined → evicted/converged).  Delivered messages are always metered
exactly; transient faults only delay turns, so surviving sessions keep
bit-exact decisions.

Checkpoint/restore reuses the flat-key ``.npz`` + JSON-manifest idiom of
``train/checkpoint.py``: device trees, host supervision arrays, the pending
queue and the session ledger round-trip, and the fault schedule is a pure
hash of ``(seed, session id, pool turn)`` — no RNG state — so a restored
pool replays the identical fault/eviction/retry sequence and unaffected
sessions finish bit-exact (tests/test_session_pool.py pins all of it).
"""

from __future__ import annotations

import dataclasses
import functools
import json
import os
from collections import deque
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.sampling import epsilon_net_size
from repro.engine import faults as F
from repro.engine import hotloop, median, maxmarg, unified
from repro.engine.state import (
    BatchCommLog,
    EngineData,
    MaxMargState,
    ProtocolState,
    SEL_MEDIAN,
    SELECTOR_CODES,
    SELECTOR_NAMES,
    UnifiedState,
    _round_up,
    maxmarg_transcript_capacity,
    transcript_capacity,
    unified_transcript_capacity,
)

# host-side slot lifecycle (the device only ever sees done flags)
SLOT_EMPTY = 0
SLOT_LIVE = 1
SLOT_QUARANTINED = 2

# terminal session statuses in the ledger
ST_PENDING = "pending"
ST_LIVE = "live"
ST_CONVERGED = "converged"
ST_BUDGET = "budget_exhausted"
ST_QUARANTINED = "quarantined"


@dataclasses.dataclass(frozen=True)
class PoolConfig:
    """Static pool geometry + supervision policy.

    Everything that pins a compile-cache key lives here: ``slots`` (the ring
    width W), ``k``/``n_pad``/``d`` (the shared instance shapes every
    admitted session is padded to — ragged shards pad with label-0 rows,
    exactly the engine's packing convention), the per-session epoch budget,
    and the fixed ``admit_block``/``corrupt_block`` scatter widths (blocks
    pad with out-of-range indices that the device scatters drop, so
    admission and corruption are each ONE pinned-shape dispatch regardless
    of how many rows they touch).

    Supervision policy: a session's turn must complete within one pool turn
    (the deadline); a miss (dropout / lost message) retries after
    ``backoff_base * 2**(retries-1)`` pool turns and quarantines when the
    consecutive-retry count exceeds ``retry_budget``.  ``comm_limit_bits``
    is the comm-blowout invariant threshold — generous against any
    legitimate per-turn bit cost (k-1 bits), tiny against
    ``faults.COMM_SPIKE_BITS``.

    ``selector="unified"`` makes admission selector-agnostic: each
    :meth:`SessionPool.submit` call names its own protocol family
    (MEDIAN / MAXMARG / SAMPLING), the selector rides the pending queue as
    data, and every mixed dispatch still uses the ONE pinned key — the
    superset :class:`~repro.engine.state.UnifiedState` cap covers every
    family, including ``res_cap`` (the largest per-session ε-net reservoir
    the pool accepts; defaults to the ε-net size at the pool's own ``eps``).
    """

    slots: int
    k: int
    n_pad: int
    d: int = 2
    selector: str = "median"
    eps: float = 0.05
    n_angles: int = 256
    max_epochs: int = 16
    max_support: int = 4
    svm_steps: int = 2000
    svm_stages: int = 3
    lam0: float = 1e-3
    # MAXMARG refit solver path: None = TPU-default (tiled Pegasos kernel
    # on TPU, classic d-unrolled loop elsewhere) — resolved once at pool
    # construction so admission keys stay pinned across the pool's life
    solver_kernel: Optional[bool] = None
    # unified pools only: max ε-net reservoir rows any SAMPLING session may
    # request; None resolves to the size at the pool's default eps
    res_cap: Optional[int] = None
    admit_block: int = 8
    corrupt_block: int = 4
    retry_budget: int = 3
    backoff_base: int = 1
    comm_limit_bits: int = 1 << 16
    checkpoint_every: int = 0            # pool turns between snapshots; 0=off
    checkpoint_dir: Optional[str] = None

    def __post_init__(self):
        if self.selector not in ("median", "maxmarg", "unified"):
            raise ValueError(f"unknown selector {self.selector!r}")
        if self.selector == "median" and self.d != 2:
            raise ValueError("MEDIAN engine is specified for R^2")
        if self.selector == "unified" and self.res_cap is None:
            # resolved once so dataclasses.asdict round-trips the pinned cap
            object.__setattr__(self, "res_cap", _round_up(
                epsilon_net_size(self.eps, self.d + 1), 8))
        if self.n_pad % 8:
            object.__setattr__(self, "n_pad", _round_up(self.n_pad, 8))
        if self.slots < 1 or self.k < 2:
            raise ValueError("need slots >= 1 and k >= 2")
        if self.checkpoint_every and not self.checkpoint_dir:
            raise ValueError("checkpoint_every needs checkpoint_dir")

    @property
    def max_turns(self) -> int:
        return self.k * self.max_epochs

    @property
    def cap(self) -> int:
        if self.selector == "median":
            return transcript_capacity(self.k, self.max_epochs)
        if self.selector == "unified":
            return unified_transcript_capacity(
                self.k, self.max_epochs, self.max_support,
                res_cap=int(self.res_cap or 0), has_median=(self.d == 2))
        return maxmarg_transcript_capacity(self.k, self.max_epochs,
                                           self.max_support)


# ---------------------------------------------------------------------------
# pinned-shape device ops (admission / corruption / supervision view)
# ---------------------------------------------------------------------------


@jax.jit
def _admit_rows(data, state, idx, dblk, sblk):
    """Scatter an admission block into the pool's device trees: ``idx`` is
    the fixed-size (A,) slot index block (out-of-range tail drops), ``dblk``
    / ``sblk`` the fresh (A, ...) data/state rows.  One dispatch per
    admission wave, cache-keyed only on the pinned block shapes."""
    return (hotloop.put_instances(data, dblk, idx),
            hotloop.put_instances(state, sblk, idx))


def _slot_masks(W, idx, kind):
    def mask(kv):
        return jnp.zeros((W,), bool).at[idx].set(kind == kv)
    return mask(F.CORRUPT_NAN), mask(F.CORRUPT_FILL), mask(F.CORRUPT_COMM)


@jax.jit
def _corrupt_median(state: ProtocolState, idx, kind) -> ProtocolState:
    """Apply drawn corruption kinds to the rows in ``idx`` (fixed-size
    block, out-of-range tail drops).  Each kind trips exactly one
    supervisor invariant: NaN separator, zeroed (non-monotone) fills, or a
    comm-bit spike.  Runs *after* the turn's dispatch — delivered messages
    were metered exactly; only the victim's own state mutates."""
    m_nan, m_fill, m_comm = _slot_masks(state.done.shape[0], idx, kind)
    return state._replace(
        h_t=jnp.where(m_nan, jnp.nan, state.h_t),
        h_v=jnp.where(m_nan[:, None], jnp.nan, state.h_v),
        w_fill=jnp.where(m_fill[:, None], 0, state.w_fill),
        comm=state.comm._replace(
            bits=state.comm.bits
            + jnp.where(m_comm, F.COMM_SPIKE_BITS, 0).astype(jnp.int32)),
    )


@jax.jit
def _corrupt_maxmarg(state: MaxMargState, idx, kind) -> MaxMargState:
    m_nan, m_fill, m_comm = _slot_masks(state.done.shape[0], idx, kind)
    return state._replace(
        h_b=jnp.where(m_nan, jnp.nan, state.h_b),
        h_w=jnp.where(m_nan[:, None], jnp.nan, state.h_w),
        w_fill=jnp.where(m_fill[:, None], 0, state.w_fill),
        comm=state.comm._replace(
            bits=state.comm.bits
            + jnp.where(m_comm, F.COMM_SPIKE_BITS, 0).astype(jnp.int32)),
    )


# UnifiedState shares MaxMargState's separator/transcript/comm leaf names,
# so the maxmarg corruption body applies verbatim — jax.jit re-keys on the
# pytree structure, giving the unified pool its own cached variant.  The
# supervision view likewise: max w_fill is each family's ACTUAL transcript
# fill (a SAMPLING row's reservoir fill is min(seen, res_cap), which grows
# monotonically per hop — distinct from the hot loop's width view, which
# inflates fills to res_cap for coverage).
_corrupt_unified = _corrupt_maxmarg


@jax.jit
def _view_median(state: ProtocolState) -> jnp.ndarray:
    """Supervision view as one (5, W) i32 transfer: done, converged, max
    transcript fill, NaN-separator flag, comm bits."""
    nan = jnp.isnan(state.h_t) | jnp.any(jnp.isnan(state.h_v), axis=1)
    return jnp.stack([state.done.astype(jnp.int32),
                      state.converged.astype(jnp.int32),
                      jnp.max(state.w_fill, axis=1),
                      nan.astype(jnp.int32),
                      state.comm.bits])


@jax.jit
def _view_maxmarg(state: MaxMargState) -> jnp.ndarray:
    nan = jnp.isnan(state.h_b) | jnp.any(jnp.isnan(state.h_w), axis=1)
    return jnp.stack([state.done.astype(jnp.int32),
                      state.converged.astype(jnp.int32),
                      jnp.max(state.w_fill, axis=1),
                      nan.astype(jnp.int32),
                      state.comm.bits])


_view_unified = _view_maxmarg


# ---------------------------------------------------------------------------
# fresh-row templates (host numpy; scattered on admission)
# ---------------------------------------------------------------------------


def _fresh_state_median(A: int, cfg: PoolConfig, live: int) -> ProtocolState:
    m, k, cap = cfg.n_angles, cfg.k, cfg.cap
    done = np.zeros((A,), bool)
    done[live:] = True                    # block padding rows are born done
    return ProtocolState(
        dir_ok=np.ones((A, m), bool),
        wx=np.zeros((A, k, cap, 2), np.float32),
        wy=np.zeros((A, k, cap), np.int32),
        w_fill=np.zeros((A, k), np.int32),
        lo_w=np.full((A, k, m), -np.inf, np.float32),
        hi_w=np.full((A, k, m), np.inf, np.float32),
        turn=np.zeros((A,), np.int32),
        done=done,
        converged=np.zeros((A,), bool),
        epochs=np.zeros((A,), np.int32),
        h_v=np.zeros((A, 2), np.float32),
        h_t=np.zeros((A,), np.float32),
        h_valid=np.zeros((A,), bool),
        comm=BatchCommLog(*(np.zeros((A,), np.int32)
                            for _ in BatchCommLog._fields)),
    )


def _fresh_state_maxmarg(A: int, cfg: PoolConfig, live: int) -> MaxMargState:
    k, cap, d = cfg.k, cfg.cap, cfg.d
    done = np.zeros((A,), bool)
    done[live:] = True
    return MaxMargState(
        wx=np.zeros((A, k, cap, d), np.float32),
        wy=np.zeros((A, k, cap), np.int32),
        w_fill=np.zeros((A, k), np.int32),
        turn=np.zeros((A,), np.int32),
        done=done,
        converged=np.zeros((A,), bool),
        epochs=np.zeros((A,), np.int32),
        h_w=np.zeros((A, d), np.float32),
        h_b=np.zeros((A,), np.float32),
        h_valid=np.zeros((A,), bool),
        warm_turn=np.zeros((A,), bool),
        c_w=np.zeros((A, k, d), np.float32),
        c_b=np.zeros((A, k), np.float32),
        c_valid=np.zeros((A, k), bool),
        warm_node=np.zeros((A, k), bool),
        latches=np.zeros((A,), np.int32),
        comm=BatchCommLog(*(np.zeros((A,), np.int32)
                            for _ in BatchCommLog._fields)),
    )


def _fresh_state_unified(A: int, cfg: PoolConfig, live: int,
                         batch: Sequence["_Pending"] = ()) -> UnifiedState:
    """Fresh superset rows for a mixed admission wave: the selector code,
    reservoir size and Vitter hop keys are per-row data taken from the
    pending entries — the device tree shapes (and so the admission scatter's
    compile key) never depend on the wave's selector mix."""
    k, cap, d = cfg.k, cfg.cap, cfg.d
    m = cfg.n_angles if d == 2 else 1
    done = np.zeros((A,), bool)
    done[live:] = True
    sel = np.zeros((A,), np.int32)
    res_cap = np.zeros((A,), np.int32)
    hop_keys = np.zeros((A, max(k - 1, 1), 2), np.uint32)
    for i, p in enumerate(batch):
        sel[i] = SELECTOR_CODES[p.selector]
        if p.selector == "sampling":
            res_cap[i] = p.res_cap
            hop_keys[i] = np.asarray(jax.random.split(
                jax.random.PRNGKey(p.seed), k - 1))
    return UnifiedState(
        sel=sel,
        dir_ok=np.ones((A, m), bool),
        lo_w=np.full((A, k, m), -np.inf, np.float32),
        hi_w=np.full((A, k, m), np.inf, np.float32),
        wx=np.zeros((A, k, cap, d), np.float32),
        wy=np.zeros((A, k, cap), np.int32),
        w_fill=np.zeros((A, k), np.int32),
        turn=np.zeros((A,), np.int32),
        done=done,
        converged=np.zeros((A,), bool),
        epochs=np.zeros((A,), np.int32),
        h_w=np.zeros((A, d), np.float32),
        h_b=np.zeros((A,), np.float32),
        h_valid=np.zeros((A,), bool),
        warm_turn=np.zeros((A,), bool),
        c_w=np.zeros((A, k, d), np.float32),
        c_b=np.zeros((A, k), np.float32),
        c_valid=np.zeros((A, k), bool),
        warm_node=np.zeros((A, k), bool),
        latches=np.zeros((A,), np.int32),
        seen=np.zeros((A,), np.int32),
        res_cap=res_cap,
        hop_keys=hop_keys,
        comm=BatchCommLog(*(np.zeros((A,), np.int32)
                            for _ in BatchCommLog._fields)),
    )


@dataclasses.dataclass
class _Pending:
    sid: int
    X: np.ndarray        # (k, n_pad, d) f32
    y: np.ndarray        # (k, n_pad) i32
    budget: int
    selector: str = "median"   # per-session family (unified pools)
    seed: int = 0              # Vitter PRNG seed (SAMPLING sessions)
    res_cap: int = 0           # ε-net reservoir rows (SAMPLING sessions)


class SessionPool:
    """Ring-buffer session pool: streaming admission over the hot loop,
    seeded fault injection, host-side supervision, checkpoint/restore.

    Typical use (the protocol service in :mod:`repro.serve.service` wraps
    this behind a streaming-ingest API)::

        pool = SessionPool(PoolConfig(slots=32, k=2, n_pad=64),
                           schedule=FaultSchedule(seed=7, p_dropout=0.05))
        sids = [pool.submit(shards) for shards in workload]
        pool.run()
        results = pool.results          # sid -> ProtocolResult
        pool.session(sid)["retries"]    # per-session supervision counters

    All supervision decisions are pure functions of (host arrays, device
    view, fault schedule), so two pools with equal config+schedule+workload
    make identical decisions — including across :meth:`checkpoint` /
    :meth:`restore` (the determinism contract tests pin).

    With ``PoolConfig(selector="unified")`` ONE pool absorbs mixed
    MEDIAN + MAXMARG + SAMPLING traffic: ``submit(shards,
    selector="sampling", seed=...)`` tags each session, the pending queue
    carries the tag as data, and dispatch/admission/corruption all stay at
    their single pinned keys (the superset state makes the selector a
    traced per-row leaf — see :mod:`repro.engine.unified`).

    Compile-key contract: everything that keys a compiled variant is fixed
    at construction — ``PoolConfig``'s geometry (``slots``/``k``/``n_pad``/
    ``d``), the ``cap`` transcript width, the solver statics
    (``max_support``/``svm_steps``/``svm_stages``, the resolved
    ``solver_kernel``), and the ``admit_block``/``corrupt_block`` scatter
    shapes.  Dispatch always uses the one key ``(round_up(slots, 4), cap,
    False, False)``, so after the first pool turn of each op NOTHING a
    caller streams in recompiles: not session count, admission order,
    selector mix (unified pools), ε, seeds, or fault timing.  Changing any
    ``PoolConfig`` field means a new pool and a fresh set of keys.
    """

    def __init__(self, config: PoolConfig,
                 schedule: Optional[F.FaultSchedule] = None,
                 stats: Optional[dict] = None):
        self.cfg = config
        self.schedule = schedule if schedule is not None else F.FaultSchedule()
        self.stats: Dict[str, Any] = stats if stats is not None else {}
        # resolved once: the solver path is part of the pinned dispatch key
        from repro.engine import dataplane
        self._solver_kernel = (dataplane.use_pallas_default()
                               if config.solver_kernel is None
                               else bool(config.solver_kernel))
        W, k, n_pad, d = config.slots, config.k, config.n_pad, config.d

        if config.selector == "median":
            from repro.core import geometry as geo
            self._V = jnp.asarray(geo.direction_grid(config.n_angles),
                                  jnp.float32)
            state0 = _fresh_state_median(W, config, live=0)
        elif config.selector == "unified":
            if config.d == 2:
                from repro.core import geometry as geo
                self._V = jnp.asarray(geo.direction_grid(config.n_angles),
                                      jnp.float32)
            else:   # median-free pool: stub grid (the substep is omitted)
                self._V = jnp.zeros((1, config.d), jnp.float32)
            state0 = _fresh_state_unified(W, config, live=0)
        else:
            self._V = None
            state0 = _fresh_state_maxmarg(W, config, live=0)
        self.data = EngineData(
            jnp.zeros((W, k, n_pad, d), jnp.float32),
            jnp.zeros((W, k, n_pad), jnp.int32),
            jnp.zeros((W,), jnp.int32))
        # empty slots are born done: the dispatch mask is host-side anyway,
        # and done=True keeps them inert even if gathered as padding
        self.state = jax.tree_util.tree_map(jnp.asarray, state0)

        self.pool_turn = 0
        self._next_sid = 0
        self.pending: deque = deque()
        self.sessions: Dict[int, Dict[str, Any]] = {}
        self.results: Dict[int, Any] = {}

        # host supervision arrays (one row per slot)
        self.sid = np.full((W,), -1, np.int64)
        self.slot_state = np.full((W,), SLOT_EMPTY, np.int32)
        self.retries = np.zeros((W,), np.int32)       # consecutive, current
        self.backoff_until = np.zeros((W,), np.int64)
        self.straggle_until = np.zeros((W,), np.int64)
        self.prev_fill = np.zeros((W,), np.int32)
        self.turns_done = np.zeros((W,), np.int32)
        self.slot_sel = np.zeros((W,), np.int32)   # SEL_* code per slot

        for key in ("admitted", "evicted_converged", "evicted_budget",
                    "quarantined", "dispatches", "pool_turns",
                    "retries_total", "backoffs_total", "dropouts",
                    "drop_msgs", "straggles", "corruptions"):
            self.stats.setdefault(key, 0)

    # -- submission ---------------------------------------------------------

    def submit(self, shards: Sequence[Tuple[np.ndarray, np.ndarray]],
               eps: Optional[float] = None,
               selector: Optional[str] = None, seed: int = 0) -> int:
        """Queue one protocol instance (k ragged shards, padded here to the
        pool's pinned (k, n_pad, d) shape).  Returns the session id.

        ``selector`` picks the session's protocol family on unified pools
        (default: MEDIAN when d=2, else MAXMARG); per-selector pools accept
        only their own.  ``seed`` feeds a SAMPLING session's Vitter chain —
        its ε-net reservoir size (from ``eps``) must fit the pool's pinned
        ``res_cap``.  Neither affects any compile key: both ride the
        pending queue as data."""
        cfg = self.cfg
        if selector is None:
            selector = (cfg.selector if cfg.selector != "unified"
                        else ("median" if cfg.d == 2 else "maxmarg"))
        if cfg.selector == "unified":
            if selector not in SELECTOR_CODES:
                raise ValueError(
                    f"unified pools take {sorted(SELECTOR_CODES)}, "
                    f"got {selector!r}")
            if selector == "median" and cfg.d != 2:
                raise ValueError("MEDIAN sessions require a d=2 pool")
        elif selector != cfg.selector:
            raise ValueError(
                f"pool is pinned to selector {cfg.selector!r}; "
                f"mixed traffic needs PoolConfig(selector='unified')")
        if len(shards) != cfg.k:
            raise ValueError(f"expected {cfg.k} shards, got {len(shards)}")
        X = np.zeros((cfg.k, cfg.n_pad, cfg.d), np.float32)
        y = np.zeros((cfg.k, cfg.n_pad), np.int32)
        n_total = 0
        for j, (Xs, ys) in enumerate(shards):
            Xs = np.asarray(Xs)
            ys = np.asarray(ys)
            n = Xs.shape[0]
            if n > cfg.n_pad:
                raise ValueError(
                    f"shard {j} has {n} rows > pinned n_pad={cfg.n_pad}")
            if Xs.shape[1] != cfg.d:
                raise ValueError(f"shard {j} is d={Xs.shape[1]}, "
                                 f"pool is d={cfg.d}")
            if not (np.abs(ys) == 1).all():
                raise ValueError("labels must be +-1")
            X[j, :n] = Xs
            y[j, :n] = ys
            n_total += n
        eps_eff = cfg.eps if eps is None else eps
        budget = int(np.floor(eps_eff * n_total))
        res_cap = 0
        if selector == "sampling":
            res_cap = epsilon_net_size(eps_eff, cfg.d + 1)
            if res_cap > (cfg.res_cap or 0):
                raise ValueError(
                    f"SAMPLING session needs a {res_cap}-row reservoir, "
                    f"pool pins res_cap={cfg.res_cap} (lower eps at "
                    f"construction or raise PoolConfig.res_cap)")
        sid = self._next_sid
        self._next_sid += 1
        self.pending.append(_Pending(sid, X, y, budget,
                                     selector=selector, seed=seed,
                                     res_cap=res_cap))
        self.sessions[sid] = {
            "status": ST_PENDING, "selector": selector,
            "retries": 0, "backoffs": 0,
            "dropouts": 0, "drop_msgs": 0, "straggles": 0,
            "corrupt_kind": -1, "quarantine_reason": None,
            "admitted_turn": -1, "evicted_turn": -1, "turns": 0,
        }
        return sid

    def session(self, sid: int) -> Dict[str, Any]:
        return self.sessions[sid]

    # -- internals ----------------------------------------------------------

    def _admit(self):
        """Refill empty slots from the pending queue in FIFO order, in
        fixed ``admit_block``-sized scatter waves (tail slots carry the
        out-of-range index W, dropped on device)."""
        cfg = self.cfg
        W, A = cfg.slots, cfg.admit_block
        free = np.flatnonzero(self.slot_state == SLOT_EMPTY)
        while self.pending and free.size:
            take = min(len(self.pending), free.size, A)
            batch = [self.pending.popleft() for _ in range(take)]
            slots = free[:take]
            free = free[take:]

            dblk = EngineData(
                np.stack([p.X for p in batch]),
                np.stack([p.y for p in batch]),
                np.asarray([p.budget for p in batch], np.int32))
            if take < A:   # pad the pinned block; tail rows scatter-drop
                dblk = EngineData(
                    np.concatenate([dblk.X,
                                    np.zeros((A - take,) + dblk.X.shape[1:],
                                             np.float32)]),
                    np.concatenate([dblk.y,
                                    np.zeros((A - take,) + dblk.y.shape[1:],
                                             np.int32)]),
                    np.concatenate([dblk.budget,
                                    np.zeros((A - take,), np.int32)]))
            if cfg.selector == "median":
                fresh = _fresh_state_median(A, cfg, live=take)
            elif cfg.selector == "unified":
                fresh = _fresh_state_unified(A, cfg, live=take, batch=batch)
            else:
                fresh = _fresh_state_maxmarg(A, cfg, live=take)
            idx = np.full((A,), W, np.int32)
            idx[:take] = slots
            self.data, self.state = _admit_rows(
                self.data, self.state, jnp.asarray(idx), dblk, fresh)

            for p, s in zip(batch, slots):
                self.sid[s] = p.sid
                self.slot_sel[s] = SELECTOR_CODES[p.selector]
                self.slot_state[s] = SLOT_LIVE
                self.retries[s] = 0
                self.backoff_until[s] = 0
                self.straggle_until[s] = 0
                self.prev_fill[s] = 0
                self.turns_done[s] = 0
                rec = self.sessions[p.sid]
                rec["status"] = ST_LIVE
                rec["admitted_turn"] = self.pool_turn
                self.stats["admitted"] += 1

    def _dispatch(self, rows: np.ndarray):
        """One mixed-phase turn over the given slot rows, always at the
        pool's SINGLE pinned dispatch key: the full ``slots``-sized index
        block (inactive tail = out-of-range W, dropped by the scatter) and
        the full ``cap`` transcript width.

        Pinning one key — rather than reusing the sweeps' fill-capped width
        and batch-size buckets — is a deliberate robustness/perf trade.
        XLA fuses the per-turn scans differently at different shapes (e.g.
        the stage-5 extremes reduction picks up FMA contraction at some
        widths), which perturbs separator floats by ulps across compile
        keys even though every decision is identical.  A service cannot
        let *which sessions happen to cohabit a batch* leak into results:
        with one key, every turn of every session runs the exact same
        compiled program, so chaos runs, fault-free runs, restored runs
        and differently-streamed runs are bit-exact per session BY
        CONSTRUCTION.  A saturated pool (the steady state the service
        optimizes for) dispatches a full block anyway, so the cost is
        confined to drain tails and the worst-case transcript width.  The
        key is appended to ``hotloop.KEY_LOG`` so the recompile gates
        cover pool traffic too."""
        cfg = self.cfg
        W = cfg.slots
        n_act = rows.size
        n_pad = _round_up(W, hotloop.BATCH_MULT)
        idx = np.full((n_pad,), W, np.int32)
        idx[:n_act] = rows
        width = cfg.cap
        hotloop.KEY_LOG.append((n_pad, width, False, False))
        if cfg.selector == "median":
            self.state = median._hot_turn(
                self.data, self._V, self.state, jnp.asarray(idx),
                jnp.int32(n_act), k=cfg.k, first_turn=False,
                cut_kernel=False, extremes_kernel=False, trans_width=width)
        elif cfg.selector == "unified":
            self.state = unified._hot_turn(
                self.data, self._V, self.state, jnp.asarray(idx),
                jnp.int32(n_act), k=cfg.k, max_support=cfg.max_support,
                steps=cfg.svm_steps, stages=cfg.svm_stages, lam0=cfg.lam0,
                trans_width=width, warm=False, per_node=False,
                has_median=(cfg.d == 2), first_turn=False,
                cut_kernel=False, extremes_kernel=False,
                fused_kernel=False, solver_kernel=self._solver_kernel)
        else:
            self.state = maxmarg._hot_turn(
                self.data, self.state, jnp.asarray(idx), jnp.int32(n_act),
                k=cfg.k, max_support=cfg.max_support, steps=cfg.svm_steps,
                stages=cfg.svm_stages, lam0=cfg.lam0, trans_width=width,
                warm=False, per_node=False, fused_kernel=False,
                solver_kernel=self._solver_kernel)
        self.stats["dispatches"] += 1

    def _corrupt(self, rows: np.ndarray, kinds: np.ndarray):
        """Post-turn corruption wave at the pinned ``corrupt_block`` shape
        (multiple waves if the draw hit more rows than one block holds)."""
        C = self.cfg.corrupt_block
        W = self.cfg.slots
        fn = {"median": _corrupt_median, "maxmarg": _corrupt_maxmarg,
              "unified": _corrupt_unified}[self.cfg.selector]
        for off in range(0, rows.size, C):
            idx = np.full((C,), W, np.int32)
            knd = np.full((C,), -1, np.int32)
            chunk = slice(off, min(off + C, rows.size))
            take = rows[chunk].size
            idx[:take] = rows[chunk]
            knd[:take] = kinds[chunk]
            self.state = fn(self.state, jnp.asarray(idx), jnp.asarray(knd))

    def _quarantine(self, slot: int, reason: str):
        self.slot_state[slot] = SLOT_QUARANTINED
        rec = self.sessions[self.sid[slot]]
        rec["status"] = ST_QUARANTINED
        rec["quarantine_reason"] = reason
        self.stats["quarantined"] += 1

    def _evict(self, slots: np.ndarray):
        """Free finished/quarantined slots, extracting results for sessions
        that terminated cleanly.  One batched device->host transfer of the
        small per-slot result leaves per eviction wave."""
        from repro.core import classifiers as clf
        from repro.core.protocols.one_way import ProtocolResult

        cfg = self.cfg
        s = self.state
        if cfg.selector == "median":
            w_np = -np.asarray(s.h_v, np.float64)
            b_np = np.asarray(s.h_t, np.float64)
        else:
            w_np = np.asarray(s.h_w, np.float64)
            b_np = np.asarray(s.h_b, np.float64)
            if cfg.selector == "unified":
                # shared-leaf convention: MEDIAN rows store h_v in h_w and
                # recover LinearSeparator(-h_v, h_t) at extraction
                w_np[self.slot_sel == SEL_MEDIAN] *= -1.0
        epochs = np.asarray(s.epochs)
        conv = np.asarray(s.converged)
        comm_np = type(s.comm)(*(np.asarray(a) for a in s.comm))

        for slot in slots:
            sid = int(self.sid[slot])
            rec = self.sessions[sid]
            quarantined = self.slot_state[slot] == SLOT_QUARANTINED
            if not quarantined:
                converged = bool(conv[slot])
                rec["status"] = ST_CONVERGED if converged else ST_BUDGET
                self.stats["evicted_converged" if converged
                           else "evicted_budget"] += 1
                h = clf.LinearSeparator(w_np[slot], float(b_np[slot]))
                sel_name = (SELECTOR_NAMES[int(self.slot_sel[slot])]
                            if cfg.selector == "unified" else cfg.selector)
                self.results[sid] = ProtocolResult(
                    h,
                    comm_np.summary(int(slot), dim=cfg.d),
                    rounds=(int(epochs[slot]) if converged
                            else cfg.max_epochs),
                    converged=converged,
                    extra={"engine": True, "session_pool": True,
                           "selector": sel_name, "sid": sid,
                           "retries": rec["retries"],
                           "backoffs": rec["backoffs"]},
                )
            rec["evicted_turn"] = self.pool_turn
            rec["turns"] = int(self.turns_done[slot])
            self.sid[slot] = -1
            self.slot_sel[slot] = 0
            self.slot_state[slot] = SLOT_EMPTY
        # freed rows stay in the device state until an admission overwrites
        # them; mark them done so a stale gather can never dispatch them
        # (fixed full-width index block: one compile key for any wave size)
        if slots.size:
            W = cfg.slots
            idx = np.full((_round_up(W, cfg.admit_block),), W, np.int32)
            idx[:slots.size] = slots
            self.state = _mark_done(self.state, jnp.asarray(idx))

    # -- the pool turn ------------------------------------------------------

    def step_pool(self):
        """One pool turn: admit → draw faults → dispatch survivors →
        corrupt → screen invariants → quarantine/evict → checkpoint."""
        cfg = self.cfg
        t = self.pool_turn
        self._admit()

        live = self.slot_state == SLOT_LIVE
        ready = live & (self.backoff_until <= t) & (self.straggle_until <= t)
        cand = np.flatnonzero(ready)

        dispatched = np.empty((0,), np.int64)
        corrupt_rows = np.empty((0,), np.int64)
        corrupt_kinds = np.empty((0,), np.int32)
        if cand.size:
            draws = self.schedule.draws(self.sid[cand], t)
            aborted = draws["dropout"] | draws["drop_msg"]
            straggle = (~aborted) & (draws["straggle"] > 0)
            go = ~aborted & ~straggle

            for i in np.flatnonzero(aborted):
                slot = cand[i]
                rec = self.sessions[self.sid[slot]]
                which = "dropouts" if draws["dropout"][i] else "drop_msgs"
                rec[which] += 1
                self.stats[which] += 1
                self.retries[slot] += 1
                rec["retries"] += 1
                self.stats["retries_total"] += 1
                if self.retries[slot] > cfg.retry_budget:
                    self._quarantine(slot, "retry_budget")
                else:
                    self.backoff_until[slot] = (
                        t + 1 + cfg.backoff_base
                        * (1 << (int(self.retries[slot]) - 1)))
                    rec["backoffs"] += 1
                    self.stats["backoffs_total"] += 1

            for i in np.flatnonzero(straggle):
                slot = cand[i]
                self.straggle_until[slot] = t + 1 + int(draws["straggle"][i])
                self.sessions[self.sid[slot]]["straggles"] += 1
                self.stats["straggles"] += 1

            dispatched = cand[go]
            if dispatched.size:
                self._dispatch(dispatched)
                self.retries[dispatched] = 0
                self.turns_done[dispatched] += 1
                for slot in dispatched:
                    self.sessions[self.sid[slot]]["turns"] = \
                        int(self.turns_done[slot])

            hit = go & (draws["corrupt"] >= 0)
            if hit.any():
                corrupt_rows = cand[hit]
                corrupt_kinds = draws["corrupt"][hit].astype(np.int32)
                self._corrupt(corrupt_rows, corrupt_kinds)
                self.stats["corruptions"] += int(corrupt_rows.size)
                for slot, kind in zip(corrupt_rows, corrupt_kinds):
                    self.sessions[self.sid[slot]]["corrupt_kind"] = int(kind)

        # -- supervision screen (one (5, W) transfer) -----------------------
        viewer = {"median": _view_median, "maxmarg": _view_maxmarg,
                  "unified": _view_unified}[cfg.selector]
        view = np.asarray(viewer(self.state))
        done, conv, fills, nan, bits = view
        live = self.slot_state == SLOT_LIVE       # minus fresh quarantines

        for slot in np.flatnonzero(live & (nan > 0)):
            self._quarantine(int(slot), "nan_separator")
        for slot in np.flatnonzero(live & (bits > cfg.comm_limit_bits)):
            if self.slot_state[slot] == SLOT_LIVE:
                self._quarantine(int(slot), "comm_blowout")
        disp_mask = np.zeros_like(live)
        disp_mask[dispatched] = True
        # every healthy continuing turn strictly grows some transcript, so
        # a dispatched live row whose max fill dropped, or failed to go (and
        # stay) positive, is corrupt
        bad_fill = disp_mask & live & (done == 0) \
            & ((fills < self.prev_fill) | (fills == 0))
        for slot in np.flatnonzero(bad_fill):
            if self.slot_state[slot] == SLOT_LIVE:
                self._quarantine(int(slot), "fill_regression")

        live = self.slot_state == SLOT_LIVE
        self.prev_fill[live] = np.maximum(self.prev_fill[live], fills[live])

        evict = np.flatnonzero(
            (self.slot_state == SLOT_QUARANTINED)
            | (live & (done > 0))
            | (live & (self.turns_done >= cfg.max_turns)))
        if evict.size:
            self._evict(evict)

        self.pool_turn += 1
        self.stats["pool_turns"] += 1
        if (cfg.checkpoint_every
                and self.pool_turn % cfg.checkpoint_every == 0):
            self.checkpoint(cfg.checkpoint_dir)

    def drained(self) -> bool:
        return not self.pending and not (self.slot_state == SLOT_LIVE).any()

    def run(self, max_pool_turns: Optional[int] = None) -> Dict[int, Any]:
        """Drive pool turns until every submitted session reaches a
        terminal status (or ``max_pool_turns`` elapse).  Returns the
        results ledger (sid -> ProtocolResult for cleanly-finished
        sessions; quarantined sids appear only in :meth:`session`)."""
        cfg = self.cfg
        if max_pool_turns is None:
            # worst case: every session serially pays its full turn budget
            # plus a full retry cycle's backoff per turn — generous, finite
            per_turn = 2 + cfg.backoff_base * (2 ** (cfg.retry_budget + 1)) \
                + self.schedule.straggle_max
            n_sessions = len(self.pending) + int(
                (self.slot_state != SLOT_EMPTY).sum())
            waves = max(1, -(-max(n_sessions, 1) // cfg.slots))
            max_pool_turns = max(64, waves * cfg.max_turns * per_turn)
        deadline = self.pool_turn + max_pool_turns
        while not self.drained() and self.pool_turn < deadline:
            self.step_pool()
        if not self.drained():
            raise RuntimeError(
                f"pool failed to drain within {max_pool_turns} pool turns "
                f"({(self.slot_state == SLOT_LIVE).sum()} live, "
                f"{len(self.pending)} pending)")
        return self.results

    # -- checkpoint / restore ----------------------------------------------

    def checkpoint(self, dirname: str) -> str:
        """Snapshot the whole pool — device trees, host supervision arrays,
        pending queue, session ledger, config+schedule — as one flat-key
        ``.npz`` plus a JSON manifest (the ``train/checkpoint.py`` idiom).
        The fault schedule is stateless, so the snapshot fully determines
        the remaining run."""
        from repro.train.checkpoint import _flatten

        os.makedirs(dirname, exist_ok=True)
        flat = _flatten({"data": self.data, "state": self.state})
        flat.update({
            "host/sid": self.sid, "host/slot_state": self.slot_state,
            "host/retries": self.retries,
            "host/backoff_until": self.backoff_until,
            "host/straggle_until": self.straggle_until,
            "host/prev_fill": self.prev_fill,
            "host/turns_done": self.turns_done,
            "host/slot_sel": self.slot_sel,
        })
        if self.pending:
            flat["pending/sid"] = np.asarray([p.sid for p in self.pending])
            flat["pending/X"] = np.stack([p.X for p in self.pending])
            flat["pending/y"] = np.stack([p.y for p in self.pending])
            flat["pending/budget"] = np.asarray(
                [p.budget for p in self.pending], np.int32)
            flat["pending/selector"] = np.asarray(
                [SELECTOR_CODES[p.selector] for p in self.pending], np.int32)
            flat["pending/seed"] = np.asarray(
                [p.seed for p in self.pending], np.int64)
            flat["pending/res_cap"] = np.asarray(
                [p.res_cap for p in self.pending], np.int32)
        path = os.path.join(dirname, f"pool_{self.pool_turn:08d}.npz")
        np.savez(path, **flat)

        results_json = {}
        for sid, r in self.results.items():
            results_json[str(sid)] = {
                "w": np.asarray(r.classifier.w, np.float64).tolist(),
                "b": float(r.classifier.b),
                "comm": r.comm, "rounds": r.rounds,
                "converged": r.converged, "extra": r.extra,
            }
        manifest = {
            "path": path,
            "pool_turn": self.pool_turn,
            "next_sid": self._next_sid,
            "config": dataclasses.asdict(self.cfg),
            "schedule": self.schedule.to_json(),
            "sessions": {str(k): v for k, v in self.sessions.items()},
            "results": results_json,
            "stats": {k: v for k, v in self.stats.items()
                      if isinstance(v, (int, float, str))},
        }
        with open(os.path.join(dirname, "latest.json"), "w") as f:
            json.dump(manifest, f)
        return path

    @classmethod
    def restore(cls, dirname: str) -> "SessionPool":
        """Rebuild a pool mid-stream from :meth:`checkpoint` output.
        Unaffected sessions resume bit-exact: device state re-uploads
        verbatim, supervision arrays and the stateless fault schedule
        replay the identical decision sequence."""
        from repro.core import classifiers as clf
        from repro.core.protocols.one_way import ProtocolResult

        with open(os.path.join(dirname, "latest.json")) as f:
            man = json.load(f)
        cfg = PoolConfig(**man["config"])
        pool = cls(cfg, F.FaultSchedule.from_json(man["schedule"]))
        z = np.load(man["path"])

        def leaf(tree, prefix):
            flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
            keys = ["/".join(str(getattr(kk, "key", getattr(kk, "idx", kk)))
                             for kk in path) for path, _ in flat]
            vals = [jnp.asarray(z[f"{prefix}/{key}"]) for key in keys]
            return jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(tree), vals)

        pool.data = leaf(pool.data, "data")
        pool.state = leaf(pool.state, "state")
        pool.sid = z["host/sid"]
        pool.slot_state = z["host/slot_state"]
        pool.retries = z["host/retries"]
        pool.backoff_until = z["host/backoff_until"]
        pool.straggle_until = z["host/straggle_until"]
        pool.prev_fill = z["host/prev_fill"]
        pool.turns_done = z["host/turns_done"]
        pool.slot_sel = z["host/slot_sel"]
        if "pending/sid" in z.files:
            for i, sid in enumerate(z["pending/sid"]):
                pool.pending.append(_Pending(
                    int(sid), z["pending/X"][i], z["pending/y"][i],
                    int(z["pending/budget"][i]),
                    selector=SELECTOR_NAMES[int(z["pending/selector"][i])],
                    seed=int(z["pending/seed"][i]),
                    res_cap=int(z["pending/res_cap"][i])))
        pool.pool_turn = man["pool_turn"]
        pool._next_sid = man["next_sid"]
        pool.sessions = {int(k): v for k, v in man["sessions"].items()}
        for sid, r in man["results"].items():
            pool.results[int(sid)] = ProtocolResult(
                clf.LinearSeparator(np.asarray(r["w"]), r["b"]),
                r["comm"], rounds=r["rounds"], converged=r["converged"],
                extra=r["extra"])
        for k, v in man["stats"].items():
            pool.stats[k] = v
        return pool


@jax.jit
def _mark_done(state, idx):
    """Pin freed slots done on device (out-of-range tail drops)."""
    return state._replace(
        done=state.done.at[idx].set(True),
        converged=state.converged.at[idx].set(False))
